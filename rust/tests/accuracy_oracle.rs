//! Differential accuracy oracle: SIMDive mul/div vs the exact arithmetic
//! in `arith::exact`, swept over the full accuracy-knob range `w ∈
//! 0..=W_MAX` (DESIGN.md §9).
//!
//! The 8-bit sweeps are *exhaustive* (every non-zero operand pair) and
//! therefore deterministic by construction; the 16/32-bit sweeps are
//! sampled with fixed `util::Rng` seeds. Errors follow the paper's §4.1
//! convention: real-valued behavioral outputs compared to the exact real
//! product/quotient, `|exact − approx| / exact`.
//!
//! The exhaustive sweeps are too slow for the debug-profile `cargo test
//! -q` tier, so they are `#[ignore]`d under `debug_assertions` and run by
//! the CI accuracy-oracle job in `--release` (where each completes in
//! well under a second).

use simdive::arith::simdive::{
    simdive_div_real_w, simdive_div_w, simdive_mul_real_w, simdive_mul_w,
};
use simdive::arith::{exact, W_MAX, WIDTHS};
use simdive::coordinator::{ErrorProfile, ReqOp};
use simdive::util::Rng;

/// Seed base for the sampled 16/32-bit sweeps.
const SEED_SAMPLED_SWEEP: u64 = 0x0AC1_E0_0D;

/// Seed for the paper-scenario divider sweep (16-bit dividend, 8-bit
/// divisor).
const SEED_DIV_16_8: u64 = 0x0D1_F168;

/// Mean and peak relative error of one `{op, bits, w}` point over an
/// operand-pair iterator, on real-valued outputs.
fn errors_over(
    is_div: bool,
    bits: u32,
    w: u32,
    pairs: impl Iterator<Item = (u64, u64)>,
) -> (f64, f64) {
    let (mut sum, mut peak, mut n) = (0.0f64, 0.0f64, 0u64);
    for (a, b) in pairs {
        let (exact, approx) = if is_div {
            (a as f64 / b as f64, simdive_div_real_w(bits, a, b, w))
        } else {
            // `exact::mul` is the repo's integer ground truth; 8/16-bit
            // products are exactly representable in f64.
            (exact::mul(bits, a, b) as f64, simdive_mul_real_w(bits, a, b, w))
        };
        let rel = (exact - approx).abs() / exact;
        sum += rel;
        peak = peak.max(rel);
        n += 1;
    }
    (sum / n as f64, peak)
}

fn exhaustive_8bit(is_div: bool, w: u32) -> (f64, f64) {
    errors_over(
        is_div,
        8,
        w,
        (1..256u64).flat_map(|a| (1..256u64).map(move |b| (a, b))),
    )
}

/// Assert a per-`w` error series improves monotonically (with `slack` for
/// quantization plateaus and sampling noise) and strongly end-to-end.
fn assert_improves(what: &str, series: &[f64], slack: f64, endpoint_ratio: f64) {
    for w in 0..series.len() - 1 {
        assert!(
            series[w + 1] <= series[w] * slack + 1e-12,
            "{what}: w={} ({:.5}) worse than w={w} ({:.5}) beyond slack {slack}",
            w + 1,
            series[w + 1],
            series[w]
        );
    }
    let (first, last) = (series[0], series[series.len() - 1]);
    assert!(
        last < first * endpoint_ratio,
        "{what}: full correction ({last:.5}) must land below {endpoint_ratio} × Mitchell ({first:.5})"
    );
}

#[cfg_attr(
    debug_assertions,
    ignore = "exhaustive 8-bit sweep; run in --release (CI accuracy-oracle job)"
)]
#[test]
fn exhaustive_8bit_mul_differential_sweep() {
    let mut mred = Vec::new();
    let mut peak = Vec::new();
    for w in 0..=W_MAX {
        let (m, p) = exhaustive_8bit(false, w);
        println!("mul8 w={w}: MRED {:.4}%, max {:.3}%", m * 100.0, p * 100.0);
        mred.push(m);
        peak.push(p);
    }
    // MRED must improve essentially monotonically with every extra LUT
    // and land far below Mitchell (w=0 ≈ 3.8%) at full correction.
    assert_improves("mul8 MRED", &mred, 1.05, 0.4);
    assert!(mred[W_MAX as usize] < 0.013, "mul8 full-w MRED {:.5}", mred[W_MAX as usize]);
    // Peak error improves too, though quantization makes it lumpier.
    assert_improves("mul8 max", &peak, 1.3, 0.8);
    assert!(peak[W_MAX as usize] < 0.09, "mul8 full-w peak {:.5}", peak[W_MAX as usize]);
}

#[cfg_attr(
    debug_assertions,
    ignore = "exhaustive 8-bit sweep; run in --release (CI accuracy-oracle job)"
)]
#[test]
fn exhaustive_8bit_div_differential_sweep() {
    let mut mred = Vec::new();
    let mut peak = Vec::new();
    for w in 0..=W_MAX {
        let (m, p) = exhaustive_8bit(true, w);
        println!("div8 w={w}: MRED {:.4}%, max {:.3}%", m * 100.0, p * 100.0);
        mred.push(m);
        peak.push(p);
    }
    assert_improves("div8 MRED", &mred, 1.05, 0.45);
    assert!(mred[W_MAX as usize] < 0.02, "div8 full-w MRED {:.5}", mred[W_MAX as usize]);
    assert_improves("div8 max", &peak, 1.3, 0.85);
    assert!(peak[W_MAX as usize] < 0.12, "div8 full-w peak {:.5}", peak[W_MAX as usize]);
}

#[cfg_attr(
    debug_assertions,
    ignore = "exhaustive integer sweep; run in --release (CI accuracy-oracle job)"
)]
#[test]
fn exhaustive_8bit_integer_outputs_track_real_oracle() {
    // The integer datapath (what the hardware emits) must track the
    // real-valued oracle within a floor plus internal fixed-point wiggle,
    // for every w and every non-zero operand pair — so the real-valued
    // sweeps above speak for the integer hardware too. Additionally, the
    // integer multiplier's exhaustive MRED vs `arith::exact` must stay in
    // the regime the unit tests pin (< 1.3% at full correction).
    let (mut int_sum, mut n) = (0.0f64, 0u64);
    for w in [0u32, 4, W_MAX] {
        for a in 1..256u64 {
            for b in 1..256u64 {
                let mr = simdive_mul_real_w(8, a, b, w);
                let mi = simdive_mul_w(8, a, b, w) as f64;
                assert!(
                    (mi - mr).abs() <= mr * 1e-9 + 1.5,
                    "mul {a}x{b} w={w}: int {mi} vs real {mr}"
                );
                let dr = simdive_div_real_w(8, a, b, w);
                let di = simdive_div_w(8, a, b, w) as f64;
                assert!(
                    (di - dr).abs() <= dr * 1e-9 + 1.5,
                    "div {a}/{b} w={w}: int {di} vs real {dr}"
                );
                if w == W_MAX {
                    let ex = exact::mul(8, a, b) as f64;
                    int_sum += (ex - mi).abs() / ex;
                    n += 1;
                }
            }
        }
    }
    let int_mred = int_sum / n as f64;
    println!("mul8 integer MRED {:.4}%", int_mred * 100.0);
    assert!(int_mred < 0.013, "mul8 integer MRED {int_mred:.5}");
}

#[test]
fn divider_mred_tracks_paper_table_claim() {
    // Paper Table 2, row "Proposed", divider scenario (16-bit dividend,
    // 8-bit divisor, quotient ≥ 1): MRED 0.77% with the paper's
    // optimized coefficients. This reproduction derives its coefficients
    // as region means of the ideal correction (DESIGN.md §4), which
    // lands ~0.3pp above the paper's figure — the same documented gap as
    // the multiplier ("≈98.9% vs the paper's >99.2%", report::tunable).
    // So the oracle pins the claim with the region-mean allowance: well
    // under 1.3%, and at least a 60% reduction of Mitchell's error.
    let sample = |w: u32| {
        let mut rng = Rng::new(SEED_DIV_16_8 ^ w as u64);
        let mut pairs = Vec::with_capacity(150_000);
        while pairs.len() < 150_000 {
            let a = rng.operand(16);
            let b = rng.operand(8);
            if a >= b {
                pairs.push((a, b));
            }
        }
        errors_over(true, 16, w, pairs.into_iter())
    };
    let (mitchell_mred, _) = sample(0);
    let (full_mred, full_peak) = sample(W_MAX);
    println!(
        "div 16/8: Mitchell MRED {:.3}%, full-w MRED {:.3}% (paper claims 0.77%), peak {:.2}%",
        mitchell_mred * 100.0,
        full_mred * 100.0,
        full_peak * 100.0
    );
    assert!(full_mred < 0.013, "full-correction div MRED {:.5}", full_mred);
    assert!(
        full_mred < 0.4 * mitchell_mred,
        "correction must remove ≥60% of Mitchell's divider error ({full_mred:.5} vs {mitchell_mred:.5})"
    );
    // Paper PRE for the divider is 5.24%; region-mean tables stay in the
    // same regime.
    assert!(full_peak < 0.08, "full-correction div peak {:.5}", full_peak);
}

#[test]
fn sampled_16_and_32_bit_sweeps_improve_with_w() {
    // Seeded sampled sweeps at the wider datapaths: the knob must behave
    // the same once the fraction resolution stops being the limiter.
    for &bits in &[16u32, 32] {
        for is_div in [false, true] {
            // One fixed operand set per {op, bits}, reused across every w
            // — the per-step comparison is then free of sampling noise.
            let mut rng =
                Rng::new(SEED_SAMPLED_SWEEP ^ ((bits as u64) << 16) ^ ((is_div as u64) << 8));
            let pairs: Vec<(u64, u64)> =
                (0..30_000).map(|_| (rng.operand(bits), rng.operand(bits))).collect();
            let mut mred = Vec::new();
            for w in 0..=W_MAX {
                let (m, _) = errors_over(is_div, bits, w, pairs.iter().copied());
                mred.push(m);
            }
            let what = format!("{}{bits} MRED", if is_div { "div" } else { "mul" });
            assert_improves(&what, &mred, 1.05, 0.5);
            assert!(mred[W_MAX as usize] < 0.016, "{what} at full w: {:.5}", mred[W_MAX as usize]);
        }
    }
}

#[test]
fn oracle_agrees_with_the_router_profile() {
    // The error-budget router picks `w` from `ErrorProfile`'s table; that
    // table must describe the same arithmetic this oracle measures. Spot
    // check the sampled 16-bit mul entries against an independent seeded
    // measurement: same regime (within 15% relative — different seeds,
    // 20k vs 30k samples), identical ordering at the endpoints.
    let p = ErrorProfile::get();
    for w in [0u32, 4, W_MAX] {
        let mut rng = Rng::new(SEED_SAMPLED_SWEEP ^ 0xFACE ^ w as u64);
        let pairs = (0..30_000).map(|_| (rng.operand(16), rng.operand(16)));
        let (m, _) = errors_over(false, 16, w, pairs);
        let profiled = p.mred_ppm(ReqOp::Mul, 16, w) as f64 / 1e6;
        assert!(
            (m - profiled).abs() < 0.15 * m.max(profiled),
            "w={w}: oracle {m:.5} vs profile {profiled:.5}"
        );
    }
    for &bits in &WIDTHS {
        for op in [ReqOp::Mul, ReqOp::Div] {
            assert!(
                p.mred_ppm(op, bits, W_MAX) < p.mred_ppm(op, bits, 0),
                "{op:?}@{bits}: profile must improve with w"
            );
        }
    }
}
