//! Property tests for the observability layer (DESIGN.md §12): log2
//! histogram percentile invariants over randomized sample sets, the
//! snapshot-vs-writer race the bucket-sum rank derivation fixes,
//! registry instance merging, and trace-ring sampling determinism.

use simdive::obs::registry::{bucket_of, HIST_BUCKETS};
use simdive::obs::{Hist, HistSnapshot, Registry, TraceRing, Value};
use simdive::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Random sample set spanning ns..ms magnitudes (log-uniform-ish: a
/// random bit width, then a random value at that width).
fn random_samples(rng: &mut Rng, n: usize) -> Vec<u64> {
    (0..n)
        .map(|_| {
            let width = 1 + rng.below(40);
            rng.below(1u64 << width)
        })
        .collect()
}

#[test]
fn percentiles_are_monotone_in_p() {
    let mut rng = Rng::new(0x0B5_0001);
    for case in 0..50 {
        let h = Hist::new();
        for s in random_samples(&mut rng, 1 + case * 7) {
            h.record_ns(s);
        }
        let ps = [0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999, 1.0];
        for pair in ps.windows(2) {
            let (lo, hi) = (h.percentile_us(pair[0]), h.percentile_us(pair[1]));
            assert!(lo <= hi, "case {case}: p{} = {lo} > p{} = {hi}", pair[0], pair[1]);
        }
    }
}

#[test]
fn percentile_is_bounded_by_twice_the_true_max() {
    let mut rng = Rng::new(0x0B5_0002);
    for case in 0..50 {
        let samples = random_samples(&mut rng, 1 + case * 11);
        let max_ns = *samples.iter().max().unwrap();
        let h = Hist::new();
        for &s in &samples {
            h.record_ns(s);
        }
        for p in [0.5, 0.99, 1.0] {
            let reported_us = h.percentile_us(p);
            // Bucket upper bound is 2^{i+1} − 1 < 2 × sample, and floor
            // division to µs preserves ≤.
            assert!(
                reported_us <= (2 * max_ns) / 1000,
                "case {case}: p{p} reported {reported_us} µs, true max {max_ns} ns"
            );
        }
    }
}

#[test]
fn p100_lands_in_the_max_samples_bucket() {
    let mut rng = Rng::new(0x0B5_0003);
    for case in 0..50 {
        let samples = random_samples(&mut rng, 1 + case * 5);
        let max_ns = *samples.iter().max().unwrap();
        let h = Hist::new();
        for &s in &samples {
            h.record_ns(s);
        }
        let i = bucket_of(max_ns);
        let bucket_upper_us = ((1u64 << (i + 1)) - 1) / 1000;
        assert_eq!(
            h.percentile_us(1.0),
            bucket_upper_us,
            "case {case}: p100 must report the max sample's bucket (max {max_ns} ns, bucket {i})"
        );
    }
}

#[test]
fn empty_hist_reports_zero_everywhere() {
    let h = Hist::new();
    assert_eq!(h.count(), 0);
    for p in [0.01, 0.5, 1.0] {
        assert_eq!(h.percentile_us(p), 0);
    }
}

/// The race the bucket-sum rank derivation fixes: percentile reads
/// concurrent with relaxed-atomic writers must never hit the
/// `unreachable!` (a rank beyond the observed sum) and never panic. With
/// a separately-maintained total count, a reader could observe the count
/// increment before the bucket increment and walk off the end.
#[test]
fn percentile_never_panics_under_concurrent_writers() {
    let h = Arc::new(Hist::new());
    let stop = Arc::new(AtomicBool::new(false));
    let writers: Vec<_> = (0..4u64)
        .map(|t| {
            let h = Arc::clone(&h);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = Rng::new(0x0B5_1000 + t);
                while !stop.load(Ordering::Relaxed) {
                    h.record_ns(rng.below(1u64 << 30));
                }
            })
        })
        .collect();
    for _ in 0..20_000 {
        let snap = h.snapshot();
        let p100 = snap.percentile_us(1.0);
        assert!(p100 >= snap.percentile_us(0.5));
    }
    stop.store(true, Ordering::Relaxed);
    for w in writers {
        w.join().unwrap();
    }
    assert!(h.count() > 0);
}

#[test]
fn snapshot_merge_is_bucketwise_and_percentile_agrees_with_pooled() {
    let mut rng = Rng::new(0x0B5_0004);
    let (a, b) = (Hist::new(), Hist::new());
    let pooled = Hist::new();
    for _ in 0..500 {
        let s = rng.below(1u64 << 34);
        if rng.below(2) == 0 {
            a.record_ns(s);
        } else {
            b.record_ns(s);
        }
        pooled.record_ns(s);
    }
    let mut merged = a.snapshot();
    merged.merge(&b.snapshot());
    assert_eq!(merged, pooled.snapshot());
    assert_eq!(merged.count(), 500);
    for p in [0.5, 0.99, 1.0] {
        assert_eq!(merged.percentile_us(p), pooled.percentile_us(p));
    }
}

#[test]
fn registry_merges_instances_and_sorts_entries() {
    let reg = Registry::new();
    // Two per-shard counter instances plus the shared get-or-create
    // handle; the snapshot must report one summed entry.
    let c0 = reg.counter_instance("pool.requests");
    let c1 = reg.counter_instance("pool.requests");
    c0.add(7);
    c1.add(5);
    let h0 = reg.hist_instance("pool.stage");
    let h1 = reg.hist_instance("pool.stage");
    h0.record_ns(10);
    h1.record_ns(1 << 20);
    reg.gauge("a.depth").set(3);
    let snap = reg.snapshot();
    assert_eq!(snap.counter("pool.requests"), Some(12));
    assert_eq!(snap.gauge("a.depth"), Some(3));
    let merged = snap.hist("pool.stage").expect("hist entry");
    assert_eq!(merged.count(), 2);
    assert_eq!(merged.buckets[bucket_of(10)], 1);
    assert_eq!(merged.buckets[bucket_of(1 << 20)], 1);
    let names: Vec<&str> = snap.entries.iter().map(|(n, _)| n.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted, "snapshot entries must be name-sorted");
}

#[test]
fn histsnapshot_value_roundtrips_through_snapshot_accessors() {
    let mut snap = simdive::obs::Snapshot::default();
    let mut h = HistSnapshot::default();
    h.buckets[HIST_BUCKETS - 1] = 3;
    snap.push("x.hist", Value::Hist(h));
    snap.push("x.counter", Value::Counter(9));
    assert_eq!(snap.hist("x.hist").unwrap().count(), 3);
    assert_eq!(snap.counter("x.hist"), None, "type-mismatched accessor must return None");
    assert_eq!(snap.counter("x.counter"), Some(9));
}

#[test]
fn trace_ring_sampling_is_seed_deterministic() {
    let a = TraceRing::new(64, 16, 0xDECADE);
    let b = TraceRing::new(64, 16, 0xDECADE);
    let c = TraceRing::new(64, 16, 0xDECADE + 1);
    let decisions_a: Vec<bool> = (0..4096).map(|_| a.sample()).collect();
    let decisions_b: Vec<bool> = (0..4096).map(|_| b.sample()).collect();
    let decisions_c: Vec<bool> = (0..4096).map(|_| c.sample()).collect();
    assert_eq!(decisions_a, decisions_b, "same seed must sample identically");
    assert_ne!(decisions_a, decisions_c, "different seed must diverge");
    let hits = decisions_a.iter().filter(|&&s| s).count();
    // 1-in-16 seeded sampling over 4096 admissions: loosely around 256.
    assert!((64..=1024).contains(&hits), "sampling rate wildly off: {hits}/4096");
}
