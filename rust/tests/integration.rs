//! Cross-layer integration: behavioral arithmetic → metrics → image/ANN
//! substrates → report harness, exercised together.

use simdive::arith::{DivDesign, MulDesign};
use simdive::image::{blend, synth, ArithKind};
use simdive::metrics::{div_error, mul_error, psnr};

#[test]
fn full_error_pipeline_matches_paper_shape() {
    // Table-2 orderings at evaluation scale (robust at 200k samples).
    let n = 200_000;
    let prop = mul_error(MulDesign::Simdive { w: 8 }, 16, n, 1).are_pct;
    let mbm = mul_error(MulDesign::Mbm, 16, n, 1).are_pct;
    let mit = mul_error(MulDesign::Mitchell, 16, n, 1).are_pct;
    assert!(prop < mbm && mbm < mit, "{prop} {mbm} {mit}");
    let dprop = div_error(DivDesign::Simdive { w: 8 }, 16, 8, n, 2).are_pct;
    let dinz = div_error(DivDesign::Inzed, 16, 8, n, 2).are_pct;
    assert!(dprop < dinz, "{dprop} {dinz}");
}

#[test]
fn image_pipeline_end_to_end() {
    let a = synth::generate(synth::Scene::Texture, 96, 1);
    let b = synth::generate(synth::Scene::Shapes, 96, 2);
    let acc = blend(&a, &b, ArithKind::Accurate);
    let sd = blend(&a, &b, ArithKind::Simdive(8));
    assert!(psnr(&acc.data, &sd.data) > 35.0);
}

#[test]
fn ann_pipeline_end_to_end() {
    use simdive::ann::{Mlp, QuantMlp};
    use simdive::datasets::{generate, Family};
    let train = generate(Family::Digits, 1500, 3);
    let test = generate(Family::Digits, 300, 4);
    let mut net = Mlp::new(&[32], 5);
    net.train(&train, 4, 0.1, 6);
    let q = QuantMlp::from_float(&net, &train[..200]);
    let qa = q.accuracy(&test, &simdive::engine::Engine::from_mul(MulDesign::Accurate));
    let qs = q.accuracy(&test, &simdive::engine::Engine::simdive(8));
    assert!(qa > 0.6, "accurate quantized {qa}");
    assert!((qa - qs).abs() < 0.06, "simdive {qs} vs accurate {qa}");
}

#[test]
fn headline_divider_claim() {
    // §4.2: proposed divider ≈4× faster / 4.6× less energy than accurate
    // IP — check the calibrated-model prediction reproduces the direction
    // with at least a 2.5× margin on both axes.
    use simdive::circuits::{baselines, simdive as sdc};
    use simdive::fabric::{calibrate, power, timing};
    let cal = calibrate::fitted();
    let acc = baselines::restoring_div(16, 8);
    let prop = sdc::div(16, 8, 8);
    let t_acc = timing::analyze(&acc, cal).critical_ns;
    let t_prop = timing::analyze(&prop, cal).critical_ns;
    assert!(t_acc / t_prop > 2.5, "speedup {}", t_acc / t_prop);
    let e_acc = power::estimate_at(&acc, cal, 1, 2048, t_acc).total_mw * t_acc;
    let e_prop = power::estimate_at(&prop, cal, 1, 2048, t_prop).total_mw * t_prop;
    assert!(e_acc / e_prop > 2.0, "energy gain {}", e_acc / e_prop);
}

#[test]
fn golden_export_runs() {
    std::env::set_var(
        "SIMDIVE_ARTIFACTS",
        std::env::temp_dir().join("simdive_it_golden"),
    );
    let msg = simdive::report::golden::export().unwrap();
    assert!(msg.contains("exported"));
    std::env::remove_var("SIMDIVE_ARTIFACTS");
}
