//! SWAR kernel properties (DESIGN.md §13): the packed 4×8-bit datapath is
//! bit-identical to the scalar SIMDive models on every lane, no carry or
//! borrow ever leaks between packed lanes, the guard-bit invariants hold
//! through every pipeline stage, and the batch entry points agree with
//! their lane-wise forms at every `{bits, w}` tier — including the
//! off-budget-table fallback.

use simdive::arith::simdive::{simdive_div_with, simdive_mul_with};
use simdive::arith::swar::{mul_lane_mask, pack4, spread_bytes, unpack4, Swar8};
use simdive::arith::table::{tables_for, CorrectionTables};
use simdive::arith::{
    div_batch_into, div_batch_lanewise_into, mul_batch_into, mul_batch_lanewise_into, LaneMode,
    W_MAX, WIDTHS,
};
use simdive::util::Rng;

/// Deterministic seeds, one per property (replayable from a failure).
const SEED_RANDOM: u64 = 0x54A0;
const SEED_MIXED: u64 = 0x54A1;
const SEED_BATCH: u64 = 0x54A2;

/// Assert every lane of a packed mul and div result against the scalar
/// model — the operative definition of "no lane leaks": if any carry,
/// borrow, or shift crossed a 16-bit field boundary, some lane's value
/// would differ from its independently computed scalar twin.
fn assert_lanes_match_scalar(t: &CorrectionTables, k: &Swar8, a: &[u64; 4], b: &[u64; 4]) {
    let (a4, b4) = (pack4(a), pack4(b));
    let mut m = [0u64; 4];
    let mut d = [0u64; 4];
    unpack4(k.mul4(a4, b4), &mut m);
    unpack4(k.div4(a4, b4), &mut d);
    for l in 0..4 {
        assert_eq!(
            m[l],
            simdive_mul_with(t, 8, a[l], b[l]),
            "mul lane {l} of {a:?}*{b:?} (w={})",
            t.w
        );
        assert_eq!(
            d[l],
            simdive_div_with(t, 8, a[l], b[l]),
            "div lane {l} of {a:?}/{b:?} (w={})",
            t.w
        );
    }
}

/// The adversarial lane patterns the issue calls out, plus the
/// carry-heaviest neighbours: every lane zero, every lane max,
/// alternating zero/max both ways, and the 127/128 boundary where the
/// leading-one position flips.
const ADVERSARIAL: [[u64; 4]; 9] = [
    [0, 0, 0, 0],
    [255, 255, 255, 255],
    [0, 255, 0, 255],
    [255, 0, 255, 0],
    [127, 128, 127, 128],
    [1, 255, 1, 255],
    [0, 1, 254, 255],
    [128, 128, 128, 128],
    [1, 1, 1, 1],
];

#[test]
fn lane_isolation_adversarial_patterns_all_w() {
    for w in 0..=W_MAX {
        let t = tables_for(w);
        let k = Swar8::try_new(t).expect("generated tables fit the SWAR budget");
        for a in &ADVERSARIAL {
            for b in &ADVERSARIAL {
                assert_lanes_match_scalar(t, &k, a, b);
            }
        }
    }
}

#[test]
fn lane_isolation_random_patterns_all_w() {
    let mut rng = Rng::new(SEED_RANDOM);
    for w in 0..=W_MAX {
        let t = tables_for(w);
        let k = Swar8::try_new(t).unwrap();
        for _ in 0..4_000 {
            let a = std::array::from_fn(|_| rng.below(256));
            let b = std::array::from_fn(|_| rng.below(256));
            assert_lanes_match_scalar(t, &k, &a, &b);
        }
    }
}

#[test]
fn mixed_mode_words_select_per_lane() {
    // Every one of the 16 mul/div lane-mode combinations, against the
    // per-lane scalar model — the word path the sharded engine executes.
    let mut rng = Rng::new(SEED_MIXED);
    for w in [0u32, 4, 8] {
        let t = tables_for(w);
        let k = Swar8::try_new(t).unwrap();
        for mode_bits in 0..16u32 {
            let modes: [LaneMode; 4] = std::array::from_fn(|i| {
                if (mode_bits >> i) & 1 == 0 { LaneMode::Mul } else { LaneMode::Div }
            });
            let mask = mul_lane_mask(&modes);
            for _ in 0..400 {
                let a: [u64; 4] = std::array::from_fn(|_| rng.below(256));
                let b: [u64; 4] = std::array::from_fn(|_| rng.below(256));
                let mut got = [0u64; 4];
                unpack4(k.exec4(mask, pack4(&a), pack4(&b)), &mut got);
                for l in 0..4 {
                    let want = match modes[l] {
                        LaneMode::Mul => simdive_mul_with(t, 8, a[l], b[l]),
                        LaneMode::Div => simdive_div_with(t, 8, a[l], b[l]),
                    };
                    assert_eq!(got[l], want, "lane {l} modes={mode_bits:04b} w={w}");
                }
            }
        }
    }
}

#[test]
fn staged_pipeline_guard_bit_invariants() {
    // The decode-stage invariants every later stage's carry/borrow-freedom
    // argument rests on (DESIGN.md §13): each normalized field is an 8-bit
    // value with its leading one at bit 7, each shift count is at most 7,
    // zero-lane masks are exact full-field masks, and the operand spread
    // leaves all guard bits clear.
    let mut rng = Rng::new(SEED_RANDOM ^ 1);
    let patterns = ADVERSARIAL
        .iter()
        .copied()
        .chain((0..2_000).map(|_| std::array::from_fn(|_| rng.below(256))))
        .collect::<Vec<[u64; 4]>>();
    for a in &patterns {
        for b in patterns.iter().take(16) {
            let (a4, b4) = (pack4(a), pack4(b));
            // The operand spread (packed Four8 bytes → 16-bit SWAR fields)
            // must leave every guard byte clear.
            let packed32 = (a[0] | (a[1] << 8) | (a[2] << 16) | (a[3] << 24)) as u32;
            assert_eq!(spread_bytes(packed32), a4);
            let dec = Swar8::decode4(a4, b4);
            for l in 0..4 {
                let sh = 16 * l;
                let (nv1, sa) = ((dec.nv1 >> sh) & 0xFFFF, (dec.sa >> sh) & 0xFFFF);
                let (nv2, sb) = ((dec.nv2 >> sh) & 0xFFFF, (dec.sb >> sh) & 0xFFFF);
                assert!((0x80..=0xFF).contains(&nv1), "nv1 lane {l}: {nv1:#x}");
                assert!((0x80..=0xFF).contains(&nv2), "nv2 lane {l}: {nv2:#x}");
                assert!(sa <= 7, "sa lane {l}: {sa}");
                assert!(sb <= 7, "sb lane {l}: {sb}");
                let anz = (dec.anz >> sh) & 0xFFFF;
                let bnz = (dec.bnz >> sh) & 0xFFFF;
                assert_eq!(anz, if a[l] == 0 { 0 } else { 0xFFFF }, "anz lane {l}");
                assert_eq!(bnz, if b[l] == 0 { 0 } else { 0xFFFF }, "bnz lane {l}");
            }
        }
    }
}

#[test]
fn batch_entries_agree_with_lanewise_every_tier() {
    // The public batch entry points (SWAR-accelerated at 8-bit) must be
    // indistinguishable from the lane-wise forms at every {bits, w} tier,
    // zeros included, for every slice length mod 4.
    let mut rng = Rng::new(SEED_BATCH);
    for &bits in &WIDTHS {
        for w in 0..=W_MAX {
            let t = tables_for(w);
            for len in [1usize, 3, 4, 6, 257] {
                let mut a: Vec<u64> = (0..len).map(|_| rng.below(1u64 << bits)).collect();
                let b: Vec<u64> = (0..len).map(|_| rng.below(1u64 << bits)).collect();
                a[0] = 0;
                let mut fast = vec![0u64; len];
                let mut lane = vec![0u64; len];
                mul_batch_into(t, bits, &a, &b, &mut fast);
                mul_batch_lanewise_into(t, bits, &a, &b, &mut lane);
                assert_eq!(fast, lane, "mul bits={bits} w={w} len={len}");
                div_batch_into(t, bits, &a, &b, &mut fast);
                div_batch_lanewise_into(t, bits, &a, &b, &mut lane);
                assert_eq!(fast, lane, "div bits={bits} w={w} len={len}");
            }
        }
    }
}

#[test]
fn off_budget_tables_fall_back_lanewise() {
    // A hand-built grid outside the SWAR guard-bit budget must be
    // rejected by the packed kernel and still produce scalar-identical
    // results through the batch entry points (which silently fall back).
    let big = CorrectionTables::from_grids(8, [[32_768; 8]; 8], [[-32_768; 8]; 8]);
    assert!(Swar8::try_new(&big).is_none(), "off-budget grid must not build a SWAR kernel");
    let a: Vec<u64> = (0..256).collect();
    let b: Vec<u64> = (0..256).rev().collect();
    let mut got = vec![0u64; a.len()];
    mul_batch_into(&big, 8, &a, &b, &mut got);
    for i in 0..a.len() {
        assert_eq!(got[i], simdive_mul_with(&big, 8, a[i], b[i]), "mul {i}");
    }
    div_batch_into(&big, 8, &a, &b, &mut got);
    for i in 0..a.len() {
        assert_eq!(got[i], simdive_div_with(&big, 8, a[i], b[i]), "div {i}");
    }
}

#[test]
fn exhaustive_all_pairs_default_tables() {
    // Every (a, b) ∈ 256×256 through the packed kernel at the paper's
    // default accuracy — the same exhaustive sweep the scalar model gets
    // in `arith::batch`, now for the SWAR path.
    let t = tables_for(8);
    let k = Swar8::try_new(t).unwrap();
    for a0 in 0..256u64 {
        let a = [a0, a0 ^ 0xFF, (a0 + 85) & 0xFF, (a0 * 3) & 0xFF];
        for b0 in 0..256u64 {
            let b = [b0, (b0 + 1) & 0xFF, b0 ^ 0xAA, (255 - b0) & 0xFF];
            assert_lanes_match_scalar(t, &k, &a, &b);
        }
    }
}
