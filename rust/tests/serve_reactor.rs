//! Reactor-backend integration tests (DESIGN.md §15): correctness on the
//! portable `poll(2)` fallback and multi-loop configurations, admission
//! fairness under a greedy connection, bounded-drain shutdown on both
//! backends, and the O(1)-threads property the reactor exists for.
//!
//! Bit-identity and protocol conformance of the default backend are
//! covered by `serve_e2e.rs` (which now runs on the reactor); this file
//! covers what is *different* about the reactor.

use simdive::arith::{batch, table};
use simdive::coordinator::ReqOp;
use simdive::serve::{Client, ReactorOptions, ServeConfig, Server, WireRequest};
use simdive::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Ground truth: the batched kernel result at the request's own `{bits, w}`.
fn expect_one(r: &WireRequest) -> u64 {
    let t = table::tables_for(r.w);
    match r.op {
        ReqOp::Mul => batch::mul_batch(t, r.bits, &[r.a], &[r.b])[0],
        ReqOp::Div => batch::div_batch(t, r.bits, &[r.a], &[r.b])[0],
    }
}

fn random_request(rng: &mut Rng, id: u64) -> WireRequest {
    let bits = [8u32, 8, 8, 16, 16, 32][rng.below(6) as usize];
    WireRequest {
        id,
        op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
        bits,
        w: rng.below(simdive::arith::W_MAX as u64 + 1) as u32,
        budget_ppm: 0,
        a: rng.operand(bits),
        b: rng.operand(bits),
    }
}

/// The portable fallback poller and a multi-loop pool must be
/// bit-identical to the kernels — same acceptance bar as the epoll path.
#[test]
fn poll_fallback_multi_loop_is_bit_identical() {
    let server = Server::start_reactor(
        "127.0.0.1:0",
        ServeConfig::default(),
        ReactorOptions { loops: 2, force_poll_fallback: true },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut handles = Vec::new();
    for conn in 0..3u64 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap().with_chunk(64);
            let mut rng = Rng::new(0xFA11_BACC + conn);
            let reqs: Vec<WireRequest> =
                (0..1_000).map(|i| random_request(&mut rng, i)).collect();
            let resps = client.exchange(&reqs).unwrap();
            for (req, resp) in reqs.iter().zip(&resps) {
                assert_eq!(resp.id, req.id);
                assert_eq!(resp.value, expect_one(req), "conn {conn} req {}", req.id);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.stats().requests, 3 * 1_000);
    server.shutdown();
}

/// Admission fairness: a greedy connection pipelining deep windows must
/// not starve a low-rate tenant. Per-connection quotas bound the
/// tenant's per-call latency even while the greedy stream saturates the
/// engine; the old global window serialized them behind each other.
#[test]
fn greedy_connection_does_not_starve_low_rate_tenant() {
    let server = Server::start(
        "127.0.0.1:0",
        ServeConfig { window: 64, ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();
    let stop = Arc::new(AtomicBool::new(false));
    let greedy = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap().with_chunk(256);
            let mut rng = Rng::new(0x6EED);
            let mut id = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let reqs: Vec<WireRequest> = (0..512)
                    .map(|_| {
                        id += 1;
                        WireRequest {
                            id,
                            op: ReqOp::Div,
                            bits: 32,
                            w: 8,
                            budget_ppm: 0,
                            a: rng.operand(32),
                            b: rng.operand(32),
                        }
                    })
                    .collect();
                client.exchange(&reqs).unwrap();
            }
        })
    };
    // Low-rate tenant: single synchronous calls, a pause between each —
    // the workload shape most exposed to head-of-line blocking.
    let mut tenant = Client::connect(addr).unwrap();
    let mut worst = Duration::ZERO;
    for i in 0..40u64 {
        let req = WireRequest {
            id: i,
            op: ReqOp::Mul,
            bits: 8,
            w: 4,
            budget_ppm: 0,
            a: 43,
            b: 10,
        };
        let t0 = Instant::now();
        let resp = tenant.call(req).unwrap();
        worst = worst.max(t0.elapsed());
        assert_eq!(resp.value, expect_one(&req));
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        worst < Duration::from_micros(1_500_000),
        "low-rate tenant p99 blew the bound under a greedy neighbor: worst {worst:?}"
    );
    // The admit stage must be live on the reactor path (fair admission is
    // what this test exercises, and its latency is the observable).
    let snap = tenant.stats2().unwrap();
    let admit = snap.hist("stage.admit").expect("stage.admit histogram missing");
    assert!(admit.count() > 0, "no admissions recorded under load");
    stop.store(true, Ordering::Relaxed);
    greedy.join().unwrap();
    server.shutdown();
}

/// `shutdown` must wake live reactor connections and return within the
/// bounded drain deadline — not hang until clients go away.
#[test]
fn reactor_shutdown_drains_live_connections_within_deadline() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let addr = server.local_addr();
    let mut clients = Vec::new();
    for i in 0..4u64 {
        let mut c = Client::connect(addr).unwrap();
        let req = WireRequest {
            id: i,
            op: ReqOp::Mul,
            bits: 8,
            w: 8,
            budget_ppm: 0,
            a: 43,
            b: 10,
        };
        c.call(req).unwrap();
        clients.push(c); // held open and idle across shutdown
    }
    let t0 = Instant::now();
    server.shutdown();
    let took = t0.elapsed();
    assert!(took < Duration::from_secs(5), "shutdown took {took:?} with live connections");
    // The server really is gone: the held connections are dead.
    let req =
        WireRequest { id: 99, op: ReqOp::Mul, bits: 8, w: 8, budget_ppm: 0, a: 1, b: 1 };
    assert!(clients[0].call(req).is_err(), "connection survived shutdown");
}

/// Regression for the threaded backend: its per-connection reader threads
/// used to park in blocking reads until io-timeout, leaving `shutdown` to
/// wait out the timeout. The connection registry must wake them.
#[test]
fn threaded_shutdown_drains_live_connections_within_deadline() {
    let server = Server::start_threaded(
        "127.0.0.1:0",
        // Long io-timeout on purpose: a drain that waits for reads to
        // time out would blow the assertion below.
        ServeConfig { io_timeout_ms: 120_000, ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut clients = Vec::new();
    for i in 0..4u64 {
        let mut c = Client::connect(addr).unwrap();
        let req = WireRequest {
            id: i,
            op: ReqOp::Mul,
            bits: 8,
            w: 8,
            budget_ppm: 0,
            a: 43,
            b: 10,
        };
        c.call(req).unwrap();
        clients.push(c);
    }
    let t0 = Instant::now();
    server.shutdown();
    let took = t0.elapsed();
    assert!(took < Duration::from_secs(5), "threaded shutdown took {took:?}");
    let req =
        WireRequest { id: 99, op: ReqOp::Mul, bits: 8, w: 8, budget_ppm: 0, a: 1, b: 1 };
    assert!(clients[0].call(req).is_err(), "connection survived shutdown");
}

/// The acceptance criterion the tentpole is named for: reactor server
/// threads are a function of the pool size, not the connection count.
#[test]
fn reactor_thread_count_is_independent_of_connections() {
    let server = Server::start_reactor(
        "127.0.0.1:0",
        ServeConfig::default(),
        ReactorOptions { loops: 2, ..ReactorOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr();
    let before = server.thread_count();
    assert_eq!(before, 1 + 2 * 2, "accept + per-loop (event loop, pump)");
    let mut clients = Vec::new();
    for i in 0..32u64 {
        let mut c = Client::connect(addr).unwrap();
        let req = WireRequest {
            id: i,
            op: ReqOp::Mul,
            bits: 8,
            w: 8,
            budget_ppm: 0,
            a: 43,
            b: 10,
        };
        c.call(req).unwrap();
        clients.push(c);
    }
    assert_eq!(
        server.thread_count(),
        before,
        "reactor thread count must not grow with connections"
    );
    assert!(server.thread_count() <= 1 + 2 * 16, "thread pool exceeded its cap");
    drop(clients);
    server.shutdown();
}

/// The baseline it replaces: thread-per-connection spends two OS threads
/// per live connection (the `connections_sweep` contrast in
/// `BENCH_serve.json`).
#[test]
fn threaded_thread_count_grows_with_connections() {
    let server = Server::start_threaded("127.0.0.1:0", ServeConfig::default()).unwrap();
    let addr = server.local_addr();
    let mut clients = Vec::new();
    for i in 0..8u64 {
        let mut c = Client::connect(addr).unwrap();
        let req = WireRequest {
            id: i,
            op: ReqOp::Mul,
            bits: 8,
            w: 8,
            budget_ppm: 0,
            a: 43,
            b: 10,
        };
        c.call(req).unwrap();
        clients.push(c);
    }
    assert!(
        server.thread_count() >= 1 + 2 * 8,
        "threaded backend should cost two threads per connection, got {}",
        server.thread_count()
    );
    drop(clients);
    server.shutdown();
}

/// Loadgen's fd preflight must fail fast with an error that tells the
/// operator exactly what to run.
#[test]
fn fd_capacity_preflight_names_ulimit() {
    assert!(simdive::serve::ensure_fd_capacity(8).is_ok());
    let err = simdive::serve::ensure_fd_capacity(u64::MAX - 1).unwrap_err();
    assert!(err.contains("ulimit -n"), "error must name the fix: {err}");
}
