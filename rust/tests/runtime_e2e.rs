//! PJRT end-to-end: load the AOT artifacts and verify the served graphs
//! bit-match the Rust behavioral models. Skips (cleanly) when artifacts
//! have not been built (`make artifacts`). Compiled only with the `pjrt`
//! feature — the default offline build has no xla bindings (DESIGN.md §2).
#![cfg(feature = "pjrt")]

use std::path::Path;

fn bytes_of(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn engine() -> Option<simdive::runtime::Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("blend.hlo.txt").exists() {
        eprintln!("skipping runtime e2e: run `make artifacts` first");
        return None;
    }
    Some(simdive::runtime::Engine::load(dir).expect("engine"))
}

#[test]
fn served_blend_bit_matches_behavioral() {
    let Some(eng) = engine() else { return };
    let mut rng = simdive::util::Rng::new(5);
    let a: Vec<i32> = (0..256 * 256).map(|_| rng.below(256) as i32).collect();
    let b: Vec<i32> = (0..256 * 256).map(|_| rng.below(256) as i32).collect();
    let la = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[256, 256],
        bytes_of(&a),
    )
    .unwrap();
    let lb = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[256, 256],
        bytes_of(&b),
    )
    .unwrap();
    let out = eng.run("blend", &[la, lb]).unwrap();
    let got = out[0].to_vec::<i32>().unwrap();
    for i in 0..a.len() {
        let want =
            (simdive::arith::simdive::simdive_mul(8, a[i] as u64, b[i] as u64) >> 8).min(255);
        assert_eq!(got[i] as u64, want, "px {i}: {}x{}", a[i], b[i]);
    }
}

#[test]
fn served_ann_is_accurate_on_eval_batch() {
    let Some(eng) = engine() else { return };
    let imgs = std::fs::read("artifacts/eval_batch.u8").unwrap();
    let labels = std::fs::read("artifacts/eval_labels.u8").unwrap();
    let vals: Vec<i32> = imgs.iter().map(|&v| v as i32).collect();
    let lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[32, 784],
        bytes_of(&vals),
    )
    .unwrap();
    let out = eng.run("ann_fwd", std::slice::from_ref(&lit)).unwrap();
    let preds = out[1].to_vec::<i64>().unwrap();
    let correct = preds.iter().zip(&labels).filter(|(&p, &l)| p == l as i64).count();
    // The quantized SIMDive model classifies its own eval batch well.
    assert!(correct >= 28, "served accuracy {correct}/32");
}

#[test]
fn engine_reports_weights() {
    let Some(eng) = engine() else { return };
    assert!(eng.weight("w0").is_some());
    assert!(eng.weight_manifest().len() >= 4);
}
