//! Gate-level ↔ behavioral equivalence across every design (the fabric
//! substitution's core validity argument): exhaustive at 8-bit for the
//! proposed units, sampled at 16/32-bit for all.

use simdive::arith;
use simdive::circuits::{baselines, mitchell, simdive as sdc};
use simdive::fabric::Simulator;
use simdive::util::Rng;

fn sample_pairs(bits: u32, n: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut rng = Rng::new(seed);
    let max = arith::max_val(bits);
    let mut a = vec![0, 0, 1, max, max, 1];
    let mut b = vec![0, 1, 0, max, 1, max];
    while a.len() < n {
        a.push(rng.below(max + 1));
        b.push(rng.below(max + 1));
    }
    (a, b)
}

#[test]
fn simdive_mul_32bit_sampled() {
    let nl = sdc::mul(32, 8);
    let sim = Simulator::new(&nl);
    let (a, b) = sample_pairs(32, 4000, 1);
    let outs = sim.run_batch(&[("a", &a), ("b", &b)]);
    for i in 0..a.len() {
        assert_eq!(
            outs[0].1[i],
            arith::simdive::simdive_mul(32, a[i], b[i]),
            "{}x{}",
            a[i],
            b[i]
        );
    }
}

#[test]
fn simdive_div_32bit_sampled() {
    let nl = sdc::div(32, 32, 8);
    let sim = Simulator::new(&nl);
    let (a, b) = sample_pairs(32, 4000, 2);
    let outs = sim.run_batch(&[("a", &a), ("b", &b)]);
    for i in 0..a.len() {
        assert_eq!(
            outs[0].1[i],
            arith::simdive::simdive_div(32, a[i], b[i]),
            "{}/{}",
            a[i],
            b[i]
        );
    }
}

#[test]
fn hybrid_16bit_both_modes_sampled() {
    let nl = sdc::hybrid(16, 8);
    let sim = Simulator::new(&nl);
    let mut rng = Rng::new(3);
    for _ in 0..3000 {
        let a = rng.below(65536);
        let b = rng.below(65536);
        let p = sim.run_single(&[("a", a), ("b", b), ("mode", 0)])[0].1;
        assert_eq!(p, arith::simdive::simdive_mul(16, a, b));
        let q = sim.run_single(&[("a", a), ("b", b), ("mode", 1)])[0].1;
        assert_eq!(q, arith::simdive::simdive_div(16, a, b));
    }
}

#[test]
fn all_table2_netlists_match_models_sampled() {
    let (a16, b16) = sample_pairs(16, 1500, 4);
    let (_, b8) = sample_pairs(8, 1500, 5);

    // Multipliers at 16-bit.
    let muls: Vec<(simdive::fabric::Netlist, Box<dyn Fn(u64, u64) -> u64>)> = vec![
        (baselines::array_mul(16), Box::new(|a, b| a * b)),
        (baselines::ca_mul(16), Box::new(|a, b| arith::ca::ca_mul(16, a, b))),
        (
            baselines::trunc_mul(16, true, true),
            Box::new(|a, b| arith::trunc::trunc_mul(16, true, true, a, b)),
        ),
        (
            baselines::trunc_mul(16, false, true),
            Box::new(|a, b| arith::trunc::trunc_mul(16, false, true, a, b)),
        ),
        (mitchell::mul(16), Box::new(|a, b| arith::mitchell::mul(16, a, b))),
        (baselines::mbm_mul(16), Box::new(|a, b| arith::saadat::mbm_mul(16, a, b))),
        (sdc::mul(16, 8), Box::new(|a, b| arith::simdive::simdive_mul(16, a, b))),
    ];
    for (nl, model) in &muls {
        let sim = Simulator::new(nl);
        let outs = sim.run_batch(&[("a", &a16), ("b", &b16)]);
        for i in 0..a16.len() {
            assert_eq!(outs[0].1[i], model(a16[i], b16[i]), "mul {}x{}", a16[i], b16[i]);
        }
    }

    // Dividers at 16/8.
    let divs: Vec<(simdive::fabric::Netlist, Box<dyn Fn(u64, u64) -> u64>)> = vec![
        (baselines::restoring_div(16, 8), Box::new(|a, b| arith::exact::div(16, a, b) & 0xFFFF)),
        (
            baselines::aaxd_div(16, 8, 12, 6),
            Box::new(|a, b| arith::aaxd::aaxd_div(16, 12, 6, a, b) & 0xFFFF),
        ),
        (
            baselines::aaxd_div(16, 8, 8, 4),
            Box::new(|a, b| arith::aaxd::aaxd_div(16, 8, 4, a, b) & 0xFFFF),
        ),
        (mitchell::div(16, 8), Box::new(|a, b| arith::mitchell::div(16, a, b) & 0xFFFF)),
        (
            baselines::inzed_div(16, 8),
            Box::new(|a, b| arith::saadat::inzed_div(16, a, b) & 0xFFFF),
        ),
        (sdc::div(16, 8, 8), Box::new(|a, b| arith::simdive::simdive_div(16, a, b) & 0xFFFF)),
    ];
    for (nl, model) in &divs {
        let sim = Simulator::new(nl);
        let outs = sim.run_batch(&[("a", &a16), ("b", &b8)]);
        for i in 0..a16.len() {
            assert_eq!(outs[0].1[i], model(a16[i], b8[i]), "div {}/{}", a16[i], b8[i]);
        }
    }
}
