//! Engine-seam properties (DESIGN.md §10): every backend is bit-identical
//! to the scalar reference for every `{op, bits, w}`, the sharded backend
//! is invariant under shard count, and shard shutdown drains in-flight
//! words before joining.

use simdive::arith::{DivDesign, MulDesign, W_MAX, WIDTHS};
use simdive::coordinator::{ReqOp, Request};
use simdive::engine::{Engine, Route, Sharded, ShardedConfig};
use simdive::util::Rng;
use std::sync::mpsc::channel;

/// Deterministic seeds, one per property (replayable from a failure).
const SEED_SLICES: u64 = 0x5EA1;
const SEED_STREAM: u64 = 0x5EA2;
const SEED_DRAIN: u64 = 0x5EA3;

fn mixed_requests(rng: &mut Rng, n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|i| {
            let bits = [8u32, 8, 16, 32][rng.below(4) as usize];
            Request {
                id: i,
                op: if rng.below(3) == 0 { ReqOp::Div } else { ReqOp::Mul },
                bits,
                w: rng.below(W_MAX as u64 + 1) as u32,
                a: rng.operand(bits),
                b: rng.operand(bits),
            }
        })
        .collect()
}

#[test]
fn all_backends_agree_on_batched_slices() {
    let mut rng = Rng::new(SEED_SLICES);
    for &bits in &WIDTHS {
        for w in [0u32, 4, 8] {
            let a: Vec<u64> = (0..300).map(|_| rng.below(1u64 << bits)).collect();
            let b: Vec<u64> = (0..300).map(|_| rng.below(1u64 << bits)).collect();
            let reference = Engine::reference(MulDesign::Simdive { w }, DivDesign::Simdive { w });
            let batched = Engine::simdive(w);
            let sharded = Engine::sharded(
                MulDesign::Simdive { w },
                DivDesign::Simdive { w },
                ShardedConfig { shards: 3, queue_depth: 128, batch: 32 },
            );
            let (mut want, mut got) = (Vec::new(), Vec::new());
            reference.mul_into(bits, &a, &b, &mut want);
            batched.mul_into(bits, &a, &b, &mut got);
            assert_eq!(got, want, "batched mul bits={bits} w={w}");
            sharded.mul_into(bits, &a, &b, &mut got);
            assert_eq!(got, want, "sharded mul bits={bits} w={w}");
            reference.div_into(bits, &a, &b, &mut want);
            batched.div_into(bits, &a, &b, &mut got);
            assert_eq!(got, want, "batched div bits={bits} w={w}");
            sharded.div_into(bits, &a, &b, &mut got);
            assert_eq!(got, want, "sharded div bits={bits} w={w}");
        }
    }
}

#[test]
fn sharded_stream_bit_identical_across_shard_counts() {
    // The tentpole invariant: for mixed {op, bits, w} traffic the sharded
    // backend returns exactly the reference results at any shard count.
    let mut rng = Rng::new(SEED_STREAM);
    let reqs = mixed_requests(&mut rng, 4_000);
    let oracle = Engine::reference(MulDesign::Accurate, DivDesign::Accurate);
    let want = oracle.execute_stream(&reqs);
    for shards in [1usize, 2, 4, 8] {
        let eng = Engine::sharded(
            MulDesign::Accurate,
            DivDesign::Accurate,
            ShardedConfig { shards, queue_depth: 256, batch: 64 },
        );
        assert_eq!(eng.execute_stream(&reqs), want, "shards={shards}");
    }
    // The batched one-shot assembler agrees too.
    assert_eq!(Engine::default().execute_stream(&reqs), want);
}

#[test]
fn sharded_swar_stream_bit_identical_across_shard_counts() {
    // The SWAR tentpole invariant (DESIGN.md §13): an 8-bit-only mixed
    // mul/div stream packs entirely into `Four8` words, so every word a
    // shard executes goes through the staged SWAR pipeline — and the
    // results must still be exactly the reference's, at any shard count,
    // with zero operands and adversarial extremes in the mix.
    let mut rng = Rng::new(SEED_STREAM ^ 0x513A);
    let extremes = [0u64, 1, 127, 128, 255];
    let reqs: Vec<Request> = (0..6_000u64)
        .map(|i| {
            let (a, b) = if rng.below(5) == 0 {
                (extremes[rng.below(5) as usize], extremes[rng.below(5) as usize])
            } else {
                (rng.below(256), rng.below(256))
            };
            Request {
                id: i,
                op: if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                bits: 8,
                w: rng.below(W_MAX as u64 + 1) as u32,
                a,
                b,
            }
        })
        .collect();
    let oracle = Engine::reference(MulDesign::Accurate, DivDesign::Accurate);
    let want = oracle.execute_stream(&reqs);
    for shards in [1usize, 2, 4, 8] {
        let eng = Engine::sharded(
            MulDesign::Accurate,
            DivDesign::Accurate,
            ShardedConfig { shards, queue_depth: 256, batch: 64 },
        );
        assert_eq!(eng.execute_stream(&reqs), want, "SWAR-heavy stream at shards={shards}");
    }
}

#[test]
fn non_simdive_designs_fall_back_bit_exactly_on_sharded() {
    // Designs without a word form (MBM, Mitchell, truncated…) route to
    // the batched slice path inside the sharded backend — same numbers.
    let mut rng = Rng::new(SEED_SLICES ^ 1);
    let a: Vec<u64> = (0..200).map(|_| rng.below(1 << 16)).collect();
    let b: Vec<u64> = (0..200).map(|_| rng.below(1 << 16)).collect();
    let sharded = Engine::sharded(
        MulDesign::Mbm,
        DivDesign::Inzed,
        ShardedConfig { shards: 2, queue_depth: 64, batch: 16 },
    );
    let reference = Engine::reference(MulDesign::Mbm, DivDesign::Inzed);
    let (mut want, mut got) = (Vec::new(), Vec::new());
    reference.mul_into(16, &a, &b, &mut want);
    sharded.mul_into(16, &a, &b, &mut got);
    assert_eq!(got, want, "mbm mul fallback");
    reference.div_into(16, &a, &b, &mut want);
    sharded.div_into(16, &a, &b, &mut got);
    assert_eq!(got, want, "inzed div fallback");
}

#[test]
fn non_simd_widths_fall_back_bit_exactly_on_sharded() {
    // SIMDive at a width with no word form (e.g. 12-bit) must route to
    // the slice kernels on every backend — same numbers, no panic in a
    // shard thread.
    let mut rng = Rng::new(SEED_SLICES ^ 2);
    let a: Vec<u64> = (0..100).map(|_| 1 + rng.below((1 << 12) - 1)).collect();
    let b: Vec<u64> = (0..100).map(|_| 1 + rng.below((1 << 12) - 1)).collect();
    let sharded = Engine::sharded(
        MulDesign::Simdive { w: 8 },
        DivDesign::Simdive { w: 8 },
        ShardedConfig { shards: 2, queue_depth: 64, batch: 16 },
    );
    let reference = Engine::reference(MulDesign::Simdive { w: 8 }, DivDesign::Simdive { w: 8 });
    let (mut want, mut got) = (Vec::new(), Vec::new());
    reference.mul_into(12, &a, &b, &mut want);
    sharded.mul_into(12, &a, &b, &mut got);
    assert_eq!(got, want, "12-bit mul fallback");
    reference.div_into(12, &a, &b, &mut want);
    sharded.div_into(12, &a, &b, &mut got);
    assert_eq!(got, want, "12-bit div fallback");
}

#[test]
fn shard_shutdown_drains_in_flight_words() {
    // Lifecycle: chunks submitted right before shutdown must be fully
    // assembled, executed and routed — shutdown joins only after every
    // in-flight word has drained.
    let mut rng = Rng::new(SEED_DRAIN);
    let reqs = mixed_requests(&mut rng, 2_000);
    let pool = Sharded::start(ShardedConfig { shards: 4, queue_depth: 64, batch: 16 });
    let (tx, rx) = channel();
    for (base, piece) in reqs.chunks(50).enumerate() {
        let chunk: Vec<(Request, Route)> = piece
            .iter()
            .enumerate()
            .map(|(k, r)| (*r, Route::Slot(tx.clone(), (base * 50 + k) as u32)))
            .collect();
        pool.submit(chunk);
    }
    drop(tx);
    // Shut down immediately: everything above is still in flight.
    let stats = pool.shutdown();
    assert_eq!(stats.requests, 2_000, "in-flight chunks must be drained, not dropped");
    let oracle = Engine::reference(MulDesign::Accurate, DivDesign::Accurate);
    let want = oracle.execute_stream(&reqs);
    let mut got: Vec<Option<u64>> = vec![None; reqs.len()];
    while let Ok((slot, resp)) = rx.recv() {
        assert_eq!(resp.id, reqs[slot as usize].id, "slot {slot} routed a different request");
        assert!(got[slot as usize].replace(resp.value).is_none(), "slot {slot} twice");
    }
    for (i, v) in got.iter().enumerate() {
        assert_eq!(*v, Some(want[i]), "slot {i}");
    }
}

#[test]
fn sharded_drop_joins_and_delivers() {
    // Dropping the pool (not calling shutdown) behaves identically.
    let (tx, rx) = channel();
    let reqs: Vec<Request> = (0..64u64)
        .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i % 200, b: 7 })
        .collect();
    {
        let pool = Sharded::start(ShardedConfig { shards: 2, queue_depth: 32, batch: 8 });
        let chunk: Vec<(Request, Route)> = reqs
            .iter()
            .enumerate()
            .map(|(k, r)| (*r, Route::Slot(tx.clone(), k as u32)))
            .collect();
        pool.submit(chunk);
        // `pool` dropped here: Drop disconnects the shard queues and
        // joins every shard thread after it drains.
    }
    drop(tx);
    let mut n = 0usize;
    while let Ok((slot, resp)) = rx.recv() {
        let req = &reqs[slot as usize];
        assert_eq!(
            resp.value,
            simdive::arith::simdive::simdive_mul_w(8, req.a, req.b, 8),
            "slot {slot}"
        );
        n += 1;
    }
    assert_eq!(n, reqs.len(), "every response delivered before the join");
}

#[test]
fn stream_results_invariant_under_chunked_submission() {
    // Submitting one big stream or many small ones must not change any
    // value (packing differs; results cannot).
    let mut rng = Rng::new(SEED_STREAM ^ 7);
    let reqs = mixed_requests(&mut rng, 1_000);
    let eng = Engine::sharded(
        MulDesign::Accurate,
        DivDesign::Accurate,
        ShardedConfig { shards: 4, queue_depth: 128, batch: 32 },
    );
    let whole = eng.execute_stream(&reqs);
    let mut pieced = Vec::new();
    let mut buf = Vec::new();
    for piece in reqs.chunks(37) {
        eng.execute_stream_into(piece, &mut buf);
        pieced.extend_from_slice(&buf);
    }
    assert_eq!(pieced, whole);
}
