//! Property tests on coordinator invariants (routing, batching, state),
//! via the in-repo prop helper (proptest substitute — DESIGN.md §1).

use simdive::arith::simdive::{simdive_div, simdive_mul};
use simdive::coordinator::{
    pack_requests, unpack_results, Coordinator, CoordinatorConfig, ReqOp, Request,
};
use simdive::util::prop;
use simdive::util::Rng;

fn random_requests(r: &mut Rng, n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|i| {
            let bits = [8u32, 16, 32][r.below(3) as usize];
            Request {
                id: i,
                op: if r.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                bits,
                a: r.operand(bits),
                b: r.operand(bits),
            }
        })
        .collect()
}

#[test]
fn prop_every_request_routed_once() {
    prop::check(
        11,
        200,
        |r| { let n = 1 + r.below(60) as usize; random_requests(r, n) },
        |reqs| {
            let words = pack_requests(reqs);
            let mut seen = std::collections::HashSet::new();
            for w in &words {
                for id in w.lane_req.iter().flatten() {
                    if !seen.insert(*id) {
                        return Err(format!("id {id} routed twice"));
                    }
                }
                if w.active_lanes as usize > w.lane_count() {
                    return Err("active_lanes exceeds lane count".into());
                }
            }
            if seen.len() != reqs.len() {
                return Err(format!("routed {} of {}", seen.len(), reqs.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_results_equal_sisd() {
    prop::check(
        12,
        100,
        |r| { let n = 1 + r.below(40) as usize; random_requests(r, n) },
        |reqs| {
            for w in pack_requests(reqs) {
                let packed = simdive::arith::simd::execute(w.op, w.word, 8);
                for (id, got) in unpack_results(&w, packed) {
                    let req = &reqs[id as usize];
                    let want = match req.op {
                        ReqOp::Mul => simdive_mul(req.bits, req.a, req.b),
                        ReqOp::Div => simdive_div(req.bits, req.a, req.b),
                    };
                    if got != want {
                        return Err(format!(
                            "req {id} ({}x{} {:?}@{}): {got} != {want}",
                            req.a, req.b, req.op, req.bits
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_invariants() {
    // The full lane-packing contract over randomized 8/16/32-bit mixes:
    // every request id appears in exactly one lane of exactly one word,
    // idle lanes carry zero operands (they are power-gated — §3.2), and
    // `active_lanes` matches the non-`None` entries of `lane_req`.
    prop::check(
        17,
        300,
        |r| { let n = 1 + r.below(70) as usize; random_requests(r, n) },
        |reqs| {
            let words = pack_requests(reqs);
            let mut seen = std::collections::HashSet::new();
            for w in &words {
                let mut active = 0u32;
                for (l, lane) in w.lane_req.iter().enumerate() {
                    match lane {
                        Some(id) => {
                            if l >= w.lane_count() {
                                return Err(format!(
                                    "id {id} sits in lane {l} beyond {:?}'s {} lanes",
                                    w.op.cfg,
                                    w.lane_count()
                                ));
                            }
                            if !seen.insert(*id) {
                                return Err(format!("id {id} packed into two lanes"));
                            }
                            active += 1;
                        }
                        None if l < w.lane_count() => {
                            // Generated operands are non-zero, so any
                            // non-zero operand in an idle lane would be a
                            // leak from an active request.
                            let (a, b) = w.word.lane(w.op.cfg, l);
                            if a != 0 || b != 0 {
                                return Err(format!(
                                    "idle lane {l} of {:?} carries operands ({a}, {b})",
                                    w.op.cfg
                                ));
                            }
                        }
                        None => {}
                    }
                }
                if active != w.active_lanes {
                    return Err(format!(
                        "active_lanes {} but {} occupied lane_req entries in {:?}",
                        w.active_lanes, active, w.op.cfg
                    ));
                }
            }
            if seen.len() != reqs.len() {
                return Err(format!("{} of {} ids packed", seen.len(), reqs.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packing_efficiency() {
    // No packing may use more words than the trivial one-per-request, and
    // uniform 8-bit loads must reach ≥ 4× compaction.
    prop::check(
        13,
        100,
        |r| { let n = 1 + r.below(80) as usize; random_requests(r, n) },
        |reqs| {
            let words = pack_requests(reqs);
            if words.len() > reqs.len() {
                return Err(format!("{} words for {} reqs", words.len(), reqs.len()));
            }
            Ok(())
        },
    );
    let reqs: Vec<Request> = (0..64)
        .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, a: 1 + i, b: 3 })
        .collect();
    assert_eq!(pack_requests(&reqs).len(), 16);
}

#[test]
fn coordinator_under_concurrent_load() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        w: 8,
        queue_depth: 256,
        batch: 32,
    });
    let mut rng = Rng::new(21);
    let reqs = random_requests(&mut rng, 2000);
    let handles: Vec<_> = reqs.iter().map(|r| coord.submit(*r)).collect();
    for (h, req) in handles.into_iter().zip(&reqs) {
        let resp = h.recv().unwrap();
        let want = match req.op {
            ReqOp::Mul => simdive_mul(req.bits, req.a, req.b),
            ReqOp::Div => simdive_div(req.bits, req.a, req.b),
        };
        assert_eq!(resp.value, want, "req {}", req.id);
    }
    let s = coord.shutdown();
    assert_eq!(s.requests, 2000);
    assert!(s.lane_utilization() > 0.25);
}
