//! Property tests on coordinator invariants (routing, batching, state),
//! via the in-repo prop helper (proptest substitute — DESIGN.md §1).
//!
//! Every generator in this file draws from `util::Rng` under one of the
//! named seeds below — `cargo test -q` is reproducible run-to-run, and a
//! failing counterexample can be replayed from the seed in its panic
//! message.

use simdive::arith::simdive::{simdive_div_w, simdive_mul_w};
use simdive::arith::W_MAX;
use simdive::coordinator::{
    pack_requests, unpack_results, Coordinator, CoordinatorConfig, ReqOp, Request,
};
use simdive::util::prop;
use simdive::util::Rng;

/// Seeds for the deterministic generators (one per property, so shrink
/// output stays attributable).
const SEED_ROUTED_ONCE: u64 = 11;
const SEED_RESULTS_EQUAL_SISD: u64 = 12;
const SEED_PACKING_EFFICIENCY: u64 = 13;
const SEED_PACK_INVARIANTS: u64 = 17;
const SEED_CONCURRENT_LOAD: u64 = 21;

fn random_requests(r: &mut Rng, n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|i| {
            let bits = [8u32, 16, 32][r.below(3) as usize];
            Request {
                id: i,
                op: if r.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                bits,
                w: r.below(W_MAX as u64 + 1) as u32,
                a: r.operand(bits),
                b: r.operand(bits),
            }
        })
        .collect()
}

fn expect(req: &Request) -> u64 {
    match req.op {
        ReqOp::Mul => simdive_mul_w(req.bits, req.a, req.b, req.w),
        ReqOp::Div => simdive_div_w(req.bits, req.a, req.b, req.w),
    }
}

#[test]
fn prop_every_request_routed_once() {
    prop::check(
        SEED_ROUTED_ONCE,
        200,
        |r| { let n = 1 + r.below(60) as usize; random_requests(r, n) },
        |reqs| {
            let words = pack_requests(reqs);
            let mut seen = std::collections::HashSet::new();
            for w in &words {
                for id in w.lane_req.iter().flatten() {
                    if !seen.insert(*id) {
                        return Err(format!("id {id} routed twice"));
                    }
                }
                if w.active_lanes as usize > w.lane_count() {
                    return Err("active_lanes exceeds lane count".into());
                }
            }
            if seen.len() != reqs.len() {
                return Err(format!("routed {} of {}", seen.len(), reqs.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_results_equal_sisd() {
    prop::check(
        SEED_RESULTS_EQUAL_SISD,
        100,
        |r| { let n = 1 + r.below(40) as usize; random_requests(r, n) },
        |reqs| {
            for w in pack_requests(reqs) {
                // Each packed word executes at its own accuracy knob.
                let packed = simdive::arith::simd::execute(w.op, w.word, w.w);
                for (id, got) in unpack_results(&w, packed) {
                    let req = &reqs[id as usize];
                    let want = expect(req);
                    if got != want {
                        return Err(format!(
                            "req {id} ({}x{} {:?}@{} w={}): {got} != {want}",
                            req.a, req.b, req.op, req.bits, req.w
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_invariants() {
    // The full lane-packing contract over randomized mixed-{bits, w}
    // loads: every request id appears in exactly one lane of exactly one
    // word, only same-w requests share a word (their correction tables
    // differ — §3.3), idle lanes carry zero operands (they are
    // power-gated — §3.2), and `active_lanes` matches the non-`None`
    // entries of `lane_req`.
    prop::check(
        SEED_PACK_INVARIANTS,
        300,
        |r| { let n = 1 + r.below(70) as usize; random_requests(r, n) },
        |reqs| {
            let words = pack_requests(reqs);
            let mut seen = std::collections::HashSet::new();
            for w in &words {
                let mut active = 0u32;
                for (l, lane) in w.lane_req.iter().enumerate() {
                    match lane {
                        Some(id) => {
                            if l >= w.lane_count() {
                                return Err(format!(
                                    "id {id} sits in lane {l} beyond {:?}'s {} lanes",
                                    w.op.cfg,
                                    w.lane_count()
                                ));
                            }
                            if !seen.insert(*id) {
                                return Err(format!("id {id} packed into two lanes"));
                            }
                            if reqs[*id as usize].w != w.w {
                                return Err(format!(
                                    "id {id} (w={}) packed into a w={} word",
                                    reqs[*id as usize].w, w.w
                                ));
                            }
                            active += 1;
                        }
                        None if l < w.lane_count() => {
                            // Generated operands are non-zero, so any
                            // non-zero operand in an idle lane would be a
                            // leak from an active request.
                            let (a, b) = w.word.lane(w.op.cfg, l);
                            if a != 0 || b != 0 {
                                return Err(format!(
                                    "idle lane {l} of {:?} carries operands ({a}, {b})",
                                    w.op.cfg
                                ));
                            }
                        }
                        None => {}
                    }
                }
                if active != w.active_lanes {
                    return Err(format!(
                        "active_lanes {} but {} occupied lane_req entries in {:?}",
                        w.active_lanes, active, w.op.cfg
                    ));
                }
            }
            if seen.len() != reqs.len() {
                return Err(format!("{} of {} ids packed", seen.len(), reqs.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packing_efficiency() {
    // No packing may use more words than the trivial one-per-request, and
    // uniform 8-bit single-w loads must reach ≥ 4× compaction.
    prop::check(
        SEED_PACKING_EFFICIENCY,
        100,
        |r| { let n = 1 + r.below(80) as usize; random_requests(r, n) },
        |reqs| {
            let words = pack_requests(reqs);
            if words.len() > reqs.len() {
                return Err(format!("{} words for {} reqs", words.len(), reqs.len()));
            }
            Ok(())
        },
    );
    let reqs: Vec<Request> = (0..64)
        .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i, b: 3 })
        .collect();
    assert_eq!(pack_requests(&reqs).len(), 16);
}

#[test]
fn coordinator_under_concurrent_load() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        queue_depth: 256,
        batch: 32,
    });
    let mut rng = Rng::new(SEED_CONCURRENT_LOAD);
    let reqs = random_requests(&mut rng, 2000);
    let handles: Vec<_> = reqs.iter().map(|r| coord.submit(*r)).collect();
    for (h, req) in handles.into_iter().zip(&reqs) {
        let resp = h.recv().unwrap();
        assert_eq!(resp.value, expect(req), "req {}", req.id);
    }
    let s = coord.shutdown();
    assert_eq!(s.requests, 2000);
    assert!(s.lane_utilization() > 0.25);
}
