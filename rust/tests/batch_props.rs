//! Property tests: the batched slice kernels in `arith::batch` are
//! bit-exactly equivalent to the scalar `simdive_mul_with` /
//! `simdive_div_with` path across all widths, all accuracy knobs `w`, and
//! the zero-operand conventions (`b == 0 → max_val`, `a == 0 → 0`). Uses
//! the in-repo prop helper (proptest substitute — DESIGN.md §1).
//!
//! Every generator draws from `util::Rng` under a seed derived from one
//! of the named bases below (mixed with the loop's `{bits, w}` so each
//! configuration explores distinct inputs) — `cargo test -q` is
//! reproducible run-to-run.

use simdive::arith::simd::{LaneCfg, LaneMode, SimdOp, SimdWord};
use simdive::arith::simdive::{simdive_div_with, simdive_mul_with};
use simdive::arith::table::tables_for;
use simdive::arith::{batch, max_val, simd, W_MAX, WIDTHS};
use simdive::util::prop;
use simdive::util::Rng;

/// Seed bases for the deterministic generators.
const SEED_MUL_BATCH: u64 = 0xB0 << 24;
const SEED_DIV_BATCH: u64 = 0xB1 << 24;
const SEED_EXECUTE_WORDS: u64 = 0xE0;
const SEED_MIXED_KERNEL: u64 = 0x3319;

/// Draw a batch of operand pairs with deliberate zero density (~1/8 of
/// each side) so the `a == 0` / `b == 0` conventions are exercised in
/// every case, alongside uniform full-width operands.
fn operand_batch(r: &mut Rng, bits: u32, n: usize) -> (Vec<u64>, Vec<u64>) {
    let draw = |r: &mut Rng| -> u64 {
        if r.below(8) == 0 {
            0
        } else {
            r.below(1u64 << bits)
        }
    };
    let a = (0..n).map(|_| draw(r)).collect();
    let b = (0..n).map(|_| draw(r)).collect();
    (a, b)
}

#[test]
fn prop_mul_batch_bit_exact_all_widths_all_w() {
    for &bits in &WIDTHS {
        for w in 0..=W_MAX {
            let t = tables_for(w);
            prop::check(
                SEED_MUL_BATCH | (bits as u64) << 8 | w as u64,
                40,
                |r| {
                    let n = 1 + r.below(200) as usize;
                    operand_batch(r, bits, n)
                },
                |(a, b)| {
                    let got = batch::mul_batch(t, bits, a, b);
                    for i in 0..a.len() {
                        let want = simdive_mul_with(t, bits, a[i], b[i]);
                        if got[i] != want {
                            return Err(format!(
                                "bits={bits} w={w}: {}x{} -> {} != {}",
                                a[i], b[i], got[i], want
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_div_batch_bit_exact_all_widths_all_w() {
    for &bits in &WIDTHS {
        for w in 0..=W_MAX {
            let t = tables_for(w);
            prop::check(
                SEED_DIV_BATCH | (bits as u64) << 16 | w as u64,
                40,
                |r| {
                    let n = 1 + r.below(200) as usize;
                    operand_batch(r, bits, n)
                },
                |(a, b)| {
                    let got = batch::div_batch(t, bits, a, b);
                    for i in 0..a.len() {
                        let want = simdive_div_with(t, bits, a[i], b[i]);
                        if got[i] != want {
                            return Err(format!(
                                "bits={bits} w={w}: {}/{} -> {} != {}",
                                a[i], b[i], got[i], want
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_multi_kernel_mixed_w_bit_exact() {
    // The coordinator-v2 kernel entry: one MultiKernel executing words
    // with per-word accuracy knobs must match the per-w scalar path
    // bit-exactly for every {cfg, modes, w} combination.
    let mk = batch::MultiKernel::new();
    prop::check(
        SEED_MIXED_KERNEL,
        80,
        |r| {
            let n = 1 + r.below(50) as usize;
            let mut ws = Vec::with_capacity(n);
            let mut ops = Vec::with_capacity(n);
            let mut words = Vec::with_capacity(n);
            for _ in 0..n {
                let cfg = LaneCfg::ALL[r.below(4) as usize];
                let lanes = cfg.lanes();
                let a: Vec<u64> = lanes.iter().map(|&(_, wd)| r.below(1u64 << wd)).collect();
                let b: Vec<u64> = lanes.iter().map(|&(_, wd)| r.below(1u64 << wd)).collect();
                let mut modes = [LaneMode::Mul; 4];
                for m in modes.iter_mut() {
                    if r.below(2) == 1 {
                        *m = LaneMode::Div;
                    }
                }
                ws.push(r.below(W_MAX as u64 + 1) as u32);
                ops.push(SimdOp { cfg, modes });
                words.push(SimdWord::pack(cfg, &a, &b));
            }
            (ws, ops, words)
        },
        |(ws, ops, words)| {
            let mut out = vec![0u64; ws.len()];
            mk.execute_mixed_into(ws, ops, words, &mut out);
            for i in 0..ws.len() {
                let want = simd::execute_with(tables_for(ws[i]), ops[i], words[i]);
                if out[i] != want {
                    return Err(format!(
                        "word {i} (w={}, {:?}): {} != {want}",
                        ws[i], ops[i], out[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn zero_conventions_all_widths() {
    for &bits in &WIDTHS {
        for w in [0, 4, W_MAX] {
            let t = tables_for(w);
            let a = [0u64, 0, 5, max_val(bits), 0];
            let b = [0u64, 9, 0, 0, max_val(bits)];
            let m = batch::mul_batch(t, bits, &a, &b);
            assert_eq!(m, vec![0, 0, 0, 0, 0], "mul zeros at bits={bits} w={w}");
            let d = batch::div_batch(t, bits, &a, &b);
            assert_eq!(d[0], max_val(bits), "0/0 saturates (b==0 checked first)");
            assert_eq!(d[1], 0, "0/9 is 0");
            assert_eq!(d[2], max_val(bits), "5/0 saturates");
            assert_eq!(d[3], max_val(bits), "max/0 saturates");
            assert_eq!(d[4], 0, "0/max is 0");
        }
    }
}

#[test]
fn prop_execute_words_bit_exact() {
    for w in [0u32, 3, 8] {
        let t = tables_for(w);
        prop::check(
            SEED_EXECUTE_WORDS + w as u64,
            60,
            |r| {
                let n = 1 + r.below(60) as usize;
                let mut ops = Vec::with_capacity(n);
                let mut words = Vec::with_capacity(n);
                for _ in 0..n {
                    let cfg = LaneCfg::ALL[r.below(4) as usize];
                    let lanes = cfg.lanes();
                    let a: Vec<u64> = lanes.iter().map(|&(_, wd)| r.below(1u64 << wd)).collect();
                    let b: Vec<u64> = lanes.iter().map(|&(_, wd)| r.below(1u64 << wd)).collect();
                    let mut modes = [LaneMode::Mul; 4];
                    for m in modes.iter_mut() {
                        if r.below(2) == 1 {
                            *m = LaneMode::Div;
                        }
                    }
                    ops.push(SimdOp { cfg, modes });
                    words.push(SimdWord::pack(cfg, &a, &b));
                }
                (ops, words)
            },
            |(ops, words)| {
                let got = batch::execute_words(t, ops, words);
                for i in 0..ops.len() {
                    let want = simd::execute_with(t, ops[i], words[i]);
                    if got[i] != want {
                        return Err(format!("word {i} ({:?}): {} != {want}", ops[i], got[i]));
                    }
                }
                Ok(())
            },
        );
    }
}
