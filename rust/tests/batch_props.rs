//! Property tests: the batched slice kernels in `arith::batch` are
//! bit-exactly equivalent to the scalar `simdive_mul_with` /
//! `simdive_div_with` path across all widths, all accuracy knobs `w`, and
//! the zero-operand conventions (`b == 0 → max_val`, `a == 0 → 0`). Uses
//! the in-repo prop helper (proptest substitute — DESIGN.md §1).

use simdive::arith::simd::{LaneCfg, LaneMode, SimdOp, SimdWord};
use simdive::arith::simdive::{simdive_div_with, simdive_mul_with};
use simdive::arith::table::tables_for;
use simdive::arith::{batch, max_val, simd, W_MAX, WIDTHS};
use simdive::util::prop;
use simdive::util::Rng;

/// Draw a batch of operand pairs with deliberate zero density (~1/8 of
/// each side) so the `a == 0` / `b == 0` conventions are exercised in
/// every case, alongside uniform full-width operands.
fn operand_batch(r: &mut Rng, bits: u32, n: usize) -> (Vec<u64>, Vec<u64>) {
    let draw = |r: &mut Rng| -> u64 {
        if r.below(8) == 0 {
            0
        } else {
            r.below(1u64 << bits)
        }
    };
    let a = (0..n).map(|_| draw(r)).collect();
    let b = (0..n).map(|_| draw(r)).collect();
    (a, b)
}

#[test]
fn prop_mul_batch_bit_exact_all_widths_all_w() {
    for &bits in &WIDTHS {
        for w in 0..=W_MAX {
            let t = tables_for(w);
            prop::check(
                (bits as u64) << 8 | w as u64,
                40,
                |r| {
                    let n = 1 + r.below(200) as usize;
                    operand_batch(r, bits, n)
                },
                |(a, b)| {
                    let got = batch::mul_batch(t, bits, a, b);
                    for i in 0..a.len() {
                        let want = simdive_mul_with(t, bits, a[i], b[i]);
                        if got[i] != want {
                            return Err(format!(
                                "bits={bits} w={w}: {}x{} -> {} != {}",
                                a[i], b[i], got[i], want
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_div_batch_bit_exact_all_widths_all_w() {
    for &bits in &WIDTHS {
        for w in 0..=W_MAX {
            let t = tables_for(w);
            prop::check(
                (bits as u64) << 16 | w as u64,
                40,
                |r| {
                    let n = 1 + r.below(200) as usize;
                    operand_batch(r, bits, n)
                },
                |(a, b)| {
                    let got = batch::div_batch(t, bits, a, b);
                    for i in 0..a.len() {
                        let want = simdive_div_with(t, bits, a[i], b[i]);
                        if got[i] != want {
                            return Err(format!(
                                "bits={bits} w={w}: {}/{} -> {} != {}",
                                a[i], b[i], got[i], want
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn zero_conventions_all_widths() {
    for &bits in &WIDTHS {
        for w in [0, 4, W_MAX] {
            let t = tables_for(w);
            let a = [0u64, 0, 5, max_val(bits), 0];
            let b = [0u64, 9, 0, 0, max_val(bits)];
            let m = batch::mul_batch(t, bits, &a, &b);
            assert_eq!(m, vec![0, 0, 0, 0, 0], "mul zeros at bits={bits} w={w}");
            let d = batch::div_batch(t, bits, &a, &b);
            assert_eq!(d[0], max_val(bits), "0/0 saturates (b==0 checked first)");
            assert_eq!(d[1], 0, "0/9 is 0");
            assert_eq!(d[2], max_val(bits), "5/0 saturates");
            assert_eq!(d[3], max_val(bits), "max/0 saturates");
            assert_eq!(d[4], 0, "0/max is 0");
        }
    }
}

#[test]
fn prop_execute_words_bit_exact() {
    for w in [0u32, 3, 8] {
        let t = tables_for(w);
        prop::check(
            0xE0 + w as u64,
            60,
            |r| {
                let n = 1 + r.below(60) as usize;
                let mut ops = Vec::with_capacity(n);
                let mut words = Vec::with_capacity(n);
                for _ in 0..n {
                    let cfg = LaneCfg::ALL[r.below(4) as usize];
                    let lanes = cfg.lanes();
                    let a: Vec<u64> = lanes.iter().map(|&(_, wd)| r.below(1u64 << wd)).collect();
                    let b: Vec<u64> = lanes.iter().map(|&(_, wd)| r.below(1u64 << wd)).collect();
                    let mut modes = [LaneMode::Mul; 4];
                    for m in modes.iter_mut() {
                        if r.below(2) == 1 {
                            *m = LaneMode::Div;
                        }
                    }
                    ops.push(SimdOp { cfg, modes });
                    words.push(SimdWord::pack(cfg, &a, &b));
                }
                (ops, words)
            },
            |(ops, words)| {
                let got = batch::execute_words(t, ops, words);
                for i in 0..ops.len() {
                    let want = simd::execute_with(t, ops[i], words[i]);
                    if got[i] != want {
                        return Err(format!("word {i} ({:?}): {} != {want}", ops[i], got[i]));
                    }
                }
                Ok(())
            },
        );
    }
}
