//! Loopback end-to-end tests of the network serving subsystem: a real TCP
//! server on 127.0.0.1, driven through `serve::client`, with every
//! response checked bit-identical against the in-process `arith::batch`
//! kernels for the same `{bits, w}` (DESIGN.md §8). Since coordinator v2
//! every mix of `{bits, w}` flows through one shared worker pool, and
//! requests may carry an error budget routed server-side (§9).

use simdive::arith::{batch, table};
use simdive::coordinator::{ErrorProfile, ReqOp};
use simdive::serve::{Client, ServeConfig, Server, WireRequest};
use simdive::util::Rng;
use std::io::{Read, Write};

/// Ground truth: the batched kernel result for one request at its own
/// `{bits, w}` — the same arithmetic the server's shared coordinator
/// runs. Budget-mode requests resolve `w` through the same profile table
/// the server's router uses (it is deterministic — seeded measurement).
fn expect_one(r: &WireRequest) -> u64 {
    let w = if r.budget_ppm > 0 {
        ErrorProfile::get().pick_w(r.op, r.bits, r.budget_ppm)
    } else {
        r.w
    };
    let t = table::tables_for(w);
    match r.op {
        ReqOp::Mul => batch::mul_batch(t, r.bits, &[r.a], &[r.b])[0],
        ReqOp::Div => batch::div_batch(t, r.bits, &[r.a], &[r.b])[0],
    }
}

fn random_request(rng: &mut Rng, id: u64) -> WireRequest {
    let bits = [8u32, 8, 8, 16, 16, 32][rng.below(6) as usize];
    WireRequest {
        id,
        op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
        bits,
        w: rng.below(simdive::arith::W_MAX as u64 + 1) as u32,
        budget_ppm: 0,
        a: rng.operand(bits),
        b: rng.operand(bits),
    }
}

/// The acceptance-criteria run: ≥ 10k mixed-width mul/div requests with
/// varied per-request `w` through one pipelined connection, every response
/// bit-identical to `arith::batch`.
#[test]
fn loopback_10k_mixed_requests_bit_identical() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let mut rng = Rng::new(0x5E12_7E57);
    let n = 10_000u64;
    let mut checked = 0u64;
    for window_base in (0..n).step_by(2_000) {
        let reqs: Vec<WireRequest> = (window_base..(window_base + 2_000).min(n))
            .map(|i| random_request(&mut rng, i))
            .collect();
        let resps = client.exchange(&reqs).unwrap();
        assert_eq!(resps.len(), reqs.len());
        for (req, resp) in reqs.iter().zip(&resps) {
            assert_eq!(resp.id, req.id, "responses must come back in submission order");
            assert_eq!(
                resp.value,
                expect_one(req),
                "bits={} w={} {:?} a={} b={}",
                req.bits,
                req.w,
                req.op,
                req.a,
                req.b
            );
            checked += 1;
        }
    }
    assert_eq!(checked, n);
    let stats = client.stats().unwrap();
    assert_eq!(stats.conn_requests, n);
    assert!(stats.requests >= n);
    assert!(stats.words > 0);
    assert!(stats.words <= n);
    assert!(stats.active_lanes <= stats.total_lanes);
    assert!(stats.energy_mpj > 0);
    assert!(stats.p50_us <= stats.p99_us);
    server.shutdown();
}

#[test]
fn concurrent_connections_are_isolated() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let addr = server.local_addr();
    let mut handles = Vec::new();
    for conn in 0..4u64 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap().with_chunk(64);
            let mut rng = Rng::new(0xC0_4C + conn);
            let reqs: Vec<WireRequest> = (0..2_500).map(|i| random_request(&mut rng, i)).collect();
            let resps = client.exchange(&reqs).unwrap();
            for (req, resp) in reqs.iter().zip(&resps) {
                assert_eq!(resp.id, req.id);
                assert_eq!(resp.value, expect_one(req), "conn {conn} req {}", req.id);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 4 * 2_500);
    server.shutdown();
}

#[test]
fn tiny_admission_window_still_completes() {
    // window ≪ pipeline: the reader must keep admitting as lanes complete
    // (backpressure, not deadlock or loss).
    let server =
        Server::start("127.0.0.1:0", ServeConfig { window: 8, ..ServeConfig::default() }).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap().with_chunk(256);
    let mut rng = Rng::new(7);
    let reqs: Vec<WireRequest> = (0..5_000).map(|i| random_request(&mut rng, i)).collect();
    let resps = client.exchange(&reqs).unwrap();
    assert_eq!(resps.len(), reqs.len());
    for (req, resp) in reqs.iter().zip(&resps) {
        assert_eq!(resp.value, expect_one(req));
    }
    server.shutdown();
}

#[test]
fn single_call_and_per_request_w_tunability() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    // The paper's running example 43 × 10 at every accuracy knob: the
    // per-request `w` on the wire must select the matching tables.
    let mut values = Vec::new();
    for w in 0..=simdive::arith::W_MAX {
        let req =
            WireRequest { id: w as u64, op: ReqOp::Mul, bits: 8, w, budget_ppm: 0, a: 43, b: 10 };
        let resp = client.call(req).unwrap();
        assert_eq!(resp.id, w as u64);
        assert_eq!(resp.value, expect_one(&req), "w={w}");
        values.push(resp.value);
    }
    // w=0 degenerates to pure Mitchell, w=8 is the paper's most accurate
    // configuration; the knob must actually change the answer.
    assert!(values.iter().any(|&v| v != values[0]), "w knob had no effect: {values:?}");
    server.shutdown();
}

#[test]
fn zero_operand_conventions_cross_the_wire() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    for bits in [8u32, 16, 32] {
        let max = simdive::arith::max_val(bits);
        let cases = [
            WireRequest { id: 0, op: ReqOp::Mul, bits, w: 8, budget_ppm: 0, a: 0, b: max },
            WireRequest { id: 1, op: ReqOp::Div, bits, w: 8, budget_ppm: 0, a: 0, b: 7 },
            WireRequest { id: 2, op: ReqOp::Div, bits, w: 8, budget_ppm: 0, a: max, b: 0 },
        ];
        let resps = client.exchange(&cases).unwrap();
        assert_eq!(resps[0].value, 0, "0 × max at {bits} bits");
        assert_eq!(resps[1].value, 0, "0 ÷ 7 at {bits} bits");
        assert_eq!(resps[2].value, max, "x ÷ 0 saturates at {bits} bits");
    }
    server.shutdown();
}

#[test]
fn error_budget_requests_route_to_cheapest_satisfying_w() {
    // Wire v2: clients may state a maximum relative-error budget instead
    // of a w. The server must (a) answer bit-identically to the kernel at
    // the w its router picks (checked via the same deterministic profile
    // table), and (b) actually vary the picked w with the budget.
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let profile = ErrorProfile::get();
    let mut rng = Rng::new(0xB0D6E7);
    let mut reqs = Vec::new();
    for i in 0..2_000u64 {
        let mut r = random_request(&mut rng, i);
        // Budgets from very loose (50%) down to unsatisfiable (0.01%).
        r.w = 0;
        r.budget_ppm = [500_000u32, 60_000, 30_000, 15_000, 100][rng.below(5) as usize];
        reqs.push(r);
    }
    let resps = client.exchange(&reqs).unwrap();
    for (req, resp) in reqs.iter().zip(&resps) {
        assert_eq!(
            resp.value,
            expect_one(req),
            "bits={} budget={}ppm routed w={}",
            req.bits,
            req.budget_ppm,
            profile.pick_w(req.op, req.bits, req.budget_ppm)
        );
    }
    // The router must use the knob range: a 50% budget is satisfied by
    // pure Mitchell, a 100 ppm budget degrades to best effort (W_MAX).
    assert_eq!(profile.pick_w(ReqOp::Mul, 16, 500_000), 0);
    assert_eq!(profile.pick_w(ReqOp::Mul, 16, 100), simdive::arith::W_MAX);
    server.shutdown();
}

#[test]
fn mixed_w_traffic_packs_lanes_through_the_shared_pool() {
    // Coordinator v2's reason to exist: mixed-accuracy traffic no longer
    // fragments across per-w pools, so the packer still fills words. An
    // 8-bit-only mixed-w stream must sustain high lane utilization.
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap().with_chunk(256);
    let mut rng = Rng::new(0x9_AC4E);
    let reqs: Vec<WireRequest> = (0..8_000u64)
        .map(|i| {
            let mut r = random_request(&mut rng, i);
            r.bits = 8;
            r.a = rng.operand(8);
            r.b = rng.operand(8);
            r
        })
        .collect();
    let resps = client.exchange(&reqs).unwrap();
    for (req, resp) in reqs.iter().zip(&resps) {
        assert_eq!(resp.value, expect_one(req));
    }
    let stats = client.stats().unwrap();
    assert!(stats.words > 0);
    let util = stats.lane_utilization();
    assert!(
        util > 0.5,
        "mixed-w 8-bit stream should pack >2 lanes/word on average, got {util:.3}"
    );
    server.shutdown();
}

#[test]
fn loadgen_loopback_reports_and_renders_json() {
    use simdive::serve::loadgen::{self, LoadgenConfig};
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let cfg =
        LoadgenConfig { connections: 2, requests: 4_000, chunk: 64, ..LoadgenConfig::default() };
    let report = loadgen::run(&addr, &cfg).unwrap();
    assert_eq!(report.requests, 4_000);
    assert_eq!(report.connections, 2);
    assert!(report.rps > 0.0);
    assert!(report.server.requests >= 4_000);
    assert!(report.server.words > 0);
    let json = loadgen::to_json(&report, 1_000, 123.4);
    assert!(json.contains("\"schema\": \"simdive-serve-v1\""));
    assert!(json.contains("\"batched_rps\": 123.4"));
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    server.shutdown();
}

#[test]
fn stats2_reports_stages_shards_and_tiers_on_a_loaded_server() {
    use simdive::obs::trace::STAGE_NAMES;
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap().with_chunk(256);
    let mut rng = Rng::new(0x57A7_5200);
    let n = 8_000u64;
    let reqs: Vec<WireRequest> = (0..n).map(|i| random_request(&mut rng, i)).collect();
    let resps = client.exchange(&reqs).unwrap();
    assert_eq!(resps.len(), reqs.len());
    for (req, resp) in reqs.iter().zip(&resps) {
        assert_eq!(resp.value, expect_one(req));
    }

    let snap = client.stats2().unwrap();
    // Every lifecycle stage must have recorded samples: admit/write on the
    // serve side, queue/assemble/execute merged across shard instances.
    for stage in STAGE_NAMES {
        let h = snap
            .hist(&format!("stage.{stage}"))
            .unwrap_or_else(|| panic!("stage.{stage} histogram missing"));
        assert!(h.count() > 0, "stage.{stage} recorded nothing under load");
        assert!(h.percentile_us(0.5) <= h.percentile_us(0.99), "stage.{stage} not monotone");
    }
    // Per-shard gauges and counters exist for shard 0 (and whatever other
    // shards the default config spawned).
    assert!(snap.gauge("shard.0.queue_depth").is_some(), "shard 0 queue-depth gauge missing");
    assert!(snap.counter("shard.0.residue_flushes").is_some(), "shard 0 residue counter missing");
    // Tier accounting is exact: every request occupies exactly one lane,
    // and the per-lane tier add happens before the response is routed, so
    // with all n responses in hand the tier counters must sum to n.
    let tier_sum: u64 = snap
        .entries
        .iter()
        .filter(|(name, _)| name.starts_with("tier."))
        .filter_map(|(name, _)| snap.counter(name))
        .sum();
    assert_eq!(tier_sum, n, "tier counters must account for every request lane");
    // All requests here carry a fixed w (budget_ppm = 0), and the engine
    // saw exactly n requests.
    assert_eq!(snap.counter("route.fixed_requests"), Some(n));
    assert_eq!(snap.counter("route.budget_requests"), Some(0));
    assert_eq!(snap.counter("engine.requests"), Some(n));
    assert_eq!(snap.counter("serve.requests"), Some(n));

    // The seeded 1-in-64 sampler must have captured traces, and every
    // span's timestamps must be monotone through the pipeline.
    let events = client.trace_events().unwrap();
    assert!(!events.is_empty(), "no sampled trace events after {n} requests");
    for e in &events {
        assert!(e.t_admit_ns > 0, "trace event missing admission stamp");
        assert!(e.t_admit_ns <= e.t_submit_ns, "admit after submit: {e:?}");
        assert!(e.t_submit_ns <= e.t_fold_ns, "submit after fold: {e:?}");
        assert!(e.t_fold_ns <= e.t_emit_ns, "fold after emit: {e:?}");
        assert!(e.t_emit_ns <= e.t_done_ns, "emit after done: {e:?}");
        assert!(e.t_done_ns <= e.t_write_ns, "done after write: {e:?}");
        assert!(matches!(e.bits, 8 | 16 | 32), "trace event bits {}", e.bits);
    }
    server.shutdown();
}

#[test]
fn bad_frame_answered_with_err_and_close() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    // Valid hello...
    let mut hello = [0u8; 8];
    hello[0..4].copy_from_slice(b"SDIV");
    hello[4..6].copy_from_slice(&simdive::serve::wire::VERSION.to_le_bytes());
    stream.write_all(&hello).unwrap();
    let mut ack = [0u8; 8];
    stream.read_exact(&mut ack).unwrap();
    assert_eq!(&ack[0..4], b"SDIV");
    // ...then a junk frame kind.
    stream.write_all(&[0x7F]).unwrap();
    let mut err = [0u8; 2];
    stream.read_exact(&mut err).unwrap();
    assert_eq!(err[0], 0xEE, "expected ERR frame");
    assert_eq!(err[1], 1, "expected ERR_BAD_FRAME");
    // Server closes after ERR.
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    server.shutdown();
}

#[test]
fn version_mismatch_gets_server_hello_then_err() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let mut hello = [0u8; 8];
    hello[0..4].copy_from_slice(b"SDIV");
    hello[4..6].copy_from_slice(&9u16.to_le_bytes());
    stream.write_all(&hello).unwrap();
    // The server still sends its own hello (so the client can name the
    // server's version in its error), then ERR_BAD_VERSION and a close.
    let mut ack = [0u8; 8];
    stream.read_exact(&mut ack).unwrap();
    assert_eq!(&ack[0..4], b"SDIV");
    assert_eq!(
        u16::from_le_bytes([ack[4], ack[5]]),
        simdive::serve::wire::VERSION,
        "server must state its version"
    );
    let mut err = [0u8; 2];
    stream.read_exact(&mut err).unwrap();
    assert_eq!(err[0], 0xEE);
    assert_eq!(err[1], 3, "expected ERR_BAD_VERSION");
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    server.shutdown();
}
