//! The static-analysis framework, proven per defect class: each test
//! hand-builds a netlist that is broken in exactly one way (pushing cells
//! and buses directly to bypass the builder's debug assertions) and
//! asserts exactly that diagnostic fires. The final sweep proves the real
//! generators are lint-error-free at every operand width (DESIGN.md §14).

use simdive::fabric::analyze::{self, Defect};
use simdive::fabric::netlist::{Bus, Cell, Netlist, NET0, NET1};
use simdive::fabric::{timing, Calibration};
use simdive::report::fabric;

#[test]
fn undriven_net_flagged() {
    let mut nl = Netlist::new();
    let x = nl.fresh_net(); // allocated, never driven
    let y = nl.lut(&[x], |m| m & 1 == 0);
    nl.output("y", &[y]);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 1);
    assert_eq!(r.count_of(Defect::UndrivenNet), 1);
    assert!(!r.is_sound());
}

#[test]
fn multiply_driven_net_flagged() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 2);
    let o = nl.fresh_net();
    nl.cells.push(Cell::Lut { inputs: vec![a[0]], truth: 0b01, out: o });
    nl.cells.push(Cell::Lut { inputs: vec![a[1]], truth: 0b01, out: o });
    nl.output("o", &[o]);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 1);
    assert_eq!(r.count_of(Defect::MultiplyDrivenNet), 1);
}

#[test]
fn topo_violation_flagged() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 1);
    let o2 = nl.fresh_net();
    let o1 = nl.fresh_net();
    // Cell 0 reads o2, which cell 1 drives — defined, but too late.
    nl.cells.push(Cell::Lut { inputs: vec![o2], truth: 0b01, out: o1 });
    nl.cells.push(Cell::Lut { inputs: vec![a[0]], truth: 0b01, out: o2 });
    nl.output("o", &[o1]);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 1);
    assert_eq!(r.count_of(Defect::TopoViolation), 1);
}

#[test]
fn bad_truth_table_flagged() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 7);
    let o1 = nl.fresh_net();
    let o2 = nl.fresh_net();
    // Truth bits set beyond entry 2^2 of a 2-input LUT.
    nl.cells.push(Cell::Lut { inputs: vec![a[0], a[1]], truth: 0xFF00, out: o1 });
    // Arity 7 cannot exist on the fabric at all.
    nl.cells.push(Cell::Lut { inputs: a.clone(), truth: 0, out: o2 });
    nl.output("o", &[o1, o2]);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 2);
    assert_eq!(r.count_of(Defect::BadTruthTable), 2);
}

#[test]
fn carry_chain_break_flagged() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 8);
    let s4 = [a[0], a[1], a[2], a[3]];
    let di = [a[4], a[5], a[6], a[7]];
    let (_o1, co1) = nl.carry4(s4, di, NET0);
    // Cascading from CO[1] instead of CO[3]: no dedicated route exists.
    let (o2, _co2) = nl.carry4(s4, di, co1[1]);
    nl.output("o", &o2);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 1);
    assert_eq!(r.count_of(Defect::CarryChainBreak), 1);
}

#[test]
fn dead_cell_flagged_as_warning() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 2);
    let _dead = nl.and2(a[0], a[1]); // never reaches an output
    let y = nl.xor2(a[0], a[1]);
    nl.output("y", &[y]);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 0, "dead logic is a warning, not an error");
    assert_eq!(r.warning_count(), 1);
    assert_eq!(r.count_of(Defect::UnreachableCell), 1);
    assert!(r.is_sound());
}

#[test]
fn const_foldable_luts_flagged() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 3);
    let outs: Vec<_> = (0..5).map(|_| nl.fresh_net()).collect();
    // Constant truth table.
    nl.cells.push(Cell::Lut { inputs: vec![a[0]], truth: 0b00, out: outs[0] });
    // Truth independent of input 1 (f = input 0).
    nl.cells.push(Cell::Lut { inputs: vec![a[0], a[1]], truth: 0b1010, out: outs[1] });
    // Constant-net input on a plain LUT.
    nl.cells.push(Cell::Lut { inputs: vec![a[0], NET1], truth: 0b0110, out: outs[2] });
    // LUT6_2 whose input 0 is unused by both halves.
    nl.cells.push(Cell::Lut52 {
        inputs: vec![a[0], a[1], a[2]],
        truth5: 0x3C,
        truth6: 0x3C,
        out5: outs[3],
        out6: outs[4],
    });
    nl.output("o", &outs);
    let r = analyze::lint(&nl);
    assert_eq!(r.error_count(), 0, "foldable LUTs are warnings, not errors");
    assert_eq!(r.count_of(Defect::ConstFoldable), 4);
}

#[test]
fn out_of_range_nets_flagged_without_panicking() {
    let mut nl = Netlist::new();
    let o = nl.fresh_net();
    nl.cells.push(Cell::Lut { inputs: vec![999], truth: 0b01, out: o });
    nl.outputs.push(Bus { name: "o".into(), nets: vec![o, 777] });
    let r = analyze::lint(&nl);
    assert_eq!(r.count_of(Defect::OutOfRangeNet), 2);
    assert!(!r.is_sound());
}

#[test]
fn every_generated_design_is_lint_error_free() {
    for bits in [8u32, 16, 32] {
        for bc in fabric::all_designs(bits) {
            let r = analyze::lint(&bc.netlist);
            assert_eq!(
                r.error_count(),
                0,
                "{} at {bits} bits:\n{}",
                bc.name,
                r.render_errors()
            );
        }
    }
}

#[test]
fn critical_path_reproduces_timing_analyze() {
    let cal = Calibration::default();
    for bc in fabric::all_designs(16) {
        let t = timing::analyze(&bc.netlist, &cal);
        let p = analyze::critical_path(&bc.netlist, &cal);
        assert!(
            (p.critical_ns - t.critical_ns).abs() < 1e-9,
            "{}: path {} vs analyze {}",
            bc.name,
            p.critical_ns,
            t.critical_ns
        );
        assert_eq!(p.levels, t.levels, "{}", bc.name);
        assert!(!p.steps.is_empty(), "{}: empty critical path", bc.name);
        let last = p.steps.last().unwrap();
        assert!(
            (last.arrival_ns - p.critical_ns).abs() < 1e-9,
            "{}: endpoint arrival {} != {}",
            bc.name,
            last.arrival_ns,
            p.critical_ns
        );
        for w in p.steps.windows(2) {
            assert!(
                w[0].arrival_ns <= w[1].arrival_ns + 1e-12,
                "{}: arrivals must be non-decreasing along the path",
                bc.name
            );
        }
    }
}

#[test]
fn cone_and_fanout_on_a_not_chain() {
    let mut nl = Netlist::new();
    let a = nl.input("a", 1);
    let mut x = a[0];
    for _ in 0..5 {
        x = nl.not(x);
    }
    nl.output("x", &[x]);
    let c = analyze::cones(&nl);
    assert_eq!(c.per_bit.len(), 1);
    assert_eq!(c.max_depth, 5);
    assert_eq!(c.max_cone_luts, 5);
    assert_eq!(c.max_cone_carry4, 0);
    let f = analyze::fanout(&nl);
    assert_eq!(f.max, 1);
    assert_eq!(f.histogram, vec![(1, 6)], "6 nets, each read exactly once");
    assert!((f.mean - 1.0).abs() < 1e-12);
}

#[cfg(debug_assertions)]
mod builder_rejects_undeclared {
    use super::*;

    #[test]
    #[should_panic(expected = "undeclared net")]
    fn in_output() {
        let mut nl = Netlist::new();
        nl.output("x", &[99]);
    }

    #[test]
    #[should_panic(expected = "undeclared net")]
    fn in_lut() {
        let mut nl = Netlist::new();
        let _ = nl.lut(&[99], |m| m & 1 == 1);
    }

    #[test]
    #[should_panic(expected = "undeclared net")]
    fn in_carry4() {
        let mut nl = Netlist::new();
        let _ = nl.carry4([99, NET0, NET0, NET0], [NET0; 4], NET0);
    }
}
