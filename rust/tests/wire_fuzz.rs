//! Wire-protocol fuzz/property tests (deterministic, seeded): random
//! byte mutations of valid SIMD-wire frames must yield clean `Err`s or
//! `ERR` answers — never a panic, and never a silently-accepted frame
//! whose decoded fields violate the protocol's invariants. Also
//! round-trips every frame kind, STATS included, through one contiguous
//! stream.

use simdive::arith::W_MAX;
use simdive::coordinator::ReqOp;
use simdive::faults::{ChaosStream, FaultConfig, FaultInjector};
use simdive::serve::wire::{
    self, ClientFrame, ServerFrame, WireRequest, WireStats, FLAG_BUDGET, REQ_BODY_LEN,
};
use simdive::serve::{ServeConfig, Server};
use simdive::util::Rng;
use std::io::Cursor;

const SEED_REQ_MUTATION: u64 = 0xF022_0001;
const SEED_BATCH_MUTATION: u64 = 0xF022_0002;
const SEED_BODY_FUZZ: u64 = 0xF022_0003;
const SEED_SERVER_FRAME_MUTATION: u64 = 0xF022_0004;

/// Every invariant `WireRequest::decode_body` promises about a request it
/// accepts. A mutated frame may still decode — mutating an operand byte
/// yields a different but *valid* request — but it must never decode to
/// something outside these bounds.
fn assert_valid(r: &WireRequest) {
    assert!(matches!(r.bits, 8 | 16 | 32), "accepted width {}", r.bits);
    assert!(r.w <= W_MAX, "accepted w {}", r.w);
    let max = simdive::arith::max_val(r.bits);
    assert!(r.a <= max && r.b <= max, "accepted out-of-range operands ({}, {})", r.a, r.b);
    assert!(matches!(r.op, ReqOp::Mul | ReqOp::Div));
}

fn sample_request(rng: &mut Rng, id: u64) -> WireRequest {
    let bits = [8u32, 16, 32][rng.below(3) as usize];
    let budget_ppm =
        if rng.below(3) == 0 { 1 + rng.below(1_000_000) as u32 } else { 0 };
    WireRequest {
        id,
        op: if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
        bits,
        w: rng.below(W_MAX as u64 + 1) as u32,
        budget_ppm,
        a: rng.operand(bits),
        b: rng.operand(bits),
    }
}

/// Outcome check shared by the mutation properties: decoding the mutated
/// bytes must terminate cleanly, and anything accepted must be valid.
fn check_mutated_client_bytes(buf: &[u8]) {
    match wire::read_client_frame(&mut Cursor::new(buf)) {
        Ok(ClientFrame::Requests(reqs)) => {
            for r in &reqs {
                assert_valid(r);
            }
        }
        Ok(ClientFrame::Bad(code)) => {
            assert!(
                matches!(code, wire::ERR_BAD_FRAME | wire::ERR_BAD_REQUEST),
                "unknown error code {code}"
            );
        }
        Ok(ClientFrame::Stats) | Ok(ClientFrame::Stats2) | Ok(ClientFrame::Trace)
        | Ok(ClientFrame::Eof) => {}
        Err(_) => {} // truncated/garbled I/O surfaces as a clean error
    }
}

#[test]
fn mutated_single_request_frames_never_panic_or_leak_invalid_fields() {
    let mut rng = Rng::new(SEED_REQ_MUTATION);
    for case in 0..4_000u64 {
        let req = sample_request(&mut rng, case);
        let mut buf = Vec::new();
        wire::write_request(&mut buf, &req).unwrap();
        // 1..=4 byte mutations anywhere in the frame (kind byte included).
        let mutations = 1 + rng.below(4) as usize;
        for _ in 0..mutations {
            let pos = rng.below(buf.len() as u64) as usize;
            buf[pos] ^= (1 + rng.below(255)) as u8;
        }
        check_mutated_client_bytes(&buf);
    }
}

#[test]
fn mutated_batch_frames_never_panic_or_leak_invalid_fields() {
    let mut rng = Rng::new(SEED_BATCH_MUTATION);
    for case in 0..800u64 {
        let n = 1 + rng.below(30);
        let reqs: Vec<WireRequest> =
            (0..n).map(|i| sample_request(&mut rng, case * 100 + i)).collect();
        let mut buf = Vec::new();
        wire::write_batch(&mut buf, &reqs).unwrap();
        let mutations = 1 + rng.below(6) as usize;
        for _ in 0..mutations {
            let pos = rng.below(buf.len() as u64) as usize;
            buf[pos] ^= (1 + rng.below(255)) as u8;
        }
        check_mutated_client_bytes(&buf);
    }
}

#[test]
fn truncated_frames_are_clean_errors() {
    let mut rng = Rng::new(SEED_BODY_FUZZ);
    let req = sample_request(&mut rng, 42);
    let mut buf = Vec::new();
    wire::write_request(&mut buf, &req).unwrap();
    wire::write_batch(&mut buf, &[sample_request(&mut rng, 43), sample_request(&mut rng, 44)])
        .unwrap();
    // Every strict prefix must either report a clean Eof (empty) or a
    // clean I/O error (mid-frame cut) — and decode the frames it fully
    // contains.
    for cut in 0..buf.len() {
        let mut cur = Cursor::new(&buf[..cut]);
        loop {
            match wire::read_client_frame(&mut cur) {
                Ok(ClientFrame::Requests(reqs)) => {
                    for r in &reqs {
                        assert_valid(r);
                    }
                }
                Ok(ClientFrame::Eof) => break,
                Ok(ClientFrame::Stats)
                | Ok(ClientFrame::Stats2)
                | Ok(ClientFrame::Trace)
                | Ok(ClientFrame::Bad(_)) => {}
                Err(e) => {
                    assert_eq!(
                        e.kind(),
                        std::io::ErrorKind::UnexpectedEof,
                        "cut at {cut}: {e}"
                    );
                    break;
                }
            }
        }
    }
}

#[test]
fn random_request_bodies_decode_or_reject_cleanly() {
    let mut rng = Rng::new(SEED_BODY_FUZZ ^ 0xB0D1);
    for _ in 0..20_000 {
        let mut body = [0u8; REQ_BODY_LEN];
        for b in body.iter_mut() {
            *b = rng.below(256) as u8;
        }
        if let Ok(r) = WireRequest::decode_body(&body) {
            assert_valid(&r);
            // Accepted bodies re-encode to the very same bytes — decode
            // accepts nothing encode could not have produced.
            let mut re = [0u8; REQ_BODY_LEN];
            r.encode_body(&mut re);
            assert_eq!(re, body, "decode/encode must be a bijection on accepted bodies");
        }
    }
}

#[test]
fn mutated_server_frames_never_panic_the_client_side() {
    let mut rng = Rng::new(SEED_SERVER_FRAME_MUTATION);
    for _ in 0..4_000 {
        let mut buf = Vec::new();
        match rng.below(3) {
            0 => wire::write_response(&mut buf, rng.next_u64(), rng.next_u64()).unwrap(),
            1 => wire::write_stats_resp(
                &mut buf,
                &WireStats { requests: rng.next_u64(), ..WireStats::default() },
            )
            .unwrap(),
            _ => wire::write_err(&mut buf, wire::ERR_BAD_REQUEST).unwrap(),
        }
        let mutations = 1 + rng.below(3) as usize;
        for _ in 0..mutations {
            let pos = rng.below(buf.len() as u64) as usize;
            buf[pos] ^= (1 + rng.below(255)) as u8;
        }
        // Any outcome is fine except a panic; a decoded frame is by
        // construction structurally valid (fixed-size bodies).
        let _ = wire::read_server_frame(&mut Cursor::new(&buf));
    }
}

#[test]
fn every_frame_kind_roundtrips_through_one_stream() {
    // hello → REQ (fixed-w) → REQ (budget) → BATCH → STATS on the client
    // stream; RESP → STATS_RESP → ERR on the server stream.
    let mut rng = Rng::new(0x2066_57EA);
    let mut c2s = Vec::new();
    wire::write_hello(&mut c2s).unwrap();
    let single = sample_request(&mut rng, 1);
    let budget = WireRequest { budget_ppm: 12_345, w: 0, ..sample_request(&mut rng, 2) };
    let batch: Vec<WireRequest> = (3..40).map(|i| sample_request(&mut rng, i)).collect();
    wire::write_request(&mut c2s, &single).unwrap();
    wire::write_request(&mut c2s, &budget).unwrap();
    wire::write_batch(&mut c2s, &batch).unwrap();
    wire::write_stats_req(&mut c2s).unwrap();

    let mut cur = Cursor::new(&c2s);
    assert_eq!(wire::read_hello(&mut cur).unwrap(), wire::VERSION);
    match wire::read_client_frame(&mut cur).unwrap() {
        ClientFrame::Requests(v) => assert_eq!(v, vec![single]),
        other => panic!("unexpected frame {other:?}"),
    }
    match wire::read_client_frame(&mut cur).unwrap() {
        ClientFrame::Requests(v) => {
            assert_eq!(v, vec![budget]);
            assert_eq!(v[0].budget_ppm, 12_345);
        }
        other => panic!("unexpected frame {other:?}"),
    }
    match wire::read_client_frame(&mut cur).unwrap() {
        ClientFrame::Requests(v) => assert_eq!(v, batch),
        other => panic!("unexpected frame {other:?}"),
    }
    assert!(matches!(wire::read_client_frame(&mut cur).unwrap(), ClientFrame::Stats));
    assert!(matches!(wire::read_client_frame(&mut cur).unwrap(), ClientFrame::Eof));

    let mut s2c = Vec::new();
    wire::write_hello(&mut s2c).unwrap();
    let stats = WireStats {
        requests: 10,
        words: 4,
        active_lanes: 14,
        total_lanes: 16,
        energy_mpj: 12_500,
        p50_us: 3,
        p99_us: 17,
        conn_requests: 10,
        conn_p50_us: 3,
        conn_p99_us: 17,
        connections: 2,
        shed_overload: 5,
        failed_unavailable: 1,
    };
    wire::write_response(&mut s2c, 9, 430).unwrap();
    wire::write_response_err(&mut s2c, 11, wire::ERR_OVERLOAD).unwrap();
    wire::write_stats_resp(&mut s2c, &stats).unwrap();
    wire::write_err(&mut s2c, wire::ERR_BAD_VERSION).unwrap();
    let mut cur = Cursor::new(&s2c);
    assert_eq!(wire::read_hello(&mut cur).unwrap(), wire::VERSION);
    assert!(matches!(
        wire::read_server_frame(&mut cur).unwrap(),
        ServerFrame::Resp(r) if r.id == 9 && r.value == 430 && r.err == 0
    ));
    assert!(matches!(
        wire::read_server_frame(&mut cur).unwrap(),
        ServerFrame::Resp(r) if r.id == 11 && r.err == wire::ERR_OVERLOAD
    ));
    match wire::read_server_frame(&mut cur).unwrap() {
        ServerFrame::Stats(s) => assert_eq!(s, stats),
        other => panic!("unexpected frame {other:?}"),
    }
    assert!(matches!(
        wire::read_server_frame(&mut cur).unwrap(),
        ServerFrame::Err(code) if code == wire::ERR_BAD_VERSION
    ));
}

#[test]
fn server_answers_corrupted_request_body_with_err_and_close() {
    use std::io::{Read, Write};
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let mut hello = [0u8; 8];
    hello[0..4].copy_from_slice(b"SDIV");
    hello[4..6].copy_from_slice(&wire::VERSION.to_le_bytes());
    stream.write_all(&hello).unwrap();
    let mut ack = [0u8; 8];
    stream.read_exact(&mut ack).unwrap();
    // A REQ frame whose body fails validation (width byte 24).
    let mut body = [0u8; REQ_BODY_LEN];
    WireRequest { id: 1, op: ReqOp::Mul, bits: 8, w: 8, budget_ppm: 0, a: 43, b: 10 }
        .encode_body(&mut body);
    body[25] = 24;
    stream.write_all(&[wire::FRAME_REQ]).unwrap();
    stream.write_all(&body).unwrap();
    let mut err = [0u8; 2];
    stream.read_exact(&mut err).unwrap();
    assert_eq!(err[0], wire::FRAME_ERR, "expected ERR frame");
    assert_eq!(err[1], wire::ERR_BAD_REQUEST);
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after ERR");
    server.shutdown();

    // Same over a reserved-flags violation.
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&hello).unwrap();
    stream.read_exact(&mut ack).unwrap();
    let mut body = [0u8; REQ_BODY_LEN];
    WireRequest { id: 1, op: ReqOp::Mul, bits: 8, w: 8, budget_ppm: 0, a: 43, b: 10 }
        .encode_body(&mut body);
    body[27] = FLAG_BUDGET | 0x40;
    stream.write_all(&[wire::FRAME_REQ]).unwrap();
    stream.write_all(&body).unwrap();
    stream.read_exact(&mut err).unwrap();
    assert_eq!((err[0], err[1]), (wire::FRAME_ERR, wire::ERR_BAD_REQUEST));
    server.shutdown();
}

/// A valid multi-frame client stream, used by the chaos-schedule tests.
fn sample_stream(rng: &mut Rng) -> (Vec<u8>, usize) {
    let mut buf = Vec::new();
    let mut frames = 0usize;
    for case in 0..20u64 {
        if rng.below(4) == 0 {
            let n = 1 + rng.below(10);
            let reqs: Vec<WireRequest> =
                (0..n).map(|i| sample_request(rng, case * 100 + i)).collect();
            wire::write_batch(&mut buf, &reqs).unwrap();
        } else {
            wire::write_request(&mut buf, &sample_request(rng, case)).unwrap();
        }
        frames += 1;
    }
    (buf, frames)
}

#[test]
fn full_stall_schedule_dribbles_but_decodes_identically() {
    // 100% stall: every read returns one byte. A decoder that assumed one
    // read per frame would garble; `read_exact` loops, so the decoded
    // stream must be byte-identical to the unstalled one.
    let mut rng = Rng::new(0x57A1_1001);
    let (buf, frames) = sample_stream(&mut rng);
    let want: Vec<ClientFrame> = {
        let mut cur = Cursor::new(&buf);
        (0..frames).map(|_| wire::read_client_frame(&mut cur).unwrap()).collect()
    };
    let inj = FaultInjector::new(FaultConfig {
        seed: 9,
        wire_stall_ppm: 1_000_000,
        ..FaultConfig::default()
    });
    let mut chaotic = ChaosStream::new(Cursor::new(&buf), inj);
    for w in &want {
        let got = wire::read_client_frame(&mut chaotic).unwrap();
        match (w, &got) {
            (ClientFrame::Requests(a), ClientFrame::Requests(b)) => assert_eq!(a, b),
            _ => panic!("stalled stream decoded differently: {w:?} vs {got:?}"),
        }
    }
    assert!(matches!(wire::read_client_frame(&mut chaotic).unwrap(), ClientFrame::Eof));
    assert_eq!(chaotic.corruptions(), 0, "stall must never alter bytes");
}

#[test]
fn reset_schedules_surface_as_clean_errors_never_panics() {
    // Sweep reset rates; every decode either succeeds, rejects cleanly,
    // or errors — and once the sticky reset fires, it keeps failing.
    for ppm in [5_000u32, 50_000, 500_000, 1_000_000] {
        let mut rng = Rng::new(0x8E5E_7000 ^ ppm as u64);
        let (buf, _) = sample_stream(&mut rng);
        let inj = FaultInjector::new(FaultConfig {
            seed: ppm as u64,
            wire_reset_ppm: ppm,
            ..FaultConfig::default()
        });
        let mut chaotic = ChaosStream::new(Cursor::new(&buf), inj);
        loop {
            match wire::read_client_frame(&mut chaotic) {
                Ok(ClientFrame::Eof) => break,
                Ok(_) => {}
                Err(e) => {
                    if chaotic.is_reset() {
                        assert_eq!(e.kind(), std::io::ErrorKind::ConnectionReset);
                        let again = wire::read_client_frame(&mut chaotic).unwrap_err();
                        assert_eq!(
                            again.kind(),
                            std::io::ErrorKind::ConnectionReset,
                            "reset must be sticky"
                        );
                    } else {
                        assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof, "{e}");
                    }
                    break;
                }
            }
        }
    }
}

#[test]
fn corruption_schedules_decode_cleanly_or_reject() {
    // Bit flips on the read path: every frame decoded off the corrupted
    // stream must still satisfy the protocol invariants or fail cleanly —
    // re-using the same outcome check as the byte-mutation properties.
    for ppm in [10_000u32, 100_000, 1_000_000] {
        let mut rng = Rng::new(0xC022_0000 ^ ppm as u64);
        let (buf, _) = sample_stream(&mut rng);
        let inj = FaultInjector::new(FaultConfig {
            seed: 0xFACE ^ ppm as u64,
            wire_corrupt_ppm: ppm,
            ..FaultConfig::default()
        });
        let mut chaotic = ChaosStream::new(Cursor::new(&buf), inj);
        loop {
            match wire::read_client_frame(&mut chaotic) {
                Ok(ClientFrame::Requests(reqs)) => {
                    for r in &reqs {
                        assert_valid(r);
                    }
                }
                Ok(ClientFrame::Eof) => break,
                Ok(ClientFrame::Stats)
                | Ok(ClientFrame::Stats2)
                | Ok(ClientFrame::Trace)
                | Ok(ClientFrame::Bad(_)) => {}
                Err(_) => break, // desynced mid-frame: a clean error
            }
        }
        if ppm == 1_000_000 {
            assert!(chaotic.corruptions() > 0, "full-rate corruption must fire");
        }
    }
}

// ---------------------------------------------------------------------------
// Wire-compat regression pins (v4): the bytes every earlier version put on
// the wire must be reproduced exactly — the new STATS2/TRACE ops are pure
// additions, never a re-encoding of what v1–v3 peers already speak.
// ---------------------------------------------------------------------------

#[test]
fn hello_bytes_are_pinned_and_every_version_decodes() {
    // The 8-byte hello has been `[S, D, I, V, ver:u16 LE, 0, 0]` since v1.
    let mut buf = Vec::new();
    wire::write_hello(&mut buf).unwrap();
    assert_eq!(wire::VERSION, 4, "bump this pin alongside the version");
    assert_eq!(buf, [b'S', b'D', b'I', b'V', 4, 0, 0, 0], "v4 hello bytes moved");
    // Decoding stays version-agnostic: hellos from every historical
    // version parse to that version number (rejection is server policy,
    // not a parse failure — a cross-version client must be able to read
    // which version the server speaks).
    for ver in 1u16..=4 {
        let h = [b'S', b'D', b'I', b'V', ver as u8, 0, 0, 0];
        assert_eq!(wire::read_hello(&mut Cursor::new(&h)).unwrap(), ver, "hello v{ver}");
    }
}

#[test]
fn legacy_stats_resp_bytes_are_pinned_after_v4() {
    // The v1 STATS_RESP: kind byte 0x82 + thirteen u64 LE fields in
    // declaration order — 105 bytes, byte-identical under v4.
    let stats = WireStats {
        requests: 0x0102_0304_0506_0708,
        words: 2,
        active_lanes: 3,
        total_lanes: 4,
        energy_mpj: 5,
        p50_us: 6,
        p99_us: 7,
        conn_requests: 8,
        conn_p50_us: 9,
        conn_p99_us: 10,
        connections: 11,
        shed_overload: 12,
        failed_unavailable: 13,
    };
    let mut buf = Vec::new();
    wire::write_stats_resp(&mut buf, &stats).unwrap();
    let mut want = vec![0x82u8];
    for v in [0x0102_0304_0506_0708u64, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13] {
        want.extend_from_slice(&v.to_le_bytes());
    }
    assert_eq!(buf.len(), 105, "legacy STATS_RESP frame length moved");
    assert_eq!(buf, want, "legacy STATS_RESP encoding moved");
    match wire::read_server_frame(&mut Cursor::new(&buf)).unwrap() {
        ServerFrame::Stats(s) => assert_eq!(s, stats),
        other => panic!("unexpected frame {other:?}"),
    }
}

#[test]
fn stats2_and_trace_request_frames_are_single_pinned_bytes() {
    let mut s2 = Vec::new();
    wire::write_stats2_req(&mut s2).unwrap();
    assert_eq!(s2, [0x04], "STATS2 request byte moved");
    let mut tr = Vec::new();
    wire::write_trace_req(&mut tr).unwrap();
    assert_eq!(tr, [0x05], "TRACE request byte moved");
    // And the legacy client kinds keep their v1 bytes.
    let mut st = Vec::new();
    wire::write_stats_req(&mut st).unwrap();
    assert_eq!(st, [0x03], "STATS request byte moved");
}

#[test]
fn server_rejects_pre_v4_hellos_with_bad_version_and_closes() {
    use std::io::{Read, Write};
    for ver in 1u16..=3 {
        let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
        let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
        let mut hello = [0u8; 8];
        hello[0..4].copy_from_slice(b"SDIV");
        hello[4..6].copy_from_slice(&ver.to_le_bytes());
        stream.write_all(&hello).unwrap();
        // The server answers with its own hello (so the old client can
        // see which version it speaks), then ERR_BAD_VERSION, then EOF.
        let mut ack = [0u8; 8];
        stream.read_exact(&mut ack).unwrap();
        assert_eq!(&ack[0..4], b"SDIV");
        assert_eq!(u16::from_le_bytes(ack[4..6].try_into().unwrap()), wire::VERSION);
        let mut err = [0u8; 2];
        stream.read_exact(&mut err).unwrap();
        assert_eq!((err[0], err[1]), (wire::FRAME_ERR, wire::ERR_BAD_VERSION), "hello v{ver}");
        let mut rest = Vec::new();
        stream.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server must close after rejecting v{ver}");
        server.shutdown();
    }
}

#[test]
fn mutated_stats2_and_trace_responses_never_panic_the_decoder() {
    use simdive::obs::registry::HIST_BUCKETS;
    use simdive::obs::{HistSnapshot, Snapshot, TraceEvent, Value};
    let mut rng = Rng::new(0xF022_0005);
    let mut snap = Snapshot::default();
    snap.push("engine.requests", Value::Counter(41));
    snap.push("shard.0.queue_depth", Value::Gauge(-3));
    let mut h = HistSnapshot::default();
    h.buckets[0] = 1;
    h.buckets[HIST_BUCKETS - 1] = 2;
    snap.push("stage.queue", Value::Hist(h));
    let events = vec![TraceEvent { id: 7, ..TraceEvent::default() }];
    for _ in 0..4_000 {
        let mut buf = Vec::new();
        if rng.below(2) == 0 {
            wire::write_stats2_resp(&mut buf, &snap).unwrap();
        } else {
            wire::write_trace_resp(&mut buf, &events).unwrap();
        }
        let mutations = 1 + rng.below(4) as usize;
        for _ in 0..mutations {
            let pos = rng.below(buf.len() as u64) as usize;
            buf[pos] ^= (1 + rng.below(255)) as u8;
        }
        // Any outcome but a panic: decoded, rejected as InvalidData, or a
        // short read — hostile length fields must hit the decode caps.
        let _ = wire::read_server_frame(&mut Cursor::new(&buf));
    }
}
