//! Fault-path integration tests (DESIGN.md §11): dead/silent servers,
//! shard-panic supervision, double-fault failure, overload shedding, the
//! engine-seam scalar fallback, connection-count reclamation, and the
//! in-process chaos scenario.
//!
//! No test relies on a sleep for *correctness*: waits are bounded
//! `recv_timeout`s / convergence polls, and the timing-sensitive shed
//! test keeps a 7× margin between its admission deadline (20 ms) and the
//! injected shard slowdown (150 ms).

use simdive::arith::simdive::{simdive_div_w, simdive_mul_w};
use simdive::arith::W_MAX;
use simdive::coordinator::{ReqOp, Request};
use simdive::engine::{Backend, Reference, Route, Sharded, ShardedConfig};
use simdive::faults::{silence_injected_panics, FaultConfig, FaultInjector};
use simdive::serve::chaos::{self, ChaosConfig};
use simdive::serve::client::{is_timeout, RetryPolicy};
use simdive::serve::wire::{self, WireRequest};
use simdive::serve::{Client, ServeConfig, Server};
use simdive::util::Rng;
use std::io::Read as _;
use std::net::TcpListener;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

fn expected_wire(r: &WireRequest) -> u64 {
    match r.op {
        ReqOp::Mul => simdive_mul_w(r.bits, r.a, r.b, r.w),
        ReqOp::Div => simdive_div_w(r.bits, r.a, r.b, r.w),
    }
}

fn expected_req(r: &Request) -> u64 {
    match r.op {
        ReqOp::Mul => simdive_mul_w(r.bits, r.a, r.b, r.w),
        ReqOp::Div => simdive_div_w(r.bits, r.a, r.b, r.w),
    }
}

fn mixed_requests(seed: u64, n: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let bits = [8u32, 8, 16, 32][rng.below(4) as usize];
            Request {
                id: i,
                op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
                bits,
                w: rng.below(W_MAX as u64 + 1) as u32,
                a: rng.operand(bits),
                b: rng.operand(bits),
            }
        })
        .collect()
}

fn wire_request(id: u64, a: u64, b: u64) -> WireRequest {
    WireRequest { id, op: ReqOp::Mul, bits: 8, w: 8, budget_ppm: 0, a, b }
}

#[test]
fn client_errors_cleanly_when_server_dies_mid_exchange() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut hello = [0u8; 8];
        s.read_exact(&mut hello).unwrap();
        wire::write_hello(&mut s).unwrap();
        // Swallow the first ~100 request bytes, then die mid-exchange.
        let mut sink = [0u8; 100];
        let _ = s.read_exact(&mut sink);
    });
    let mut client = Client::connect(addr).unwrap();
    let reqs: Vec<WireRequest> =
        (0..1000).map(|i| wire_request(i, 1 + i % 200, 3)).collect();
    let t0 = Instant::now();
    assert!(client.exchange(&reqs).is_err(), "a dead server must be an error, not a hang");
    // The default socket timeout bounds every blocking call; the whole
    // exchange must fail well inside it.
    assert!(t0.elapsed() < Duration::from_secs(30), "took {:?}", t0.elapsed());
    fake.join().unwrap();
}

#[test]
fn silent_server_yields_timeout_not_hang() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (done_tx, done_rx) = channel::<()>();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut hello = [0u8; 8];
        s.read_exact(&mut hello).unwrap();
        wire::write_hello(&mut s).unwrap();
        // Hold the connection open, never answering a request.
        let _ = done_rx.recv();
    });
    let client = Client::connect(addr).unwrap();
    let mut client = client.with_io_timeout(Some(Duration::from_millis(200))).unwrap();
    let e = client.call(wire_request(1, 43, 10)).unwrap_err();
    assert!(is_timeout(&e), "expected a socket timeout, got {e}");
    done_tx.send(()).unwrap();
    fake.join().unwrap();
}

#[test]
fn shard_panic_supervision_recovers_in_flight_words() {
    silence_injected_panics();
    // 40% of emission rounds panic after emitting; recovery re-executes
    // every emitted word, so every request still gets its exact answer.
    let inj = FaultInjector::new(FaultConfig {
        seed: 0x5117,
        shard_panic_ppm: 400_000,
        ..FaultConfig::default()
    });
    let pool = Sharded::start_with_faults(
        ShardedConfig { shards: 2, queue_depth: 64, batch: 8 },
        Some(inj),
    );
    let reqs = mixed_requests(0xFA01, 1000);
    let (tx, rx) = channel();
    for (base, piece) in reqs.chunks(50).enumerate() {
        let chunk: Vec<(Request, Route)> = piece
            .iter()
            .enumerate()
            .map(|(k, r)| (*r, Route::Slot(tx.clone(), (base * 50 + k) as u32)))
            .collect();
        pool.submit(chunk);
    }
    let mut got = vec![None; reqs.len()];
    for _ in 0..reqs.len() {
        let (slot, resp) = rx
            .recv_timeout(Duration::from_secs(20))
            .expect("supervision must deliver every response");
        assert!(got[slot as usize].replace(resp).is_none(), "slot {slot} answered twice");
    }
    for (k, r) in reqs.iter().enumerate() {
        let resp = got[k].unwrap();
        assert_eq!(resp.err, 0, "req {k} failed under recoverable faults");
        assert_eq!(resp.value, expected_req(r), "req {k} not bit-exact after recovery");
    }
    let s = pool.shutdown();
    assert_eq!(s.requests, 1000);
}

#[test]
fn unrecoverable_shard_fault_fails_requests_instead_of_hanging() {
    silence_injected_panics();
    // Every round panics AND every recovery is forced to fail: requests
    // must still resolve — with ERR_UNAVAILABLE — and shutdown must join.
    let inj = FaultInjector::new(FaultConfig {
        seed: 0xDEAD,
        shard_panic_ppm: 1_000_000,
        recover_panic_ppm: 1_000_000,
        ..FaultConfig::default()
    });
    let pool = Sharded::start_with_faults(
        ShardedConfig { shards: 2, queue_depth: 32, batch: 8 },
        Some(inj),
    );
    let reqs = mixed_requests(0xFA02, 200);
    let (tx, rx) = channel();
    let chunk: Vec<(Request, Route)> = reqs
        .iter()
        .enumerate()
        .map(|(k, r)| (*r, Route::Slot(tx.clone(), k as u32)))
        .collect();
    pool.submit(chunk);
    for _ in 0..reqs.len() {
        let (_, resp) = rx
            .recv_timeout(Duration::from_secs(20))
            .expect("a double fault must fail the request, not strand it");
        assert_eq!(
            resp.err,
            simdive::engine::sharded::RESP_ERR_UNAVAILABLE,
            "double-faulted requests must carry the unavailable code"
        );
    }
    pool.shutdown(); // must join: the shard threads survived every panic
}

#[test]
fn engine_stream_falls_back_to_scalar_when_shards_fail() {
    silence_injected_panics();
    let inj = FaultInjector::new(FaultConfig {
        seed: 3,
        shard_panic_ppm: 1_000_000,
        recover_panic_ppm: 1_000_000,
        ..FaultConfig::default()
    });
    let pool = Sharded::start_with_faults(
        ShardedConfig { shards: 2, queue_depth: 64, batch: 8 },
        Some(inj),
    );
    let reqs = mixed_requests(0xFA03, 500);
    let (mut out, mut want) = (Vec::new(), Vec::new());
    // Even with every shard round double-faulting, the Backend seam
    // contract holds: in-process callers get scalar-model answers.
    Backend::execute_stream(&pool, &reqs, &mut out);
    Reference.execute_stream(&reqs, &mut want);
    assert_eq!(out, want, "seam contract must survive total shard failure");
    pool.shutdown();
}

#[test]
fn overload_is_shed_with_deadline_and_recovered_by_retry() {
    silence_injected_panics();
    // Window of 1 + 150 ms shard slowdown vs a 20 ms admission deadline:
    // the first request of a burst is admitted, the rest shed. The 7×
    // margin between deadline and slowdown keeps this deterministic.
    let cfg = ServeConfig {
        workers: 2,
        batch: 8,
        queue_depth: 64,
        window: 1,
        deadline_ms: 20,
        io_timeout_ms: 10_000,
        faults: Some(FaultConfig {
            seed: 7,
            shard_slow_ppm: 1_000_000,
            slow_ms: 150,
            ..FaultConfig::default()
        }),
    };
    let server = Server::start("127.0.0.1:0", cfg).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let reqs: Vec<WireRequest> = (0..8).map(|i| wire_request(i, 10 + i, 3)).collect();
    let resps = client.exchange(&reqs).unwrap();
    let (mut ok, mut shed) = (0u32, 0u32);
    for (resp, req) in resps.iter().zip(&reqs) {
        if resp.err == 0 {
            assert_eq!(resp.value, expected_wire(req));
            ok += 1;
        } else {
            assert_eq!(resp.err, wire::ERR_OVERLOAD, "unexpected error {}", resp.err);
            shed += 1;
        }
    }
    assert!(ok >= 1, "the admitted request must succeed");
    assert!(shed >= 1, "a full window past its deadline must shed");
    let stats = client.stats().unwrap();
    assert!(stats.shed_overload >= shed as u64, "server must count what it shed");

    // Retry recovers everything: overload is transient by design.
    let policy = RetryPolicy {
        max_attempts: 30,
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(5),
        deadline: Duration::from_secs(60),
    };
    let reqs: Vec<WireRequest> = (100..108).map(|i| wire_request(i, 1 + i % 200, 7)).collect();
    let resps = client.exchange_with_retry(&reqs, &policy).unwrap();
    for (resp, req) in resps.iter().zip(&reqs) {
        assert_eq!(resp.err, 0, "retry must eventually land every request");
        assert_eq!(resp.value, expected_wire(req));
    }
    server.shutdown();
}

#[test]
fn connections_return_to_baseline_after_a_client_storm() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
    assert_eq!(server.connections(), 0);
    let addr = server.local_addr();
    let mut handles = Vec::new();
    for c in 0..8u64 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr)?;
            let reqs: Vec<WireRequest> =
                (0..200).map(|i| wire_request(i, 1 + (c * 37 + i) % 200, 3)).collect();
            let resps = client.exchange(&reqs)?;
            assert_eq!(resps.len(), reqs.len());
            Ok::<(), std::io::Error>(())
        }));
    }
    for h in handles {
        h.join().unwrap().unwrap();
    }
    // Bounded convergence poll: TCP close propagation, not correctness.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.connections() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(server.connections(), 0, "connection slots must be reclaimed");
    server.shutdown();
}

#[test]
fn chaos_scenario_invariants_hold_under_server_faults() {
    silence_injected_panics();
    let cfg = ServeConfig {
        faults: Some(FaultConfig::server_chaos(0xAB, 10_000)),
        ..ServeConfig::default()
    };
    let server = Server::start("127.0.0.1:0", cfg).unwrap();
    let ccfg = ChaosConfig {
        connections: 2,
        requests: 2_000,
        chunk: 64,
        saboteur_rounds: 4,
        ..ChaosConfig::default()
    };
    let report = chaos::run(&server.local_addr().to_string(), &ccfg).unwrap();
    assert!(
        report.invariants_hold(),
        "chaos invariants violated: mismatches {}, unresolved {}, connections {} -> {}",
        report.mismatches,
        report.unresolved,
        report.baseline_connections,
        report.final_connections
    );
    assert_eq!(
        report.completed + report.failed,
        report.requests,
        "every request needs a definitive outcome"
    );
    server.shutdown();
}
