//! Coordinator lifecycle tests: shutdown/drop join every thread, work
//! queued before the stop completes with its responses delivered, and
//! concurrent streaming submitters keep their per-request index slots
//! (DESIGN.md §9).

use simdive::arith::simdive::simdive_mul_w;
use simdive::coordinator::{Coordinator, CoordinatorConfig, ReqOp, Request};
use simdive::util::Rng;
use std::sync::mpsc::channel;
use std::sync::Arc;

fn mul_req(id: u64, w: u32, a: u64, b: u64) -> Request {
    Request { id, op: ReqOp::Mul, bits: 8, w, a, b }
}

#[test]
fn shutdown_completes_in_flight_batches_before_joining() {
    // A batch queued before the Stop message must be fully executed and
    // its responses delivered even though shutdown() is called while the
    // batch is still in flight.
    let coord = Coordinator::start(CoordinatorConfig::default());
    let reqs: Vec<Request> =
        (0..500u64).map(|i| mul_req(i, (i % 9) as u32, 1 + i % 255, 3)).collect();
    let handle = coord.submit_batch(reqs.clone());
    let stats = coord.shutdown();
    assert_eq!(stats.requests, 500, "queued work must be drained, not dropped");
    let responses = handle.wait();
    assert_eq!(responses.len(), 500);
    for (resp, req) in responses.iter().zip(&reqs) {
        assert_eq!(resp.id, req.id);
        assert_eq!(resp.value, simdive_mul_w(8, req.a, req.b, req.w), "req {}", req.id);
    }
}

#[test]
fn drop_joins_threads_and_delivers_pending_singles() {
    let mut receivers = Vec::new();
    let reqs: Vec<Request> =
        (0..64u64).map(|i| mul_req(i, 8, 1 + i % 200, 7)).collect();
    {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            queue_depth: 128,
            batch: 16,
        });
        for r in &reqs {
            receivers.push(coord.submit(*r));
        }
        // `coord` dropped here: dropping the shard pool disconnects the
        // shard queues, which drain fully before every thread is joined.
    }
    for (rx, req) in receivers.into_iter().zip(&reqs) {
        let resp = rx.recv().expect("response must have been delivered before the join");
        assert_eq!(resp.value, simdive_mul_w(8, req.a, req.b, 8));
    }
}

#[test]
fn repeated_start_shutdown_cycles_are_clean() {
    // Start/stop churn must not wedge or accumulate state: every cycle's
    // threads are joined inside shutdown(), so 16 cycles complete quickly
    // and each one serves its requests in full.
    for cycle in 0..16u64 {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            queue_depth: 64,
            batch: 8,
        });
        let reqs: Vec<Request> =
            (0..40u64).map(|i| mul_req(i, (cycle % 9) as u32, 1 + i, 5)).collect();
        let responses = coord.submit_batch(reqs.clone()).wait();
        for (resp, req) in responses.iter().zip(&reqs) {
            assert_eq!(resp.value, simdive_mul_w(8, req.a, req.b, req.w), "cycle {cycle}");
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 40, "cycle {cycle}");
    }
}

#[test]
fn concurrent_streaming_submitters_preserve_index_slots() {
    // Several threads stream batches into one coordinator over one shared
    // response channel, each with its own base slot range. Every slot
    // must come back exactly once, carrying the response of exactly the
    // request submitted under that slot.
    const SUBMITTERS: u64 = 4;
    const PER: u64 = 1_000;
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::default()));
    let (tx, rx) = channel();
    let mut threads = Vec::new();
    for t in 0..SUBMITTERS {
        let coord = Arc::clone(&coord);
        let tx = tx.clone();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x51071 + t);
            let base = (t * PER) as u32;
            // Split into several streaming calls to interleave with the
            // other submitters.
            for chunk in 0..4u64 {
                let reqs: Vec<Request> = (0..PER / 4)
                    .map(|k| {
                        let slot = t * PER + chunk * (PER / 4) + k;
                        mul_req(slot, rng.below(9) as u32, rng.operand(8), rng.operand(8))
                    })
                    .collect();
                coord.submit_batch_streaming(
                    reqs,
                    base + (chunk * (PER / 4)) as u32,
                    &tx,
                );
            }
        }));
    }
    drop(tx);
    for th in threads {
        th.join().unwrap();
    }
    let total = (SUBMITTERS * PER) as usize;
    let mut seen: Vec<Option<u64>> = vec![None; total];
    for _ in 0..total {
        let (slot, resp) = rx.recv().expect("missing responses");
        assert!(
            seen[slot as usize].replace(resp.value).is_none(),
            "slot {slot} delivered twice"
        );
        // The request under slot s carried id s (by construction), and
        // the response must echo it.
        assert_eq!(resp.id, slot as u64, "slot {slot} routed a different request");
    }
    assert!(rx.try_recv().is_err(), "no extra responses may appear");
    assert!(seen.iter().all(|s| s.is_some()));
    // Recompute the expected values from each submitter's deterministic
    // RNG stream and compare slot-by-slot.
    for t in 0..SUBMITTERS {
        let mut rng = Rng::new(0x51071 + t);
        for chunk in 0..4u64 {
            for k in 0..PER / 4 {
                let slot = (t * PER + chunk * (PER / 4) + k) as usize;
                let w = rng.below(9) as u32;
                let a = rng.operand(8);
                let b = rng.operand(8);
                assert_eq!(
                    seen[slot],
                    Some(simdive_mul_w(8, a, b, w)),
                    "slot {slot} value mismatch"
                );
            }
        }
    }
    let coord = Arc::into_inner(coord).expect("all submitter clones joined");
    let s = coord.shutdown();
    assert_eq!(s.requests, SUBMITTERS * PER);
}
