//! SIMD lane packing demo: mixed-precision, mixed-functionality requests
//! bin-packed into 32-bit SIMDive words and dispatched through the L3
//! coordinator, with lane utilization and the power-gating energy model.
//!
//! Run: `cargo run --release --example simd_packing`

use simdive::coordinator::{pack_requests, Coordinator, CoordinatorConfig, ReqOp, Request};
use simdive::util::Rng;

fn main() {
    // Static packing view: mixed widths *and* mixed accuracy knobs —
    // requests with different w never share a word (their correction
    // tables differ), but one coordinator serves them all.
    let reqs = vec![
        Request { id: 0, op: ReqOp::Mul, bits: 16, w: 8, a: 1200, b: 37 },
        Request { id: 1, op: ReqOp::Div, bits: 8, w: 8, a: 200, b: 9 },
        Request { id: 2, op: ReqOp::Mul, bits: 8, w: 8, a: 43, b: 10 },
        Request { id: 3, op: ReqOp::Div, bits: 32, w: 4, a: 1 << 20, b: 77 },
        Request { id: 4, op: ReqOp::Mul, bits: 8, w: 4, a: 7, b: 3 },
        Request { id: 5, op: ReqOp::Mul, bits: 8, w: 4, a: 9, b: 5 },
    ];
    println!("packing {} mixed requests:", reqs.len());
    for w in pack_requests(&reqs) {
        println!(
            "  {:?} w={} modes {:?} lanes {:?} ({} active)",
            w.op.cfg,
            w.w,
            &w.op.modes[..w.lane_count()],
            w.lane_req,
            w.active_lanes
        );
    }

    // Dynamic: a bursty mixed workload through the threaded coordinator.
    let coord = Coordinator::start(CoordinatorConfig::default());
    let mut rng = Rng::new(42);
    let n = 20_000u64;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        // 8-bit heavy with some 16/32 — the DNN/multimedia mix the paper
        // motivates (§3.2).
        let bits = [8u32, 8, 8, 8, 16, 16, 32][rng.below(7) as usize];
        pending.push(coord.submit(Request {
            id: i,
            op: if rng.below(5) == 0 { ReqOp::Div } else { ReqOp::Mul },
            bits,
            w: rng.below(9) as u32,
            a: rng.operand(bits),
            b: rng.operand(bits),
        }));
        if pending.len() >= 512 {
            for h in pending.drain(..) {
                h.recv().unwrap();
            }
        }
    }
    for h in pending.drain(..) {
        h.recv().unwrap();
    }
    let dt = t0.elapsed();
    let s = coord.shutdown();
    println!(
        "\nserved {} requests in {:.2}s ({:.0} kops/s)",
        s.requests,
        dt.as_secs_f64(),
        s.requests as f64 / dt.as_secs_f64() / 1e3
    );
    println!(
        "packed into {} words — lane utilization {:.1}%, modeled energy {:.2} µJ \
         (idle lanes power-gated at 10%)",
        s.words,
        s.lane_utilization() * 100.0,
        s.energy_pj / 1e6
    );
}
