//! Image pipeline (paper §4.3 / Figs. 3–4): multiply-blend and Gaussian
//! smoothing over the synthetic scene set with accurate, SIMDive and
//! MBM/INZeD arithmetic; writes PGM outputs into artifacts/figures/.
//!
//! Run: `cargo run --release --example image_pipeline`

use simdive::image::{blend, gaussian_smooth, pgm, synth, ArithKind};
use simdive::metrics::psnr;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts/figures");
    std::fs::create_dir_all(&dir)?;

    println!("== multiply-blend (Fig. 3 style) ==");
    let a = synth::generate(synth::Scene::Portrait, 256, 7);
    let b = synth::generate(synth::Scene::Architecture, 256, 8);
    let acc = blend(&a, &b, ArithKind::Accurate);
    for kind in [ArithKind::Simdive(8), ArithKind::MbmInzed, ArithKind::Mitchell] {
        let out = blend(&a, &b, kind);
        println!("  {:10}: PSNR vs accurate = {:.1} dB", kind.name(), psnr(&acc.data, &out.data));
    }
    pgm::write_pgm(&acc, &dir.join("pipeline_blend_accurate.pgm"))?;
    pgm::write_pgm(&blend(&a, &b, ArithKind::Simdive(8)), &dir.join("pipeline_blend_simdive.pgm"))?;

    println!("\n== Gaussian denoise (Fig. 4 style) ==");
    let clean = synth::generate(synth::Scene::Portrait, 256, 9);
    let noisy = synth::add_gaussian_noise(&clean, 18.0, 10);
    println!("  noisy    : PSNR vs clean = {:.1} dB", psnr(&clean.data, &noisy.data));
    for (label, kind, hybrid) in [
        ("accurate", ArithKind::Accurate, false),
        ("simdive div-only", ArithKind::Simdive(8), false),
        ("simdive hybrid", ArithKind::Simdive(8), true),
        ("mbm/inzed hybrid", ArithKind::MbmInzed, true),
    ] {
        let out = gaussian_smooth(&noisy, kind, hybrid);
        println!("  {:17}: PSNR vs clean = {:.1} dB", label, psnr(&clean.data, &out.data));
    }
    pgm::write_pgm(&noisy, &dir.join("pipeline_noisy.pgm"))?;
    pgm::write_pgm(
        &gaussian_smooth(&noisy, ArithKind::Simdive(8), true),
        &dir.join("pipeline_denoised_simdive.pgm"),
    )?;
    println!("\nPGM outputs in artifacts/figures/");
    Ok(())
}
