//! Quickstart: the paper's running example (43 × 10, 43 ÷ 10) across
//! accurate / Mitchell / SIMDive, the tunable-accuracy knob, and a look at
//! the gate-level unit's calibrated metrics.
//!
//! Run: `cargo run --release --example quickstart`

use simdive::arith::simdive as sd;
use simdive::arith::{exact, mitchell};
use simdive::fabric::{area, calibrate, timing};

fn main() {
    println!("== SIMDive quickstart ==\n");
    println!("paper running example, 8-bit operands a=43 b=10:");
    println!("  exact    : 43×10 = {:3}   43÷10 = {}", exact::mul(8, 43, 10), exact::div(8, 43, 10));
    println!("  mitchell : 43×10 = {:3}   43÷10 = {}", mitchell::mul(8, 43, 10), mitchell::div(8, 43, 10));
    println!("  simdive  : 43×10 = {:3}   43÷10 = {}", sd::simdive_mul(8, 43, 10), sd::simdive_div(8, 43, 10));

    println!("\ntunable accuracy (w = number of coefficient LUTs):");
    for w in [0u32, 2, 4, 8] {
        let p = sd::simdive_mul_w(8, 43, 10, w);
        println!("  w={w}: 43×10 = {p:3}  (exact 430)");
    }

    println!("\ngate-level 16-bit hybrid multiplier-divider (calibrated Virtex-7 model):");
    let nl = simdive::circuits::simdive::hybrid(16, 8);
    let cal = calibrate::fitted();
    let a = area::report(&nl);
    let t = timing::analyze(&nl, cal);
    println!("  area  : {} LUT6 ({} CARRY4)", a.luts, a.carry4);
    println!("  delay : {:.2} ns critical path ({} logic levels)", t.critical_ns, t.levels);
    println!("\nNext: `cargo run --release table2` regenerates paper Table 2.");
}
