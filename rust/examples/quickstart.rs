//! Quickstart: the engine seam first (DESIGN.md §10) — one `Engine`
//! handle runs the paper's running example (43 × 10, 43 ÷ 10) across
//! accurate / Mitchell / SIMDive, the tunable-accuracy knob, batched
//! slices, and a mixed-`{bits, w}` word stream — then a look at the
//! gate-level unit's calibrated metrics.
//!
//! Run: `cargo run --release --example quickstart`

use simdive::arith::{DivDesign, MulDesign};
use simdive::coordinator::{ReqOp, Request};
use simdive::engine::Engine;
use simdive::fabric::{area, calibrate, timing};

fn main() {
    println!("== SIMDive quickstart ==\n");

    // The engine seam: every design sits behind the same handle. The
    // substrates (ANN, image, metrics, the serve path) all execute
    // through this API — so should you.
    let exact = Engine::accurate();
    let mitchell = Engine::batched(MulDesign::Mitchell, DivDesign::Mitchell);
    let simdive = Engine::simdive(8);

    println!("paper running example, 8-bit operands a=43 b=10:");
    for (name, eng) in [("exact", &exact), ("mitchell", &mitchell), ("simdive", &simdive)] {
        println!(
            "  {name:<8} : 43×10 = {:3}   43÷10 = {}",
            eng.mul(8, 43, 10),
            eng.div(8, 43, 10)
        );
    }

    println!("\ntunable accuracy (w = number of coefficient LUTs):");
    for w in [0u32, 2, 4, 8] {
        println!("  w={w}: 43×10 = {:3}  (exact 430)", Engine::simdive(w).mul(8, 43, 10));
    }

    // Batched slices: one call, tables resolved once, bit-identical to
    // the scalar path.
    let a: [u64; 4] = [43, 43, 200, 255];
    let b: [u64; 4] = [10, 13, 3, 2];
    let mut prods = Vec::new();
    simdive.mul_into(8, &a, &b, &mut prods);
    println!("\nbatched 8-bit multiplies through the engine: {prods:?}");

    // A mixed-{bits, w} word stream — what the coordinator shards execute
    // under serving traffic, available in-process through the same seam.
    let reqs = [
        Request { id: 0, op: ReqOp::Mul, bits: 8, w: 8, a: 43, b: 10 },
        Request { id: 1, op: ReqOp::Div, bits: 8, w: 2, a: 200, b: 13 },
        Request { id: 2, op: ReqOp::Mul, bits: 16, w: 5, a: 300, b: 21 },
        Request { id: 3, op: ReqOp::Div, bits: 32, w: 0, a: 1 << 20, b: 3 },
    ];
    let vals = simdive.execute_stream(&reqs);
    println!("mixed {{bits, w}} stream (mul/div, 8/16/32-bit): {vals:?}");

    println!("\ngate-level 16-bit hybrid multiplier-divider (calibrated Virtex-7 model):");
    let nl = simdive::circuits::simdive::hybrid(16, 8);
    let cal = calibrate::fitted();
    let ar = area::report(&nl);
    let t = timing::analyze(&nl, cal);
    println!("  area  : {} LUT6 ({} CARRY4)", ar.luts, ar.carry4);
    println!("  delay : {:.2} ns critical path ({} logic levels)", t.critical_ns, t.levels);
    println!("\nNext: `cargo run --release table2` regenerates paper Table 2.");
}
