//! End-to-end ANN serving over the network (DESIGN.md §8): trains a small
//! MLP on the synthetic digits set, quantizes it to 8 bits, and runs
//! inference with every weight×activation product routed through
//! `serve::client` to a loopback SIMD-wire server — the paper's SIMDive
//! multiplier behind a real TCP boundary, with the accuracy knob `w`
//! chosen per request on the wire.
//!
//! Each prediction is verified bit-identical to the in-process
//! `QuantMlp::predict` through a batched engine with the same
//! `MulDesign::Simdive { w }`, so the network path provably computes the
//! same network.
//!
//! Run: `cargo run --release --example ann_serving [-- <test-images>]`

use simdive::ann::{Mlp, QuantMlp};
use simdive::arith::MulDesign;
use simdive::coordinator::ReqOp;
use simdive::datasets::{generate, Family};
use simdive::engine::Engine;
use simdive::serve::{Client, ServeConfig, Server, WireRequest};
use std::time::Instant;

/// Quantized forward pass with the multiplies served over the wire:
/// mirrors `QuantMlp::predict` exactly, but the per-layer product batch
/// goes through one pipelined `exchange` at accuracy `w` instead of the
/// local batched kernel. Returns (predicted class, wire requests issued).
fn predict_over_wire(q: &QuantMlp, pixels: &[u8], client: &mut Client, w: u32) -> (usize, u64) {
    let layers = q.w_q.len();
    let mut act: Vec<u8> = pixels.to_vec();
    let mut issued = 0u64;
    for l in 0..layers {
        let (fan_in, fan_out) = (q.dims[l], q.dims[l + 1]);
        // Gather non-zero weight×activation pairs, as the local path does.
        let mut reqs: Vec<WireRequest> = Vec::new();
        let mut neg: Vec<bool> = Vec::new();
        let mut row_end: Vec<usize> = Vec::new();
        for o in 0..fan_out {
            let row = &q.w_q[l][o * fan_in..(o + 1) * fan_in];
            for (i, &wq) in row.iter().enumerate() {
                let a = act[i] as u64;
                if a == 0 || wq == 0 {
                    continue;
                }
                reqs.push(WireRequest {
                    id: reqs.len() as u64,
                    op: ReqOp::Mul,
                    bits: 8,
                    w,
                    budget_ppm: 0,
                    a: wq.unsigned_abs() as u64,
                    b: a,
                });
                neg.push(wq < 0);
            }
            row_end.push(reqs.len());
        }
        issued += reqs.len() as u64;
        let resps = client.exchange(&reqs).expect("serving exchange failed");
        let mut next = vec![0u8; fan_out];
        let mut logits = vec![0i64; fan_out];
        let mut start = 0usize;
        for o in 0..fan_out {
            let end = row_end[o];
            let mut acc = q.b_q[l][o];
            for k in start..end {
                let p = resps[k].value as i64;
                acc += if neg[k] { -p } else { p };
            }
            start = end;
            if l + 1 < layers {
                let v = (acc.max(0) as f32 * q.requant[l]).round();
                next[o] = v.clamp(0.0, 255.0) as u8;
            } else {
                logits[o] = acc;
            }
        }
        if l + 1 < layers {
            act = next;
        } else {
            let best = logits.iter().enumerate().max_by_key(|&(_, &v)| v).unwrap().0;
            return (best, issued);
        }
    }
    unreachable!()
}

fn main() {
    let test_images: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);

    println!("== ANN serving over SIMD-wire ==\n");
    println!("training a small digits MLP (offline stand-in for MNIST)...");
    let train = generate(Family::Digits, 1200, 60_000);
    let test = generate(Family::Digits, test_images, 10_000);
    let mut net = Mlp::new(&[32], 42);
    net.train(&train, 3, 0.04, 77);
    let q = QuantMlp::from_float(&net, &train[..400]);

    let server =
        Server::start("127.0.0.1:0", ServeConfig::default()).expect("cannot bind loopback server");
    println!("loopback SIMD-wire server on {}\n", server.local_addr());
    let mut client = Client::connect(server.local_addr()).expect("connect failed");

    // Serve inference at two accuracy knobs: the paper's full 8-LUT
    // configuration and a cheaper 2-LUT one — the trade-off every client
    // picks per request on the wire.
    for w in [8u32, 2] {
        let engine = Engine::from_mul(MulDesign::Simdive { w });
        let t0 = Instant::now();
        let mut correct = 0usize;
        let mut requests = 0u64;
        for ex in &test {
            let (pred, issued) = predict_over_wire(&q, &ex.pixels, &mut client, w);
            let local = q.predict(&ex.pixels, &engine);
            assert_eq!(pred, local, "network and in-process inference diverged at w={w}");
            requests += issued;
            if pred == ex.label as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "w={w}: {correct}/{} correct — {requests} wire multiplies in {dt:.2}s \
             ({:.1} kreq/s), bit-identical to in-process inference",
            test.len(),
            requests as f64 / dt / 1e3
        );
    }

    let stats = client.stats().expect("stats failed");
    println!(
        "\nserver totals: {} requests, {} SIMD words, lane utilization {:.0}%, \
         modeled energy {:.2} µJ, p50 {} µs, p99 {} µs",
        stats.requests,
        stats.words,
        stats.lane_utilization() * 100.0,
        stats.energy_pj() / 1e6,
        stats.p50_us,
        stats.p99_us
    );
    drop(client);
    server.shutdown();
}
