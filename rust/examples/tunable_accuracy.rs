//! The accuracy knob (§3.3): sweep w = 0..=8 coefficient LUTs and print
//! error vs area, demonstrating "one more LUT = one more coefficient bit".
//!
//! Run: `cargo run --release --example tunable_accuracy`

fn main() {
    println!("{}", simdive::report::tunable::render(150_000));
}
