//! Network serving throughput: a loopback SIMD-wire server driven by the
//! in-crate load generator, reported next to the in-process coordinator
//! batched figure so the cost of the network boundary is visible.
//!
//! Results go to stdout and to `BENCH_serve.json` at the repository root
//! (schema `simdive-serve-v1`, documented in CHANGES.md alongside the
//! hotpath schema).

use simdive::serve::loadgen::{self, LoadgenConfig};
use simdive::serve::{ServeConfig, Server};

/// Total requests across connections.
const REQUESTS: u64 = 100_000;

/// In-process coordinator comparison requests (matches hotpath's figure).
const COORD_REQUESTS: u64 = 40_000;

fn main() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default())
        .expect("cannot bind loopback server");
    let addr = server.local_addr().to_string();
    let cfg = LoadgenConfig { requests: REQUESTS, ..LoadgenConfig::default() };
    let report = loadgen::run(&addr, &cfg).expect("loadgen run failed");
    let s = &report.server;
    println!(
        "[bench] serve: {} requests over {} connections in {:.3}s — {:.1} kreq/s \
         (p50 {} µs, p99 {} µs, lane util {:.0}%)",
        report.requests,
        report.connections,
        report.wall_s,
        report.rps / 1e3,
        s.p50_us,
        s.p99_us,
        s.lane_utilization() * 100.0
    );
    let coord_rps = loadgen::coordinator_batched_rps(COORD_REQUESTS);
    println!(
        "[bench] coordinator (in-process, batched): {:.1} kreq/s — network/in-process ratio {:.2}",
        coord_rps / 1e3,
        report.rps / coord_rps
    );
    server.shutdown();

    let json = loadgen::to_json(&report, COORD_REQUESTS, coord_rps);
    let path = simdive::util::repo_root().join("BENCH_serve.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[bench] wrote {}", path.display()),
        Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
    }
}
