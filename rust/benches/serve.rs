//! Network serving throughput: a loopback SIMD-wire server driven by the
//! in-crate load generator, reported next to the in-process coordinator
//! batched figure so the cost of the network boundary is visible, plus a
//! degraded-mode sweep — the chaos scenario at fault rates
//! {0, 0.1%, 1%} — appended as the `"chaos"` section (DESIGN.md §11),
//! and the reactor-vs-threaded connection-count ladder appended as the
//! `"connections_sweep"` section (DESIGN.md §15).
//!
//! Results go to stdout and to `BENCH_serve.json` at the repository root
//! (schema `simdive-serve-v1`, documented in CHANGES.md alongside the
//! hotpath schema; the chaos and sweep sections are append-only).

use simdive::faults::{silence_injected_panics, FaultConfig};
use simdive::serve::chaos::{self, ChaosConfig};
use simdive::serve::loadgen::{self, LoadgenConfig};
use simdive::serve::{ServeConfig, Server};

/// Total requests across connections.
const REQUESTS: u64 = 100_000;

/// In-process coordinator comparison requests (matches hotpath's figure).
const COORD_REQUESTS: u64 = 40_000;

/// Verified requests per chaos sweep point.
const CHAOS_REQUESTS: u64 = 20_000;

/// Fault rates swept (ppm per decision point): none, 0.1%, 1%.
const FAULT_PPM: [u64; 3] = [0, 1_000, 10_000];

const FAULT_SEED: u64 = 0xC4A05;

fn main() {
    let server = Server::start("127.0.0.1:0", ServeConfig::default())
        .expect("cannot bind loopback server");
    let addr = server.local_addr().to_string();
    let cfg = LoadgenConfig { requests: REQUESTS, ..LoadgenConfig::default() };
    let report = loadgen::run(&addr, &cfg).expect("loadgen run failed");
    let s = &report.server;
    println!(
        "[bench] serve: {} requests over {} connections in {:.3}s — {:.1} kreq/s \
         (p50 {} µs, p99 {} µs, lane util {:.0}%)",
        report.requests,
        report.connections,
        report.wall_s,
        report.rps / 1e3,
        s.p50_us,
        s.p99_us,
        s.lane_utilization() * 100.0
    );
    let coord_rps = loadgen::coordinator_batched_rps(COORD_REQUESTS);
    println!(
        "[bench] coordinator (in-process, batched): {:.1} kreq/s — network/in-process ratio {:.2}",
        coord_rps / 1e3,
        report.rps / coord_rps
    );
    server.shutdown();

    // Degraded-mode sweep: one fresh fault-injected server per rate, the
    // chaos scenario's invariants asserted at every point.
    silence_injected_panics();
    let mut sweep = Vec::new();
    for ppm in FAULT_PPM {
        let faults = (ppm > 0).then(|| FaultConfig::server_chaos(FAULT_SEED, ppm as u32));
        let server = Server::start("127.0.0.1:0", ServeConfig { faults, ..ServeConfig::default() })
            .expect("cannot bind chaos loopback server");
        let addr = server.local_addr().to_string();
        let ccfg = ChaosConfig { requests: CHAOS_REQUESTS, seed: FAULT_SEED, ..ChaosConfig::default() };
        let c = chaos::run(&addr, &ccfg).expect("chaos run failed");
        println!(
            "[bench] chaos @ {ppm} ppm: {} completed / {} failed / {} reconnects — \
             {:.1} kreq/s (shed {}, unavailable {}, mismatches {}, unresolved {}, \
             connections {} -> {})",
            c.completed,
            c.failed,
            c.reconnects,
            c.rps / 1e3,
            c.server.shed_overload,
            c.server.failed_unavailable,
            c.mismatches,
            c.unresolved,
            c.baseline_connections,
            c.final_connections,
        );
        assert!(
            c.invariants_hold(),
            "chaos invariants violated at {ppm} ppm: mismatches {}, unresolved {}, \
             connections {} -> {}",
            c.mismatches,
            c.unresolved,
            c.baseline_connections,
            c.final_connections,
        );
        server.shutdown();
        sweep.push((ppm, c));
    }

    // Connection-count ladder, both backends, fresh server per rung
    // (DESIGN.md §15): this is where the reactor's O(1) thread pool and
    // the baseline's O(connections) threads separate.
    let conn_sweep = loadgen::run_connections_sweep();
    for p in &conn_sweep {
        if p.ok {
            println!(
                "[bench] sweep {} @{} conns: {:.1} kreq/s (p50 {} µs, p99 {} µs, {} threads)",
                p.mode,
                p.connections,
                p.rps / 1e3,
                p.p50_us,
                p.p99_us,
                p.threads
            );
        } else {
            println!("[bench] sweep {} @{} conns: failed/skipped", p.mode, p.connections);
        }
    }

    let json = loadgen::to_json_full(&report, COORD_REQUESTS, coord_rps, &sweep, &conn_sweep);
    let path = simdive::util::repo_root().join("BENCH_serve.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[bench] wrote {}", path.display()),
        Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
    }
}
