//! Regenerates Fig. 3 (image blending PSNR, SIMDive vs MBM).
mod harness;

fn main() {
    let msg = harness::timed("fig3 blending (4 scenes × 3 variants)", || {
        simdive::report::figs::fig3().expect("fig3")
    });
    println!("{msg}");
    // Hot path: blended megapixels/s with the SIMDive multiplier.
    use simdive::image::{blend, synth, ArithKind};
    let a = synth::generate(synth::Scene::Portrait, 256, 1);
    let b = synth::generate(synth::Scene::Texture, 256, 2);
    let ns = harness::ns_per_op("blend 256×256 (SIMDive-8)", || {
        std::hint::black_box(blend(&a, &b, ArithKind::Simdive(8)));
    });
    println!(
        "[bench] blend throughput: {:.1} Mpx/s",
        (256.0 * 256.0) / ns * 1e3
    );
}
