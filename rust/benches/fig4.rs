//! Regenerates Fig. 4 (Gaussian smoothing PSNR, div-only and hybrid).
mod harness;

fn main() {
    let msg = harness::timed("fig4 gaussian (4 scenes × 4 variants)", || {
        simdive::report::figs::fig4().expect("fig4")
    });
    println!("{msg}");
}
