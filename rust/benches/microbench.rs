//! Infrastructure microbenches: netlist simulation throughput, packer,
//! coordinator round-trip, PJRT execution latency (if artifacts exist).
mod harness;

fn main() {
    // Netlist bit-parallel simulation throughput.
    let nl = simdive::circuits::simdive::mul(16, 8);
    let sim = simdive::fabric::Simulator::new(&nl);
    let mut rng = simdive::util::Rng::new(3);
    let avals: Vec<u64> = (0..4096).map(|_| rng.below(65536)).collect();
    let bvals: Vec<u64> = (0..4096).map(|_| rng.below(65536)).collect();
    let ns = harness::ns_per_op("netlist sim 4096 vectors (simdive mul16)", || {
        std::hint::black_box(sim.run_batch(&[("a", &avals), ("b", &bvals)]));
    });
    println!(
        "[bench] netlist sim rate: {:.2} Mvec/s",
        4096.0 / ns * 1e3
    );

    // Lane packer throughput.
    use simdive::coordinator::{pack_requests, ReqOp, Request};
    let reqs: Vec<Request> = (0..256u64)
        .map(|i| {
            let bits = [8, 16, 32][(i % 3) as usize];
            Request {
                id: i,
                op: if i % 3 == 0 { ReqOp::Div } else { ReqOp::Mul },
                bits,
                w: (i % 9) as u32,
                a: 1 + (i % 200),
                b: 3 + (i % 100),
            }
        })
        .collect();
    harness::ns_per_op("pack 256 requests", || {
        std::hint::black_box(pack_requests(&reqs));
    });

    // Coordinator round-trip (windowed batch submission, 1024 per window).
    use simdive::coordinator::{Coordinator, CoordinatorConfig};
    let coord = Coordinator::start(CoordinatorConfig::default());
    let t0 = std::time::Instant::now();
    let n = 50_000u64;
    let mut submitted = 0u64;
    while submitted < n {
        let window = (n - submitted).min(1024);
        let batch: Vec<Request> = (submitted..submitted + window)
            .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + (i % 250), b: 3 })
            .collect();
        coord.submit_batch(batch).wait();
        submitted += window;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("[bench] coordinator: {:.1} kops/s", n as f64 / dt / 1e3);
    coord.shutdown();

    // PJRT execution latency (skipped when artifacts are absent or the
    // pjrt feature is off — DESIGN.md §2).
    pjrt_latency();
}

#[cfg(feature = "pjrt")]
fn pjrt_latency() {
    let dir = simdive::runtime::default_artifacts_dir();
    if dir.join("ann_fwd.hlo.txt").exists() {
        let eng = simdive::runtime::Engine::load(&dir).expect("engine");
        let vals = vec![0i32; 32 * 784];
        let bytes = unsafe {
            std::slice::from_raw_parts(vals.as_ptr() as *const u8, vals.len() * 4)
        };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[32, 784],
            bytes,
        )
        .unwrap();
        let ns = harness::ns_per_op("PJRT ann_fwd batch-32", || {
            std::hint::black_box(eng.run("ann_fwd", std::slice::from_ref(&lit)).unwrap());
        });
        println!(
            "[bench] served inference: {:.1} images/s",
            32.0 / ns * 1e9
        );
    } else {
        println!("[bench] PJRT latency skipped (run `make artifacts`)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_latency() {
    println!("[bench] PJRT latency skipped (built without the pjrt feature)");
}
