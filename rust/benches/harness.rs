//! Minimal bench harness shared by all `cargo bench` targets (criterion is
//! unavailable in the offline registry — DESIGN.md §1). Each bench prints
//! the paper table/figure it regenerates plus wall-clock timing of the
//! regeneration and of the relevant hot paths.

// Each bench target compiles this module separately and uses a subset of
// the helpers, so unused-function lints are expected.
#![allow(dead_code)]

use std::time::Instant;

/// Time a closure, printing `name: <ms> (result-lines…)`.
pub fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("[bench] {name}: {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
    out
}

/// Measure mean ns/op of `f` over enough iterations to cover ~200 ms.
pub fn ns_per_op(name: &str, mut f: impl FnMut()) -> f64 {
    // Warm up + calibrate.
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed().as_millis() < 30 {
        f();
        n += 1;
    }
    let iters = (n * 8).max(10);
    let t1 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t1.elapsed().as_nanos() as f64 / iters as f64;
    println!("[bench] {name}: {ns:.1} ns/op ({iters} iters)");
    ns
}
