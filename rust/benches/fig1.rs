//! Regenerates Fig. 1 (Mitchell error heat maps → CSV) and times the
//! exhaustive 8-bit error scan.
mod harness;

fn main() {
    let msg = harness::timed("fig1 heat maps (exhaustive 8-bit ×2 ops)", || {
        simdive::report::figs::fig1().expect("fig1")
    });
    println!("{msg}");
}
