//! §3.3 tunable-accuracy sweep: ARE/PRE/area vs w (0..=8 LUTs).
mod harness;

fn main() {
    let samples = if std::env::var("BENCH_FAST").is_ok() { 60_000 } else { 300_000 };
    let table = harness::timed("tunable sweep", || {
        simdive::report::tunable::render(samples)
    });
    println!("{table}");
}
