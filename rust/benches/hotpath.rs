//! Hot-path throughput: scalar-call vs batched kernels for mul/div at
//! 8/16/32 bits, coordinator round-trip throughput under per-request and
//! per-batch submission, and the engine shard-scaling sweep
//! (`sharded_rps` at 1/2/4/8 shards — DESIGN.md §10).
//!
//! Results go to stdout and to `BENCH_hotpath.json` at the repository
//! root, so the performance trajectory is tracked PR-over-PR (the JSON
//! format is documented in CHANGES.md).
//!
//! "Scalar" is the pre-batching hot path exactly as the substrates used
//! it: one `MulDesign`/`DivDesign` dispatch per element, which resolves
//! the correction tables and rescales the coefficient per call. "Batched"
//! is one `arith::batch` kernel call per slice — at 8 bits that entry
//! point routes through the packed 4-lane SWAR kernel (DESIGN.md §13),
//! so the 8-bit rows also time the pre-SWAR lane-wise form
//! (`*_batch_lanewise_into`) to isolate the SWAR payoff. All paths
//! compute bit-identical results (asserted here before timing).

use simdive::arith::{batch, table, DivDesign, MulDesign};
use simdive::coordinator::{ReqOp, Request};
use simdive::util::Rng;
use std::fmt::Write as _;
use std::hint::black_box;
use std::time::Instant;

/// Elements per timed pass.
const N: usize = 1 << 16;

/// Requests per coordinator round-trip measurement.
const COORD_REQUESTS: u64 = 40_000;

/// Measure mean seconds per invocation of `f`, running ~0.3 s after a
/// warm-up pass.
fn time_secs(mut f: impl FnMut()) -> f64 {
    f(); // warm up
    let t0 = Instant::now();
    let mut passes = 0u32;
    while t0.elapsed().as_millis() < 300 {
        f();
        passes += 1;
    }
    t0.elapsed().as_secs_f64() / passes as f64
}

struct OpResult {
    bits: u32,
    scalar_mops: f64,
    batched_mops: f64,
    /// Lane-wise batch throughput — measured only at 8 bits, where the
    /// default batch entry takes the SWAR path instead (DESIGN.md §13).
    lanewise_mops: Option<f64>,
}

impl OpResult {
    fn speedup(&self) -> f64 {
        self.batched_mops / self.scalar_mops
    }
}

fn bench_op(bits: u32, is_div: bool, rng: &mut Rng) -> OpResult {
    let a: Vec<u64> = (0..N).map(|_| rng.below(1u64 << bits)).collect();
    let b: Vec<u64> = (0..N).map(|_| rng.below(1u64 << bits)).collect();
    let tables = table::tables_for(8);
    let mut out = vec![0u64; N];

    // Bit-exactness gate before timing anything.
    if is_div {
        batch::div_batch_into(tables, bits, &a, &b, &mut out);
        for i in 0..N {
            assert_eq!(out[i], DivDesign::Simdive { w: 8 }.div(bits, a[i], b[i]));
        }
    } else {
        batch::mul_batch_into(tables, bits, &a, &b, &mut out);
        for i in 0..N {
            assert_eq!(out[i], MulDesign::Simdive { w: 8 }.mul(bits, a[i], b[i]));
        }
    }

    // `black_box` on the design mirrors the pre-batching substrates, where
    // the design is a runtime parameter (e.g. `QuantMlp::predict(…, design)`)
    // — the dispatch and table resolution cannot be hoisted out of the loop.
    let scalar_secs = if is_div {
        time_secs(|| {
            let d = black_box(DivDesign::Simdive { w: 8 });
            let mut acc = 0u64;
            for i in 0..N {
                acc = acc.wrapping_add(d.div(bits, black_box(a[i]), black_box(b[i])));
            }
            black_box(acc);
        })
    } else {
        time_secs(|| {
            let d = black_box(MulDesign::Simdive { w: 8 });
            let mut acc = 0u64;
            for i in 0..N {
                acc = acc.wrapping_add(d.mul(bits, black_box(a[i]), black_box(b[i])));
            }
            black_box(acc);
        })
    };

    let batched_secs = if is_div {
        time_secs(|| {
            batch::div_batch_into(tables, bits, black_box(&a), black_box(&b), &mut out);
            black_box(&out);
        })
    } else {
        time_secs(|| {
            batch::mul_batch_into(tables, bits, black_box(&a), black_box(&b), &mut out);
            black_box(&out);
        })
    };

    // At 8 bits the default entry point above went through the SWAR
    // kernel; time the pre-SWAR lane-wise form too so the packed-lane
    // payoff is tracked separately from the table-hoisting payoff.
    let lanewise_secs = (bits == 8).then(|| {
        let (aa, bb) = (black_box(&a), black_box(&b));
        if is_div {
            time_secs(|| {
                batch::div_batch_lanewise_into(tables, bits, aa, bb, &mut out);
                black_box(&out);
            })
        } else {
            time_secs(|| {
                batch::mul_batch_lanewise_into(tables, bits, aa, bb, &mut out);
                black_box(&out);
            })
        }
    });

    let r = OpResult {
        bits,
        scalar_mops: N as f64 / scalar_secs / 1e6,
        batched_mops: N as f64 / batched_secs / 1e6,
        lanewise_mops: lanewise_secs.map(|s| N as f64 / s / 1e6),
    };
    let swar_note = match r.lanewise_mops {
        Some(l) => format!(", lanewise {:.1} Mops/s (SWAR {:.2}x)", l, r.batched_mops / l),
        None => String::new(),
    };
    println!(
        "[bench] {}{:<2}: scalar {:.1} Mops/s, batched {:.1} Mops/s ({:.2}x){swar_note}",
        if is_div { "div" } else { "mul" },
        bits,
        r.scalar_mops,
        r.batched_mops,
        r.speedup()
    );
    r
}

/// Fixed-w request generator: same workload as pre-v2 benches (every
/// request at the full 8-LUT knob), so `batched_rps` stays comparable
/// PR-over-PR.
fn make(i: u64) -> Request {
    let bits = [8u32, 8, 16, 32][(i % 4) as usize];
    Request {
        id: i,
        op: if i % 4 == 0 { ReqOp::Div } else { ReqOp::Mul },
        bits,
        w: 8,
        a: 1 + (i % ((1u64 << bits) - 1)),
        b: 1 + ((i * 7) % ((1u64 << bits) - 1)),
    }
}

/// Mixed-accuracy generator: the shared-pool headline workload — every
/// request picks its own w.
fn make_mixed(i: u64) -> Request {
    Request { w: (i % 9) as u32, ..make(i) }
}

fn bench_coordinator() -> (f64, f64, f64, f64) {
    use simdive::coordinator::{Coordinator, CoordinatorConfig};
    let n = COORD_REQUESTS;

    // Per-request submission (one channel per request).
    let coord = Coordinator::start(CoordinatorConfig::default());
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(1024);
    for i in 0..n {
        handles.push(coord.submit(make(i)));
        if handles.len() == 1024 {
            for h in handles.drain(..) {
                h.recv().unwrap();
            }
        }
    }
    for h in handles.drain(..) {
        h.recv().unwrap();
    }
    let scalar_rps = n as f64 / t0.elapsed().as_secs_f64();
    coord.shutdown();

    // Batched submission (one channel + index slots per 1024 requests).
    let coord = Coordinator::start(CoordinatorConfig::default());
    let t0 = Instant::now();
    let mut submitted = 0u64;
    while submitted < n {
        let window = (n - submitted).min(1024);
        let reqs: Vec<Request> = (submitted..submitted + window).map(make).collect();
        coord.submit_batch(reqs).wait();
        submitted += window;
    }
    let batched_rps = n as f64 / t0.elapsed().as_secs_f64();
    coord.shutdown();

    // Mixed-w batched submission through the same shared pool, with lane
    // utilization from the coordinator's own accounting.
    let coord = Coordinator::start(CoordinatorConfig::default());
    let t0 = Instant::now();
    let mut submitted = 0u64;
    while submitted < n {
        let window = (n - submitted).min(1024);
        let reqs: Vec<Request> = (submitted..submitted + window).map(make_mixed).collect();
        coord.submit_batch(reqs).wait();
        submitted += window;
    }
    let mixed_rps = n as f64 / t0.elapsed().as_secs_f64();
    let mixed_util = coord.shutdown().lane_utilization();

    println!(
        "[bench] coordinator: per-request {:.1} kreq/s, batched {:.1} kreq/s ({:.2}x), \
         mixed-w batched {:.1} kreq/s (lane util {:.0}%)",
        scalar_rps / 1e3,
        batched_rps / 1e3,
        batched_rps / scalar_rps,
        mixed_rps / 1e3,
        mixed_util * 100.0
    );
    (scalar_rps, batched_rps, mixed_rps, mixed_util)
}

/// Engine shard-scaling sweep (DESIGN.md §10): the mixed-w workload
/// executed directly through `engine::Sharded` at 1/2/4/8 shards, in
/// 4096-request streams. The 4+-shard figures exceeding the single-pool
/// `batched_mixed_w_rps` is the sharding payoff tracked in
/// `BENCH_hotpath.json` (`coordinator.sharded_rps`).
fn bench_sharded(n: u64) -> Vec<(usize, f64)> {
    use simdive::arith::simdive::{simdive_div_w, simdive_mul_w};
    use simdive::engine::{Engine, ShardedConfig};
    let expect = |r: &Request| match r.op {
        ReqOp::Mul => simdive_mul_w(r.bits, r.a, r.b, r.w),
        ReqOp::Div => simdive_div_w(r.bits, r.a, r.b, r.w),
    };
    let reqs: Vec<Request> = (0..n).map(make_mixed).collect();
    let mut out: Vec<u64> = Vec::new();
    let mut results = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        let eng = Engine::sharded(
            MulDesign::Simdive { w: 8 },
            DivDesign::Simdive { w: 8 },
            ShardedConfig { shards, queue_depth: 1024, batch: 64 },
        );
        // Bit-exactness gate before timing (the scaling claim is only
        // worth tracking if the answers stay identical).
        let gate = 1024.min(reqs.len());
        eng.execute_stream_into(&reqs[..gate], &mut out);
        for (r, &got) in reqs[..gate].iter().zip(&out) {
            assert_eq!(got, expect(r), "sharded x{shards} diverged");
        }
        // Warm-up pass, then timed passes for ~0.3 s.
        for chunk in reqs.chunks(4096) {
            eng.execute_stream_into(chunk, &mut out);
        }
        let t0 = Instant::now();
        let mut passes = 0u32;
        while t0.elapsed().as_millis() < 300 {
            for chunk in reqs.chunks(4096) {
                eng.execute_stream_into(chunk, &mut out);
                black_box(&out);
            }
            passes += 1;
        }
        let rps = (n * passes as u64) as f64 / t0.elapsed().as_secs_f64();
        println!("[bench] engine sharded x{shards}: {:.1} kreq/s", rps / 1e3);
        results.push((shards, rps));
    }
    results
}

/// Observability overhead probe (DESIGN.md §12): the same mixed-w
/// workload through two otherwise-identical 4-shard pools, one detached
/// (`Sharded::start`) and one with the full metrics registry attached
/// (`Sharded::start_observed` — tier counters, stage histograms, queue
/// gauges, span stamping). The acceptance budget is < 3% throughput loss;
/// the measured figure lands in `BENCH_hotpath.json` under `obs`.
fn bench_obs_overhead(n: u64) -> (f64, f64, f64) {
    use simdive::engine::{Engine, Sharded, ShardedConfig};
    use simdive::obs::Registry;
    use std::sync::Arc;
    let reqs: Vec<Request> = (0..n).map(make_mixed).collect();
    let cfg = ShardedConfig { shards: 4, queue_depth: 1024, batch: 64 };
    let registry = Registry::new();
    let time_pool = |pool: Sharded| -> f64 {
        let eng = Engine::with_backend(
            Arc::new(pool),
            MulDesign::Simdive { w: 8 },
            DivDesign::Simdive { w: 8 },
        );
        let mut out: Vec<u64> = Vec::new();
        for chunk in reqs.chunks(4096) {
            eng.execute_stream_into(chunk, &mut out); // warm up
        }
        let t0 = Instant::now();
        let mut passes = 0u32;
        while t0.elapsed().as_millis() < 300 {
            for chunk in reqs.chunks(4096) {
                eng.execute_stream_into(chunk, &mut out);
                black_box(&out);
            }
            passes += 1;
        }
        (n * passes as u64) as f64 / t0.elapsed().as_secs_f64()
    };
    let bare_rps = time_pool(Sharded::start(cfg));
    let observed_rps = time_pool(Sharded::start_observed(cfg, None, &registry));
    // The registry must have tier counters registered by the observed pool
    // — an empty registry would mean the "observed" run timed nothing.
    let snap = registry.snapshot();
    assert!(
        snap.entries.iter().any(|(name, _)| name.starts_with("tier.")),
        "observed pool registered no tier counters"
    );
    let overhead_pct = (1.0 - observed_rps / bare_rps) * 100.0;
    println!(
        "[bench] obs overhead: bare {:.1} kreq/s, observed {:.1} kreq/s ({:+.2}%)",
        bare_rps / 1e3,
        observed_rps / 1e3,
        overhead_pct
    );
    (bare_rps, observed_rps, overhead_pct)
}

fn json_op_section(results: &[&OpResult]) -> String {
    let mut s = String::from("{");
    for (k, r) in results.iter().enumerate() {
        if k > 0 {
            s.push_str(", ");
        }
        write!(
            s,
            "\"{}\": {{\"scalar_mops\": {:.2}, \"batched_mops\": {:.2}, \"speedup\": {:.3}",
            r.bits,
            r.scalar_mops,
            r.batched_mops,
            r.speedup()
        )
        .unwrap();
        if let Some(l) = r.lanewise_mops {
            write!(s, ", \"lanewise_mops\": {l:.2}, \"swar_speedup\": {:.3}", r.batched_mops / l)
                .unwrap();
        }
        s.push('}');
    }
    s.push('}');
    s
}

fn main() {
    let mut rng = Rng::new(0x407_BA7C);
    let mut muls = Vec::new();
    let mut divs = Vec::new();
    for &bits in &simdive::arith::WIDTHS {
        muls.push(bench_op(bits, false, &mut rng));
        divs.push(bench_op(bits, true, &mut rng));
    }
    let (coord_scalar_rps, coord_batched_rps, coord_mixed_rps, coord_mixed_util) =
        bench_coordinator();
    let sharded = bench_sharded(COORD_REQUESTS);
    let (obs_bare, obs_observed, obs_overhead) = bench_obs_overhead(COORD_REQUESTS);

    // JSON fragments for the shard sweep (`shards` lists the swept
    // counts; `sharded_rps` maps each count to its throughput).
    let shard_counts = sharded.iter().map(|(s, _)| s.to_string()).collect::<Vec<_>>().join(", ");
    let mut sharded_rps = String::from("{");
    for (k, (s, rps)) in sharded.iter().enumerate() {
        if k > 0 {
            sharded_rps.push_str(", ");
        }
        write!(sharded_rps, "\"{s}\": {rps:.1}").unwrap();
    }
    sharded_rps.push('}');

    // Schema note: `batched_mixed_w_rps`/`mixed_w_lane_utilization`
    // (coordinator v2), `shards`/`sharded_rps` (engine sharding), the
    // `obs` block (observability overhead, DESIGN.md §12), and the
    // per-op `lanewise_mops`/`swar_speedup` fields on the 8-bit rows
    // (SWAR kernels, DESIGN.md §13) are append-only additions; the
    // schema name is unchanged (CHANGES.md).
    let json = format!(
        "{{\n  \"schema\": \"simdive-hotpath-v1\",\n  \"elements_per_pass\": {N},\n  \
         \"mul\": {},\n  \"div\": {},\n  \"coordinator\": {{\"requests\": {COORD_REQUESTS}, \
         \"per_request_rps\": {:.1}, \"batched_rps\": {:.1}, \
         \"batched_mixed_w_rps\": {:.1}, \"mixed_w_lane_utilization\": {:.4}, \
         \"shards\": [{}], \"sharded_rps\": {}}},\n  \
         \"obs\": {{\"sharded_rps_bare\": {obs_bare:.1}, \
         \"sharded_rps_observed\": {obs_observed:.1}, \
         \"overhead_pct\": {obs_overhead:.2}}}\n}}\n",
        json_op_section(&muls.iter().collect::<Vec<_>>()),
        json_op_section(&divs.iter().collect::<Vec<_>>()),
        coord_scalar_rps,
        coord_batched_rps,
        coord_mixed_rps,
        coord_mixed_util,
        shard_counts,
        sharded_rps,
    );
    let path = simdive::util::repo_root().join("BENCH_hotpath.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[bench] wrote {}", path.display()),
        Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
    }
}
