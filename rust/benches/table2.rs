//! Regenerates paper Table 2 (SISD 16×16 multipliers, 16/8 dividers,
//! integrated unit) and times the hot paths.
mod harness;

fn main() {
    let samples = if std::env::var("BENCH_FAST").is_ok() { 100_000 } else { 1_000_000 };
    let table = harness::timed("table2 full regeneration", || {
        simdive::report::table2::render(samples)
    });
    println!("{table}");
    // Behavioral hot paths (the serving-path arithmetic).
    let mut rng = simdive::util::Rng::new(1);
    let pairs: Vec<(u64, u64)> =
        (0..4096).map(|_| (rng.operand(16), rng.operand(16))).collect();
    let mut i = 0;
    harness::ns_per_op("simdive_mul16 behavioral", || {
        let (a, b) = pairs[i & 4095];
        i += 1;
        std::hint::black_box(simdive::arith::simdive::simdive_mul(16, a, b));
    });
    let mut j = 0;
    harness::ns_per_op("simdive_div16 behavioral", || {
        let (a, b) = pairs[j & 4095];
        j += 1;
        std::hint::black_box(simdive::arith::simdive::simdive_div(16, a, b));
    });
}
