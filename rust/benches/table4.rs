//! Regenerates paper Table 4 (ANN accuracy with accurate/approximate
//! multipliers) and times quantized inference per image.
mod harness;
use simdive::report::table4::{render, Scale};

fn main() {
    let scale = if std::env::var("BENCH_FAST").is_ok() {
        Scale { train: 1500, test: 300, epochs: 3, nodes: 48 }
    } else {
        Scale::default()
    };
    let table = harness::timed("table4 full regeneration (train + eval ×4 designs)", || {
        render(scale)
    });
    println!("{table}");
    // Per-image quantized inference timing through the engine seam.
    use simdive::ann::{Mlp, QuantMlp};
    use simdive::arith::MulDesign;
    use simdive::datasets::{generate, Family};
    use simdive::engine::Engine;
    let train = generate(Family::Digits, 1500, 11);
    let mut net = Mlp::new(&[48], 7);
    net.train(&train, 2, 0.1, 8);
    let q = QuantMlp::from_float(&net, &train[..200]);
    let test = generate(Family::Digits, 64, 12);
    let engine = Engine::from_mul(MulDesign::Simdive { w: 8 });
    let mut i = 0;
    harness::ns_per_op("quantized inference/image (SIMDive mul)", || {
        let ex = &test[i & 63];
        i += 1;
        std::hint::black_box(q.predict(&ex.pixels, &engine));
    });
}
