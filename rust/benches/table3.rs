//! Regenerates paper Table 3 (32-bit SIMD designs) and times the SIMD
//! behavioral word path.
mod harness;

fn main() {
    let table = harness::timed("table3 full regeneration", || {
        simdive::report::table3::render()
    });
    println!("{table}");
    use simdive::arith::simd::{execute, LaneCfg, LaneMode, SimdOp, SimdWord};
    let op = SimdOp::uniform(LaneCfg::Four8, LaneMode::Mul);
    let mut x = 0x0102_0304u32;
    harness::ns_per_op("simd word execute (4×8 mul)", || {
        x = x.wrapping_mul(0x9E3779B9).wrapping_add(1);
        std::hint::black_box(execute(op, SimdWord::new(x | 0x0101_0101, 0x0503_0907), 8));
    });
}
