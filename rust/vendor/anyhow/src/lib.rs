//! Offline shim for the `anyhow` crate (the real crate is unavailable in
//! the vendored registry — DESIGN.md §1). Implements exactly the surface
//! the simdive crate uses: `Error`, `Result`, the `Context` extension
//! trait on `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros.
//!
//! Semantics mirror anyhow where it matters here:
//! * `Display` prints the outermost message only;
//! * alternate `Display` (`{:#}`) prints the whole context chain joined
//!   with `": "`;
//! * `Debug` prints the outermost message plus a `Caused by:` list, so a
//!   `fn main() -> anyhow::Result<()>` failure reads well;
//! * any `std::error::Error` converts via `?`, capturing its source chain.

use std::fmt;

/// A context-chain error. `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/nonexistent/simdive-shim-test")
            .map(|_| ())
            .context("reading artifacts dir /nonexistent")
    }

    #[test]
    fn context_chain_formats() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading artifacts dir /nonexistent");
        assert!(format!("{err:#}").starts_with("reading artifacts dir /nonexistent: "));
        assert!(format!("{err:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(format!("{err}"), "missing value");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).is_err());
        assert!(format!("{}", f(5).unwrap_err()).contains("five"));
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
