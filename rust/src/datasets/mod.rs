//! Synthetic 28×28 classification datasets — the offline stand-in for
//! MNIST digits and Fashion-MNIST (DESIGN.md §1). Both are procedural and
//! seed-deterministic; the *same spec* is implemented in
//! `python/compile/datagen.py` (shared constants, same glyphs) so the JAX
//! training pipeline and the Rust inference substrate agree on the data.
//!
//! * `digits`: seven-segment-style digit glyphs rendered with random
//!   shift/scale/shear + pixel noise — 10 classes.
//! * `fashion`: 10 parametric texture/silhouette classes (stripes, checks,
//!   blobs, frames, …) with the same augmentation.

use crate::util::Rng;

pub const IMG: usize = 28;
pub const CLASSES: usize = 10;

/// Which dataset family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Digits,
    Fashion,
}

/// A labelled example: 28×28 grayscale, row-major.
#[derive(Clone, Debug)]
pub struct Example {
    pub pixels: [u8; IMG * IMG],
    pub label: u8,
}

/// Seven-segment truth table: segments (a,b,c,d,e,f,g) per digit.
const SEGMENTS: [[bool; 7]; 10] = [
    [true, true, true, true, true, true, false],    // 0
    [false, true, true, false, false, false, false],// 1
    [true, true, false, true, true, false, true],   // 2
    [true, true, true, true, false, false, true],   // 3
    [false, true, true, false, false, true, true],  // 4
    [true, false, true, true, false, true, true],   // 5
    [true, false, true, true, true, true, true],    // 6
    [true, true, true, false, false, false, false], // 7
    [true, true, true, true, true, true, true],     // 8
    [true, true, true, true, false, true, true],    // 9
];

fn render_digit(label: u8, rng: &mut Rng) -> [u8; IMG * IMG] {
    let mut img = [0f64; IMG * IMG];
    let segs = SEGMENTS[label as usize];
    // Base glyph box in unit coords.
    let (x0, x1) = (0.28, 0.72);
    let (y0, ym, y1) = (0.15, 0.5, 0.85);
    let thick = 0.06 + rng.f64() * 0.03;
    // Augmentation: shift, scale, shear.
    let sx = 0.8 + rng.f64() * 0.4;
    let sy = 0.8 + rng.f64() * 0.4;
    let shear = (rng.f64() - 0.5) * 0.3;
    let dx = (rng.f64() - 0.5) * 0.18;
    let dy = (rng.f64() - 0.5) * 0.18;
    // Segment geometry: (is_horizontal, cx/cy line endpoints).
    let seg_lines: [(bool, f64, f64, f64); 7] = [
        (true, y0, x0, x1),  // a: top
        (false, x1, y0, ym), // b: top-right
        (false, x1, ym, y1), // c: bottom-right
        (true, y1, x0, x1),  // d: bottom
        (false, x0, ym, y1), // e: bottom-left
        (false, x0, y0, ym), // f: top-left
        (true, ym, x0, x1),  // g: middle
    ];
    for py in 0..IMG {
        for px in 0..IMG {
            // Inverse-transform pixel to glyph space.
            let u0 = (px as f64 + 0.5) / IMG as f64;
            let v0 = (py as f64 + 0.5) / IMG as f64;
            let u = (u0 - 0.5 - dx) / sx + 0.5 - shear * (v0 - 0.5);
            let v = (v0 - 0.5 - dy) / sy + 0.5;
            let mut intensity = 0.0f64;
            for (si, &(horiz, line, lo, hi)) in seg_lines.iter().enumerate() {
                if !segs[si] {
                    continue;
                }
                let (d_line, d_span) = if horiz {
                    ((v - line).abs(), if u < lo { lo - u } else if u > hi { u - hi } else { 0.0 })
                } else {
                    ((u - line).abs(), if v < lo { lo - v } else if v > hi { v - hi } else { 0.0 })
                };
                let d = d_line.max(d_span);
                if d < thick {
                    intensity = intensity.max(1.0 - (d / thick).powi(2));
                }
            }
            img[py * IMG + px] = intensity * (200.0 + rng.f64() * 55.0);
        }
    }
    finish(img, rng)
}

fn render_fashion(label: u8, rng: &mut Rng) -> [u8; IMG * IMG] {
    let mut img = [0f64; IMG * IMG];
    let p1 = 0.2 + rng.f64() * 0.12; // silhouette inset
    let freq = 2.0 + rng.f64() * 2.0;
    let phase = rng.f64() * std::f64::consts::TAU;
    for py in 0..IMG {
        for px in 0..IMG {
            let u = (px as f64 + 0.5) / IMG as f64;
            let v = (py as f64 + 0.5) / IMG as f64;
            let inside: f64 = match label {
                // 0: solid block ("tshirt"), 1: tall rect ("trouser"),
                // 2: horizontal stripes, 3: vertical stripes, 4: checks,
                // 5: centre blob ("bag"), 6: frame ("coat"), 7: diagonal,
                // 8: two blobs ("sneaker"), 9: ring ("ankle boot").
                0 => f64::from(u > p1 && u < 1.0 - p1 && v > p1 && v < 1.0 - p1),
                1 => f64::from(u > 0.35 && u < 0.65 && v > 0.1 && v < 0.9),
                2 => ((freq * 2.0 * v * std::f64::consts::TAU + phase).sin() > 0.0) as u8 as f64,
                3 => ((freq * 2.0 * u * std::f64::consts::TAU + phase).sin() > 0.0) as u8 as f64,
                4 => {
                    (((freq * u * std::f64::consts::TAU).sin() > 0.0)
                        ^ ((freq * v * std::f64::consts::TAU).sin() > 0.0)) as u8 as f64
                }
                5 => {
                    let d = ((u - 0.5).powi(2) + (v - 0.5).powi(2)).sqrt();
                    f64::from(d < 0.3)
                }
                6 => {
                    let inner = u > 0.3 && u < 0.7 && v > 0.3 && v < 0.7;
                    let outer = u > 0.12 && u < 0.88 && v > 0.12 && v < 0.88;
                    f64::from(outer && !inner)
                }
                7 => (((u + v) * freq * std::f64::consts::TAU).sin() > 0.0) as u8 as f64,
                8 => {
                    let d1 = ((u - 0.32).powi(2) + (v - 0.6).powi(2)).sqrt();
                    let d2 = ((u - 0.68).powi(2) + (v - 0.45).powi(2)).sqrt();
                    f64::from(d1 < 0.18 || d2 < 0.18)
                }
                _ => {
                    let d = ((u - 0.5).powi(2) + (v - 0.5).powi(2)).sqrt();
                    f64::from(d > 0.2 && d < 0.36)
                }
            };
            img[py * IMG + px] = inside * (160.0 + rng.f64() * 70.0);
        }
    }
    finish(img, rng)
}

/// Shared post-processing: additive noise + clamp. The noise level is
/// chosen so a well-trained float MLP sits in the mid/high-90s — like the
/// paper's MNIST setting — leaving visible headroom for quantization and
/// approximate-multiplier deltas (Table 4).
fn finish(mut img: [f64; IMG * IMG], rng: &mut Rng) -> [u8; IMG * IMG] {
    let mut out = [0u8; IMG * IMG];
    for (o, v) in out.iter_mut().zip(img.iter_mut()) {
        let n = rng.normal() * 40.0;
        *o = (*v + n).clamp(0.0, 255.0) as u8;
    }
    out
}

/// Generate `count` examples of a family, deterministic in `seed`.
pub fn generate(family: Family, count: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ family as u64);
    (0..count)
        .map(|_| {
            let label = rng.below(CLASSES as u64) as u8;
            let pixels = match family {
                Family::Digits => render_digit(label, &mut rng),
                Family::Fashion => render_fashion(label, &mut rng),
            };
            Example { pixels, label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(Family::Digits, 10, 7);
        let b = generate(Family::Digits, 10, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
    }

    #[test]
    fn all_classes_appear() {
        for fam in [Family::Digits, Family::Fashion] {
            let ex = generate(fam, 500, 3);
            let mut seen = [false; CLASSES];
            for e in &ex {
                seen[e.label as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{fam:?}: missing class");
        }
    }

    #[test]
    fn digits_are_distinguishable_by_template() {
        // Nearest-mean-template classification on clean renders must beat
        // chance by a wide margin — sanity that classes carry signal.
        let train = generate(Family::Digits, 1500, 11);
        let test = generate(Family::Digits, 300, 12);
        let mut means = vec![[0f64; IMG * IMG]; CLASSES];
        let mut counts = [0usize; CLASSES];
        for e in &train {
            counts[e.label as usize] += 1;
            for (m, &p) in means[e.label as usize].iter_mut().zip(&e.pixels) {
                *m += p as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for e in &test {
            let mut best = (f64::INFINITY, 0usize);
            for (c, m) in means.iter().enumerate() {
                let d: f64 =
                    m.iter().zip(&e.pixels).map(|(&mv, &p)| (mv - p as f64).powi(2)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == e.label as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "template accuracy {acc}");
    }

    #[test]
    fn fashion_classes_distinguishable() {
        let train = generate(Family::Fashion, 1000, 13);
        let test = generate(Family::Fashion, 200, 14);
        let mut means = vec![[0f64; IMG * IMG]; CLASSES];
        let mut counts = [0usize; CLASSES];
        for e in &train {
            counts[e.label as usize] += 1;
            for (m, &p) in means[e.label as usize].iter_mut().zip(&e.pixels) {
                *m += p as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for e in &test {
            let mut best = (f64::INFINITY, 0usize);
            for (c, m) in means.iter().enumerate() {
                let d: f64 =
                    m.iter().zip(&e.pixels).map(|(&mv, &p)| (mv - p as f64).powi(2)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == e.label as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "fashion template accuracy {acc}");
    }
}
