//! The §4.3 image applications with pluggable arithmetic.
//!
//! * **Multiply-based blending** (Fig. 3): `out = A·B / 256` — every
//!   multiply routed through the selected approximate multiplier.
//! * **Gaussian smoothing** (Fig. 4): 5×5 integer kernel (sum 273, the
//!   classic discrete Gaussian), evaluated in two modes: *div-only*
//!   (multiplies exact, the ÷273 normalization approximate) and *hybrid*
//!   (weight multiplies **and** the normalization approximate) — exactly
//!   the paper's two experiment arms.

use super::Image;
use crate::arith::{DivDesign, MulDesign};
use crate::engine::Engine;

/// Pluggable arithmetic backend for the applications.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArithKind {
    /// Exact integer arithmetic (the reference pipeline).
    Accurate,
    /// Mitchell's multiplier/divider [22].
    Mitchell,
    /// MBM multiplier [28] + INZeD divider [29] (the SoA pairing).
    MbmInzed,
    /// SIMDive at tuning `w`.
    Simdive(u32),
}

impl ArithKind {
    /// The equivalent multiplier design (identical per-element semantics,
    /// including `ArithKind::Accurate` ↔ `MulDesign::Accurate`).
    pub fn mul_design(self) -> MulDesign {
        match self {
            ArithKind::Accurate => MulDesign::Accurate,
            ArithKind::Mitchell => MulDesign::Mitchell,
            ArithKind::MbmInzed => MulDesign::Mbm,
            ArithKind::Simdive(w) => MulDesign::Simdive { w },
        }
    }

    /// The equivalent divider design (`MbmInzed` pairs MBM's multiplier
    /// with the INZeD divider, as in the paper's SoA baseline).
    pub fn div_design(self) -> DivDesign {
        match self {
            ArithKind::Accurate => DivDesign::Accurate,
            ArithKind::Mitchell => DivDesign::Mitchell,
            ArithKind::MbmInzed => DivDesign::Inzed,
            ArithKind::Simdive(w) => DivDesign::Simdive { w },
        }
    }

    /// The engine handle executing this arithmetic kind: the batched
    /// backend with this kind's `{mul, div}` design pair (DESIGN.md §10).
    /// The pipelines call this once per image and route every multiply
    /// (16-bit lanes) and normalization divide (a 32-bit lane — wide
    /// enough for the 5×5 kernel's accumulators) through the one seam.
    pub fn engine(self) -> Engine {
        Engine::batched(self.mul_design(), self.div_design())
    }

    pub fn name(self) -> &'static str {
        match self {
            ArithKind::Accurate => "Accurate",
            ArithKind::Mitchell => "Mitchell",
            ArithKind::MbmInzed => "MBM/INZeD",
            ArithKind::Simdive(_) => "SIMDive",
        }
    }
}

/// Multiply-blend two images: `out = A·B / 256` with the multiplier from
/// `kind` (the divide-by-256 is a shift in all variants, as in the paper's
/// multiplier-only experiment). Pixels are processed in tiles through the
/// engine's batched multiplier — one table resolution per tile, not per
/// pixel — with bit-identical results.
pub fn blend(a: &Image, b: &Image, kind: ArithKind) -> Image {
    assert_eq!(a.width, b.width);
    assert_eq!(a.height, b.height);
    const TILE: usize = 4096;
    let engine = kind.engine();
    let mut out = Image::new(a.width, a.height);
    let mut ops_a: Vec<u64> = Vec::with_capacity(TILE);
    let mut ops_b: Vec<u64> = Vec::with_capacity(TILE);
    let mut prods: Vec<u64> = Vec::with_capacity(TILE);
    let mut offset = 0usize;
    while offset < a.data.len() {
        let end = (offset + TILE).min(a.data.len());
        ops_a.clear();
        ops_a.extend(a.data[offset..end].iter().map(|&p| p as u64));
        ops_b.clear();
        ops_b.extend(b.data[offset..end].iter().map(|&p| p as u64));
        engine.mul_into(16, &ops_a, &ops_b, &mut prods);
        for (dst, &p) in out.data[offset..end].iter_mut().zip(&prods) {
            *dst = (p >> 8).min(255) as u8;
        }
        offset = end;
    }
    out
}

/// The classic 5×5 integer Gaussian kernel (σ ≈ 1), sum = 273.
pub const GAUSS5: [[u64; 5]; 5] = [
    [1, 4, 7, 4, 1],
    [4, 16, 26, 16, 4],
    [7, 26, 41, 26, 7],
    [4, 16, 26, 16, 4],
    [1, 4, 7, 4, 1],
];
pub const GAUSS5_SUM: u64 = 273;

/// Gaussian smoothing. `approx_mul` selects the hybrid arm (weight
/// multiplies also approximate); the ÷273 normalization always uses
/// `kind`'s divider (the div-only arm passes `approx_mul = false`).
///
/// Evaluation is row-batched through the engine seam: in the hybrid arm
/// the 25 weight multiplies of every pixel in a row form one batched
/// multiply (width·25 products per call), and the ÷273 normalizations of
/// the row form one batched divide. Tap order and accumulation are
/// unchanged, so output is bit-identical to the per-pixel path.
pub fn gaussian_smooth(img: &Image, kind: ArithKind, approx_mul: bool) -> Image {
    const TAPS: usize = 25;
    let engine = kind.engine();
    let mut out = Image::new(img.width, img.height);
    // The weight pattern of a row is the same for every row: width copies
    // of the flattened 5×5 kernel. Build it once.
    let ops_w: Vec<u64> = if approx_mul {
        GAUSS5.iter().flatten().copied().cycle().take(img.width * TAPS).collect()
    } else {
        Vec::new()
    };
    let mut ops_px: Vec<u64> = Vec::with_capacity(img.width * TAPS);
    let mut prods: Vec<u64> = Vec::new();
    let mut accs: Vec<u64> = Vec::with_capacity(img.width);
    let divisors: Vec<u64> = vec![GAUSS5_SUM; img.width];
    let mut quots: Vec<u64> = Vec::new();
    for y in 0..img.height {
        accs.clear();
        if approx_mul {
            // Gather the row's taps, batch-multiply, then reduce per pixel.
            ops_px.clear();
            for x in 0..img.width {
                for dy in 0..5isize {
                    for dx in 0..5isize {
                        let px = img.at_clamped(x as isize + dx - 2, y as isize + dy - 2) as u64;
                        ops_px.push(px);
                    }
                }
            }
            engine.mul_into(16, &ops_w, &ops_px, &mut prods);
            for chunk in prods.chunks_exact(TAPS) {
                accs.push(chunk.iter().sum());
            }
        } else {
            for x in 0..img.width {
                let mut acc = 0u64;
                for (dy, row) in GAUSS5.iter().enumerate() {
                    for (dx, &w) in row.iter().enumerate() {
                        let px = img.at_clamped(
                            x as isize + dx as isize - 2,
                            y as isize + dy as isize - 2,
                        ) as u64;
                        acc += w * px;
                    }
                }
                accs.push(acc);
            }
        }
        engine.div_into(32, &accs, &divisors, &mut quots);
        for (x, &v) in quots.iter().enumerate() {
            out.set(x, y, v.min(255) as u8);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{add_gaussian_noise, generate, Scene};
    use crate::metrics::psnr;

    #[test]
    fn kernel_sum_is_273() {
        let s: u64 = GAUSS5.iter().flatten().sum();
        assert_eq!(s, GAUSS5_SUM);
    }

    #[test]
    fn accurate_blend_matches_direct() {
        let a = generate(Scene::Portrait, 32, 1);
        let b = generate(Scene::Texture, 32, 2);
        let out = blend(&a, &b, ArithKind::Accurate);
        for i in 0..out.data.len() {
            assert_eq!(out.data[i] as u64, (a.data[i] as u64 * b.data[i] as u64) >> 8);
        }
    }

    #[test]
    fn fig3_blending_psnr_ordering() {
        // Paper Fig. 3: SIMDive blending PSNR (vs accurate result) ≈ 46.6,
        // MBM ≈ 32.1 — SIMDive must beat MBM by a wide margin.
        let a = generate(Scene::Portrait, 128, 11);
        let b = generate(Scene::Architecture, 128, 12);
        let acc = blend(&a, &b, ArithKind::Accurate);
        let sd = blend(&a, &b, ArithKind::Simdive(8));
        let mbm = blend(&a, &b, ArithKind::MbmInzed);
        let p_sd = psnr(&acc.data, &sd.data);
        let p_mbm = psnr(&acc.data, &mbm.data);
        assert!(p_sd > p_mbm + 5.0, "SIMDive {p_sd} vs MBM {p_mbm}");
        assert!(p_sd > 38.0, "SIMDive blending PSNR {p_sd}");
    }

    #[test]
    fn fig4_gaussian_psnr_ordering() {
        // Paper Fig. 4 (PSNR vs the noise-free original): SIMDive div-only
        // ≈ 24.5 > INZeD ≈ 20.9; hybrid SIMDive ≈ 23.3 ≥ hybrid MBM/INZeD
        // ≈ 21.3, and hybrid ≈ div-only for SIMDive.
        let clean = generate(Scene::Portrait, 128, 21);
        let noisy = add_gaussian_noise(&clean, 18.0, 22);
        let p = |img: &Image| psnr(&clean.data, &img.data);

        let sd_div = p(&gaussian_smooth(&noisy, ArithKind::Simdive(8), false));
        let soa_div = p(&gaussian_smooth(&noisy, ArithKind::MbmInzed, false));
        assert!(sd_div > soa_div, "div-only: SIMDive {sd_div} vs INZeD {soa_div}");

        let sd_hyb = p(&gaussian_smooth(&noisy, ArithKind::Simdive(8), true));
        let soa_hyb = p(&gaussian_smooth(&noisy, ArithKind::MbmInzed, true));
        assert!(sd_hyb >= soa_hyb - 0.2, "hybrid: SIMDive {sd_hyb} vs MBM/INZeD {soa_hyb}");
        // Hybrid stays close to div-only for SIMDive (paper's motivation
        // for the integrated unit).
        assert!((sd_div - sd_hyb).abs() < 2.0, "div {sd_div} vs hybrid {sd_hyb}");
    }

    /// Per-pixel reference of the batched [`blend`]/[`gaussian_smooth`]
    /// paths, used as the bit-equality oracle (one scalar engine dispatch
    /// per pixel — the seam's scalar convenience form).
    fn blend_scalar(a: &Image, b: &Image, kind: ArithKind) -> Image {
        let engine = kind.engine();
        let mut out = Image::new(a.width, a.height);
        for i in 0..a.data.len() {
            let p = engine.mul(16, a.data[i] as u64, b.data[i] as u64);
            out.data[i] = (p >> 8).min(255) as u8;
        }
        out
    }

    fn gaussian_scalar(img: &Image, kind: ArithKind, approx_mul: bool) -> Image {
        let engine = kind.engine();
        let mut out = Image::new(img.width, img.height);
        for y in 0..img.height {
            for x in 0..img.width {
                let mut acc = 0u64;
                for (dy, row) in GAUSS5.iter().enumerate() {
                    for (dx, &w) in row.iter().enumerate() {
                        let px = img
                            .at_clamped(x as isize + dx as isize - 2, y as isize + dy as isize - 2)
                            as u64;
                        acc += if approx_mul { engine.mul(16, w, px) } else { w * px };
                    }
                }
                let v = engine.div(32, acc, GAUSS5_SUM);
                out.set(x, y, v.min(255) as u8);
            }
        }
        out
    }

    #[test]
    fn batched_pipelines_bit_match_scalar() {
        let a = generate(Scene::Portrait, 64, 41);
        let b = generate(Scene::Texture, 64, 42);
        for kind in [
            ArithKind::Accurate,
            ArithKind::Mitchell,
            ArithKind::MbmInzed,
            ArithKind::Simdive(8),
            ArithKind::Simdive(3),
        ] {
            assert_eq!(blend(&a, &b, kind).data, blend_scalar(&a, &b, kind).data, "{kind:?}");
            for approx_mul in [false, true] {
                assert_eq!(
                    gaussian_smooth(&a, kind, approx_mul).data,
                    gaussian_scalar(&a, kind, approx_mul).data,
                    "{kind:?} hybrid={approx_mul}"
                );
            }
        }
    }

    #[test]
    fn gaussian_reduces_noise() {
        let clean = generate(Scene::Portrait, 96, 31);
        let noisy = add_gaussian_noise(&clean, 18.0, 32);
        let sm = gaussian_smooth(&noisy, ArithKind::Accurate, false);
        assert!(
            psnr(&clean.data, &sm.data) > psnr(&clean.data, &noisy.data),
            "smoothing must improve PSNR on noisy input"
        );
    }
}
