//! Deterministic synthetic test images with natural-image statistics
//! (smooth illumination gradients + band-limited texture + sharp edges),
//! the offline stand-in for the USC-SIPI photographs (DESIGN.md §1).

use super::Image;
use crate::util::Rng;

/// A named synthetic scene.
#[derive(Clone, Copy, Debug)]
pub enum Scene {
    /// Smooth radial gradient + soft blobs ("portrait"-like).
    Portrait,
    /// Strong edges + periodic texture ("buildings"-like).
    Architecture,
    /// Band-limited noise texture ("grass"-like).
    Texture,
    /// High-contrast geometric shapes (worst case for approximation).
    Shapes,
}

impl Scene {
    pub const ALL: [Scene; 4] =
        [Scene::Portrait, Scene::Architecture, Scene::Texture, Scene::Shapes];
}

/// Render a scene at `size`×`size`, deterministic in `seed`.
pub fn generate(scene: Scene, size: usize, seed: u64) -> Image {
    let mut rng = Rng::new(seed ^ (scene as u64).wrapping_mul(0x9E37_79B9));
    let mut img = Image::new(size, size);
    // Low-frequency lobes shared by all scenes (illumination).
    let lobes: Vec<(f64, f64, f64, f64)> = (0..4)
        .map(|_| {
            (
                rng.f64() * size as f64,
                rng.f64() * size as f64,
                (0.2 + rng.f64() * 0.5) * size as f64,
                rng.f64() * 120.0,
            )
        })
        .collect();
    // Per-scene detail parameters.
    let phase = rng.f64() * std::f64::consts::TAU;
    let freq = 0.15 + rng.f64() * 0.25;
    let mut noise = vec![0.0f64; size * size];
    if matches!(scene, Scene::Texture) {
        // Band-limited noise: white noise box-blurred twice.
        let mut white: Vec<f64> = (0..size * size).map(|_| rng.f64() - 0.5).collect();
        for _ in 0..2 {
            let mut blurred = vec![0.0f64; size * size];
            for y in 0..size {
                for x in 0..size {
                    let mut s = 0.0;
                    for dy in -1isize..=1 {
                        for dx in -1isize..=1 {
                            let xi = (x as isize + dx).clamp(0, size as isize - 1) as usize;
                            let yi = (y as isize + dy).clamp(0, size as isize - 1) as usize;
                            s += white[yi * size + xi];
                        }
                    }
                    blurred[y * size + x] = s / 9.0;
                }
            }
            white = blurred;
        }
        noise = white;
    }
    let rects: Vec<(usize, usize, usize, usize, f64)> = (0..6)
        .map(|_| {
            let x0 = rng.below(size as u64 * 3 / 4) as usize;
            let y0 = rng.below(size as u64 * 3 / 4) as usize;
            let w = 4 + rng.below(size as u64 / 3) as usize;
            let h = 4 + rng.below(size as u64 / 3) as usize;
            (x0, y0, w, h, rng.f64() * 255.0)
        })
        .collect();

    for y in 0..size {
        for x in 0..size {
            let (xf, yf) = (x as f64, y as f64);
            let mut v = 90.0f64;
            for &(cx, cy, r, amp) in &lobes {
                let d2 = (xf - cx).powi(2) + (yf - cy).powi(2);
                v += amp * (-d2 / (r * r)).exp();
            }
            match scene {
                Scene::Portrait => {}
                Scene::Architecture => {
                    v += 45.0 * ((freq * xf + phase).sin() * (freq * 0.7 * yf).cos()).signum();
                }
                Scene::Texture => {
                    v += 520.0 * noise[y * size + x];
                }
                Scene::Shapes => {
                    for &(x0, y0, w, h, level) in &rects {
                        if x >= x0 && x < x0 + w && y >= y0 && y < y0 + h {
                            v = level;
                        }
                    }
                }
            }
            img.set(x, y, v.clamp(0.0, 255.0) as u8);
        }
    }
    img
}

/// Add white Gaussian noise with the given σ (for the Fig.-4 denoising
/// scenario).
pub fn add_gaussian_noise(img: &Image, sigma: f64, seed: u64) -> Image {
    let mut rng = Rng::new(seed);
    let mut out = img.clone();
    for px in out.data.iter_mut() {
        let v = *px as f64 + rng.normal() * sigma;
        *px = v.clamp(0.0, 255.0) as u8;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = generate(Scene::Portrait, 64, 5);
        let b = generate(Scene::Portrait, 64, 5);
        assert_eq!(a, b);
        let c = generate(Scene::Portrait, 64, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn scenes_have_reasonable_dynamic_range() {
        for scene in Scene::ALL {
            let img = generate(scene, 128, 1);
            let min = *img.data.iter().min().unwrap();
            let max = *img.data.iter().max().unwrap();
            assert!(max - min > 60, "{scene:?}: range {min}..{max}");
        }
    }

    #[test]
    fn noise_increases_mse_but_bounded() {
        let img = generate(Scene::Portrait, 64, 2);
        let noisy = add_gaussian_noise(&img, 12.0, 3);
        let p = crate::metrics::psnr(&img.data, &noisy.data);
        assert!(p > 20.0 && p < 35.0, "noisy PSNR {p}");
    }
}
