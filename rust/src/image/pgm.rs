//! Minimal binary PGM (P5) reader/writer so experiment outputs can be
//! inspected with standard tools.

use super::Image;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Write an image as binary PGM.
pub fn write_pgm(img: &Image, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write!(f, "P5\n{} {}\n255\n", img.width, img.height)?;
    f.write_all(&img.data)?;
    Ok(())
}

/// Read a binary PGM.
pub fn read_pgm(path: &Path) -> Result<Image> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut buf)?;
    // Parse header: magic, width, height, maxval, single whitespace, data.
    let mut pos = 0usize;
    let mut token = || -> Result<String> {
        while pos < buf.len() && buf[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if pos < buf.len() && buf[pos] == b'#' {
            while pos < buf.len() && buf[pos] != b'\n' {
                pos += 1;
            }
            while pos < buf.len() && buf[pos].is_ascii_whitespace() {
                pos += 1;
            }
        }
        let start = pos;
        while pos < buf.len() && !buf[pos].is_ascii_whitespace() {
            pos += 1;
        }
        Ok(String::from_utf8_lossy(&buf[start..pos]).into_owned())
    };
    if token()? != "P5" {
        bail!("not a binary PGM");
    }
    let width: usize = token()?.parse()?;
    let height: usize = token()?.parse()?;
    let maxval: usize = token()?.parse()?;
    if maxval != 255 {
        bail!("only 8-bit PGM supported");
    }
    pos += 1; // single whitespace after maxval
    let data = buf[pos..pos + width * height].to_vec();
    Ok(Image { width, height, data })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    #[test]
    fn roundtrip() {
        let img = generate(Scene::Shapes, 32, 9);
        let dir = std::env::temp_dir().join("simdive_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pgm");
        write_pgm(&img, &path).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(img, back);
    }
}
