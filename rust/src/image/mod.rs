//! Image-processing substrate for the paper's §4.3 applications (Figs.
//! 3–4): multiply-based image blending and Gaussian smoothing, each with a
//! pluggable multiplier/divider so accurate, SIMDive, MBM and INZeD
//! variants run the *same* code path.
//!
//! USC-SIPI is not reachable offline; [`synth`] generates deterministic
//! photographic-statistics test images instead (DESIGN.md §1).

pub mod ops;
pub mod pgm;
pub mod synth;

pub use ops::{blend, gaussian_smooth, ArithKind};

/// An 8-bit grayscale image.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub data: Vec<u8>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Self {
        Image { width, height, data: vec![0; width * height] }
    }

    #[inline]
    pub fn at(&self, x: usize, y: usize) -> u8 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: u8) {
        self.data[y * self.width + x] = v;
    }

    /// Clamped accessor (edge replication) for convolution borders.
    #[inline]
    pub fn at_clamped(&self, x: isize, y: isize) -> u8 {
        let xc = x.clamp(0, self.width as isize - 1) as usize;
        let yc = y.clamp(0, self.height as isize - 1) as usize;
        self.at(xc, yc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamped_access_replicates_edges() {
        let mut img = Image::new(4, 4);
        img.set(0, 0, 9);
        img.set(3, 3, 7);
        assert_eq!(img.at_clamped(-2, -2), 9);
        assert_eq!(img.at_clamped(5, 5), 7);
    }
}
