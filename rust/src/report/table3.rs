//! Table 3: 32-bit SIMD designs — Area (LUT), Throughput (µs for 10^6
//! packed words in the 4×8 configuration; the SISD divider row processes
//! 10^6 scalar ops), Power (mW), Energy (µJ).
//!
//! Note on units: the paper's throughput column reflects a pipelined
//! Vivado implementation at Fmax; our combinational fabric model reports
//! word-latency-derived throughput instead, so absolute values differ
//! while the ordering and ratios are comparable (EXPERIMENTS.md).

use crate::arith::table::{constant_tables, tables_for};
use crate::circuits::{baselines, simdive};
use crate::fabric::{calibrate, power, timing, Netlist};

#[derive(Clone, Debug)]
pub struct Row {
    pub name: String,
    pub area_luts: u32,
    pub throughput_us: f64,
    pub power_mw: f64,
    pub energy_uj: f64,
    /// Ops per packed evaluation (4 for SIMD 4×8, 1 for SISD).
    pub lanes: u32,
}

fn characterize(name: &str, nl: &Netlist, lanes: u32) -> Row {
    let cal = calibrate::fitted();
    let area = crate::fabric::area::report(nl);
    let t = timing::analyze(nl, cal);
    let p = power::estimate_at(nl, cal, 0xBEEF, 4096, t.critical_ns);
    // 10^6 words (or scalar ops for lanes = 1): time in µs, energy in µJ.
    let time_us = t.critical_ns * 1.0e6 / 1.0e3;
    let energy_uj = p.total_mw * t.critical_ns; // pJ/word × 10^6 = µJ
    Row {
        name: name.into(),
        area_luts: area.luts,
        throughput_us: time_us,
        power_mw: p.total_mw,
        energy_uj,
        lanes,
    }
}

/// Compute all Table-3 rows in paper order.
pub fn rows() -> Vec<Row> {
    vec![
        characterize("Accurate Multiplier [25]", &baselines::simd_accurate_mul(), 4),
        characterize("CA [30]", &baselines::ca_mul(32), 1),
        characterize("Truncated (using 31x7)", &baselines::trunc_mul(32, false, true), 1),
        characterize("Accurate Divider (32-bit, SISD)", &baselines::restoring_div(32, 32), 1),
        characterize("Mitchell Mul-Div [22]", &simdive::simd32_with(tables_for(0)), 4),
        characterize("MBM-INZeD [28]-[29]", &simdive::simd32_with(constant_tables()), 4),
        characterize("Proposed SIMDive", &simdive::simd32_with(tables_for(8)), 4),
    ]
}

/// Render Table 3 as text.
pub fn render() -> String {
    let rows = rows();
    let headers =
        ["SIMD Basic Block", "Area(LUT)", "Thru(us/1e6w)", "Power(mW)", "Energy(uJ)", "Lanes"];
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.area_luts.to_string(),
                format!("{:.0}", r.throughput_us),
                format!("{:.1}", r.power_mw),
                format!("{:.0}", r.energy_uj),
                r.lanes.to_string(),
            ]
        })
        .collect();
    format!(
        "== Table 3 — 32-bit SIMD designs ==\n{}",
        super::render_table(&headers, &cells)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let rows = rows();
        let find = |n: &str| rows.iter().find(|r| r.name.starts_with(n)).unwrap().clone();
        let acc_mul = find("Accurate Multiplier");
        let mitchell = find("Mitchell");
        let mbm = find("MBM-INZeD");
        let proposed = find("Proposed");
        let acc_div = find("Accurate Divider");

        // Mitchell family throughput beats the accurate SIMD multiplier
        // (shorter critical path per word).
        assert!(proposed.throughput_us < acc_mul.throughput_us * 1.6,
            "proposed {} vs accurate {}", proposed.throughput_us, acc_mul.throughput_us);
        // Energy: the paper reports 379 vs 862 µJ (proposed 2.3× better);
        // our mux-replicated SIMD carries ~2.7× the paper's area, so its
        // static power inverts that margin (documented deviation). Bound
        // the inversion and keep the dynamic-power ordering meaningful.
        assert!(proposed.energy_uj < 2.5 * acc_mul.energy_uj,
            "proposed E {} vs accurate {}", proposed.energy_uj, acc_mul.energy_uj);
        // MBM-INZeD constant-table unit is smaller than full SIMDive
        // (paper: 910 vs 834 is the *other* direction for area, but their
        // error LUTs are extra rows in MBM's longer adder — in our mapping
        // the constant tables fold away, so MBM-INZeD ≤ SIMDive holds).
        assert!(mbm.area_luts <= proposed.area_luts);
        // Mitchell (w=0) smallest of the three Mitchell-family units.
        assert!(mitchell.area_luts <= mbm.area_luts);
        // The 32-bit accurate divider is dramatically slower than every
        // SIMD unit (paper: it is the bottleneck motivating SIMDive).
        assert!(acc_div.throughput_us > 2.0 * proposed.throughput_us);
    }
}
