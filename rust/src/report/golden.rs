//! Golden-vector export: pins the Python layers (Pallas kernel and jnp
//! oracle) to the Rust behavioral models. Format: one line per case,
//! `a b result`, plus a JSON-ish manifest of the correction tables.

use crate::arith::{simdive, table};
use crate::util::Rng;
use std::fmt::Write as _;

/// Write golden vectors + tables into `artifacts/golden/`.
pub fn export() -> anyhow::Result<String> {
    let dir = super::artifacts_dir().join("golden");
    let mut count = 0usize;

    for bits in [8u32, 16, 32] {
        for w in [0u32, 8] {
            let mut rng = Rng::new(0x601D + bits as u64 + w as u64);
            let mut mul_txt = String::new();
            let mut div_txt = String::new();
            // Edge cases + random.
            let mut cases: Vec<(u64, u64)> = vec![
                (0, 0),
                (0, 1),
                (1, 0),
                (1, 1),
                (crate::arith::max_val(bits), crate::arith::max_val(bits)),
                (crate::arith::max_val(bits), 1),
                (1, crate::arith::max_val(bits)),
                (43.min(crate::arith::max_val(bits)), 10),
            ];
            for _ in 0..2000 {
                cases.push((rng.below(1 << bits.min(63)), rng.below(1 << bits.min(63))));
            }
            for &(a, b) in &cases {
                writeln!(mul_txt, "{a} {b} {}", simdive::simdive_mul_w(bits, a, b, w)).ok();
                writeln!(div_txt, "{a} {b} {}", simdive::simdive_div_w(bits, a, b, w)).ok();
                count += 2;
            }
            std::fs::write(dir.join(format!("mul_{bits}_w{w}.txt")), mul_txt)?;
            std::fs::write(dir.join(format!("div_{bits}_w{w}.txt")), div_txt)?;
        }
    }

    // Correction tables at full resolution (signed fixed-point 2^-12).
    let t = table::tables_for(8);
    let mut tbl = String::from("# op i j coeff_fixed12\n");
    for i in 0..8 {
        for j in 0..8 {
            writeln!(tbl, "mul {i} {j} {}", t.mul[i][j]).ok();
        }
    }
    for i in 0..8 {
        for j in 0..8 {
            writeln!(tbl, "div {i} {j} {}", t.div[i][j]).ok();
        }
    }
    std::fs::write(dir.join("tables_w8.txt"), tbl)?;
    Ok(format!("exported {count} golden cases + tables to {}", dir.display()))
}

#[cfg(test)]
mod tests {
    #[test]
    fn export_writes_files() {
        std::env::set_var("SIMDIVE_ARTIFACTS", std::env::temp_dir().join("simdive_golden"));
        let msg = super::export().unwrap();
        assert!(msg.contains("exported"));
        let dir = std::env::temp_dir().join("simdive_golden/golden");
        assert!(dir.join("mul_8_w8.txt").exists());
        assert!(dir.join("tables_w8.txt").exists());
        std::env::remove_var("SIMDIVE_ARTIFACTS");
    }
}
