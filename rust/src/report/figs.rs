//! Figures 1, 3 and 4.
//!
//! * Fig. 1 — Mitchell error heat maps: per-(a,b) relative error of the
//!   8-bit multiplier and divider (exhaustive), written as CSV grids plus
//!   the per-power-of-two "top view" profile. The shapes (max 11.1% mul,
//!   ≈12.5% div, proportional replication per octave) are the paper's
//!   motivation for the 64-region correction.
//! * Fig. 3 — image blending PSNR (vs the accurate-multiplier result).
//! * Fig. 4 — Gaussian smoothing PSNR (vs the noise-free original), in
//!   div-only and hybrid modes.

use crate::arith::{mitchell, DivDesign, MulDesign};
use crate::image::synth::{add_gaussian_noise, generate, Scene};
use crate::image::{blend, gaussian_smooth, pgm, ArithKind};
use crate::metrics::psnr;
use std::fmt::Write as _;

/// Fig. 1: write the error heat maps; returns a summary string.
pub fn fig1() -> anyhow::Result<String> {
    let dir = super::artifacts_dir().join("figures");
    let mut mul_csv = String::from("a,b,rel_err\n");
    let mut div_csv = String::from("a,b,rel_err\n");
    let (mut mul_max, mut div_max) = (0.0f64, 0.0f64);
    for a in 1..256u64 {
        for b in 1..256u64 {
            let em = (a as f64 * b as f64 - mitchell::mul_real(8, a, b)).abs()
                / (a as f64 * b as f64);
            let ed = (a as f64 / b as f64 - mitchell::div_real(8, a, b)).abs()
                / (a as f64 / b as f64);
            mul_max = mul_max.max(em);
            div_max = div_max.max(ed);
            writeln!(mul_csv, "{a},{b},{em:.6}").ok();
            writeln!(div_csv, "{a},{b},{ed:.6}").ok();
        }
    }
    std::fs::write(dir.join("fig1_mul_heatmap.csv"), &mul_csv)?;
    std::fs::write(dir.join("fig1_div_heatmap.csv"), &div_csv)?;

    // Top view: mean relative error per fraction-region (8×8), averaged
    // over octaves — demonstrates the per-power-of-two replication.
    let mut top = String::from("op,i,j,mean_rel_err\n");
    for is_div in [false, true] {
        let op = if is_div { "div" } else { "mul" };
        let mut sums = [[0.0f64; 8]; 8];
        let mut counts = [[0u64; 8]; 8];
        for a in 1..256u64 {
            for b in 1..256u64 {
                let (_, fa) =
                    crate::arith::frac_aligned(8, std::num::NonZeroU64::new(a).expect("a >= 1"));
                let (_, fb) =
                    crate::arith::frac_aligned(8, std::num::NonZeroU64::new(b).expect("b >= 1"));
                let (i, j) = ((fa >> 4) as usize, (fb >> 4) as usize);
                let e = if is_div {
                    (a as f64 / b as f64 - mitchell::div_real(8, a, b)).abs()
                        / (a as f64 / b as f64)
                } else {
                    (a as f64 * b as f64 - mitchell::mul_real(8, a, b)).abs()
                        / (a as f64 * b as f64)
                };
                sums[i][j] += e;
                counts[i][j] += 1;
            }
        }
        for i in 0..8 {
            for j in 0..8 {
                writeln!(top, "{op},{i},{j},{:.6}", sums[i][j] / counts[i][j].max(1) as f64).ok();
            }
        }
    }
    std::fs::write(dir.join("fig1_topview.csv"), &top)?;
    Ok(format!(
        "Fig.1: Mitchell 8-bit peak rel. error — mul {:.2}% (theory 11.11%), div {:.2}% (theory ≈12.5%)\n\
         CSVs: artifacts/figures/fig1_{{mul,div}}_heatmap.csv, fig1_topview.csv",
        mul_max * 100.0,
        div_max * 100.0
    ))
}

/// Fig. 3: blending PSNR per scene — SIMDive vs MBM (vs accurate result).
pub fn fig3() -> anyhow::Result<String> {
    let dir = super::artifacts_dir().join("figures");
    let mut out = String::from("== Fig. 3 — multiply-blend PSNR vs accurate result (dB) ==\n");
    let mut rows = Vec::new();
    let (mut sum_sd, mut sum_mbm) = (0.0, 0.0);
    for (i, scene) in Scene::ALL.iter().enumerate() {
        let a = generate(*scene, 256, 100 + i as u64);
        let b = generate(Scene::ALL[(i + 1) % 4], 256, 200 + i as u64);
        let acc = blend(&a, &b, ArithKind::Accurate);
        let sd = blend(&a, &b, ArithKind::Simdive(8));
        let mbm = blend(&a, &b, ArithKind::MbmInzed);
        let p_sd = psnr(&acc.data, &sd.data);
        let p_mbm = psnr(&acc.data, &mbm.data);
        sum_sd += p_sd;
        sum_mbm += p_mbm;
        rows.push(vec![
            format!("{scene:?}"),
            format!("{p_sd:.1}"),
            format!("{p_mbm:.1}"),
        ]);
        if i == 0 {
            pgm::write_pgm(&acc, &dir.join("fig3_accurate.pgm"))?;
            pgm::write_pgm(&sd, &dir.join("fig3_simdive.pgm"))?;
            pgm::write_pgm(&mbm, &dir.join("fig3_mbm.pgm"))?;
        }
    }
    out += &super::render_table(&["Scene", "SIMDive", "MBM [28]"], &rows);
    out += &format!(
        "Average: SIMDive {:.1} dB vs MBM {:.1} dB (paper: 46.6 vs 32.1)\n",
        sum_sd / 4.0,
        sum_mbm / 4.0
    );
    Ok(out)
}

/// Fig. 4: Gaussian smoothing PSNR vs the noise-free original.
pub fn fig4() -> anyhow::Result<String> {
    let dir = super::artifacts_dir().join("figures");
    let mut out =
        String::from("== Fig. 4 — Gaussian smoothing PSNR vs noise-free original (dB) ==\n");
    let mut rows = Vec::new();
    let (mut s_sd_div, mut s_soa_div, mut s_sd_hyb, mut s_soa_hyb) = (0.0, 0.0, 0.0, 0.0);
    for (i, scene) in Scene::ALL.iter().enumerate() {
        let clean = generate(*scene, 256, 300 + i as u64);
        let noisy = add_gaussian_noise(&clean, 18.0, 400 + i as u64);
        let p = |img: &crate::image::Image| psnr(&clean.data, &img.data);
        let sd_div = p(&gaussian_smooth(&noisy, ArithKind::Simdive(8), false));
        let soa_div = p(&gaussian_smooth(&noisy, ArithKind::MbmInzed, false));
        let sd_hyb = p(&gaussian_smooth(&noisy, ArithKind::Simdive(8), true));
        let soa_hyb = p(&gaussian_smooth(&noisy, ArithKind::MbmInzed, true));
        s_sd_div += sd_div;
        s_soa_div += soa_div;
        s_sd_hyb += sd_hyb;
        s_soa_hyb += soa_hyb;
        rows.push(vec![
            format!("{scene:?}"),
            format!("{sd_div:.1}"),
            format!("{soa_div:.1}"),
            format!("{sd_hyb:.1}"),
            format!("{soa_hyb:.1}"),
        ]);
        if i == 0 {
            pgm::write_pgm(&noisy, &dir.join("fig4_noisy.pgm"))?;
            pgm::write_pgm(
                &gaussian_smooth(&noisy, ArithKind::Simdive(8), true),
                &dir.join("fig4_simdive_hybrid.pgm"),
            )?;
            pgm::write_pgm(
                &gaussian_smooth(&noisy, ArithKind::MbmInzed, true),
                &dir.join("fig4_mbm_inzed_hybrid.pgm"),
            )?;
        }
    }
    out += &super::render_table(
        &["Scene", "SIMDive div", "INZeD div", "SIMDive hyb", "MBM/INZeD hyb"],
        &rows,
    );
    out += &format!(
        "Averages: div-only SIMDive {:.1} vs INZeD {:.1} (paper 24.5 vs 20.9); \
         hybrid SIMDive {:.1} vs MBM/INZeD {:.1} (paper 23.3 vs 21.3)\n",
        s_sd_div / 4.0,
        s_soa_div / 4.0,
        s_sd_hyb / 4.0,
        s_soa_hyb / 4.0
    );
    Ok(out)
}

/// Convenience: error stats used by the figure tests.
pub fn headline_errors() -> (f64, f64) {
    let m = crate::metrics::mul_error(MulDesign::Simdive { w: 8 }, 16, 200_000, 1);
    let d = crate::metrics::div_error(DivDesign::Simdive { w: 8 }, 16, 8, 200_000, 1);
    (m.are_pct, d.are_pct)
}

#[cfg(test)]
mod tests {
    #[test]
    fn headline_sub_one_percent() {
        let (m, d) = super::headline_errors();
        assert!(m < 1.1, "mul ARE {m}");
        assert!(d < 1.3, "div ARE {d}");
    }
}
