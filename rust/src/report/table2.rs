//! Table 2: design metrics of SISD 16×16 multipliers and 16/8 dividers —
//! Area (6-LUT), Delay (ns), Power (mW), Energy (µJ for 10^6 ops), ARE,
//! PRE, and CF = A·E·D/(1−NED) normalized to the accurate design.

use crate::arith::{DivDesign, MulDesign};
use crate::circuits::{baselines, mitchell, simdive};
use crate::fabric::{calibrate, power, timing, Netlist};
use crate::metrics::{self, div_error, mul_error, ErrorReport};

/// One row of Table 2.
#[derive(Clone, Debug)]
pub struct Row {
    pub name: String,
    pub area_luts: u32,
    pub delay_ns: f64,
    pub power_mw: f64,
    pub energy_uj: f64,
    pub err: ErrorReport,
    pub cf: f64,
}

fn characterize(name: &str, nl: &Netlist, err: ErrorReport, seed: u64) -> Row {
    let cal = calibrate::fitted();
    let area = crate::fabric::area::report(nl);
    let t = timing::analyze(nl, cal);
    let p = power::estimate_at(nl, cal, seed, 4096, t.critical_ns);
    // Energy for 10^6 operations: mW × ns = pJ/op → µJ per 10^6 ops.
    let energy_uj = p.total_mw * t.critical_ns;
    Row {
        name: name.into(),
        area_luts: area.luts,
        delay_ns: t.critical_ns,
        power_mw: p.total_mw,
        energy_uj,
        err,
        cf: 0.0, // filled after normalization
    }
}

/// Error-evaluation sample count (paper: 10^6 uniform inputs).
pub const ERROR_SAMPLES: u64 = 1_000_000;

/// Compute all Table-2 rows (multipliers, dividers, integrated unit).
pub fn rows(samples: u64) -> (Vec<Row>, Vec<Row>, Row) {
    let seed = 0xF00D;
    // --- multipliers (16×16) ---
    let mut muls = vec![
        characterize(
            "Accurate IP [36]",
            &baselines::array_mul(16),
            ErrorReport::default(),
            seed,
        ),
        characterize(
            "CA [30]",
            &baselines::ca_mul(16),
            mul_error(MulDesign::Ca, 16, samples, 1),
            seed,
        ),
        characterize(
            "Trunc (four 7x7)",
            &baselines::trunc_mul(16, true, true),
            mul_error(MulDesign::TruncFour7x7, 16, samples, 2),
            seed,
        ),
        characterize(
            "Trunc (two 15x7)",
            &baselines::trunc_mul(16, false, true),
            mul_error(MulDesign::TruncTwo15x7, 16, samples, 3),
            seed,
        ),
        characterize(
            "Mitchell [22]",
            &mitchell::mul(16),
            mul_error(MulDesign::Mitchell, 16, samples, 4),
            seed,
        ),
        characterize(
            "MBM [28]",
            &baselines::mbm_mul(16),
            mul_error(MulDesign::Mbm, 16, samples, 5),
            seed,
        ),
        characterize(
            "Proposed",
            &simdive::mul(16, 8),
            mul_error(MulDesign::Simdive { w: 8 }, 16, samples, 6),
            seed,
        ),
    ];
    // --- dividers (16/8) ---
    let mut divs = vec![
        characterize(
            "Accurate IP [37]",
            &baselines::restoring_div(16, 8),
            ErrorReport::default(),
            seed,
        ),
        characterize(
            "AAXD (12/6) [13]",
            &baselines::aaxd_div(16, 8, 12, 6),
            div_error(DivDesign::Aaxd { m: 12, n: 6 }, 16, 8, samples, 7),
            seed,
        ),
        characterize(
            "AAXD (8/4) [13]",
            &baselines::aaxd_div(16, 8, 8, 4),
            div_error(DivDesign::Aaxd { m: 8, n: 4 }, 16, 8, samples, 8),
            seed,
        ),
        characterize(
            "Mitchell [22]",
            &mitchell::div(16, 8),
            div_error(DivDesign::Mitchell, 16, 8, samples, 9),
            seed,
        ),
        characterize(
            "INZeD [29]",
            &baselines::inzed_div(16, 8),
            div_error(DivDesign::Inzed, 16, 8, samples, 10),
            seed,
        ),
        characterize(
            "Proposed",
            &simdive::div(16, 8, 8),
            div_error(DivDesign::Simdive { w: 8 }, 16, 8, samples, 11),
            seed,
        ),
    ];
    // --- integrated hybrid mul-div ---
    let hybrid = characterize(
        "Proposed Integrated Mul-Div",
        &simdive::hybrid(16, 8),
        mul_error(MulDesign::Simdive { w: 8 }, 16, samples, 12),
        seed,
    );

    // CF normalization against each group's accurate row.
    let norm = |rows: &mut [Row]| {
        let acc = metrics::cost_function(
            rows[0].area_luts as f64,
            rows[0].energy_uj,
            rows[0].delay_ns,
            0.0,
        );
        for r in rows.iter_mut() {
            r.cf = metrics::cost_function(
                r.area_luts as f64,
                r.energy_uj,
                r.delay_ns,
                r.err.ned,
            ) / acc;
        }
    };
    norm(&mut muls);
    norm(&mut divs);
    let mut hybrid = hybrid;
    hybrid.cf = metrics::cost_function(
        hybrid.area_luts as f64,
        hybrid.energy_uj,
        hybrid.delay_ns,
        hybrid.err.ned,
    ) / metrics::cost_function(
        muls[0].area_luts as f64,
        muls[0].energy_uj,
        muls[0].delay_ns,
        0.0,
    );
    (muls, divs, hybrid)
}

/// Render Table 2 as text.
pub fn render(samples: u64) -> String {
    let (muls, divs, hybrid) = rows(samples);
    let to_cells = |r: &Row| {
        vec![
            r.name.clone(),
            r.area_luts.to_string(),
            format!("{:.1}", r.delay_ns),
            format!("{:.1}", r.power_mw),
            format!("{:.0}", r.energy_uj),
            if r.err.are_pct == 0.0 && r.name.contains("Accurate") {
                "-".into()
            } else {
                format!("{:.2}", r.err.are_pct)
            },
            if r.err.pre_pct == 0.0 && r.name.contains("Accurate") {
                "-".into()
            } else {
                format!("{:.2}", r.err.pre_pct)
            },
            format!("{:.2}", r.cf),
        ]
    };
    let headers = [
        "SISD Circuit",
        "Area(6-LUT)",
        "Delay(ns)",
        "Power(mW)",
        "Energy(uJ)",
        "ARE(%)",
        "PRE(%)",
        "CF",
    ];
    let mut out = String::from("== Table 2 — SISD multipliers (16x16) ==\n");
    out += &super::render_table(&headers, &muls.iter().map(to_cells).collect::<Vec<_>>());
    out += "\n== Table 2 — SISD dividers (16/8) ==\n";
    out += &super::render_table(&headers, &divs.iter().map(to_cells).collect::<Vec<_>>());
    out += "\n== Table 2 — integrated unit ==\n";
    out += &super::render_table(&headers, &[to_cells(&hybrid)]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        // Small sample count for test speed; orderings are robust.
        let (muls, divs, hybrid) = rows(60_000);
        let find = |rows: &[Row], n: &str| -> Row {
            rows.iter().find(|r| r.name.starts_with(n)).unwrap().clone()
        };
        let acc_m = find(&muls, "Accurate");
        let mit_m = find(&muls, "Mitchell");
        let prop_m = find(&muls, "Proposed");
        // Mitchell-family faster than accurate; area parity within ~10%
        // (the paper's 174-vs-287 LUT gap needs Vivado-level mux packing
        // our structural mapper does not perform — EXPERIMENTS.md).
        assert!((mit_m.area_luts as f64) < acc_m.area_luts as f64 * 1.15,
            "mitchell {} vs accurate {}", mit_m.area_luts, acc_m.area_luts);
        assert!(prop_m.delay_ns < acc_m.delay_ns);
        // Proposed: best ARE of the Mitchell family; CF < 1.
        assert!(prop_m.err.are_pct < mit_m.err.are_pct);
        assert!(prop_m.cf < 1.0, "CF {}", prop_m.cf);

        let acc_d = find(&divs, "Accurate");
        let prop_d = find(&divs, "Proposed");
        // Headline: proposed divider ≈4× faster, big energy gain.
        let speedup = acc_d.delay_ns / prop_d.delay_ns;
        assert!(speedup > 2.0, "div speedup {speedup}");
        let egain = acc_d.energy_uj / prop_d.energy_uj;
        assert!(egain > 2.0, "div energy gain {egain}");
        // Integrated unit ≈ the two separate accurate IPs combined (the
        // paper's stronger 268-vs-455 margin needs Vivado-level packing of
        // the dual decoders; ours lands within ~10% of the combined IPs,
        // still far below two separate SIMDive-class units).
        assert!(
            (hybrid.area_luts as f64)
                < (acc_m.area_luts + acc_d.area_luts) as f64 * 1.15,
            "hybrid {} vs {}",
            hybrid.area_luts,
            acc_m.area_luts + acc_d.area_luts
        );
    }
}
