//! `netlist-check` sweep: static-analysis cost report over every
//! generated design (DESIGN.md §14).
//!
//! Each design is linted ([`crate::fabric::analyze::lint`]) and
//! characterized with the same area/timing/power models Tables 2–3 use,
//! plus the cone/depth and critical-path passes. `to_json` renders the
//! append-only `BENCH_fabric.json` artifact (schema `simdive-fabric-v1`)
//! CI commits alongside `BENCH_hotpath.json` / `BENCH_serve.json`, so
//! every future netlist rewrite (ROADMAP item 4) diffs against a pinned
//! baseline.

use crate::circuits::{baselines, BuiltCircuit, CircuitKind};
use crate::fabric::{analyze, area, power, timing::Calibration};

/// Paper-reported figure for designs the paper's Table 2 characterizes at
/// the 16-bit operating point (LUTs only where the paper gives an area).
#[derive(Clone, Copy, Debug)]
pub struct PaperRef {
    pub luts: Option<f64>,
    pub delay_ns: f64,
    pub power_mw: f64,
}

/// Table-2 reference row for a design name, where one exists.
pub fn paper_ref(name: &str) -> Option<PaperRef> {
    match name {
        "accurate_mul_16" => Some(PaperRef { luts: Some(287.0), delay_ns: 6.4, power_mw: 47.8 }),
        "accurate_div_16_8" => Some(PaperRef { luts: Some(168.0), delay_ns: 21.4, power_mw: 24.6 }),
        "mitchell_mul_16" => Some(PaperRef { luts: None, delay_ns: 4.7, power_mw: 35.5 }),
        "mitchell_div_16_8" => Some(PaperRef { luts: None, delay_ns: 5.3, power_mw: 20.3 }),
        _ => None,
    }
}

/// One design's static-analysis + model figures.
#[derive(Clone, Debug)]
pub struct DesignRow {
    pub name: String,
    pub bits: u32,
    pub luts: u32,
    pub carry4: u32,
    pub slices: u32,
    pub max_depth: u32,
    pub max_cone_luts: u32,
    pub max_cone_carry4: u32,
    pub critical_ns: f64,
    /// Cells on the extracted critical path (CARRY4 blocks collapsed).
    pub critical_path_cells: usize,
    pub power_mw: f64,
    pub energy_pj: f64,
    pub lint_errors: usize,
    pub lint_warnings: usize,
    pub paper: Option<PaperRef>,
}

/// Every generated design at one operand width — the Tables 2–3 catalog
/// plus the 32-bit SIMD units where the width admits them.
pub fn all_designs(bits: u32) -> Vec<BuiltCircuit> {
    let db = bits / 2;
    let mut kinds = vec![
        CircuitKind::AccurateMul,
        CircuitKind::AccurateDiv { divisor_bits: db },
        CircuitKind::MitchellMul,
        CircuitKind::MitchellDiv { divisor_bits: db },
        CircuitKind::MbmMul,
        CircuitKind::InzedDiv { divisor_bits: db },
        CircuitKind::CaMul,
        CircuitKind::TruncMul { seven_a: true, seven_b: true },
        CircuitKind::TruncMul { seven_a: false, seven_b: true },
        CircuitKind::SimdiveMul { w: 8 },
        CircuitKind::SimdiveDiv { divisor_bits: db, w: 8 },
        CircuitKind::SimdiveHybrid { w: 8 },
    ];
    // AAXD keep-widths follow the paper's configurations per operand size.
    match bits {
        8 => kinds.push(CircuitKind::AaxdDiv { divisor_bits: db, m: 6, n: 3 }),
        16 => {
            kinds.push(CircuitKind::AaxdDiv { divisor_bits: db, m: 12, n: 6 });
            kinds.push(CircuitKind::AaxdDiv { divisor_bits: db, m: 8, n: 4 });
        }
        _ => kinds.push(CircuitKind::AaxdDiv { divisor_bits: db, m: 24, n: 12 }),
    }
    let mut designs: Vec<BuiltCircuit> = kinds.iter().map(|k| k.build(bits)).collect();
    if bits == 32 {
        designs.push(CircuitKind::SimdiveSimd32 { w: 8 }.build(bits));
        designs.push(BuiltCircuit {
            name: "simd_accurate_mul_32".into(),
            netlist: baselines::simd_accurate_mul(),
        });
    }
    designs
}

/// True when `name` matches the CLI `--design` filter ("mul" / "div" /
/// "all"); the hybrid and SIMD units contain both datapaths and match
/// either filter.
fn matches_filter(name: &str, filter: &str) -> bool {
    match filter {
        "all" => true,
        // Anchor on the "_mul"/"_div" name segment — "simdive" itself
        // contains "div", so a bare substring match would be wrong.
        f => {
            name.contains(&format!("_{f}")) || name.contains("hybrid") || name.contains("simd32")
        }
    }
}

/// Lint + characterize every design at each width, filtered by
/// `--design`.
pub fn sweep(bits_list: &[u32], filter: &str, cal: &Calibration) -> Vec<DesignRow> {
    let mut rows = Vec::new();
    for &bits in bits_list {
        for bc in all_designs(bits) {
            if !matches_filter(&bc.name, filter) {
                continue;
            }
            let nl = &bc.netlist;
            let lint = analyze::lint(nl);
            let a = area::report(nl);
            let cones = analyze::cones(nl);
            let path = analyze::critical_path(nl, cal);
            let p = power::estimate_at(nl, cal, 0xF00D, power::DEFAULT_VECTORS, path.critical_ns);
            rows.push(DesignRow {
                name: bc.name.clone(),
                bits,
                luts: a.luts,
                carry4: a.carry4,
                slices: a.slices,
                max_depth: cones.max_depth,
                max_cone_luts: cones.max_cone_luts,
                max_cone_carry4: cones.max_cone_carry4,
                critical_ns: path.critical_ns,
                critical_path_cells: path.steps.len(),
                power_mw: p.total_mw,
                energy_pj: p.total_mw * path.critical_ns,
                lint_errors: lint.error_count(),
                lint_warnings: lint.warning_count(),
                paper: paper_ref(&bc.name),
            });
        }
    }
    rows
}

/// Aligned text table over the sweep rows.
pub fn render(rows: &[DesignRow]) -> String {
    let headers = [
        "design", "bits", "LUTs", "CARRY4", "depth", "cone", "crit(ns)", "cells", "P(mW)",
        "E(pJ)", "err", "warn", "paper(ns)",
    ];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.bits.to_string(),
                r.luts.to_string(),
                r.carry4.to_string(),
                r.max_depth.to_string(),
                r.max_cone_luts.to_string(),
                format!("{:.2}", r.critical_ns),
                r.critical_path_cells.to_string(),
                format!("{:.1}", r.power_mw),
                format!("{:.1}", r.energy_pj),
                r.lint_errors.to_string(),
                r.lint_warnings.to_string(),
                r.paper.map_or_else(|| "-".into(), |p| format!("{:.1}", p.delay_ns)),
            ]
        })
        .collect();
    super::render_table(&headers, &body)
}

/// `BENCH_fabric.json` (schema `simdive-fabric-v1`). Append-only: fields
/// may be added in later schema revisions, never renamed or removed.
pub fn to_json(rows: &[DesignRow]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"simdive-fabric-v1\",\n  \"designs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"name\": \"{}\", \"bits\": {}, \"luts\": {}, \"carry4\": {}, \
             \"slices\": {}, \"max_depth\": {}, \"max_cone_luts\": {}, \
             \"max_cone_carry4\": {}, \"critical_ns\": {:.4}, \
             \"critical_path_cells\": {}, \"power_mw\": {:.3}, \"energy_pj\": {:.3}, \
             \"lint_errors\": {}, \"lint_warnings\": {}",
            r.name,
            r.bits,
            r.luts,
            r.carry4,
            r.slices,
            r.max_depth,
            r.max_cone_luts,
            r.max_cone_carry4,
            r.critical_ns,
            r.critical_path_cells,
            r.power_mw,
            r.energy_pj,
            r.lint_errors,
            r.lint_warnings,
        );
        if let Some(p) = r.paper {
            s.push_str(", \"paper\": {");
            if let Some(l) = p.luts {
                let _ = write!(s, "\"luts\": {l:.1}, ");
            }
            let _ = write!(s, "\"delay_ns\": {:.1}, \"power_mw\": {:.1}}}", p.delay_ns, p.power_mw);
        }
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_8bit_is_clean_and_filtered() {
        let cal = Calibration::default();
        let all = sweep(&[8], "all", &cal);
        assert!(all.len() >= 13, "8-bit catalog has {} designs", all.len());
        for r in &all {
            assert_eq!(r.lint_errors, 0, "{} has lint errors", r.name);
            assert!(r.luts > 0 && r.critical_ns > 0.0, "{} not characterized", r.name);
        }
        let muls = sweep(&[8], "mul", &cal);
        assert!(muls.len() < all.len());
        assert!(muls.iter().all(|r| r.name.contains("mul") || r.name.contains("hybrid")));
    }

    #[test]
    fn json_has_schema_and_paper_refs() {
        let cal = Calibration::default();
        let rows = sweep(&[16], "div", &cal);
        let json = to_json(&rows);
        assert!(json.contains("\"schema\": \"simdive-fabric-v1\""));
        assert!(json.contains("accurate_div_16_8"));
        assert!(json.contains("\"delay_ns\": 21.4"));
    }
}
