//! Table 4: ANN classification accuracy with accurate / approximate
//! multipliers — digits & fashion datasets × {2, 3} hidden layers ×
//! {double precision, 8-bit accurate, SIMDive, MBM}, plus multiplier
//! area/energy normalized to the 8-bit accurate design.

use crate::ann::{Mlp, QuantMlp};
use crate::arith::MulDesign;
use crate::circuits::{baselines, simdive};
use crate::datasets::{generate, Family};
use crate::engine::Engine;
use crate::fabric::{calibrate, power, timing};

#[derive(Clone, Debug)]
pub struct Row {
    pub dataset: &'static str,
    pub hidden_layers: usize,
    pub nodes: usize,
    pub acc_double: f64,
    pub acc_q8_accurate: f64,
    pub acc_q8_simdive: f64,
    pub acc_q8_mbm: f64,
}

/// Experiment scale (paper: 60k train / 10k test; scaled for runtime).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub train: usize,
    pub test: usize,
    pub epochs: usize,
    pub nodes: usize,
}

impl Default for Scale {
    fn default() -> Self {
        Scale { train: 6000, test: 1000, epochs: 7, nodes: 100 }
    }
}

fn run_config(family: Family, name: &'static str, layers: usize, scale: Scale) -> Row {
    let train = generate(family, scale.train, 60_000 + layers as u64);
    let test = generate(family, scale.test, 10_000 + layers as u64);
    let hidden = vec![scale.nodes; layers];
    let mut net = Mlp::new(&hidden, 42 + layers as u64);
    // Per-sample SGD: deeper stacks need a smaller step to stay stable.
    let lr = if layers >= 3 { 0.02 } else { 0.04 };
    net.train(&train, scale.epochs, lr, 77);
    let q = QuantMlp::from_float(&net, &train[..scale.train.min(500)]);
    // Each design runs through one batched engine handle (the seam the
    // serving path uses too — DESIGN.md §10).
    let eval = |d: MulDesign| q.accuracy(&test, &Engine::from_mul(d)) * 100.0;
    Row {
        dataset: name,
        hidden_layers: layers,
        nodes: scale.nodes,
        acc_double: net.accuracy(&test) * 100.0,
        acc_q8_accurate: eval(MulDesign::Accurate),
        acc_q8_simdive: eval(MulDesign::Simdive { w: 8 }),
        acc_q8_mbm: eval(MulDesign::Mbm),
    }
}

/// All four Table-4 rows.
pub fn rows(scale: Scale) -> Vec<Row> {
    let mut out = Vec::new();
    for layers in [2usize, 3] {
        out.push(run_config(Family::Digits, "Digits", layers, scale));
    }
    for layers in [2usize, 3] {
        out.push(run_config(Family::Fashion, "Fashion", layers, scale));
    }
    out
}

/// Normalized multiplier area/energy (8-bit designs, accurate = 1).
pub fn normalized_cost() -> (f64, f64, f64, f64) {
    let cal = calibrate::fitted();
    let metric = |nl: &crate::fabric::Netlist| -> (f64, f64) {
        let a = crate::fabric::area::report(nl).luts as f64;
        let t = timing::analyze(nl, cal).critical_ns;
        let p = power::estimate_at(nl, cal, 0xAB, 4096, t).total_mw;
        (a, p * t)
    };
    // Ratios quoted at 16-bit: below ~8 bits the logarithmic front-end
    // overhead dominates under our structural mapping (the paper's 8-bit
    // ratios of 0.78/0.62 rely on Vivado-level packing); at 16 bit the
    // crossover is passed and the direction of the claim reproduces.
    let (a_acc, e_acc) = metric(&baselines::array_mul(16));
    let (a_sd, e_sd) = metric(&simdive::mul(16, 8));
    let (a_mbm, e_mbm) = metric(&baselines::mbm_mul(16));
    (a_sd / a_acc, e_sd / e_acc, a_mbm / a_acc, e_mbm / e_acc)
}

/// Render Table 4.
pub fn render(scale: Scale) -> String {
    let rows = rows(scale);
    let headers = [
        "Dataset", "Hidden", "Nodes", "Double(%)", "8b Accurate(%)", "8b SIMDive(%)", "8b MBM(%)",
    ];
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.into(),
                r.hidden_layers.to_string(),
                r.nodes.to_string(),
                format!("{:.2}", r.acc_double),
                format!("{:.2}", r.acc_q8_accurate),
                format!("{:.2}", r.acc_q8_simdive),
                format!("{:.2}", r.acc_q8_mbm),
            ]
        })
        .collect();
    let (a_sd, e_sd, a_mbm, e_mbm) = normalized_cost();
    format!(
        "== Table 4 — ANN accuracy (synthetic digits/fashion; DESIGN.md §1) ==\n{}\n\
         Multiplier area  (normalized to 8-bit accurate): SIMDive {:.2}, MBM {:.2}\n\
         Multiplier energy(normalized to 8-bit accurate): SIMDive {:.2}, MBM {:.2}\n",
        super::render_table(&headers, &cells),
        a_sd, a_mbm, e_sd, e_mbm
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scale_run_preserves_shape() {
        let scale = Scale { train: 1500, test: 250, epochs: 5, nodes: 32 };
        let r = run_config(Family::Digits, "Digits", 2, scale);
        // Quantization costs a little; SIMDive tracks accurate closely
        // (Table 4's headline: same or better accuracy).
        assert!(r.acc_double > 60.0, "double {}", r.acc_double);
        assert!(r.acc_q8_accurate > r.acc_double - 10.0);
        assert!((r.acc_q8_simdive - r.acc_q8_accurate).abs() < 6.0);
    }

    #[test]
    fn simdive_multiplier_cheaper_than_accurate() {
        let (a_sd, e_sd, _a_mbm, _e_mbm) = normalized_cost();
        // Paper: area 0.78, energy 0.62 vs accurate (8-bit); our ratios
        // are at 16-bit (see normalized_cost) — energy must be below
        // parity, area near parity.
        assert!(a_sd < 1.2, "SIMDive area ratio {a_sd}");
        assert!(e_sd < 1.05, "SIMDive energy ratio {e_sd}");
    }
}
