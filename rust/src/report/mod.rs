//! Experiment harness: one module per paper table/figure, each producing
//! the same rows the paper reports. Shared by the CLI (`repro <exp>`) and
//! the benches (`cargo bench`). See DESIGN.md §5 for the experiment index.

pub mod fabric;
pub mod figs;
pub mod golden;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod tunable;

/// Render a list of rows as an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    line(
        &mut out,
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
    );
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Output directory for experiment artifacts (CSV, images).
pub fn artifacts_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from(
        std::env::var("SIMDIVE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let _ = std::fs::create_dir_all(dir.join("figures"));
    let _ = std::fs::create_dir_all(dir.join("golden"));
    dir
}

#[cfg(test)]
mod tests {
    #[test]
    fn render_alignment() {
        let t = super::render_table(
            &["name", "v"],
            &[vec!["a".into(), "1".into()], vec!["long-name".into(), "22".into()]],
        );
        assert!(t.contains("long-name"));
        assert!(t.lines().count() == 4);
    }
}
