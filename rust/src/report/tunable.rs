//! §3.3/§3.4 tunable-accuracy sweep: ARE/PRE and area as a function of the
//! number of coefficient LUTs `w` (0 = pure Mitchell … 8 = full SIMDive).

use crate::arith::{DivDesign, MulDesign};
use crate::circuits::simdive;
use crate::metrics::{div_error, mul_error};

#[derive(Clone, Debug)]
pub struct Point {
    pub w: u32,
    pub mul_are: f64,
    pub mul_pre: f64,
    pub div_are: f64,
    pub div_pre: f64,
    pub mul_area_luts: u32,
}

pub fn sweep(samples: u64) -> Vec<Point> {
    (0..=8u32)
        .map(|w| {
            let m = mul_error(MulDesign::Simdive { w }, 16, samples, 100 + w as u64);
            let d = div_error(DivDesign::Simdive { w }, 16, 8, samples, 200 + w as u64);
            let area = crate::fabric::area::report(&simdive::mul(16, w)).luts;
            Point {
                w,
                mul_are: m.are_pct,
                mul_pre: m.pre_pct,
                div_are: d.are_pct,
                div_pre: d.pre_pct,
                mul_area_luts: area,
            }
        })
        .collect()
}

pub fn render(samples: u64) -> String {
    let pts = sweep(samples);
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.w.to_string(),
                format!("{:.3}", p.mul_are),
                format!("{:.2}", p.mul_pre),
                format!("{:.3}", p.div_are),
                format!("{:.2}", p.div_pre),
                p.mul_area_luts.to_string(),
            ]
        })
        .collect();
    format!(
        "== Tunable accuracy sweep (w = coefficient LUTs) ==\n{}\n\
         Paper §3.3: one more LUT = one more coefficient bit; 8 LUTs → >99.2% accuracy.\n",
        super::render_table(
            &["w", "mul ARE%", "mul PRE%", "div ARE%", "div PRE%", "mul LUTs"],
            &rows
        )
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn sweep_monotone_in_w() {
        let pts = super::sweep(60_000);
        assert_eq!(pts.len(), 9);
        // w=0 is Mitchell (~3.9% mul ARE); w=8 under 1.1%; area grows.
        assert!(pts[0].mul_are > 3.0);
        assert!(pts[8].mul_are < 1.1);
        assert!(pts[8].mul_area_luts > pts[0].mul_area_luts);
        // 8-LUT configuration approaches the paper's >99.2%-accuracy
        // claim (mean relative accuracy = 100 − ARE; ours lands ≈98.9
        // with region-mean coefficients vs the paper's optimized ones).
        assert!(100.0 - pts[8].mul_are > 98.7, "accuracy {}", 100.0 - pts[8].mul_are);
    }
}
