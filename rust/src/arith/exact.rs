//! Accurate reference arithmetic (stand-in for the Xilinx LogiCORE IPs).
//!
//! Semantics match the soft IPs the paper uses as baselines: full-width
//! unsigned multiply, and truncating (floor) unsigned divide with the
//! divide-by-zero convention of saturating to all-ones (the LogiCORE divider
//! flags the case; a saturated quotient is the standard wrapper behaviour).

use super::max_val;

/// Exact `N x N -> 2N` unsigned multiply.
#[inline]
pub fn mul(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    a.wrapping_mul(b)
}

/// Exact floor division. `b == 0` saturates to the N-bit max.
#[inline]
pub fn div(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    if b == 0 {
        max_val(bits)
    } else {
        a / b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_matches_native() {
        assert_eq!(mul(8, 43, 10), 430);
        assert_eq!(mul(16, 65535, 65535), 65535u64 * 65535);
        assert_eq!(mul(32, 0xFFFF_FFFF, 0xFFFF_FFFF), 0xFFFF_FFFFu64 * 0xFFFF_FFFF);
    }

    #[test]
    fn div_floor_semantics() {
        assert_eq!(div(8, 43, 10), 4);
        assert_eq!(div(16, 7, 9), 0);
        assert_eq!(div(32, 1 << 31, 3), (1u64 << 31) / 3);
    }

    #[test]
    fn div_by_zero_saturates() {
        assert_eq!(div(8, 200, 0), 255);
        assert_eq!(div(16, 1, 0), 65535);
        assert_eq!(div(32, 0, 0), u32::MAX as u64);
    }
}
