//! SIMD (sub-word parallel) semantics of the 32-bit SIMDive unit (§3.2).
//!
//! One 32-bit unit decomposes — via the one-hot `precision` control — into
//! `1×32`, `2×16`, `16+8+8`, or `4×8` lanes, and every lane independently
//! selects multiply or divide (`Mul/Div mode` signal): the paper's
//! *mixed-precision, mixed-functionality* feature. A lane of width `N`
//! produces a `2N`-bit result field, so a packed result is 64 bits.

use super::simdive::{simdive_div_with, simdive_mul_with};
use super::table::{tables_for, CorrectionTables};

/// Lane decomposition of the 32-bit unit (one-hot `precision` control).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaneCfg {
    /// One 32×32 lane.
    One32,
    /// Two 16×16 lanes.
    Two16,
    /// One 16×16 lane (high) and two 8×8 lanes (low).
    One16Two8,
    /// Four 8×8 lanes.
    Four8,
}

impl LaneCfg {
    /// `(bit offset, width)` of each lane, low lane first.
    pub fn lanes(self) -> &'static [(u32, u32)] {
        match self {
            LaneCfg::One32 => &[(0, 32)],
            LaneCfg::Two16 => &[(0, 16), (16, 16)],
            LaneCfg::One16Two8 => &[(0, 8), (8, 8), (16, 16)],
            LaneCfg::Four8 => &[(0, 8), (8, 8), (16, 8), (24, 8)],
        }
    }

    pub fn lane_count(self) -> usize {
        self.lanes().len()
    }

    pub const ALL: [LaneCfg; 4] =
        [LaneCfg::One32, LaneCfg::Two16, LaneCfg::One16Two8, LaneCfg::Four8];
}

/// Per-lane functionality (the `Mul/Div mode` control signal).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaneMode {
    Mul,
    Div,
}

/// A packed SIMD operation: configuration + per-lane modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdOp {
    pub cfg: LaneCfg,
    /// Modes for up to four lanes, indexed like `cfg.lanes()`.
    pub modes: [LaneMode; 4],
}

impl SimdOp {
    pub fn uniform(cfg: LaneCfg, mode: LaneMode) -> Self {
        SimdOp { cfg, modes: [mode; 4] }
    }
}

/// A packed pair of 32-bit operand words.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdWord {
    pub a: u32,
    pub b: u32,
}

impl SimdWord {
    pub fn new(a: u32, b: u32) -> Self {
        SimdWord { a, b }
    }

    /// Pack per-lane operands under `cfg`. Values must fit their lanes.
    pub fn pack(cfg: LaneCfg, ops_a: &[u64], ops_b: &[u64]) -> Self {
        let lanes = cfg.lanes();
        assert_eq!(ops_a.len(), lanes.len());
        assert_eq!(ops_b.len(), lanes.len());
        let (mut a, mut b) = (0u32, 0u32);
        for (i, &(off, w)) in lanes.iter().enumerate() {
            assert!(super::fits(ops_a[i], w), "lane {i} operand A too wide");
            assert!(super::fits(ops_b[i], w), "lane {i} operand B too wide");
            a |= (ops_a[i] as u32) << off;
            b |= (ops_b[i] as u32) << off;
        }
        SimdWord { a, b }
    }

    /// Extract the operands of lane `i` under `cfg`.
    pub fn lane(self, cfg: LaneCfg, i: usize) -> (u64, u64) {
        let (off, w) = cfg.lanes()[i];
        let mask = super::max_val(w);
        (((self.a >> off) as u64) & mask, ((self.b >> off) as u64) & mask)
    }
}

/// Execute one packed op on a SIMDive unit with tables at tuning `w`.
///
/// The result is a 64-bit word: lane `i` of width `N` at operand offset
/// `off` occupies result bits `[2·off, 2·off + 2N)` (a multiply fills the
/// field; a divide's `N`-bit quotient is zero-extended into it).
pub fn execute(op: SimdOp, word: SimdWord, w: u32) -> u64 {
    execute_with(tables_for(w), op, word)
}

/// As [`execute`] with explicit tables.
pub fn execute_with(t: &CorrectionTables, op: SimdOp, word: SimdWord) -> u64 {
    let mut out = 0u64;
    for (i, &(off, width)) in op.cfg.lanes().iter().enumerate() {
        let (a, b) = word.lane(op.cfg, i);
        let r = match op.modes[i] {
            LaneMode::Mul => simdive_mul_with(t, width, a, b),
            LaneMode::Div => simdive_div_with(t, width, a, b),
        };
        debug_assert!(width == 32 || r < (1u64 << (2 * width)));
        out |= r << (2 * off);
    }
    out
}

/// Extract lane `i`'s result field from a packed 64-bit result.
pub fn result_lane(op: SimdOp, result: u64, i: usize) -> u64 {
    let (off, width) = op.cfg.lanes()[i];
    if width == 32 {
        result
    } else {
        (result >> (2 * off)) & super::max_val(2 * width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::{simdive_div, simdive_mul};

    #[test]
    fn lane_geometry_covers_32_bits() {
        for cfg in LaneCfg::ALL {
            let mut mask = 0u32;
            for &(off, w) in cfg.lanes() {
                let m = (super::super::max_val(w) as u32) << off;
                assert_eq!(mask & m, 0, "{cfg:?}: overlapping lanes");
                mask |= m;
            }
            assert_eq!(mask, u32::MAX, "{cfg:?}: lanes must tile 32 bits");
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let ops_a = [0x12u64, 0x34, 0x56, 0x78];
        let ops_b = [0x9Au64, 0xBC, 0xDE, 0xF0];
        let w = SimdWord::pack(LaneCfg::Four8, &ops_a, &ops_b);
        for i in 0..4 {
            assert_eq!(w.lane(LaneCfg::Four8, i), (ops_a[i], ops_b[i]));
        }
    }

    #[test]
    fn simd_lanes_match_sisd() {
        // Core SIMD property: each packed lane result equals the SISD
        // result of the same operands — no cross-lane contamination.
        let mut rng = crate::util::Rng::new(77);
        for _ in 0..5_000 {
            for cfg in LaneCfg::ALL {
                let lanes = cfg.lanes();
                let ops_a: Vec<u64> = lanes.iter().map(|&(_, w)| rng.operand(w)).collect();
                let ops_b: Vec<u64> = lanes.iter().map(|&(_, w)| rng.operand(w)).collect();
                let word = SimdWord::pack(cfg, &ops_a, &ops_b);
                let mut modes = [LaneMode::Mul; 4];
                for m in modes.iter_mut().take(lanes.len()) {
                    if rng.below(2) == 1 {
                        *m = LaneMode::Div;
                    }
                }
                let op = SimdOp { cfg, modes };
                let packed = execute(op, word, 8);
                for i in 0..lanes.len() {
                    let (a, b) = (ops_a[i], ops_b[i]);
                    let wid = lanes[i].1;
                    let want = match modes[i] {
                        LaneMode::Mul => simdive_mul(wid, a, b),
                        LaneMode::Div => simdive_div(wid, a, b),
                    };
                    assert_eq!(
                        result_lane(op, packed, i),
                        want,
                        "{cfg:?} lane {i} ({a}, {b}) mode {:?}",
                        modes[i]
                    );
                }
            }
        }
    }

    #[test]
    fn mixed_functionality_in_one_word() {
        // The paper's flagship feature: mul and div lanes coexisting.
        let word = SimdWord::pack(LaneCfg::Four8, &[43, 200, 7, 255], &[10, 13, 3, 2]);
        let op = SimdOp {
            cfg: LaneCfg::Four8,
            modes: [LaneMode::Mul, LaneMode::Div, LaneMode::Mul, LaneMode::Div],
        };
        let r = execute(op, word, 8);
        assert_eq!(result_lane(op, r, 0), simdive_mul(8, 43, 10));
        assert_eq!(result_lane(op, r, 1), simdive_div(8, 200, 13));
        assert_eq!(result_lane(op, r, 2), simdive_mul(8, 7, 3));
        assert_eq!(result_lane(op, r, 3), simdive_div(8, 255, 2));
    }

    #[test]
    fn one32_lane_passes_through() {
        let word = SimdWord::new(123_456_789, 987);
        let op = SimdOp::uniform(LaneCfg::One32, LaneMode::Mul);
        assert_eq!(execute(op, word, 8), simdive_mul(32, 123_456_789, 987));
        let op = SimdOp::uniform(LaneCfg::One32, LaneMode::Div);
        assert_eq!(execute(op, word, 8), simdive_div(32, 123_456_789, 987));
    }

    #[test]
    #[should_panic(expected = "too wide")]
    fn pack_rejects_oversized_operand() {
        SimdWord::pack(LaneCfg::Four8, &[256, 1, 1, 1], &[1, 1, 1, 1]);
    }
}
