//! AAXD — adaptive-approximation divider (Jiang et al., DATE'18 [13]).
//!
//! Principle: dynamically truncate both operands around their leading ones
//! (keep the top `m` bits of the dividend and top `n` bits of the divisor),
//! divide the small values exactly, and shift the quotient back. The paper
//! evaluates AAXD(12/6) and AAXD(8/4) as divider baselines in Table 2.

use std::num::NonZeroU64;

use super::mitchell::lod;

/// AAXD approximate division keeping `m` dividend / `n` divisor bits.
#[inline]
pub fn aaxd_div(bits: u32, m: u32, n: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    debug_assert!(m >= 1 && n >= 1 && m <= bits && n <= bits);
    let Some(nb) = NonZeroU64::new(b) else {
        return super::max_val(bits);
    };
    let Some(na) = NonZeroU64::new(a) else {
        return 0;
    };
    let ka = lod(na);
    let kb = lod(nb);
    // Keep the top m (n) bits starting at the leading one; sa/sb are the
    // number of truncated low bits.
    let sa = (ka as i64 + 1 - m as i64).max(0);
    let sb = (kb as i64 + 1 - n as i64).max(0);
    let at = a >> sa;
    let bt = b >> sb;
    let q = at / bt; // exact small division (the m/n-bit array divider)
    // Undo the scaling: a/b ≈ (at / bt) · 2^(sa - sb).
    let shift = sa - sb;
    let v = if shift >= 0 {
        (q as u128) << shift.min(100)
    } else {
        (q as u128) >> (-shift)
    };
    v.min(super::max_val(bits) as u128) as u64
}

/// Real-valued AAXD divide (error-analysis form: the small division is
/// evaluated in the reals, matching the paper's behavioral error models).
#[inline]
pub fn aaxd_div_real(bits: u32, m: u32, n: u32, a: u64, b: u64) -> f64 {
    let Some(nb) = NonZeroU64::new(b) else {
        return super::max_val(bits) as f64;
    };
    let Some(na) = NonZeroU64::new(a) else {
        return 0.0;
    };
    let ka = lod(na);
    let kb = lod(nb);
    let sa = (ka as i64 + 1 - m as i64).max(0);
    let sb = (kb as i64 + 1 - n as i64).max(0);
    let at = (a >> sa) as f64;
    let bt = (b >> sb) as f64;
    at / bt * 2f64.powi((sa - sb) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact;

    #[test]
    fn exact_when_operands_fit() {
        // If both operands already fit in m/n bits nothing is truncated.
        for a in 1..128u64 {
            for b in 1..16u64 {
                assert_eq!(aaxd_div(16, 8, 4, a, b), a / b);
            }
        }
    }

    #[test]
    fn zero_conventions() {
        assert_eq!(aaxd_div(16, 8, 4, 0, 9), 0);
        assert_eq!(aaxd_div(16, 8, 4, 9, 0), 65535);
    }

    #[test]
    fn error_regime_matches_paper() {
        // Paper Table 2: AAXD(8/4) ARE ≈ 3%, AAXD(12/6) ARE ≈ 0.74%, both
        // with PRE up to 100%. The paper's divider scenario is 16/8: 16-bit
        // dividend, 8-bit divisor, quotient ≥ 1; errors vs real quotient.
        let mut rng = crate::util::Rng::new(42);
        let (mut e84, mut e126, mut n) = (0.0, 0.0, 0u64);
        while n < 200_000 {
            let a = rng.operand(16);
            let b = rng.operand(8);
            if a < b {
                continue;
            }
            let real = a as f64 / b as f64;
            e84 += (real - aaxd_div_real(16, 8, 4, a, b)).abs() / real;
            e126 += (real - aaxd_div_real(16, 12, 6, a, b)).abs() / real;
            n += 1;
        }
        let (are84, are126) = (e84 / n as f64 * 100.0, e126 / n as f64 * 100.0);
        assert!(are126 < are84, "12/6 ({are126}) must beat 8/4 ({are84})");
        assert!(are84 < 6.0, "8/4 ARE {are84}%");
        assert!(are126 < 1.8, "12/6 ARE {are126}%");
    }

    #[test]
    fn quotient_fits_width() {
        crate::util::prop::check_operand_pairs(7, 20_000, 16, |a, b| {
            let q = aaxd_div(16, 8, 4, a, b);
            if q <= 65535 { Ok(()) } else { Err(format!("{a}/{b} -> {q}")) }
        });
    }

    #[test]
    fn monotone_in_kept_bits_on_average() {
        // More kept bits → not worse, on the paper's 16/8 scenario.
        let mut rng = crate::util::Rng::new(9);
        let pairs: Vec<(u64, u64)> = std::iter::repeat_with(|| (rng.operand(16), rng.operand(8)))
            .filter(|&(a, b)| a >= b)
            .take(50_000)
            .collect();
        let mut prev = f64::INFINITY;
        for (m, n) in [(6u32, 3u32), (8, 4), (12, 6), (16, 8)] {
            let mut e = 0.0;
            for &(a, b) in &pairs {
                let real = a as f64 / b as f64;
                e += (real - aaxd_div(16, m, n, a, b) as f64).abs() / real;
            }
            assert!(e <= prev * 1.02, "({m}/{n}) regressed: {e} > {prev}");
            prev = e;
        }
        // Full width = exact (floor).
        let mut rng = crate::util::Rng::new(10);
        for _ in 0..10_000 {
            let a = rng.operand(16);
            let b = rng.operand(16);
            assert_eq!(aaxd_div(16, 16, 16, a, b), exact::div(16, a, b));
        }
    }
}
