//! Design registry: every multiplier/divider row of the paper's Tables 2–3
//! as a uniform enum, so the error evaluators, benches and application
//! substrates can iterate over designs generically.

use super::{aaxd, batch, ca, exact, mitchell, saadat, simdive, table, trunc};

/// Multiplier designs (Table 2 upper half + Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MulDesign {
    /// Accurate soft IP (Xilinx LogiCORE stand-in).
    Accurate,
    /// CA: hierarchical from approximate 4×4 blocks [30].
    Ca,
    /// Truncated, 16×16 from four 7×7 instances.
    TruncFour7x7,
    /// Truncated, 16×16 from two 15×7 instances.
    TruncTwo15x7,
    /// Truncated, 32-bit from 31×7 instances (Table 3).
    Trunc31x7,
    /// Mitchell's logarithmic multiplier [22].
    Mitchell,
    /// MBM: minimally biased multiplier [28].
    Mbm,
    /// Proposed SIMDive multiplier at tuning `w`.
    Simdive { w: u32 },
}

impl MulDesign {
    /// Evaluate the design at operand width `bits`.
    #[inline]
    pub fn mul(&self, bits: u32, a: u64, b: u64) -> u64 {
        match *self {
            MulDesign::Accurate => exact::mul(bits, a, b),
            MulDesign::Ca => ca::ca_mul(bits, a, b),
            MulDesign::TruncFour7x7 => trunc::trunc_mul(bits, true, true, a, b),
            MulDesign::TruncTwo15x7 => trunc::trunc_mul(bits, false, true, a, b),
            MulDesign::Trunc31x7 => trunc::trunc_mul(bits, false, true, a, b),
            MulDesign::Mitchell => mitchell::mul(bits, a, b),
            MulDesign::Mbm => saadat::mbm_mul(bits, a, b),
            MulDesign::Simdive { w } => simdive::simdive_mul_w(bits, a, b, w),
        }
    }

    /// Batched evaluation into a reusable buffer: `out[i] = self.mul(bits,
    /// a[i], b[i])`, bit-exactly. SIMDive routes through the
    /// [`batch`](super::batch) slice kernel (tables and width resolved
    /// once per call); the other designs fall back to per-element calls.
    pub fn mul_batch_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.resize(a.len(), 0);
        match *self {
            MulDesign::Simdive { w } => {
                batch::mul_batch_into(table::tables_for(w), bits, a, b, out)
            }
            _ => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = self.mul(bits, x, y);
                }
            }
        }
    }

    /// Batched real-valued evaluation into a reusable buffer: `out[i] =
    /// self.mul_real(bits, a[i], b[i])` exactly. SIMDive routes through
    /// the [`batch`](super::batch) real-valued slice kernel (tables and
    /// rescale resolved once per call — what the error sweeps hit via the
    /// engine seam); the other designs fall back to per-element calls.
    pub fn mul_real_batch_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<f64>) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.resize(a.len(), 0.0);
        match *self {
            MulDesign::Simdive { w } => {
                batch::mul_real_batch_into(table::tables_for(w), bits, a, b, out)
            }
            _ => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = self.mul_real(bits, x, y);
                }
            }
        }
    }

    /// Real-valued output for error analysis (the paper's behavioral-model
    /// form; integer designs return their integer result as a real).
    #[inline]
    pub fn mul_real(&self, bits: u32, a: u64, b: u64) -> f64 {
        match *self {
            MulDesign::Accurate
            | MulDesign::Ca
            | MulDesign::TruncFour7x7
            | MulDesign::TruncTwo15x7
            | MulDesign::Trunc31x7 => self.mul(bits, a, b) as f64,
            MulDesign::Mitchell => mitchell::mul_real(bits, a, b),
            MulDesign::Mbm => saadat::mbm_mul_real(bits, a, b),
            MulDesign::Simdive { w } => simdive::simdive_mul_real_w(bits, a, b, w),
        }
    }

    pub fn name(&self) -> String {
        match *self {
            MulDesign::Accurate => "Accurate IP [36]".into(),
            MulDesign::Ca => "CA [30]".into(),
            MulDesign::TruncFour7x7 => "Trunc (four 7x7)".into(),
            MulDesign::TruncTwo15x7 => "Trunc (two 15x7)".into(),
            MulDesign::Trunc31x7 => "Truncated (using 31x7)".into(),
            MulDesign::Mitchell => "Mitchell [22]".into(),
            MulDesign::Mbm => "MBM [28]".into(),
            MulDesign::Simdive { w } => format!("Proposed (w={w})"),
        }
    }

    /// The Table 2 multiplier rows, in paper order.
    pub fn table2_rows() -> Vec<MulDesign> {
        vec![
            MulDesign::Accurate,
            MulDesign::Ca,
            MulDesign::TruncFour7x7,
            MulDesign::TruncTwo15x7,
            MulDesign::Mitchell,
            MulDesign::Mbm,
            MulDesign::Simdive { w: 8 },
        ]
    }
}

/// Divider designs (Table 2 lower half).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DivDesign {
    /// Accurate soft IP (Xilinx LogiCORE stand-in, restoring array).
    Accurate,
    /// AAXD with m dividend / n divisor bits kept [13].
    Aaxd { m: u32, n: u32 },
    /// Mitchell's logarithmic divider [22].
    Mitchell,
    /// INZeD: near-zero-bias Mitchell divider [29].
    Inzed,
    /// Proposed SIMDive divider at tuning `w`.
    Simdive { w: u32 },
}

impl DivDesign {
    #[inline]
    pub fn div(&self, bits: u32, a: u64, b: u64) -> u64 {
        match *self {
            DivDesign::Accurate => exact::div(bits, a, b),
            DivDesign::Aaxd { m, n } => aaxd::aaxd_div(bits, m, n, a, b),
            DivDesign::Mitchell => mitchell::div(bits, a, b),
            DivDesign::Inzed => saadat::inzed_div(bits, a, b),
            DivDesign::Simdive { w } => simdive::simdive_div_w(bits, a, b, w),
        }
    }

    /// Batched evaluation into a reusable buffer: `out[i] = self.div(bits,
    /// a[i], b[i])`, bit-exactly. SIMDive routes through the
    /// [`batch`](super::batch) slice kernel; the other designs fall back
    /// to per-element calls.
    pub fn div_batch_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.resize(a.len(), 0);
        match *self {
            DivDesign::Simdive { w } => {
                batch::div_batch_into(table::tables_for(w), bits, a, b, out)
            }
            _ => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = self.div(bits, x, y);
                }
            }
        }
    }

    /// Batched real-valued evaluation into a reusable buffer: `out[i] =
    /// self.div_real(bits, a[i], b[i])` exactly. SIMDive routes through
    /// the [`batch`](super::batch) real-valued slice kernel; the other
    /// designs fall back to per-element calls.
    pub fn div_real_batch_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<f64>) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.resize(a.len(), 0.0);
        match *self {
            DivDesign::Simdive { w } => {
                batch::div_real_batch_into(table::tables_for(w), bits, a, b, out)
            }
            _ => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = self.div_real(bits, x, y);
                }
            }
        }
    }

    /// Real-valued output for error analysis (behavioral-model form).
    #[inline]
    pub fn div_real(&self, bits: u32, a: u64, b: u64) -> f64 {
        match *self {
            DivDesign::Accurate => {
                if b == 0 {
                    super::max_val(bits) as f64
                } else {
                    a as f64 / b as f64
                }
            }
            DivDesign::Aaxd { m, n } => aaxd::aaxd_div_real(bits, m, n, a, b),
            DivDesign::Mitchell => mitchell::div_real(bits, a, b),
            DivDesign::Inzed => saadat::inzed_div_real(bits, a, b),
            DivDesign::Simdive { w } => simdive::simdive_div_real_w(bits, a, b, w),
        }
    }

    pub fn name(&self) -> String {
        match *self {
            DivDesign::Accurate => "Accurate IP [37]".into(),
            DivDesign::Aaxd { m, n } => format!("AAXD ({m}/{n}) [13]"),
            DivDesign::Mitchell => "Mitchell [22]".into(),
            DivDesign::Inzed => "INZeD [29]".into(),
            DivDesign::Simdive { w } => format!("Proposed (w={w})"),
        }
    }

    /// The Table 2 divider rows, in paper order.
    pub fn table2_rows() -> Vec<DivDesign> {
        vec![
            DivDesign::Accurate,
            DivDesign::Aaxd { m: 12, n: 6 },
            DivDesign::Aaxd { m: 8, n: 4 },
            DivDesign::Mitchell,
            DivDesign::Inzed,
            DivDesign::Simdive { w: 8 },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_mul_designs_handle_zero_and_max() {
        for d in MulDesign::table2_rows() {
            assert_eq!(d.mul(16, 0, 1234), 0, "{}", d.name());
            let p = d.mul(16, 65535, 65535);
            assert!(p < (1u64 << 32), "{}: {p}", d.name());
        }
    }

    #[test]
    fn all_div_designs_handle_edge_cases() {
        for d in DivDesign::table2_rows() {
            assert_eq!(d.div(16, 0, 99), 0, "{}", d.name());
            assert_eq!(d.div(16, 99, 0), 65535, "{} div-by-zero", d.name());
            assert!(d.div(16, 65535, 1) <= 65535, "{}", d.name());
        }
    }

    #[test]
    fn accurate_is_identity() {
        assert_eq!(MulDesign::Accurate.mul(16, 123, 456), 123 * 456);
        assert_eq!(DivDesign::Accurate.div(16, 456, 123), 456 / 123);
    }

    #[test]
    fn batched_dispatch_matches_scalar_for_every_design() {
        let mut rng = crate::util::Rng::new(42);
        let a: Vec<u64> = (0..200).map(|_| rng.below(1 << 16)).collect();
        let b: Vec<u64> = (0..200).map(|_| rng.below(1 << 16)).collect();
        let mut out = Vec::new();
        for d in MulDesign::table2_rows() {
            d.mul_batch_into(16, &a, &b, &mut out);
            for i in 0..a.len() {
                assert_eq!(out[i], d.mul(16, a[i], b[i]), "{} at {i}", d.name());
            }
        }
        for d in DivDesign::table2_rows() {
            d.div_batch_into(16, &a, &b, &mut out);
            for i in 0..a.len() {
                assert_eq!(out[i], d.div(16, a[i], b[i]), "{} at {i}", d.name());
            }
        }
    }

    #[test]
    fn batched_real_dispatch_matches_scalar_for_every_design() {
        let mut rng = crate::util::Rng::new(43);
        let a: Vec<u64> = (0..200).map(|_| rng.below(1 << 16)).collect();
        let b: Vec<u64> = (0..200).map(|_| rng.below(1 << 16)).collect();
        let mut out = Vec::new();
        for d in MulDesign::table2_rows() {
            d.mul_real_batch_into(16, &a, &b, &mut out);
            for i in 0..a.len() {
                assert_eq!(out[i], d.mul_real(16, a[i], b[i]), "{} at {i}", d.name());
            }
        }
        for d in DivDesign::table2_rows() {
            d.div_real_batch_into(16, &a, &b, &mut out);
            for i in 0..a.len() {
                assert_eq!(out[i], d.div_real(16, a[i], b[i]), "{} at {i}", d.name());
            }
        }
    }

    #[test]
    fn identity_one_behaviour() {
        // All Mitchell-family designs are exact for power-of-two operands.
        for d in [MulDesign::Mitchell, MulDesign::Simdive { w: 0 }] {
            assert_eq!(d.mul(16, 1 << 5, 1 << 7), 1 << 12, "{}", d.name());
        }
        for d in [DivDesign::Mitchell, DivDesign::Simdive { w: 0 }] {
            assert_eq!(d.div(16, 1 << 12, 1 << 5), 1 << 7, "{}", d.name());
        }
    }
}
