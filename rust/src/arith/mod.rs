//! Behavioral (bit-exact) arithmetic models.
//!
//! This module is the numeric ground truth of the reproduction: every
//! multiplier/divider evaluated in the paper's Tables 2–3 has a fast
//! behavioral model here, and the gate-level netlists in [`crate::circuits`]
//! as well as the Pallas kernel / jnp oracle on the Python side are verified
//! bit-exactly against these functions (see DESIGN.md §4 for the contract).
//!
//! Operand convention: unsigned `N`-bit integers (`N ∈ {8, 16, 32}`) carried
//! in `u64`. Multiplication returns a `2N`-bit product, division an `N`-bit
//! quotient, both in `u64`.
//!
//! Hot paths go through [`batch`]: slice kernels bit-identical to the
//! scalar entry points with the table/width resolution hoisted out of the
//! inner loop (DESIGN.md §6).

pub mod aaxd;
pub mod batch;
pub mod ca;
pub mod exact;
pub mod mitchell;
pub mod models;
pub mod saadat;
pub mod simd;
pub mod simdive;
pub mod swar;
pub mod table;
pub mod trunc;

pub use batch::{
    div_batch, div_batch_into, div_batch_lanewise_into, execute_words, execute_words_into,
    mul_batch, mul_batch_into, mul_batch_lanewise_into, MultiKernel, WordKernel,
};
pub use mitchell::{frac_aligned, lod};
pub use models::{DivDesign, MulDesign};
pub use simd::{LaneCfg, LaneMode, SimdOp, SimdWord};
pub use simdive::{simdive_div, simdive_mul, Simdive};
pub use table::{CorrectionTables, TABLE_RESOLUTION_BITS, W_MAX};

/// Supported operand widths.
pub const WIDTHS: [u32; 3] = [8, 16, 32];

/// Check an operand fits in `bits`.
#[inline]
pub fn fits(a: u64, bits: u32) -> bool {
    bits == 64 || a < (1u64 << bits)
}

/// Maximum value of a `bits`-bit operand.
#[inline]
pub fn max_val(bits: u32) -> u64 {
    if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 }
}
