//! State-of-the-art Mitchell-derived baselines from Saadat et al.:
//!
//! * **MBM** (Minimally Biased Multiplier, TCAD'18 [28]): Mitchell's
//!   multiplier plus a single *input-independent* correction constant that
//!   zeroes the mean error. Over uniform fractions the ideal corrections
//!   integrate to `∫∫_{x+y<1} xy = 1/24` and `∫∫_{x+y≥1} (1-x)(1-y)/2 = 1/48`,
//!   i.e. a total bias of exactly **1/16** — a single bit at position 2^-4,
//!   which is what makes MBM nearly free in hardware.
//! * **INZeD** (near-zero-error-bias divider, DAC'19 [29]): same idea for
//!   Mitchell's divider; the constant is the mean of the (negative) ideal
//!   divider correction, computed numerically once.
//!
//! Both share the overflow weakness the paper points out (§2): a single
//! coefficient for the whole interval mis-corrects the region boundaries,
//! which is exactly what SIMDive's 64-region table fixes.

use super::mitchell::{div_decode, frac_aligned, mul_decode};
use super::table::TABLE_RESOLUTION_BITS;
use std::num::NonZeroU64;
use std::sync::OnceLock;

/// MBM's correction constant: exactly 1/16 (see module docs).
pub const MBM_COEFF: f64 = 1.0 / 16.0;

/// INZeD's correction constant (mean ideal divider correction, negative).
pub fn inzed_coeff() -> f64 {
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        // Numeric mean of the ideal divider correction over the unit square.
        let n = 512;
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                let x1 = (i as f64 + 0.5) / n as f64;
                let x2 = (j as f64 + 0.5) / n as f64;
                s += if x1 >= x2 {
                    x2 * (x2 - x1) / (1.0 + x2)
                } else {
                    (x1 - x2) * (1.0 - x2) / (1.0 + x2)
                };
            }
        }
        s / (n * n) as f64
    })
}

/// INZeD's constant in `F = bits − 1` fraction-bit units (negative) —
/// exposed for the gate-level netlist, which folds it into the ternary
/// adder's constant operand.
pub fn inzed_coeff_f_units(bits: u32) -> i64 {
    to_f_units(inzed_coeff(), bits)
}

#[inline]
fn to_f_units(c: f64, bits: u32) -> i64 {
    let fixed = (c * (1i64 << TABLE_RESOLUTION_BITS) as f64).round() as i64;
    let f = bits - 1;
    if f >= TABLE_RESOLUTION_BITS {
        fixed << (f - TABLE_RESOLUTION_BITS)
    } else {
        fixed >> (TABLE_RESOLUTION_BITS - f)
    }
}

/// MBM approximate multiply.
#[inline]
pub fn mbm_mul(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = to_f_units(MBM_COEFF, bits);
    mul_decode(bits, k1, k2, f1 as i64 + f2 as i64 + corr)
}

/// Real-valued MBM multiply (error-analysis form).
#[inline]
pub fn mbm_mul_real(bits: u32, a: u64, b: u64) -> f64 {
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = to_f_units(MBM_COEFF, bits);
    super::mitchell::mul_decode_real(bits, k1, k2, f1 as i64 + f2 as i64 + corr)
}

/// Real-valued INZeD divide (error-analysis form).
#[inline]
pub fn inzed_div_real(bits: u32, a: u64, b: u64) -> f64 {
    let Some(b) = NonZeroU64::new(b) else {
        return super::max_val(bits) as f64;
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = to_f_units(inzed_coeff(), bits);
    super::mitchell::div_decode_real(bits, k1, k2, f1 as i64 - f2 as i64 + corr)
}

/// INZeD approximate divide.
#[inline]
pub fn inzed_div(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let Some(b) = NonZeroU64::new(b) else {
        return super::max_val(bits);
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = to_f_units(inzed_coeff(), bits);
    div_decode(bits, k1, k2, f1 as i64 - f2 as i64 + corr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{exact, mitchell};

    #[test]
    fn inzed_coeff_is_negative_and_small() {
        let c = inzed_coeff();
        assert!(c < 0.0 && c > -0.1, "inzed coeff {c}");
    }

    #[test]
    fn mbm_reduces_mean_error_vs_mitchell() {
        let (mut e_mbm, mut e_mit, mut n) = (0.0, 0.0, 0u64);
        for a in 1..256u64 {
            for b in 1..256u64 {
                let ex = exact::mul(8, a, b) as f64;
                e_mbm += (ex - mbm_mul(8, a, b) as f64).abs() / ex;
                e_mit += (ex - mitchell::mul(8, a, b) as f64).abs() / ex;
                n += 1;
            }
        }
        let (are_mbm, are_mit) = (e_mbm / n as f64, e_mit / n as f64);
        assert!(are_mbm < are_mit, "MBM {are_mbm} !< Mitchell {are_mit}");
        // Paper Table 2: MBM ARE ≈ 2.63% (16-bit). Same regime at 8-bit.
        assert!(are_mbm < 0.04, "MBM ARE {are_mbm}");
    }

    #[test]
    fn mbm_bias_is_near_zero() {
        // "Minimally biased": signed mean error ≈ 0 (<< Mitchell's -3.8%).
        let (mut bias, mut n) = (0.0, 0u64);
        for a in 1..256u64 {
            for b in 1..256u64 {
                let ex = exact::mul(8, a, b) as f64;
                bias += (mbm_mul(8, a, b) as f64 - ex) / ex;
                n += 1;
            }
        }
        let bias = bias / n as f64;
        assert!(bias.abs() < 0.01, "MBM bias {bias}");
    }

    #[test]
    fn inzed_reduces_mean_error_vs_mitchell_div() {
        // Paper's 16/8 divider scenario (quotients ≥ 1, floor negligible).
        let (mut e_inz, mut e_mit, mut n) = (0.0, 0.0, 0u64);
        for a in (1..65536u64).step_by(7) {
            for b in 1..256u64 {
                if a < b {
                    continue;
                }
                let real = a as f64 / b as f64;
                e_inz += (real - inzed_div_real(16, a, b)).abs() / real;
                e_mit += (real - mitchell::div_real(16, a, b)).abs() / real;
                n += 1;
            }
        }
        let (are_inz, are_mit) = (e_inz / n as f64, e_mit / n as f64);
        assert!(are_inz < are_mit, "INZeD {are_inz} !< Mitchell {are_mit}");
        // Paper Table 2: INZeD 2.93% vs Mitchell 4.11%.
        assert!(are_inz < 0.04, "INZeD ARE {are_inz}");
    }

    #[test]
    fn zero_conventions() {
        assert_eq!(mbm_mul(16, 0, 5), 0);
        assert_eq!(inzed_div(16, 0, 5), 0);
        assert_eq!(inzed_div(16, 5, 0), 65535);
    }
}
