//! Mitchell's logarithmic multiplication and division (the 1962 algorithm,
//! paper §3.1) plus the shared leading-one / fraction-alignment helpers used
//! by every Mitchell-derived design in this crate.
//!
//! Fixed-point layout: for an `N`-bit operand the aligned fraction has
//! `F = N - 1` bits. With `k = ⌊log2 a⌋` and `f = (a - 2^k) << (F - k)`,
//! the real fraction is `x = f / 2^F ∈ [0, 1)`.
//!
//! Zero never enters the log domain: [`lod`] and [`frac_aligned`] take
//! [`NonZeroU64`], so every caller must resolve its zero convention
//! (`0 · b = 0`, `a / 0 = max`, …) *before* alignment. The guard used to be
//! a `debug_assert!`, which release builds compiled away — `lod(0)` then
//! returned `63 - 64` wrapped to a huge shift count downstream. With packed
//! SWAR lanes feeding these helpers the guard has to be structural, not
//! advisory.

use std::num::NonZeroU64;

/// Position of the leading one (`⌊log2 a⌋`).
#[inline]
pub fn lod(a: NonZeroU64) -> u32 {
    63 - a.leading_zeros()
}

/// Fraction bits of `a`, left-aligned to `F = bits - 1` fractional places.
#[inline]
pub fn frac_aligned(bits: u32, a: NonZeroU64) -> (u32, u64) {
    let f = bits - 1;
    let k = lod(a);
    let frac = (a.get() - (1u64 << k)) << (f - k);
    (k, frac)
}

/// Decode the Mitchell multiplier antilog: given the (possibly corrected)
/// fraction sum `t` (which may exceed `2^F`, and may include a correction),
/// produce `⌊mantissa · 2^(k1 + k2 − F)⌋` per Eq. 5, saturated to `2N` bits.
///
/// Shared by Mitchell, MBM and SIMDive so the overflow handling is identical
/// across all Mitchell-family designs (this is exactly the paper's decode:
/// carry-out of the fraction adder selects the `x1+x2 ≥ 1` case).
///
/// The shift clamps mirror [`div_decode`]: any in-contract `{bits, k, t}`
/// stays far inside them, but an out-of-contract exponent saturates through
/// the `2N`-bit cap instead of shifting a `u128` by ≥ 128 bits (a panic in
/// debug, wrapped garbage in release).
#[inline]
pub fn mul_decode(bits: u32, k1: u32, k2: u32, t: i64) -> u64 {
    let f = bits - 1;
    debug_assert!(t >= 0, "mul fraction sum cannot be negative");
    let t = t as u128;
    let ksum = k1 + k2;
    let (mant, exp) = if t < (1u128 << f) {
        ((1u128 << f) + t, ksum as i64 - f as i64)
    } else {
        // Carry out of the fraction adder: 2^(k1+k2+1) · t / 2^F.
        (t, ksum as i64 + 1 - f as i64)
    };
    let v = if exp >= 0 {
        mant << exp.min(63)
    } else if -exp >= 128 {
        0
    } else {
        mant >> (-exp)
    };
    let cap = if bits == 32 { u64::MAX as u128 } else { (1u128 << (2 * bits)) - 1 };
    v.min(cap) as u64
}

/// Decode the Mitchell divider antilog per Eq. 6: `t` is the (possibly
/// corrected) fraction difference, which may be negative. Quotient is
/// `N`-bit, floor semantics; exponent underflow floors to 0.
#[inline]
pub fn div_decode(bits: u32, k1: u32, k2: u32, t: i64) -> u64 {
    let f = bits - 1;
    let kdiff = k1 as i64 - k2 as i64;
    let (mant, exp) = if t >= 0 {
        ((1i64 << f) + t, kdiff - f as i64)
    } else {
        // Borrow: 2^(k1-k2-1) · (2 + x1 - x2 [+ c]).
        ((2i64 << f) + t, kdiff - 1 - f as i64)
    };
    if mant <= 0 {
        // Only reachable with a (negative) correction large enough to cancel
        // the implicit leading one; clamp to zero like the hardware would.
        return 0;
    }
    let mant = mant as u128;
    let v = if exp >= 0 {
        mant << exp.min(63)
    } else if -exp >= 128 {
        0
    } else {
        mant >> (-exp)
    };
    v.min(super::max_val(bits) as u128) as u64
}

/// Real-valued multiplier decode (no floor): the algorithm's output as the
/// paper's MATLAB/C++ behavioral models evaluate it for error analysis
/// (§4.1 — ARE/PRE are computed on behavioral models, not bit-truncated
/// hardware outputs; floor effects at tiny products would otherwise
/// dominate the peak-error statistic).
#[inline]
pub fn mul_decode_real(bits: u32, k1: u32, k2: u32, t: i64) -> f64 {
    let f = bits - 1;
    let scale = (1u64 << f) as f64;
    let t = t as f64;
    if t < scale {
        (scale + t) / scale * 2f64.powi((k1 + k2) as i32)
    } else {
        t / scale * 2f64.powi((k1 + k2 + 1) as i32)
    }
}

/// Real-valued divider decode (no floor); see [`mul_decode_real`].
#[inline]
pub fn div_decode_real(bits: u32, k1: u32, k2: u32, t: i64) -> f64 {
    let f = bits - 1;
    let scale = (1u64 << f) as f64;
    let kdiff = k1 as i32 - k2 as i32;
    if t >= 0 {
        (scale + t as f64) / scale * 2f64.powi(kdiff)
    } else {
        (2.0 * scale + t as f64) / scale * 2f64.powi(kdiff - 1)
    }
}

/// Real-valued Mitchell multiply (error-analysis form).
#[inline]
pub fn mul_real(bits: u32, a: u64, b: u64) -> f64 {
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    mul_decode_real(bits, k1, k2, (f1 + f2) as i64)
}

/// Real-valued Mitchell divide (error-analysis form).
#[inline]
pub fn div_real(bits: u32, a: u64, b: u64) -> f64 {
    let Some(b) = NonZeroU64::new(b) else {
        return super::max_val(bits) as f64;
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    div_decode_real(bits, k1, k2, f1 as i64 - f2 as i64)
}

/// Mitchell multiplication (no correction). `a == 0 || b == 0` → 0.
#[inline]
pub fn mul(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    mul_decode(bits, k1, k2, (f1 + f2) as i64)
}

/// Mitchell division (no correction). `b == 0` saturates, `a == 0` → 0.
#[inline]
pub fn div(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let Some(b) = NonZeroU64::new(b) else {
        return super::max_val(bits);
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    div_decode(bits, k1, k2, f1 as i64 - f2 as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact;

    fn nz(v: u64) -> NonZeroU64 {
        NonZeroU64::new(v).expect("test operand must be non-zero")
    }

    #[test]
    fn paper_running_example() {
        // Paper §3.1: 43 × 10 → Mitchell 408 (accurate 430); 43 / 10 → 4.
        assert_eq!(mul(8, 43, 10), 408);
        assert_eq!(div(8, 43, 10), 4);
    }

    #[test]
    fn lod_basics() {
        assert_eq!(lod(nz(1)), 0);
        assert_eq!(lod(nz(2)), 1);
        assert_eq!(lod(nz(3)), 1);
        assert_eq!(lod(nz(255)), 7);
        assert_eq!(lod(nz(1 << 31)), 31);
    }

    #[test]
    fn zero_is_unrepresentable_in_the_log_domain() {
        // The structural guard: there is no `lod(0)` to call. The only way
        // to manufacture an argument is through `NonZeroU64`, which rejects
        // zero — in release builds too, where the old `debug_assert!` was
        // compiled away and `lod(0)` wrapped to `u32::MAX`.
        assert!(NonZeroU64::new(0).is_none());
        for v in 1..=u8::MAX as u64 {
            assert_eq!(lod(nz(v)), v.ilog2());
        }
    }

    #[test]
    fn frac_alignment() {
        // 43 = 2^5 (1 + 0.01011b): fraction 0b01011 aligned to 7 bits = 0b0101100.
        let (k, f) = frac_aligned(8, nz(43));
        assert_eq!(k, 5);
        assert_eq!(f, 0b0101100);
        // 10 = 2^3 (1 + 0.01b).
        let (k, f) = frac_aligned(8, nz(10));
        assert_eq!(k, 3);
        assert_eq!(f, 0b0100000);
    }

    #[test]
    fn powers_of_two_are_exact() {
        // Mitchell is exact when both fractions are zero.
        for i in 0..8 {
            for j in 0..8 {
                let (a, b) = (1u64 << i, 1u64 << j);
                assert_eq!(mul(8, a, b), a * b);
                assert_eq!(div(8, a, b), if i >= j { a / b } else { 0 });
            }
        }
    }

    #[test]
    fn mul_never_overestimates() {
        // Classical Mitchell property: P̃ ≤ P, error < 11.1%.
        for a in 1..256u64 {
            for b in 1..256u64 {
                let approx = mul(8, a, b);
                let ex = exact::mul(8, a, b);
                assert!(approx <= ex, "a={a} b={b}: {approx} > {ex}");
                let rel = (ex - approx) as f64 / ex as f64;
                assert!(rel < 0.1112, "a={a} b={b}: rel {rel}");
            }
        }
    }

    #[test]
    fn div_error_bounded() {
        // Mitchell division floor-truncated vs real quotient: check the
        // relative error of the *real-valued* decode stays within the known
        // analytic bound (≈ +12.5% over, never more than ~0 under in the
        // integer floor sense beyond 1 ulp effects at tiny quotients).
        for a in 1..256u64 {
            for b in 1..256u64 {
                let approx = div(8, a, b) as f64;
                let real = a as f64 / b as f64;
                // floor() can lose up to 1.0; compare against real+1.
                assert!(approx <= real * 1.1251 + 1.0, "a={a} b={b} approx={approx} real={real}");
            }
        }
    }

    #[test]
    fn zero_conventions() {
        assert_eq!(mul(16, 0, 1234), 0);
        assert_eq!(mul(16, 1234, 0), 0);
        assert_eq!(div(16, 0, 7), 0);
        assert_eq!(div(16, 7, 0), 65535);
    }

    #[test]
    fn zero_operand_conventions_exhaustive() {
        // Every zero convention, every width, integer and real forms —
        // exercised in release as well as debug, now that the guard
        // underneath is structural rather than a debug assertion.
        for &bits in &crate::arith::WIDTHS {
            let max = crate::arith::max_val(bits);
            for x in [0u64, 1, 2, 97, max] {
                assert_eq!(mul(bits, 0, x), 0, "0·{x} at {bits}-bit");
                assert_eq!(mul(bits, x, 0), 0, "{x}·0 at {bits}-bit");
                assert_eq!(div(bits, x, 0), max, "{x}/0 at {bits}-bit");
                assert_eq!(mul_real(bits, 0, x), 0.0);
                assert_eq!(mul_real(bits, x, 0), 0.0);
                assert_eq!(div_real(bits, x, 0), max as f64);
            }
            assert_eq!(div(bits, 0, 5), 0, "0/5 at {bits}-bit");
            assert_eq!(div(bits, 0, 0), max, "0/0 follows b==0 first");
            assert_eq!(div_real(bits, 0, 5), 0.0);
            assert_eq!(div_real(bits, 0, 0), max as f64);
        }
    }

    #[test]
    fn wide_widths_consistent_with_narrow() {
        // The same (a, b) evaluated at wider widths must give the same
        // result: alignment is width-independent in value terms.
        for a in [1u64, 3, 43, 100, 255] {
            for b in [1u64, 7, 10, 200, 255] {
                assert_eq!(mul(8, a, b), mul(16, a, b));
                assert_eq!(mul(8, a, b), mul(32, a, b));
                assert_eq!(div(8, a, b), div(16, a, b));
                assert_eq!(div(8, a, b), div(32, a, b));
            }
        }
    }

    #[test]
    fn mul_32bit_saturation_paths() {
        let m = u32::MAX as u64;
        let v = mul(32, m, m);
        assert!(v <= u64::MAX);
        assert!(v as u128 <= (m as u128) * (m as u128));
    }

    #[test]
    fn mul_decode_max_exponent_pinned() {
        // Max k1+k2 at 32-bit: a = b = u32::MAX → k1 = k2 = 31 and the
        // maximal fraction sum t = 2·(2^31 − 1) carries out of the fraction
        // adder, so exp = 32 and the decode is (2^32 − 2) · 2^32.
        let fmax = (1i64 << 31) - 1;
        let want = u64::MAX - (1u64 << 33) + 1; // 2^64 − 2^33
        assert_eq!(mul_decode(32, 31, 31, 2 * fmax), want);
        assert_eq!(mul(32, u32::MAX as u64, u32::MAX as u64), want);
        // Mitchell never overestimates: stays under the exact product.
        assert!((want as u128) <= (u32::MAX as u128) * (u32::MAX as u128));
    }

    #[test]
    fn mul_decode_max_correction_saturates() {
        // A correction pushing the fraction sum to its i64 ceiling must
        // saturate through the 2N-bit cap, not shift past 128 bits.
        assert_eq!(mul_decode(32, 31, 31, i64::MAX), u64::MAX);
        assert_eq!(mul_decode(8, 7, 7, i64::MAX), crate::arith::max_val(16));
    }

    #[test]
    fn mul_decode_out_of_contract_exponent_clamps() {
        // Out-of-contract LOD pairs used to compute `mant << exp` with
        // exp ≥ 128 — a panic in debug, wrapped garbage in release. Now
        // they clamp symmetrically with div_decode and saturate.
        assert_eq!(mul_decode(8, 63, 63, 0), crate::arith::max_val(16));
        assert_eq!(mul_decode(16, 63, 63, 1), crate::arith::max_val(32));
    }

    #[test]
    fn div_decode_clamps_stay_pinned() {
        // The divider-side clamps mul_decode now mirrors: huge positive
        // exponents saturate to max_val, mant ≤ 0 floors to zero.
        assert_eq!(div_decode(8, 63, 0, 0), crate::arith::max_val(8));
        assert_eq!(div_decode(8, 0, 0, -(1i64 << 8)), 0);
    }
}
