//! SIMDive: Mitchell's algorithm + the paper's 64-region error-reduction
//! scheme (§3.2–3.3), with the tunable-accuracy knob `w`.
//!
//! The hardware adds the correction coefficient in the *same* ternary
//! add/sub step that combines the two fractional parts (one LUT + carry
//! chain pass), so behaviorally the correction is applied to the fraction
//! sum/difference before the antilog decode — exactly what these functions
//! do. Verified bit-exactly against the gate-level netlists in
//! `circuits::simdive` and against the Pallas kernel via golden vectors.

use std::num::NonZeroU64;

use super::mitchell::{div_decode, frac_aligned, mul_decode};
use super::table::{default_tables, tables_for, CorrectionTables};

/// SIMDive approximate multiply at tuning `w` (0..=8 coefficient bits).
#[inline]
pub fn simdive_mul_w(bits: u32, a: u64, b: u64, w: u32) -> u64 {
    simdive_mul_with(tables_for(w), bits, a, b)
}

/// SIMDive approximate divide at tuning `w`.
#[inline]
pub fn simdive_div_w(bits: u32, a: u64, b: u64, w: u32) -> u64 {
    simdive_div_with(tables_for(w), bits, a, b)
}

/// SIMDive multiply with the default (8-LUT) tables.
#[inline]
pub fn simdive_mul(bits: u32, a: u64, b: u64) -> u64 {
    simdive_mul_with(default_tables(), bits, a, b)
}

/// SIMDive divide with the default (8-LUT) tables.
#[inline]
pub fn simdive_div(bits: u32, a: u64, b: u64) -> u64 {
    simdive_div_with(default_tables(), bits, a, b)
}

/// Multiply with explicit tables (used by the sweep and the SIMD unit).
#[inline]
pub fn simdive_mul_with(t: &CorrectionTables, bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let c = t.mul[CorrectionTables::region(bits, f1)][CorrectionTables::region(bits, f2)];
    let corr = CorrectionTables::scale_to_f(c, bits);
    mul_decode(bits, k1, k2, f1 as i64 + f2 as i64 + corr)
}

/// Divide with explicit tables.
#[inline]
pub fn simdive_div_with(t: &CorrectionTables, bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let Some(b) = NonZeroU64::new(b) else {
        return super::max_val(bits);
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let c = t.div[CorrectionTables::region(bits, f1)][CorrectionTables::region(bits, f2)];
    let corr = CorrectionTables::scale_to_f(c, bits);
    div_decode(bits, k1, k2, f1 as i64 - f2 as i64 + corr)
}

/// Real-valued SIMDive multiply (error-analysis form, see
/// [`mitchell::mul_decode_real`](super::mitchell::mul_decode_real)).
#[inline]
pub fn simdive_mul_real_w(bits: u32, a: u64, b: u64, w: u32) -> f64 {
    let t = tables_for(w);
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let c = t.mul[CorrectionTables::region(bits, f1)][CorrectionTables::region(bits, f2)];
    let corr = CorrectionTables::scale_to_f(c, bits);
    super::mitchell::mul_decode_real(bits, k1, k2, f1 as i64 + f2 as i64 + corr)
}

/// Real-valued SIMDive divide (error-analysis form).
#[inline]
pub fn simdive_div_real_w(bits: u32, a: u64, b: u64, w: u32) -> f64 {
    let t = tables_for(w);
    let Some(b) = NonZeroU64::new(b) else {
        return super::max_val(bits) as f64;
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let c = t.div[CorrectionTables::region(bits, f1)][CorrectionTables::region(bits, f2)];
    let corr = CorrectionTables::scale_to_f(c, bits);
    super::mitchell::div_decode_real(bits, k1, k2, f1 as i64 - f2 as i64 + corr)
}

/// A configured SIMDive unit: width + accuracy knob, usable as a pluggable
/// arithmetic backend by the application substrates (ANN, image).
#[derive(Clone, Copy, Debug)]
pub struct Simdive {
    pub bits: u32,
    pub w: u32,
}

impl Simdive {
    pub fn new(bits: u32, w: u32) -> Self {
        assert!(super::WIDTHS.contains(&bits), "unsupported width {bits}");
        assert!(w <= super::W_MAX);
        Simdive { bits, w }
    }

    #[inline]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        simdive_mul_w(self.bits, a, b, self.w)
    }

    #[inline]
    pub fn div(&self, a: u64, b: u64) -> u64 {
        simdive_div_w(self.bits, a, b, self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{exact, mitchell};

    #[test]
    fn w0_degenerates_to_mitchell() {
        for a in (1..256u64).step_by(7) {
            for b in (1..256u64).step_by(5) {
                assert_eq!(simdive_mul_w(8, a, b, 0), mitchell::mul(8, a, b));
                assert_eq!(simdive_div_w(8, a, b, 0), mitchell::div(8, a, b));
            }
        }
    }

    #[test]
    fn zero_conventions() {
        assert_eq!(simdive_mul(16, 0, 99), 0);
        assert_eq!(simdive_mul(16, 99, 0), 0);
        assert_eq!(simdive_div(16, 0, 99), 0);
        assert_eq!(simdive_div(16, 99, 0), 65535);
    }

    #[test]
    fn exhaustive_8bit_mul_error_bounds() {
        // Paper Table 2 row "Proposed": ARE 0.82%, PRE 4.9% at 16-bit.
        // Exhaustive at 8-bit lands in the same ARE regime; the PRE bound is
        // looser because tiny products quantize (e.g. 3×3 = 9 decodes to
        // 8.75 → floor 8, an unavoidable 1-ulp artifact at 8-bit).
        let (mut sum, mut peak, mut n) = (0.0f64, 0.0f64, 0u64);
        for a in 1..256u64 {
            for b in 1..256u64 {
                let ex = exact::mul(8, a, b);
                let ap = simdive_mul(8, a, b);
                let rel = (ex as f64 - ap as f64).abs() / ex as f64;
                sum += rel;
                peak = peak.max(rel);
                n += 1;
            }
        }
        let are = sum / n as f64 * 100.0;
        let pre = peak * 100.0;
        assert!(are < 1.2, "mul ARE {are:.3}%");
        assert!(pre < 12.0, "mul PRE {pre:.3}%");
    }

    #[test]
    fn sampled_16bit_mul_error_matches_table2() {
        // The paper's actual configuration: 16×16, uniform operands, errors
        // on the real-valued behavioral output (§4.1). Paper: ARE 0.82,
        // PRE 4.9.
        let mut rng = crate::util::Rng::new(1234);
        let (mut sum, mut peak, mut n) = (0.0f64, 0.0f64, 0u64);
        for _ in 0..1_000_000 {
            let a = rng.operand(16);
            let b = rng.operand(16);
            let ex = exact::mul(16, a, b) as f64;
            let rel = (ex - simdive_mul_real_w(16, a, b, 8)).abs() / ex;
            sum += rel;
            peak = peak.max(rel);
            n += 1;
        }
        let are = sum / n as f64 * 100.0;
        let pre = peak * 100.0;
        assert!(are < 1.1, "mul ARE {are:.3}%");
        assert!(pre < 6.5, "mul PRE {pre:.3}%");
    }

    #[test]
    fn div_16_8_error_matches_table2() {
        // Paper's divider scenario is 16/8 (16-bit dividend, 8-bit divisor).
        // Errors on the real-valued behavioral output vs the real quotient.
        // Paper: ARE 0.77%, PRE 5.24%.
        let (mut sum, mut peak, mut n) = (0.0f64, 0.0f64, 0u64);
        for a in (1..65536u64).step_by(3) {
            for b in 1..256u64 {
                if a < b {
                    continue; // quotient < 1: not part of the 16/8 use case
                }
                let real = a as f64 / b as f64;
                let ap = simdive_div_real_w(16, a, b, 8);
                let rel = (real - ap).abs() / real;
                sum += rel;
                peak = peak.max(rel);
                n += 1;
            }
        }
        let are = sum / n as f64 * 100.0;
        let pre = peak * 100.0;
        assert!(are < 1.3, "div ARE {are:.3}%");
        assert!(pre < 8.0, "div PRE {pre:.3}%");
    }

    #[test]
    fn integer_and_real_forms_agree_up_to_floor() {
        // The integer hardware output is the floor of the real-valued
        // behavioral output (within 1 ulp from internal fixed-point).
        crate::util::prop::check_operand_pairs(55, 20_000, 16, |a, b| {
            let real = simdive_mul_real_w(16, a, b, 8);
            let int = simdive_mul(16, a, b) as f64;
            if (int - real).abs() <= real * 1e-9 + 1.0 {
                Ok(())
            } else {
                Err(format!("{a}x{b}: int {int} vs real {real}"))
            }
        });
    }

    #[test]
    fn accuracy_improves_with_w_mul() {
        // More LUTs must not make the mean error worse (paper's knob).
        let mut prev = f64::INFINITY;
        for w in [0u32, 2, 4, 6, 8] {
            let mut sum = 0.0;
            let mut n = 0u64;
            for a in (1..256u64).step_by(3) {
                for b in (1..256u64).step_by(3) {
                    let ex = exact::mul(8, a, b) as f64;
                    let ap = simdive_mul_w(8, a, b, w) as f64;
                    sum += (ex - ap).abs() / ex;
                    n += 1;
                }
            }
            let are = sum / n as f64;
            assert!(
                are <= prev * 1.05,
                "w={w}: ARE {are} worse than previous {prev}"
            );
            prev = are;
        }
    }

    #[test]
    fn width_consistency_within_quantization() {
        // The same value pair at a wider width uses a longer fraction
        // datapath, so the correction is quantized differently (an 8-bit
        // unit has a 7-bit fraction; a 32-bit unit has 31). Results must
        // agree to within the coarser unit's quantization (< 2% relative).
        for a in [3u64, 43, 100, 255] {
            for b in [7u64, 10, 31, 254] {
                let m8 = simdive_mul(8, a, b) as f64;
                let m16 = simdive_mul(16, a, b) as f64;
                let m32 = simdive_mul(32, a, b) as f64;
                assert!((m8 - m16).abs() / m16.max(1.0) < 0.02, "{a}x{b}: {m8} vs {m16}");
                assert!((m16 - m32).abs() / m32.max(1.0) < 0.005, "{a}x{b}: {m16} vs {m32}");
                let d16 = simdive_div(16, a, b) as i64;
                let d32 = simdive_div(32, a, b) as i64;
                assert!((d16 - d32).abs() <= 1, "{a}/{b}: {d16} vs {d32}");
            }
        }
    }

    #[test]
    fn div_quotient_fits_width() {
        crate::util::prop::check_operand_pairs(11, 20_000, 16, |a, b| {
            let q = simdive_div(16, a, b);
            if q <= 65535 { Ok(()) } else { Err(format!("{a}/{b} -> {q}")) }
        });
    }

    #[test]
    fn mul_product_fits_2n() {
        crate::util::prop::check_operand_pairs(12, 20_000, 16, |a, b| {
            let p = simdive_mul(16, a, b);
            if p < (1u64 << 32) { Ok(()) } else { Err(format!("{a}*{b} -> {p}")) }
        });
    }

    #[test]
    fn paper_example_improves_over_mitchell() {
        // 43 × 10: accurate 430, Mitchell 408. SIMDive must be closer.
        let m = mitchell::mul(8, 43, 10) as i64;
        let s = simdive_mul(8, 43, 10) as i64;
        assert!((430 - s).abs() < (430 - m).abs(), "mitchell {m}, simdive {s}");
    }
}
