//! CA — area-optimized approximate array multiplier (Ullah et al.,
//! DAC'18 [30] / SMApproxLib-style), the paper's FPGA-customized
//! approximate-multiplier baseline.
//!
//! Modeled approximation: the multiplier reduces partial products with
//! row-pair carry-chain adders (the canonical 7-series mapping, see
//! `circuits::baselines::array_mul`), and the approximate variant *kills
//! the carries generated in the low two bits of every first-level row-pair
//! adder* — trading carry-chain segments for error exactly in the LSB
//! region, the approach of [30]. Composition into wider multipliers uses
//! exact upper adders, so — as the paper stresses in §4.2 — the error
//! *accumulates with operand size* because truncated blocks also feed
//! upper bit positions.
//!
//! The gate-level netlist (`circuits::baselines::ca_mul`) implements the
//! identical rule and is verified bit-exact against this model. Note: [30]
//! additionally shrinks LUT count through INIT-level logic optimization
//! that a structural mapper cannot reproduce; our CA area therefore tracks
//! the accurate array more closely than the paper's 245-vs-287 LUTs (the
//! deviation is recorded in EXPERIMENTS.md).

/// One first-level row pair: `rowA + 2·rowB` with carries *generated* in
/// bit positions 0–1 dropped (the carry chain starts at bit 2).
#[inline]
fn pair_sum_truncated(row_a: u64, row_b: u64) -> u64 {
    let x = row_a;
    let y = row_b << 1;
    // Low 2 bits add without carry out; upper bits add with cin = 0.
    let low = ((x & 3) + (y & 3)) & 3;
    let high = (x & !3) + (y & !3);
    high + low
}

/// CA approximate multiply: `bits`-wide operands, row-pair reduction with
/// truncated LSB carries at the first level, exact adder tree above.
pub fn ca_mul(bits: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    debug_assert!(bits % 2 == 0);
    let mut acc: u128 = 0;
    for j in 0..(bits / 2) {
        let row_a = if (b >> (2 * j)) & 1 == 1 { a } else { 0 };
        let row_b = if (b >> (2 * j + 1)) & 1 == 1 { a } else { 0 };
        acc += (pair_sum_truncated(row_a, row_b) as u128) << (2 * j);
    }
    let cap = if bits >= 32 { u64::MAX as u128 } else { (1u128 << (2 * bits)) - 1 };
    acc.min(cap) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact;

    #[test]
    fn pair_truncation_drops_only_low_carry() {
        // 3 + 2·3 = 9: low-2 sum = 3+2 = 5 → carry out of bit 1 dropped.
        assert_eq!(pair_sum_truncated(3, 3), 5);
        // No low-bit carry → exact.
        assert_eq!(pair_sum_truncated(4, 2), 8);
        assert_eq!(pair_sum_truncated(0, 7), 14);
    }

    #[test]
    fn ca_underestimates() {
        crate::util::prop::check_operand_pairs(3, 50_000, 16, |a, b| {
            let p = ca_mul(16, a, b);
            let e = exact::mul(16, a, b);
            if p <= e { Ok(()) } else { Err(format!("{a}*{b}: {p} > {e}")) }
        });
    }

    #[test]
    fn worst_case_small_operands() {
        // 3 × 3 = 9 → 5: the large-PRE / tiny-ARE signature of static
        // LSB approximation (paper reports PRE 19% for [30]'s variant;
        // our carry-kill variant peaks at 44% — see module docs).
        assert_eq!(ca_mul(16, 3, 3), 5);
    }

    #[test]
    fn are_is_small_at_16bit() {
        // Paper Table 2: CA ARE ≈ 0.3%.
        let mut rng = crate::util::Rng::new(2);
        let (mut sum, mut n) = (0.0, 0u64);
        for _ in 0..300_000 {
            let a = rng.operand(16);
            let b = rng.operand(16);
            let ex = exact::mul(16, a, b) as f64;
            sum += (ex - ca_mul(16, a, b) as f64) / ex;
            n += 1;
        }
        let are = sum / n as f64 * 100.0;
        assert!(are < 1.0, "CA ARE {are}%");
    }

    #[test]
    fn error_grows_with_width() {
        // §4.2 point 2: mean absolute error grows strongly with width.
        let mut rng = crate::util::Rng::new(4);
        let (mut abs16, mut abs32) = (0.0, 0.0);
        for _ in 0..100_000 {
            let a16 = rng.operand(16);
            let b16 = rng.operand(16);
            abs16 += (exact::mul(16, a16, b16) - ca_mul(16, a16, b16)) as f64;
            let a32 = rng.operand(32);
            let b32 = rng.operand(32);
            abs32 += (exact::mul(32, a32, b32) - ca_mul(32, a32, b32)) as f64;
        }
        assert!(abs32 / abs16 > 1000.0, "error must scale with width");
    }

    #[test]
    fn zero_and_identity() {
        assert_eq!(ca_mul(16, 0, 1234), 0);
        assert_eq!(ca_mul(16, 1234, 0), 0);
        assert_eq!(ca_mul(16, 1, 1), 1);
        // Powers of two never trigger the low-bit carries.
        assert_eq!(ca_mul(16, 256, 128), 256 * 128);
    }
}
