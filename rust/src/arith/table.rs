//! The paper's §3.3 light-weight error-reduction scheme: 64 correction
//! coefficients per operation, indexed by the 3 MSBs of each operand's
//! aligned fraction (8 × 8 regions), each bit of the coefficient produced by
//! one 6-LUT in hardware.
//!
//! Coefficients are the region-mean of the *analytically ideal* correction
//! (DESIGN.md §4 derives the closed forms from the paper's Eq. 7–8):
//!
//! * mul, `x1 + x2 < 1`:  `c = x1·x2`
//! * mul, `x1 + x2 ≥ 1`:  `c = (1 − x1)(1 − x2) / 2`
//! * div, `x1 ≥ x2`:      `c = x2(x2 − x1)/(1 + x2)`   (≤ 0)
//! * div, `x1 < x2`:      `c = (x1 − x2)(1 − x2)/(1 + x2)` (≤ 0)
//!
//! Tunable accuracy ("one more LUT = one more coefficient bit"): the stored
//! high-resolution coefficients are quantized to `W ∈ 0..=8` bits, keeping
//! bit positions `2^-3 .. 2^-(W+2)` with round-to-nearest at the kept LSB.
//! `W = 0` degenerates to pure Mitchell; `W = 8` is the paper's 8-LUT,
//! "99.2% accuracy" configuration.

use std::sync::OnceLock;

/// Fixed-point resolution (fractional bits) of the stored coefficients.
pub const TABLE_RESOLUTION_BITS: u32 = 12;

/// Maximum number of coefficient bits ("LUTs") supported.
pub const W_MAX: u32 = 8;

/// Samples per axis when averaging the ideal correction over a region.
const GRID: usize = 32;

/// Correction tables for one (mul, div) pair at a given tuning `w`.
///
/// Entries are signed fixed-point with [`TABLE_RESOLUTION_BITS`] fractional
/// bits. Multiplier entries are ≥ 0, divider entries ≤ 0.
///
/// Each table is stored twice: as the 8×8 grid the paper describes (and
/// the netlist generator consumes), and flattened to a single 64-entry
/// array indexed by [`Self::flat_index`] — one load with no nested bounds
/// arithmetic, which is what the batched kernels in
/// [`batch`](super::batch) index in their inner loops.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CorrectionTables {
    pub w: u32,
    pub mul: [[i32; 8]; 8],
    pub div: [[i32; 8]; 8],
    /// `mul` flattened: `mul_flat[flat_index(i, j)] == mul[i][j]`.
    pub mul_flat: [i32; 64],
    /// `div` flattened: `div_flat[flat_index(i, j)] == div[i][j]`.
    pub div_flat: [i32; 64],
}

impl CorrectionTables {
    /// Generate the tables for accuracy knob `w` (number of LUTs, 0..=8).
    pub fn generate(w: u32) -> Self {
        assert!(w <= W_MAX, "w must be 0..=8 (got {w})");
        let full = full_resolution();
        let mut mul = [[0i32; 8]; 8];
        let mut div = [[0i32; 8]; 8];
        for i in 0..8 {
            for j in 0..8 {
                mul[i][j] = quantize(full.0[i][j], w);
                div[i][j] = quantize(full.1[i][j], w);
            }
        }
        CorrectionTables::from_grids(w, mul, div)
    }

    /// Build tables from 8×8 coefficient grids, deriving the flat forms.
    pub fn from_grids(w: u32, mul: [[i32; 8]; 8], div: [[i32; 8]; 8]) -> Self {
        let mut mul_flat = [0i32; 64];
        let mut div_flat = [0i32; 64];
        for i in 0..8 {
            for j in 0..8 {
                mul_flat[Self::flat_index(i, j)] = mul[i][j];
                div_flat[Self::flat_index(i, j)] = div[i][j];
            }
        }
        CorrectionTables { w, mul, div, mul_flat, div_flat }
    }

    /// Index into the flat tables: `(region(a) << 3) | region(b)`.
    #[inline]
    pub fn flat_index(ra: usize, rb: usize) -> usize {
        (ra << 3) | rb
    }

    /// Scale a coefficient into `F = bits − 1` fraction-bit units for use
    /// in the Mitchell decode. Truncation is toward zero (on the
    /// *magnitude*), matching the hardware error-LUT bank, which produces
    /// magnitude bits and drops any below the F-grid ulp.
    #[inline]
    pub fn scale_to_f(coeff: i32, bits: u32) -> i64 {
        let f = bits - 1;
        let mag = coeff.unsigned_abs() as i64;
        let scaled = if f >= TABLE_RESOLUTION_BITS {
            mag << (f - TABLE_RESOLUTION_BITS)
        } else {
            mag >> (TABLE_RESOLUTION_BITS - f)
        };
        if coeff < 0 { -scaled } else { scaled }
    }

    /// Region index of an aligned fraction: its 3 MSBs.
    #[inline]
    pub fn region(bits: u32, frac: u64) -> usize {
        ((frac >> (bits - 1 - 3)) & 0x7) as usize
    }
}

/// Ideal multiplier correction at a fraction point.
fn ideal_mul(x1: f64, x2: f64) -> f64 {
    if x1 + x2 < 1.0 {
        x1 * x2
    } else {
        (1.0 - x1) * (1.0 - x2) / 2.0
    }
}

/// Ideal divider correction at a fraction point.
fn ideal_div(x1: f64, x2: f64) -> f64 {
    if x1 >= x2 {
        x2 * (x2 - x1) / (1.0 + x2)
    } else {
        (x1 - x2) * (1.0 - x2) / (1.0 + x2)
    }
}

/// Region means at full resolution, as real numbers. Cached: generation is
/// deterministic and cheap but called from many tests.
fn full_resolution() -> &'static ([[f64; 8]; 8], [[f64; 8]; 8]) {
    static CACHE: OnceLock<([[f64; 8]; 8], [[f64; 8]; 8])> = OnceLock::new();
    CACHE.get_or_init(|| {
        let mut mul = [[0.0f64; 8]; 8];
        let mut div = [[0.0f64; 8]; 8];
        for i in 0..8 {
            for j in 0..8 {
                let (mut sm, mut sd) = (0.0, 0.0);
                for gi in 0..GRID {
                    for gj in 0..GRID {
                        // Sample at cell centres of the region.
                        let x1 = (i as f64 + (gi as f64 + 0.5) / GRID as f64) / 8.0;
                        let x2 = (j as f64 + (gj as f64 + 0.5) / GRID as f64) / 8.0;
                        sm += ideal_mul(x1, x2);
                        sd += ideal_div(x1, x2);
                    }
                }
                let n = (GRID * GRID) as f64;
                mul[i][j] = sm / n;
                div[i][j] = sd / n;
            }
        }
        (mul, div)
    })
}

/// Quantize a real coefficient to `w` kept bits at positions
/// `2^-3 .. 2^-(w+2)`, returning fixed-point at [`TABLE_RESOLUTION_BITS`].
/// The magnitude is clamped to the representable range
/// `[0, 2^-2 − 2^-(w+2)]` so every kept bit maps to exactly one hardware
/// LUT output (the "one LUT per coefficient bit" property of §3.3).
fn quantize(c: f64, w: u32) -> i32 {
    if w == 0 {
        return 0;
    }
    // Step of the least-significant kept bit.
    let step = 2f64.powi(-((w + 2) as i32));
    let max = 0.25 - step;
    let q = ((c.abs() / step).round() * step).min(max) * c.signum();
    (q * (1i64 << TABLE_RESOLUTION_BITS) as f64).round() as i32
}

/// Global default tables (w = 8, the paper's full 8-LUT configuration).
pub fn default_tables() -> &'static CorrectionTables {
    static CACHE: OnceLock<CorrectionTables> = OnceLock::new();
    CACHE.get_or_init(|| CorrectionTables::generate(W_MAX))
}

/// Tables for every w, cached (used by the tunable-accuracy sweep).
pub fn tables_for(w: u32) -> &'static CorrectionTables {
    static CACHE: OnceLock<Vec<CorrectionTables>> = OnceLock::new();
    let all = CACHE.get_or_init(|| (0..=W_MAX).map(CorrectionTables::generate).collect());
    &all[w as usize]
}

/// Constant-coefficient tables modelling the MBM [28] + INZeD [29]
/// pairing: every multiplier region gets MBM's 1/16 and every divider
/// region INZeD's global constant. Running the SIMDive datapath with
/// these tables *is* the "MBM-INZeD" SIMD baseline of Table 3 (their
/// error-LUT bank folds to constants, which the netlist constant-folding
/// removes — reproducing the area difference structurally).
pub fn constant_tables() -> &'static CorrectionTables {
    static CACHE: OnceLock<CorrectionTables> = OnceLock::new();
    CACHE.get_or_init(|| {
        let res = 1i64 << TABLE_RESOLUTION_BITS;
        let mul_c = (res as f64 / 16.0).round() as i32;
        let div_c = (crate::arith::saadat::inzed_coeff() * res as f64).round() as i32;
        CorrectionTables::from_grids(W_MAX, [[mul_c; 8]; 8], [[div_c; 8]; 8])
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signs_match_theory() {
        let t = CorrectionTables::generate(8);
        for i in 0..8 {
            for j in 0..8 {
                assert!(t.mul[i][j] >= 0, "mul[{i}][{j}] = {}", t.mul[i][j]);
                assert!(t.div[i][j] <= 0, "div[{i}][{j}] = {}", t.div[i][j]);
            }
        }
    }

    #[test]
    fn w0_is_pure_mitchell() {
        let t = CorrectionTables::generate(0);
        assert_eq!(t.mul, [[0; 8]; 8]);
        assert_eq!(t.div, [[0; 8]; 8]);
    }

    #[test]
    fn monotone_refinement() {
        // Each extra LUT must not move a coefficient by more than the step
        // it refines (|c_w − c_{w+1}| ≤ 2^-(w+3) in real units).
        for w in 1..8u32 {
            let a = CorrectionTables::generate(w);
            let b = CorrectionTables::generate(w + 1);
            let tol =
                (2f64.powi(-((w + 3) as i32)) * (1 << TABLE_RESOLUTION_BITS) as f64) as i32 + 1;
            for i in 0..8 {
                for j in 0..8 {
                    assert!((a.mul[i][j] - b.mul[i][j]).abs() <= tol);
                    assert!((a.div[i][j] - b.div[i][j]).abs() <= tol);
                }
            }
        }
    }

    #[test]
    fn corner_regions_have_expected_magnitudes() {
        let t = CorrectionTables::generate(8);
        // Region (0,0): x1,x2 ∈ [0, 1/8) → ideal mul mean ≈ (1/16)^2.
        let c00 = t.mul[0][0] as f64 / (1 << TABLE_RESOLUTION_BITS) as f64;
        assert!((c00 - 1.0 / 256.0).abs() < 0.004, "c00 = {c00}");
        // Region (7,7): x1,x2 ∈ [7/8, 1) → case x1+x2 ≥ 1, mean ≈ (1/16)^2 / 2.
        let c77 = t.mul[7][7] as f64 / (1 << TABLE_RESOLUTION_BITS) as f64;
        assert!(c77 < 0.01, "c77 = {c77}");
        // Region (4,4) has x1+x2 ≥ 1 everywhere → c = mean (1−x1)(1−x2)/2
        // ≈ 0.4375²/2 ≈ 0.0957.
        let c44 = t.mul[4][4] as f64 / (1 << TABLE_RESOLUTION_BITS) as f64;
        assert!((c44 - 0.0957).abs() < 0.01, "c44 = {c44}");
        // The largest mul corrections sit just below the x1+x2 = 1 diagonal
        // (e.g. region (3,3): all case-1, mean x1x2 ≈ 0.4375² ≈ 0.1914).
        let c33 = t.mul[3][3] as f64 / (1 << TABLE_RESOLUTION_BITS) as f64;
        assert!(c33 > 0.15, "c33 = {c33}");
    }

    #[test]
    fn quantized_values_fit_lut_bit_positions() {
        // Every coefficient must be representable as w bits at positions
        // 2^-3 .. 2^-(w+2): |c12| < 1024 (bit 2^-2 clear) and a multiple of
        // the kept LSB.
        for w in 1..=8u32 {
            let t = CorrectionTables::generate(w);
            let lsb = 1i32 << (TABLE_RESOLUTION_BITS - 2 - w);
            for i in 0..8 {
                for j in 0..8 {
                    for v in [t.mul[i][j], t.div[i][j]] {
                        assert!(v.abs() < 1024, "w={w} [{i}][{j}]: {v} needs bit 2^-2");
                        assert_eq!(v % lsb, 0, "w={w} [{i}][{j}]: {v} not multiple of {lsb}");
                    }
                }
            }
        }
    }

    #[test]
    fn region_indexing() {
        // 8-bit: F = 7, top 3 bits of the 7-bit fraction.
        assert_eq!(CorrectionTables::region(8, 0b0000000), 0);
        assert_eq!(CorrectionTables::region(8, 0b1111111), 7);
        assert_eq!(CorrectionTables::region(8, 0b1010000), 5);
        // 32-bit: F = 31.
        assert_eq!(CorrectionTables::region(32, 0x7FFF_FFFF), 7);
        assert_eq!(CorrectionTables::region(32, 0x1000_0000), 1);
    }

    #[test]
    fn scale_to_f_truncates_magnitude() {
        assert!(CorrectionTables::scale_to_f(-100, 32) < 0);
        assert!(CorrectionTables::scale_to_f(100, 32) > 0);
        // F = 7 < 12: magnitude shift right by 5, sign restored.
        assert_eq!(CorrectionTables::scale_to_f(-32, 8), -1);
        assert_eq!(CorrectionTables::scale_to_f(32, 8), 1);
        // Sub-ulp magnitudes truncate to zero for either sign (the
        // hardware bank drops bits below the F grid).
        assert_eq!(CorrectionTables::scale_to_f(-16, 8), 0);
        assert_eq!(CorrectionTables::scale_to_f(16, 8), 0);
    }

    #[test]
    fn cached_generation_consistent() {
        assert_eq!(tables_for(8), default_tables());
        assert_eq!(tables_for(3), &CorrectionTables::generate(3));
    }

    #[test]
    fn flat_tables_mirror_grids() {
        for w in 0..=W_MAX {
            let t = tables_for(w);
            for i in 0..8 {
                for j in 0..8 {
                    let k = CorrectionTables::flat_index(i, j);
                    assert_eq!(t.mul_flat[k], t.mul[i][j], "w={w} mul[{i}][{j}]");
                    assert_eq!(t.div_flat[k], t.div[i][j], "w={w} div[{i}][{j}]");
                }
            }
        }
        let c = constant_tables();
        assert!(c.mul_flat.iter().all(|&v| v == c.mul[0][0]));
        assert!(c.div_flat.iter().all(|&v| v == c.div[0][0]));
    }
}
