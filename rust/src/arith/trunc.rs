//! Truncated multipliers — the "Trunc" baselines of Table 2 and the
//! `31x7` SIMD baseline of Table 3.
//!
//! A 16×16 multiplier composed of `p×q`-bit elementary instances cannot
//! carry all operand bits: building it from four 7×7 instances means each
//! 8-bit operand half is truncated to its top 7 bits (the LSB of every
//! 8-bit segment is dropped); from two 15×7 instances, operand A keeps 15
//! of 16 bits while B's segments are truncated to 7. Truncation is static
//! (no leading-one alignment), which is exactly why the peak relative error
//! is 100% — tiny operands truncate to zero (Table 2 PRE column).

/// Mask that keeps the top 7 bits of every 8-bit operand segment.
#[inline]
fn seg7_mask(bits: u32) -> u64 {
    debug_assert!(bits % 8 == 0);
    let mut m = 0u64;
    for s in 0..(bits / 8) {
        m |= 0xFEu64 << (8 * s);
    }
    m
}

/// Truncated multiply from `p×7`-style instances: `a` keeps `pa` ∈
/// {bits−1, seg7} pattern encoded by masks below.
#[inline]
pub fn masked_mul(a: u64, am: u64, b: u64, bm: u64) -> u64 {
    (a & am).wrapping_mul(b & bm)
}

/// Table 2 baseline: 16×16 built from four 7×7 instances — both operands
/// lose the LSB of each 8-bit segment.
#[inline]
pub fn trunc_four_7x7(a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, 16) && super::fits(b, 16));
    masked_mul(a, seg7_mask(16), b, seg7_mask(16))
}

/// Table 2 baseline: 16×16 built from two 15×7 instances — A keeps its top
/// 15 bits, B loses the LSB of each 8-bit segment.
#[inline]
pub fn trunc_two_15x7(a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, 16) && super::fits(b, 16));
    masked_mul(a, 0xFFFE, b, seg7_mask(16))
}

/// Table 3 SIMD baseline: 32×32 using 31×7 instances (same pattern at 32
/// bits: A keeps 31 bits, B's four segments keep 7 each).
#[inline]
pub fn trunc_31x7(a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, 32) && super::fits(b, 32));
    masked_mul(a, 0xFFFF_FFFE, b, seg7_mask(32))
}

/// Generic form used by the design registry: `seven_a`/`seven_b` selects
/// segment-truncation for that operand, otherwise only the LSB is dropped.
#[inline]
pub fn trunc_mul(bits: u32, seven_a: bool, seven_b: bool, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let am = if seven_a { seg7_mask(bits) } else { super::max_val(bits) & !1 };
    let bm = if seven_b { seg7_mask(bits) } else { super::max_val(bits) & !1 };
    masked_mul(a, am, b, bm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact;

    #[test]
    fn masks_drop_expected_bits() {
        assert_eq!(seg7_mask(16), 0xFEFE);
        assert_eq!(seg7_mask(32), 0xFEFE_FEFE);
        // 0x0101 has only segment LSBs set → truncates to zero entirely.
        assert_eq!(trunc_four_7x7(0x0101, 0x0101), 0);
        // Bits above the segment LSBs survive.
        assert_eq!(trunc_four_7x7(0x0202, 0x0202), 0x0202 * 0x0202);
    }

    #[test]
    fn truncation_never_overestimates() {
        crate::util::prop::check_operand_pairs(8, 50_000, 16, |a, b| {
            let e = exact::mul(16, a, b);
            for p in [trunc_four_7x7(a, b), trunc_two_15x7(a, b)] {
                if p > e {
                    return Err(format!("{a}*{b}: {p} > {e}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn peak_error_is_100_percent() {
        // Static truncation zeroes tiny operands → 100% relative error.
        assert_eq!(trunc_four_7x7(1, 1), 0);
        assert_eq!(trunc_two_15x7(3, 1), 0); // b=1 segment-truncates to 0
        assert_eq!(trunc_31x7(1, 1), 0);
    }

    #[test]
    fn error_ordering_matches_table2() {
        // Paper: ARE(four 7x7) = 2.35% vs ARE(two 15x7) = 1.19% — the
        // one-sided truncation must be roughly 2x more accurate.
        let mut rng = crate::util::Rng::new(5);
        let (mut e77, mut e157, mut n) = (0.0, 0.0, 0u64);
        for _ in 0..300_000 {
            let a = rng.operand(16);
            let b = rng.operand(16);
            let ex = exact::mul(16, a, b) as f64;
            e77 += (ex - trunc_four_7x7(a, b) as f64).abs() / ex;
            e157 += (ex - trunc_two_15x7(a, b) as f64).abs() / ex;
            n += 1;
        }
        let (are77, are157) = (e77 / n as f64 * 100.0, e157 / n as f64 * 100.0);
        assert!(are157 < are77, "15x7 {are157}% must beat 7x7 {are77}%");
        assert!(
            are77 > 2.0 * are157 * 0.5 && are77 < 4.0 * are157,
            "ratio off: {are77} vs {are157}"
        );
        assert!(are77 < 6.0, "7x7 ARE {are77}%");
        assert!(are157 < 3.0, "15x7 ARE {are157}%");
    }

    #[test]
    fn full_lsb_only_is_nearly_exact() {
        // Dropping only the LSBs (no seven-segment truncation) is the most
        // accurate configuration of the family.
        let mut rng = crate::util::Rng::new(6);
        let (mut e, mut n) = (0.0, 0u64);
        for _ in 0..100_000 {
            let a = rng.operand(16);
            let b = rng.operand(16);
            let ex = exact::mul(16, a, b) as f64;
            e += (ex - trunc_mul(16, false, false, a, b) as f64).abs() / ex;
            n += 1;
        }
        let are = e / n as f64;
        assert!(are < 0.005, "lsb-only ARE {are}");
    }

    #[test]
    fn product_fits_2n() {
        crate::util::prop::check_operand_pairs(9, 20_000, 16, |a, b| {
            let p = trunc_four_7x7(a, b);
            if p < (1u64 << 32) { Ok(()) } else { Err(format!("{a}*{b} -> {p}")) }
        });
    }

    #[test]
    fn simd_31x7_consistent_with_16bit_pattern() {
        // The 32-bit variant applies the same per-segment rule.
        let a = 0x0001_0101u64;
        let b = 0x0101_0101u64;
        assert_eq!(trunc_31x7(a, b), (a & 0xFFFF_FFFE) * (b & 0xFEFE_FEFE));
    }
}
