//! Batched (slice-based) SIMDive kernels — the software hot path.
//!
//! The scalar entry points in [`simdive`](super::simdive) resolve the
//! correction tables (`OnceLock` + `Vec` indexing), the operand width, and
//! the fixed-point rescale of the coefficient *per call*. Fine for the
//! error-analysis sweeps; wasteful for the substrates that evaluate
//! millions of products per request (quantized ANN inference, image
//! tiles, the coordinator's packed words).
//!
//! These kernels take whole operand slices plus one [`CorrectionTables`]
//! reference and hoist everything loop-invariant out of the inner loop:
//!
//! * the 8×8 coefficient grid is read through its flattened 64-entry form
//!   ([`CorrectionTables::mul_flat`]), indexed by
//!   `(region(a) << 3) | region(b)` — one load, no nested indexing;
//! * the per-region [`CorrectionTables::scale_to_f`] rescale is
//!   precomputed into a 64-entry `i64` array per call (it depends only on
//!   the coefficient and the width, not the operands);
//! * the inner loop carries no `assert!`, no `Vec` indexing and no table
//!   resolution — only `debug_assert!` — leaving a short dependency chain
//!   of `lzcnt`/shift/add per element that LLVM can unroll and schedule
//!   (and partially vectorize) freely.
//!
//! At 8-bit width the integer batch entries go one step further: whenever
//! the rescaled correction grid fits the SWAR guard-bit budget
//! ([`swar::Swar8::try_new`] — always true for the generated tables), the
//! slice is processed four lanes per `u64` through [`swar`], and
//! [`WordKernel`]/[`MultiKernel`] route whole
//! [`LaneCfg::Four8`](super::simd::LaneCfg::Four8) words through
//! [`swar::Swar8::exec4`]. The lane-wise loops remain as
//! [`mul_batch_lanewise_into`]/[`div_batch_lanewise_into`] — the fallback
//! for off-budget tables and the baseline the benches and property tests
//! compare against.
//!
//! Every kernel is **bit-identical** to the scalar path: the per-element
//! arithmetic is the same [`frac_aligned`] → correction → decode pipeline,
//! verified by the property tests below and in `tests/batch_props.rs`.

use std::num::NonZeroU64;

use super::mitchell::{div_decode, div_decode_real, frac_aligned, mul_decode, mul_decode_real};
use super::simd::{LaneCfg, LaneMode, SimdOp, SimdWord};
use super::swar;
use super::table::{tables_for, CorrectionTables, W_MAX};

/// Per-call context for one operation kind at one width: the flat
/// coefficient grid rescaled to `F = bits - 1` fraction-bit units.
#[derive(Clone, Copy)]
struct Rescaled {
    corr: [i64; 64],
}

impl Rescaled {
    #[inline]
    fn new(flat: &[i32; 64], bits: u32) -> Self {
        let mut corr = [0i64; 64];
        for (k, &c) in flat.iter().enumerate() {
            corr[k] = CorrectionTables::scale_to_f(c, bits);
        }
        Rescaled { corr }
    }
}

/// Region-pair index of two aligned fractions: `(region(f1) << 3) |
/// region(f2)`, matching [`CorrectionTables::flat_index`].
#[inline(always)]
fn pair_index(region_shift: u32, f1: u64, f2: u64) -> usize {
    ((((f1 >> region_shift) & 0x7) << 3) | ((f2 >> region_shift) & 0x7)) as usize
}

/// One batched multiply element. Identical arithmetic to
/// [`simdive_mul_with`](super::simdive::simdive_mul_with).
#[inline(always)]
fn mul_one(rc: &Rescaled, bits: u32, region_shift: u32, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = rc.corr[pair_index(region_shift, f1, f2)];
    mul_decode(bits, k1, k2, f1 as i64 + f2 as i64 + corr)
}

/// One batched divide element. Identical arithmetic to
/// [`simdive_div_with`](super::simdive::simdive_div_with).
#[inline(always)]
fn div_one(rc: &Rescaled, bits: u32, region_shift: u32, max: u64, a: u64, b: u64) -> u64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let Some(b) = NonZeroU64::new(b) else {
        return max;
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = rc.corr[pair_index(region_shift, f1, f2)];
    div_decode(bits, k1, k2, f1 as i64 - f2 as i64 + corr)
}

/// Batched SIMDive multiply: `out[i] = simdive_mul_with(t, bits, a[i],
/// b[i])`, bit-exactly, with all table/width resolution hoisted out of the
/// loop. Slices must have equal length.
///
/// At `bits == 8` with an in-budget table this runs four lanes per `u64`
/// through the [`swar`] kernel (lane-wise tail for the last `len % 4`
/// elements); otherwise it is [`mul_batch_lanewise_into`].
pub fn mul_batch_into(t: &CorrectionTables, bits: u32, a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    if bits == 8 {
        if let Some(k) = swar::Swar8::try_new(t) {
            let main = a.len() - a.len() % 4;
            for ((o, ac), bc) in out[..main]
                .chunks_exact_mut(4)
                .zip(a[..main].chunks_exact(4))
                .zip(b[..main].chunks_exact(4))
            {
                swar::unpack4(k.mul4(swar::pack4(ac), swar::pack4(bc)), o);
            }
            mul_batch_lanewise_into(t, bits, &a[main..], &b[main..], &mut out[main..]);
            return;
        }
    }
    mul_batch_lanewise_into(t, bits, a, b, out);
}

/// Lane-wise form of [`mul_batch_into`]: one [`frac_aligned`] → correct →
/// decode chain per element, at any width. Public as the SWAR fallback and
/// as the baseline `benches/hotpath.rs` measures the packed speedup
/// against.
pub fn mul_batch_lanewise_into(
    t: &CorrectionTables,
    bits: u32,
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let rc = Rescaled::new(&t.mul_flat, bits);
    let region_shift = bits - 4;
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = mul_one(&rc, bits, region_shift, x, y);
    }
}

/// Allocating form of [`mul_batch_into`].
pub fn mul_batch(t: &CorrectionTables, bits: u32, a: &[u64], b: &[u64]) -> Vec<u64> {
    let mut out = vec![0u64; a.len()];
    mul_batch_into(t, bits, a, b, &mut out);
    out
}

/// Batched SIMDive divide: `out[i] = simdive_div_with(t, bits, a[i],
/// b[i])`, bit-exactly (`b == 0 → max_val(bits)`, `a == 0 → 0`). Slices
/// must have equal length.
///
/// At `bits == 8` with an in-budget table this runs four lanes per `u64`
/// through the [`swar`] kernel, like [`mul_batch_into`].
pub fn div_batch_into(t: &CorrectionTables, bits: u32, a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    if bits == 8 {
        if let Some(k) = swar::Swar8::try_new(t) {
            let main = a.len() - a.len() % 4;
            for ((o, ac), bc) in out[..main]
                .chunks_exact_mut(4)
                .zip(a[..main].chunks_exact(4))
                .zip(b[..main].chunks_exact(4))
            {
                swar::unpack4(k.div4(swar::pack4(ac), swar::pack4(bc)), o);
            }
            div_batch_lanewise_into(t, bits, &a[main..], &b[main..], &mut out[main..]);
            return;
        }
    }
    div_batch_lanewise_into(t, bits, a, b, out);
}

/// Lane-wise form of [`div_batch_into`]: the SWAR fallback and the bench
/// baseline, like [`mul_batch_lanewise_into`].
pub fn div_batch_lanewise_into(
    t: &CorrectionTables,
    bits: u32,
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let rc = Rescaled::new(&t.div_flat, bits);
    let region_shift = bits - 4;
    let max = super::max_val(bits);
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = div_one(&rc, bits, region_shift, max, x, y);
    }
}

/// Allocating form of [`div_batch_into`].
pub fn div_batch(t: &CorrectionTables, bits: u32, a: &[u64], b: &[u64]) -> Vec<u64> {
    let mut out = vec![0u64; a.len()];
    div_batch_into(t, bits, a, b, &mut out);
    out
}

/// One batched real-valued multiply element. Identical arithmetic to
/// [`simdive_mul_real_w`](super::simdive::simdive_mul_real_w) — the
/// behavioral error-analysis form (paper §4.1).
#[inline(always)]
fn mul_one_real(rc: &Rescaled, bits: u32, region_shift: u32, a: u64, b: u64) -> f64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let (Some(a), Some(b)) = (NonZeroU64::new(a), NonZeroU64::new(b)) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = rc.corr[pair_index(region_shift, f1, f2)];
    mul_decode_real(bits, k1, k2, f1 as i64 + f2 as i64 + corr)
}

/// One batched real-valued divide element. Identical arithmetic to
/// [`simdive_div_real_w`](super::simdive::simdive_div_real_w).
#[inline(always)]
fn div_one_real(rc: &Rescaled, bits: u32, region_shift: u32, max: f64, a: u64, b: u64) -> f64 {
    debug_assert!(super::fits(a, bits) && super::fits(b, bits));
    let Some(b) = NonZeroU64::new(b) else {
        return max;
    };
    let Some(a) = NonZeroU64::new(a) else {
        return 0.0;
    };
    let (k1, f1) = frac_aligned(bits, a);
    let (k2, f2) = frac_aligned(bits, b);
    let corr = rc.corr[pair_index(region_shift, f1, f2)];
    div_decode_real(bits, k1, k2, f1 as i64 - f2 as i64 + corr)
}

/// Batched real-valued SIMDive multiply: `out[i] =
/// simdive_mul_real_w(bits, a[i], b[i], t.w)` exactly, with the table
/// resolution and coefficient rescale hoisted out of the loop. This is
/// what the error sweeps (`metrics::error`, the Table-2/tunable reports)
/// evaluate through the engine seam instead of one scalar dispatch per
/// sample.
pub fn mul_real_batch_into(t: &CorrectionTables, bits: u32, a: &[u64], b: &[u64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let rc = Rescaled::new(&t.mul_flat, bits);
    let region_shift = bits - 4;
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = mul_one_real(&rc, bits, region_shift, x, y);
    }
}

/// Batched real-valued SIMDive divide: `out[i] = simdive_div_real_w(bits,
/// a[i], b[i], t.w)` exactly (`b == 0 → max_val(bits)` as a real).
pub fn div_real_batch_into(t: &CorrectionTables, bits: u32, a: &[u64], b: &[u64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let rc = Rescaled::new(&t.div_flat, bits);
    let region_shift = bits - 4;
    let max = super::max_val(bits) as f64;
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = div_one_real(&rc, bits, region_shift, max, x, y);
    }
}

/// Rescaled mul+div coefficient grids for every lane width, computed once
/// per batch (widths are 8/16/32 → index `log2(width) - 3`), plus the
/// packed 4×8-bit kernel when the table fits its guard-bit budget.
struct WordContext {
    mul: [Rescaled; 3],
    div: [Rescaled; 3],
    /// `Some` whenever the rescaled grids fit the SWAR budget — always,
    /// for generated tables. `Four8` words then execute packed.
    swar8: Option<swar::Swar8>,
}

impl WordContext {
    fn new(t: &CorrectionTables) -> Self {
        WordContext {
            mul: [
                Rescaled::new(&t.mul_flat, 8),
                Rescaled::new(&t.mul_flat, 16),
                Rescaled::new(&t.mul_flat, 32),
            ],
            div: [
                Rescaled::new(&t.div_flat, 8),
                Rescaled::new(&t.div_flat, 16),
                Rescaled::new(&t.div_flat, 32),
            ],
            swar8: swar::Swar8::try_new(t),
        }
    }

    /// Execute one packed word; bit-identical to
    /// [`simd::execute_with`](super::simd::execute_with). `Four8` words
    /// take the packed SWAR datapath when available; everything else (and
    /// the off-budget fallback) is the lane-wise loop.
    #[inline]
    fn execute(&self, op: SimdOp, word: SimdWord) -> u64 {
        if op.cfg == LaneCfg::Four8 {
            if let Some(k) = &self.swar8 {
                return k.exec4(
                    swar::mul_lane_mask(&op.modes),
                    swar::spread_bytes(word.a),
                    swar::spread_bytes(word.b),
                );
            }
        }
        self.execute_lanewise(op, word)
    }

    /// The per-lane reference loop behind [`WordContext::execute`].
    #[inline]
    fn execute_lanewise(&self, op: SimdOp, word: SimdWord) -> u64 {
        let mut out = 0u64;
        for (i, &(off, width)) in op.cfg.lanes().iter().enumerate() {
            let (a, b) = word.lane(op.cfg, i);
            let widx = (width.trailing_zeros() - 3) as usize;
            let region_shift = width - 4;
            let r = match op.modes[i] {
                LaneMode::Mul => mul_one(&self.mul[widx], width, region_shift, a, b),
                LaneMode::Div => {
                    div_one(&self.div[widx], width, region_shift, super::max_val(width), a, b)
                }
            };
            debug_assert!(width == 32 || r < (1u64 << (2 * width)));
            out |= r << (2 * off);
        }
        out
    }
}

/// Reusable packed-word kernel: the six per-width coefficient rescales of
/// a [`CorrectionTables`] hoisted once at construction, so long-lived
/// executors (the coordinator workers) pay the setup once per thread
/// rather than once per dispatched chunk.
pub struct WordKernel {
    ctx: WordContext,
}

impl WordKernel {
    pub fn new(t: &CorrectionTables) -> Self {
        WordKernel { ctx: WordContext::new(t) }
    }

    /// Execute one packed word; bit-identical to
    /// [`simd::execute_with`](super::simd::execute_with).
    #[inline]
    pub fn execute(&self, op: SimdOp, word: SimdWord) -> u64 {
        self.ctx.execute(op, word)
    }

    /// Execute a chunk of packed words into `out` (equal lengths).
    pub fn execute_into(&self, ops: &[SimdOp], words: &[SimdWord], out: &mut [u64]) {
        debug_assert_eq!(ops.len(), words.len());
        debug_assert_eq!(ops.len(), out.len());
        for ((o, &op), &word) in out.iter_mut().zip(ops).zip(words) {
            *o = self.ctx.execute(op, word);
        }
    }
}

/// Mixed-accuracy packed-word kernel: one rescaled context per accuracy
/// knob `w ∈ 0..=W_MAX`, all built at construction. This is the kernel
/// entry of coordinator v2 (DESIGN.md §9): a single shared worker pool
/// executes words of *any* `{bits, w}` mix, so per-word `w` tags select
/// the correction tables with one index — no per-word table resolution
/// and no per-`w` worker pools.
///
/// Bit-identical to `simd::execute_with(tables_for(w), op, word)` for
/// every word (property-tested in `tests/batch_props.rs`).
pub struct MultiKernel {
    /// Indexed by `w`.
    ctxs: Vec<WordContext>,
}

impl MultiKernel {
    /// Build contexts for every accuracy knob (9 × ~3 KB of rescaled
    /// coefficients — cheap enough to pay once per worker thread).
    pub fn new() -> Self {
        MultiKernel { ctxs: (0..=W_MAX).map(|w| WordContext::new(tables_for(w))).collect() }
    }

    /// Execute one packed word at accuracy knob `w`.
    #[inline]
    pub fn execute(&self, w: u32, op: SimdOp, word: SimdWord) -> u64 {
        debug_assert!(w <= W_MAX);
        self.ctxs[w as usize].execute(op, word)
    }

    /// The packed 4×8-bit kernel at accuracy knob `w`, when the table fits
    /// the SWAR budget. The sharded engine uses this to stage `Four8`
    /// words through the decode → approx → correct → assemble pipeline;
    /// `None` means the word must go through [`MultiKernel::execute`].
    #[inline]
    pub fn swar8(&self, w: u32) -> Option<&swar::Swar8> {
        debug_assert!(w <= W_MAX);
        self.ctxs[w as usize].swar8.as_ref()
    }

    /// Execute a chunk of packed words with per-word accuracy knobs into
    /// `out` (all slices of equal length).
    pub fn execute_mixed_into(
        &self,
        ws: &[u32],
        ops: &[SimdOp],
        words: &[SimdWord],
        out: &mut [u64],
    ) {
        debug_assert_eq!(ws.len(), ops.len());
        debug_assert_eq!(ws.len(), words.len());
        debug_assert_eq!(ws.len(), out.len());
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.execute(ws[i], ops[i], words[i]);
        }
    }
}

impl Default for MultiKernel {
    fn default() -> Self {
        Self::new()
    }
}

/// Batched packed-word execution: `out[i] = simd::execute_with(t, ops[i],
/// words[i])`, bit-exactly, with the six per-width coefficient rescales
/// hoisted out of the loop. One-shot form of [`WordKernel`].
pub fn execute_words_into(
    t: &CorrectionTables,
    ops: &[SimdOp],
    words: &[SimdWord],
    out: &mut [u64],
) {
    WordKernel::new(t).execute_into(ops, words, out);
}

/// Allocating form of [`execute_words_into`].
pub fn execute_words(t: &CorrectionTables, ops: &[SimdOp], words: &[SimdWord]) -> Vec<u64> {
    let mut out = vec![0u64; ops.len()];
    execute_words_into(t, ops, words, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simd::{self, LaneCfg};
    use crate::arith::simdive::{simdive_div_with, simdive_mul_with};
    use crate::arith::table::tables_for;
    use crate::util::Rng;

    #[test]
    fn mul_batch_matches_scalar_exhaustive_8bit() {
        let t = tables_for(8);
        let a: Vec<u64> = (0..256u64).collect();
        for bv in 0..256u64 {
            let b = vec![bv; 256];
            let got = mul_batch(t, 8, &a, &b);
            for (i, &g) in got.iter().enumerate() {
                assert_eq!(g, simdive_mul_with(t, 8, a[i], bv), "{}*{bv}", a[i]);
            }
        }
    }

    #[test]
    fn div_batch_matches_scalar_exhaustive_8bit() {
        let t = tables_for(8);
        let a: Vec<u64> = (0..256u64).collect();
        for bv in 0..256u64 {
            let b = vec![bv; 256];
            let got = div_batch(t, 8, &a, &b);
            for (i, &g) in got.iter().enumerate() {
                assert_eq!(g, simdive_div_with(t, 8, a[i], bv), "{}/{bv}", a[i]);
            }
        }
    }

    #[test]
    fn batch_matches_scalar_all_widths_and_w() {
        let mut rng = Rng::new(0xBA7C);
        for &bits in &crate::arith::WIDTHS {
            for w in 0..=crate::arith::W_MAX {
                let t = tables_for(w);
                let a: Vec<u64> = (0..512).map(|_| rng.below(1u64 << bits)).collect();
                let b: Vec<u64> = (0..512).map(|_| rng.below(1u64 << bits)).collect();
                let m = mul_batch(t, bits, &a, &b);
                let d = div_batch(t, bits, &a, &b);
                for i in 0..a.len() {
                    assert_eq!(
                        m[i],
                        simdive_mul_with(t, bits, a[i], b[i]),
                        "mul w={w} bits={bits}"
                    );
                    assert_eq!(
                        d[i],
                        simdive_div_with(t, bits, a[i], b[i]),
                        "div w={w} bits={bits}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_conventions_survive_batching() {
        let t = tables_for(8);
        for &bits in &crate::arith::WIDTHS {
            let a = [0u64, 99, 0, crate::arith::max_val(bits)];
            let b = [99u64, 0, 0, 0];
            let m = mul_batch(t, bits, &a, &b);
            assert_eq!(m, vec![0, 0, 0, 0]);
            let d = div_batch(t, bits, &a, &b);
            assert_eq!(d[0], 0, "0/x must be 0");
            assert_eq!(d[1], crate::arith::max_val(bits), "x/0 must saturate");
            assert_eq!(d[2], crate::arith::max_val(bits), "0/0 follows b==0 first");
            assert_eq!(d[3], crate::arith::max_val(bits));
        }
    }

    #[test]
    fn swar_batch_tail_and_lanewise_agree() {
        let mut rng = Rng::new(0x51AA);
        for w in 0..=crate::arith::W_MAX {
            let t = tables_for(w);
            for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 63] {
                let a: Vec<u64> = (0..len).map(|_| rng.below(256)).collect();
                let b: Vec<u64> = (0..len).map(|_| rng.below(256)).collect();
                let mut fast = vec![0u64; len];
                let mut lane = vec![0u64; len];
                mul_batch_into(t, 8, &a, &b, &mut fast);
                mul_batch_lanewise_into(t, 8, &a, &b, &mut lane);
                assert_eq!(fast, lane, "mul w={w} len={len}");
                div_batch_into(t, 8, &a, &b, &mut fast);
                div_batch_lanewise_into(t, 8, &a, &b, &mut lane);
                assert_eq!(fast, lane, "div w={w} len={len}");
            }
        }
    }

    #[test]
    fn empty_batches_are_fine() {
        let t = tables_for(8);
        assert!(mul_batch(t, 16, &[], &[]).is_empty());
        assert!(div_batch(t, 16, &[], &[]).is_empty());
        assert!(execute_words(t, &[], &[]).is_empty());
    }

    #[test]
    fn multi_kernel_matches_per_w_word_kernels() {
        let mk = MultiKernel::new();
        let mut rng = Rng::new(0x3317);
        for w in 0..=crate::arith::W_MAX {
            let single = WordKernel::new(tables_for(w));
            for _ in 0..100 {
                let cfg = LaneCfg::ALL[rng.below(4) as usize];
                let lanes = cfg.lanes();
                let a: Vec<u64> = lanes.iter().map(|&(_, wd)| rng.below(1u64 << wd)).collect();
                let b: Vec<u64> = lanes.iter().map(|&(_, wd)| rng.below(1u64 << wd)).collect();
                let mut modes = [LaneMode::Mul; 4];
                for m in modes.iter_mut() {
                    if rng.below(2) == 1 {
                        *m = LaneMode::Div;
                    }
                }
                let op = SimdOp { cfg, modes };
                let word = SimdWord::pack(cfg, &a, &b);
                assert_eq!(mk.execute(w, op, word), single.execute(op, word), "w={w}");
            }
        }
    }

    #[test]
    fn execute_mixed_into_matches_scalar_path() {
        let mk = MultiKernel::new();
        let mut rng = Rng::new(0x3318);
        let mut ws = Vec::new();
        let mut ops = Vec::new();
        let mut words = Vec::new();
        for _ in 0..300 {
            let cfg = LaneCfg::ALL[rng.below(4) as usize];
            let lanes = cfg.lanes();
            let a: Vec<u64> = lanes.iter().map(|&(_, wd)| rng.below(1u64 << wd)).collect();
            let b: Vec<u64> = lanes.iter().map(|&(_, wd)| rng.below(1u64 << wd)).collect();
            let mut modes = [LaneMode::Mul; 4];
            for m in modes.iter_mut() {
                if rng.below(2) == 1 {
                    *m = LaneMode::Div;
                }
            }
            ws.push(rng.below(crate::arith::W_MAX as u64 + 1) as u32);
            ops.push(SimdOp { cfg, modes });
            words.push(SimdWord::pack(cfg, &a, &b));
        }
        let mut out = vec![0u64; ws.len()];
        mk.execute_mixed_into(&ws, &ops, &words, &mut out);
        for i in 0..ws.len() {
            assert_eq!(
                out[i],
                simd::execute_with(tables_for(ws[i]), ops[i], words[i]),
                "word {i} at w={}",
                ws[i]
            );
        }
    }

    #[test]
    fn real_batch_matches_scalar_real_all_widths_and_w() {
        use crate::arith::simdive::{simdive_div_real_w, simdive_mul_real_w};
        let mut rng = Rng::new(0xF10A);
        for &bits in &crate::arith::WIDTHS {
            for w in [0u32, 3, 8] {
                let t = tables_for(w);
                let mut a: Vec<u64> = (0..256).map(|_| rng.below(1u64 << bits)).collect();
                let b: Vec<u64> = (0..256).map(|_| rng.below(1u64 << bits)).collect();
                a[0] = 0; // exercise the zero conventions too
                let mut m = vec![0.0f64; a.len()];
                let mut d = vec![0.0f64; a.len()];
                mul_real_batch_into(t, bits, &a, &b, &mut m);
                div_real_batch_into(t, bits, &a, &b, &mut d);
                for i in 0..a.len() {
                    assert_eq!(
                        m[i],
                        simdive_mul_real_w(bits, a[i], b[i], w),
                        "mul w={w} bits={bits}"
                    );
                    assert_eq!(
                        d[i],
                        simdive_div_real_w(bits, a[i], b[i], w),
                        "div w={w} bits={bits}"
                    );
                }
            }
        }
    }

    #[test]
    fn real_batch_zero_divisor_saturates() {
        let t = tables_for(8);
        let mut out = [0.0f64; 2];
        div_real_batch_into(t, 16, &[100, 0], &[0, 0], &mut out);
        assert_eq!(out[0], 65535.0);
        assert_eq!(out[1], 65535.0, "0/0 follows b==0 first");
    }

    #[test]
    fn execute_words_matches_simd_execute() {
        let mut rng = Rng::new(0x51D);
        for w in [0u32, 4, 8] {
            let t = tables_for(w);
            let mut ops = Vec::new();
            let mut words = Vec::new();
            for _ in 0..400 {
                let cfg = LaneCfg::ALL[rng.below(4) as usize];
                let lanes = cfg.lanes();
                let ops_a: Vec<u64> = lanes.iter().map(|&(_, wd)| rng.below(1u64 << wd)).collect();
                let ops_b: Vec<u64> = lanes.iter().map(|&(_, wd)| rng.below(1u64 << wd)).collect();
                let mut modes = [LaneMode::Mul; 4];
                for m in modes.iter_mut() {
                    if rng.below(2) == 1 {
                        *m = LaneMode::Div;
                    }
                }
                ops.push(SimdOp { cfg, modes });
                words.push(SimdWord::pack(cfg, &ops_a, &ops_b));
            }
            let got = execute_words(t, &ops, &words);
            for i in 0..ops.len() {
                assert_eq!(
                    got[i],
                    simd::execute_with(t, ops[i], words[i]),
                    "word {i} at w={w}"
                );
            }
        }
    }
}
