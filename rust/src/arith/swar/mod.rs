//! True software-SIMD (SWAR) kernels for the 8-bit SIMDive tier.
//!
//! Everything else in [`arith`](super) simulates the paper's sub-word
//! parallelism lane by lane; this module actually packs the four 8-bit
//! lanes of a [`LaneCfg::Four8`](super::simd::LaneCfg::Four8) word into one
//! `u64` and runs LOD, log-approximation, correction lookup and antilog
//! assembly on all lanes per instruction. The word layout, the guard-bit
//! budget of every stage, and the carry/borrow-freedom argument are
//! documented in DESIGN.md §13; the kernel is bit-identical to the scalar
//! [`simdive`](super::simdive) path (exhaustively property-tested in
//! `tests/swar_props.rs` and gated through `tests/engine_props.rs`).
//!
//! # Word layout
//!
//! Four 8-bit lanes live in four 16-bit fields of a `u64` (lane `i` at bits
//! `[16i, 16i + 8)`), leaving 8 guard bits above each lane. The guard bits
//! absorb every intermediate the datapath produces — corrected fraction
//! sums (≤ 9 bits), borrow sentinels (bit 8), shift counts — so no stage
//! ever carries into a neighbouring lane. The one place a lane needs more
//! than 16 bits, the multiplier's antilog shift (`mant · 2^e` is up to 24
//! bits wide), the word is split into even/odd lanes across two `u64`s
//! with 32-bit fields, shifted, saturated to 16 bits and re-interleaved —
//! which lands each lane's `2N`-bit product exactly where the packed
//! result layout of [`simd::execute`](super::simd::execute) wants it.
//!
//! # Stages
//!
//! The kernel is factored into the four pipeline stages the sharded engine
//! overlaps across consecutive words (decode → approx → correct →
//! assemble); [`Swar8::exec4`] is *defined as* their composition, so the
//! staged path in `engine::sharded` and the monolithic word path here
//! cannot diverge.
//!
//! # Fallback contract
//!
//! [`Swar8::try_new`] admits a table only when every rescaled coefficient
//! fits the guard-bit budget (mul ∈ `[0, 255]`, div ∈ `[-128, 0]` in
//! `F = 7` units — the generated tables sit far inside at ≤ 31). Tables
//! built from arbitrary grids that exceed it get `None` and callers fall
//! back to the lane-wise loops, keeping bit-exactness unconditional.

use super::simd::LaneMode;
use super::table::CorrectionTables;

#[cfg(feature = "portable-simd")]
pub mod portable;

/// One bit set at the bottom of each 16-bit field.
const ONE: u64 = 0x0001_0001_0001_0001;
/// The top bit of each 16-bit field.
const H16: u64 = 0x8000_8000_8000_8000;
/// One bit set at the bottom of each 32-bit field.
const ONE32: u64 = 0x0000_0001_0000_0001;
/// The low 16 bits of each 32-bit field.
const LOW32: u64 = 0x0000_FFFF_0000_FFFF;

/// Largest mul correction (in `F = 7` units) the guard bits absorb: keeps
/// the corrected fraction sum ≤ 509 < 2^9, so carry detection via bits
/// 7–8 stays exact.
const MAX_MUL_CORR: i64 = 255;
/// Largest div correction magnitude: keeps the borrow-sentinel arithmetic
/// (`f1 + 256 − f2 − |c|`) non-negative per field, so no borrow can cross
/// a lane boundary.
const MAX_DIV_CORR: i64 = 128;

/// Splat a 16-bit constant into all four fields.
#[inline(always)]
const fn splat16(c: u16) -> u64 {
    (c as u64) * ONE
}

/// Splat a 32-bit constant into both 32-bit fields.
#[inline(always)]
const fn splat32(c: u32) -> u64 {
    (c as u64) * ONE32
}

/// Spread the four bytes of a packed [`Four8`](super::simd::LaneCfg::Four8)
/// operand word into the four 16-bit SWAR fields (byte `i` → bits
/// `[16i, 16i + 8)`), guard bits all zero.
#[inline(always)]
pub fn spread_bytes(x: u32) -> u64 {
    let x = x as u64;
    let x = (x | (x << 16)) & 0x0000_FFFF_0000_FFFF;
    (x | (x << 8)) & 0x00FF_00FF_00FF_00FF
}

/// Pack four 8-bit operands (one per slice element) into a SWAR word.
#[inline(always)]
pub fn pack4(vals: &[u64]) -> u64 {
    debug_assert_eq!(vals.len(), 4);
    debug_assert!(vals.iter().all(|&v| v <= 0xFF), "SWAR lanes are 8-bit");
    vals[0] | (vals[1] << 16) | (vals[2] << 32) | (vals[3] << 48)
}

/// Unpack the four 16-bit result fields of a SWAR word into a slice.
#[inline(always)]
pub fn unpack4(word: u64, out: &mut [u64]) {
    debug_assert_eq!(out.len(), 4);
    for (i, o) in out.iter_mut().enumerate() {
        *o = (word >> (16 * i)) & 0xFFFF;
    }
}

/// Full-field mask of the lanes whose mode is [`LaneMode::Mul`]: `0xFFFF`
/// in field `i` iff lane `i` multiplies. `u64::MAX` ⇔ all-mul,
/// `0` ⇔ all-div.
#[inline]
pub fn mul_lane_mask(modes: &[LaneMode; 4]) -> u64 {
    let mut m = 0u64;
    for (i, mode) in modes.iter().enumerate() {
        if matches!(mode, LaneMode::Mul) {
            m |= 0xFFFFu64 << (16 * i);
        }
    }
    m
}

/// Per-field non-zero mask: `0xFFFF` where the field is non-zero, `0`
/// where it is zero. Exact for field values < `0x8000` (ours are ≤ 255):
/// adding `0x7FFF` sets the field's top bit iff the field was non-zero,
/// and cannot carry across fields.
#[inline(always)]
fn nz_mask16(v: u64) -> u64 {
    (((v + splat16(0x7FFF)) & H16) >> 15) * 0xFFFF
}

/// All-lane leading-one alignment: the SWAR counterpart of
/// [`frac_aligned`](super::mitchell::frac_aligned). Fields must hold
/// non-zero 8-bit values (the decode stage substitutes 1 into dead lanes
/// first — the structural analogue of `lod`'s `NonZeroU64` contract).
///
/// Three barrel stages shift each field left until its bit 7 is set;
/// a field's value never exceeds 0xFF at any stage (we only shift when the
/// top nibble/pair/bit is absent), so nothing leaks into the guard bits.
/// Returns `(nv, s)` with `nv = a << (7 − k)` (bit 7 set, fraction in bits
/// 0–6) and `s = 7 − lod(a)` per field.
#[inline(always)]
fn normalize(mut v: u64) -> (u64, u64) {
    let mut s = 0u64;
    for &(sh, top) in &[(4u32, 0xF0u16), (2, 0xC0), (1, 0x80)] {
        let t = v & splat16(top);
        let present = ((t + splat16(0x7FFF)) & H16) >> 15;
        let m = (present ^ ONE) * 0xFFFF;
        v ^= (v ^ (v << sh)) & m;
        s += m & splat16(sh as u16);
    }
    (v, s)
}

/// Correction-table index per field: `(region(f1) << 3) | region(f2)`,
/// regions being the 3 MSBs of each aligned fraction (bits 4–6 of `nv`).
#[inline(always)]
fn pair_idx(nv1: u64, nv2: u64) -> u64 {
    ((nv1 >> 1) & splat16(0x38)) | ((nv2 >> 4) & splat16(0x07))
}

/// Gather four table entries, one per field. The four scalar loads are the
/// one step software cannot vectorize without `vpgatherdd`; everything
/// around them stays packed.
#[inline(always)]
fn gather4(tab: &[u16; 64], idx: u64) -> u64 {
    let c0 = tab[(idx & 0x3F) as usize] as u64;
    let c1 = tab[((idx >> 16) & 0x3F) as usize] as u64;
    let c2 = tab[((idx >> 32) & 0x3F) as usize] as u64;
    let c3 = tab[((idx >> 48) & 0x3F) as usize] as u64;
    c0 | (c1 << 16) | (c2 << 32) | (c3 << 48)
}

/// Variable left shift per 32-bit field, shift counts 0..=15 in `e`.
/// Values stay ≤ 24 bits (9-bit mantissa · 2^15), so no field leak.
#[inline(always)]
fn shl_var32(mut v: u64, e: u64) -> u64 {
    for &(sh, bit) in &[(8u32, 3u32), (4, 2), (2, 1), (1, 0)] {
        let m = ((e >> bit) & ONE32) * 0xFFFF_FFFF;
        v ^= (v ^ (v << sh)) & m;
    }
    v
}

/// Variable right shift per 16-bit field, shift counts 0..=15 in `r`,
/// field values ≤ 0xFF. Masking each partial shift to the low 8 bits of
/// its field discards the neighbour bits a whole-word `>>` drags in.
#[inline(always)]
fn shr_var16(mut v: u64, r: u64) -> u64 {
    for &(sh, bit) in &[(8u32, 3u32), (4, 2), (2, 1), (1, 0)] {
        let m = ((r >> bit) & ONE) * 0xFFFF;
        let shifted = (v >> sh) & splat16(0xFF);
        v ^= (v ^ shifted) & m;
    }
    v
}

/// Saturate two 17-bit values (one per 32-bit field) to 16 bits: bit 16
/// set ⇒ the field becomes `0xFFFF` — the `2N`-bit cap of
/// [`mul_decode`](super::mitchell::mul_decode) at `N = 8`.
#[inline(always)]
fn sat16x2(q: u64) -> u64 {
    let hi = (q >> 16) & ONE32;
    (q | (hi * 0xFFFF)) & splat32(0xFFFF)
}

/// Decode-stage output: zero-lane masks plus all four lanes aligned into
/// the log domain.
#[derive(Clone, Copy, Debug)]
pub struct Decoded {
    /// `0xFFFF` per field where operand A is non-zero.
    pub anz: u64,
    /// `0xFFFF` per field where operand B is non-zero.
    pub bnz: u64,
    /// Normalized A lanes: bit 7 set, fraction in bits 0–6 (dead lanes
    /// hold the substituted value 1, normalized to 0x80).
    pub nv1: u64,
    /// Normalized B lanes.
    pub nv2: u64,
    /// Per-field normalization distance `7 − lod(a)`.
    pub sa: u64,
    /// Per-field normalization distance `7 − lod(b)`.
    pub sb: u64,
}

/// Approx-stage output: the uncorrected Mitchell log-domain sums and the
/// correction-table index, carried alongside the decode state.
#[derive(Clone, Copy, Debug)]
pub struct Approxed {
    pub dec: Decoded,
    /// Region-pair table index per field (6 bits).
    pub idx: u64,
    /// Uncorrected mul fraction sum `f1 + f2` per field (≤ 254).
    pub msum: u64,
    /// Borrow-sentinel div base `f1 + 256 − f2` per field (∈ [129, 383]).
    pub dbase: u64,
}

/// Correct-stage output: fraction sums with the table corrections folded
/// in, ready for antilog assembly.
#[derive(Clone, Copy, Debug)]
pub struct Corrected {
    pub dec: Decoded,
    /// Corrected mul sum `f1 + f2 + c` per field (≤ 509).
    pub mul_t: u64,
    /// Corrected div sentinel `f1 + 256 − f2 − |c|` per field (≥ 1);
    /// bit 8 is the no-borrow flag (`t ≥ 0` in scalar terms).
    pub div_t: u64,
}

/// The packed 4×8-bit SIMDive kernel: one correction-table pair rescaled
/// to `F = 7` units at construction, safe for guard-bit arithmetic by
/// [`Swar8::try_new`]'s range check.
#[derive(Clone, Debug)]
pub struct Swar8 {
    /// Mul corrections, `0..=MAX_MUL_CORR`.
    mul: [u16; 64],
    /// Div correction magnitudes (the table entries are ≤ 0),
    /// `0..=MAX_DIV_CORR`.
    div: [u16; 64],
}

impl Swar8 {
    /// Rescale `t` to `F = 7` units and admit it iff every coefficient
    /// fits the guard-bit budget (see module docs). Generated tables
    /// always fit (entries ≤ 31); hand-built grids may not, and get the
    /// lane-wise fallback instead.
    pub fn try_new(t: &CorrectionTables) -> Option<Swar8> {
        let mut mul = [0u16; 64];
        let mut div = [0u16; 64];
        for k in 0..64 {
            let m = CorrectionTables::scale_to_f(t.mul_flat[k], 8);
            let d = CorrectionTables::scale_to_f(t.div_flat[k], 8);
            if !(0..=MAX_MUL_CORR).contains(&m) || !(-MAX_DIV_CORR..=0).contains(&d) {
                return None;
            }
            mul[k] = m as u16;
            div[k] = (-d) as u16;
        }
        Some(Swar8 { mul, div })
    }

    /// Stage 1 — decode: compute the zero-lane masks, substitute 1 into
    /// dead lanes (zero can never reach the aligner — the packed analogue
    /// of [`lod`](super::mitchell::lod)'s `NonZeroU64` guard), and align
    /// all four lanes to the log domain.
    #[inline]
    pub fn decode4(a4: u64, b4: u64) -> Decoded {
        let anz = nz_mask16(a4);
        let bnz = nz_mask16(b4);
        let (nv1, sa) = normalize(a4 | (ONE & !anz));
        let (nv2, sb) = normalize(b4 | (ONE & !bnz));
        Decoded { anz, bnz, nv1, nv2, sa, sb }
    }

    /// Stage 2 — approx: Mitchell's log-domain approximation, uncorrected.
    /// `msum` is the mul fraction sum; `dbase` biases the div difference
    /// by +256 so the later subtraction cannot borrow across lanes and
    /// bit 8 doubles as the sign sentinel.
    #[inline]
    pub fn approx4(dec: Decoded) -> Approxed {
        let f1 = dec.nv1 & splat16(0x7F);
        let f2 = dec.nv2 & splat16(0x7F);
        let idx = pair_idx(dec.nv1, dec.nv2);
        Approxed { dec, idx, msum: f1 + f2, dbase: f1 + splat16(0x100) - f2 }
    }

    /// Stage 3 — correct: gather both tables at the region-pair index and
    /// fold the coefficients into the log-domain sums.
    #[inline]
    pub fn correct4(&self, ap: Approxed) -> Corrected {
        Corrected {
            dec: ap.dec,
            mul_t: ap.msum + gather4(&self.mul, ap.idx),
            div_t: ap.dbase - gather4(&self.div, ap.idx),
        }
    }

    /// Stage 4 — assemble: antilog decode, saturation and zero-convention
    /// masking, selecting mul or div per lane by `mul_lanes` (a
    /// [`mul_lane_mask`]). Uniform words skip the unused datapath.
    #[inline]
    pub fn assemble4(c: Corrected, mul_lanes: u64) -> u64 {
        if mul_lanes == u64::MAX {
            assemble_mul(&c.dec, c.mul_t)
        } else if mul_lanes == 0 {
            assemble_div(&c.dec, c.div_t)
        } else {
            (assemble_mul(&c.dec, c.mul_t) & mul_lanes)
                | (assemble_div(&c.dec, c.div_t) & !mul_lanes)
        }
    }

    /// Execute one packed word with per-lane modes: the composition of the
    /// four stages. Bit-identical to four scalar
    /// [`simdive`](super::simdive) calls on the unpacked lanes.
    #[inline]
    pub fn exec4(&self, mul_lanes: u64, a4: u64, b4: u64) -> u64 {
        Self::assemble4(self.correct4(Self::approx4(Self::decode4(a4, b4))), mul_lanes)
    }

    /// All-mul word: skips the div gather and datapath entirely.
    #[inline]
    pub fn mul4(&self, a4: u64, b4: u64) -> u64 {
        let ap = Self::approx4(Self::decode4(a4, b4));
        assemble_mul(&ap.dec, ap.msum + gather4(&self.mul, ap.idx))
    }

    /// All-div word: skips the mul gather and datapath entirely.
    #[inline]
    pub fn div4(&self, a4: u64, b4: u64) -> u64 {
        let ap = Self::approx4(Self::decode4(a4, b4));
        assemble_div(&ap.dec, ap.dbase - gather4(&self.div, ap.idx))
    }
}

/// Mul antilog assembly. Carry detection (`ts ≥ 128` ⇒ the fraction adder
/// carried out) reads bits 7–8 — exact because `ts ≤ 509`. The implicit
/// leading one is added only on the no-carry side, the exponent is
/// `e = k1 + k2 + carry ∈ [0, 15]`, and the product is
/// `(mant << e) >> 7` — identical to `mant · 2^(e − 7)` under floor — run
/// in 32-bit fields with even/odd lane interleave, then saturated to the
/// 16-bit result field.
#[inline(always)]
fn assemble_mul(d: &Decoded, ts: u64) -> u64 {
    let cb = ((ts >> 7) | (ts >> 8)) & ONE;
    let mant = ts + ((cb ^ ONE) << 7);
    let e = splat16(14) - d.sa - d.sb + cb;
    let d0 = mant & LOW32;
    let d1 = (mant >> 16) & LOW32;
    let e0 = e & LOW32;
    let e1 = (e >> 16) & LOW32;
    let q0 = sat16x2((shl_var32(d0, e0) >> 7) & splat32(0x1_FFFF));
    let q1 = sat16x2((shl_var32(d1, e1) >> 7) & splat32(0x1_FFFF));
    (q0 | (q1 << 16)) & d.anz & d.bnz
}

/// Div antilog assembly. Bit 8 of the sentinel sum is the no-borrow flag
/// (`nb = 1 ⇔ t ≥ 0`); the mantissa drops the sentinel's excess
/// (`2^8 + t` with `nb` folding the two scalar cases into one), the shift
/// is `r = 8 − (k1 − k2) − nb ∈ [0, 15]`, and quotients are ≤ 255 so the
/// divider needs no cap. Dead divisor lanes saturate to 255, dead dividend
/// lanes zero — `b == 0` wins over `a == 0`, matching the scalar order.
#[inline(always)]
fn assemble_div(d: &Decoded, tb: u64) -> u64 {
    let nb = (tb >> 8) & ONE;
    let mant = tb - (nb << 7);
    let r = (splat16(8) + d.sa) - d.sb - nb;
    let q = shr_var16(mant, r);
    (q & d.anz & d.bnz) | (splat16(0xFF) & !d.bnz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::{simdive_div_with, simdive_mul_with};
    use crate::arith::table::tables_for;

    #[test]
    fn spread_bytes_layout() {
        assert_eq!(spread_bytes(0x4433_2211), 0x0044_0033_0022_0011);
        assert_eq!(spread_bytes(0), 0);
        assert_eq!(spread_bytes(u32::MAX), 0x00FF_00FF_00FF_00FF);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let vals = [0u64, 255, 43, 128];
        let w = pack4(&vals);
        let mut back = [0u64; 4];
        unpack4(w, &mut back);
        assert_eq!(back, vals);
    }

    #[test]
    fn normalize_matches_scalar_lod() {
        use std::num::NonZeroU64;
        for v in 1..=255u64 {
            let (nv, s) = normalize(pack4(&[v, v, v, v]));
            let k = crate::arith::lod(NonZeroU64::new(v).unwrap());
            let want_nv = v << (7 - k);
            let want_s = (7 - k) as u64;
            for lane in 0..4 {
                assert_eq!((nv >> (16 * lane)) & 0xFFFF, want_nv, "nv for {v}");
                assert_eq!((s >> (16 * lane)) & 0xFFFF, want_s, "s for {v}");
            }
        }
    }

    #[test]
    fn nz_mask_is_per_field_exact() {
        assert_eq!(nz_mask16(0), 0);
        assert_eq!(nz_mask16(pack4(&[1, 0, 255, 0])), 0x0000_FFFF_0000_FFFF);
        assert_eq!(nz_mask16(pack4(&[255, 255, 255, 255])), u64::MAX);
    }

    #[test]
    fn generated_tables_always_admit() {
        for w in 0..=crate::arith::W_MAX {
            let k = Swar8::try_new(tables_for(w));
            assert!(k.is_some(), "generated tables at w={w} must fit the SWAR budget");
        }
    }

    #[test]
    fn out_of_budget_tables_are_rejected() {
        // 32768 at 12 fractional bits rescales to 1024 in F = 7 units:
        // past both budgets.
        let big = CorrectionTables::from_grids(8, [[32_768; 8]; 8], [[-32_768; 8]; 8]);
        assert!(Swar8::try_new(&big).is_none());
        // Just inside: mul 255 ⇔ 255 << 5, div −128 ⇔ −128 << 5.
        let edge = CorrectionTables::from_grids(8, [[255 << 5; 8]; 8], [[-(128 << 5); 8]; 8]);
        assert!(Swar8::try_new(&edge).is_some());
        // Just outside on each side.
        let m = CorrectionTables::from_grids(8, [[256 << 5; 8]; 8], [[0; 8]; 8]);
        assert!(Swar8::try_new(&m).is_none());
        let d = CorrectionTables::from_grids(8, [[0; 8]; 8], [[-(129 << 5); 8]; 8]);
        assert!(Swar8::try_new(&d).is_none());
        let pos_div = CorrectionTables::from_grids(8, [[0; 8]; 8], [[32; 8]; 8]);
        assert!(Swar8::try_new(&pos_div).is_none(), "positive div corrections are off-model");
    }

    #[test]
    fn paper_example_all_lanes() {
        let k = Swar8::try_new(tables_for(8)).unwrap();
        let a4 = pack4(&[43, 43, 43, 43]);
        let b4 = pack4(&[10, 10, 10, 10]);
        let want_m = simdive_mul_with(tables_for(8), 8, 43, 10);
        let want_d = simdive_div_with(tables_for(8), 8, 43, 10);
        let mut m = [0u64; 4];
        let mut d = [0u64; 4];
        unpack4(k.mul4(a4, b4), &mut m);
        unpack4(k.div4(a4, b4), &mut d);
        assert_eq!(m, [want_m; 4]);
        assert_eq!(d, [want_d; 4]);
    }

    #[test]
    fn uniform_entry_points_equal_staged_composition() {
        let k = Swar8::try_new(tables_for(5)).unwrap();
        let mut rng = crate::util::Rng::new(0x5A5A);
        for _ in 0..2_000 {
            let a: Vec<u64> = (0..4).map(|_| rng.below(256)).collect();
            let b: Vec<u64> = (0..4).map(|_| rng.below(256)).collect();
            let (a4, b4) = (pack4(&a), pack4(&b));
            assert_eq!(k.mul4(a4, b4), k.exec4(u64::MAX, a4, b4));
            assert_eq!(k.div4(a4, b4), k.exec4(0, a4, b4));
        }
    }

    #[test]
    fn zero_lanes_follow_scalar_conventions() {
        let t = tables_for(8);
        let k = Swar8::try_new(t).unwrap();
        let a4 = pack4(&[0, 99, 0, 255]);
        let b4 = pack4(&[99, 0, 0, 0]);
        let mut m = [0u64; 4];
        let mut d = [0u64; 4];
        unpack4(k.mul4(a4, b4), &mut m);
        unpack4(k.div4(a4, b4), &mut d);
        assert_eq!(m, [0, 0, 0, 0]);
        assert_eq!(d, [0, 255, 255, 255], "x/0 saturates, 0/x is 0, 0/0 follows b==0 first");
    }
}
