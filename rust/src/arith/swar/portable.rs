//! Optional `std::simd` variant of the 4×8-bit kernel.
//!
//! Compiled only with `--features portable-simd` on a nightly toolchain
//! (`std::simd` is unstable); the `u64` SWAR path in the parent module is
//! the portable default and the bit-exactness reference. Where the SWAR
//! path emulates per-lane data flow with guard bits and barrel-stage
//! masks, this one lets the vector ISA do it: each lane is a `u16` element
//! of a [`Simd<u16, 4>`], so lane isolation is structural and the variable
//! shifts are single vector ops.
//!
//! The two paths share the correction tables through [`Swar8`] and must
//! produce identical words; `lanes_match_swar` below pins that whenever
//! this module is built.

use std::simd::cmp::{SimdOrd, SimdPartialEq, SimdPartialOrd};
use std::simd::num::SimdUint;
use std::simd::Simd;

use super::{pack4, unpack4, Swar8};

type V = Simd<u16, 4>;

/// Per-element leading-one distance `7 - lod(v)` for non-zero 8-bit lanes,
/// as a three-stage conditional-shift ladder (the vector twin of the SWAR
/// `normalize`). Returns `(nv, s)` with bit 7 of every `nv` lane set.
#[inline]
fn normalize(mut v: V) -> (V, V) {
    let mut s = V::splat(0);
    for (sh, top) in [(4u16, 0xF0u16), (2, 0xC0), (1, 0x80)] {
        let absent = (v & V::splat(top)).simd_eq(V::splat(0));
        v = absent.select(v << V::splat(sh), v);
        s += absent.select(V::splat(sh), V::splat(0));
    }
    (v, s)
}

/// Execute one packed word with per-lane modes via `std::simd`.
/// Bit-identical to [`Swar8::exec4`] on the same operands.
pub fn exec4(k: &Swar8, mul_lanes: u64, a4: u64, b4: u64) -> u64 {
    let mut a = [0u64; 4];
    let mut b = [0u64; 4];
    unpack4(a4, &mut a);
    unpack4(b4, &mut b);
    let av = V::from_array(a.map(|v| v as u16));
    let bv = V::from_array(b.map(|v| v as u16));

    let anz = av.simd_ne(V::splat(0));
    let bnz = bv.simd_ne(V::splat(0));
    let (nv1, sa) = normalize(anz.select(av, V::splat(1)));
    let (nv2, sb) = normalize(bnz.select(bv, V::splat(1)));

    let f1 = nv1 & V::splat(0x7F);
    let f2 = nv2 & V::splat(0x7F);
    let idx = ((nv1 >> V::splat(1)) & V::splat(0x38)) | ((nv2 >> V::splat(4)) & V::splat(0x07));
    let (mc, dc) = k.gather_pair(idx.to_array());

    // Mul datapath: 32-bit lanes give the antilog shift its headroom.
    let ts = f1 + f2 + mc;
    let cb = ((ts >> V::splat(7)) | (ts >> V::splat(8))) & V::splat(1);
    let mant = (ts + ((cb ^ V::splat(1)) << V::splat(7))).cast::<u32>();
    let e = (V::splat(14) - sa - sb + cb).cast::<u32>();
    let q = (mant << e) >> Simd::<u32, 4>::splat(7);
    let mul_q = q.simd_min(Simd::<u32, 4>::splat(0xFFFF)).cast::<u16>();
    let mul_r = (anz & bnz).select(mul_q, V::splat(0));

    // Div datapath: the +256 bias keeps the difference non-negative and
    // makes bit 8 the no-borrow flag, exactly as in the SWAR path.
    let tb = f1 + V::splat(0x100) - f2 - dc;
    let nb = (tb >> V::splat(8)) & V::splat(1);
    let dmant = tb - (nb << V::splat(7));
    let r = V::splat(8) + sa - sb - nb;
    let div_q = (dmant >> r) & V::splat(0xFF);
    let div_r = bnz.select(anz.select(div_q, V::splat(0)), V::splat(0xFF));

    let mm = V::from_array(std::array::from_fn(|i| ((mul_lanes >> (16 * i)) & 0xFFFF) as u16));
    let out = mm.simd_gt(V::splat(0)).select(mul_r, div_r);
    pack4(&out.to_array().map(u64::from))
}

impl Swar8 {
    /// Gather both correction vectors for four table indices.
    #[inline]
    fn gather_pair(&self, idx: [u16; 4]) -> (V, V) {
        let m = V::from_array(idx.map(|i| self.mul[(i & 0x3F) as usize]));
        let d = V::from_array(idx.map(|i| self.div[(i & 0x3F) as usize]));
        (m, d)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{mul_lane_mask, pack4, Swar8};
    use crate::arith::simd::LaneMode;
    use crate::arith::table::tables_for;

    #[test]
    fn lanes_match_swar() {
        let mut rng = crate::util::Rng::new(0xBEEF);
        for w in 0..=crate::arith::W_MAX {
            let k = Swar8::try_new(tables_for(w)).unwrap();
            for case in 0..4_000u32 {
                let a: Vec<u64> = (0..4).map(|_| rng.below(256)).collect();
                let b: Vec<u64> = (0..4).map(|_| rng.below(256)).collect();
                let modes = std::array::from_fn(|i| {
                    if (case >> i) & 1 == 0 { LaneMode::Mul } else { LaneMode::Div }
                });
                let mask = mul_lane_mask(&modes);
                let (a4, b4) = (pack4(&a), pack4(&b));
                assert_eq!(super::exec4(&k, mask, a4, b4), k.exec4(mask, a4, b4));
            }
        }
    }
}
