//! Serving-side counters: completed-request counts and a lock-free
//! log2-bucketed latency histogram, kept per connection and server-wide
//! (DESIGN.md §8). The writer thread records one sample per response at
//! completion time (admission → response write), so the percentiles
//! include queueing under the admission window — the number a client
//! actually experiences.
//!
//! The histogram itself lives in [`crate::obs::registry`]; this module
//! keeps the serving-flavored wrappers ([`LatencyHist`], [`ServeCounters`])
//! so the serve layer's call sites and the wire-stats assembly stay
//! unchanged. Moving onto [`obs::Hist`](crate::obs::Hist) also fixed a
//! snapshot race the old standalone histogram had: it kept a separate
//! total-count atomic next to the buckets, so a percentile read racing a
//! recorder could observe `count` ahead of the bucket it targets and walk
//! off the end of the populated buckets, over-reporting the percentile.
//! `obs::Hist` stores buckets only and derives the rank from the observed
//! bucket sum of one consistent local copy.

use crate::obs::Hist;
use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free latency histogram over log2-spaced nanosecond buckets:
/// bucket `i` counts samples in `[2^i, 2^{i+1})` ns, with the top bucket
/// absorbing everything ≥ 2^47 ns (~39 h).
///
/// Percentiles are read as the *upper bound* of the bucket holding the
/// requested rank — at most 2× off, which is plenty for p50/p99 serving
/// telemetry and costs one relaxed increment per sample.
#[derive(Default)]
pub struct LatencyHist {
    inner: Hist,
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { inner: Hist::new() }
    }

    /// Record one latency sample in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.inner.record_ns(ns);
    }

    pub fn count(&self) -> u64 {
        self.inner.count()
    }

    /// Percentile `p` in `(0, 1]`, reported in microseconds (upper bound of
    /// the holding bucket). Returns 0 when no samples were recorded.
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.inner.percentile_us(p)
    }
}

/// Completed-request counter + latency histogram; one per connection and
/// one server-wide.
pub struct ServeCounters {
    requests: AtomicU64,
    pub hist: LatencyHist,
}

impl ServeCounters {
    pub fn new() -> Self {
        ServeCounters { requests: AtomicU64::new(0), hist: LatencyHist::new() }
    }

    /// Record one completed request and its admission→response latency.
    pub fn record(&self, latency_ns: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.hist.record_ns(latency_ns);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }
}

impl Default for ServeCounters {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(0.5), 0);
        assert_eq!(h.percentile_us(0.99), 0);
    }

    #[test]
    fn percentiles_bound_samples() {
        let h = LatencyHist::new();
        // 99 samples at ~1 µs, one at ~1 ms.
        for _ in 0..99 {
            h.record_ns(1_000);
        }
        h.record_ns(1_000_000);
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_us(0.50);
        let p99 = h.percentile_us(0.99);
        let p100 = h.percentile_us(1.0);
        // p50/p99 fall in the ~1 µs bucket (upper bound ≤ 2 µs), p100 in
        // the ~1 ms bucket.
        assert!((1..=2).contains(&p50), "p50 = {p50}");
        assert!((1..=2).contains(&p99), "p99 = {p99}");
        assert!((1_000..=2_100).contains(&p100), "p100 = {p100}");
        assert!(p50 <= p99 && p99 <= p100);
    }

    #[test]
    fn zero_and_huge_samples_are_absorbed() {
        let h = LatencyHist::new();
        h.record_ns(0);
        h.record_ns(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.percentile_us(1.0) > 0);
    }

    #[test]
    fn counters_accumulate() {
        let c = ServeCounters::new();
        c.record(5_000);
        c.record(7_000);
        assert_eq!(c.requests(), 2);
        assert_eq!(c.hist.count(), 2);
    }
}
