//! The chaos load scenario (`simdive loadgen --chaos`, DESIGN.md §11):
//! drive a (possibly fault-injected) server with verified traffic while a
//! saboteur connection speaks deliberately corrupted/stalled/reset wire
//! at it, then check the three robustness invariants:
//!
//! 1. **No hang** — every request resolves (success or definitive
//!    failure) within the retry budget; the scenario itself terminates.
//! 2. **No wrong answer** — every successful response is bit-identical
//!    to the scalar models (`simdive_mul_w`/`simdive_div_w`). Faults may
//!    fail a request, never silently corrupt one: the saboteur's
//!    corruption rides a *separate* connection, so verified traffic is
//!    only ever exposed to server-side faults, which are answer-preserving
//!    by the supervision contract.
//! 3. **No leak** — once the storm ends, the server's open-connection
//!    count returns to the pre-storm baseline (threads and window slots
//!    are reclaimed, not stranded).
//!
//! Everything is deterministic per `seed` on the injection side; wall
//! clock (and thus retry interleavings) of course are not.

use super::client::{Client, RetryPolicy};
use super::wire::{self, WireRequest, WireStats};
use crate::arith::simdive::{simdive_div_w, simdive_mul_w};
use crate::arith::W_MAX;
use crate::coordinator::ReqOp;
use crate::faults::{ChaosStream, FaultConfig, FaultInjector};
use crate::obs::Snapshot;
use crate::util::Rng;
use std::io::{self, Read};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Chaos-scenario configuration.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Verified-traffic connections.
    pub connections: usize,
    /// Total verified requests across all connections.
    pub requests: u64,
    /// Client pipeline chunk.
    pub chunk: usize,
    /// Seed for the traffic generators and the saboteur's wire chaos.
    pub seed: u64,
    /// Retry policy every traffic connection uses.
    pub retry: RetryPolicy,
    /// Saboteur connections opened in sequence, each speaking corrupted
    /// wire until the server (rightly) kills it.
    pub saboteur_rounds: u32,
    /// Wire-fault rate (ppm per decision) of the saboteur's stream.
    pub saboteur_ppm: u32,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            connections: 4,
            requests: 20_000,
            chunk: 128,
            seed: 0xC4A05,
            retry: RetryPolicy::default(),
            saboteur_rounds: 32,
            saboteur_ppm: 50_000,
        }
    }
}

/// What one chaos run observed.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Verified requests submitted.
    pub requests: u64,
    /// Responses with `err == 0` (all value-checked).
    pub completed: u64,
    /// Definitive per-request failures (`ERR_OVERLOAD`/`ERR_UNAVAILABLE`
    /// surviving the retry budget).
    pub failed: u64,
    /// Successful responses whose value differed from the scalar models.
    /// **Any non-zero value is an invariant violation.**
    pub mismatches: u64,
    /// Requests with no definitive outcome (transport failure exhausted
    /// the retry budget). **Any non-zero value is an invariant violation**
    /// (at the fault rates the bench sweeps — a saturated retry budget is
    /// a hang in disguise).
    pub unresolved: u64,
    /// Reconnects performed by the traffic clients' retry layer.
    pub reconnects: u64,
    /// Saboteur rounds actually completed.
    pub saboteur_rounds: u32,
    pub wall_s: f64,
    /// Completed verified requests per second (degraded-mode throughput).
    pub rps: f64,
    /// Server snapshot after the storm.
    pub server: WireStats,
    /// The server's `STATS2` registry snapshot after the storm — includes
    /// the `faults.*` observation counters of every injected-fault site.
    pub stats2: Snapshot,
    /// Open connections before the storm (includes the monitor itself).
    pub baseline_connections: u64,
    /// Open connections once the post-storm drain poll converged.
    pub final_connections: u64,
}

impl ChaosReport {
    /// The three invariants: no wrong answer, no hang (every request got
    /// a definitive outcome), no connection leak.
    pub fn invariants_hold(&self) -> bool {
        self.mismatches == 0
            && self.unresolved == 0
            && self.final_connections <= self.baseline_connections
    }
}

/// The scalar-model oracle for one wire request (fixed-`w` mode only).
fn expected(r: &WireRequest) -> u64 {
    match r.op {
        ReqOp::Mul => simdive_mul_w(r.bits, r.a, r.b, r.w),
        ReqOp::Div => simdive_div_w(r.bits, r.a, r.b, r.w),
    }
}

/// Generate one verifiable request: always fixed-`w` (never error-budget
/// mode, whose routed `w` the client cannot know), so the oracle above is
/// exact.
fn make_request(rng: &mut Rng, id: u64) -> WireRequest {
    let bits = [8u32, 8, 16, 32][rng.below(4) as usize];
    WireRequest {
        id,
        op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
        bits,
        w: rng.below(W_MAX as u64 + 1) as u32,
        budget_ppm: 0,
        a: rng.operand(bits),
        b: rng.operand(bits),
    }
}

/// Per-traffic-thread tally.
#[derive(Default)]
struct Tally {
    completed: u64,
    failed: u64,
    mismatches: u64,
    unresolved: u64,
    reconnects: u64,
}

fn traffic_thread(
    addr: &str,
    cfg: &ChaosConfig,
    conn_index: usize,
    quota: u64,
    barrier: &Barrier,
) -> io::Result<Tally> {
    let client = if quota == 0 {
        None
    } else {
        Some(Client::connect_retry(addr, Duration::from_secs(5)))
    };
    barrier.wait();
    let mut tally = Tally::default();
    let Some(client) = client else { return Ok(tally) };
    let mut client = client?.with_chunk(cfg.chunk.max(1));
    let mut rng = Rng::new(cfg.seed ^ (0x9E37_79B9 * (conn_index as u64 + 1)));
    let window = cfg.chunk.max(1) as u64 * 4;
    let mut done = 0u64;
    while done < quota {
        let n = (quota - done).min(window);
        let reqs: Vec<WireRequest> =
            (0..n).map(|k| make_request(&mut rng, done + k)).collect();
        match client.exchange_with_retry(&reqs, &cfg.retry) {
            Ok(resps) => {
                for (resp, req) in resps.iter().zip(&reqs) {
                    if resp.err != 0 {
                        tally.failed += 1;
                    } else if resp.value != expected(req) {
                        tally.mismatches += 1;
                    } else {
                        tally.completed += 1;
                    }
                }
            }
            Err(_) => {
                // The whole window ran out its retry budget: a definitive
                // scenario failure, recorded, never a hang.
                tally.unresolved += n;
            }
        }
        done += n;
    }
    tally.reconnects = client.reconnects();
    Ok(tally)
}

/// One saboteur connection: clean hello (so the server commits a
/// connection), then batch frames pushed through a [`ChaosStream`] that
/// corrupts, stalls and resets them. Every outcome is fine — the point is
/// that the *server* survives it; all errors here are swallowed.
fn saboteur_round(addr: &str, inj: &Arc<FaultInjector>, rng: &mut Rng) {
    let Ok(stream) = TcpStream::connect(addr) else { return };
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    stream.set_write_timeout(Some(Duration::from_millis(500))).ok();
    // Hello goes out clean: a corrupted hello is rejected before the
    // server even spawns the per-connection threads, which would leave
    // the interesting reader/writer paths unexercised.
    if wire::write_hello(&mut (&stream)).is_err() || wire::read_hello(&mut (&stream)).is_err() {
        return;
    }
    let mut chaotic = ChaosStream::new(&stream, Arc::clone(inj));
    for _ in 0..4 {
        let reqs: Vec<WireRequest> = (0..16).map(|k| make_request(rng, k)).collect();
        if wire::write_batch(&mut chaotic, &reqs).is_err() {
            return; // injected reset or server closed on us — both fine
        }
        let mut sink = [0u8; 512];
        let _ = chaotic.read(&mut sink);
        if chaotic.is_reset() {
            return;
        }
    }
}

/// Run the chaos scenario against `addr`. Blocks until the verified
/// traffic and the saboteur both finish and the post-storm connection
/// drain converges (bounded poll, ≤10 s).
pub fn run(addr: &str, cfg: &ChaosConfig) -> io::Result<ChaosReport> {
    let connections = cfg.connections.max(1);
    // The monitor connects first: its stats view defines the baseline.
    let mut monitor = Client::connect_retry(addr, Duration::from_secs(5))?;
    let baseline_connections = monitor.stats()?.connections;

    let per = cfg.requests / connections as u64;
    let remainder = cfg.requests % connections as u64;
    let barrier = Arc::new(Barrier::new(connections + 1));
    let mut handles = Vec::with_capacity(connections);
    for c in 0..connections {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        let barrier = Arc::clone(&barrier);
        let quota = per + if (c as u64) < remainder { 1 } else { 0 };
        handles.push(std::thread::spawn(move || {
            traffic_thread(&addr, &cfg, c, quota, &barrier)
        }));
    }
    let saboteur = {
        let addr = addr.to_string();
        let inj = FaultInjector::new(FaultConfig::wire_chaos(cfg.seed, cfg.saboteur_ppm));
        let rounds = cfg.saboteur_rounds;
        let seed = cfg.seed;
        std::thread::spawn(move || {
            let mut rng = Rng::new(seed ^ 0x5AB0);
            let mut done = 0u32;
            for _ in 0..rounds {
                saboteur_round(&addr, &inj, &mut rng);
                done += 1;
            }
            done
        })
    };
    barrier.wait();
    let t0 = Instant::now();

    let mut tally = Tally::default();
    let mut first_err: Option<io::Error> = None;
    for h in handles {
        match h.join().expect("chaos traffic thread panicked") {
            Ok(t) => {
                tally.completed += t.completed;
                tally.failed += t.failed;
                tally.mismatches += t.mismatches;
                tally.unresolved += t.unresolved;
                tally.reconnects += t.reconnects;
            }
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    let saboteur_rounds = saboteur.join().expect("saboteur thread panicked");
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // Leak check: bounded convergence poll (never a correctness sleep —
    // the bound only caps how long we wait for TCP close propagation).
    let drain_deadline = Instant::now() + Duration::from_secs(10);
    let mut final_connections = monitor.stats()?.connections;
    while final_connections > baseline_connections && Instant::now() < drain_deadline {
        std::thread::sleep(Duration::from_millis(20));
        final_connections = monitor.stats()?.connections;
    }
    let server = monitor.stats()?;
    let stats2 = monitor.stats2()?;

    Ok(ChaosReport {
        requests: cfg.requests,
        completed: tally.completed,
        failed: tally.failed,
        mismatches: tally.mismatches,
        unresolved: tally.unresolved,
        reconnects: tally.reconnects,
        saboteur_rounds,
        wall_s,
        rps: tally.completed as f64 / wall_s.max(1e-9),
        server,
        stats2,
        baseline_connections,
        final_connections,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_matches_scalar_models() {
        let mut rng = Rng::new(7);
        for i in 0..500 {
            let r = make_request(&mut rng, i);
            assert_eq!(r.budget_ppm, 0, "chaos traffic must stay verifiable");
            assert!(r.w <= W_MAX);
            let e = expected(&r);
            let again = expected(&r);
            assert_eq!(e, again, "oracle is a pure function");
        }
    }

    #[test]
    fn invariants_gate_on_the_three_clauses() {
        let ok = ChaosReport {
            requests: 10,
            completed: 8,
            failed: 2,
            mismatches: 0,
            unresolved: 0,
            reconnects: 3,
            saboteur_rounds: 4,
            wall_s: 1.0,
            rps: 8.0,
            server: WireStats::default(),
            stats2: Snapshot::default(),
            baseline_connections: 1,
            final_connections: 1,
        };
        assert!(ok.invariants_hold(), "failures alone do not violate invariants");
        assert!(!ChaosReport { mismatches: 1, ..ok.clone() }.invariants_hold());
        assert!(!ChaosReport { unresolved: 1, ..ok.clone() }.invariants_hold());
        assert!(!ChaosReport { final_connections: 2, ..ok.clone() }.invariants_hold());
    }
}
