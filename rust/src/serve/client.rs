//! Pipelined SIMD-wire client (DESIGN.md §8, fault tolerance §11).
//!
//! [`Client::exchange`] is the throughput path: it keeps up to two
//! pipeline chunks of requests in flight (writing chunk *k+1* before the
//! responses of chunk *k* have drained) and reassembles the out-of-order
//! response stream into submission order by correlation id. The chunk
//! size is capped so the worst-case unread response backlog always fits
//! kernel socket buffers — the client can therefore never deadlock
//! against a server whose admission window is smaller than the pipeline.
//!
//! Fault tolerance: connections carry default read/write socket timeouts
//! ([`DEFAULT_IO_TIMEOUT`], overridable via [`Client::with_io_timeout`]),
//! so a silent peer yields a timeout error instead of a hang. Per-request
//! `RESP_ERR` failures (`ERR_OVERLOAD`/`ERR_UNAVAILABLE`) surface as
//! ordinary [`WireResponse`]s with `err != 0`. [`Client::exchange_with_retry`]
//! layers idempotent retry on top: transport errors reconnect, retriable
//! per-request failures resubmit, both under capped exponential backoff
//! and a hard deadline — safe because every SIMD-wire computation is pure.
//! Each sleep is equal-jittered (uniform in `[b/2, b]`) from a per-client
//! seeded RNG, so clients that fail together do not retry in lockstep.

use super::wire::{self, ServerFrame, WireRequest, WireResponse, WireStats};
use crate::obs::{Snapshot, TraceEvent};
use crate::util::Rng;
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Default pipeline chunk (requests per `BATCH` frame).
pub const DEFAULT_CHUNK: usize = 256;

/// Upper bound on the pipeline chunk: with two chunks in flight plus one
/// being written, the unread response backlog stays ≤ ~3 · 1024 · 17 B
/// ≈ 52 KB, below the smallest kernel socket buffers.
pub const MAX_CHUNK: usize = 1024;

/// Default read/write socket timeout: long enough for any healthy
/// exchange, short enough that a dead server surfaces as an error in
/// seconds, not never.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(5);

/// Is this error a blocked-socket timeout? `SO_RCVTIMEO`/`SO_SNDTIMEO`
/// expiry surfaces as `WouldBlock` on Unix and `TimedOut` on Windows.
pub fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Retry policy for [`Client::exchange_with_retry`]: capped exponential
/// backoff under a hard wall-clock deadline. Retry is idempotent-safe —
/// every SIMD-wire request is a pure computation, so re-executing one
/// after an ambiguous transport failure can only repeat the same answer.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Maximum attempts charged against transport failures, reconnects
    /// and retriable per-request failures combined.
    pub max_attempts: u32,
    /// First backoff; doubles per attempt up to `max_backoff`.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// Hard wall-clock budget for the whole call.
    pub deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 8,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(250),
            deadline: Duration::from_secs(30),
        }
    }
}

impl RetryPolicy {
    fn backoff(&self, attempt: u32) -> Duration {
        self.base_backoff.saturating_mul(1u32 << attempt.min(16)).min(self.max_backoff)
    }
}

/// Equal-jitter a backoff: uniform in `[base/2, base]`. Keeps at least
/// half the deterministic backoff (so retry pressure still decays
/// exponentially) while decorrelating clients whose failures — and hence
/// retry clocks — were synchronized by the same server event.
fn jittered(base: Duration, rng: &mut Rng) -> Duration {
    let ns = base.as_nanos() as u64;
    if ns == 0 {
        return base;
    }
    let half = ns / 2;
    Duration::from_nanos(half + rng.below(ns - half + 1))
}

/// Per-process seed sequence for client backoff RNGs: each new
/// connection takes a distinct seed, so two clients built in the same
/// instant still jitter independently.
static NEXT_BACKOFF_SEED: AtomicU64 = AtomicU64::new(0x0B5E_ED0F);

fn next_backoff_seed() -> u64 {
    NEXT_BACKOFF_SEED.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed)
}

/// Is a per-request failure worth retrying? Overload and shard
/// unavailability are transient by design; protocol errors are not.
pub fn retriable(err: u8) -> bool {
    matches!(err, wire::ERR_OVERLOAD | wire::ERR_UNAVAILABLE)
}

/// A SIMD-wire connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    chunk: usize,
    /// Resolved peer address, kept for reconnects.
    addr: SocketAddr,
    io_timeout: Option<Duration>,
    /// Reconnects performed by `exchange_with_retry` over this client's
    /// lifetime (chaos-report observability).
    reconnects: u64,
    /// Jitter source for retry backoff; survives reconnects so the
    /// jitter stream never resets in lockstep with the failure.
    backoff_rng: Rng,
}

impl Client {
    /// Connect and perform the hello exchange. The connection starts with
    /// [`DEFAULT_IO_TIMEOUT`] on both socket directions.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Client::handshake(stream, Some(DEFAULT_IO_TIMEOUT), DEFAULT_CHUNK)
    }

    fn handshake(
        stream: TcpStream,
        io_timeout: Option<Duration>,
        chunk: usize,
    ) -> io::Result<Client> {
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(io_timeout)?;
        stream.set_write_timeout(io_timeout)?;
        let addr = stream.peer_addr()?;
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut reader = BufReader::new(stream);
        wire::write_hello(&mut writer)?;
        writer.flush()?;
        let version = wire::read_hello(&mut reader)?;
        if version != wire::VERSION {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("server speaks SIMD-wire v{version}, client v{}", wire::VERSION),
            ));
        }
        Ok(Client {
            reader,
            writer,
            chunk,
            addr,
            io_timeout,
            reconnects: 0,
            backoff_rng: Rng::new(next_backoff_seed()),
        })
    }

    /// Connect, retrying while the server is still coming up (used by the
    /// load generator and CI smoke against a just-spawned `simdive serve`).
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        timeout: Duration,
    ) -> io::Result<Client> {
        let t0 = Instant::now();
        loop {
            match Client::connect(addr.clone()) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if t0.elapsed() >= timeout {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Set the pipeline chunk size (clamped to `1..=MAX_CHUNK`).
    pub fn with_chunk(mut self, chunk: usize) -> Client {
        self.chunk = chunk.clamp(1, MAX_CHUNK);
        self
    }

    /// Re-seed the retry-backoff jitter source (deterministic tests; the
    /// default seed is a per-process sequence, distinct per connection).
    pub fn with_retry_seed(mut self, seed: u64) -> Client {
        self.backoff_rng = Rng::new(seed);
        self
    }

    /// Override the read/write socket timeout (`None` = block forever,
    /// the pre-v3 behavior). Applies to the live connection and to every
    /// reconnect made by [`Client::exchange_with_retry`].
    pub fn with_io_timeout(mut self, timeout: Option<Duration>) -> io::Result<Client> {
        let stream = self.reader.get_ref();
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        self.io_timeout = timeout;
        Ok(self)
    }

    /// Reconnects performed by [`Client::exchange_with_retry`] so far.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Tear down the (possibly wedged) connection and build a fresh one
    /// to the same peer, preserving chunk and timeout settings. Any
    /// responses still in flight on the old connection are abandoned —
    /// the server frees their window slots when it observes the close.
    fn reconnect(&mut self) -> io::Result<()> {
        let stream = TcpStream::connect(self.addr)?;
        let fresh = Client::handshake(stream, self.io_timeout, self.chunk)?;
        self.reader = fresh.reader;
        self.writer = fresh.writer;
        self.reconnects += 1;
        Ok(())
    }

    /// One synchronous round trip. The response may carry `err != 0` (a
    /// per-request server failure); transport and protocol problems are
    /// `Err`.
    pub fn call(&mut self, req: WireRequest) -> io::Result<WireResponse> {
        wire::write_request(&mut self.writer, &req)?;
        self.writer.flush()?;
        self.read_response()
    }

    /// Pipelined exchange: submit every request, return the responses in
    /// **submission order** (responses arrive out of order; correlation is
    /// by id, so ids must be unique within one call — duplicates are
    /// rejected up front rather than silently mis-associated). Per-request
    /// server failures come back as responses with `err != 0`; a response
    /// for an id never submitted (or submitted and already answered) is a
    /// protocol error, never a panic.
    pub fn exchange(&mut self, reqs: &[WireRequest]) -> io::Result<Vec<WireResponse>> {
        let n = reqs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // id → submission position still awaiting its response.
        let mut by_id: HashMap<u64, usize> = HashMap::with_capacity(n);
        for (i, r) in reqs.iter().enumerate() {
            if by_id.insert(r.id, i).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("duplicate request id {} in one exchange", r.id),
                ));
            }
        }
        let mut out: Vec<Option<WireResponse>> = vec![None; n];
        let max_inflight = 2 * self.chunk;
        let (mut sent, mut recvd) = (0usize, 0usize);
        while recvd < n {
            // Top up the pipeline without exceeding two chunks in flight.
            while sent < n && (sent - recvd) + (n - sent).min(self.chunk) <= max_inflight {
                let take = (n - sent).min(self.chunk);
                wire::write_batch(&mut self.writer, &reqs[sent..sent + take])?;
                sent += take;
            }
            self.writer.flush()?;
            // Drain responses until another chunk fits (or until done).
            loop {
                let resp = self.read_response()?;
                let pos = by_id.remove(&resp.id).ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("response for unknown or duplicate id {}", resp.id),
                    )
                })?;
                out[pos] = Some(resp);
                recvd += 1;
                if recvd == n {
                    break;
                }
                let can_send =
                    sent < n && (sent - recvd) + (n - sent).min(self.chunk) <= max_inflight;
                if can_send {
                    break;
                }
            }
        }
        out.into_iter()
            .map(|o| {
                o.ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        "exchange bookkeeping lost a response",
                    )
                })
            })
            .collect()
    }

    /// [`Client::exchange`] with idempotent retry: transport failures
    /// reconnect and resubmit every unresolved request; per-request
    /// failures with a [`retriable`] code resubmit just those requests.
    /// Backoff doubles per attempt (capped), and the whole call observes
    /// `policy.deadline`. When the budget runs out with retriable
    /// failures still outstanding, the last failed responses are returned
    /// (`err != 0`) — a definitive failure, never a hang; a transport
    /// failure that exhausts the budget is `Err`.
    pub fn exchange_with_retry(
        &mut self,
        reqs: &[WireRequest],
        policy: &RetryPolicy,
    ) -> io::Result<Vec<WireResponse>> {
        let t0 = Instant::now();
        let mut out: Vec<Option<WireResponse>> = vec![None; reqs.len()];
        // Submission positions still needing a (successful or final) answer.
        let mut todo: Vec<usize> = (0..reqs.len()).collect();
        let mut attempt = 0u32;
        while !todo.is_empty() {
            let batch: Vec<WireRequest> = todo.iter().map(|&i| reqs[i]).collect();
            match self.exchange(&batch) {
                Ok(resps) => {
                    let mut still = Vec::new();
                    for (k, resp) in resps.into_iter().enumerate() {
                        let i = todo[k];
                        out[i] = Some(resp);
                        if resp.err != 0 && retriable(resp.err) {
                            still.push(i);
                        }
                    }
                    todo = still;
                    if todo.is_empty() {
                        break;
                    }
                    // Retriable failures left: back off, then resubmit.
                    attempt += 1;
                    if attempt >= policy.max_attempts || t0.elapsed() >= policy.deadline {
                        break; // deliver the recorded failures
                    }
                    std::thread::sleep(jittered(policy.backoff(attempt), &mut self.backoff_rng));
                }
                Err(e) => {
                    // Transport fault: the connection state is unknown, so
                    // reconnect before resubmitting the unresolved tail.
                    attempt += 1;
                    if attempt >= policy.max_attempts || t0.elapsed() >= policy.deadline {
                        return Err(io::Error::new(
                            e.kind(),
                            format!(
                                "retry budget exhausted after {attempt} attempts \
                                 ({} requests unresolved): {e}",
                                todo.len()
                            ),
                        ));
                    }
                    std::thread::sleep(jittered(policy.backoff(attempt), &mut self.backoff_rng));
                    while let Err(re) = self.reconnect() {
                        attempt += 1;
                        if attempt >= policy.max_attempts || t0.elapsed() >= policy.deadline {
                            return Err(re);
                        }
                        std::thread::sleep(jittered(
                            policy.backoff(attempt),
                            &mut self.backoff_rng,
                        ));
                    }
                }
            }
        }
        out.into_iter()
            .map(|o| {
                o.ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::TimedOut,
                        "request unresolved within the retry budget",
                    )
                })
            })
            .collect()
    }

    /// Fetch a server stats snapshot. Must not be called with requests in
    /// flight (i.e. outside `exchange`, which always drains fully).
    pub fn stats(&mut self) -> io::Result<WireStats> {
        wire::write_stats_req(&mut self.writer)?;
        self.writer.flush()?;
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Stats(s) => Ok(s),
            ServerFrame::Err(code) => Err(server_err(code)),
            other => Err(unexpected_frame(&other, "legacy stats")),
        }
    }

    /// Fetch the `STATS2` registry snapshot (wire v4): every counter,
    /// gauge and stage/latency histogram under its dotted name. Same
    /// no-requests-in-flight contract as [`Client::stats`].
    pub fn stats2(&mut self) -> io::Result<Snapshot> {
        wire::write_stats2_req(&mut self.writer)?;
        self.writer.flush()?;
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Stats2(s) => Ok(s),
            ServerFrame::Err(code) => Err(server_err(code)),
            other => Err(unexpected_frame(&other, "stats2")),
        }
    }

    /// Drain the server's sampled trace ring (wire v4), oldest event
    /// first. Same no-requests-in-flight contract as [`Client::stats`].
    pub fn trace_events(&mut self) -> io::Result<Vec<TraceEvent>> {
        wire::write_trace_req(&mut self.writer)?;
        self.writer.flush()?;
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Trace(events) => Ok(events),
            ServerFrame::Err(code) => Err(server_err(code)),
            other => Err(unexpected_frame(&other, "trace")),
        }
    }

    fn read_response(&mut self) -> io::Result<WireResponse> {
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Resp(r) => Ok(r),
            ServerFrame::Err(code) => Err(server_err(code)),
            other => Err(unexpected_frame(&other, "responses")),
        }
    }
}

/// Protocol-confusion error: the server answered with a frame kind the
/// client wasn't awaiting.
fn unexpected_frame(frame: &ServerFrame, awaiting: &str) -> io::Error {
    let kind = match frame {
        ServerFrame::Resp(_) => "response",
        ServerFrame::Stats(_) => "stats",
        ServerFrame::Stats2(_) => "stats2",
        ServerFrame::Trace(_) => "trace",
        ServerFrame::Err(_) => "error",
    };
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("unexpected {kind} frame while awaiting {awaiting}"),
    )
}

/// Human-readable error for a connection-fatal `ERR` code. Unknown codes
/// (a newer server) map to a generic message, never a panic.
fn server_err(code: u8) -> io::Error {
    let what = match code {
        wire::ERR_BAD_FRAME => "bad frame",
        wire::ERR_BAD_REQUEST => "bad request",
        wire::ERR_BAD_VERSION => "unsupported protocol version",
        wire::ERR_OVERLOAD => "overloaded (admission deadline exceeded)",
        wire::ERR_UNAVAILABLE => "shard unavailable",
        _ => "unknown error",
    };
    io::Error::new(io::ErrorKind::InvalidData, format!("server error {code} ({what})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_backoff_is_capped_exponential() {
        let p = RetryPolicy {
            base_backoff: Duration::from_millis(4),
            max_backoff: Duration::from_millis(50),
            ..RetryPolicy::default()
        };
        assert_eq!(p.backoff(0), Duration::from_millis(4));
        assert_eq!(p.backoff(1), Duration::from_millis(8));
        assert_eq!(p.backoff(2), Duration::from_millis(16));
        assert_eq!(p.backoff(4), Duration::from_millis(50), "cap binds");
        assert_eq!(p.backoff(60), Duration::from_millis(50), "huge attempts stay capped");
    }

    #[test]
    fn retriable_codes_are_exactly_the_transient_ones() {
        assert!(retriable(wire::ERR_OVERLOAD));
        assert!(retriable(wire::ERR_UNAVAILABLE));
        assert!(!retriable(wire::ERR_BAD_FRAME));
        assert!(!retriable(wire::ERR_BAD_REQUEST));
        assert!(!retriable(wire::ERR_BAD_VERSION));
        assert!(!retriable(0));
        assert!(!retriable(200), "unknown codes are final, not retried blind");
    }

    #[test]
    fn unknown_err_codes_do_not_panic() {
        let e = server_err(250);
        assert!(e.to_string().contains("unknown error"), "{e}");
    }

    #[test]
    fn jitter_stays_within_equal_jitter_bounds() {
        let mut rng = Rng::new(7);
        let base = Duration::from_millis(100);
        for _ in 0..1000 {
            let j = jittered(base, &mut rng);
            assert!(j >= base / 2, "jitter below half base: {j:?}");
            assert!(j <= base, "jitter above base: {j:?}");
        }
    }

    #[test]
    fn jitter_leaves_zero_backoff_alone() {
        let mut rng = Rng::new(7);
        assert_eq!(jittered(Duration::ZERO, &mut rng), Duration::ZERO);
    }

    #[test]
    fn jitter_is_deterministic_per_seed_and_spreads_across_seeds() {
        let base = Duration::from_millis(64);
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..8).map(|_| jittered(base, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42), "same seed must replay the same jitter");
        assert_ne!(draw(42), draw(43), "distinct seeds must decorrelate");
    }

    #[test]
    fn backoff_seeds_are_distinct_per_client() {
        let a = next_backoff_seed();
        let b = next_backoff_seed();
        assert_ne!(a, b);
    }
}
