//! Pipelined SIMD-wire client (DESIGN.md §8).
//!
//! [`Client::exchange`] is the throughput path: it keeps up to two
//! pipeline chunks of requests in flight (writing chunk *k+1* before the
//! responses of chunk *k* have drained) and reassembles the out-of-order
//! response stream into submission order by correlation id. The chunk
//! size is capped so the worst-case unread response backlog always fits
//! kernel socket buffers — the client can therefore never deadlock
//! against a server whose admission window is smaller than the pipeline.

use super::wire::{self, ServerFrame, WireRequest, WireResponse, WireStats};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Default pipeline chunk (requests per `BATCH` frame).
pub const DEFAULT_CHUNK: usize = 256;

/// Upper bound on the pipeline chunk: with two chunks in flight plus one
/// being written, the unread response backlog stays ≤ ~3 · 1024 · 17 B
/// ≈ 52 KB, below the smallest kernel socket buffers.
pub const MAX_CHUNK: usize = 1024;

/// A SIMD-wire connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    chunk: usize,
}

impl Client {
    /// Connect and perform the hello exchange.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut reader = BufReader::new(stream);
        wire::write_hello(&mut writer)?;
        writer.flush()?;
        let version = wire::read_hello(&mut reader)?;
        if version != wire::VERSION {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("server speaks SIMD-wire v{version}, client v{}", wire::VERSION),
            ));
        }
        Ok(Client { reader, writer, chunk: DEFAULT_CHUNK })
    }

    /// Connect, retrying while the server is still coming up (used by the
    /// load generator and CI smoke against a just-spawned `simdive serve`).
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        timeout: Duration,
    ) -> io::Result<Client> {
        let t0 = Instant::now();
        loop {
            match Client::connect(addr.clone()) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if t0.elapsed() >= timeout {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Set the pipeline chunk size (clamped to `1..=MAX_CHUNK`).
    pub fn with_chunk(mut self, chunk: usize) -> Client {
        self.chunk = chunk.clamp(1, MAX_CHUNK);
        self
    }

    /// One synchronous round trip.
    pub fn call(&mut self, req: WireRequest) -> io::Result<WireResponse> {
        wire::write_request(&mut self.writer, &req)?;
        self.writer.flush()?;
        self.read_response()
    }

    /// Pipelined exchange: submit every request, return the responses in
    /// **submission order** (responses arrive out of order; correlation is
    /// by id, so ids must be unique within one call — duplicates are
    /// rejected up front rather than silently mis-associated).
    pub fn exchange(&mut self, reqs: &[WireRequest]) -> io::Result<Vec<WireResponse>> {
        let n = reqs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // id → submission position still awaiting its response.
        let mut by_id: HashMap<u64, usize> = HashMap::with_capacity(n);
        for (i, r) in reqs.iter().enumerate() {
            if by_id.insert(r.id, i).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("duplicate request id {} in one exchange", r.id),
                ));
            }
        }
        let mut out: Vec<Option<WireResponse>> = vec![None; n];
        let max_inflight = 2 * self.chunk;
        let (mut sent, mut recvd) = (0usize, 0usize);
        while recvd < n {
            // Top up the pipeline without exceeding two chunks in flight.
            while sent < n && (sent - recvd) + (n - sent).min(self.chunk) <= max_inflight {
                let take = (n - sent).min(self.chunk);
                wire::write_batch(&mut self.writer, &reqs[sent..sent + take])?;
                sent += take;
            }
            self.writer.flush()?;
            // Drain responses until another chunk fits (or until done).
            loop {
                let resp = self.read_response()?;
                let pos = by_id.remove(&resp.id).ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("response for unknown id {}", resp.id),
                    )
                })?;
                out[pos] = Some(resp);
                recvd += 1;
                if recvd == n {
                    break;
                }
                let can_send =
                    sent < n && (sent - recvd) + (n - sent).min(self.chunk) <= max_inflight;
                if can_send {
                    break;
                }
            }
        }
        Ok(out.into_iter().map(|o| o.unwrap()).collect())
    }

    /// Fetch a server stats snapshot. Must not be called with requests in
    /// flight (i.e. outside `exchange`, which always drains fully).
    pub fn stats(&mut self) -> io::Result<WireStats> {
        wire::write_stats_req(&mut self.writer)?;
        self.writer.flush()?;
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Stats(s) => Ok(s),
            ServerFrame::Resp(r) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response frame (id {}) while awaiting stats", r.id),
            )),
            ServerFrame::Err(code) => Err(server_err(code)),
        }
    }

    fn read_response(&mut self) -> io::Result<WireResponse> {
        match wire::read_server_frame(&mut self.reader)? {
            ServerFrame::Resp(r) => Ok(r),
            ServerFrame::Stats(_) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "unexpected stats frame while awaiting responses",
            )),
            ServerFrame::Err(code) => Err(server_err(code)),
        }
    }
}

fn server_err(code: u8) -> io::Error {
    let what = match code {
        wire::ERR_BAD_FRAME => "bad frame",
        wire::ERR_BAD_REQUEST => "bad request",
        wire::ERR_BAD_VERSION => "unsupported protocol version",
        _ => "unknown error",
    };
    io::Error::new(io::ErrorKind::InvalidData, format!("server error {code} ({what})"))
}
