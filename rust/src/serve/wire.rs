//! SIMD-wire: the versioned little-endian binary protocol of the network
//! serving subsystem (DESIGN.md §8).
//!
//! A connection opens with an 8-byte hello exchanged in both directions
//! (`MAGIC` + protocol version; the server always states its own version,
//! then answers an unsupported one with `ERR_BAD_VERSION` and a close),
//! then carries a stream of 1-byte-kind frames. Request bodies are
//! fixed-size (32 bytes) and carry the paper's per-operand accuracy knob
//! `w` (§3.3) *per request*, so every client chooses its own
//! accuracy/latency trade-off on the wire. A `BATCH` frame carries up to
//! [`MAX_BATCH`] request bodies under one header — the framing the
//! pipelined client and the load generator use.
//!
//! Wire v2 (append-only evolution of v1): the request body grows a
//! trailing `budget_ppm:u32` field and a defined `flags` bit. With
//! [`FLAG_BUDGET`] set, the client states a maximum mean-relative-error
//! budget in parts per million instead of committing to a `w`; the
//! server's error-budget router (DESIGN.md §9) picks the cheapest `w`
//! satisfying it. Reserved flag bits must be zero and the flag must agree
//! with the field (`FLAG_BUDGET` ⟺ `budget_ppm > 0`) — a frame violating
//! either is malformed, never silently reinterpreted.
//!
//! Wire v3 (fault tolerance — DESIGN.md §11): a *per-request* error frame
//! `RESP_ERR` joins the connection-fatal `ERR`. The server answers a
//! request it sheds under overload ([`ERR_OVERLOAD`]) or fails after
//! shard supervision gives up ([`ERR_UNAVAILABLE`]) with a `RESP_ERR`
//! carrying the request's id — the connection stays open and every other
//! in-flight request is unaffected. `STATS_RESP` appends three counters
//! (open connections, shed requests, unavailable-failed requests).
//!
//! Wire v4 (observability — DESIGN.md §12): two new request/response
//! pairs. `STATS2` answers with a **tagged key–value** snapshot of the
//! server's metrics registry — counters, gauges and log2 histograms under
//! stable dotted names — so the stats surface grows by adding entries,
//! never by re-laying-out a fixed struct. `TRACE` drains the server's
//! sampled trace ring as fixed 60-byte lifecycle events. The legacy
//! `STATS`/`STATS_RESP` pair is untouched and stays bit-identical to v3.
//!
//! | kind | dir | body |
//! |------|-----|------|
//! | `REQ` (0x01)         | c→s | 32 B: `id:u64, a:u64, b:u64, op:u8, bits:u8, w:u8, flags:u8, budget_ppm:u32` |
//! | `BATCH` (0x02)       | c→s | `count:u16` then `count` request bodies |
//! | `STATS` (0x03)       | c→s | empty |
//! | `STATS2` (0x04)      | c→s | empty (wire v4) |
//! | `TRACE` (0x05)       | c→s | empty (wire v4) |
//! | `RESP` (0x81)        | s→c | 16 B: `id:u64, value:u64` |
//! | `STATS_RESP` (0x82)  | s→c | 104 B: thirteen `u64` counters ([`WireStats`]) |
//! | `RESP_ERR` (0x83)    | s→c | 9 B: `id:u64, code:u8` — per-request failure, connection stays open |
//! | `STATS2_RESP` (0x84) | s→c | `count:u32` then `count` × (`key_len:u16, key, tag:u8, value`) — tag 0 counter `u64`, 1 gauge `i64`, 2 histogram (`nbuckets:u8` then `nbuckets` × `u64`) |
//! | `TRACE_RESP` (0x85)  | s→c | `count:u32` then `count` × 60 B events (`id:u64, op:u8, bits:u8, w:u8, shard:u8`, six `u64` timestamps) |
//! | `ERR` (0xEE)         | s→c | 1 B error code, then the server closes |
//!
//! Responses arrive **out of order** (as SIMD lanes complete); the `id` is
//! the correlation key and is echoed verbatim.

use crate::arith::W_MAX;
use crate::coordinator::ReqOp;
use crate::obs::registry::HIST_BUCKETS;
use crate::obs::{HistSnapshot, Snapshot, TraceEvent, Value};
use std::io::{self, Read, Write};

/// Connection magic, first bytes on the wire in both directions.
pub const MAGIC: [u8; 4] = *b"SDIV";

/// Protocol version carried in the hello. v2 widened the request body by
/// an appended `budget_ppm:u32` and defined [`FLAG_BUDGET`]; v3 added the
/// per-request `RESP_ERR` frame and three appended stats counters; v4
/// added the `STATS2` tagged key–value snapshot and `TRACE` frames.
pub const VERSION: u16 = 4;

/// Frame kinds (client → server).
pub const FRAME_REQ: u8 = 0x01;
pub const FRAME_BATCH: u8 = 0x02;
pub const FRAME_STATS: u8 = 0x03;
/// Registry snapshot request (wire v4); empty body.
pub const FRAME_STATS2: u8 = 0x04;
/// Trace-ring drain request (wire v4); empty body.
pub const FRAME_TRACE: u8 = 0x05;

/// Frame kinds (server → client).
pub const FRAME_RESP: u8 = 0x81;
pub const FRAME_STATS_RESP: u8 = 0x82;
/// Per-request failure (wire v3); unlike `ERR` the connection stays open.
pub const FRAME_RESP_ERR: u8 = 0x83;
/// Tagged key–value registry snapshot (wire v4).
pub const FRAME_STATS2_RESP: u8 = 0x84;
/// Sampled lifecycle trace events (wire v4).
pub const FRAME_TRACE_RESP: u8 = 0x85;
pub const FRAME_ERR: u8 = 0xEE;

/// Error codes carried by an `ERR` frame (connection-fatal) or a
/// `RESP_ERR` frame (per-request, wire v3).
pub const ERR_BAD_FRAME: u8 = 1;
pub const ERR_BAD_REQUEST: u8 = 2;
pub const ERR_BAD_VERSION: u8 = 3;
/// The admission window stayed full past the request's deadline; the
/// server shed the request instead of queueing it unboundedly. Safe to
/// retry after backoff (the computation is pure/idempotent).
pub const ERR_OVERLOAD: u8 = 4;
/// Shard supervision gave up on the request (double fault: the executing
/// shard panicked and recovery failed too). Safe to retry.
pub const ERR_UNAVAILABLE: u8 = 5;

/// Fixed size of a request body (v2: v1's 28 bytes + `budget_ppm:u32`).
pub const REQ_BODY_LEN: usize = 32;

/// Request `flags` bit 0: route by error budget. When set, `budget_ppm`
/// holds the client's maximum mean relative error (parts per million;
/// 10_000 ppm = 1%) and the server picks the cheapest accuracy knob
/// satisfying it; the `w` byte is carried but ignored. All other flag
/// bits are reserved and must be zero.
pub const FLAG_BUDGET: u8 = 0x01;

/// Fixed size of a response body.
pub const RESP_BODY_LEN: usize = 16;

/// Fixed size of a `RESP_ERR` body: `id:u64, code:u8`.
pub const RESP_ERR_BODY_LEN: usize = 9;

/// Maximum request bodies in one `BATCH` frame (`count` is a `u16`).
pub const MAX_BATCH: usize = u16::MAX as usize;

/// Decode caps for the variable-length v4 frames: a corrupted or hostile
/// length prefix must never drive an unbounded allocation. Far above any
/// real snapshot (the registry carries ~100 names) or trace ring.
pub const MAX_STATS2_ENTRIES: usize = 4096;
/// Longest metric name accepted on the wire.
pub const MAX_STATS2_KEY_LEN: usize = 256;
/// Fixed encoded size of one trace event: `id:u64` + four shape bytes +
/// six `u64` timestamps.
pub const TRACE_EVENT_LEN: usize = 60;
/// Maximum events in one `TRACE_RESP` frame.
pub const MAX_TRACE_EVENTS: usize = 65_536;

/// One request as it travels on the wire: the coordinator request fields
/// plus the per-request accuracy knob `w`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireRequest {
    /// Client-chosen correlation id, echoed on the response.
    pub id: u64,
    pub op: ReqOp,
    /// Operand width: 8, 16 or 32.
    pub bits: u32,
    /// Accuracy knob (number of coefficient LUTs), `0..=W_MAX`. Ignored
    /// by the server when `budget_ppm > 0`.
    pub w: u32,
    /// Error budget in parts per million; `0` = fixed-`w` mode. When
    /// non-zero the server's error-budget router picks the cheapest `w`
    /// whose profiled MRED fits (DESIGN.md §9).
    pub budget_ppm: u32,
    pub a: u64,
    pub b: u64,
}

impl WireRequest {
    /// Encode the fixed-size body (no kind byte). `FLAG_BUDGET` is set
    /// exactly when `budget_ppm > 0`.
    pub fn encode_body(&self, buf: &mut [u8; REQ_BODY_LEN]) {
        buf[0..8].copy_from_slice(&self.id.to_le_bytes());
        buf[8..16].copy_from_slice(&self.a.to_le_bytes());
        buf[16..24].copy_from_slice(&self.b.to_le_bytes());
        buf[24] = match self.op {
            ReqOp::Mul => 0,
            ReqOp::Div => 1,
        };
        buf[25] = self.bits as u8;
        buf[26] = self.w as u8;
        buf[27] = if self.budget_ppm > 0 { FLAG_BUDGET } else { 0 };
        buf[28..32].copy_from_slice(&self.budget_ppm.to_le_bytes());
    }

    /// Decode and validate a fixed-size body. Errors name the offending
    /// field; the server answers them with `ERR_BAD_REQUEST`. Reserved
    /// flag bits and a flag/field mismatch are rejected — a corrupted
    /// frame must never be silently reinterpreted.
    pub fn decode_body(buf: &[u8; REQ_BODY_LEN]) -> Result<WireRequest, String> {
        let id = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let a = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        let b = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let op = match buf[24] {
            0 => ReqOp::Mul,
            1 => ReqOp::Div,
            other => return Err(format!("bad op byte {other}")),
        };
        let bits = buf[25] as u32;
        if !matches!(bits, 8 | 16 | 32) {
            return Err(format!("bad width {bits}"));
        }
        let w = buf[26] as u32;
        if w > W_MAX {
            return Err(format!("accuracy knob w={w} exceeds {W_MAX}"));
        }
        let flags = buf[27];
        if flags & !FLAG_BUDGET != 0 {
            return Err(format!("reserved flag bits set (0x{flags:02x})"));
        }
        let budget_ppm = u32::from_le_bytes(buf[28..32].try_into().unwrap());
        if (flags & FLAG_BUDGET != 0) != (budget_ppm > 0) {
            return Err(format!(
                "budget flag 0x{flags:02x} disagrees with budget_ppm {budget_ppm}"
            ));
        }
        let max = crate::arith::max_val(bits);
        if a > max || b > max {
            return Err(format!("operands ({a}, {b}) exceed {bits}-bit range"));
        }
        Ok(WireRequest { id, op, bits, w, budget_ppm, a, b })
    }
}

/// One response as it travels on the wire. A successful `RESP` carries
/// `err == 0` and the value; a per-request `RESP_ERR` (wire v3) decodes
/// to `err != 0` with `value == 0` — one type, so the client's pipeline
/// reassembly treats failures as ordinary out-of-order completions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireResponse {
    pub id: u64,
    pub value: u64,
    /// `0` for success, else the `ERR_*` code the server failed this
    /// request with (`ERR_OVERLOAD`, `ERR_UNAVAILABLE`, or a future code —
    /// clients must tolerate unknown values).
    pub err: u8,
}

impl WireResponse {
    pub fn is_ok(&self) -> bool {
        self.err == 0
    }
}

/// The `STATS_RESP` payload: server-wide counters (first seven fields),
/// the requesting connection's own view (next three), and the v3
/// fault-tolerance counters (last three). Fixed thirteen-`u64`
/// little-endian layout; new fields are append-only with a version bump.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Completed requests, server-wide.
    pub requests: u64,
    /// Packed SIMD words executed by the shared coordinator.
    pub words: u64,
    pub active_lanes: u64,
    pub total_lanes: u64,
    /// Modelled energy in milli-pJ (integer on the wire).
    pub energy_mpj: u64,
    /// Server-wide admission→response latency percentiles, microseconds.
    pub p50_us: u64,
    pub p99_us: u64,
    /// Completed requests on this connection.
    pub conn_requests: u64,
    pub conn_p50_us: u64,
    pub conn_p99_us: u64,
    /// Currently open connections (wire v3).
    pub connections: u64,
    /// Requests shed with `ERR_OVERLOAD` (wire v3).
    pub shed_overload: u64,
    /// Requests failed with `ERR_UNAVAILABLE` (wire v3).
    pub failed_unavailable: u64,
}

impl WireStats {
    pub const BODY_LEN: usize = 104;

    pub fn lane_utilization(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.active_lanes as f64 / self.total_lanes as f64
        }
    }

    pub fn energy_pj(&self) -> f64 {
        self.energy_mpj as f64 / 1000.0
    }

    fn fields(&self) -> [u64; 13] {
        [
            self.requests,
            self.words,
            self.active_lanes,
            self.total_lanes,
            self.energy_mpj,
            self.p50_us,
            self.p99_us,
            self.conn_requests,
            self.conn_p50_us,
            self.conn_p99_us,
            self.connections,
            self.shed_overload,
            self.failed_unavailable,
        ]
    }

    fn from_fields(f: [u64; 13]) -> WireStats {
        WireStats {
            requests: f[0],
            words: f[1],
            active_lanes: f[2],
            total_lanes: f[3],
            energy_mpj: f[4],
            p50_us: f[5],
            p99_us: f[6],
            conn_requests: f[7],
            conn_p50_us: f[8],
            conn_p99_us: f[9],
            connections: f[10],
            shed_overload: f[11],
            failed_unavailable: f[12],
        }
    }
}

/// Write the 8-byte hello (magic, version, reserved).
pub fn write_hello<W: Write>(w: &mut W) -> io::Result<()> {
    let mut buf = [0u8; 8];
    buf[0..4].copy_from_slice(&MAGIC);
    buf[4..6].copy_from_slice(&VERSION.to_le_bytes());
    w.write_all(&buf)
}

/// Read and check the 8-byte hello; returns the peer's version.
pub fn read_hello<R: Read>(r: &mut R) -> io::Result<u16> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    if buf[0..4] != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad SIMD-wire magic"));
    }
    Ok(u16::from_le_bytes(buf[4..6].try_into().unwrap()))
}

/// Write a single-request frame.
pub fn write_request<W: Write>(w: &mut W, req: &WireRequest) -> io::Result<()> {
    let mut body = [0u8; REQ_BODY_LEN];
    req.encode_body(&mut body);
    w.write_all(&[FRAME_REQ])?;
    w.write_all(&body)
}

/// Write a batch frame (`reqs.len()` must be `1..=MAX_BATCH`).
pub fn write_batch<W: Write>(w: &mut W, reqs: &[WireRequest]) -> io::Result<()> {
    assert!(!reqs.is_empty() && reqs.len() <= MAX_BATCH, "batch of {}", reqs.len());
    w.write_all(&[FRAME_BATCH])?;
    w.write_all(&(reqs.len() as u16).to_le_bytes())?;
    let mut body = [0u8; REQ_BODY_LEN];
    for req in reqs {
        req.encode_body(&mut body);
        w.write_all(&body)?;
    }
    Ok(())
}

/// Write a stats-request frame.
pub fn write_stats_req<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(&[FRAME_STATS])
}

/// Write a response frame.
pub fn write_response<W: Write>(w: &mut W, id: u64, value: u64) -> io::Result<()> {
    let mut buf = [0u8; 1 + RESP_BODY_LEN];
    buf[0] = FRAME_RESP;
    buf[1..9].copy_from_slice(&id.to_le_bytes());
    buf[9..17].copy_from_slice(&value.to_le_bytes());
    w.write_all(&buf)
}

/// Write a per-request error frame (wire v3). Unlike [`write_err`] the
/// connection stays open; the failure only resolves the one request.
pub fn write_response_err<W: Write>(w: &mut W, id: u64, code: u8) -> io::Result<()> {
    debug_assert_ne!(code, 0, "RESP_ERR code 0 would decode as success");
    let mut buf = [0u8; 1 + RESP_ERR_BODY_LEN];
    buf[0] = FRAME_RESP_ERR;
    buf[1..9].copy_from_slice(&id.to_le_bytes());
    buf[9] = code;
    w.write_all(&buf)
}

/// Write a stats-response frame.
pub fn write_stats_resp<W: Write>(w: &mut W, s: &WireStats) -> io::Result<()> {
    w.write_all(&[FRAME_STATS_RESP])?;
    for v in s.fields() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Write a `STATS2` request frame (wire v4).
pub fn write_stats2_req<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(&[FRAME_STATS2])
}

/// Write a `TRACE` request frame (wire v4).
pub fn write_trace_req<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(&[FRAME_TRACE])
}

/// Value type tags in a `STATS2_RESP` entry.
const TAG_COUNTER: u8 = 0;
const TAG_GAUGE: u8 = 1;
const TAG_HIST: u8 = 2;

/// Write a `STATS2_RESP` frame: the registry snapshot as tagged
/// key–value entries (wire v4). Entries past [`MAX_STATS2_ENTRIES`] are
/// dropped (never reached by the real registry).
pub fn write_stats2_resp<W: Write>(w: &mut W, snap: &Snapshot) -> io::Result<()> {
    let n = snap.entries.len().min(MAX_STATS2_ENTRIES);
    let mut buf = Vec::with_capacity(8 + n * 32);
    buf.push(FRAME_STATS2_RESP);
    buf.extend_from_slice(&(n as u32).to_le_bytes());
    for (name, value) in snap.entries.iter().take(n) {
        let key = name.as_bytes();
        assert!(
            !key.is_empty() && key.len() <= MAX_STATS2_KEY_LEN,
            "metric name '{name}' violates the wire key bounds"
        );
        buf.extend_from_slice(&(key.len() as u16).to_le_bytes());
        buf.extend_from_slice(key);
        match value {
            Value::Counter(v) => {
                buf.push(TAG_COUNTER);
                buf.extend_from_slice(&v.to_le_bytes());
            }
            Value::Gauge(v) => {
                buf.push(TAG_GAUGE);
                buf.extend_from_slice(&v.to_le_bytes());
            }
            Value::Hist(h) => {
                buf.push(TAG_HIST);
                buf.push(HIST_BUCKETS as u8);
                for b in h.buckets {
                    buf.extend_from_slice(&b.to_le_bytes());
                }
            }
        }
    }
    w.write_all(&buf)
}

/// Write a `TRACE_RESP` frame (wire v4). Events past [`MAX_TRACE_EVENTS`]
/// are dropped (the server's ring is far smaller).
pub fn write_trace_resp<W: Write>(w: &mut W, events: &[TraceEvent]) -> io::Result<()> {
    let n = events.len().min(MAX_TRACE_EVENTS);
    let mut buf = Vec::with_capacity(8 + n * TRACE_EVENT_LEN);
    buf.push(FRAME_TRACE_RESP);
    buf.extend_from_slice(&(n as u32).to_le_bytes());
    for e in &events[..n] {
        buf.extend_from_slice(&e.id.to_le_bytes());
        buf.extend_from_slice(&[e.op, e.bits, e.w, e.shard]);
        for t in [e.t_admit_ns, e.t_submit_ns, e.t_fold_ns, e.t_emit_ns, e.t_done_ns, e.t_write_ns]
        {
            buf.extend_from_slice(&t.to_le_bytes());
        }
    }
    w.write_all(&buf)
}

fn bad_data(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Decode a `STATS2_RESP` body. Every length prefix is validated against
/// its cap before allocation; unknown tags are errors (a v4 client never
/// sees them from a v4 server — silent skipping would hide corruption).
fn read_stats2_body<R: Read>(r: &mut R) -> io::Result<Snapshot> {
    let mut cnt = [0u8; 4];
    r.read_exact(&mut cnt)?;
    let count = u32::from_le_bytes(cnt) as usize;
    if count > MAX_STATS2_ENTRIES {
        return Err(bad_data(format!("STATS2 entry count {count} exceeds cap")));
    }
    let mut snap = Snapshot::default();
    for _ in 0..count {
        let mut kl = [0u8; 2];
        r.read_exact(&mut kl)?;
        let key_len = u16::from_le_bytes(kl) as usize;
        if key_len == 0 || key_len > MAX_STATS2_KEY_LEN {
            return Err(bad_data(format!("STATS2 key length {key_len} out of bounds")));
        }
        let mut key = vec![0u8; key_len];
        r.read_exact(&mut key)?;
        let name = String::from_utf8(key).map_err(|_| bad_data("STATS2 key is not valid UTF-8"))?;
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        let value = match tag[0] {
            TAG_COUNTER => Value::Counter(read_u64(r)?),
            TAG_GAUGE => Value::Gauge(read_u64(r)? as i64),
            TAG_HIST => {
                let mut nb = [0u8; 1];
                r.read_exact(&mut nb)?;
                let n = nb[0] as usize;
                if n > HIST_BUCKETS {
                    return Err(bad_data(format!("STATS2 histogram has {n} buckets")));
                }
                let mut h = HistSnapshot::default();
                for b in h.buckets.iter_mut().take(n) {
                    *b = read_u64(r)?;
                }
                Value::Hist(h)
            }
            other => return Err(bad_data(format!("unknown STATS2 value tag {other}"))),
        };
        snap.push(name, value);
    }
    Ok(snap)
}

/// Decode a `TRACE_RESP` body.
fn read_trace_body<R: Read>(r: &mut R) -> io::Result<Vec<TraceEvent>> {
    let mut cnt = [0u8; 4];
    r.read_exact(&mut cnt)?;
    let count = u32::from_le_bytes(cnt) as usize;
    if count > MAX_TRACE_EVENTS {
        return Err(bad_data(format!("TRACE event count {count} exceeds cap")));
    }
    let mut events = Vec::with_capacity(count.min(4096));
    let mut body = [0u8; TRACE_EVENT_LEN];
    for _ in 0..count {
        r.read_exact(&mut body)?;
        events.push(TraceEvent {
            id: u64::from_le_bytes(body[0..8].try_into().unwrap()),
            op: body[8],
            bits: body[9],
            w: body[10],
            shard: body[11],
            t_admit_ns: u64::from_le_bytes(body[12..20].try_into().unwrap()),
            t_submit_ns: u64::from_le_bytes(body[20..28].try_into().unwrap()),
            t_fold_ns: u64::from_le_bytes(body[28..36].try_into().unwrap()),
            t_emit_ns: u64::from_le_bytes(body[36..44].try_into().unwrap()),
            t_done_ns: u64::from_le_bytes(body[44..52].try_into().unwrap()),
            t_write_ns: u64::from_le_bytes(body[52..60].try_into().unwrap()),
        });
    }
    Ok(events)
}

/// Write an error frame (the server closes the connection after this).
pub fn write_err<W: Write>(w: &mut W, code: u8) -> io::Result<()> {
    w.write_all(&[FRAME_ERR, code])
}

/// A frame as decoded by the server.
#[derive(Debug)]
pub enum ClientFrame {
    /// One `REQ` or the contents of one `BATCH`.
    Requests(Vec<WireRequest>),
    Stats,
    /// Registry snapshot request (wire v4).
    Stats2,
    /// Trace-ring drain request (wire v4).
    Trace,
    /// Clean end of stream (the client closed the connection).
    Eof,
    /// Protocol violation; the payload is the `ERR_*` code to answer with.
    Bad(u8),
}

/// Read one client frame. I/O errors (including truncated frames) surface
/// as `Err`; a clean close before a kind byte is `Ok(Eof)`.
pub fn read_client_frame<R: Read>(r: &mut R) -> io::Result<ClientFrame> {
    let mut kind = [0u8; 1];
    if r.read(&mut kind)? == 0 {
        return Ok(ClientFrame::Eof);
    }
    match kind[0] {
        FRAME_REQ => {
            let mut body = [0u8; REQ_BODY_LEN];
            r.read_exact(&mut body)?;
            match WireRequest::decode_body(&body) {
                Ok(req) => Ok(ClientFrame::Requests(vec![req])),
                Err(_) => Ok(ClientFrame::Bad(ERR_BAD_REQUEST)),
            }
        }
        FRAME_BATCH => {
            let mut cnt = [0u8; 2];
            r.read_exact(&mut cnt)?;
            let count = u16::from_le_bytes(cnt) as usize;
            let mut reqs = Vec::with_capacity(count);
            let mut body = [0u8; REQ_BODY_LEN];
            for _ in 0..count {
                r.read_exact(&mut body)?;
                match WireRequest::decode_body(&body) {
                    Ok(req) => reqs.push(req),
                    Err(_) => return Ok(ClientFrame::Bad(ERR_BAD_REQUEST)),
                }
            }
            if reqs.is_empty() {
                return Ok(ClientFrame::Bad(ERR_BAD_FRAME));
            }
            Ok(ClientFrame::Requests(reqs))
        }
        FRAME_STATS => Ok(ClientFrame::Stats),
        FRAME_STATS2 => Ok(ClientFrame::Stats2),
        FRAME_TRACE => Ok(ClientFrame::Trace),
        _ => Ok(ClientFrame::Bad(ERR_BAD_FRAME)),
    }
}

/// A frame as decoded by the client.
#[derive(Debug)]
pub enum ServerFrame {
    Resp(WireResponse),
    Stats(WireStats),
    /// Registry snapshot (wire v4).
    Stats2(Snapshot),
    /// Sampled lifecycle trace events (wire v4).
    Trace(Vec<TraceEvent>),
    /// Server-reported protocol error code; the connection is closing.
    Err(u8),
}

/// Read one server frame.
pub fn read_server_frame<R: Read>(r: &mut R) -> io::Result<ServerFrame> {
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    match kind[0] {
        FRAME_RESP => {
            let mut body = [0u8; RESP_BODY_LEN];
            r.read_exact(&mut body)?;
            Ok(ServerFrame::Resp(WireResponse {
                id: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                value: u64::from_le_bytes(body[8..16].try_into().unwrap()),
                err: 0,
            }))
        }
        FRAME_RESP_ERR => {
            let mut body = [0u8; RESP_ERR_BODY_LEN];
            r.read_exact(&mut body)?;
            let code = body[8];
            if code == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "RESP_ERR frame with code 0",
                ));
            }
            Ok(ServerFrame::Resp(WireResponse {
                id: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                value: 0,
                err: code,
            }))
        }
        FRAME_STATS_RESP => {
            let mut body = [0u8; WireStats::BODY_LEN];
            r.read_exact(&mut body)?;
            let mut fields = [0u64; 13];
            for (i, f) in fields.iter_mut().enumerate() {
                *f = u64::from_le_bytes(body[i * 8..i * 8 + 8].try_into().unwrap());
            }
            Ok(ServerFrame::Stats(WireStats::from_fields(fields)))
        }
        FRAME_STATS2_RESP => Ok(ServerFrame::Stats2(read_stats2_body(r)?)),
        FRAME_TRACE_RESP => Ok(ServerFrame::Trace(read_trace_body(r)?)),
        FRAME_ERR => {
            let mut code = [0u8; 1];
            r.read_exact(&mut code)?;
            Ok(ServerFrame::Err(code[0]))
        }
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unknown server frame kind 0x{other:02x}"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(id: u64, op: ReqOp, bits: u32, w: u32, a: u64, b: u64) -> WireRequest {
        WireRequest { id, op, bits, w, budget_ppm: 0, a, b }
    }

    #[test]
    fn hello_roundtrip() {
        let mut buf = Vec::new();
        write_hello(&mut buf).unwrap();
        assert_eq!(buf.len(), 8);
        assert_eq!(read_hello(&mut Cursor::new(&buf)).unwrap(), VERSION);
    }

    #[test]
    fn hello_rejects_bad_magic() {
        let mut buf = Vec::new();
        write_hello(&mut buf).unwrap();
        buf[0] = b'X';
        assert!(read_hello(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn request_body_roundtrip() {
        for r in [
            req(0, ReqOp::Mul, 8, 0, 0, 255),
            req(u64::MAX, ReqOp::Div, 32, 8, u32::MAX as u64, 1),
            req(7, ReqOp::Div, 16, 3, 5000, 40),
            WireRequest { budget_ppm: 15_000, ..req(9, ReqOp::Mul, 8, 0, 43, 10) },
            WireRequest { budget_ppm: u32::MAX, ..req(10, ReqOp::Div, 32, 0, 1 << 30, 3) },
        ] {
            let mut body = [0u8; REQ_BODY_LEN];
            r.encode_body(&mut body);
            assert_eq!(WireRequest::decode_body(&body).unwrap(), r);
        }
    }

    #[test]
    fn decode_rejects_bad_fields() {
        let mut body = [0u8; REQ_BODY_LEN];
        req(1, ReqOp::Mul, 8, 8, 43, 10).encode_body(&mut body);
        let mut bad_op = body;
        bad_op[24] = 9;
        assert!(WireRequest::decode_body(&bad_op).is_err());
        let mut bad_bits = body;
        bad_bits[25] = 24;
        assert!(WireRequest::decode_body(&bad_bits).is_err());
        let mut bad_w = body;
        bad_w[26] = (W_MAX + 1) as u8;
        assert!(WireRequest::decode_body(&bad_w).is_err());
        let mut bad_operand = body;
        bad_operand[9] = 1; // a = 43 + 256 exceeds 8 bits
        assert!(WireRequest::decode_body(&bad_operand).is_err());
        let mut bad_flags = body;
        bad_flags[27] = 0x82; // reserved bits
        assert!(WireRequest::decode_body(&bad_flags).is_err());
        // Flag/field mismatches in both directions.
        let mut flag_no_budget = body;
        flag_no_budget[27] = FLAG_BUDGET;
        assert!(WireRequest::decode_body(&flag_no_budget).is_err());
        let mut budget_no_flag = body;
        budget_no_flag[28] = 42;
        assert!(WireRequest::decode_body(&budget_no_flag).is_err());
    }

    #[test]
    fn budget_frame_roundtrip() {
        let r = WireRequest { budget_ppm: 30_000, ..req(77, ReqOp::Div, 16, 0, 5000, 40) };
        let mut buf = Vec::new();
        write_request(&mut buf, &r).unwrap();
        match read_client_frame(&mut Cursor::new(&buf)).unwrap() {
            ClientFrame::Requests(v) => {
                assert_eq!(v, vec![r]);
                assert_eq!(v[0].budget_ppm, 30_000);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn single_request_frame_roundtrip() {
        let r = req(42, ReqOp::Mul, 8, 8, 43, 10);
        let mut buf = Vec::new();
        write_request(&mut buf, &r).unwrap();
        assert_eq!(buf.len(), 1 + REQ_BODY_LEN);
        match read_client_frame(&mut Cursor::new(&buf)).unwrap() {
            ClientFrame::Requests(v) => assert_eq!(v, vec![r]),
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn batch_frame_roundtrip() {
        let reqs: Vec<WireRequest> =
            (0..100).map(|i| req(i, ReqOp::Div, 16, (i % 9) as u32, 5000 + i, 1 + i)).collect();
        let mut buf = Vec::new();
        write_batch(&mut buf, &reqs).unwrap();
        assert_eq!(buf.len(), 3 + reqs.len() * REQ_BODY_LEN);
        match read_client_frame(&mut Cursor::new(&buf)).unwrap() {
            ClientFrame::Requests(v) => assert_eq!(v, reqs),
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn response_and_stats_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 99, 430).unwrap();
        let stats = WireStats {
            requests: 1,
            words: 2,
            active_lanes: 3,
            total_lanes: 4,
            energy_mpj: 5,
            p50_us: 6,
            p99_us: 7,
            conn_requests: 8,
            conn_p50_us: 9,
            conn_p99_us: 10,
            connections: 11,
            shed_overload: 12,
            failed_unavailable: 13,
        };
        write_stats_resp(&mut buf, &stats).unwrap();
        write_err(&mut buf, ERR_BAD_FRAME).unwrap();
        let mut cur = Cursor::new(&buf);
        match read_server_frame(&mut cur).unwrap() {
            ServerFrame::Resp(r) => assert_eq!(r, WireResponse { id: 99, value: 430, err: 0 }),
            other => panic!("unexpected frame {other:?}"),
        }
        match read_server_frame(&mut cur).unwrap() {
            ServerFrame::Stats(s) => assert_eq!(s, stats),
            other => panic!("unexpected frame {other:?}"),
        }
        match read_server_frame(&mut cur).unwrap() {
            ServerFrame::Err(code) => assert_eq!(code, ERR_BAD_FRAME),
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn response_err_frame_roundtrip() {
        let mut buf = Vec::new();
        write_response_err(&mut buf, 7, ERR_OVERLOAD).unwrap();
        write_response_err(&mut buf, u64::MAX, ERR_UNAVAILABLE).unwrap();
        assert_eq!(buf.len(), 2 * (1 + RESP_ERR_BODY_LEN));
        let mut cur = Cursor::new(&buf);
        match read_server_frame(&mut cur).unwrap() {
            ServerFrame::Resp(r) => {
                assert_eq!(r, WireResponse { id: 7, value: 0, err: ERR_OVERLOAD });
                assert!(!r.is_ok());
            }
            other => panic!("unexpected frame {other:?}"),
        }
        match read_server_frame(&mut cur).unwrap() {
            ServerFrame::Resp(r) => {
                assert_eq!(r.id, u64::MAX);
                assert_eq!(r.err, ERR_UNAVAILABLE);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn response_err_with_zero_code_is_rejected() {
        // A RESP_ERR whose code byte is 0 would masquerade as success if
        // decoded permissively; the decoder must reject it instead.
        let mut buf = vec![FRAME_RESP_ERR];
        buf.extend_from_slice(&42u64.to_le_bytes());
        buf.push(0);
        let e = read_server_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn stats2_roundtrip_preserves_every_value_kind() {
        let mut snap = Snapshot::default();
        snap.push("engine.requests", Value::Counter(12_345));
        snap.push("shard.0.queue_depth", Value::Gauge(-3));
        let mut h = HistSnapshot::default();
        h.buckets[10] = 99;
        h.buckets[20] = 1;
        snap.push("stage.execute", Value::Hist(h));
        let mut buf = Vec::new();
        write_stats2_resp(&mut buf, &snap).unwrap();
        match read_server_frame(&mut Cursor::new(&buf)).unwrap() {
            ServerFrame::Stats2(got) => {
                assert_eq!(got, snap);
                assert_eq!(got.counter("engine.requests"), Some(12_345));
                assert_eq!(got.gauge("shard.0.queue_depth"), Some(-3));
                assert_eq!(got.hist("stage.execute").unwrap().count(), 100);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn stats2_and_trace_requests_decode() {
        let mut buf = Vec::new();
        write_stats2_req(&mut buf).unwrap();
        write_trace_req(&mut buf).unwrap();
        let mut cur = Cursor::new(&buf);
        assert!(matches!(read_client_frame(&mut cur).unwrap(), ClientFrame::Stats2));
        assert!(matches!(read_client_frame(&mut cur).unwrap(), ClientFrame::Trace));
    }

    #[test]
    fn trace_roundtrip_is_byte_exact() {
        let events: Vec<TraceEvent> = (0..5)
            .map(|i| TraceEvent {
                id: i,
                op: (i % 2) as u8,
                bits: 16,
                w: 3,
                shard: (i % 4) as u8,
                t_admit_ns: 100 * i,
                t_submit_ns: 100 * i + 10,
                t_fold_ns: 100 * i + 20,
                t_emit_ns: 100 * i + 40,
                t_done_ns: 100 * i + 70,
                t_write_ns: 100 * i + 90,
            })
            .collect();
        let mut buf = Vec::new();
        write_trace_resp(&mut buf, &events).unwrap();
        assert_eq!(buf.len(), 5 + events.len() * TRACE_EVENT_LEN);
        match read_server_frame(&mut Cursor::new(&buf)).unwrap() {
            ServerFrame::Trace(got) => assert_eq!(got, events),
            other => panic!("unexpected frame {other:?}"),
        }
        // An empty ring round-trips too.
        let mut empty = Vec::new();
        write_trace_resp(&mut empty, &[]).unwrap();
        match read_server_frame(&mut Cursor::new(&empty)).unwrap() {
            ServerFrame::Trace(got) => assert!(got.is_empty()),
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn stats2_decoder_rejects_hostile_lengths() {
        // Entry count beyond the cap: rejected before any allocation.
        let mut buf = vec![FRAME_STATS2_RESP];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_server_frame(&mut Cursor::new(&buf)).is_err());
        // Zero-length key.
        let mut buf = vec![FRAME_STATS2_RESP];
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        assert!(read_server_frame(&mut Cursor::new(&buf)).is_err());
        // Unknown value tag.
        let mut buf = vec![FRAME_STATS2_RESP];
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'x');
        buf.push(9); // tag
        assert!(read_server_frame(&mut Cursor::new(&buf)).is_err());
        // Histogram with too many buckets.
        let mut buf = vec![FRAME_STATS2_RESP];
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'x');
        buf.push(2); // TAG_HIST
        buf.push((HIST_BUCKETS + 1) as u8);
        assert!(read_server_frame(&mut Cursor::new(&buf)).is_err());
        // Trace count beyond the cap.
        let mut buf = vec![FRAME_TRACE_RESP];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_server_frame(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn eof_and_bad_kind() {
        let empty: Vec<u8> = Vec::new();
        assert!(matches!(
            read_client_frame(&mut Cursor::new(&empty)).unwrap(),
            ClientFrame::Eof
        ));
        let junk = vec![0x7Fu8];
        assert!(matches!(
            read_client_frame(&mut Cursor::new(&junk)).unwrap(),
            ClientFrame::Bad(ERR_BAD_FRAME)
        ));
    }
}
