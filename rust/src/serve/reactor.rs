//! Poll-based serve reactor (DESIGN.md §15): a fixed pool of event-loop
//! threads multiplexing every connection over non-blocking sockets, so
//! server thread count is bounded by the pool size instead of growing
//! two-threads-per-connection.
//!
//! Dependency-free by construction (no `libc`, no `mio`): the kernel
//! interface is a pair of raw `extern "C"` syscall shims —
//! `epoll(7)` on Linux, with a portable `poll(2)` fallback selectable at
//! runtime ([`ReactorOptions::force_poll_fallback`]) and used by default
//! on non-Linux unix targets. The fallback rebuilds its `pollfd` array on
//! every wait (O(registered fds)), which is exactly the cost epoll
//! amortizes away; both backends expose the same level-triggered
//! [`Event`] surface so the event loop above them is identical.
//!
//! Thread layout per reactor: `L` event loops (each owning a slab of
//! connection state machines, see [`super::conn`]) plus `L` completion
//! pump threads that move engine completions from the per-loop mpsc
//! channel into the loop's completion queue and wake its poller. The
//! pumps are deliberately detached: they exit on their own when the
//! coordinator drops the response routes at server teardown.

use super::conn::{Conn, LoopCtx};
use super::server::{fair_quota, Inner, DRAIN_DEADLINE};
use crate::coordinator::{Request, Response};
use crate::obs::Span;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::raw::{c_int, c_short, c_ulong};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Raw syscall surface. Declarations only — the symbols come from the platform
// C library every Rust program already links.
// ---------------------------------------------------------------------------

extern "C" {
    #[cfg(target_os = "linux")]
    fn epoll_create1(flags: c_int) -> c_int;
    #[cfg(target_os = "linux")]
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    #[cfg(target_os = "linux")]
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
}

#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: c_int = 0x80000;
#[cfg(target_os = "linux")]
const EPOLL_CTL_ADD: c_int = 1;
#[cfg(target_os = "linux")]
const EPOLL_CTL_DEL: c_int = 2;
#[cfg(target_os = "linux")]
const EPOLL_CTL_MOD: c_int = 3;
#[cfg(target_os = "linux")]
const EPOLLIN: u32 = 0x1;
#[cfg(target_os = "linux")]
const EPOLLOUT: u32 = 0x4;
#[cfg(target_os = "linux")]
const EPOLLERR: u32 = 0x8;
#[cfg(target_os = "linux")]
const EPOLLHUP: u32 = 0x10;

const POLLIN: c_short = 0x1;
const POLLOUT: c_short = 0x4;
const POLLERR: c_short = 0x8;
const POLLHUP: c_short = 0x10;
const POLLNVAL: c_short = 0x20;

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: c_int = 7;
#[cfg(not(target_os = "linux"))]
const RLIMIT_NOFILE: c_int = 8;

/// Kernel `struct epoll_event`. On x86 the kernel ABI packs it to 12
/// bytes (`__EPOLL_PACKED` in the C headers); other architectures use
/// natural alignment.
#[cfg(target_os = "linux")]
#[derive(Clone, Copy)]
#[repr(C)]
#[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
struct EpollEvent {
    events: u32,
    data: u64,
}

/// Kernel `struct pollfd`.
#[derive(Clone, Copy)]
#[repr(C)]
struct PollFd {
    fd: c_int,
    events: c_short,
    revents: c_short,
}

/// Kernel `struct rlimit` (64-bit `rlim_t` on every supported target).
#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

/// Check that the process may hold `needed` file descriptors, raising the
/// soft limit toward the hard limit if necessary. Returns the effective
/// soft limit, or a human-actionable error naming `ulimit -n`.
pub fn ensure_fd_capacity(needed: u64) -> Result<u64, String> {
    let mut lim = Rlimit { cur: 0, max: 0 };
    // SAFETY: `lim` is a valid, writable `struct rlimit`-layout value and
    // RLIMIT_NOFILE is a valid resource id; getrlimit writes at most
    // `size_of::<Rlimit>()` bytes into it.
    let rc = unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) };
    if rc != 0 {
        return Err(format!("getrlimit(RLIMIT_NOFILE) failed: {}", io::Error::last_os_error()));
    }
    if lim.cur >= needed {
        return Ok(lim.cur);
    }
    if lim.max >= needed {
        let want = Rlimit { cur: needed, max: lim.max };
        // SAFETY: `want` is a valid `struct rlimit`-layout value that
        // setrlimit only reads; the soft limit stays within the hard limit.
        let rc = unsafe { setrlimit(RLIMIT_NOFILE, &want) };
        if rc == 0 {
            return Ok(needed);
        }
    }
    Err(format!(
        "need {needed} file descriptors but the soft limit is {} (hard limit {}); \
         raise it with `ulimit -n {needed}` or lower the connection count",
        lim.cur, lim.max
    ))
}

// ---------------------------------------------------------------------------
// Poller: one level-triggered readiness surface over both backends.
// ---------------------------------------------------------------------------

/// Readiness interest bits (see [`interest`]).
pub(crate) mod interest {
    pub const READ: u8 = 0b01;
    pub const WRITE: u8 = 0b10;
}

/// Token reserved for the loop's [`Waker`] pipe.
pub(crate) const WAKE_TOKEN: u64 = u64::MAX;

/// One readiness event. Error/hangup conditions are folded into
/// `readable` as well (a read attempt is how the state machine observes
/// the close), with `error` carrying the distinction.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub error: bool,
}

pub(crate) enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Poll(PollPoller),
}

impl Poller {
    /// The platform-preferred backend: epoll on Linux, poll elsewhere.
    pub fn new() -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            Ok(Poller::Epoll(EpollPoller::new()?))
        }
        #[cfg(not(target_os = "linux"))]
        {
            Self::poll_fallback()
        }
    }

    /// The portable `poll(2)` backend, regardless of platform.
    pub fn poll_fallback() -> io::Result<Poller> {
        Ok(Poller::Poll(PollPoller::new()))
    }

    pub fn is_fallback(&self) -> bool {
        matches!(self, Poller::Poll(_))
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_ADD, fd, interest, token),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_MOD, fd, interest, token),
            Poller::Poll(p) => p.modify(fd, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_DEL, fd, 0, 0),
            Poller::Poll(p) => p.deregister(fd),
        }
    }

    /// Wait for readiness, filling `out` (cleared first). Retries `EINTR`
    /// internally.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<usize> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, timeout),
            Poller::Poll(p) => p.wait(out, timeout),
        }
    }
}

fn timeout_ms(timeout: Duration) -> c_int {
    // Round up so a 100µs request does not busy-spin at timeout 0.
    timeout.as_millis().clamp(1, 60_000) as c_int
}

#[cfg(target_os = "linux")]
pub(crate) struct EpollPoller {
    epfd: RawFd,
    buf: Vec<EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    fn new() -> io::Result<EpollPoller> {
        // SAFETY: epoll_create1 takes a flags word and returns a new fd or
        // -1; no memory is passed.
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EpollPoller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
    }

    fn ctl(&mut self, op: c_int, fd: RawFd, interest: u8, token: u64) -> io::Result<()> {
        let mut events = 0u32;
        if interest & interest::READ != 0 {
            events |= EPOLLIN;
        }
        if interest & interest::WRITE != 0 {
            events |= EPOLLOUT;
        }
        let mut ev = EpollEvent { events, data: token };
        // SAFETY: `self.epfd` is a live epoll fd owned by this struct and
        // `ev` is a valid epoll_event the kernel only reads (ignored for
        // EPOLL_CTL_DEL).
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<usize> {
        out.clear();
        let ms = timeout_ms(timeout);
        loop {
            // SAFETY: `self.buf` is a live allocation of `buf.len()`
            // epoll_event slots; the kernel writes at most `maxevents` of
            // them and we only read the first `n`.
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(e);
            }
            for raw in self.buf.iter().take(n as usize) {
                // Field copies, not references: the struct may be packed.
                let bits = raw.events;
                let token = raw.data;
                out.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    error: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            return Ok(n as usize);
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        // SAFETY: `self.epfd` is a live fd owned exclusively by this
        // struct; closing it exactly once on drop cannot race another user.
        unsafe { close(self.epfd) };
    }
}

/// `poll(2)` backend: a flat registry of `(fd, token, interest)` rebuilt
/// into a `pollfd` array on every wait. O(fds) per wait — the portable
/// floor, not the fast path.
pub(crate) struct PollPoller {
    reg: Vec<(RawFd, u64, u8)>,
    fds: Vec<PollFd>,
}

impl PollPoller {
    fn new() -> PollPoller {
        PollPoller { reg: Vec::new(), fds: Vec::new() }
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        if self.reg.iter().any(|&(f, _, _)| f == fd) {
            return Err(io::Error::other("fd already registered"));
        }
        self.reg.push((fd, token, interest));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, interest: u8) -> io::Result<()> {
        match self.reg.iter_mut().find(|(f, _, _)| *f == fd) {
            Some(entry) => {
                entry.2 = interest;
                Ok(())
            }
            None => Err(io::Error::other("fd not registered")),
        }
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self.reg.iter().position(|&(f, _, _)| f == fd) {
            Some(i) => {
                self.reg.swap_remove(i);
                Ok(())
            }
            None => Err(io::Error::other("fd not registered")),
        }
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<usize> {
        out.clear();
        self.fds.clear();
        for &(fd, _, interest) in &self.reg {
            let mut events = 0 as c_short;
            if interest & interest::READ != 0 {
                events |= POLLIN;
            }
            if interest & interest::WRITE != 0 {
                events |= POLLOUT;
            }
            self.fds.push(PollFd { fd, events, revents: 0 });
        }
        let ms = timeout_ms(timeout);
        loop {
            // SAFETY: `self.fds` is a live allocation of `fds.len()` pollfd
            // slots; the kernel reads `events` and writes `revents` in place.
            let n = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as c_ulong, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(e);
            }
            for (pfd, &(_, token, _)) in self.fds.iter().zip(&self.reg) {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: r & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0,
                    writable: r & POLLOUT != 0,
                    error: r & (POLLERR | POLLHUP | POLLNVAL) != 0,
                });
            }
            return Ok(out.len());
        }
    }
}

// ---------------------------------------------------------------------------
// Waker: cross-thread poller wakeup over a socketpair.
// ---------------------------------------------------------------------------

/// Wakes a sleeping event loop from another thread by writing one byte to
/// the loop's wake pipe (a non-blocking `UnixStream` pair). A full pipe
/// means a wakeup is already pending, so `EWOULDBLOCK` is success.
pub(crate) struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

// ---------------------------------------------------------------------------
// Reactor: the event-loop pool.
// ---------------------------------------------------------------------------

/// Reactor tuning. Deliberately *not* part of [`super::ServeConfig`]
/// (whose field set is frozen by exhaustive struct literals in the fault
/// suite): backend choice is a constructor concern, not a serve policy.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReactorOptions {
    /// Event-loop threads. `0` = auto (available parallelism, capped at 4).
    pub loops: usize,
    /// Use the portable `poll(2)` backend even where epoll is available
    /// (exercised by tests; the default picks the platform backend).
    pub force_poll_fallback: bool,
}

/// How often each loop sweeps for idle/stalled connections.
const SWEEP_EVERY: Duration = Duration::from_millis(500);
/// Poll timeout while any connection has unadmitted backlog: bounds the
/// admission retry and overload-shed latency.
const ADMIT_TICK: Duration = Duration::from_millis(5);
/// Completion-pump batch cap per channel drain.
const PUMP_BATCH: usize = 4096;
/// Slab capacity per loop (tokens carry a 16-bit slot index).
const MAX_CONNS_PER_LOOP: usize = 65_536;

fn effective_loops(requested: usize) -> usize {
    if requested > 0 {
        return requested.min(64);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 4)
}

/// State shared between a loop thread and the outside world (accept
/// thread, completion pump, shutdown).
pub(crate) struct LoopShared {
    /// Connections handed over by the accept thread.
    incoming: Mutex<Vec<TcpStream>>,
    /// Engine completions staged by this loop's pump thread.
    completions: Mutex<Vec<Response>>,
    waker: Waker,
    /// Connections currently owned by this loop (dispatch balance key).
    conns: AtomicUsize,
}

pub(crate) struct Reactor {
    loops: Vec<Arc<LoopShared>>,
    handles: Vec<JoinHandle<()>>,
}

/// The accept thread's handle into the pool: routes a fresh connection to
/// the least-loaded loop and wakes it.
pub(crate) struct Dispatcher {
    loops: Vec<Arc<LoopShared>>,
}

impl Dispatcher {
    pub fn dispatch(&self, inner: &Inner, stream: TcpStream) {
        let target = self
            .loops
            .iter()
            .min_by_key(|l| l.conns.load(Ordering::Relaxed))
            .expect("reactor has at least one loop");
        // Counted at dispatch (not at hello) so `connections` tracks every
        // socket the server holds; every loop-side drop path decrements.
        let open = inner.connections.fetch_add(1, Ordering::Relaxed) + 1;
        inner.peak_connections.fetch_max(open, Ordering::Relaxed);
        target.conns.fetch_add(1, Ordering::Relaxed);
        target.incoming.lock().unwrap().push(stream);
        target.waker.wake();
    }
}

impl Reactor {
    pub fn start(inner: &Arc<Inner>, opts: ReactorOptions) -> io::Result<Reactor> {
        let n = effective_loops(opts.loops);
        let mut loops = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let poller =
                if opts.force_poll_fallback { Poller::poll_fallback()? } else { Poller::new()? };
            let (wake_tx, wake_rx) = UnixStream::pair()?;
            wake_tx.set_nonblocking(true)?;
            wake_rx.set_nonblocking(true)?;
            let shared = Arc::new(LoopShared {
                incoming: Mutex::new(Vec::new()),
                completions: Mutex::new(Vec::new()),
                waker: Waker { tx: wake_tx },
                conns: AtomicUsize::new(0),
            });
            let (resp_tx, resp_rx) = std::sync::mpsc::channel::<(u32, Response)>();
            {
                // Detached on purpose: the pump blocks in `recv` and exits
                // when the coordinator's response routes (the only senders)
                // drop at teardown — after `Coordinator::shutdown` has
                // consumed the coordinator, which is too late to join from.
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("serve-pump-{i}"))
                    .spawn(move || pump_loop(resp_rx, shared))?;
            }
            let handle = {
                let inner = Arc::clone(inner);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("serve-loop-{i}"))
                    .spawn(move || event_loop(inner, shared, wake_rx, poller, resp_tx))?
            };
            loops.push(shared);
            handles.push(handle);
        }
        Ok(Reactor { loops, handles })
    }

    pub fn event_loops(&self) -> usize {
        self.loops.len()
    }

    pub fn dispatcher(&self) -> Dispatcher {
        Dispatcher { loops: self.loops.clone() }
    }

    pub fn wake_all(&self) {
        for l in &self.loops {
            l.waker.wake();
        }
    }

    /// Join the loop threads (they self-drain once `Inner::stop` is set).
    pub fn join(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Completion pump: block for one engine completion, drain greedily, stage
/// the batch for the owning loop and wake it.
fn pump_loop(rx: Receiver<(u32, Response)>, shared: Arc<LoopShared>) {
    while let Ok((_, first)) = rx.recv() {
        let mut batch = vec![first];
        while batch.len() < PUMP_BATCH {
            match rx.try_recv() {
                Ok((_, resp)) => batch.push(resp),
                Err(_) => break,
            }
        }
        shared.completions.lock().unwrap().extend(batch);
        shared.waker.wake();
    }
}

// ---------------------------------------------------------------------------
// Event loop: slab of connection state machines + round structure.
// ---------------------------------------------------------------------------

struct SlabSlot {
    gen: u16,
    conn: Option<Conn>,
}

/// Generation-tagged connection slab. Tokens are `(gen << 16) | index`;
/// a completion for a closed-and-reused slot fails the generation check
/// and is dropped instead of reaching the wrong connection.
#[derive(Default)]
struct Slab {
    slots: Vec<SlabSlot>,
    free: Vec<u16>,
    live: usize,
}

fn token_of(gen: u16, idx: usize) -> u32 {
    ((gen as u32) << 16) | idx as u32
}

impl Slab {
    fn insert(&mut self, conn: Conn) -> Option<u32> {
        let idx = match self.free.pop() {
            Some(i) => i as usize,
            None => {
                if self.slots.len() >= MAX_CONNS_PER_LOOP {
                    return None;
                }
                self.slots.push(SlabSlot { gen: 0, conn: None });
                self.slots.len() - 1
            }
        };
        self.slots[idx].conn = Some(conn);
        self.live += 1;
        Some(token_of(self.slots[idx].gen, idx))
    }

    fn get_mut(&mut self, token: u32) -> Option<&mut Conn> {
        let idx = (token & 0xFFFF) as usize;
        let gen = (token >> 16) as u16;
        let slot = self.slots.get_mut(idx)?;
        if slot.gen != gen {
            return None;
        }
        slot.conn.as_mut()
    }

    fn remove(&mut self, token: u32) -> Option<Conn> {
        let idx = (token & 0xFFFF) as usize;
        let gen = (token >> 16) as u16;
        let slot = self.slots.get_mut(idx)?;
        if slot.gen != gen {
            return None;
        }
        let conn = slot.conn.take()?;
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(idx as u16);
        self.live -= 1;
        Some(conn)
    }

    fn tokens(&self) -> Vec<u32> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.conn.is_some())
            .map(|(i, s)| token_of(s.gen, i))
            .collect()
    }
}

/// Decrement both open-connection counters for one dropped connection.
fn conn_closed(inner: &Inner, shared: &LoopShared) {
    inner.connections.fetch_sub(1, Ordering::Relaxed);
    shared.conns.fetch_sub(1, Ordering::Relaxed);
}

fn event_loop(
    inner: Arc<Inner>,
    shared: Arc<LoopShared>,
    mut wake_rx: UnixStream,
    mut poller: Poller,
    resp_tx: Sender<(u32, Response)>,
) {
    if poller.register(wake_rx.as_raw_fd(), WAKE_TOKEN, interest::READ).is_err() {
        // Without a waker the loop cannot be driven; nothing has been
        // accepted onto it yet, so exiting is safe.
        return;
    }
    let mut slab = Slab::default();
    let mut events: Vec<Event> = Vec::new();
    let mut submit: Vec<(Request, Span)> = Vec::new();
    // Tokens needing a service pass this round (deduplicated via the
    // per-conn `queued_service` flag).
    let mut service: Vec<u32> = Vec::new();
    // Tokens with unadmitted backlog, re-serviced every ADMIT_TICK.
    let mut backlog: Vec<u32> = Vec::new();
    let mut next_sweep = Instant::now() + SWEEP_EVERY;
    let mut drain_deadline: Option<Instant> = None;

    loop {
        let stopping = inner.stop.load(Ordering::SeqCst);
        if stopping && drain_deadline.is_none() {
            drain_deadline = Some(Instant::now() + DRAIN_DEADLINE);
        }
        let timeout = if stopping {
            Duration::from_millis(10)
        } else if !backlog.is_empty() {
            ADMIT_TICK
        } else {
            next_sweep
                .saturating_duration_since(Instant::now())
                .clamp(Duration::from_millis(1), SWEEP_EVERY)
        };
        let _ = poller.wait(&mut events, timeout);

        // Round-constant admission policy: every connection gets an equal
        // share of the configured window, floored at one slot so a
        // saturated sibling can never starve a low-rate tenant entirely.
        let quota =
            fair_quota(inner.cfg.window, inner.connections.load(Ordering::Relaxed) as usize);
        let deadline =
            (inner.cfg.deadline_ms > 0).then(|| Duration::from_millis(inner.cfg.deadline_ms));

        // 1. Socket readiness.
        for ev in events.iter().copied() {
            if ev.token == WAKE_TOKEN {
                drain_wake(&mut wake_rx);
                continue;
            }
            let tok = ev.token as u32;
            let mut ctx = LoopCtx { inner: &inner, submit: &mut submit, resp_tx: &resp_tx };
            if let Some(conn) = slab.get_mut(tok) {
                conn.pump(ev.readable || ev.error, ev.writable, &mut ctx, quota, deadline);
                if !conn.queued_service {
                    conn.queued_service = true;
                    service.push(tok);
                }
            }
        }

        // 2. Adopt dispatched connections.
        let fresh: Vec<TcpStream> = std::mem::take(&mut *shared.incoming.lock().unwrap());
        for stream in fresh {
            if stopping {
                conn_closed(&inner, &shared);
                continue;
            }
            let conn = match Conn::new(stream, inner.cfg.window) {
                Ok(c) => c,
                Err(_) => {
                    conn_closed(&inner, &shared);
                    continue;
                }
            };
            match slab.insert(conn) {
                Some(tok) => {
                    let conn = slab.get_mut(tok).expect("freshly inserted conn");
                    conn.set_token(tok);
                    let fd = conn.fd();
                    let want = conn.desired_interest();
                    if poller.register(fd, tok as u64, want).is_err() {
                        slab.remove(tok);
                        conn_closed(&inner, &shared);
                        continue;
                    }
                    let conn = slab.get_mut(tok).expect("conn still present");
                    conn.registered = want;
                    conn.queued_service = true;
                    service.push(tok);
                }
                None => conn_closed(&inner, &shared), // slab full: shed the socket
            }
        }

        // 3. Engine completions staged by the pump.
        let comps: Vec<Response> = std::mem::take(&mut *shared.completions.lock().unwrap());
        for resp in comps {
            let tok = (resp.id >> 32) as u32;
            if let Some(conn) = slab.get_mut(tok) {
                conn.on_completion(resp, &inner);
                if !conn.queued_service {
                    conn.queued_service = true;
                    service.push(tok);
                }
            }
            // Stale token (connection already closed): completion dropped.
        }

        // 4. Backlog tick: re-service everyone with unadmitted requests so
        // admission retries and overload shedding stay on the 5ms clock.
        for tok in backlog.drain(..) {
            if let Some(conn) = slab.get_mut(tok) {
                conn.in_backlog = false;
                if !conn.queued_service {
                    conn.queued_service = true;
                    service.push(tok);
                }
            }
        }

        // 5. Idle sweep schedule: visit every connection on the slow tick.
        let now = Instant::now();
        let sweep_due = now >= next_sweep;
        if sweep_due {
            next_sweep = now + SWEEP_EVERY;
            for tok in slab.tokens() {
                if let Some(conn) = slab.get_mut(tok) {
                    if !conn.queued_service {
                        conn.queued_service = true;
                        service.push(tok);
                    }
                }
            }
        }

        // 6. Service pass: admission, shedding, write flush, then interest
        // reconciliation and close bookkeeping.
        let io_timeout =
            (inner.cfg.io_timeout_ms > 0).then(|| Duration::from_millis(inner.cfg.io_timeout_ms));
        for tok in std::mem::take(&mut service) {
            {
                let mut ctx = LoopCtx { inner: &inner, submit: &mut submit, resp_tx: &resp_tx };
                let Some(conn) = slab.get_mut(tok) else { continue };
                conn.queued_service = false;
                if stopping {
                    conn.begin_shutdown();
                }
                conn.pump(false, false, &mut ctx, quota, deadline);
            }
            let Some(conn) = slab.get_mut(tok) else { continue };
            let idle = match io_timeout {
                Some(t) if sweep_due => conn.idle_expired(now, t),
                _ => false,
            };
            if conn.should_close() || idle {
                let fd = conn.fd();
                let _ = poller.deregister(fd);
                drop(slab.remove(tok));
                conn_closed(&inner, &shared);
                continue;
            }
            let want = conn.desired_interest();
            if want != conn.registered {
                conn.registered = want;
                let fd = conn.fd();
                let _ = poller.modify(fd, tok as u64, want);
            }
            if conn.has_backlog() && !conn.in_backlog {
                conn.in_backlog = true;
                backlog.push(tok);
            }
        }

        // 7. One streaming submission per round: admissions from every
        // connection share the coordinator batch. Blocks only when the
        // shard queues are full — which *is* the backpressure path.
        {
            let mut ctx = LoopCtx { inner: &inner, submit: &mut submit, resp_tx: &resp_tx };
            ctx.flush_submit();
        }

        if stopping {
            if slab.live == 0 {
                break;
            }
            if drain_deadline.is_some_and(|d| Instant::now() >= d) {
                // Drain deadline expired: force-close the stragglers.
                for tok in slab.tokens() {
                    if let Some(conn) = slab.get_mut(tok) {
                        let fd = conn.fd();
                        let _ = poller.deregister(fd);
                    }
                    drop(slab.remove(tok));
                    conn_closed(&inner, &shared);
                }
                break;
            }
        }
    }
}

fn drain_wake(rx: &mut UnixStream) {
    let mut buf = [0u8; 256];
    loop {
        match rx.read(&mut buf) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break, // WouldBlock: fully drained
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wakeable_pair() -> (UnixStream, UnixStream) {
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        (a, b)
    }

    fn poller_sees_readability(mut poller: Poller) {
        let (tx, rx) = wakeable_pair();
        poller.register(rx.as_raw_fd(), 7, interest::READ).unwrap();
        let mut out = Vec::new();

        // Nothing written yet: a short wait returns no events.
        poller.wait(&mut out, Duration::from_millis(5)).unwrap();
        assert!(out.iter().all(|e| e.token != 7), "spurious readiness: {out:?}");

        (&tx).write_all(&[1u8]).unwrap();
        poller.wait(&mut out, Duration::from_millis(1000)).unwrap();
        let ev = out.iter().find(|e| e.token == 7).expect("readable event");
        assert!(ev.readable);

        // Interest can be narrowed to write-only and the fd deregistered.
        poller.modify(rx.as_raw_fd(), 7, interest::WRITE).unwrap();
        poller.wait(&mut out, Duration::from_millis(100)).unwrap();
        let ev = out.iter().find(|e| e.token == 7).expect("writable event");
        assert!(ev.writable);
        poller.deregister(rx.as_raw_fd()).unwrap();
        poller.wait(&mut out, Duration::from_millis(5)).unwrap();
        assert!(out.iter().all(|e| e.token != 7), "event after deregister: {out:?}");
    }

    #[test]
    fn poll_fallback_backend_reports_readiness() {
        let poller = Poller::poll_fallback().unwrap();
        assert!(poller.is_fallback());
        poller_sees_readability(poller);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_reports_readiness() {
        let poller = Poller::new().unwrap();
        assert!(!poller.is_fallback(), "Linux default must be epoll");
        poller_sees_readability(poller);
    }

    #[test]
    fn fair_quota_splits_window_beyond_sixteen_conns() {
        // Up to 16 connections every tenant keeps the full window (the
        // pre-reactor per-connection semantics).
        assert_eq!(fair_quota(1024, 0), 1024);
        assert_eq!(fair_quota(1024, 1), 1024);
        assert_eq!(fair_quota(1024, 16), 1024);
        // Beyond that the window is shared fairly, floored at one slot.
        assert_eq!(fair_quota(1024, 64), 256);
        assert_eq!(fair_quota(1024, 16_384), 1);
        assert_eq!(fair_quota(1024, 1_000_000), 1);
        // Tiny windows still admit.
        assert_eq!(fair_quota(1, 10_000), 1);
        assert_eq!(fair_quota(0, 3), 1);
    }

    #[test]
    fn fd_capacity_check_names_ulimit_in_errors() {
        // The current limit always covers a trivial ask.
        assert!(ensure_fd_capacity(8).is_ok());
        // An impossible ask fails with actionable advice.
        let err = ensure_fd_capacity(u64::MAX - 1).unwrap_err();
        assert!(err.contains("ulimit -n"), "unhelpful fd error: {err}");
    }
}
