//! SIMD-wire TCP server over coordinator v2 (DESIGN.md §8–§9).
//!
//! Thread layout: one accept thread; per connection, the spawned
//! connection thread becomes the *reader* and starts one *writer* thread.
//! The reader decodes frames, admits requests under a bounded in-flight
//! window (admission control: when the window is full the reader stops
//! draining the socket, so backpressure propagates over TCP instead of
//! buffering unboundedly), and funnels them into **one shared
//! coordinator** via [`Coordinator::submit_batch_streaming`] — requests
//! carry their accuracy knob `w` per request, and the coordinator's own
//! mixed-`{bits, w}` word assembler keeps different-`w` requests out of
//! each other's words (their correction tables differ — §3.3) while the
//! whole accuracy spectrum shares one worker pool. The writer drains
//! completions and writes response frames **out of order, as SIMD lanes
//! complete**, freeing window slots and recording latency as it goes.
//!
//! Requests flagged with an error budget instead of a fixed `w` are
//! resolved at admission through the error-budget router
//! ([`ErrorProfile::pick_w`]): the cheapest `w` whose profiled MRED fits
//! the stated budget.
//!
//! Fault tolerance (DESIGN.md §11): admission carries a deadline — a
//! request that cannot get a window slot within `deadline_ms` is shed
//! per-request with `ERR_OVERLOAD` (the connection stays open); sockets
//! carry read/write timeouts so a stalled peer errors out instead of
//! wedging its threads; and a request that shard supervision gave up on
//! fails per-request with `ERR_UNAVAILABLE`. With `cfg.faults` set, the
//! deterministic chaos injector drops accepted connections and is
//! threaded into the shard pool (injected panics / slow shards / delayed
//! completions).

use super::stats::ServeCounters;
use super::wire::{self, ClientFrame, WireStats};
use crate::coordinator::{
    Coordinator, CoordinatorConfig, ErrorProfile, Request, Response, Stats,
};
use crate::faults::{FaultConfig, FaultInjector, SITE_NAMES};
use crate::obs::{
    self, Counter, Hist, Registry, Snapshot, Span, Tiers, TraceEvent, TraceRing, Value,
};
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Fixed seed of the server's trace-sampling ring: the 1-in-N sampling
/// decision is a pure function of `(seed, arrival index)`, so a given
/// arrival order traces the same requests run-to-run.
const TRACE_SEED: u64 = 0x51D1_7E0B_5EED;

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker shards of the shared coordinator's execution pool
    /// (`engine::Sharded` — DESIGN.md §10).
    pub workers: usize,
    /// Coordinator packing-batch size.
    pub batch: usize,
    /// Coordinator bounded-queue depth.
    pub queue_depth: usize,
    /// Per-connection admission window: maximum in-flight requests before
    /// the reader stops draining the socket.
    pub window: usize,
    /// Admission deadline (ms): how long a request may wait for a window
    /// slot before it is shed with `ERR_OVERLOAD` instead of blocking the
    /// connection forever. `0` = wait indefinitely (the pre-deadline
    /// behavior).
    pub deadline_ms: u64,
    /// Per-connection socket read/write timeout (ms). A peer that stalls
    /// mid-frame — or a socket whose send buffer a dead peer never drains —
    /// errors out instead of wedging the reader/writer thread. `0` =
    /// disabled.
    pub io_timeout_ms: u64,
    /// Chaos-harness fault plan. `None` (the default) injects nothing and
    /// adds nothing to the hot path beyond an `Option` check.
    pub faults: Option<FaultConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            batch: 64,
            queue_depth: 1024,
            window: 1024,
            deadline_ms: 2_000,
            io_timeout_ms: 10_000,
            faults: None,
        }
    }
}

/// Shared server state.
struct Inner {
    cfg: ServeConfig,
    stop: AtomicBool,
    /// The one shared coordinator serving every `{bits, w}` mix
    /// (coordinator v2 — DESIGN.md §9).
    coordinator: Coordinator,
    /// Server-wide completed requests + latency.
    global: ServeCounters,
    connections: AtomicU64,
    /// Requests shed with `ERR_OVERLOAD` (admission deadline expired).
    shed: AtomicU64,
    /// Requests failed with `ERR_UNAVAILABLE` (shard supervision gave up).
    unavailable: AtomicU64,
    /// Chaos-harness injector shared with the coordinator's shard pool;
    /// `None` in production.
    injector: Option<Arc<FaultInjector>>,
    /// The metrics registry behind `STATS2` (DESIGN.md §12). The shard
    /// pool records its stage/tier/shard metrics into it directly.
    registry: Arc<Registry>,
    /// Seeded-sampled bounded ring of completed request traces.
    ring: Arc<TraceRing>,
    /// Serve-side stage histograms (`admit` = admission→shard-submit,
    /// `write` = response-routed→socket-write); the engine records the
    /// `queue`/`assemble`/`execute` stages.
    stage_admit: Arc<Hist>,
    stage_write: Arc<Hist>,
    /// Budget-routing decision counters.
    route_budget: Arc<Counter>,
    route_fixed: Arc<Counter>,
    /// `route.budget_w{w}`: which knob the budget router resolved to.
    route_budget_w: Vec<Arc<Counter>>,
    /// Per-`{op, bits, w}` tier counters — the same handles the shard
    /// pool increments (get-or-create registration shares them).
    tiers: Tiers,
}

impl Inner {
    fn coordinator_stats(&self) -> Stats {
        self.coordinator.stats()
    }

    /// Build the `STATS_RESP` payload for one connection's view.
    fn snapshot(&self, conn: &ServeCounters) -> WireStats {
        let cs = self.coordinator_stats();
        WireStats {
            requests: self.global.requests(),
            words: cs.words,
            active_lanes: cs.active_lanes,
            total_lanes: cs.total_lanes,
            energy_mpj: (cs.energy_pj * 1000.0).round() as u64,
            p50_us: self.global.hist.percentile_us(0.50),
            p99_us: self.global.hist.percentile_us(0.99),
            conn_requests: conn.requests(),
            conn_p50_us: conn.hist.percentile_us(0.50),
            conn_p99_us: conn.hist.percentile_us(0.99),
            connections: self.connections.load(Ordering::Relaxed),
            shed_overload: self.shed.load(Ordering::Relaxed),
            failed_unavailable: self.unavailable.load(Ordering::Relaxed),
        }
    }

    /// Build the `STATS2` payload: the full registry snapshot plus the
    /// serve-level counters that live outside the registry (legacy
    /// atomics kept for `STATS` bit-compatibility), fault-injection
    /// observation counters, and the delivered-MRED estimate.
    fn snapshot2(&self) -> Snapshot {
        let mut snap = self.registry.snapshot();
        snap.push("conn.open", Value::Gauge(self.connections.load(Ordering::Relaxed) as i64));
        snap.push("serve.requests", Value::Counter(self.global.requests()));
        snap.push("serve.shed_overload", Value::Counter(self.shed.load(Ordering::Relaxed)));
        snap.push(
            "serve.failed_unavailable",
            Value::Counter(self.unavailable.load(Ordering::Relaxed)),
        );
        if let Some(inj) = &self.injector {
            for (name, n) in SITE_NAMES.iter().zip(inj.fired_counts()) {
                snap.push(format!("faults.{name}"), Value::Counter(n));
            }
        }
        // Delivered-MRED estimate: the tier-count-weighted mean of the
        // profiled MRED of every tier actually served. Only computed when
        // some budget-routed request already forced the profile — a stats
        // read must never pay the multi-second profile computation itself.
        if let Some(profile) = ErrorProfile::try_get() {
            let (mut total, mut weighted) = (0u64, 0u128);
            for (op, bits, w, n) in self.tiers.nonzero() {
                total += n;
                weighted += n as u128 * profile.mred_ppm(op, bits, w) as u128;
            }
            if total > 0 {
                snap.push("delivered.mred_ppm", Value::Gauge((weighted / total as u128) as i64));
            }
        }
        snap.entries.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }
}

/// The serving front end. Dropping (or [`Server::shutdown`]) stops the
/// accept loop; established connections drain on their own threads.
pub struct Server {
    addr: SocketAddr,
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections.
    pub fn start<A: ToSocketAddrs>(listen: A, cfg: ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let injector = cfg.faults.filter(|f| f.is_active()).map(FaultInjector::new);
        let registry = Registry::new();
        let inner = Arc::new(Inner {
            cfg,
            stop: AtomicBool::new(false),
            coordinator: Coordinator::start_observed(
                CoordinatorConfig {
                    workers: cfg.workers,
                    queue_depth: cfg.queue_depth,
                    batch: cfg.batch,
                },
                injector.clone(),
                &registry,
            ),
            global: ServeCounters::new(),
            connections: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            unavailable: AtomicU64::new(0),
            injector,
            ring: TraceRing::with_seed(TRACE_SEED),
            stage_admit: registry.hist("stage.admit"),
            stage_write: registry.hist("stage.write"),
            route_budget: registry.counter("route.budget_requests"),
            route_fixed: registry.counter("route.fixed_requests"),
            route_budget_w: (0..=crate::arith::W_MAX)
                .map(|w| registry.counter(&format!("route.budget_w{w}")))
                .collect(),
            tiers: Tiers::register(&registry),
            registry,
        });
        let accept = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || accept_loop(listener, inner))
        };
        Ok(Server { addr, inner, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Server-wide stats snapshot (connection-local fields are zero).
    pub fn stats(&self) -> WireStats {
        self.inner.snapshot(&ServeCounters::new())
    }

    /// The `STATS2` registry snapshot (what a v4 client receives).
    pub fn stats2(&self) -> Snapshot {
        self.inner.snapshot2()
    }

    /// The retained sampled trace events, oldest first.
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        self.inner.ring.events()
    }

    /// Currently open connections.
    pub fn connections(&self) -> u64 {
        self.inner.connections.load(Ordering::Relaxed)
    }

    /// Stop accepting new connections and join the accept thread.
    pub fn shutdown(mut self) {
        self.stop_accept();
    }

    fn stop_accept(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_accept();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<Inner>) {
    for conn in listener.incoming() {
        if inner.stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                // Chaos harness: drop a freshly accepted connection before
                // the hello (the client sees an immediate reset/EOF and
                // must reconnect).
                if inner.injector.as_ref().is_some_and(|i| i.accept_drop()) {
                    drop(stream);
                    continue;
                }
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, inner);
                });
            }
            Err(_) => continue, // transient accept error
        }
    }
}

/// Per-connection in-flight window: a fixed slot table guarded by a
/// mutex + condvar. `acquire` is the admission-control point — it blocks
/// the reader when every slot is taken, which stops socket draining and
/// pushes backpressure to the client over TCP.
struct Inflight {
    slots: Mutex<SlotTable>,
    freed: Condvar,
}

struct SlotTable {
    free: Vec<u32>,
    /// `entries[slot]` = (wire id, admission time) of the occupying request.
    entries: Vec<(u64, Instant)>,
}

impl Inflight {
    fn new(window: usize) -> Self {
        let window = window.max(1);
        Inflight {
            slots: Mutex::new(SlotTable {
                free: (0..window as u32).rev().collect(),
                entries: vec![(0, Instant::now()); window],
            }),
            freed: Condvar::new(),
        }
    }

    /// Take a slot if one is free (never blocks).
    fn try_acquire(&self, wire_id: u64) -> Option<u32> {
        let mut t = self.slots.lock().unwrap();
        let slot = t.free.pop()?;
        t.entries[slot as usize] = (wire_id, Instant::now());
        Some(slot)
    }

    /// Block until a slot frees, then take it.
    fn acquire(&self, wire_id: u64) -> u32 {
        self.acquire_deadline(wire_id, None).expect("unbounded acquire cannot time out")
    }

    /// Block until a slot frees or `deadline` elapses. `None` deadline =
    /// wait indefinitely (always returns `Some`). A `None` return is the
    /// shedding signal: the request waited its whole admission budget and
    /// never got a slot.
    fn acquire_deadline(&self, wire_id: u64, deadline: Option<Duration>) -> Option<u32> {
        let start = Instant::now();
        let mut t = self.slots.lock().unwrap();
        loop {
            if let Some(slot) = t.free.pop() {
                t.entries[slot as usize] = (wire_id, Instant::now());
                return Some(slot);
            }
            match deadline {
                None => t = self.freed.wait(t).unwrap(),
                Some(d) => {
                    let left = d.checked_sub(start.elapsed())?;
                    let (guard, timeout) = self.freed.wait_timeout(t, left).unwrap();
                    t = guard;
                    if timeout.timed_out() && t.free.is_empty() {
                        return None;
                    }
                }
            }
        }
    }

    /// Free a slot; returns the wire id and the admission→now latency.
    fn release(&self, slot: u32) -> (u64, u64) {
        let mut t = self.slots.lock().unwrap();
        let (id, t0) = t.entries[slot as usize];
        t.free.push(slot);
        drop(t);
        self.freed.notify_one();
        (id, t0.elapsed().as_nanos() as u64)
    }
}

/// Shared buffered write half. The writer thread owns the response
/// stream; the reader grabs the lock only for the rare `STATS_RESP`/`ERR`
/// frames, so frames never interleave mid-frame.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

fn handle_conn(stream: TcpStream, inner: Arc<Inner>) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    // Socket timeouts: a peer that stalls mid-frame (or never drains its
    // receive buffer) errors this connection out instead of wedging its
    // reader/writer threads forever.
    if inner.cfg.io_timeout_ms > 0 {
        let t = Some(Duration::from_millis(inner.cfg.io_timeout_ms));
        stream.set_read_timeout(t)?;
        stream.set_write_timeout(t)?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream)));

    // Hello exchange. The server always answers with its *own* hello (so
    // a cross-version client can read the server's version and report it),
    // then closes a mismatched connection with ERR_BAD_VERSION.
    let peer_version = wire::read_hello(&mut reader)?;
    {
        let mut w = writer.lock().unwrap();
        wire::write_hello(&mut *w)?;
        if peer_version != wire::VERSION {
            wire::write_err(&mut *w, wire::ERR_BAD_VERSION)?;
            w.flush()?;
            return Ok(());
        }
        w.flush()?;
    }

    inner.connections.fetch_add(1, Ordering::Relaxed);
    let conn_stats = Arc::new(ServeCounters::new());
    let inflight = Arc::new(Inflight::new(inner.cfg.window));
    // Set once the reader has queued an `ERR` frame: the protocol promises
    // `ERR` is the last frame, so the writer stops emitting `RESP`s.
    let closed = Arc::new(AtomicBool::new(false));
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<(u32, Response)>();

    let writer_handle = {
        let writer = Arc::clone(&writer);
        let inflight = Arc::clone(&inflight);
        let conn_stats = Arc::clone(&conn_stats);
        let inner = Arc::clone(&inner);
        let closed = Arc::clone(&closed);
        std::thread::spawn(move || {
            writer_loop(writer, resp_rx, inflight, conn_stats, inner, closed)
        })
    };

    let result =
        reader_loop(&mut reader, &writer, &inner, &inflight, &conn_stats, &resp_tx, &closed);

    // Dropping our sender lets the writer exit once every in-flight
    // response (whose routes hold clones) has been delivered.
    drop(resp_tx);
    let _ = writer_handle.join();
    inner.connections.fetch_sub(1, Ordering::Relaxed);
    result
}

/// Resolve a wire request's effective accuracy knob: the stated `w`, or —
/// with an error budget on the wire — the cheapest `w` whose profiled
/// MRED fits the budget (DESIGN.md §9). Counts the routing decision.
fn resolve_w(inner: &Inner, r: &wire::WireRequest) -> u32 {
    if r.budget_ppm > 0 {
        let w = ErrorProfile::get().pick_w(r.op, r.bits, r.budget_ppm);
        inner.route_budget.inc();
        if let Some(c) = inner.route_budget_w.get(w as usize) {
            c.inc();
        }
        w
    } else {
        inner.route_fixed.inc();
        r.w
    }
}

fn reader_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &SharedWriter,
    inner: &Arc<Inner>,
    inflight: &Arc<Inflight>,
    conn_stats: &Arc<ServeCounters>,
    resp_tx: &Sender<(u32, Response)>,
    closed: &Arc<AtomicBool>,
) -> io::Result<()> {
    // Admitted requests buffered for one streaming submission; the shared
    // coordinator's assembler does the per-{bits, w} sub-queueing.
    let mut pending: Vec<(Request, Span)> = Vec::new();
    loop {
        match wire::read_client_frame(reader)? {
            ClientFrame::Eof => return Ok(()),
            ClientFrame::Bad(code) => {
                // `ERR` must be the last frame on the wire: mark the
                // connection closed *before* taking the lock, so once the
                // writer's current drain (which holds the lock) finishes,
                // it emits no further `RESP` frames.
                closed.store(true, Ordering::SeqCst);
                let mut w = writer.lock().unwrap();
                wire::write_err(&mut *w, code)?;
                w.flush()?;
                return Ok(());
            }
            ClientFrame::Stats => {
                // Submit buffered work first so the snapshot reflects it.
                submit_pending(inner, &mut pending, resp_tx);
                let snap = inner.snapshot(conn_stats);
                let mut w = writer.lock().unwrap();
                wire::write_stats_resp(&mut *w, &snap)?;
                w.flush()?;
            }
            ClientFrame::Stats2 => {
                submit_pending(inner, &mut pending, resp_tx);
                let snap = inner.snapshot2();
                let mut w = writer.lock().unwrap();
                wire::write_stats2_resp(&mut *w, &snap)?;
                w.flush()?;
            }
            ClientFrame::Trace => {
                let events = inner.ring.events();
                let mut w = writer.lock().unwrap();
                wire::write_trace_resp(&mut *w, &events)?;
                w.flush()?;
            }
            ClientFrame::Requests(reqs) => {
                let deadline =
                    (inner.cfg.deadline_ms > 0).then(|| Duration::from_millis(inner.cfg.deadline_ms));
                for r in &reqs {
                    // Admission control: take a window slot, submitting
                    // buffered work before blocking so slots can free.
                    let slot = match inflight.try_acquire(r.id) {
                        Some(s) => s,
                        None => {
                            submit_pending(inner, &mut pending, resp_tx);
                            match inflight.acquire_deadline(r.id, deadline) {
                                Some(s) => s,
                                None => {
                                    // Admission deadline expired: shed this
                                    // request per-request (`RESP_ERR`, the
                                    // connection stays open) rather than
                                    // stalling every request behind it.
                                    inner.shed.fetch_add(1, Ordering::Relaxed);
                                    let mut w = writer.lock().unwrap();
                                    wire::write_response_err(&mut *w, r.id, wire::ERR_OVERLOAD)?;
                                    w.flush()?;
                                    continue;
                                }
                            }
                        }
                    };
                    // The coordinator-side id is the window slot; the wire
                    // id is recovered from the slot table on completion.
                    let w = resolve_w(inner, r);
                    let op_byte = match r.op {
                        crate::coordinator::ReqOp::Mul => 0u8,
                        crate::coordinator::ReqOp::Div => 1u8,
                    };
                    let span = Span::admitted(inner.ring.sample(), op_byte, r.bits as u8, w as u8);
                    pending.push((
                        Request { id: slot as u64, op: r.op, bits: r.bits, w, a: r.a, b: r.b },
                        span,
                    ));
                    if pending.len() >= inner.cfg.batch {
                        submit_pending(inner, &mut pending, resp_tx);
                    }
                }
                submit_pending(inner, &mut pending, resp_tx);
            }
        }
    }
}

/// Stream the buffered admissions into the shared coordinator.
fn submit_pending(
    inner: &Arc<Inner>,
    pending: &mut Vec<(Request, Span)>,
    resp_tx: &Sender<(u32, Response)>,
) {
    if !pending.is_empty() {
        inner.coordinator.submit_batch_streaming_spanned(std::mem::take(pending), 0, resp_tx);
    }
}

/// Writer thread: drain completions, free window slots, record latency,
/// and write `RESP` frames out-of-order as lanes complete. Write failures
/// (client went away) switch to drain-only mode so slots keep freeing and
/// the reader can run to its own error/EOF.
fn writer_loop(
    writer: SharedWriter,
    rx: Receiver<(u32, Response)>,
    inflight: Arc<Inflight>,
    conn_stats: Arc<ServeCounters>,
    inner: Arc<Inner>,
    closed: Arc<AtomicBool>,
) {
    let mut dead = false;
    loop {
        // Block for one completion, then drain greedily before flushing.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut w = writer.lock().unwrap();
        let mut msg = Some(first);
        while let Some((_, resp)) = msg.take() {
            let (wire_id, latency_ns) = inflight.release(resp.id as u32);
            conn_stats.record(latency_ns);
            inner.global.record(latency_ns);
            // Serve-side stage stamps: `admit` covers admission→shard
            // submission, `write` covers response-routed→socket-write.
            // Sampled spans become full trace events at this point — the
            // request's last stop in the pipeline.
            let span = resp.span;
            if span.t_admit_ns > 0 {
                let t_write = obs::now_ns();
                inner.stage_admit.record_ns(span.t_submit_ns.saturating_sub(span.t_admit_ns));
                inner.stage_write.record_ns(t_write.saturating_sub(span.t_done_ns));
                if span.sampled {
                    inner.ring.push(TraceEvent::from_span(wire_id, &span, t_write));
                }
            }
            dead = dead || closed.load(Ordering::SeqCst);
            if resp.err != 0 {
                // Shard supervision gave this request up (double fault):
                // fail it per-request; the connection survives.
                inner.unavailable.fetch_add(1, Ordering::Relaxed);
                if !dead && wire::write_response_err(&mut *w, wire_id, wire::ERR_UNAVAILABLE).is_err()
                {
                    dead = true;
                }
            } else if !dead && wire::write_response(&mut *w, wire_id, resp.value).is_err() {
                dead = true;
            }
            if let Ok(m) = rx.try_recv() {
                msg = Some(m);
            }
        }
        if !dead && w.flush().is_err() {
            dead = true;
        }
    }
    if !dead {
        let _ = writer.lock().unwrap().flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inflight_window_blocks_and_frees() {
        let inflight = Arc::new(Inflight::new(2));
        let s0 = inflight.acquire(10);
        let s1 = inflight.acquire(11);
        assert_ne!(s0, s1);
        assert!(inflight.try_acquire(12).is_none(), "window must be full");
        let inflight2 = Arc::clone(&inflight);
        let t = std::thread::spawn(move || inflight2.acquire(12));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let (id, _lat) = inflight.release(s0);
        assert_eq!(id, 10);
        let s2 = t.join().unwrap();
        assert_eq!(s2, s0, "freed slot is reused");
        inflight.release(s1);
        inflight.release(s2);
        assert!(inflight.try_acquire(13).is_some());
    }

    #[test]
    fn server_binds_ephemeral_port_and_shuts_down() {
        let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0);
        assert_eq!(server.connections(), 0);
        server.shutdown();
    }
}
