//! SIMD-wire TCP server over coordinator v2 (DESIGN.md §8–§9, §15).
//!
//! Two backends share everything above the socket layer:
//!
//! * **Reactor** (the default, [`Server::start`] /
//!   [`Server::start_reactor`]): a fixed pool of event-loop threads
//!   multiplexing non-blocking sockets through a poll/epoll shim
//!   ([`super::reactor`]), with per-connection state machines
//!   ([`super::conn`]) and *fair admission* — each connection's in-flight
//!   quota is an equal share of the configured window, floored at one
//!   slot, so a saturating tenant cannot starve a low-rate one. Thread
//!   count is bounded by the pool size, not the connection count.
//! * **Threaded** ([`Server::start_threaded`]): the original
//!   reader/writer thread pair per connection ([`super::threaded`]),
//!   retained as the A/B baseline for the connection-count sweep.
//!
//! Both funnel admitted requests into **one shared coordinator** via
//! [`Coordinator::submit_batch_streaming_spanned`] — requests carry their
//! accuracy knob `w` per request, and the coordinator's mixed-`{bits, w}`
//! word assembler keeps different-`w` requests out of each other's words
//! (their correction tables differ — §3.3) while the whole accuracy
//! spectrum shares one worker pool. Responses flow back out of order, as
//! SIMD lanes complete.
//!
//! Requests flagged with an error budget instead of a fixed `w` are
//! resolved at admission through the error-budget router
//! ([`ErrorProfile::pick_w`]): the cheapest `w` whose profiled MRED fits
//! the stated budget.
//!
//! Fault tolerance (DESIGN.md §11): admission carries a deadline — a
//! request that cannot get a window slot within `deadline_ms` is shed
//! per-request with `ERR_OVERLOAD` (the connection stays open); stalled
//! peers are timed out (socket timeouts on the threaded backend, the idle
//! sweep on the reactor); and a request that shard supervision gave up on
//! fails per-request with `ERR_UNAVAILABLE`. With `cfg.faults` set, the
//! deterministic chaos injector drops accepted connections and is
//! threaded into the shard pool (injected panics / slow shards / delayed
//! completions).
//!
//! Shutdown ([`Server::shutdown`] or drop) stops the accept loop, wakes
//! every live connection, and drains them with a bounded deadline
//! ([`DRAIN_DEADLINE`]) — `simdive serve` exits promptly under Ctrl-C
//! instead of leaving connection threads parked in blocking reads.

use super::reactor::{self, ReactorOptions};
use super::stats::ServeCounters;
use super::threaded;
use super::wire::{self, WireStats};
use crate::coordinator::{Coordinator, CoordinatorConfig, ErrorProfile, Stats};
use crate::faults::{FaultConfig, FaultInjector, SITE_NAMES};
use crate::obs::{Counter, Hist, Registry, Snapshot, Tiers, TraceEvent, TraceRing, Value};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Fixed seed of the server's trace-sampling ring: the 1-in-N sampling
/// decision is a pure function of `(seed, arrival index)`, so a given
/// arrival order traces the same requests run-to-run.
const TRACE_SEED: u64 = 0x51D1_7E0B_5EED;

/// How long shutdown waits for live connections to drain before
/// force-closing the stragglers.
pub(crate) const DRAIN_DEADLINE: Duration = Duration::from_secs(3);

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker shards of the shared coordinator's execution pool
    /// (`engine::Sharded` — DESIGN.md §10).
    pub workers: usize,
    /// Coordinator packing-batch size.
    pub batch: usize,
    /// Coordinator bounded-queue depth.
    pub queue_depth: usize,
    /// Admission window. On the threaded backend this is per connection:
    /// maximum in-flight requests before the reader stops draining the
    /// socket. On the reactor it is the *shared* budget that fair
    /// admission splits into per-connection quotas (full window up to 16
    /// connections, an equal share — floored at one slot — beyond that).
    pub window: usize,
    /// Admission deadline (ms): how long a request may wait for a window
    /// slot before it is shed with `ERR_OVERLOAD` instead of blocking the
    /// connection forever. `0` = wait indefinitely (the pre-deadline
    /// behavior).
    pub deadline_ms: u64,
    /// Per-connection socket read/write timeout (ms). A peer that stalls
    /// mid-frame — or a socket whose send buffer a dead peer never drains —
    /// errors out instead of wedging its connection. `0` = disabled.
    pub io_timeout_ms: u64,
    /// Chaos-harness fault plan. `None` (the default) injects nothing and
    /// adds nothing to the hot path beyond an `Option` check.
    pub faults: Option<FaultConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            batch: 64,
            queue_depth: 1024,
            window: 1024,
            deadline_ms: 2_000,
            io_timeout_ms: 10_000,
            faults: None,
        }
    }
}

/// Fair admission quota (DESIGN.md §15): each connection's share of the
/// window. Up to 16 connections every tenant keeps the full window (the
/// historical per-connection semantics); beyond that the window is split
/// equally, floored at one slot so every connection always makes
/// progress.
pub(crate) fn fair_quota(window: usize, active_conns: usize) -> usize {
    let window = window.max(1);
    (window * 16 / active_conns.max(1)).clamp(1, window)
}

/// Shared server state (both backends).
pub(crate) struct Inner {
    pub(crate) cfg: ServeConfig,
    pub(crate) stop: AtomicBool,
    /// The one shared coordinator serving every `{bits, w}` mix
    /// (coordinator v2 — DESIGN.md §9).
    pub(crate) coordinator: Coordinator,
    /// Server-wide completed requests + latency.
    pub(crate) global: ServeCounters,
    pub(crate) connections: AtomicU64,
    /// High-water mark of `connections` (thread-count accounting for the
    /// threaded backend).
    pub(crate) peak_connections: AtomicU64,
    /// Requests shed with `ERR_OVERLOAD` (admission deadline expired).
    pub(crate) shed: AtomicU64,
    /// Requests failed with `ERR_UNAVAILABLE` (shard supervision gave up).
    pub(crate) unavailable: AtomicU64,
    /// Chaos-harness injector shared with the coordinator's shard pool;
    /// `None` in production.
    pub(crate) injector: Option<Arc<FaultInjector>>,
    /// The metrics registry behind `STATS2` (DESIGN.md §12). The shard
    /// pool records its stage/tier/shard metrics into it directly.
    pub(crate) registry: Arc<Registry>,
    /// Seeded-sampled bounded ring of completed request traces.
    pub(crate) ring: Arc<TraceRing>,
    /// Serve-side stage histograms (`admit` = admission→shard-submit,
    /// `write` = response-routed→socket-write); the engine records the
    /// `queue`/`assemble`/`execute` stages.
    pub(crate) stage_admit: Arc<Hist>,
    pub(crate) stage_write: Arc<Hist>,
    /// Budget-routing decision counters.
    pub(crate) route_budget: Arc<Counter>,
    pub(crate) route_fixed: Arc<Counter>,
    /// `route.budget_w{w}`: which knob the budget router resolved to.
    pub(crate) route_budget_w: Vec<Arc<Counter>>,
    /// Per-`{op, bits, w}` tier counters — the same handles the shard
    /// pool increments (get-or-create registration shares them).
    pub(crate) tiers: Tiers,
}

impl Inner {
    fn coordinator_stats(&self) -> Stats {
        self.coordinator.stats()
    }

    /// Build the `STATS_RESP` payload for one connection's view.
    pub(crate) fn snapshot(&self, conn: &ServeCounters) -> WireStats {
        let cs = self.coordinator_stats();
        WireStats {
            requests: self.global.requests(),
            words: cs.words,
            active_lanes: cs.active_lanes,
            total_lanes: cs.total_lanes,
            energy_mpj: (cs.energy_pj * 1000.0).round() as u64,
            p50_us: self.global.hist.percentile_us(0.50),
            p99_us: self.global.hist.percentile_us(0.99),
            conn_requests: conn.requests(),
            conn_p50_us: conn.hist.percentile_us(0.50),
            conn_p99_us: conn.hist.percentile_us(0.99),
            connections: self.connections.load(Ordering::Relaxed),
            shed_overload: self.shed.load(Ordering::Relaxed),
            failed_unavailable: self.unavailable.load(Ordering::Relaxed),
        }
    }

    /// Build the `STATS2` payload: the full registry snapshot plus the
    /// serve-level counters that live outside the registry (legacy
    /// atomics kept for `STATS` bit-compatibility), fault-injection
    /// observation counters, and the delivered-MRED estimate.
    pub(crate) fn snapshot2(&self) -> Snapshot {
        let mut snap = self.registry.snapshot();
        snap.push("conn.open", Value::Gauge(self.connections.load(Ordering::Relaxed) as i64));
        snap.push("serve.requests", Value::Counter(self.global.requests()));
        snap.push("serve.shed_overload", Value::Counter(self.shed.load(Ordering::Relaxed)));
        snap.push(
            "serve.failed_unavailable",
            Value::Counter(self.unavailable.load(Ordering::Relaxed)),
        );
        if let Some(inj) = &self.injector {
            for (name, n) in SITE_NAMES.iter().zip(inj.fired_counts()) {
                snap.push(format!("faults.{name}"), Value::Counter(n));
            }
        }
        // Delivered-MRED estimate: the tier-count-weighted mean of the
        // profiled MRED of every tier actually served. Only computed when
        // some budget-routed request already forced the profile — a stats
        // read must never pay the multi-second profile computation itself.
        if let Some(profile) = ErrorProfile::try_get() {
            let (mut total, mut weighted) = (0u64, 0u128);
            for (op, bits, w, n) in self.tiers.nonzero() {
                total += n;
                weighted += n as u128 * profile.mred_ppm(op, bits, w) as u128;
            }
            if total > 0 {
                snap.push("delivered.mred_ppm", Value::Gauge((weighted / total as u128) as i64));
            }
        }
        snap.entries.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }
}

/// Which backend owns the established connections.
enum Backend {
    Reactor(reactor::Reactor),
    Threaded(Arc<threaded::ConnRegistry>),
}

/// Where the accept loop hands fresh connections.
enum AcceptSink {
    Reactor(reactor::Dispatcher),
    Threaded(Arc<threaded::ConnRegistry>),
}

/// The serving front end. [`Server::shutdown`] (or drop) stops the accept
/// loop and drains live connections with a bounded deadline.
pub struct Server {
    addr: SocketAddr,
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
    backend: Backend,
}

impl Server {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections on the default backend (the reactor).
    pub fn start<A: ToSocketAddrs>(listen: A, cfg: ServeConfig) -> io::Result<Server> {
        Self::start_reactor(listen, cfg, ReactorOptions::default())
    }

    /// Start on the poll-based reactor backend with explicit tuning.
    pub fn start_reactor<A: ToSocketAddrs>(
        listen: A,
        cfg: ServeConfig,
        opts: ReactorOptions,
    ) -> io::Result<Server> {
        let (listener, addr, inner) = Self::bind(listen, cfg)?;
        let pool = reactor::Reactor::start(&inner, opts)?;
        let sink = AcceptSink::Reactor(pool.dispatcher());
        let accept = Self::spawn_accept(listener, &inner, sink)?;
        Ok(Server { addr, inner, accept: Some(accept), backend: Backend::Reactor(pool) })
    }

    /// Start on the legacy thread-per-connection backend.
    pub fn start_threaded<A: ToSocketAddrs>(listen: A, cfg: ServeConfig) -> io::Result<Server> {
        let (listener, addr, inner) = Self::bind(listen, cfg)?;
        let registry = threaded::ConnRegistry::new();
        let sink = AcceptSink::Threaded(Arc::clone(&registry));
        let accept = Self::spawn_accept(listener, &inner, sink)?;
        Ok(Server { addr, inner, accept: Some(accept), backend: Backend::Threaded(registry) })
    }

    fn bind<A: ToSocketAddrs>(
        listen: A,
        cfg: ServeConfig,
    ) -> io::Result<(TcpListener, SocketAddr, Arc<Inner>)> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let injector = cfg.faults.filter(|f| f.is_active()).map(FaultInjector::new);
        let registry = Registry::new();
        let inner = Arc::new(Inner {
            cfg,
            stop: AtomicBool::new(false),
            coordinator: Coordinator::start_observed(
                CoordinatorConfig {
                    workers: cfg.workers,
                    queue_depth: cfg.queue_depth,
                    batch: cfg.batch,
                },
                injector.clone(),
                &registry,
            ),
            global: ServeCounters::new(),
            connections: AtomicU64::new(0),
            peak_connections: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            unavailable: AtomicU64::new(0),
            injector,
            ring: TraceRing::with_seed(TRACE_SEED),
            stage_admit: registry.hist("stage.admit"),
            stage_write: registry.hist("stage.write"),
            route_budget: registry.counter("route.budget_requests"),
            route_fixed: registry.counter("route.fixed_requests"),
            route_budget_w: (0..=crate::arith::W_MAX)
                .map(|w| registry.counter(&format!("route.budget_w{w}")))
                .collect(),
            tiers: Tiers::register(&registry),
            registry,
        });
        Ok((listener, addr, inner))
    }

    fn spawn_accept(
        listener: TcpListener,
        inner: &Arc<Inner>,
        sink: AcceptSink,
    ) -> io::Result<JoinHandle<()>> {
        let inner = Arc::clone(inner);
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, inner, sink))
    }

    /// The bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Server-wide stats snapshot (connection-local fields are zero).
    pub fn stats(&self) -> WireStats {
        self.inner.snapshot(&ServeCounters::new())
    }

    /// The `STATS2` registry snapshot (what a v4 client receives).
    pub fn stats2(&self) -> Snapshot {
        self.inner.snapshot2()
    }

    /// The retained sampled trace events, oldest first.
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        self.inner.ring.events()
    }

    /// Currently open connections.
    pub fn connections(&self) -> u64 {
        self.inner.connections.load(Ordering::Relaxed)
    }

    /// Serving-side thread count implied by the current backend: accept +
    /// event loops + completion pumps for the reactor (a constant), accept
    /// + a reader/writer pair per *peak* connection for the threaded
    /// backend (O(connections) — the number the reactor exists to bound).
    /// Coordinator shard workers are excluded: both backends share them.
    pub fn thread_count(&self) -> usize {
        match &self.backend {
            Backend::Reactor(pool) => 1 + 2 * pool.event_loops(),
            Backend::Threaded(_) => {
                1 + 2 * self.inner.peak_connections.load(Ordering::Relaxed) as usize
            }
        }
    }

    /// Stop accepting, wake every live connection, and drain them with a
    /// bounded deadline ([`DRAIN_DEADLINE`]).
    pub fn shutdown(mut self) {
        self.stop_all();
    }

    /// Idempotent teardown (also runs on drop, including after
    /// `shutdown` consumed the value).
    fn stop_all(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        match &mut self.backend {
            Backend::Reactor(pool) => {
                // Loops observe `stop`, switch their connections to drain
                // mode, and exit once empty or at the drain deadline.
                pool.wake_all();
                pool.join();
            }
            Backend::Threaded(registry) => registry.drain(),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_all();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<Inner>, sink: AcceptSink) {
    for conn in listener.incoming() {
        if inner.stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                // Chaos harness: drop a freshly accepted connection before
                // the hello (the client sees an immediate reset/EOF and
                // must reconnect).
                if inner.injector.as_ref().is_some_and(|i| i.accept_drop()) {
                    drop(stream);
                    continue;
                }
                match &sink {
                    AcceptSink::Reactor(dispatcher) => dispatcher.dispatch(&inner, stream),
                    AcceptSink::Threaded(registry) => {
                        threaded::spawn_conn(stream, Arc::clone(&inner), Arc::clone(registry))
                    }
                }
            }
            Err(_) => continue, // transient accept error
        }
    }
}

/// Resolve a wire request's effective accuracy knob: the stated `w`, or —
/// with an error budget on the wire — the cheapest `w` whose profiled
/// MRED fits the budget (DESIGN.md §9). Counts the routing decision.
pub(crate) fn resolve_w(inner: &Inner, r: &wire::WireRequest) -> u32 {
    if r.budget_ppm > 0 {
        let w = ErrorProfile::get().pick_w(r.op, r.bits, r.budget_ppm);
        inner.route_budget.inc();
        if let Some(c) = inner.route_budget_w.get(w as usize) {
            c.inc();
        }
        w
    } else {
        inner.route_fixed.inc();
        r.w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_binds_ephemeral_port_and_shuts_down() {
        let server = Server::start("127.0.0.1:0", ServeConfig::default()).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0);
        assert_eq!(server.connections(), 0);
        server.shutdown();
    }

    #[test]
    fn threaded_backend_binds_and_shuts_down() {
        let server = Server::start_threaded("127.0.0.1:0", ServeConfig::default()).unwrap();
        assert_ne!(server.local_addr().port(), 0);
        assert_eq!(server.thread_count(), 1, "no connections yet: accept thread only");
        server.shutdown();
    }

    #[test]
    fn reactor_thread_count_is_constant() {
        let server = Server::start_reactor(
            "127.0.0.1:0",
            ServeConfig::default(),
            ReactorOptions { loops: 2, force_poll_fallback: false },
        )
        .unwrap();
        assert_eq!(server.thread_count(), 1 + 2 * 2);
        server.shutdown();
    }
}
