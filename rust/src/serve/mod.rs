//! Network serving subsystem: the SIMD-wire protocol, a TCP server over
//! the coordinator, a pipelined client library, and a load generator
//! (DESIGN.md §8).
//!
//! The paper's headline claims are throughput and energy under SIMD
//! packing with *tunable* accuracy; this layer gives those claims a
//! network boundary to be measured across. Everything is dependency-free
//! (`std::net` + threads — tokio is unavailable offline, DESIGN.md §1):
//!
//! * [`wire`] — versioned little-endian binary protocol; fixed-size
//!   request frames carry `{id, op, bits, w, budget_ppm, a, b}` so the
//!   per-operand accuracy knob `w` (§3.3) — or, since wire v2, a maximum
//!   relative-error budget routed server-side — travels on the wire per
//!   request, plus batch framing and a `STATS` op.
//! * [`server`] — TCP listener over two backends sharing one admission,
//!   routing and observability core: the default poll-based *reactor*
//!   (DESIGN.md §15) — a fixed pool of event-loop threads multiplexing
//!   non-blocking sockets with per-connection fair-admission quotas —
//!   and the legacy thread-per-connection backend, kept as the sweep
//!   baseline. Both feed one shared mixed-`{bits, w}` coordinator with
//!   an error-budget router at admission (DESIGN.md §9) and write
//!   responses out of order as SIMD lanes complete.
//! * [`reactor`] — the dependency-free epoll/`poll(2)` shim, event-loop
//!   pool, and the fd-capacity helper ([`ensure_fd_capacity`]).
//! * [`client`] — pipelined client used by the examples, tests and load
//!   generator; reconnect backoff carries seeded jitter so synchronized
//!   reconnect storms decorrelate.
//! * [`stats`] — per-connection and server-wide counters with log2
//!   latency histograms, exposed via the `STATS` wire op.
//!   Since wire v4 the server also carries a full metrics registry and a
//!   sampled trace ring ([`crate::obs`], DESIGN.md §12), exported over
//!   the `STATS2`/`TRACE` ops behind `simdive stats` / `simdive trace`.
//! * [`loadgen`] — multi-connection load generator writing
//!   `BENCH_serve.json` (schema `simdive-serve-v1`), including the
//!   reactor-vs-threaded `connections_sweep` (`loadgen --sweep`).
//! * [`chaos`] — the fault-injection load scenario (`loadgen --chaos`,
//!   DESIGN.md §11): verified traffic plus a saboteur connection, with
//!   no-hang / no-wrong-answer / no-leak invariant checks.

pub mod chaos;
pub mod client;
mod conn;
pub mod loadgen;
pub mod reactor;
pub mod server;
pub mod stats;
mod threaded;
pub mod wire;

pub use client::Client;
pub use reactor::{ensure_fd_capacity, ReactorOptions};
pub use server::{ServeConfig, Server};
pub use wire::{WireRequest, WireResponse, WireStats};
