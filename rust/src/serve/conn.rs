//! Connection state machine for the poll-based reactor (DESIGN.md §15).
//!
//! One [`Conn`] owns everything the old reader/writer thread pair held,
//! reshaped for non-blocking sockets: an incremental frame decoder over a
//! read buffer, a FIFO of decoded-but-unadmitted requests, a
//! per-connection in-flight slot table (the admission window), and a
//! write buffer that response frames append to and the event loop flushes
//! opportunistically. All methods run on the owning event-loop thread —
//! nothing here is shared or locked.
//!
//! Life cycle: `Handshake` (buffer 8 bytes, answer the hello, reject a
//! version mismatch) → `Open` (decode frames, admit under the fair
//! quota, shed the head of the queue when its admission deadline lapses)
//! → close, when the peer is done (`eof`), the protocol closed the
//! connection with a final `ERR` frame (`closed`), or the socket died
//! (`dead`), and every admitted request has drained back out.

use super::reactor::interest;
use super::server::{resolve_w, Inner};
use super::stats::ServeCounters;
use super::wire::{self, ClientFrame};
use crate::coordinator::{ReqOp, Request, Response};
use crate::obs::{self, Span, TraceEvent};
use std::collections::VecDeque;
use std::io::{self, Cursor, Read, Write};
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

/// Stop reading a connection whose write buffer the peer is not draining:
/// past this backlog, backpressure moves to the socket.
const MAX_WBUF_BACKLOG: usize = 1 << 20;
/// Stop reading when this much undecoded input is buffered (a complete
/// maximal BATCH frame is ~2 MiB; this bounds a peer that streams faster
/// than it can be admitted).
const MAX_RBUF_BUFFERED: usize = 4 << 20;

/// Byte length of the frame starting at `buf[0]`, or `None` if not even
/// the length-determining prefix has arrived yet. Unknown kinds report 1:
/// [`wire::read_client_frame`] answers `Bad` from the kind byte alone.
pub(crate) fn frame_len(buf: &[u8]) -> Option<usize> {
    let kind = *buf.first()?;
    match kind {
        wire::FRAME_REQ => Some(1 + wire::REQ_BODY_LEN),
        wire::FRAME_BATCH => {
            if buf.len() < 3 {
                return None;
            }
            let count = u16::from_le_bytes([buf[1], buf[2]]) as usize;
            Some(3 + count * wire::REQ_BODY_LEN)
        }
        _ => Some(1),
    }
}

/// Per-event-loop submission context: the shared server state plus the
/// loop's streaming-submission buffer and its completion route.
pub(crate) struct LoopCtx<'a> {
    pub inner: &'a Inner,
    pub submit: &'a mut Vec<(Request, Span)>,
    pub resp_tx: &'a Sender<(u32, Response)>,
}

impl LoopCtx<'_> {
    /// Stream the buffered admissions into the shared coordinator. Blocks
    /// only when the shard queues are full — the engine-side backpressure
    /// path, same as the threaded backend.
    pub fn flush_submit(&mut self) {
        if !self.submit.is_empty() {
            self.inner.coordinator.submit_batch_streaming_spanned(
                std::mem::take(self.submit),
                0,
                self.resp_tx,
            );
        }
    }
}

enum State {
    Handshake,
    Open,
}

pub(crate) struct Conn {
    stream: TcpStream,
    state: State,
    rbuf: Vec<u8>,
    rpos: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    /// Decoded requests not yet admitted to the in-flight window.
    pending: VecDeque<wire::WireRequest>,
    /// When the current head of `pending` started waiting for admission;
    /// the overload-shedding clock (reset whenever the head changes).
    head_since: Option<Instant>,
    /// `slots[s]` = `(wire id, admission time)` of the in-flight request
    /// whose engine id carries slot `s`.
    slots: Vec<Option<(u64, Instant)>>,
    free: Vec<u32>,
    in_flight: usize,
    pub(crate) stats: ServeCounters,
    /// `(slab token) << 32`, OR-ed with the slot to form engine ids.
    id_base: u64,
    /// No more reads: peer EOF, protocol close, or server shutdown.
    pub(crate) eof: bool,
    /// An `ERR` frame was queued — the protocol promises it is the last
    /// frame, so response writes are suppressed from here on.
    pub(crate) closed: bool,
    /// Hard socket error: drop without flushing.
    pub(crate) dead: bool,
    /// Event-loop bookkeeping flags (owned by the loop, stored here so a
    /// token is never queued twice in one round).
    pub(crate) in_backlog: bool,
    pub(crate) queued_service: bool,
    /// Interest bits currently registered with the poller.
    pub(crate) registered: u8,
    last_read: Instant,
    last_write_progress: Instant,
}

impl Conn {
    pub(crate) fn new(stream: TcpStream, window: usize) -> io::Result<Conn> {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true)?;
        let window = window.max(1);
        let now = Instant::now();
        Ok(Conn {
            stream,
            state: State::Handshake,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            head_since: None,
            slots: vec![None; window],
            free: (0..window as u32).rev().collect(),
            in_flight: 0,
            stats: ServeCounters::new(),
            id_base: 0,
            eof: false,
            closed: false,
            dead: false,
            in_backlog: false,
            queued_service: false,
            registered: 0,
            last_read: now,
            last_write_progress: now,
        })
    }

    pub(crate) fn set_token(&mut self, token: u32) {
        self.id_base = (token as u64) << 32;
    }

    pub(crate) fn fd(&self) -> RawFd {
        self.stream.as_raw_fd()
    }

    /// One full turn of the state machine: read what the socket has,
    /// decode complete frames, admit under `quota`, shed an expired head,
    /// and flush the write buffer.
    pub(crate) fn pump(
        &mut self,
        readable: bool,
        writable: bool,
        ctx: &mut LoopCtx<'_>,
        quota: usize,
        deadline: Option<Duration>,
    ) {
        if self.dead {
            return;
        }
        if readable && !self.eof && !self.read_paused() {
            self.fill_rbuf();
        }
        self.parse_frames(ctx, quota);
        if !self.dead {
            self.try_admit(ctx, quota);
            self.shed_expired(ctx, deadline);
        }
        if writable || self.wpos < self.wbuf.len() {
            self.flush_wbuf();
        }
        self.compact_rbuf();
    }

    /// Route one engine completion back onto the wire (out of order, as
    /// lanes complete). Frees the window slot, records latency and the
    /// serve-side stage stamps, and queues the response frame — unless
    /// the connection already closed, in which case the slot still frees
    /// but nothing is written.
    pub(crate) fn on_completion(&mut self, resp: Response, inner: &Inner) {
        let slot = (resp.id & 0xFFFF_FFFF) as usize;
        let Some(entry) = self.slots.get_mut(slot) else { return };
        let Some((wire_id, t0)) = entry.take() else { return };
        self.free.push(slot as u32);
        self.in_flight -= 1;
        let latency_ns = t0.elapsed().as_nanos() as u64;
        self.stats.record(latency_ns);
        inner.global.record(latency_ns);
        // Serve-side stage stamps, mirrored from the threaded writer:
        // `admit` covers admission→shard submission, `write` covers
        // response-routed→write-queued. Sampled spans become trace events
        // here — the request's last stop in the pipeline.
        let span = resp.span;
        if span.t_admit_ns > 0 {
            let t_write = obs::now_ns();
            inner.stage_admit.record_ns(span.t_submit_ns.saturating_sub(span.t_admit_ns));
            inner.stage_write.record_ns(t_write.saturating_sub(span.t_done_ns));
            if span.sampled {
                inner.ring.push(TraceEvent::from_span(wire_id, &span, t_write));
            }
        }
        if resp.err != 0 {
            inner.unavailable.fetch_add(1, Ordering::Relaxed);
            if !self.closed && !self.dead {
                let _ = wire::write_response_err(&mut self.wbuf, wire_id, wire::ERR_UNAVAILABLE);
            }
        } else if !self.closed && !self.dead {
            let _ = wire::write_response(&mut self.wbuf, wire_id, resp.value);
        }
    }

    /// Server shutdown: stop reading and drop unadmitted requests so the
    /// connection converges to close once in-flight work drains.
    pub(crate) fn begin_shutdown(&mut self) {
        self.eof = true;
        self.pending.clear();
        self.head_since = None;
    }

    pub(crate) fn should_close(&self) -> bool {
        if self.dead {
            return true;
        }
        (self.eof || self.closed)
            && self.pending.is_empty()
            && self.in_flight == 0
            && self.wpos >= self.wbuf.len()
    }

    /// The non-blocking analogue of the threaded backend's socket
    /// timeouts, checked on the slow sweep: a peer that neither talks nor
    /// drains its responses for `timeout` gets closed. A connection that
    /// is merely waiting on the engine (requests pending or in flight) is
    /// never idle.
    pub(crate) fn idle_expired(&self, now: Instant, timeout: Duration) -> bool {
        if self.wpos < self.wbuf.len() && now.duration_since(self.last_write_progress) > timeout {
            return true;
        }
        !self.eof
            && self.pending.is_empty()
            && self.in_flight == 0
            && now.duration_since(self.last_read) > timeout
    }

    /// Reads pause while unadmitted backlog exists or the peer is not
    /// draining its responses: backpressure propagates over TCP instead
    /// of buffering unboundedly (same policy as the threaded reader
    /// blocking on admission).
    pub(crate) fn read_paused(&self) -> bool {
        !self.pending.is_empty() || self.wbuf.len() - self.wpos > MAX_WBUF_BACKLOG
    }

    pub(crate) fn has_backlog(&self) -> bool {
        !self.pending.is_empty()
    }

    pub(crate) fn desired_interest(&self) -> u8 {
        let mut want = 0u8;
        if !self.eof && !self.read_paused() {
            want |= interest::READ;
        }
        if self.wpos < self.wbuf.len() {
            want |= interest::WRITE;
        }
        want
    }

    fn fill_rbuf(&mut self) {
        let mut tmp = [0u8; 16 * 1024];
        loop {
            if self.rbuf.len() - self.rpos >= MAX_RBUF_BUFFERED {
                return;
            }
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.eof = true;
                    return;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    self.last_read = Instant::now();
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    fn parse_frames(&mut self, ctx: &mut LoopCtx<'_>, quota: usize) {
        loop {
            if self.dead {
                return;
            }
            match self.state {
                State::Handshake => {
                    if self.rbuf.len() - self.rpos < 8 {
                        if self.eof {
                            // Peer went away mid-hello: nothing to answer.
                            self.dead = true;
                        }
                        return;
                    }
                    let hello = {
                        let avail = &self.rbuf[self.rpos..self.rpos + 8];
                        wire::read_hello(&mut Cursor::new(avail))
                    };
                    self.rpos += 8;
                    match hello {
                        // Bad magic: close without a reply (mirrors the
                        // threaded backend, where the failed hello read
                        // errors the connection out before any write).
                        Err(_) => {
                            self.dead = true;
                            return;
                        }
                        Ok(version) => {
                            // Always answer with our own hello so a
                            // cross-version client can report the skew.
                            let _ = wire::write_hello(&mut self.wbuf);
                            if version != wire::VERSION {
                                let _ = wire::write_err(&mut self.wbuf, wire::ERR_BAD_VERSION);
                                self.closed = true;
                                self.eof = true;
                                return;
                            }
                            self.state = State::Open;
                        }
                    }
                }
                State::Open => {
                    if self.closed {
                        return;
                    }
                    let (frame, len) = {
                        let avail = &self.rbuf[self.rpos..];
                        let Some(len) = frame_len(avail) else { return };
                        if avail.len() < len {
                            return;
                        }
                        (wire::read_client_frame(&mut Cursor::new(&avail[..len])), len)
                    };
                    self.rpos += len;
                    match frame {
                        // Unreachable with a complete frame slice; defensive.
                        Err(_) | Ok(ClientFrame::Eof) => {
                            self.dead = true;
                            return;
                        }
                        Ok(ClientFrame::Bad(code)) => {
                            // `ERR` is the last frame on the wire: queue it,
                            // drop unadmitted work, and converge to close
                            // once in-flight responses drain (suppressed).
                            let _ = wire::write_err(&mut self.wbuf, code);
                            self.closed = true;
                            self.eof = true;
                            self.pending.clear();
                            self.head_since = None;
                            return;
                        }
                        Ok(ClientFrame::Stats) => {
                            // Submit buffered admissions first so the
                            // snapshot reflects them (threaded parity).
                            self.try_admit(ctx, quota);
                            ctx.flush_submit();
                            let snap = ctx.inner.snapshot(&self.stats);
                            let _ = wire::write_stats_resp(&mut self.wbuf, &snap);
                        }
                        Ok(ClientFrame::Stats2) => {
                            self.try_admit(ctx, quota);
                            ctx.flush_submit();
                            let snap = ctx.inner.snapshot2();
                            let _ = wire::write_stats2_resp(&mut self.wbuf, &snap);
                        }
                        Ok(ClientFrame::Trace) => {
                            let events = ctx.inner.ring.events();
                            let _ = wire::write_trace_resp(&mut self.wbuf, &events);
                        }
                        Ok(ClientFrame::Requests(reqs)) => {
                            let was_empty = self.pending.is_empty();
                            self.pending.extend(reqs);
                            if was_empty && !self.pending.is_empty() {
                                self.head_since = Some(Instant::now());
                            }
                        }
                    }
                }
            }
        }
    }

    /// Admission control: move pending requests into free window slots up
    /// to the fair per-connection `quota`, resolving the accuracy knob and
    /// stamping spans exactly as the threaded reader did.
    fn try_admit(&mut self, ctx: &mut LoopCtx<'_>, quota: usize) {
        if self.closed || self.dead {
            return;
        }
        let cap = quota.min(self.slots.len());
        while self.in_flight < cap && !self.pending.is_empty() {
            let r = self.pending.pop_front().expect("pending is nonempty");
            let slot = self.free.pop().expect("in_flight below cap implies a free slot");
            self.slots[slot as usize] = Some((r.id, Instant::now()));
            self.in_flight += 1;
            // The next head (if any) starts its own admission clock.
            self.head_since =
                if self.pending.is_empty() { None } else { Some(Instant::now()) };
            let w = resolve_w(ctx.inner, &r);
            let op_byte = match r.op {
                ReqOp::Mul => 0u8,
                ReqOp::Div => 1u8,
            };
            let span = Span::admitted(ctx.inner.ring.sample(), op_byte, r.bits as u8, w as u8);
            ctx.submit.push((
                Request { id: self.id_base | slot as u64, op: r.op, bits: r.bits, w, a: r.a, b: r.b },
                span,
            ));
            if ctx.submit.len() >= ctx.inner.cfg.batch {
                ctx.flush_submit();
            }
        }
    }

    /// Overload shedding: if the head of the unadmitted queue has waited
    /// out the admission deadline, shed *it* (and only it) with
    /// `ERR_OVERLOAD`; the connection stays open and the next head gets a
    /// fresh clock — the same per-request semantics as the threaded
    /// reader's `acquire_deadline`.
    fn shed_expired(&mut self, ctx: &mut LoopCtx<'_>, deadline: Option<Duration>) {
        let Some(d) = deadline else { return };
        if self.closed || self.dead {
            return;
        }
        let Some(t0) = self.head_since else { return };
        if t0.elapsed() < d {
            return;
        }
        if let Some(r) = self.pending.pop_front() {
            ctx.inner.shed.fetch_add(1, Ordering::Relaxed);
            let _ = wire::write_response_err(&mut self.wbuf, r.id, wire::ERR_OVERLOAD);
        }
        self.head_since = if self.pending.is_empty() { None } else { Some(Instant::now()) };
    }

    fn flush_wbuf(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.last_write_progress = Instant::now();
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos >= self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > 64 * 1024 {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }

    fn compact_rbuf(&mut self) {
        if self.rpos >= self.rbuf.len() {
            self.rbuf.clear();
            self.rpos = 0;
        } else if self.rpos > 4096 {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_len_computes_wire_frame_sizes() {
        assert_eq!(frame_len(&[]), None);
        assert_eq!(frame_len(&[wire::FRAME_REQ]), Some(1 + wire::REQ_BODY_LEN));
        // BATCH needs its 2-byte count before the length is known.
        assert_eq!(frame_len(&[wire::FRAME_BATCH]), None);
        assert_eq!(frame_len(&[wire::FRAME_BATCH, 2]), None);
        assert_eq!(frame_len(&[wire::FRAME_BATCH, 2, 0]), Some(3 + 2 * wire::REQ_BODY_LEN));
        // A maximal BATCH is ~2 MiB — bounded, and far below the rbuf cap.
        let max = frame_len(&[wire::FRAME_BATCH, 0xFF, 0xFF]).unwrap();
        assert_eq!(max, 3 + wire::MAX_BATCH * wire::REQ_BODY_LEN);
        assert!(max < MAX_RBUF_BUFFERED);
        assert_eq!(frame_len(&[wire::FRAME_STATS]), Some(1));
        assert_eq!(frame_len(&[wire::FRAME_STATS2]), Some(1));
        assert_eq!(frame_len(&[wire::FRAME_TRACE]), Some(1));
        // Unknown kinds are answered (ERR_BAD_FRAME) from the kind alone.
        assert_eq!(frame_len(&[0x7F]), Some(1));
    }
}
