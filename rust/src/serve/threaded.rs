//! Thread-per-connection serve backend (the pre-reactor architecture,
//! retained behind [`super::Server::start_threaded`]).
//!
//! Per connection: the spawned connection thread becomes the *reader* and
//! starts one *writer* thread. The reader decodes frames, admits requests
//! under a bounded in-flight window ([`Inflight`] — when the window is
//! full the reader stops draining the socket, so backpressure propagates
//! over TCP), and funnels them into the shared coordinator. The writer
//! drains completions and writes response frames out of order as SIMD
//! lanes complete.
//!
//! This backend is kept for A/B comparison in the connection-count sweep
//! (`loadgen --sweep`): it is the baseline whose thread-pair-per-socket
//! scheduler thrash the reactor (DESIGN.md §15) exists to remove. It
//! shares `Inner` — config, coordinator, counters, registry, trace ring —
//! with the reactor backend, so every observability surface reads the
//! same either way.

use super::server::{resolve_w, Inner, DRAIN_DEADLINE};
use super::stats::ServeCounters;
use super::wire::{self, ClientFrame};
use crate::coordinator::{Request, Response};
use crate::obs::{self, Span, TraceEvent};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Connection threads carry shallow stacks; the default 8 MiB per thread
/// is what makes thread-per-connection fall over first at high counts.
const THREAD_STACK: usize = 512 * 1024;

/// Live-connection registry: a duplicate handle of every established
/// socket, so shutdown can `shutdown(2)` them all — which unblocks the
/// reader/writer threads out of their blocking socket calls — and then
/// wait (bounded) for the connection threads to deregister themselves.
pub(crate) struct ConnRegistry {
    streams: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    pub fn new() -> Arc<ConnRegistry> {
        Arc::new(ConnRegistry { streams: Mutex::new(HashMap::new()), next_id: AtomicU64::new(0) })
    }

    fn register(&self, stream: TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.streams.lock().unwrap().insert(id, stream);
        id
    }

    fn unregister(&self, id: u64) {
        self.streams.lock().unwrap().remove(&id);
    }

    /// Wake every live connection out of its blocking reads/writes and
    /// wait up to [`DRAIN_DEADLINE`] for the connection threads to exit.
    /// Re-issues the socket shutdown each poll so a connection that
    /// registered mid-drain is caught too.
    pub fn drain(&self) {
        let t0 = Instant::now();
        loop {
            {
                let streams = self.streams.lock().unwrap();
                if streams.is_empty() {
                    return;
                }
                for stream in streams.values() {
                    let _ = stream.shutdown(Shutdown::Both);
                }
            }
            if t0.elapsed() >= DRAIN_DEADLINE {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

/// Spawn the reader thread for a freshly accepted connection. A failed
/// spawn (thread exhaustion — the failure mode this backend is benched
/// for) drops the stream: the client sees a clean close, not a panic.
pub(crate) fn spawn_conn(stream: TcpStream, inner: Arc<Inner>, registry: Arc<ConnRegistry>) {
    let spawned = std::thread::Builder::new()
        .name("serve-conn".into())
        .stack_size(THREAD_STACK)
        .spawn(move || {
            let reg_id = stream.try_clone().ok().map(|dup| registry.register(dup));
            let _ = handle_conn(stream, inner);
            if let Some(id) = reg_id {
                registry.unregister(id);
            }
        });
    let _ = spawned;
}

/// Per-connection in-flight window: a fixed slot table guarded by a
/// mutex + condvar. `acquire` is the admission-control point — it blocks
/// the reader when every slot is taken, which stops socket draining and
/// pushes backpressure to the client over TCP.
struct Inflight {
    slots: Mutex<SlotTable>,
    freed: Condvar,
}

struct SlotTable {
    free: Vec<u32>,
    /// `entries[slot]` = (wire id, admission time) of the occupying request.
    entries: Vec<(u64, Instant)>,
}

impl Inflight {
    fn new(window: usize) -> Self {
        let window = window.max(1);
        Inflight {
            slots: Mutex::new(SlotTable {
                free: (0..window as u32).rev().collect(),
                entries: vec![(0, Instant::now()); window],
            }),
            freed: Condvar::new(),
        }
    }

    /// Take a slot if one is free (never blocks).
    fn try_acquire(&self, wire_id: u64) -> Option<u32> {
        let mut t = self.slots.lock().unwrap();
        let slot = t.free.pop()?;
        t.entries[slot as usize] = (wire_id, Instant::now());
        Some(slot)
    }

    /// Block until a slot frees, then take it.
    #[cfg(test)]
    fn acquire(&self, wire_id: u64) -> u32 {
        self.acquire_deadline(wire_id, None).expect("unbounded acquire cannot time out")
    }

    /// Block until a slot frees or `deadline` elapses. `None` deadline =
    /// wait indefinitely (always returns `Some`). A `None` return is the
    /// shedding signal: the request waited its whole admission budget and
    /// never got a slot.
    fn acquire_deadline(&self, wire_id: u64, deadline: Option<Duration>) -> Option<u32> {
        let start = Instant::now();
        let mut t = self.slots.lock().unwrap();
        loop {
            if let Some(slot) = t.free.pop() {
                t.entries[slot as usize] = (wire_id, Instant::now());
                return Some(slot);
            }
            match deadline {
                None => t = self.freed.wait(t).unwrap(),
                Some(d) => {
                    let left = d.checked_sub(start.elapsed())?;
                    let (guard, timeout) = self.freed.wait_timeout(t, left).unwrap();
                    t = guard;
                    if timeout.timed_out() && t.free.is_empty() {
                        return None;
                    }
                }
            }
        }
    }

    /// Free a slot; returns the wire id and the admission→now latency.
    fn release(&self, slot: u32) -> (u64, u64) {
        let mut t = self.slots.lock().unwrap();
        let (id, t0) = t.entries[slot as usize];
        t.free.push(slot);
        drop(t);
        self.freed.notify_one();
        (id, t0.elapsed().as_nanos() as u64)
    }
}

/// Shared buffered write half. The writer thread owns the response
/// stream; the reader grabs the lock only for the rare `STATS_RESP`/`ERR`
/// frames, so frames never interleave mid-frame.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

fn handle_conn(stream: TcpStream, inner: Arc<Inner>) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    // Socket timeouts: a peer that stalls mid-frame (or never drains its
    // receive buffer) errors this connection out instead of wedging its
    // reader/writer threads forever.
    if inner.cfg.io_timeout_ms > 0 {
        let t = Some(Duration::from_millis(inner.cfg.io_timeout_ms));
        stream.set_read_timeout(t)?;
        stream.set_write_timeout(t)?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream)));

    // Hello exchange. The server always answers with its *own* hello (so
    // a cross-version client can read the server's version and report it),
    // then closes a mismatched connection with ERR_BAD_VERSION.
    let peer_version = wire::read_hello(&mut reader)?;
    {
        let mut w = writer.lock().unwrap();
        wire::write_hello(&mut *w)?;
        if peer_version != wire::VERSION {
            wire::write_err(&mut *w, wire::ERR_BAD_VERSION)?;
            w.flush()?;
            return Ok(());
        }
        w.flush()?;
    }

    let open = inner.connections.fetch_add(1, Ordering::Relaxed) + 1;
    inner.peak_connections.fetch_max(open, Ordering::Relaxed);
    let conn_stats = Arc::new(ServeCounters::new());
    let inflight = Arc::new(Inflight::new(inner.cfg.window));
    // Set once the reader has queued an `ERR` frame: the protocol promises
    // `ERR` is the last frame, so the writer stops emitting `RESP`s.
    let closed = Arc::new(AtomicBool::new(false));
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<(u32, Response)>();

    let writer_spawn = {
        let writer = Arc::clone(&writer);
        let inflight = Arc::clone(&inflight);
        let conn_stats = Arc::clone(&conn_stats);
        let inner = Arc::clone(&inner);
        let closed = Arc::clone(&closed);
        std::thread::Builder::new()
            .name("serve-writer".into())
            .stack_size(THREAD_STACK)
            .spawn(move || writer_loop(writer, resp_rx, inflight, conn_stats, inner, closed))
    };
    let writer_handle = match writer_spawn {
        Ok(h) => h,
        Err(e) => {
            inner.connections.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
    };

    let result =
        reader_loop(&mut reader, &writer, &inner, &inflight, &conn_stats, &resp_tx, &closed);

    // Dropping our sender lets the writer exit once every in-flight
    // response (whose routes hold clones) has been delivered.
    drop(resp_tx);
    let _ = writer_handle.join();
    inner.connections.fetch_sub(1, Ordering::Relaxed);
    result
}

fn reader_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &SharedWriter,
    inner: &Arc<Inner>,
    inflight: &Arc<Inflight>,
    conn_stats: &Arc<ServeCounters>,
    resp_tx: &Sender<(u32, Response)>,
    closed: &Arc<AtomicBool>,
) -> io::Result<()> {
    // Admitted requests buffered for one streaming submission; the shared
    // coordinator's assembler does the per-{bits, w} sub-queueing.
    let mut pending: Vec<(Request, Span)> = Vec::new();
    loop {
        match wire::read_client_frame(reader)? {
            ClientFrame::Eof => return Ok(()),
            ClientFrame::Bad(code) => {
                // `ERR` must be the last frame on the wire: mark the
                // connection closed *before* taking the lock, so once the
                // writer's current drain (which holds the lock) finishes,
                // it emits no further `RESP` frames.
                closed.store(true, Ordering::SeqCst);
                let mut w = writer.lock().unwrap();
                wire::write_err(&mut *w, code)?;
                w.flush()?;
                return Ok(());
            }
            ClientFrame::Stats => {
                // Submit buffered work first so the snapshot reflects it.
                submit_pending(inner, &mut pending, resp_tx);
                let snap = inner.snapshot(conn_stats);
                let mut w = writer.lock().unwrap();
                wire::write_stats_resp(&mut *w, &snap)?;
                w.flush()?;
            }
            ClientFrame::Stats2 => {
                submit_pending(inner, &mut pending, resp_tx);
                let snap = inner.snapshot2();
                let mut w = writer.lock().unwrap();
                wire::write_stats2_resp(&mut *w, &snap)?;
                w.flush()?;
            }
            ClientFrame::Trace => {
                let events = inner.ring.events();
                let mut w = writer.lock().unwrap();
                wire::write_trace_resp(&mut *w, &events)?;
                w.flush()?;
            }
            ClientFrame::Requests(reqs) => {
                let deadline = (inner.cfg.deadline_ms > 0)
                    .then(|| Duration::from_millis(inner.cfg.deadline_ms));
                for r in &reqs {
                    // Admission control: take a window slot, submitting
                    // buffered work before blocking so slots can free.
                    let slot = match inflight.try_acquire(r.id) {
                        Some(s) => s,
                        None => {
                            submit_pending(inner, &mut pending, resp_tx);
                            match inflight.acquire_deadline(r.id, deadline) {
                                Some(s) => s,
                                None => {
                                    // Admission deadline expired: shed this
                                    // request per-request (`RESP_ERR`, the
                                    // connection stays open) rather than
                                    // stalling every request behind it.
                                    inner.shed.fetch_add(1, Ordering::Relaxed);
                                    let mut w = writer.lock().unwrap();
                                    wire::write_response_err(&mut *w, r.id, wire::ERR_OVERLOAD)?;
                                    w.flush()?;
                                    continue;
                                }
                            }
                        }
                    };
                    // The coordinator-side id is the window slot; the wire
                    // id is recovered from the slot table on completion.
                    let w = resolve_w(inner, r);
                    let op_byte = match r.op {
                        crate::coordinator::ReqOp::Mul => 0u8,
                        crate::coordinator::ReqOp::Div => 1u8,
                    };
                    let span = Span::admitted(inner.ring.sample(), op_byte, r.bits as u8, w as u8);
                    pending.push((
                        Request { id: slot as u64, op: r.op, bits: r.bits, w, a: r.a, b: r.b },
                        span,
                    ));
                    if pending.len() >= inner.cfg.batch {
                        submit_pending(inner, &mut pending, resp_tx);
                    }
                }
                submit_pending(inner, &mut pending, resp_tx);
            }
        }
    }
}

/// Stream the buffered admissions into the shared coordinator.
fn submit_pending(
    inner: &Arc<Inner>,
    pending: &mut Vec<(Request, Span)>,
    resp_tx: &Sender<(u32, Response)>,
) {
    if !pending.is_empty() {
        inner.coordinator.submit_batch_streaming_spanned(std::mem::take(pending), 0, resp_tx);
    }
}

/// Writer thread: drain completions, free window slots, record latency,
/// and write `RESP` frames out-of-order as lanes complete. Write failures
/// (client went away) switch to drain-only mode so slots keep freeing and
/// the reader can run to its own error/EOF.
fn writer_loop(
    writer: SharedWriter,
    rx: Receiver<(u32, Response)>,
    inflight: Arc<Inflight>,
    conn_stats: Arc<ServeCounters>,
    inner: Arc<Inner>,
    closed: Arc<AtomicBool>,
) {
    let mut dead = false;
    loop {
        // Block for one completion, then drain greedily before flushing.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut w = writer.lock().unwrap();
        let mut msg = Some(first);
        while let Some((_, resp)) = msg.take() {
            let (wire_id, latency_ns) = inflight.release(resp.id as u32);
            conn_stats.record(latency_ns);
            inner.global.record(latency_ns);
            // Serve-side stage stamps: `admit` covers admission→shard
            // submission, `write` covers response-routed→socket-write.
            // Sampled spans become full trace events at this point — the
            // request's last stop in the pipeline.
            let span = resp.span;
            if span.t_admit_ns > 0 {
                let t_write = obs::now_ns();
                inner.stage_admit.record_ns(span.t_submit_ns.saturating_sub(span.t_admit_ns));
                inner.stage_write.record_ns(t_write.saturating_sub(span.t_done_ns));
                if span.sampled {
                    inner.ring.push(TraceEvent::from_span(wire_id, &span, t_write));
                }
            }
            dead = dead || closed.load(Ordering::SeqCst);
            if resp.err != 0 {
                // Shard supervision gave this request up (double fault):
                // fail it per-request; the connection survives.
                inner.unavailable.fetch_add(1, Ordering::Relaxed);
                if !dead
                    && wire::write_response_err(&mut *w, wire_id, wire::ERR_UNAVAILABLE).is_err()
                {
                    dead = true;
                }
            } else if !dead && wire::write_response(&mut *w, wire_id, resp.value).is_err() {
                dead = true;
            }
            if let Ok(m) = rx.try_recv() {
                msg = Some(m);
            }
        }
        if !dead && w.flush().is_err() {
            dead = true;
        }
    }
    if !dead {
        let _ = writer.lock().unwrap().flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inflight_window_blocks_and_frees() {
        let inflight = Arc::new(Inflight::new(2));
        let s0 = inflight.acquire(10);
        let s1 = inflight.acquire(11);
        assert_ne!(s0, s1);
        assert!(inflight.try_acquire(12).is_none(), "window must be full");
        let inflight2 = Arc::clone(&inflight);
        let t = std::thread::spawn(move || inflight2.acquire(12));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let (id, _lat) = inflight.release(s0);
        assert_eq!(id, 10);
        let s2 = t.join().unwrap();
        assert_eq!(s2, s0, "freed slot is reused");
        inflight.release(s1);
        inflight.release(s2);
        assert!(inflight.try_acquire(13).is_some());
    }
}
