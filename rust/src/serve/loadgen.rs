//! Load generator for the SIMD-wire server: N connections × pipelined
//! request streams with a configurable width mix and per-request accuracy
//! knob spread, reporting client-side throughput plus the server's own
//! `STATS` snapshot, and writing `BENCH_serve.json` (schema
//! `simdive-serve-v1`, documented in CHANGES.md alongside the hotpath
//! schema). Used by the `simdive loadgen` subcommand, `benches/serve.rs`
//! and the CI loopback smoke.
//!
//! [`run_connections_sweep`] drives both server backends (reactor and
//! thread-per-connection) across a 1→10k connection-count ladder against
//! fresh loopback servers, producing the `connections_sweep` section of
//! `BENCH_serve.json` (append-only; schema name unchanged). Before
//! opening sockets, runs fail fast with an `ulimit -n`-naming error when
//! the process fd limit cannot cover the requested connection count.

use super::client::Client;
use super::wire::{WireRequest, WireStats};
use crate::arith::W_MAX;
use crate::coordinator::ReqOp;
use crate::obs::trace::STAGE_NAMES;
use crate::obs::Snapshot;
use crate::util::Rng;
use std::fmt::Write as _;
use std::io;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Concurrent connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: u64,
    /// Client pipeline chunk (requests per `BATCH` frame).
    pub chunk: usize,
    /// Operand-width mix, sampled uniformly (e.g. `[8, 8, 8, 16, 16, 32]`
    /// for the DNN/multimedia-heavy mix of §3.2).
    pub widths: Vec<u32>,
    /// `Some(w)` pins every request's accuracy knob; `None` spreads it
    /// uniformly over `0..=W_MAX`.
    pub fixed_w: Option<u32>,
    /// `Some(ppm)` puts every request in error-budget mode instead: the
    /// wire carries the budget and the server's router picks the cheapest
    /// satisfying `w` (overrides `fixed_w`/the spread).
    pub budget_ppm: Option<u32>,
    /// One in `div_ratio` requests is a divide (rest multiply).
    pub div_ratio: u64,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            connections: 4,
            requests: 100_000,
            chunk: 256,
            widths: vec![8, 8, 8, 16, 16, 32],
            fixed_w: None,
            budget_ppm: None,
            div_ratio: 4,
            seed: 0xD15C0,
        }
    }
}

/// What one load-generation run measured.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub connections: usize,
    pub requests: u64,
    pub chunk: usize,
    pub widths: Vec<u32>,
    pub wall_s: f64,
    /// Client-observed completed requests per second across connections.
    pub rps: f64,
    /// Server-side snapshot taken after the run.
    pub server: WireStats,
    /// The server's `STATS2` registry snapshot (wire v4): per-stage
    /// histograms, per-shard gauges, per-tier counters.
    pub stats2: Snapshot,
}

/// Generate one request deterministically from a connection's RNG.
fn make_request(cfg: &LoadgenConfig, rng: &mut Rng, id: u64) -> WireRequest {
    let bits = cfg.widths[rng.below(cfg.widths.len() as u64) as usize];
    let w = cfg.fixed_w.unwrap_or_else(|| rng.below(W_MAX as u64 + 1) as u32);
    let (w, budget_ppm) = match cfg.budget_ppm {
        Some(ppm) => (0, ppm.max(1)),
        None => (w, 0),
    };
    WireRequest {
        id,
        op: if rng.below(cfg.div_ratio.max(1)) == 0 { ReqOp::Div } else { ReqOp::Mul },
        bits,
        w,
        budget_ppm,
        a: rng.operand(bits),
        b: rng.operand(bits),
    }
}

/// Drive `addr` with `cfg`; blocks until every request has its response.
///
/// Every connection is established (with retry, for just-spawned servers)
/// *before* the throughput clock starts — `rps` measures serving, not
/// server start-up.
pub fn run(addr: &str, cfg: &LoadgenConfig) -> io::Result<LoadgenReport> {
    let connections = cfg.connections.max(1);
    // Fail fast — before any socket opens — when the fd limit cannot
    // cover the sweep point, with an error that names `ulimit -n`.
    super::reactor::ensure_fd_capacity(connections as u64 + 64).map_err(io::Error::other)?;
    let chunk = cfg.chunk.clamp(1, super::client::MAX_CHUNK);
    let per = cfg.requests / connections as u64;
    let remainder = cfg.requests % connections as u64;
    // At high connection counts the accept backlog drains one handshake
    // at a time; scale the connect-retry budget with the ladder.
    let connect_timeout = Duration::from_secs(5) + Duration::from_millis(2 * connections as u64);
    // All parties (worker threads + this one) rendezvous after connecting.
    let barrier = Arc::new(Barrier::new(connections + 1));
    let mut handles = Vec::with_capacity(connections);
    for c in 0..connections {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        let barrier = Arc::clone(&barrier);
        let quota = per + if (c as u64) < remainder { 1 } else { 0 };
        // Named small-stack threads: 10k default-stack (8 MB) spawns
        // would reserve ~80 GB of address space.
        let builder = std::thread::Builder::new()
            .name(format!("loadgen-{c}"))
            .stack_size(256 * 1024);
        let handle = builder.spawn(move || -> io::Result<u64> {
            let client = if quota == 0 {
                None
            } else {
                Some(Client::connect_retry(addr.as_str(), connect_timeout))
            };
            barrier.wait();
            let Some(client) = client else { return Ok(0) };
            let mut client = client?.with_chunk(chunk);
            let mut rng = Rng::new(cfg.seed ^ (0x9E37_79B9 * (c as u64 + 1)));
            let mut done = 0u64;
            // Windows of up to 8 pipeline chunks per exchange call.
            let window = chunk as u64 * 8;
            while done < quota {
                let n = (quota - done).min(window);
                let reqs: Vec<WireRequest> =
                    (0..n).map(|k| make_request(&cfg, &mut rng, done + k)).collect();
                let resps = client.exchange(&reqs)?;
                debug_assert_eq!(resps.len(), reqs.len());
                done += n;
            }
            Ok(done)
        })?;
        handles.push(handle);
    }
    barrier.wait();
    let t0 = Instant::now();
    let mut total = 0u64;
    let mut first_err: Option<io::Error> = None;
    for h in handles {
        match h.join().expect("loadgen connection thread panicked") {
            Ok(n) => total += n,
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    // Final server-side snapshots over a fresh connection.
    let mut probe = Client::connect_retry(addr, Duration::from_secs(5))?;
    let server = probe.stats()?;
    let stats2 = probe.stats2()?;
    Ok(LoadgenReport {
        connections,
        requests: total,
        chunk,
        widths: cfg.widths.clone(),
        wall_s,
        rps: total as f64 / wall_s,
        server,
        stats2,
    })
}

/// In-process coordinator batched-submission throughput over the same
/// request generator — the comparison number reported next to the network
/// rps (mirrors the `coordinator.batched_rps` figure of
/// `BENCH_hotpath.json`).
pub fn coordinator_batched_rps(n: u64) -> f64 {
    use crate::coordinator::{Coordinator, CoordinatorConfig, Request};
    let cfg = LoadgenConfig { fixed_w: Some(W_MAX), ..LoadgenConfig::default() };
    let mut rng = Rng::new(cfg.seed);
    let coord = Coordinator::start(CoordinatorConfig::default());
    let t0 = Instant::now();
    let mut submitted = 0u64;
    while submitted < n {
        let window = (n - submitted).min(1024);
        let reqs: Vec<Request> = (0..window)
            .map(|k| {
                let r = make_request(&cfg, &mut rng, submitted + k);
                Request { id: r.id, op: r.op, bits: r.bits, w: r.w, a: r.a, b: r.b }
            })
            .collect();
        coord.submit_batch(reqs).wait();
        submitted += window;
    }
    let rps = n as f64 / t0.elapsed().as_secs_f64();
    coord.shutdown();
    rps
}

/// Connection-count ladder swept on the reactor backend. The top rung is
/// the 10k-connection point the reactor exists for.
pub const SWEEP_REACTOR_POINTS: [usize; 5] = [1, 64, 512, 4096, 10_000];

/// Ladder for the thread-per-connection baseline. Capped below 10k: two
/// OS threads per connection exhausts spawn capacity well before the
/// reactor's ceiling, and the sweep stops at the first rung that fails
/// rather than burying the machine.
pub const SWEEP_THREADED_POINTS: [usize; 4] = [1, 64, 512, 4096];

/// One measured rung of the `connections_sweep`: a fresh loopback server
/// on `mode` driven at `connections`. `ok == false` records a rung that
/// was skipped (fd limit) or failed (spawn/connect exhaustion) — kept in
/// the report so the baseline's collapse point is data, not absence.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub connections: usize,
    pub mode: &'static str,
    pub ok: bool,
    pub rps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Server-side thread count at the end of the rung
    /// ([`super::server::Server::thread_count`]): constant for the
    /// reactor, `O(connections)` for the threaded baseline.
    pub threads: usize,
}

fn failed_point(connections: usize, mode: &'static str) -> SweepPoint {
    SweepPoint { connections, mode, ok: false, rps: 0.0, p50_us: 0, p99_us: 0, threads: 0 }
}

/// Requests per rung: enough work that the measurement dominates setup,
/// without making the 10k rung take minutes.
fn sweep_requests(connections: usize) -> u64 {
    (connections as u64 * 16).clamp(20_000, 120_000)
}

/// Run one rung: fresh loopback server, loadgen at `connections`, tear
/// down. A long per-connection quota with many idle gaps needs a long
/// server io-timeout, so the rung server relaxes it to 60 s.
fn sweep_point(mode: &'static str, connections: usize) -> io::Result<SweepPoint> {
    use super::server::{ServeConfig, Server};
    let cfg = ServeConfig { io_timeout_ms: 60_000, ..ServeConfig::default() };
    let server = match mode {
        "threaded" => Server::start_threaded("127.0.0.1:0", cfg)?,
        _ => Server::start("127.0.0.1:0", cfg)?,
    };
    let addr = server.local_addr().to_string();
    let requests = sweep_requests(connections);
    // Small chunks at high fan-in: keep per-connection pipelines shallow
    // so the rung measures concurrency, not one connection's pipeline.
    let chunk = ((requests / connections as u64) / 8).clamp(1, 64) as usize;
    let lg = LoadgenConfig { connections, requests, chunk, ..LoadgenConfig::default() };
    let result = run(&addr, &lg);
    let threads = server.thread_count();
    server.shutdown();
    let rep = result?;
    Ok(SweepPoint {
        connections,
        mode,
        ok: true,
        rps: rep.rps,
        p50_us: rep.server.p50_us,
        p99_us: rep.server.p99_us,
        threads,
    })
}

/// Sweep both backends across their connection ladders against fresh
/// loopback servers. Rungs whose fd requirement (two ends per connection
/// plus headroom) exceeds the raisable limit are recorded as `ok: false`
/// and skipped; a threaded rung that fails outright ends that backend's
/// ladder (each rung needs `2 × connections` threads — past its collapse
/// point, higher rungs only fail more slowly).
pub fn run_connections_sweep() -> Vec<SweepPoint> {
    let mut out = Vec::new();
    let ladders: [(&'static str, &[usize]); 2] =
        [("reactor", &SWEEP_REACTOR_POINTS), ("threaded", &SWEEP_THREADED_POINTS)];
    for (mode, ladder) in ladders {
        for &n in ladder {
            if let Err(e) = super::reactor::ensure_fd_capacity(2 * n as u64 + 256) {
                eprintln!("[sweep] skipping {mode} @{n} connections: {e}");
                out.push(failed_point(n, mode));
                continue;
            }
            match sweep_point(mode, n) {
                Ok(p) => out.push(p),
                Err(e) => {
                    eprintln!("[sweep] {mode} @{n} connections failed: {e}");
                    out.push(failed_point(n, mode));
                    if mode == "threaded" {
                        break;
                    }
                }
            }
        }
    }
    out
}

/// Render the `simdive-serve-v1` JSON document.
pub fn to_json(report: &LoadgenReport, coord_requests: u64, coord_batched_rps: f64) -> String {
    to_json_full(report, coord_requests, coord_batched_rps, &[], &[])
}

/// [`to_json`] plus a `"chaos"` array: degraded-mode throughput at each
/// swept fault rate (same schema name — the section is append-only, so
/// consumers of the fault-free document keep parsing unchanged).
pub fn to_json_with_chaos(
    report: &LoadgenReport,
    coord_requests: u64,
    coord_batched_rps: f64,
    chaos: &[(u64, super::chaos::ChaosReport)],
) -> String {
    to_json_full(report, coord_requests, coord_batched_rps, chaos, &[])
}

/// [`to_json_with_chaos`] plus a `"connections_sweep"` array: one object
/// per [`SweepPoint`]. Both extra sections are append-only and omitted
/// when empty — the schema name stays `simdive-serve-v1`.
pub fn to_json_full(
    report: &LoadgenReport,
    coord_requests: u64,
    coord_batched_rps: f64,
    chaos: &[(u64, super::chaos::ChaosReport)],
    sweep: &[SweepPoint],
) -> String {
    let mut widths = String::from("[");
    for (i, w) in report.widths.iter().enumerate() {
        if i > 0 {
            widths.push_str(", ");
        }
        write!(widths, "{w}").unwrap();
    }
    widths.push(']');
    let mut chaos_section = String::new();
    if !chaos.is_empty() {
        chaos_section.push_str(",\n  \"chaos\": [");
        for (i, (ppm, c)) in chaos.iter().enumerate() {
            if i > 0 {
                chaos_section.push(',');
            }
            write!(
                chaos_section,
                "\n    {{\"fault_ppm\": {ppm}, \"requests\": {}, \"completed\": {}, \
                 \"failed\": {}, \"mismatches\": {}, \"unresolved\": {}, \
                 \"reconnects\": {}, \"rps\": {:.1}, \"shed_overload\": {}, \
                 \"failed_unavailable\": {}}}",
                c.requests,
                c.completed,
                c.failed,
                c.mismatches,
                c.unresolved,
                c.reconnects,
                c.rps,
                c.server.shed_overload,
                c.server.failed_unavailable,
            )
            .unwrap();
        }
        chaos_section.push_str("\n  ]");
    }
    let mut sweep_section = String::new();
    if !sweep.is_empty() {
        sweep_section.push_str(",\n  \"connections_sweep\": [");
        for (i, p) in sweep.iter().enumerate() {
            if i > 0 {
                sweep_section.push(',');
            }
            write!(
                sweep_section,
                "\n    {{\"connections\": {}, \"mode\": \"{}\", \"ok\": {}, \"rps\": {:.1}, \
                 \"p50_us\": {}, \"p99_us\": {}, \"threads\": {}}}",
                p.connections, p.mode, p.ok, p.rps, p.p50_us, p.p99_us, p.threads,
            )
            .unwrap();
        }
        sweep_section.push_str("\n  ]");
    }
    // Observability sections (append-only additions to the v1 schema):
    // per-stage latency breakdown and per-shard state from the server's
    // `STATS2` snapshot. Omitted entirely when the snapshot is empty, so
    // pre-v4 consumers and synthetic reports render unchanged.
    let mut obs_section = String::new();
    let snap = &report.stats2;
    if !snap.entries.is_empty() {
        obs_section.push_str(",\n  \"stages\": {");
        let mut first = true;
        for name in STAGE_NAMES {
            if let Some(h) = snap.hist(&format!("stage.{name}")) {
                if !first {
                    obs_section.push_str(", ");
                }
                first = false;
                write!(
                    obs_section,
                    "\"{name}\": {{\"count\": {}, \"p50_us\": {}, \"p99_us\": {}}}",
                    h.count(),
                    h.percentile_us(0.50),
                    h.percentile_us(0.99),
                )
                .unwrap();
            }
        }
        obs_section.push('}');
        obs_section.push_str(",\n  \"shards\": [");
        let mut shard = 0usize;
        while let Some(depth) = snap.gauge(&format!("shard.{shard}.queue_depth")) {
            if shard > 0 {
                obs_section.push_str(", ");
            }
            write!(
                obs_section,
                "{{\"shard\": {shard}, \"queue_depth\": {depth}, \"residue_flushes\": {}}}",
                snap.counter(&format!("shard.{shard}.residue_flushes")).unwrap_or(0),
            )
            .unwrap();
            shard += 1;
        }
        obs_section.push(']');
    }
    let s = &report.server;
    format!(
        "{{\n  \"schema\": \"simdive-serve-v1\",\n  \"connections\": {},\n  \"requests\": {},\n  \
         \"chunk\": {},\n  \"widths\": {widths},\n  \"wall_s\": {:.4},\n  \"rps\": {:.1},\n  \
         \"server\": {{\"requests\": {}, \"words\": {}, \"lane_utilization\": {:.4}, \
         \"energy_pj\": {:.1}, \"p50_us\": {}, \"p99_us\": {}}},\n  \
         \"coordinator\": {{\"requests\": {coord_requests}, \"batched_rps\": {:.1}}}{obs_section}{chaos_section}{sweep_section}\n}}\n",
        report.connections,
        report.requests,
        report.chunk,
        report.wall_s,
        report.rps,
        s.requests,
        s.words,
        s.lane_utilization(),
        s.energy_pj(),
        s.p50_us,
        s.p99_us,
        coord_batched_rps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_generator_respects_config() {
        let cfg =
            LoadgenConfig { widths: vec![16], fixed_w: Some(3), ..LoadgenConfig::default() };
        let mut rng = Rng::new(1);
        for i in 0..200 {
            let r = make_request(&cfg, &mut rng, i);
            assert_eq!(r.bits, 16);
            assert_eq!(r.w, 3, "--w pin must reach every request");
            assert_eq!(r.id, i);
            assert!((1..=crate::arith::max_val(16)).contains(&r.a));
        }
        let cfg = LoadgenConfig::default();
        let mut rng = Rng::new(2);
        let mut saw_w = [false; (W_MAX + 1) as usize];
        let mut saw_div = false;
        for i in 0..2000 {
            let r = make_request(&cfg, &mut rng, i);
            assert!(matches!(r.bits, 8 | 16 | 32));
            assert_eq!(r.budget_ppm, 0, "default mode is fixed-w");
            saw_w[r.w as usize] = true;
            saw_div |= r.op == ReqOp::Div;
        }
        assert!(saw_w.iter().all(|&s| s), "w spread must cover 0..=W_MAX");
        assert!(saw_div);
        let cfg = LoadgenConfig { budget_ppm: Some(12_000), ..LoadgenConfig::default() };
        let mut rng = Rng::new(3);
        for i in 0..200 {
            let r = make_request(&cfg, &mut rng, i);
            assert_eq!(r.budget_ppm, 12_000, "budget must reach every request");
            assert_eq!(r.w, 0, "budget mode leaves the w byte unused");
        }
    }

    #[test]
    fn json_document_is_well_formed_enough() {
        let report = LoadgenReport {
            connections: 2,
            requests: 100,
            chunk: 16,
            widths: vec![8, 16],
            wall_s: 0.5,
            rps: 200.0,
            server: WireStats { requests: 100, words: 30, ..WireStats::default() },
            stats2: Snapshot::default(),
        };
        let j = to_json(&report, 40_000, 1234.5);
        assert!(j.contains("\"schema\": \"simdive-serve-v1\""));
        assert!(j.contains("\"widths\": [8, 16]"));
        assert!(j.contains("\"batched_rps\": 1234.5"));
        assert!(!j.contains("\"chaos\""), "no chaos section without a sweep");
        assert!(!j.contains("\"stages\""), "no stage section without a stats2 snapshot");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn stage_and_shard_sections_render_from_stats2() {
        use crate::obs::{HistSnapshot, Value};
        let mut snap = Snapshot::default();
        let mut h = HistSnapshot::default();
        h.buckets[10] = 50;
        snap.push("stage.queue", Value::Hist(h));
        snap.push("stage.execute", Value::Hist(h));
        snap.push("shard.0.queue_depth", Value::Gauge(0));
        snap.push("shard.0.residue_flushes", Value::Counter(7));
        snap.push("shard.1.queue_depth", Value::Gauge(2));
        let report = LoadgenReport {
            connections: 1,
            requests: 50,
            chunk: 8,
            widths: vec![8],
            wall_s: 0.1,
            rps: 500.0,
            server: WireStats::default(),
            stats2: snap,
        };
        let j = to_json(&report, 0, 0.0);
        assert!(j.contains("\"stages\": {"));
        assert!(j.contains("\"queue\": {\"count\": 50"));
        assert!(j.contains("\"execute\": {\"count\": 50"));
        assert!(!j.contains("\"admit\""), "absent stages are omitted, not zero-filled");
        assert!(j.contains("\"shards\": ["));
        assert!(j.contains("{\"shard\": 0, \"queue_depth\": 0, \"residue_flushes\": 7}"));
        assert!(j.contains("{\"shard\": 1, \"queue_depth\": 2, \"residue_flushes\": 0}"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn chaos_section_is_appended_and_balanced() {
        let report = LoadgenReport {
            connections: 1,
            requests: 10,
            chunk: 4,
            widths: vec![8],
            wall_s: 0.1,
            rps: 100.0,
            server: WireStats::default(),
            stats2: Snapshot::default(),
        };
        let c = crate::serve::chaos::ChaosReport {
            requests: 10,
            completed: 9,
            failed: 1,
            mismatches: 0,
            unresolved: 0,
            reconnects: 2,
            saboteur_rounds: 4,
            wall_s: 0.2,
            rps: 45.0,
            server: WireStats { shed_overload: 3, failed_unavailable: 1, ..WireStats::default() },
            stats2: Snapshot::default(),
            baseline_connections: 1,
            final_connections: 1,
        };
        let j = to_json_with_chaos(&report, 10, 99.9, &[(0, c.clone()), (10_000, c)]);
        assert!(j.contains("\"schema\": \"simdive-serve-v1\""), "schema name must not change");
        assert!(j.contains("\"chaos\": ["));
        assert!(j.contains("\"fault_ppm\": 10000"));
        assert!(j.contains("\"shed_overload\": 3"));
        assert!(!j.contains("\"connections_sweep\""), "no sweep section without a sweep");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn connections_sweep_section_is_appended_and_balanced() {
        let report = LoadgenReport {
            connections: 1,
            requests: 10,
            chunk: 4,
            widths: vec![8],
            wall_s: 0.1,
            rps: 100.0,
            server: WireStats::default(),
            stats2: Snapshot::default(),
        };
        let sweep = vec![
            SweepPoint {
                connections: 64,
                mode: "reactor",
                ok: true,
                rps: 123_456.7,
                p50_us: 90,
                p99_us: 800,
                threads: 5,
            },
            failed_point(10_000, "threaded"),
        ];
        let j = to_json_full(&report, 10, 99.9, &[], &sweep);
        assert!(j.contains("\"schema\": \"simdive-serve-v1\""), "schema name must not change");
        assert!(j.contains("\"connections_sweep\": ["));
        assert!(j.contains(
            "{\"connections\": 64, \"mode\": \"reactor\", \"ok\": true, \"rps\": 123456.7, \
             \"p50_us\": 90, \"p99_us\": 800, \"threads\": 5}"
        ));
        assert!(j.contains("\"mode\": \"threaded\", \"ok\": false"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn sweep_rungs_scale_requests_and_stay_bounded() {
        assert_eq!(sweep_requests(1), 20_000, "floor binds at the bottom rung");
        assert_eq!(sweep_requests(4096), 65_536);
        assert_eq!(sweep_requests(10_000), 120_000, "ceiling binds at the top rung");
        assert_eq!(SWEEP_REACTOR_POINTS.last(), Some(&10_000));
        assert!(SWEEP_THREADED_POINTS.iter().all(|&n| n < 10_000));
    }
}
