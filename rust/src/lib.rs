//! # SIMDive — full-system reproduction
//!
//! Approximate SIMD soft multiplier-divider for FPGAs with tunable accuracy
//! (Ebrahimi, Ullah, Kumar — GLSVLSI 2020), rebuilt as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * [`arith`] — bit-exact behavioral models of SIMDive and every baseline.
//! * [`fabric`] — simulated Virtex-7 fabric (LUT6/CARRY4 netlists, area,
//!   timing, power) standing in for Vivado + the VC707 board.
//! * [`circuits`] — gate-level netlists of all designs, verified against
//!   [`arith`].
//! * [`metrics`] — ARE/PRE/NED/CF/PSNR evaluators for the paper's tables.
//! * [`image`], [`ann`], [`datasets`] — the application substrates of the
//!   paper's §4.3 (image blending, Gaussian smoothing, quantized MLP).
//! * [`engine`] — the unified execution seam: one [`engine::Backend`]
//!   trait (reference / batched / sharded) from the scalar models to the
//!   serve path. New callers should hold an [`engine::Engine`] handle
//!   rather than dispatching designs by hand.
//! * [`coordinator`] — the L3 SIMD dispatch front end (lane packing,
//!   batching, power gating) over the sharded engine.
//! * [`serve`] — the network serving subsystem: SIMD-wire protocol, TCP
//!   server over the coordinator, pipelined client, load generator.
//! * [`faults`] — deterministic, seeded fault injection (wire, engine,
//!   server) behind the fault-tolerant serving defenses and the chaos
//!   load scenario.
//! * [`obs`] — the dependency-free observability layer: lock-free
//!   metrics registry (counters/gauges/log2 histograms), request
//!   lifecycle tracing, and the `STATS2` snapshot source.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (Python never runs on the request path).
//!
//! See DESIGN.md for the system inventory and the per-experiment index.
#![cfg_attr(feature = "portable-simd", feature(portable_simd))]

pub mod arith;
pub mod ann;
pub mod circuits;
pub mod datasets;
pub mod engine;
pub mod fabric;
pub mod faults;
pub mod image;
pub mod coordinator;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;
