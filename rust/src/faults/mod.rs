//! Deterministic, seeded fault injection — the chaos harness behind the
//! fault-tolerant serving work (DESIGN.md §11).
//!
//! Faults here are *scheduled*, not random: every injection site draws
//! from a per-site atomic counter hashed with the configured seed
//! (splitmix64), so the k-th decision at a site is a pure function of
//! `(seed, site, k)`. Two runs with the same seed and the same per-site
//! traffic volume inject the same number of faults at the same relative
//! points, regardless of thread interleaving — which is what makes the
//! chaos invariants (`serve::chaos`) reproducible enough to assert on.
//!
//! The harness threads into every layer of the serving stack:
//!
//! * **wire** — [`ChaosStream`] wraps any `Read + Write` transport and
//!   injects byte corruption, one-byte dribble stalls (short reads and
//!   writes that exercise every `read_exact` resumption path), and sticky
//!   connection resets. Used by `tests/wire_fuzz.rs` and the saboteur
//!   connections of the chaos load scenario.
//! * **engine** — [`FaultyBackend`] wraps any [`Backend`] and injects
//!   panics, slow calls and delayed completions at the seam;
//!   `engine::Sharded` accepts an injector directly
//!   (`Sharded::start_with_faults`) so shard threads can panic *inside*
//!   the execution loop, where supervision has to catch them.
//! * **server** — `serve::Server` drops accepted connections at the door
//!   and injects shard faults via its coordinator when
//!   `ServeConfig::faults` is set.
//!
//! Rates are parts-per-million per decision point (a decision is one
//! read/write call, one shard emission round, one accepted connection —
//! not one request), so 10_000 ppm = 1% of decisions fault.

use crate::arith::{DivDesign, MulDesign};
use crate::coordinator::packer::Request;
use crate::engine::Backend;
use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Fault rates and magnitudes. All rates are parts-per-million per
/// decision point; a zero rate disables that fault entirely (and a
/// default-constructed config injects nothing).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultConfig {
    /// Seed of the deterministic schedule.
    pub seed: u64,
    /// Flip one bit of one byte per faulted read/write call.
    pub wire_corrupt_ppm: u32,
    /// Dribble: serve the faulted read/write one byte at a time.
    pub wire_stall_ppm: u32,
    /// Inject a sticky `ConnectionReset` (the stream is dead afterwards).
    pub wire_reset_ppm: u32,
    /// Panic a shard emission round (or a `FaultyBackend` call).
    pub shard_panic_ppm: u32,
    /// Sleep `slow_ms` before a shard emission round executes.
    pub shard_slow_ppm: u32,
    pub slow_ms: u64,
    /// Sleep `delay_ms` between execution and response routing.
    pub delay_ppm: u32,
    pub delay_ms: u64,
    /// Drop an accepted connection before the hello exchange.
    pub accept_drop_ppm: u32,
    /// Test hook for the double-fault path: make shard *recovery* fail
    /// too, so the request is answered `ERR_UNAVAILABLE` instead of
    /// re-executed (DESIGN.md §11).
    pub recover_panic_ppm: u32,
}

impl FaultConfig {
    /// Server-side fault mix at an aggregate rate: shard panics at the
    /// full rate, slow shards and delayed completions at half, accept
    /// drops at a quarter. The shape the chaos bench sweep uses.
    pub fn server_chaos(seed: u64, rate_ppm: u32) -> FaultConfig {
        FaultConfig {
            seed,
            shard_panic_ppm: rate_ppm,
            shard_slow_ppm: rate_ppm / 2,
            slow_ms: 2,
            delay_ppm: rate_ppm / 2,
            delay_ms: 1,
            accept_drop_ppm: rate_ppm / 4,
            ..FaultConfig::default()
        }
    }

    /// Wire-level fault mix: corruption, stalls and resets all at
    /// `rate_ppm`. Used by the fuzz schedules and saboteur connections.
    pub fn wire_chaos(seed: u64, rate_ppm: u32) -> FaultConfig {
        FaultConfig {
            seed,
            wire_corrupt_ppm: rate_ppm,
            wire_stall_ppm: rate_ppm,
            wire_reset_ppm: rate_ppm,
            ..FaultConfig::default()
        }
    }

    /// Does any rate inject at all?
    pub fn is_active(&self) -> bool {
        self.wire_corrupt_ppm > 0
            || self.wire_stall_ppm > 0
            || self.wire_reset_ppm > 0
            || self.shard_panic_ppm > 0
            || self.shard_slow_ppm > 0
            || self.delay_ppm > 0
            || self.accept_drop_ppm > 0
            || self.recover_panic_ppm > 0
    }
}

/// Injection sites, one deterministic counter each.
#[derive(Clone, Copy)]
enum Site {
    WireCorrupt = 0,
    WireStall,
    WireReset,
    ShardPanic,
    ShardSlow,
    Delay,
    AcceptDrop,
    RecoverPanic,
}

/// Number of injection sites (length of [`SITE_NAMES`] and of the
/// per-site counter arrays).
pub const SITE_COUNT: usize = 8;

/// Site names in discriminant order — the observability layer exports
/// fired-fault counts as `faults.<site name>`.
pub const SITE_NAMES: [&str; SITE_COUNT] = [
    "wire_corrupt",
    "wire_stall",
    "wire_reset",
    "shard_panic",
    "shard_slow",
    "delay",
    "accept_drop",
    "recover_panic",
];

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The shared decision engine: one per server / pool / stream family.
/// Cheap enough to consult on every I/O call (one relaxed fetch_add and
/// a hash when the site's rate is non-zero; a load-free early-out when
/// it is zero).
pub struct FaultInjector {
    cfg: FaultConfig,
    counters: [AtomicU64; SITE_COUNT],
    /// Decisions that actually fired, per site — the injector's own
    /// observation channel, exported as `faults.*` counters.
    fired: [AtomicU64; SITE_COUNT],
}

impl FaultInjector {
    pub fn new(cfg: FaultConfig) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            cfg,
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// The k-th decision at `site` faults iff
    /// `splitmix64(seed ⊕ splitmix64(site ≪ 32 ⊕ k)) mod 1e6 < ppm`.
    fn decide(&self, site: Site, ppm: u32) -> bool {
        if ppm == 0 {
            return false;
        }
        let k = self.counters[site as usize].fetch_add(1, Ordering::Relaxed);
        let h = splitmix64(self.cfg.seed ^ splitmix64(((site as u64 + 1) << 32) ^ k));
        let fire = h % 1_000_000 < ppm as u64;
        if fire {
            self.fired[site as usize].fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Faults actually injected so far, indexed like [`SITE_NAMES`].
    pub fn fired_counts(&self) -> [u64; SITE_COUNT] {
        std::array::from_fn(|i| self.fired[i].load(Ordering::Relaxed))
    }

    /// Derive a deterministic value from the seed and a caller salt
    /// (corruption positions, saboteur choices).
    pub fn derive(&self, salt: u64) -> u64 {
        splitmix64(self.cfg.seed ^ splitmix64(salt))
    }

    pub fn wire_corrupt(&self) -> bool {
        self.decide(Site::WireCorrupt, self.cfg.wire_corrupt_ppm)
    }

    pub fn wire_stall(&self) -> bool {
        self.decide(Site::WireStall, self.cfg.wire_stall_ppm)
    }

    pub fn wire_reset(&self) -> bool {
        self.decide(Site::WireReset, self.cfg.wire_reset_ppm)
    }

    pub fn shard_panic(&self) -> bool {
        self.decide(Site::ShardPanic, self.cfg.shard_panic_ppm)
    }

    pub fn shard_slow(&self) -> bool {
        self.decide(Site::ShardSlow, self.cfg.shard_slow_ppm)
    }

    pub fn delay_completion(&self) -> bool {
        self.decide(Site::Delay, self.cfg.delay_ppm)
    }

    pub fn accept_drop(&self) -> bool {
        self.decide(Site::AcceptDrop, self.cfg.accept_drop_ppm)
    }

    pub fn recover_panic(&self) -> bool {
        self.decide(Site::RecoverPanic, self.cfg.recover_panic_ppm)
    }

    pub fn slow_delay(&self) -> Duration {
        Duration::from_millis(self.cfg.slow_ms)
    }

    pub fn completion_delay(&self) -> Duration {
        Duration::from_millis(self.cfg.delay_ms)
    }
}

/// A `Read + Write` transport with scheduled wire faults: bit flips,
/// one-byte dribble stalls, and sticky connection resets. Wrap a
/// `TcpStream` (saboteur connections) or a `Cursor` (fuzz schedules).
pub struct ChaosStream<S> {
    inner: S,
    inj: Arc<FaultInjector>,
    /// Count of corrupted calls so far — the decoder must have rejected
    /// or errored on something if this is non-zero.
    corruptions: u64,
    /// Salt counter for deterministic corruption positions.
    events: u64,
    /// A reset fired; every subsequent call fails.
    reset: bool,
}

impl<S> ChaosStream<S> {
    pub fn new(inner: S, inj: Arc<FaultInjector>) -> ChaosStream<S> {
        ChaosStream { inner, inj, corruptions: 0, events: 0, reset: false }
    }

    /// How many read/write calls were corrupted so far.
    pub fn corruptions(&self) -> u64 {
        self.corruptions
    }

    /// Whether a sticky reset has fired.
    pub fn is_reset(&self) -> bool {
        self.reset
    }

    pub fn into_inner(self) -> S {
        self.inner
    }

    fn reset_err(&mut self) -> io::Error {
        self.reset = true;
        io::Error::new(io::ErrorKind::ConnectionReset, "injected connection reset")
    }

    /// Deterministic (position, xor-mask) for the next corruption.
    fn corruption(&mut self, len: usize) -> (usize, u8) {
        self.events += 1;
        let h = self.inj.derive(0xC0_44 ^ self.events);
        let pos = (h as usize) % len;
        let mask = 1u8 << ((h >> 32) % 8);
        (pos, mask)
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return self.inner.read(buf);
        }
        if self.reset || self.inj.wire_reset() {
            return Err(self.reset_err());
        }
        // Stall: dribble one byte per call — a short read every caller
        // must resume from (read_exact loops; a decoder that assumed one
        // read per frame would corrupt here).
        let take = if self.inj.wire_stall() { 1 } else { buf.len() };
        let n = self.inner.read(&mut buf[..take])?;
        if n > 0 && self.inj.wire_corrupt() {
            let (pos, mask) = self.corruption(n);
            buf[pos] ^= mask;
            self.corruptions += 1;
        }
        Ok(n)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        if self.reset || self.inj.wire_reset() {
            return Err(self.reset_err());
        }
        let take = if self.inj.wire_stall() { 1 } else { buf.len() };
        if self.inj.wire_corrupt() {
            let mut owned = buf[..take].to_vec();
            let (pos, mask) = self.corruption(owned.len());
            owned[pos] ^= mask;
            self.corruptions += 1;
            // A partial write of the corrupted prefix is fine: write_all
            // retries the (uncorrupted) tail, leaving exactly one flipped
            // bit on the wire.
            return self.inner.write(&owned);
        }
        self.inner.write(&buf[..take])
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.reset {
            return Err(self.reset_err());
        }
        self.inner.flush()
    }
}

/// A [`Backend`] decorator injecting engine-seam faults: panics and slow
/// calls before delegation, delayed completions after. With an all-zero
/// config it is a transparent pass-through (bit-identical by the seam
/// contract — asserted in `tests/serve_faults.rs`).
pub struct FaultyBackend {
    inner: Arc<dyn Backend>,
    inj: Arc<FaultInjector>,
}

impl FaultyBackend {
    pub fn new(inner: Arc<dyn Backend>, inj: Arc<FaultInjector>) -> FaultyBackend {
        FaultyBackend { inner, inj }
    }

    fn before(&self) {
        if self.inj.shard_slow() {
            std::thread::sleep(self.inj.slow_delay());
        }
        if self.inj.shard_panic() {
            panic!("injected backend fault");
        }
    }

    fn after(&self) {
        if self.inj.delay_completion() {
            std::thread::sleep(self.inj.completion_delay());
        }
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn mul_batch(&self, design: MulDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        self.before();
        self.inner.mul_batch(design, bits, a, b, out);
        self.after();
    }

    fn div_batch(&self, design: DivDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        self.before();
        self.inner.div_batch(design, bits, a, b, out);
        self.after();
    }

    fn mul_real_batch(
        &self,
        design: MulDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        self.before();
        self.inner.mul_real_batch(design, bits, a, b, out);
        self.after();
    }

    fn div_real_batch(
        &self,
        design: DivDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        self.before();
        self.inner.div_real_batch(design, bits, a, b, out);
        self.after();
    }

    fn execute_stream(&self, reqs: &[Request], out: &mut Vec<u64>) {
        self.before();
        self.inner.execute_stream(reqs, out);
        self.after();
    }
}

/// Keep the default panic hook from spamming stderr with *injected*
/// panics ("injected" in the payload) during chaos runs; every other
/// panic still reaches the previous hook. Installed once per process —
/// safe to call repeatedly and from concurrent tests.
pub fn silence_injected_panics() {
    use std::sync::OnceLock;
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let injected = payload
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected"))
                .or_else(|| payload.downcast_ref::<String>().map(|s| s.contains("injected")))
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn default_config_injects_nothing() {
        let inj = FaultInjector::new(FaultConfig::default());
        assert!(!inj.config().is_active());
        for _ in 0..1000 {
            assert!(!inj.wire_corrupt());
            assert!(!inj.shard_panic());
            assert!(!inj.accept_drop());
        }
    }

    #[test]
    fn schedule_is_deterministic_and_rate_shaped() {
        let cfg = FaultConfig { seed: 42, shard_panic_ppm: 100_000, ..FaultConfig::default() };
        let a = FaultInjector::new(cfg);
        let b = FaultInjector::new(cfg);
        let fire_a: Vec<bool> = (0..10_000).map(|_| a.shard_panic()).collect();
        let fire_b: Vec<bool> = (0..10_000).map(|_| b.shard_panic()).collect();
        assert_eq!(fire_a, fire_b, "same seed → same schedule");
        let hits = fire_a.iter().filter(|&&f| f).count();
        // 10% nominal over 10k decisions; 3σ ≈ ±90.
        assert!((700..=1300).contains(&hits), "hit rate {hits}/10000 off nominal");
        let other_seed = FaultInjector::new(FaultConfig { seed: 43, ..cfg });
        let fire_c: Vec<bool> = (0..10_000).map(|_| other_seed.shard_panic()).collect();
        assert_ne!(fire_a, fire_c, "different seed → different schedule");
    }

    #[test]
    fn fired_counts_track_injections_per_site() {
        let cfg = FaultConfig { seed: 42, shard_panic_ppm: 100_000, ..FaultConfig::default() };
        let inj = FaultInjector::new(cfg);
        let hits = (0..10_000).filter(|_| inj.shard_panic()).count() as u64;
        assert!(hits > 0);
        let counts = inj.fired_counts();
        let site = SITE_NAMES.iter().position(|&n| n == "shard_panic").unwrap();
        assert_eq!(counts[site], hits);
        assert_eq!(counts.iter().sum::<u64>(), hits, "no other site fired");
    }

    #[test]
    fn sites_are_independent() {
        let cfg = FaultConfig {
            seed: 7,
            shard_panic_ppm: 1_000_000,
            shard_slow_ppm: 0,
            ..FaultConfig::default()
        };
        let inj = FaultInjector::new(cfg);
        for _ in 0..100 {
            assert!(inj.shard_panic());
            assert!(!inj.shard_slow(), "zero-rate site must never fire");
        }
    }

    #[test]
    fn chaos_stream_passthrough_when_inactive() {
        let inj = FaultInjector::new(FaultConfig::default());
        let data = b"hello chaos".to_vec();
        let mut cs = ChaosStream::new(Cursor::new(data.clone()), inj);
        let mut out = Vec::new();
        cs.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        assert_eq!(cs.corruptions(), 0);
        assert!(!cs.is_reset());
    }

    #[test]
    fn chaos_stream_stall_dribbles_but_preserves_bytes() {
        let cfg = FaultConfig { seed: 9, wire_stall_ppm: 1_000_000, ..FaultConfig::default() };
        let inj = FaultInjector::new(cfg);
        let data: Vec<u8> = (0..=255).collect();
        let mut cs = ChaosStream::new(Cursor::new(data.clone()), inj);
        let mut out = vec![0u8; data.len()];
        cs.read_exact(&mut out).unwrap();
        assert_eq!(out, data, "stalls must never change content");
    }

    #[test]
    fn chaos_stream_corruption_flips_exactly_one_bit_per_event() {
        let cfg = FaultConfig { seed: 11, wire_corrupt_ppm: 1_000_000, ..FaultConfig::default() };
        let inj = FaultInjector::new(cfg);
        let data = vec![0u8; 64];
        let mut cs = ChaosStream::new(Cursor::new(data), inj);
        let mut out = vec![0u8; 64];
        cs.read_exact(&mut out).unwrap();
        assert!(cs.corruptions() >= 1);
        let flipped: u32 = out.iter().map(|b| b.count_ones()).sum();
        assert_eq!(flipped as u64, cs.corruptions(), "one bit per corrupted read");
    }

    #[test]
    fn chaos_stream_reset_is_sticky() {
        let cfg = FaultConfig { seed: 13, wire_reset_ppm: 1_000_000, ..FaultConfig::default() };
        let inj = FaultInjector::new(cfg);
        let mut cs = ChaosStream::new(Cursor::new(vec![1u8, 2, 3]), inj);
        let mut buf = [0u8; 1];
        let e = cs.read(&mut buf).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::ConnectionReset);
        assert!(cs.is_reset());
        assert!(cs.read(&mut buf).is_err(), "reset streams stay dead");
        assert!(cs.write(&[0]).is_err());
    }

    #[test]
    fn faulty_backend_is_transparent_when_inactive() {
        use crate::engine::{Backend, Batched};
        let inj = FaultInjector::new(FaultConfig::default());
        let fb = FaultyBackend::new(Arc::new(Batched::new()), inj);
        let inner = Batched::new();
        let a: Vec<u64> = (1..=64).collect();
        let b: Vec<u64> = (1..=64).rev().collect();
        let (mut got, mut want) = (Vec::new(), Vec::new());
        fb.mul_batch(MulDesign::Simdive { w: 8 }, 8, &a, &b, &mut got);
        inner.mul_batch(MulDesign::Simdive { w: 8 }, 8, &a, &b, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn faulty_backend_panics_on_schedule() {
        use crate::engine::Batched;
        silence_injected_panics();
        let cfg = FaultConfig { seed: 3, shard_panic_ppm: 1_000_000, ..FaultConfig::default() };
        let fb = FaultyBackend::new(Arc::new(Batched::new()), FaultInjector::new(cfg));
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = Vec::new();
            fb.mul_batch(MulDesign::Accurate, 8, &[1], &[2], &mut out);
        }));
        assert!(caught.is_err(), "100% panic rate must panic");
    }
}
