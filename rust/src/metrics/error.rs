//! ARE / PRE / NED evaluators over the design registry.
//!
//! Error convention (paper §4.1): behavioral models are compared in the
//! reals — `|accurate − approx| / accurate` — over uniformly distributed
//! random operands (10^6 for SISD). NED is the mean error distance divided
//! by the maximum error distance observed.
//!
//! Evaluation goes through the batched engine seam (DESIGN.md §10):
//! operands are drawn in chunks and evaluated with one
//! [`Engine::mul_real_into`]/[`Engine::div_real_into`] call per chunk, so
//! SIMDive's correction tables are resolved once per chunk instead of
//! once per sample. The draw order and accumulation order are identical
//! to the historical per-element loop, so every statistic is
//! bit-for-bit unchanged.

use crate::arith::{DivDesign, MulDesign};
use crate::engine::Engine;
use crate::util::Rng;

/// Error statistics for one design.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorReport {
    /// Average absolute relative error, percent.
    pub are_pct: f64,
    /// Peak absolute relative error, percent.
    pub pre_pct: f64,
    /// Normalized error distance (mean |ED| / max |ED| over the sample).
    pub ned: f64,
}

/// Operand pairs evaluated per engine call.
const CHUNK: usize = 8192;

/// Streaming ARE/PRE/NED accumulator (one `add` per accepted sample, in
/// draw order — float summation order matches the pre-engine loop).
#[derive(Default)]
struct ErrAcc {
    sum_rel: f64,
    peak_rel: f64,
    sum_ed: f64,
    max_ed: f64,
}

impl ErrAcc {
    #[inline]
    fn add(&mut self, exact: f64, approx: f64) {
        let ed = (exact - approx).abs();
        let rel = ed / exact;
        self.sum_rel += rel;
        self.peak_rel = self.peak_rel.max(rel);
        self.sum_ed += ed;
        self.max_ed = self.max_ed.max(ed);
    }

    fn report(&self, samples: u64) -> ErrorReport {
        ErrorReport {
            are_pct: self.sum_rel / samples as f64 * 100.0,
            pre_pct: self.peak_rel * 100.0,
            ned: if self.max_ed == 0.0 {
                0.0
            } else {
                self.sum_ed / samples as f64 / self.max_ed
            },
        }
    }
}

/// Evaluate a multiplier over `samples` uniform non-zero pairs at `bits`.
pub fn mul_error(design: MulDesign, bits: u32, samples: u64, seed: u64) -> ErrorReport {
    let engine = Engine::from_mul(design);
    let mut rng = Rng::new(seed);
    let mut acc = ErrAcc::default();
    let mut a: Vec<u64> = Vec::with_capacity(CHUNK);
    let mut b: Vec<u64> = Vec::with_capacity(CHUNK);
    let mut approx: Vec<f64> = Vec::new();
    let mut done = 0u64;
    while done < samples {
        let n = ((samples - done) as usize).min(CHUNK);
        a.clear();
        b.clear();
        for _ in 0..n {
            a.push(rng.operand(bits));
            b.push(rng.operand(bits));
        }
        engine.mul_real_into(bits, &a, &b, &mut approx);
        for ((&x, &y), &ap) in a.iter().zip(b.iter()).zip(approx.iter()) {
            acc.add((x as f64) * (y as f64), ap);
        }
        done += n as u64;
    }
    acc.report(samples)
}

/// Evaluate a divider over the paper's 16/8-style scenario: `bits`-wide
/// dividend, `divisor_bits`-wide divisor, quotient ≥ 1 (a ≥ b).
pub fn div_error(
    design: DivDesign,
    bits: u32,
    divisor_bits: u32,
    samples: u64,
    seed: u64,
) -> ErrorReport {
    let engine = Engine::batched(MulDesign::Accurate, design);
    let mut rng = Rng::new(seed);
    let mut acc = ErrAcc::default();
    let mut a: Vec<u64> = Vec::with_capacity(CHUNK);
    let mut b: Vec<u64> = Vec::with_capacity(CHUNK);
    let mut approx: Vec<f64> = Vec::new();
    let mut done = 0u64;
    while done < samples {
        // Draw a chunk, keeping only a ≥ b pairs (the quotient ≥ 1 use
        // case) in draw order — the accepted sequence is identical to the
        // historical rejection loop's.
        a.clear();
        b.clear();
        while a.len() < CHUNK && done + (a.len() as u64) < samples {
            let x = rng.operand(bits);
            let y = rng.operand(divisor_bits);
            if x >= y {
                a.push(x);
                b.push(y);
            }
        }
        engine.div_real_into(bits, &a, &b, &mut approx);
        for ((&x, &y), &ap) in a.iter().zip(b.iter()).zip(approx.iter()) {
            acc.add(x as f64 / y as f64, ap);
        }
        done += a.len() as u64;
    }
    acc.report(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accurate_designs_have_zero_error() {
        let m = mul_error(MulDesign::Accurate, 16, 50_000, 1);
        assert_eq!(m.are_pct, 0.0);
        assert_eq!(m.pre_pct, 0.0);
        assert_eq!(m.ned, 0.0);
        let d = div_error(DivDesign::Accurate, 16, 8, 50_000, 1);
        assert_eq!(d.are_pct, 0.0);
    }

    #[test]
    fn table2_mul_error_ordering() {
        // Paper Table 2 ordering: Proposed (0.82) < Trunc15x7 (1.19) <
        // Trunc7x7 (2.35) < MBM (2.63) < Mitchell (3.85); CA lowest (0.3).
        let n = 300_000;
        let are = |d: MulDesign| mul_error(d, 16, n, 7).are_pct;
        let proposed = are(MulDesign::Simdive { w: 8 });
        let mbm = are(MulDesign::Mbm);
        let mitchell = are(MulDesign::Mitchell);
        let ca = are(MulDesign::Ca);
        assert!(proposed < mbm, "proposed {proposed} !< mbm {mbm}");
        assert!(mbm < mitchell, "mbm {mbm} !< mitchell {mitchell}");
        assert!(ca < proposed, "ca {ca} !< proposed {proposed}");
        assert!(proposed < 1.1, "proposed ARE {proposed}");
        assert!(mitchell > 3.0 && mitchell < 4.6, "mitchell ARE {mitchell}");
    }

    #[test]
    fn table2_div_error_ordering() {
        // Paper: Proposed (0.77) < INZeD (2.93) < Mitchell (4.11);
        // AAXD(12/6) = 0.74, AAXD(8/4) = 2.99.
        let n = 300_000;
        let are = |d: DivDesign| div_error(d, 16, 8, n, 7).are_pct;
        let proposed = are(DivDesign::Simdive { w: 8 });
        let inzed = are(DivDesign::Inzed);
        let mitchell = are(DivDesign::Mitchell);
        let aaxd126 = are(DivDesign::Aaxd { m: 12, n: 6 });
        let aaxd84 = are(DivDesign::Aaxd { m: 8, n: 4 });
        assert!(proposed < inzed, "proposed {proposed} !< inzed {inzed}");
        assert!(inzed < mitchell, "inzed {inzed} !< mitchell {mitchell}");
        assert!(aaxd126 < aaxd84, "aaxd 12/6 {aaxd126} !< 8/4 {aaxd84}");
        assert!(proposed < 1.3, "proposed div ARE {proposed}");
        assert!(mitchell > 3.0 && mitchell < 5.0, "mitchell div ARE {mitchell}");
    }

    #[test]
    fn simdive_peak_error_is_lowest_among_log_designs() {
        // "lowest peak error among approximate designs (up to 20×)".
        let n = 300_000;
        let pre = |d: MulDesign| mul_error(d, 16, n, 9).pre_pct;
        let proposed = pre(MulDesign::Simdive { w: 8 });
        let mitchell = pre(MulDesign::Mitchell);
        let mbm = pre(MulDesign::Mbm);
        assert!(proposed < mitchell && proposed < mbm,
            "proposed {proposed} vs mitchell {mitchell}, mbm {mbm}");
        // Paper: 4.9 vs 11.11 (Mitchell) and 8.81 (MBM).
        assert!(proposed < 6.5, "proposed PRE {proposed}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = mul_error(MulDesign::Mitchell, 16, 10_000, 3);
        let b = mul_error(MulDesign::Mitchell, 16, 10_000, 3);
        assert_eq!(a.are_pct, b.are_pct);
        assert_eq!(a.ned, b.ned);
    }

    #[test]
    fn chunked_sweep_matches_per_element_loop() {
        // The engine-routed sweep must reproduce the historical
        // per-element rejection loop bit-for-bit (same draws, same
        // accumulation order). Re-derive both statistics the slow way and
        // compare exactly.
        let (bits, divisor_bits, samples, seed) = (16u32, 8u32, 20_000u64, 5u64);
        let design = DivDesign::Simdive { w: 8 };
        let mut rng = Rng::new(seed);
        let mut acc = ErrAcc::default();
        let mut n = 0u64;
        while n < samples {
            let a = rng.operand(bits);
            let b = rng.operand(divisor_bits);
            if a < b {
                continue;
            }
            acc.add(a as f64 / b as f64, design.div_real(bits, a, b));
            n += 1;
        }
        let slow = acc.report(samples);
        let fast = div_error(design, bits, divisor_bits, samples, seed);
        assert_eq!(slow.are_pct, fast.are_pct);
        assert_eq!(slow.pre_pct, fast.pre_pct);
        assert_eq!(slow.ned, fast.ned);

        let mdesign = MulDesign::Simdive { w: 8 };
        let mut rng = Rng::new(seed);
        let mut acc = ErrAcc::default();
        for _ in 0..samples {
            let a = rng.operand(bits);
            let b = rng.operand(bits);
            acc.add((a as f64) * (b as f64), mdesign.mul_real(bits, a, b));
        }
        let slow = acc.report(samples);
        let fast = mul_error(mdesign, bits, samples, seed);
        assert_eq!(slow.are_pct, fast.are_pct);
        assert_eq!(slow.pre_pct, fast.pre_pct);
        assert_eq!(slow.ned, fast.ned);
    }
}
