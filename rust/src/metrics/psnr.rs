//! PSNR for the image-processing experiments (Figs. 3–4).

/// Peak signal-to-noise ratio between two same-sized 8-bit images, dB.
/// Identical images return +inf.
pub fn psnr(reference: &[u8], test: &[u8]) -> f64 {
    assert_eq!(reference.len(), test.len());
    assert!(!reference.is_empty());
    let mse: f64 = reference
        .iter()
        .zip(test)
        .map(|(&r, &t)| {
            let d = r as f64 - t as f64;
            d * d
        })
        .sum::<f64>()
        / reference.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_images_infinite() {
        let img = vec![42u8; 100];
        assert!(psnr(&img, &img).is_infinite());
    }

    #[test]
    fn one_lsb_error_everywhere_is_48db() {
        let a = vec![100u8; 1000];
        let b = vec![101u8; 1000];
        let p = psnr(&a, &b);
        assert!((p - 48.13).abs() < 0.01, "{p}");
    }

    #[test]
    fn larger_error_lower_psnr() {
        let a = vec![100u8; 1000];
        let b = vec![110u8; 1000];
        let c = vec![150u8; 1000];
        assert!(psnr(&a, &b) > psnr(&a, &c));
    }
}
