//! Quality metrics and evaluators for the paper's tables and figures:
//! ARE / PRE (average / peak absolute relative error), NED (normalized
//! error distance), the cost function CF = Area·Energy·Delay/(1−NED) [3],
//! and PSNR for the image applications.

pub mod error;
pub mod psnr;

pub use error::{div_error, mul_error, ErrorReport};
pub use psnr::psnr;

/// The paper's cost function [3]: `Area × Energy × Delay / (1 − NED)`,
/// normalized by the caller against the accurate design's value.
pub fn cost_function(area_luts: f64, energy_pj: f64, delay_ns: f64, ned: f64) -> f64 {
    area_luts * energy_pj * delay_ns / (1.0 - ned).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cf_monotone_in_each_factor() {
        let base = cost_function(100.0, 200.0, 5.0, 0.1);
        assert!(cost_function(110.0, 200.0, 5.0, 0.1) > base);
        assert!(cost_function(100.0, 220.0, 5.0, 0.1) > base);
        assert!(cost_function(100.0, 200.0, 5.5, 0.1) > base);
        assert!(cost_function(100.0, 200.0, 5.0, 0.2) > base);
    }

    #[test]
    fn cf_accurate_design_has_zero_ned() {
        let acc = cost_function(287.0, 306.0, 6.4, 0.0);
        assert!((acc - 287.0 * 306.0 * 6.4).abs() < 1e-9);
    }
}
