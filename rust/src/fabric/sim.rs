//! Bit-parallel functional simulation of a [`Netlist`].
//!
//! Each net carries a `u64` word of 64 independent test vectors, so an
//! exhaustive 8-bit-operand sweep (65 536 vectors) takes 1 024 evaluation
//! passes. Cells were created in topological order by the builder, so one
//! linear pass per word suffices (asserted in `Simulator::new`).

use super::netlist::{Cell, Net, Netlist};

/// Prepared simulator for a netlist.
pub struct Simulator<'a> {
    nl: &'a Netlist,
}

impl<'a> Simulator<'a> {
    pub fn new(nl: &'a Netlist) -> Self {
        // Sanity: builder order must be topological (every cell input is a
        // constant, a primary input, or an earlier cell output).
        #[cfg(debug_assertions)]
        {
            let mut defined = vec![false; nl.net_count()];
            defined[0] = true;
            defined[1] = true;
            for b in &nl.inputs {
                for &n in &b.nets {
                    defined[n as usize] = true;
                }
            }
            for cell in &nl.cells {
                let check = |n: Net, defined: &Vec<bool>| {
                    debug_assert!(defined[n as usize], "net {n} used before defined");
                };
                match cell {
                    Cell::Lut { inputs, out, .. } => {
                        inputs.iter().for_each(|&n| check(n, &defined));
                        defined[*out as usize] = true;
                    }
                    Cell::Lut52 { inputs, out5, out6, .. } => {
                        inputs.iter().for_each(|&n| check(n, &defined));
                        defined[*out5 as usize] = true;
                        defined[*out6 as usize] = true;
                    }
                    Cell::Carry4 { s, di, cin, o, co } => {
                        s.iter().chain(di.iter()).for_each(|&n| check(n, &defined));
                        check(*cin, &defined);
                        for k in 0..4 {
                            defined[o[k] as usize] = true;
                            defined[co[k] as usize] = true;
                        }
                    }
                }
            }
        }
        Simulator { nl }
    }

    /// Evaluate one word of 64 vectors. `set` assigns each input bus a
    /// slice of per-bit words (bus bit `i` ← `set[bus][i]`). Returns the
    /// full net-value array (indexable by `Net`).
    pub fn eval_word(&self, set: &[(&str, Vec<u64>)]) -> Vec<u64> {
        let nl = self.nl;
        let mut v = vec![0u64; nl.net_count()];
        v[1] = u64::MAX;
        for bus in &nl.inputs {
            let assigned = set
                .iter()
                .find(|(n, _)| *n == bus.name)
                .unwrap_or_else(|| panic!("missing input bus {}", bus.name));
            assert_eq!(assigned.1.len(), bus.nets.len(), "bus {} width", bus.name);
            for (i, &n) in bus.nets.iter().enumerate() {
                v[n as usize] = assigned.1[i];
            }
        }
        for cell in &nl.cells {
            match cell {
                Cell::Lut { inputs, truth, out } => {
                    v[*out as usize] = eval_lut(*truth, inputs, &v);
                }
                Cell::Lut52 { inputs, truth5, truth6, out5, out6 } => {
                    let lo = &inputs[..inputs.len().min(5)];
                    v[*out5 as usize] = eval_lut(*truth5 as u64, lo, &v);
                    v[*out6 as usize] = eval_lut(*truth6, inputs, &v);
                }
                Cell::Carry4 { s, di, cin, o, co } => {
                    let mut c = v[*cin as usize];
                    for k in 0..4 {
                        let sk = v[s[k] as usize];
                        let dk = v[di[k] as usize];
                        v[o[k] as usize] = sk ^ c;
                        c = (sk & c) | (!sk & dk);
                        v[co[k] as usize] = c;
                    }
                }
            }
        }
        v
    }

    /// Evaluate a single vector: inputs as `(bus name, value)`; returns
    /// each output bus as `(name, value)`.
    pub fn run_single(&self, ins: &[(&str, u64)]) -> Vec<(String, u64)> {
        let set: Vec<(&str, Vec<u64>)> = self
            .nl
            .inputs
            .iter()
            .map(|bus| {
                let val = ins
                    .iter()
                    .find(|(n, _)| *n == bus.name)
                    .unwrap_or_else(|| panic!("missing input {}", bus.name))
                    .1;
                let words: Vec<u64> = (0..bus.nets.len())
                    .map(|i| if (val >> i) & 1 == 1 { u64::MAX } else { 0 })
                    .collect();
                (bus.name.as_str(), words)
            })
            .collect();
        let v = self.eval_word(&set);
        self.read_outputs(&v, 0)
    }

    /// Evaluate a batch of vectors (any count), packing 64 per word pass.
    /// `ins[bus]` is a slice of per-vector values. Returns, per output bus,
    /// a vector of per-vector values.
    pub fn run_batch(&self, ins: &[(&str, &[u64])]) -> Vec<(String, Vec<u64>)> {
        let count = ins.first().map(|(_, v)| v.len()).unwrap_or(0);
        for (name, v) in ins {
            assert_eq!(v.len(), count, "input {name} length mismatch");
        }
        let mut outs: Vec<(String, Vec<u64>)> = self
            .nl
            .outputs
            .iter()
            .map(|b| (b.name.clone(), Vec::with_capacity(count)))
            .collect();
        let mut base = 0;
        while base < count {
            let lanes = (count - base).min(64);
            let set: Vec<(&str, Vec<u64>)> = self
                .nl
                .inputs
                .iter()
                .map(|bus| {
                    let vals = ins
                        .iter()
                        .find(|(n, _)| *n == bus.name)
                        .unwrap_or_else(|| panic!("missing input {}", bus.name))
                        .1;
                    let words: Vec<u64> = (0..bus.nets.len())
                        .map(|bit| {
                            let mut w = 0u64;
                            for lane in 0..lanes {
                                w |= ((vals[base + lane] >> bit) & 1) << lane;
                            }
                            w
                        })
                        .collect();
                    (bus.name.as_str(), words)
                })
                .collect();
            let v = self.eval_word(&set);
            for (oi, bus) in self.nl.outputs.iter().enumerate() {
                for lane in 0..lanes {
                    let mut val = 0u64;
                    for (bit, &n) in bus.nets.iter().enumerate() {
                        val |= ((v[n as usize] >> lane) & 1) << bit;
                    }
                    outs[oi].1.push(val);
                }
            }
            base += lanes;
        }
        outs
    }

    fn read_outputs(&self, v: &[u64], lane: u32) -> Vec<(String, u64)> {
        self.nl
            .outputs
            .iter()
            .map(|bus| {
                let mut val = 0u64;
                for (bit, &n) in bus.nets.iter().enumerate() {
                    val |= ((v[n as usize] >> lane) & 1) << bit;
                }
                (bus.name.clone(), val)
            })
            .collect()
    }
}

/// Shannon-fold a LUT truth table over word-parallel input values.
#[inline]
fn eval_lut(truth: u64, inputs: &[Net], v: &[u64]) -> u64 {
    let k = inputs.len();
    debug_assert!(k <= 6);
    // table[j] = word-value of truth entry j, folded input by input.
    let mut table = [0u64; 64];
    let entries = 1usize << k;
    for (j, t) in table.iter_mut().enumerate().take(entries) {
        *t = if (truth >> j) & 1 == 1 { u64::MAX } else { 0 };
    }
    let mut len = entries;
    for &inp in inputs {
        let x = v[inp as usize];
        len /= 2;
        for j in 0..len {
            table[j] = (table[2 * j] & !x) | (table[2 * j + 1] & x);
        }
    }
    table[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::netlist::NET0;

    #[test]
    fn batch_matches_single() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let b = nl.input("b", 8);
        let (s, co) = nl.adder(&a, &b, NET0);
        let mut out = s;
        out.push(co);
        nl.output("sum", &out);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(5);
        let avals: Vec<u64> = (0..1000).map(|_| rng.below(256)).collect();
        let bvals: Vec<u64> = (0..1000).map(|_| rng.below(256)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..1000 {
            assert_eq!(outs[0].1[i], avals[i] + bvals[i]);
            let single = sim.run_single(&[("a", avals[i]), ("b", bvals[i])]);
            assert_eq!(single[0].1, avals[i] + bvals[i]);
        }
    }

    #[test]
    fn non_multiple_of_64_batch() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 4);
        let n = nl.lut(&a, |m| m == 0xF);
        nl.output("and", &[n]);
        let sim = Simulator::new(&nl);
        let vals: Vec<u64> = (0..67).map(|i| i % 16).collect();
        let outs = sim.run_batch(&[("a", &vals)]);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(outs[0].1[i], u64::from(v == 15), "i={i}");
        }
    }
}
