//! Static timing analysis with calibrated Virtex-7 primitive delays.
//!
//! Arrival times propagate through the netlist in one topological pass:
//!
//! * LUT output    = max(input arrivals) + `t_lut` + `t_net`
//!   (`t_net` is the average general-routing hop that follows a LUT);
//! * CARRY4 `O_i`  = max(S_i arrival, chain carry arrival) + `t_carry_out`;
//! * CARRY4 `CO_i` = max(S_i/DI_i, carry in) + `t_carry_bit`
//!   (dedicated CO→CIN routing has no `t_net`).
//!
//! Critical path = max arrival over primary-output nets.
//!
//! ## Calibration
//! The constants are fitted once against the two *accurate baselines* the
//! paper reports from Vivado on the VC707 (Table 2): the soft multiplier IP
//! (287 LUT, 6.4 ns) and divider IP (168 LUT, 21.4 ns). Everything else the
//! model produces is a prediction. Defaults below are standard Virtex-7
//! data-sheet magnitudes (LUT ≈ 0.12 ns, net ≈ 0.6 ns, carry ≈ 30 ps/bit).

use super::netlist::{Cell, Net, Netlist};

/// Calibrated primitive delays (ns) and power coefficients.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// LUT logic delay (ns).
    pub t_lut: f64,
    /// Average general-routing delay after a LUT output (ns).
    pub t_net: f64,
    /// Carry propagation per bit inside/between CARRY4 (ns).
    pub t_carry_bit: f64,
    /// S/DI entry into the chain and O exit mux (ns).
    pub t_carry_out: f64,
    /// Dynamic power coefficient: mW per (toggle/vector · net).
    pub p_dyn_coeff: f64,
    /// Static + clocking power per LUT (mW).
    pub p_static_lut: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            t_lut: 0.12,
            t_net: 0.55,
            t_carry_bit: 0.035,
            t_carry_out: 0.10,
            p_dyn_coeff: 0.040,
            p_static_lut: 0.045,
        }
    }
}

/// Timing result for one design.
#[derive(Clone, Copy, Debug, Default)]
pub struct TimingReport {
    /// Critical-path delay (ns).
    pub critical_ns: f64,
    /// Logic levels (LUT hops) on the critical path.
    pub levels: u32,
}

/// Per-net arrival times, logic levels, and critical-predecessor links —
/// the full propagation state behind [`analyze`], shared with the
/// critical-path extraction in [`crate::fabric::analyze::cones`].
#[derive(Clone, Debug)]
pub struct Arrivals {
    /// Arrival time per net (ns); inputs and constants arrive at 0.
    pub t: Vec<f64>,
    /// Logic level (LUT hops) per net.
    pub lvl: Vec<u32>,
    /// For each cell-driven net: the input net whose arrival set its
    /// time, and the driving cell's index. `None` for inputs/constants.
    pub pred: Vec<Option<(Net, usize)>>,
}

/// Propagate arrival times through the netlist in one topological pass,
/// recording per-net predecessors. The arithmetic is identical to what
/// [`analyze`] reports (which is now a thin wrapper over this).
pub fn arrivals(nl: &Netlist, cal: &Calibration) -> Arrivals {
    let n = nl.net_count();
    let mut t = vec![0.0f64; n];
    let mut lvl = vec![0u32; n];
    let mut pred: Vec<Option<(Net, usize)>> = vec![None; n];
    for (ci, cell) in nl.cells.iter().enumerate() {
        match cell {
            Cell::Lut { inputs, out, .. } => {
                let (a, l, p) = worst_input(&t, &lvl, inputs);
                t[*out as usize] = a + cal.t_lut + cal.t_net;
                lvl[*out as usize] = l + 1;
                pred[*out as usize] = p.map(|p| (p, ci));
            }
            Cell::Lut52 { inputs, out5, out6, .. } => {
                let (a, l, p) = worst_input(&t, &lvl, inputs);
                for o in [*out5, *out6] {
                    t[o as usize] = a + cal.t_lut + cal.t_net;
                    lvl[o as usize] = l + 1;
                    pred[o as usize] = p.map(|p| (p, ci));
                }
            }
            Cell::Carry4 { s, di, cin, o, co } => {
                let mut carry_t = t[*cin as usize];
                let mut carry_l = lvl[*cin as usize];
                // The net the chain's current worst arrival came through.
                let mut carry_p = *cin;
                for k in 0..4 {
                    let (sd, sdp) = if t[s[k] as usize] >= t[di[k] as usize] {
                        (t[s[k] as usize], s[k])
                    } else {
                        (t[di[k] as usize], di[k])
                    };
                    let sl = lvl[s[k] as usize].max(lvl[di[k] as usize]);
                    // CO_k: worst of incoming carry and this bit's S/DI.
                    if sd > carry_t {
                        carry_t = sd;
                        carry_p = sdp;
                    }
                    carry_t += cal.t_carry_bit;
                    carry_l = carry_l.max(sl);
                    t[co[k] as usize] = carry_t;
                    lvl[co[k] as usize] = carry_l;
                    pred[co[k] as usize] = Some((carry_p, ci));
                    // O_k = S_k ⊕ C_k through the XOR mux.
                    let entry = carry_t - cal.t_carry_bit;
                    if t[s[k] as usize] >= entry {
                        t[o[k] as usize] = t[s[k] as usize] + cal.t_carry_out;
                        pred[o[k] as usize] = Some((s[k], ci));
                    } else {
                        t[o[k] as usize] = entry + cal.t_carry_out;
                        pred[o[k] as usize] = Some((carry_p, ci));
                    }
                    lvl[o[k] as usize] = carry_l;
                    carry_p = co[k];
                }
            }
        }
    }
    Arrivals { t, lvl, pred }
}

/// Worst (arrival, level) over a LUT's inputs plus the argmax net.
fn worst_input(t: &[f64], lvl: &[u32], inputs: &[Net]) -> (f64, u32, Option<Net>) {
    let (mut a, mut l, mut p) = (0.0f64, 0u32, None);
    for &i in inputs {
        if p.is_none() || t[i as usize] > a {
            a = t[i as usize];
            p = Some(i);
        }
        l = l.max(lvl[i as usize]);
    }
    (a, l, p)
}

/// Propagate arrival times and return the critical path.
pub fn analyze(nl: &Netlist, cal: &Calibration) -> TimingReport {
    let ar = arrivals(nl, cal);
    let mut rep = TimingReport::default();
    for bus in &nl.outputs {
        for &n in &bus.nets {
            if ar.t[n as usize] > rep.critical_ns {
                rep.critical_ns = ar.t[n as usize];
                rep.levels = ar.lvl[n as usize];
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::netlist::{Netlist, NET0};

    fn cal() -> Calibration {
        Calibration::default()
    }

    #[test]
    fn single_lut_delay() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 2);
        let x = nl.xor2(a[0], a[1]);
        nl.output("x", &[x]);
        let r = analyze(&nl, &cal());
        assert!((r.critical_ns - (cal().t_lut + cal().t_net)).abs() < 1e-12);
        assert_eq!(r.levels, 1);
    }

    #[test]
    fn chain_depth_accumulates() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 1);
        let mut x = a[0];
        for _ in 0..5 {
            x = nl.not(x);
        }
        nl.output("x", &[x]);
        let r = analyze(&nl, &cal());
        assert_eq!(r.levels, 5);
        assert!((r.critical_ns - 5.0 * (cal().t_lut + cal().t_net)).abs() < 1e-9);
    }

    #[test]
    fn adder_carry_is_fast() {
        // A 32-bit adder must be far faster than 32 LUT levels: the carry
        // chain contributes ~t_carry_bit per bit, not t_lut + t_net.
        let mut nl = Netlist::new();
        let a = nl.input("a", 32);
        let b = nl.input("b", 32);
        let (s, co) = nl.adder(&a, &b, NET0);
        let mut out = s;
        out.push(co);
        nl.output("s", &out);
        let r = analyze(&nl, &cal());
        let lut_level = cal().t_lut + cal().t_net;
        assert!(r.critical_ns < lut_level + 33.0 * cal().t_carry_bit + cal().t_carry_out + 0.01,
            "32-bit add too slow: {} ns", r.critical_ns);
        assert!(r.critical_ns > lut_level, "must include the propagate LUT");
    }

    #[test]
    fn wider_adder_is_slower() {
        let delay = |w: u32| {
            let mut nl = Netlist::new();
            let a = nl.input("a", w);
            let b = nl.input("b", w);
            let (s, _) = nl.adder(&a, &b, NET0);
            nl.output("s", &s);
            analyze(&nl, &cal()).critical_ns
        };
        assert!(delay(8) < delay(16));
        assert!(delay(16) < delay(32));
    }
}
