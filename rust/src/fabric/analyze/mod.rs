//! Multi-pass static analysis over [`crate::fabric::Netlist`]
//! (DESIGN.md §14) — the soundness gate under every area/timing/power
//! number the fabric reports, and the substrate for the ROADMAP-4
//! transformation passes (which will rerun these passes as their
//! no-regression gate).
//!
//! * [`lint`] — structural lint: undriven/multiply-driven nets,
//!   topological-order violations, truth-table/arity mismatches, CARRY4
//!   chain breaks, dead cells, const-foldable LUTs. Structured
//!   [`Diagnostic`]s with an error/warning severity split.
//! * [`cones`] — per-output-bit logic depth + transitive-fanin cone
//!   size, fanout histogram.
//! * [`critical_path`] — the worst cell chain itself, reproducing
//!   `timing::analyze` delay/levels exactly.
//!
//! Entry points: `simdive netlist-check` (CLI, via [`crate::report::fabric`]),
//! [`debug_validate`] (debug-build hooks in every circuit generator), and
//! `tests/netlist_lint.rs` (per-defect-class proof netlists).

pub mod cones;
pub mod lint;

pub use cones::{
    cones, critical_path, fanout, ConeReport, CriticalPath, FanoutStats, OutputCone, PathStep,
};
pub use lint::{lint, Defect, Diagnostic, LintReport, Severity};

use crate::fabric::Netlist;

/// Debug-build validation hook for the circuit generators: panic with the
/// rendered diagnostics if the netlist has any lint *error*. Warnings
/// (dead cells, foldable LUTs) are expected on some real designs and do
/// not fire this. Called under `#[cfg(debug_assertions)]` from every
/// `circuits::{simdive, mitchell, baselines}` constructor, so each test
/// that builds a design lints it for free.
pub fn debug_validate(nl: &Netlist, name: &str) {
    let report = lint(nl);
    if !report.is_sound() {
        panic!(
            "netlist '{name}' failed structural lint ({} errors):\n{}",
            report.error_count(),
            report.render_errors()
        );
    }
}
