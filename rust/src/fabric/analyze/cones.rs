//! Analysis passes: per-output-bit logic depth and transitive-fanin cone
//! size, fanout statistics, and critical-path extraction (DESIGN.md §14).
//!
//! These passes assume a structurally sound netlist (no out-of-range net
//! references) — run [`super::lint`] first on untrusted input. Depth and
//! arrival numbers come from [`crate::fabric::timing::arrivals`], so the
//! extracted critical path reproduces `timing::analyze` exactly (pinned
//! by `tests/netlist_lint.rs`).

use crate::fabric::netlist::{Cell, Net, Netlist};
use crate::fabric::timing::{self, Calibration};
use std::collections::BTreeMap;

/// Depth and transitive-fanin cone of one primary-output bit.
#[derive(Clone, Debug)]
pub struct OutputCone {
    /// Output bus name.
    pub bus: String,
    /// Bit index within the bus (LSB = 0).
    pub bit: usize,
    /// The net driving this output bit.
    pub net: Net,
    /// Logic depth in LUT levels (carry-chain hops do not add levels,
    /// matching `timing::analyze`).
    pub depth: u32,
    /// LUT6/LUT6_2 cells in the transitive fanin cone.
    pub cone_luts: u32,
    /// CARRY4 cells in the transitive fanin cone.
    pub cone_carry4: u32,
}

/// Cone/depth analysis over every primary-output bit.
#[derive(Clone, Debug, Default)]
pub struct ConeReport {
    pub per_bit: Vec<OutputCone>,
    pub max_depth: u32,
    pub max_cone_luts: u32,
    pub max_cone_carry4: u32,
}

/// Compute logic depth and transitive-fanin cone per output bit.
pub fn cones(nl: &Netlist) -> ConeReport {
    let n = nl.net_count();
    let lvl = timing::arrivals(nl, &Calibration::default()).lvl;
    let mut driver_of: Vec<Option<usize>> = vec![None; n];
    for (ci, cell) in nl.cells.iter().enumerate() {
        for net in cell.drives() {
            driver_of[net as usize] = Some(ci);
        }
    }
    // Stamped visited sets so the per-bit walks share one allocation.
    let mut net_stamp = vec![u32::MAX; n];
    let mut cell_stamp = vec![u32::MAX; nl.cells.len()];
    let mut report = ConeReport::default();
    let mut stamp = 0u32;
    let mut stack: Vec<Net> = Vec::new();
    for bus in &nl.outputs {
        for (bit, &net) in bus.nets.iter().enumerate() {
            let (mut luts, mut carry4) = (0u32, 0u32);
            stack.clear();
            stack.push(net);
            while let Some(cur) = stack.pop() {
                if net_stamp[cur as usize] == stamp {
                    continue;
                }
                net_stamp[cur as usize] = stamp;
                let Some(ci) = driver_of[cur as usize] else { continue };
                if cell_stamp[ci] != stamp {
                    cell_stamp[ci] = stamp;
                    match &nl.cells[ci] {
                        Cell::Lut { .. } | Cell::Lut52 { .. } => luts += 1,
                        Cell::Carry4 { .. } => carry4 += 1,
                    }
                    stack.extend(nl.cells[ci].reads());
                }
            }
            let cone = OutputCone {
                bus: bus.name.clone(),
                bit,
                net,
                depth: lvl[net as usize],
                cone_luts: luts,
                cone_carry4: carry4,
            };
            report.max_depth = report.max_depth.max(cone.depth);
            report.max_cone_luts = report.max_cone_luts.max(cone.cone_luts);
            report.max_cone_carry4 = report.max_cone_carry4.max(cone.cone_carry4);
            report.per_bit.push(cone);
            stamp += 1;
        }
    }
    report
}

/// Fanout statistics over every driven net (constants excluded — their
/// fanout is unbounded by construction and says nothing about routing).
#[derive(Clone, Debug, Default)]
pub struct FanoutStats {
    /// Highest fanout observed.
    pub max: u32,
    /// A net achieving `max`.
    pub max_net: Net,
    /// Mean fanout over all counted nets.
    pub mean: f64,
    /// `(fanout, number of nets with that fanout)`, ascending.
    pub histogram: Vec<(u32, u32)>,
}

/// Count readers (cell input pins + primary-output bus positions) per
/// input/cell-driven net and summarize the distribution.
pub fn fanout(nl: &Netlist) -> FanoutStats {
    let n = nl.net_count();
    let mut readers = vec![0u32; n];
    for cell in &nl.cells {
        for net in cell.reads() {
            readers[net as usize] += 1;
        }
    }
    for bus in &nl.outputs {
        for &net in &bus.nets {
            readers[net as usize] += 1;
        }
    }
    let mut counted = vec![false; n];
    for bus in &nl.inputs {
        for &net in &bus.nets {
            counted[net as usize] = true;
        }
    }
    for cell in &nl.cells {
        for net in cell.drives() {
            counted[net as usize] = true;
        }
    }
    let mut stats = FanoutStats::default();
    let mut hist: BTreeMap<u32, u32> = BTreeMap::new();
    let (mut total, mut nets) = (0u64, 0u64);
    for net in 0..n as u32 {
        if !counted[net as usize] {
            continue;
        }
        let f = readers[net as usize];
        *hist.entry(f).or_insert(0) += 1;
        total += u64::from(f);
        nets += 1;
        if f > stats.max {
            stats.max = f;
            stats.max_net = net;
        }
    }
    stats.mean = if nets == 0 { 0.0 } else { total as f64 / nets as f64 };
    stats.histogram = hist.into_iter().collect();
    stats
}

/// One cell on the extracted critical path.
#[derive(Clone, Debug)]
pub struct PathStep {
    /// Index into `Netlist::cells`.
    pub cell: usize,
    /// Primitive kind ("LUT6" / "LUT6_2" / "CARRY4").
    pub kind: &'static str,
    /// The cell output net the path leaves through.
    pub via: Net,
    /// Arrival time at `via` (ns).
    pub arrival_ns: f64,
}

/// The actual worst cell chain, not just its delay.
#[derive(Clone, Debug, Default)]
pub struct CriticalPath {
    /// Worst arrival over the primary outputs — identical to
    /// `timing::analyze(..).critical_ns`.
    pub critical_ns: f64,
    /// LUT levels on the path — identical to `timing::analyze(..).levels`.
    pub levels: u32,
    /// Output bus / bit the path ends on.
    pub endpoint_bus: String,
    pub endpoint_bit: usize,
    /// The input or constant net the path starts from.
    pub start_net: Net,
    /// Cells from startpoint to endpoint; consecutive hops inside one
    /// CARRY4 block are collapsed into a single step.
    pub steps: Vec<PathStep>,
}

/// Extract the critical path by walking the per-net predecessor links
/// recorded by [`timing::arrivals`] back from the worst output bit.
pub fn critical_path(nl: &Netlist, cal: &Calibration) -> CriticalPath {
    let ar = timing::arrivals(nl, cal);
    let mut endpoint: Option<(usize, usize, Net)> = None;
    let mut best = 0.0f64;
    for (bi, bus) in nl.outputs.iter().enumerate() {
        for (bit, &net) in bus.nets.iter().enumerate() {
            if ar.t[net as usize] > best || endpoint.is_none() {
                best = ar.t[net as usize];
                endpoint = Some((bi, bit, net));
            }
        }
    }
    let Some((bi, bit, net)) = endpoint else {
        return CriticalPath::default();
    };
    let mut path = CriticalPath {
        critical_ns: ar.t[net as usize],
        levels: ar.lvl[net as usize],
        endpoint_bus: nl.outputs[bi].name.clone(),
        endpoint_bit: bit,
        start_net: net,
        steps: Vec::new(),
    };
    let mut cur = net;
    while let Some((pnet, ci)) = ar.pred[cur as usize] {
        if path.steps.last().map(|s| s.cell) != Some(ci) {
            path.steps.push(PathStep {
                cell: ci,
                kind: nl.cells[ci].kind(),
                via: cur,
                arrival_ns: ar.t[cur as usize],
            });
        }
        cur = pnet;
    }
    path.steps.reverse();
    path.start_net = cur;
    path
}
