//! Structural lint passes over a [`Netlist`] (DESIGN.md §14).
//!
//! Every pass returns structured [`Diagnostic`]s instead of panicking, so
//! the same code serves three callers: the `netlist-check` CLI (reports
//! and gates on errors), the debug-build validation hooks inside the
//! circuit generators, and the deliberately-broken netlists in
//! `tests/netlist_lint.rs`. All passes are bounds-safe — a netlist that
//! references net ids beyond [`Netlist::net_count`] produces
//! [`Defect::OutOfRangeNet`] diagnostics and the wild ids are skipped by
//! the later passes rather than indexing out of bounds.
//!
//! Severity split: *errors* are soundness violations no generator may
//! produce (the builder API upholds them by construction — the sweep in
//! `tests/netlist_lint.rs` proves it for every design at every width);
//! *warnings* are mapper-sweepable inefficiencies that do occur in real
//! designs (dead barrel-mux bits in AAXD's scale-back, the LOD's
//! fractured position LUT carrying a structurally unused input) and are
//! reported as counts without failing any gate.

use crate::fabric::netlist::{Cell, Net, Netlist, NET0, NET1};
use std::fmt;

/// Diagnostic severity. Errors gate `netlist-check` and panic the
/// debug-build validation hooks; warnings are informational.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

/// The defect classes the lint passes detect.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Defect {
    /// A cell or IO bus references a net id `>= net_count()`.
    OutOfRangeNet,
    /// A used net has no driver: not a constant, not a primary input,
    /// not any cell's output.
    UndrivenNet,
    /// A net with more than one driver (constants and primary inputs
    /// count as drivers).
    MultiplyDrivenNet,
    /// A cell reads a net whose driving cell appears later in the cell
    /// list — the builder's "topological order" guarantee, checked.
    TopoViolation,
    /// LUT arity outside 1..=6, or truth-table bits set beyond `2^arity`.
    BadTruthTable,
    /// A CARRY4 cascades from another block's CO[k] with k < 3 —
    /// mid-block taps have no dedicated CO→CIN route on the fabric.
    CarryChainBreak,
    /// Dead logic: a cell outside every primary output's cone of
    /// influence (a technology mapper would sweep it).
    UnreachableCell,
    /// A LUT a mapper could fold: constant truth table, an input the
    /// truth table does not depend on, or a constant-net input.
    ConstFoldable,
}

impl Defect {
    /// Severity class of this defect (see module docs for the split).
    pub fn severity(self) -> Severity {
        match self {
            Defect::UnreachableCell | Defect::ConstFoldable => Severity::Warning,
            _ => Severity::Error,
        }
    }

    /// Stable kebab-case slug used in rendered diagnostics.
    pub fn name(self) -> &'static str {
        match self {
            Defect::OutOfRangeNet => "out-of-range-net",
            Defect::UndrivenNet => "undriven-net",
            Defect::MultiplyDrivenNet => "multiply-driven-net",
            Defect::TopoViolation => "topo-violation",
            Defect::BadTruthTable => "bad-truth-table",
            Defect::CarryChainBreak => "carry-chain-break",
            Defect::UnreachableCell => "unreachable-cell",
            Defect::ConstFoldable => "const-foldable",
        }
    }
}

/// One structured lint finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub defect: Defect,
    /// Index into `Netlist::cells`, when the finding is about a cell.
    pub cell: Option<usize>,
    /// The net involved, when the finding is about a net.
    pub net: Option<Net>,
    pub message: String,
}

impl Diagnostic {
    pub fn severity(&self) -> Severity {
        self.defect.severity()
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity() {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{sev}[{}]: {}", self.defect.name(), self.message)
    }
}

/// The result of running every lint pass.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity() == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// No errors (warnings allowed) — the gate `netlist-check` applies.
    pub fn is_sound(&self) -> bool {
        self.error_count() == 0
    }

    pub fn count_of(&self, defect: Defect) -> usize {
        self.diagnostics.iter().filter(|d| d.defect == defect).count()
    }

    /// Render every error, one per line (empty string when sound).
    pub fn render_errors(&self) -> String {
        self.diagnostics
            .iter()
            .filter(|d| d.severity() == Severity::Error)
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Run every lint pass over the netlist.
pub fn lint(nl: &Netlist) -> LintReport {
    let n = nl.net_count();
    let in_range = |net: Net| (net as usize) < n;
    let mut diags = Vec::new();

    // Pass 1 — out-of-range references. Later passes skip wild ids, so a
    // corrupt netlist yields diagnostics instead of a panic.
    for (ci, cell) in nl.cells.iter().enumerate() {
        for net in cell.reads().into_iter().chain(cell.drives()) {
            if !in_range(net) {
                diags.push(Diagnostic {
                    defect: Defect::OutOfRangeNet,
                    cell: Some(ci),
                    net: Some(net),
                    message: format!(
                        "{} cell {ci} references net {net}, but only {n} nets exist",
                        cell.kind()
                    ),
                });
            }
        }
    }
    for bus in nl.inputs.iter().chain(nl.outputs.iter()) {
        for &net in &bus.nets {
            if !in_range(net) {
                diags.push(Diagnostic {
                    defect: Defect::OutOfRangeNet,
                    cell: None,
                    net: Some(net),
                    message: format!(
                        "IO bus '{}' references net {net}, but only {n} nets exist",
                        bus.name
                    ),
                });
            }
        }
    }

    // Driver census: constants and primary inputs are drivers, then every
    // cell output. `cell_driven` distinguishes topo violations (driven,
    // but later) from genuinely undriven nets.
    let mut driver_count = vec![0u32; n];
    let mut cell_driven = vec![false; n];
    if n > 0 {
        driver_count[NET0 as usize] = 1;
    }
    if n > 1 {
        driver_count[NET1 as usize] = 1;
    }
    for bus in &nl.inputs {
        for &net in &bus.nets {
            if in_range(net) {
                driver_count[net as usize] += 1;
            }
        }
    }
    for cell in &nl.cells {
        for net in cell.drives() {
            if in_range(net) {
                driver_count[net as usize] += 1;
                cell_driven[net as usize] = true;
            }
        }
    }

    // Pass 2 — multiply-driven nets.
    for net in 0..n as u32 {
        if driver_count[net as usize] > 1 {
            diags.push(Diagnostic {
                defect: Defect::MultiplyDrivenNet,
                cell: None,
                net: Some(net),
                message: format!(
                    "net {net} has {} drivers (constants and primary inputs count as one)",
                    driver_count[net as usize]
                ),
            });
        }
    }

    // Pass 3 — undriven-net use, reported once per net at its first use.
    let mut undriven_seen = vec![false; n];
    for (ci, cell) in nl.cells.iter().enumerate() {
        for net in cell.reads() {
            if in_range(net) && driver_count[net as usize] == 0 && !undriven_seen[net as usize] {
                undriven_seen[net as usize] = true;
                diags.push(Diagnostic {
                    defect: Defect::UndrivenNet,
                    cell: Some(ci),
                    net: Some(net),
                    message: format!(
                        "net {net}, read by {} cell {ci}, has no driver",
                        cell.kind()
                    ),
                });
            }
        }
    }
    for bus in &nl.outputs {
        for &net in &bus.nets {
            if in_range(net) && driver_count[net as usize] == 0 && !undriven_seen[net as usize] {
                undriven_seen[net as usize] = true;
                diags.push(Diagnostic {
                    defect: Defect::UndrivenNet,
                    cell: None,
                    net: Some(net),
                    message: format!("net {net}, on output bus '{}', has no driver", bus.name),
                });
            }
        }
    }

    // Pass 4 — topological order: a cell may only read nets defined by
    // constants, inputs, or *earlier* cells (the invariant `Simulator`'s
    // single linear pass relies on).
    let mut defined = vec![false; n];
    if n > 0 {
        defined[NET0 as usize] = true;
    }
    if n > 1 {
        defined[NET1 as usize] = true;
    }
    for bus in &nl.inputs {
        for &net in &bus.nets {
            if in_range(net) {
                defined[net as usize] = true;
            }
        }
    }
    for (ci, cell) in nl.cells.iter().enumerate() {
        for net in cell.reads() {
            if in_range(net) && !defined[net as usize] && cell_driven[net as usize] {
                diags.push(Diagnostic {
                    defect: Defect::TopoViolation,
                    cell: Some(ci),
                    net: Some(net),
                    message: format!(
                        "{} cell {ci} reads net {net} before its driving cell runs",
                        cell.kind()
                    ),
                });
            }
        }
        for net in cell.drives() {
            if in_range(net) {
                defined[net as usize] = true;
            }
        }
    }

    // Pass 5 — truth-table/arity consistency. Cells flagged here are
    // excluded from the const-foldable pass to avoid cascading noise.
    let mut bad_truth = vec![false; nl.cells.len()];
    for (ci, cell) in nl.cells.iter().enumerate() {
        let mut bad = |msg: String| {
            bad_truth[ci] = true;
            diags.push(Diagnostic {
                defect: Defect::BadTruthTable,
                cell: Some(ci),
                net: None,
                message: msg,
            });
        };
        match cell {
            Cell::Lut { inputs, truth, .. } => {
                let k = inputs.len();
                if k == 0 || k > 6 {
                    bad(format!("LUT6 cell {ci} has arity {k} (must be 1..=6)"));
                } else if k < 6 && (truth >> (1u64 << k)) != 0 {
                    bad(format!(
                        "LUT6 cell {ci} (arity {k}) has truth bits set beyond entry 2^{k}"
                    ));
                }
            }
            Cell::Lut52 { inputs, truth5, truth6, .. } => {
                let k = inputs.len();
                if k == 0 || k > 6 {
                    bad(format!("LUT6_2 cell {ci} has arity {k} (must be 1..=6)"));
                } else {
                    let k5 = k.min(5);
                    if k5 < 5 && (truth5 >> (1u32 << k5)) != 0 {
                        bad(format!(
                            "LUT6_2 cell {ci}: O5 truth has bits set beyond entry 2^{k5}"
                        ));
                    }
                    if k < 6 && (truth6 >> (1u64 << k)) != 0 {
                        bad(format!(
                            "LUT6_2 cell {ci}: O6 truth has bits set beyond entry 2^{k}"
                        ));
                    }
                }
            }
            Cell::Carry4 { .. } => {}
        }
    }

    // Pass 6 — CARRY4 chain continuity: a cascaded block must take its
    // CIN from a CO[3] (or a LUT/constant/input net); CO[0..3] taps have
    // no dedicated route to a CIN pin on the 7-series fabric.
    let mut co_pos: Vec<Option<(usize, usize)>> = vec![None; n];
    for (ci, cell) in nl.cells.iter().enumerate() {
        if let Cell::Carry4 { co, .. } = cell {
            for (k, &net) in co.iter().enumerate() {
                if in_range(net) {
                    co_pos[net as usize] = Some((ci, k));
                }
            }
        }
    }
    for (ci, cell) in nl.cells.iter().enumerate() {
        if let Cell::Carry4 { cin, .. } = cell {
            if in_range(*cin) {
                if let Some((src, k)) = co_pos[*cin as usize] {
                    if k < 3 {
                        diags.push(Diagnostic {
                            defect: Defect::CarryChainBreak,
                            cell: Some(ci),
                            net: Some(*cin),
                            message: format!(
                                "CARRY4 cell {ci} cascades from CO[{k}] of cell {src}; \
                                 blocks must chain from CO[3]"
                            ),
                        });
                    }
                }
            }
        }
    }

    // Pass 7 — cone of influence from the primary outputs, walked in
    // reverse topological order: a cell is live iff one of its outputs is
    // needed, and a live cell makes every net it reads needed.
    let mut needed = vec![false; n];
    for bus in &nl.outputs {
        for &net in &bus.nets {
            if in_range(net) {
                needed[net as usize] = true;
            }
        }
    }
    let mut live = vec![false; nl.cells.len()];
    for (ci, cell) in nl.cells.iter().enumerate().rev() {
        if cell.drives().into_iter().any(|net| in_range(net) && needed[net as usize]) {
            live[ci] = true;
            for net in cell.reads() {
                if in_range(net) {
                    needed[net as usize] = true;
                }
            }
        }
    }
    for (ci, cell) in nl.cells.iter().enumerate() {
        if !live[ci] {
            diags.push(Diagnostic {
                defect: Defect::UnreachableCell,
                cell: Some(ci),
                net: None,
                message: format!(
                    "{} cell {ci} is outside every primary output's cone (dead logic)",
                    cell.kind()
                ),
            });
        }
    }

    // Pass 8 — const-foldable LUTs. One diagnostic per cell, first reason
    // found. LUT6_2 cells legitimately keep constant-net inputs (ternary
    // adders over constant buses) and half-unused inputs (O5-only pins),
    // so only inputs unused by *both* halves and all-constant pairs are
    // flagged there.
    for (ci, cell) in nl.cells.iter().enumerate() {
        if bad_truth[ci] {
            continue;
        }
        match cell {
            Cell::Lut { inputs, truth, .. } => {
                let k = inputs.len();
                let reason = if *truth == 0 || *truth == full_mask(k) {
                    Some(format!("LUT6 cell {ci} computes a constant"))
                } else if let Some(i) = (0..k).find(|&i| truth_independent(*truth, k, i)) {
                    Some(format!(
                        "LUT6 cell {ci}: truth table is independent of input {i}"
                    ))
                } else {
                    inputs.iter().position(|&x| x == NET0 || x == NET1).map(|i| {
                        format!("LUT6 cell {ci}: input {i} is a constant net")
                    })
                };
                if let Some(message) = reason {
                    diags.push(Diagnostic {
                        defect: Defect::ConstFoldable,
                        cell: Some(ci),
                        net: None,
                        message,
                    });
                }
            }
            Cell::Lut52 { inputs, truth5, truth6, .. } => {
                let k = inputs.len();
                let k5 = k.min(5);
                let const5 = *truth5 == 0 || u64::from(*truth5) == full_mask(k5);
                let const6 = *truth6 == 0 || *truth6 == full_mask(k);
                let reason = if const5 && const6 {
                    Some(format!("LUT6_2 cell {ci} computes two constants"))
                } else {
                    (0..k)
                        .find(|&i| {
                            let unused6 = truth_independent(*truth6, k, i);
                            let unused5 =
                                i >= k5 || truth_independent(u64::from(*truth5), k5, i);
                            unused6 && unused5
                        })
                        .map(|i| {
                            format!("LUT6_2 cell {ci}: input {i} is unused by both O5 and O6")
                        })
                };
                if let Some(message) = reason {
                    diags.push(Diagnostic {
                        defect: Defect::ConstFoldable,
                        cell: Some(ci),
                        net: None,
                        message,
                    });
                }
            }
            Cell::Carry4 { .. } => {}
        }
    }

    LintReport { diagnostics: diags }
}

/// All-ones truth table over `2^arity` entries.
fn full_mask(arity: usize) -> u64 {
    if arity >= 6 {
        u64::MAX
    } else {
        (1u64 << (1usize << arity)) - 1
    }
}

/// True when the truth table over `arity` inputs does not depend on
/// input `i`.
fn truth_independent(truth: u64, arity: usize, i: usize) -> bool {
    for m in 0..(1u64 << arity) {
        if (truth >> m) & 1 != (truth >> (m ^ (1 << i))) & 1 {
            return false;
        }
    }
    true
}
