//! Toggle-based dynamic power estimation.
//!
//! The simulator packs 64 consecutive random input vectors into each net's
//! word, so `popcount(v ^ (v << 1))` counts that net's transitions over the
//! vector stream — the switching-activity measure Vivado's Power Analyzer
//! derives from simulation traces (§4.1 of the paper: power is reported
//! from Power Analyzer simulations over uniform random inputs).
//!
//! `P_total = p_dyn_coeff · (toggles per vector across all nets)
//!          + p_static_lut · LUTs`.

use super::netlist::{Cell, Netlist};
use super::sim::Simulator;
use super::timing::Calibration;
use crate::util::Rng;

/// Default number of random vectors for power estimation.
pub const DEFAULT_VECTORS: u32 = 4096;

/// Power figures for one design.
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerReport {
    /// Dynamic power (mW) at the calibrated activity coefficient.
    pub dynamic_mw: f64,
    /// Static + clock-tree power (mW).
    pub static_mw: f64,
    /// Total (mW).
    pub total_mw: f64,
    /// Mean toggles per input vector across all cell-output nets.
    pub toggles_per_vector: f64,
}

/// Estimate power over `vectors` uniform random input vectors.
/// `delay_ns` is the design's cycle time: dynamic power is switching
/// energy per operation divided by the operation period (a long-latency
/// design amortizes its toggles over more time), so
/// `P_dyn = p_dyn_coeff · toggles/vector / delay_ns`.
pub fn estimate_at(
    nl: &Netlist,
    cal: &Calibration,
    seed: u64,
    vectors: u32,
    delay_ns: f64,
) -> PowerReport {
    let sim = Simulator::new(nl);
    let mut rng = Rng::new(seed);
    let words = (vectors as usize).div_ceil(64);
    let mut toggles = 0u64;

    // Which nets are cell outputs (they carry the capacitive load that
    // matters; input nets toggle for free from the testbench).
    let mut is_out = vec![false; nl.net_count()];
    for c in &nl.cells {
        match c {
            Cell::Lut { out, .. } => is_out[*out as usize] = true,
            Cell::Lut52 { out5, out6, .. } => {
                is_out[*out5 as usize] = true;
                is_out[*out6 as usize] = true;
            }
            Cell::Carry4 { o, co, .. } => {
                for k in 0..4 {
                    is_out[o[k] as usize] = true;
                    is_out[co[k] as usize] = true;
                }
            }
        }
    }

    for _ in 0..words {
        // 64 random vectors: each input bit gets an independent random word
        // (bit t of the word = value at time-step t).
        let set: Vec<(&str, Vec<u64>)> = nl
            .inputs
            .iter()
            .map(|bus| {
                let words: Vec<u64> = bus.nets.iter().map(|_| rng.next_u64()).collect();
                (bus.name.as_str(), words)
            })
            .collect();
        let v = sim.eval_word(&set);
        for (n, &val) in v.iter().enumerate() {
            if is_out[n] {
                toggles += (val ^ (val << 1)).count_ones() as u64;
            }
        }
    }

    let per_vec = toggles as f64 / (words as f64 * 64.0);
    let luts = super::area::report(nl).luts as f64;
    let dynamic = cal.p_dyn_coeff * per_vec / delay_ns.max(1e-9);
    let stat = cal.p_static_lut * luts;
    PowerReport {
        dynamic_mw: dynamic,
        static_mw: stat,
        total_mw: dynamic + stat,
        toggles_per_vector: per_vec,
    }
}

/// Convenience: estimate with the design's own critical-path delay.
pub fn estimate(nl: &Netlist, cal: &Calibration, seed: u64, vectors: u32) -> PowerReport {
    let delay = super::timing::analyze(nl, cal).critical_ns;
    estimate_at(nl, cal, seed, vectors, delay)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::netlist::{Netlist, NET0};

    #[test]
    fn bigger_circuit_draws_more_power() {
        let power = |w: u32| {
            let mut nl = Netlist::new();
            let a = nl.input("a", w);
            let b = nl.input("b", w);
            let (s, _) = nl.adder(&a, &b, NET0);
            nl.output("s", &s);
            estimate(&nl, &Calibration::default(), 1, 2048).total_mw
        };
        assert!(power(8) < power(16));
        assert!(power(16) < power(32));
    }

    #[test]
    fn constant_circuit_has_no_dynamic_power() {
        let mut nl = Netlist::new();
        let _a = nl.input("a", 4);
        let c = nl.constant(4, 0b1010);
        nl.output("c", &c);
        let r = estimate(&nl, &Calibration::default(), 2, 1024);
        assert_eq!(r.toggles_per_vector, 0.0);
        assert_eq!(r.dynamic_mw, 0.0);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let b = nl.input("b", 8);
        let (s, _) = nl.adder(&a, &b, NET0);
        nl.output("s", &s);
        let r1 = estimate(&nl, &Calibration::default(), 7, 1024);
        let r2 = estimate(&nl, &Calibration::default(), 7, 1024);
        assert_eq!(r1.total_mw, r2.total_mw);
    }

    #[test]
    fn toggle_rate_is_plausible() {
        // An 8-bit adder's outputs toggle roughly half the time each.
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let b = nl.input("b", 8);
        let (s, _) = nl.adder(&a, &b, NET0);
        nl.output("s", &s);
        let r = estimate(&nl, &Calibration::default(), 3, 4096);
        // 8 sum outs + 8 propagate luts + carries ≈ 24 nets, ~0.5 each.
        assert!(r.toggles_per_vector > 5.0 && r.toggles_per_vector < 20.0,
            "toggles/vec {}", r.toggles_per_vector);
    }
}
