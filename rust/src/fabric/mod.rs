//! Simulated Xilinx Virtex-7 fabric — the substrate that replaces Vivado
//! 17.4 + the VC707 board in the paper's evaluation (DESIGN.md §1).
//!
//! A design is a structural [`netlist::Netlist`] of Virtex-7 primitives:
//! 6-input LUTs (optionally fractured into two 5-LUTs, as the paper's LOD
//! uses), CARRY4 carry-chain blocks, and constant/IO nets. On top of the
//! netlist the fabric provides:
//!
//! * [`sim`] — bit-parallel functional simulation (64 test vectors per
//!   pass), used to verify every gate-level design against its behavioral
//!   model and to drive the power model;
//! * [`area`] — LUT / carry / slice counting (the paper's "Area (6-LUT)"
//!   column);
//! * [`timing`] — static timing analysis with calibrated primitive delays
//!   (the "Delay (ns)" column);
//! * [`power`] — toggle-based dynamic power + per-LUT static leakage
//!   (the "Power (mW)" column), with energy = power × delay per op;
//! * [`analyze`] — multi-pass static analysis: structural lint
//!   (structured diagnostics), cone/depth/fanout analysis, and
//!   critical-path extraction (DESIGN.md §14).
//!
//! Calibration: the four timing/power constants are fitted once against
//! the paper's two accurate-IP baselines (Table 2); all approximate-design
//! rows are then *predictions* of this model. See `timing::Calibration`.

pub mod analyze;
pub mod area;
pub mod calibrate;
pub mod netlist;
pub mod power;
pub mod sim;
pub mod timing;

pub use area::AreaReport;
pub use netlist::{Net, Netlist};
pub use power::PowerReport;
pub use sim::Simulator;
pub use timing::{Calibration, TimingReport};

/// Full design metrics for one circuit, as reported in Tables 2–3.
#[derive(Clone, Debug)]
pub struct DesignMetrics {
    pub name: String,
    pub area: AreaReport,
    pub timing: TimingReport,
    pub power: PowerReport,
}

impl DesignMetrics {
    /// Energy per operation in picojoules: P(mW) × delay(ns) = pJ.
    pub fn energy_pj(&self) -> f64 {
        self.power.total_mw * self.timing.critical_ns
    }

    /// Characterize a netlist: area + timing + power in one pass.
    pub fn characterize(name: &str, nl: &Netlist, cal: &Calibration, seed: u64) -> Self {
        let area = area::report(nl);
        let timing = timing::analyze(nl, cal);
        let power = power::estimate(nl, cal, seed, power::DEFAULT_VECTORS);
        DesignMetrics { name: name.to_string(), area, timing, power }
    }
}
