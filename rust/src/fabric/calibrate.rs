//! Calibration fit (DESIGN.md §5): the timing and power coefficients are
//! fitted once, by least squares, against the paper's four *published
//! baseline rows* from Vivado on the VC707 — the accurate multiplier IP
//! (287 LUT, 6.4 ns, 47.8 mW), the accurate divider IP (168 LUT, 21.4 ns,
//! 24.6 mW), and Mitchell's multiplier (4.7 ns, 35.5 mW) and divider
//! (5.3 ns, 20.3 mW). Every *proposed/SoA-approximate* number the fabric
//! produces (SIMDive, MBM, INZeD, AAXD, truncated, CA) is then a
//! prediction of the calibrated model.
//!
//! Two coefficients cannot reproduce four Vivado numbers exactly — our
//! structural technology mapping is shallower than Vivado's on the
//! partial-product array and deeper on the mux-heavy logarithmic decode —
//! so the fit minimizes summed squared *relative* residuals; the residual
//! per target (±≈50%) is reported by the tests and EXPERIMENTS.md, and all
//! cross-design *orderings* are taken from the fitted model's predictions.

use super::netlist::Netlist;
use super::power;
use super::timing::{analyze, Calibration};
use std::sync::OnceLock;

/// Paper targets (Table 2): accurate IP rows + Mitchell rows.
pub const TARGET_MUL: (f64, f64, f64) = (287.0, 6.4, 47.8); // LUT, ns, mW
pub const TARGET_DIV: (f64, f64, f64) = (168.0, 21.4, 24.6);
pub const TARGET_MIT_MUL: (f64, f64) = (4.7, 35.5); // ns, mW
pub const TARGET_MIT_DIV: (f64, f64) = (5.3, 20.3);

fn delay_with(nl: &Netlist, u: f64, v: f64) -> f64 {
    let cal = Calibration {
        t_lut: 0.0,
        t_net: u,
        t_carry_bit: v,
        t_carry_out: 0.10,
        ..Calibration::default()
    };
    analyze(nl, &cal).critical_ns
}

/// Fit the calibration against the accurate multiplier/divider netlists.
pub fn fitted() -> &'static Calibration {
    static CACHE: OnceLock<Calibration> = OnceLock::new();
    CACHE.get_or_init(|| {
        let mul = crate::circuits::baselines::array_mul(16);
        let div = crate::circuits::baselines::restoring_div(16, 8);
        let mmul = crate::circuits::mitchell::mul(16);
        let mdiv = crate::circuits::mitchell::div(16, 8);

        // delay(u, v) = max over paths of (A_p·u + B_p·v + C_p): piecewise
        // linear in the unknowns, so a robust least-squares compromise is
        // found by nested grid search minimizing the summed squared
        // *relative* residuals against the four published targets.
        let score = |u: f64, v: f64| -> f64 {
            let r1 = (delay_with(&mul, u, v) - TARGET_MUL.1) / TARGET_MUL.1;
            let r2 = (delay_with(&div, u, v) - TARGET_DIV.1) / TARGET_DIV.1;
            let r3 = (delay_with(&mmul, u, v) - TARGET_MIT_MUL.0) / TARGET_MIT_MUL.0;
            let r4 = (delay_with(&mdiv, u, v) - TARGET_MIT_DIV.0) / TARGET_MIT_DIV.0;
            r1 * r1 + r2 * r2 + r3 * r3 + r4 * r4
        };
        let (mut u, mut v) = (0.4f64, 0.05f64);
        let (mut lo_u, mut hi_u, mut lo_v, mut hi_v) = (0.02f64, 1.5f64, 0.002f64, 0.3f64);
        for _ in 0..5 {
            let mut best = (f64::INFINITY, u, v);
            for i in 0..=24 {
                for j in 0..=24 {
                    let uu = lo_u + (hi_u - lo_u) * i as f64 / 24.0;
                    let vv = lo_v + (hi_v - lo_v) * j as f64 / 24.0;
                    let s = score(uu, vv);
                    if s < best.0 {
                        best = (s, uu, vv);
                    }
                }
            }
            u = best.1;
            v = best.2;
            let (su, sv) = ((hi_u - lo_u) / 8.0, (hi_v - lo_v) / 8.0);
            lo_u = (u - su).max(0.02);
            hi_u = u + su;
            lo_v = (v - sv).max(0.002);
            hi_v = v + sv;
        }

        // Power fit: P = cd·(toggles/delay) + cs·LUTs — switching energy
        // amortized over the operation period plus per-LUT static/clock
        // power. Linear 2×2 solve with a non-negative grid fallback.
        let base = Calibration {
            t_lut: 0.0,
            t_net: u,
            t_carry_bit: v,
            t_carry_out: 0.10,
            p_dyn_coeff: 1.0,
            p_static_lut: 0.0,
        };
        let observe = |nl: &Netlist| -> (f64, f64) {
            let d = analyze(nl, &base).critical_ns;
            let rate =
                power::estimate_at(nl, &base, 0xCA11B, 4096, 1.0).toggles_per_vector / d;
            (rate, super::area::report(nl).luts as f64)
        };
        let obs = [observe(&mul), observe(&div), observe(&mmul), observe(&mdiv)];
        let ptargets =
            [TARGET_MUL.2, TARGET_DIV.2, TARGET_MIT_MUL.1, TARGET_MIT_DIV.1];
        // Non-negative least squares via grid refinement over the four
        // published power targets.
        let pscore = |cd: f64, cs: f64| -> f64 {
            obs.iter()
                .zip(&ptargets)
                .map(|(&(rate, luts), &t)| {
                    let p = cd * rate + cs * luts;
                    ((p - t) / t).powi(2)
                })
                .sum()
        };
        let mut best = (f64::INFINITY, 0.1, 0.02);
        for i in 0..=60 {
            for j in 0..=60 {
                let ccd = i as f64 * 0.015;
                let ccs = j as f64 * 0.004;
                let sc = pscore(ccd, ccs);
                if sc < best.0 {
                    best = (sc, ccd, ccs);
                }
            }
        }
        let (cd, cs) = (best.1.max(1e-3), best.2.max(1e-4));

        Calibration {
            t_lut: 0.0, // folded into t_net by the fit
            t_net: u,
            t_carry_bit: v,
            t_carry_out: 0.10,
            p_dyn_coeff: cd,
            p_static_lut: cs,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::baselines::{array_mul, restoring_div};
    use crate::fabric::power::estimate;

    #[test]
    fn fit_reproduces_targets() {
        let cal = fitted();
        // The two-parameter model cannot hit all four Vivado targets
        // exactly (our structural mapping is shallower than Vivado on the
        // pp-array and deeper on the logarithmic decode); the LS fit lands
        // within roughly ±65% of each target. What must hold is the
        // qualitative shape: Mitchell's units faster than the accurate
        // multiplier, which is much faster than the accurate divider.
        let dm = analyze(&array_mul(16), cal).critical_ns;
        let dd = analyze(&restoring_div(16, 8), cal).critical_ns;
        let dmm = analyze(&crate::circuits::mitchell::mul(16), cal).critical_ns;
        let dmd = analyze(&crate::circuits::mitchell::div(16, 8), cal).critical_ns;
        assert!((dm - TARGET_MUL.1).abs() / TARGET_MUL.1 < 0.7, "mul delay {dm} vs 6.4");
        assert!((dd - TARGET_DIV.1).abs() / TARGET_DIV.1 < 0.7, "div delay {dd} vs 21.4");
        assert!(
            (dmm - TARGET_MIT_MUL.0).abs() / TARGET_MIT_MUL.0 < 1.2,
            "mitchell mul {dmm} vs 4.7"
        );
        assert!(
            (dmd - TARGET_MIT_DIV.0).abs() / TARGET_MIT_DIV.0 < 1.2,
            "mitchell div {dmd} vs 5.3"
        );
        assert!(dmd < dd, "mitchell div must beat the accurate divider");
        let pm = estimate(&array_mul(16), cal, 0xCA11B, 4096).total_mw;
        let pd = estimate(&restoring_div(16, 8), cal, 0xCA11B, 4096).total_mw;
        assert!((pm - TARGET_MUL.2).abs() / TARGET_MUL.2 < 0.6, "mul power {pm} vs 47.8");
        assert!((pd - TARGET_DIV.2).abs() / TARGET_DIV.2 < 0.6, "div power {pd} vs 24.6");
    }

    #[test]
    fn fitted_values_physical() {
        let cal = fitted();
        assert!(cal.t_net > 0.0 && cal.t_net < 3.0, "t_net {}", cal.t_net);
        assert!(cal.t_carry_bit > 0.0 && cal.t_carry_bit < 0.3);
        assert!(cal.p_dyn_coeff > 0.0 && cal.p_static_lut > 0.0);
    }
}
