//! Area model: LUT / carry / slice counting.
//!
//! The paper's "Area (6-LUT)" column counts LUT6 equivalents; a fractured
//! LUT6_2 is one LUT, and CARRY4 blocks are free (dedicated silicon next to
//! the LUTs) but are tracked for slice estimation — a 7-series slice holds
//! four LUT6 and one CARRY4.

use super::netlist::{Cell, Netlist};

/// Area figures for one design.
#[derive(Clone, Copy, Debug, Default)]
pub struct AreaReport {
    /// LUT6-equivalent count (the paper's area unit).
    pub luts: u32,
    /// CARRY4 block count.
    pub carry4: u32,
    /// Slice estimate: max(luts/4, carry4) rounded up.
    pub slices: u32,
}

/// Count primitives.
pub fn report(nl: &Netlist) -> AreaReport {
    let mut luts = 0u32;
    let mut carry4 = 0u32;
    for c in &nl.cells {
        match c {
            Cell::Lut { .. } | Cell::Lut52 { .. } => luts += 1,
            Cell::Carry4 { .. } => carry4 += 1,
        }
    }
    let slices = (luts.div_ceil(4)).max(carry4);
    AreaReport { luts, carry4, slices }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::netlist::{Netlist, NET0};

    #[test]
    fn adder_area_is_one_lut_per_bit() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 16);
        let b = nl.input("b", 16);
        let _ = nl.adder(&a, &b, NET0);
        let r = report(&nl);
        assert_eq!(r.luts, 16, "one propagate LUT per bit");
        assert_eq!(r.carry4, 4, "16 bits = 4 CARRY4");
        assert_eq!(r.slices, 4);
    }

    #[test]
    fn lut52_counts_once() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 4);
        let _ = nl.lut52(&a, |m| m == 0, |m| m == 1);
        assert_eq!(report(&nl).luts, 1);
    }

    #[test]
    fn ternary_adder_costs_one_extra_lut() {
        // Paper §3.3: ternary addition needs one more LUT than binary.
        let mut nl2 = Netlist::new();
        let a = nl2.input("a", 8);
        let b = nl2.input("b", 8);
        let _ = nl2.adder(&a, &b, NET0);
        let binary = report(&nl2).luts;

        let mut nl3 = Netlist::new();
        let a = nl3.input("a", 8);
        let b = nl3.input("b", 8);
        let c = nl3.input("c", 8);
        let _ = nl3.ternary_adder(&a, &b, &c);
        let ternary = report(&nl3).luts;
        assert_eq!(ternary, binary + 1, "paper §3.3: ternary = binary + 1 LUT");
    }
}
