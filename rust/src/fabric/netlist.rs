//! Structural netlist of Virtex-7 primitives + a builder API.
//!
//! Primitives modeled (7-series CLB, per the paper's Fig. 2(c) and the
//! Xilinx UNISIM library [35]):
//!
//! * `LUT6` — any boolean function of ≤ 6 inputs (truth table in a `u64`);
//! * `LUT6_2` — a LUT6 fractured into two functions of the same ≤ 5
//!   inputs (O5/O6 outputs), used by the paper's 4-bit LOD;
//! * `CARRY4` — four bits of the dedicated fast carry chain: per bit,
//!   `O_i = S_i ⊕ C_i` and `C_{i+1} = S_i ? C_i : DI_i`.
//!
//! Nets are dense `u32` ids; net 0 is constant-0, net 1 is constant-1.
//! Cells must be created in topological order (the builder API guarantees
//! this naturally), which keeps simulation and timing a single linear pass.

/// A net (wire) id.
pub type Net = u32;

/// Constant-zero net.
pub const NET0: Net = 0;
/// Constant-one net.
pub const NET1: Net = 1;

/// A fabric primitive.
#[derive(Clone, Debug)]
pub enum Cell {
    /// LUT6: `out = truth[ inputs as index ]` (input 0 is the LSB).
    Lut { inputs: Vec<Net>, truth: u64, out: Net },
    /// LUT6_2 fractured: `out6` over all ≤ 6 inputs, `out5` over the low 5.
    Lut52 { inputs: Vec<Net>, truth5: u32, truth6: u64, out5: Net, out6: Net },
    /// CARRY4: `s`/`di` per bit, `cin`; outputs `o` (sum) and `co` (carry).
    Carry4 { s: [Net; 4], di: [Net; 4], cin: Net, o: [Net; 4], co: [Net; 4] },
}

impl Cell {
    /// Primitive name as it would appear in an EDIF/UNISIM netlist.
    pub fn kind(&self) -> &'static str {
        match self {
            Cell::Lut { .. } => "LUT6",
            Cell::Lut52 { .. } => "LUT6_2",
            Cell::Carry4 { .. } => "CARRY4",
        }
    }

    /// Every net this cell reads (input pins, in pin order).
    pub fn reads(&self) -> Vec<Net> {
        match self {
            Cell::Lut { inputs, .. } | Cell::Lut52 { inputs, .. } => inputs.clone(),
            Cell::Carry4 { s, di, cin, .. } => {
                let mut r = Vec::with_capacity(9);
                r.extend_from_slice(s);
                r.extend_from_slice(di);
                r.push(*cin);
                r
            }
        }
    }

    /// Every net this cell drives (output pins).
    pub fn drives(&self) -> Vec<Net> {
        match self {
            Cell::Lut { out, .. } => vec![*out],
            Cell::Lut52 { out5, out6, .. } => vec![*out5, *out6],
            Cell::Carry4 { o, co, .. } => {
                let mut d = Vec::with_capacity(8);
                d.extend_from_slice(o);
                d.extend_from_slice(co);
                d
            }
        }
    }
}

/// A named bus of nets (LSB first).
#[derive(Clone, Debug)]
pub struct Bus {
    pub name: String,
    pub nets: Vec<Net>,
}

/// A structural netlist.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    next_net: Net,
    pub cells: Vec<Cell>,
    pub inputs: Vec<Bus>,
    pub outputs: Vec<Bus>,
}

impl Netlist {
    pub fn new() -> Self {
        // Reserve nets 0/1 for constants.
        Netlist { next_net: 2, cells: Vec::new(), inputs: Vec::new(), outputs: Vec::new() }
    }

    pub fn net_count(&self) -> usize {
        self.next_net as usize
    }

    fn fresh(&mut self) -> Net {
        let n = self.next_net;
        self.next_net += 1;
        n
    }

    /// Allocate a net with no driver. Only the analysis tests need this —
    /// the builder methods drive every net they hand out, and a fresh net
    /// left undriven is exactly what `analyze::lint` exists to flag.
    pub fn fresh_net(&mut self) -> Net {
        self.fresh()
    }

    /// Debug check: every referenced net must have been allocated already.
    /// Malformed netlists fail at the build site instead of deep inside
    /// `Simulator`/`timing` (release builds rely on `analyze::lint`).
    fn check_declared(&self, nets: &[Net], ctx: &str) {
        debug_assert!(
            nets.iter().all(|&n| n < self.next_net),
            "{ctx} references undeclared net {:?} (next_net = {})",
            nets.iter().find(|&&n| n >= self.next_net),
            self.next_net
        );
    }

    /// Declare a primary input bus of `width` nets (LSB first).
    pub fn input(&mut self, name: &str, width: u32) -> Vec<Net> {
        let nets: Vec<Net> = (0..width).map(|_| self.fresh()).collect();
        self.inputs.push(Bus { name: name.into(), nets: nets.clone() });
        nets
    }

    /// Declare a primary output bus.
    pub fn output(&mut self, name: &str, nets: &[Net]) {
        self.check_declared(nets, "output()");
        self.outputs.push(Bus { name: name.into(), nets: nets.to_vec() });
    }

    /// Generic LUT over `inputs` with a function on the input bits.
    /// The function receives the input assignment as a bit-mask (input `i`
    /// at bit `i`). Constant inputs are folded away (as any technology
    /// mapper would); an all-constant function returns a constant net.
    pub fn lut<F: Fn(u32) -> bool>(&mut self, inputs: &[Net], f: F) -> Net {
        assert!(!inputs.is_empty() && inputs.len() <= 6, "LUT arity {}", inputs.len());
        let mut truth = 0u64;
        for m in 0..(1u32 << inputs.len()) {
            if f(m) {
                truth |= 1 << m;
            }
        }
        self.lut_raw(inputs, truth)
    }

    /// LUT from a raw truth table (constant inputs folded).
    pub fn lut_raw(&mut self, inputs: &[Net], truth: u64) -> Net {
        self.check_declared(inputs, "lut()");
        let (inputs, truth) = fold_constants(inputs, truth);
        if inputs.is_empty() {
            return if truth & 1 == 1 { NET1 } else { NET0 };
        }
        // Wire-equivalent LUT (identity of one input) needs no cell.
        if inputs.len() == 1 {
            if truth == 0b10 {
                return inputs[0];
            }
            if truth == 0b00 {
                return NET0;
            }
            if truth == 0b11 {
                return NET1;
            }
        }
        let out = self.fresh();
        self.cells.push(Cell::Lut { inputs, truth, out });
        out
    }

    /// Fractured LUT6_2: one physical LUT producing two outputs — `O6` may
    /// use all ≤ 6 inputs, `O5` only the low ≤ 5 (7-series fracturing
    /// rule). Returns `(out5, out6)`.
    pub fn lut52<F5, F6>(&mut self, inputs: &[Net], f5: F5, f6: F6) -> (Net, Net)
    where
        F5: Fn(u32) -> bool,
        F6: Fn(u32) -> bool,
    {
        assert!(!inputs.is_empty() && inputs.len() <= 6, "LUT6_2 arity {}", inputs.len());
        self.check_declared(inputs, "lut52()");
        let arity5 = inputs.len().min(5);
        let mut t5 = 0u32;
        for m in 0..(1u32 << arity5) {
            if f5(m) {
                t5 |= 1 << m;
            }
        }
        let mut t6 = 0u64;
        for m in 0..(1u32 << inputs.len()) {
            if f6(m) {
                t6 |= 1 << m;
            }
        }
        // If either half degenerates to a constant/wire after folding, emit
        // the other half as a plain LUT (one physical LUT either way).
        let (in5, t5f) = fold_constants(&inputs[..inputs.len().min(5)], t5 as u64);
        let (in6, t6f) = fold_constants(inputs, t6);
        let trivial5 = in5.is_empty() || (in5.len() == 1 && matches!(t5f, 0 | 0b10 | 0b11));
        let trivial6 = in6.is_empty() || (in6.len() == 1 && matches!(t6f, 0 | 0b10 | 0b11));
        if trivial5 || trivial6 {
            let o5 = self.lut_raw(&inputs[..inputs.len().min(5)], t5 as u64);
            let o6 = self.lut_raw(inputs, t6);
            return (o5, o6);
        }
        let out5 = self.fresh();
        let out6 = self.fresh();
        self.cells.push(Cell::Lut52 {
            inputs: inputs.to_vec(),
            truth5: t5,
            truth6: t6,
            out5,
            out6,
        });
        (out5, out6)
    }

    /// One CARRY4 block. `s`/`di` are the per-bit select/data inputs.
    /// Returns `(o, co)`.
    pub fn carry4(&mut self, s: [Net; 4], di: [Net; 4], cin: Net) -> ([Net; 4], [Net; 4]) {
        self.check_declared(&s, "carry4() S");
        self.check_declared(&di, "carry4() DI");
        self.check_declared(&[cin], "carry4() CIN");
        let o = [self.fresh(), self.fresh(), self.fresh(), self.fresh()];
        let co = [self.fresh(), self.fresh(), self.fresh(), self.fresh()];
        self.cells.push(Cell::Carry4 { s, di, cin, o, co });
        (o, co)
    }

    // ---------- derived combinational helpers ----------

    pub fn not(&mut self, a: Net) -> Net {
        self.lut(&[a], |m| m & 1 == 0)
    }

    pub fn and2(&mut self, a: Net, b: Net) -> Net {
        self.lut(&[a, b], |m| m & 3 == 3)
    }

    pub fn or2(&mut self, a: Net, b: Net) -> Net {
        self.lut(&[a, b], |m| m & 3 != 0)
    }

    pub fn xor2(&mut self, a: Net, b: Net) -> Net {
        self.lut(&[a, b], |m| (m & 1) ^ ((m >> 1) & 1) == 1)
    }

    /// 2:1 mux: `sel ? hi : lo`.
    pub fn mux2(&mut self, sel: Net, lo: Net, hi: Net) -> Net {
        self.lut(&[lo, hi, sel], |m| {
            if m & 0b100 != 0 { m & 0b010 != 0 } else { m & 0b001 != 0 }
        })
    }

    /// Bus-wide 2:1 mux (pads the shorter bus with constant 0).
    pub fn mux2_bus(&mut self, sel: Net, lo: &[Net], hi: &[Net]) -> Vec<Net> {
        let w = lo.len().max(hi.len());
        (0..w)
            .map(|i| {
                let l = lo.get(i).copied().unwrap_or(NET0);
                let h = hi.get(i).copied().unwrap_or(NET0);
                self.mux2(sel, l, h)
            })
            .collect()
    }

    /// N-input OR tree (LUT6-packed).
    pub fn or_tree(&mut self, nets: &[Net]) -> Net {
        match nets.len() {
            0 => NET0,
            1 => nets[0],
            n if n <= 6 => self.lut(nets, |m| m != 0),
            _ => {
                let mid: Vec<Net> = nets.chunks(6).map(|c| self.lut(c, |m| m != 0)).collect();
                self.or_tree(&mid)
            }
        }
    }

    /// Ripple adder over the dedicated carry chain: `a + b + cin`.
    /// One LUT per bit computes the propagate `a⊕b` feeding CARRY4 `S`,
    /// with `DI = a` — the canonical 7-series adder mapping.
    /// Returns `(sum, carry_out)`.
    pub fn adder(&mut self, a: &[Net], b: &[Net], cin: Net) -> (Vec<Net>, Net) {
        let w = a.len().max(b.len());
        let mut s_nets = Vec::with_capacity(w);
        let mut d_nets = Vec::with_capacity(w);
        for i in 0..w {
            let ai = a.get(i).copied().unwrap_or(NET0);
            let bi = b.get(i).copied().unwrap_or(NET0);
            s_nets.push(self.xor2(ai, bi));
            d_nets.push(ai);
        }
        let (sum, co) = self.carry_chain(&s_nets, &d_nets, cin);
        (sum, co)
    }

    /// Subtractor `a - b + (cin ? 0 : -1)`… standard two's complement:
    /// computes `a + !b + cin` (pass `cin = NET1` for plain `a - b`).
    /// Returns `(diff, carry_out)`; `carry_out == 1` means no borrow.
    pub fn subtractor(&mut self, a: &[Net], b: &[Net], cin: Net) -> (Vec<Net>, Net) {
        let w = a.len().max(b.len());
        let mut s_nets = Vec::with_capacity(w);
        let mut d_nets = Vec::with_capacity(w);
        for i in 0..w {
            let ai = a.get(i).copied().unwrap_or(NET0);
            let bi = b.get(i).copied().unwrap_or(NET0);
            // propagate = a ⊕ !b
            s_nets.push(self.lut(&[ai, bi], |m| (m & 1) ^ (((m >> 1) & 1) ^ 1) == 1));
            d_nets.push(ai);
        }
        self.carry_chain(&s_nets, &d_nets, cin)
    }

    /// Raw carry chain over CARRY4 blocks from per-bit `S`/`DI`.
    pub fn carry_chain(&mut self, s: &[Net], di: &[Net], cin: Net) -> (Vec<Net>, Net) {
        assert_eq!(s.len(), di.len());
        let mut out = Vec::with_capacity(s.len());
        let mut carry = cin;
        for chunk in 0..s.len().div_ceil(4) {
            let base = chunk * 4;
            let mut s4 = [NET0; 4];
            let mut d4 = [NET0; 4];
            for k in 0..4 {
                if base + k < s.len() {
                    s4[k] = s[base + k];
                    d4[k] = di[base + k];
                } else {
                    // Pad: S=0 selects DI=0 → carry is killed beyond width…
                    // use S=0, DI=carry-preserving? Padding with S=1 keeps
                    // propagating the carry so `co[3]` of the last block is
                    // the true carry-out.
                    s4[k] = NET1;
                    d4[k] = NET0;
                }
            }
            let (o, co) = self.carry4(s4, d4, carry);
            for k in 0..4 {
                if base + k < s.len() {
                    out.push(o[k]);
                }
            }
            carry = co[3];
        }
        (out, carry)
    }

    /// Ternary adder `a + b + c` (see [`Netlist::ternary_adder_cin`]).
    pub fn ternary_adder(&mut self, a: &[Net], b: &[Net], c: &[Net]) -> Vec<Net> {
        self.ternary_adder_cin(a, b, c, NET0)
    }

    /// Ternary adder `a + b + c + cin` using the 7-series LUT6 +
    /// carry-chain mapping (paper §3.3): bit `i`'s LUT consumes
    /// `(a_i, b_i, c_i)` and the previous bit's triple to form the chain
    /// `S` input, with `DI` the previous majority — one LUT per bit plus
    /// one extra MSB LUT, exactly the "+1 LUT" cost the paper describes.
    /// The carry-in feeds the chain directly (free), which lets the
    /// subtract-form `a + ~b + c + 1` run in a single chain pass.
    pub fn ternary_adder_cin(&mut self, a: &[Net], b: &[Net], c: &[Net], cin: Net) -> Vec<Net> {
        self.ternary_core(a, b, c, cin, false)
    }

    /// Ternary subtract-form adder `a + ~b + c + cin` — operand `b` is
    /// complemented *inside* the compressor LUTs (free on the fabric, as
    /// any input inversion is absorbed by the LUT INIT). With `cin = 1`
    /// this computes `a - b + c` in a single carry-chain pass, which is
    /// how SIMDive's divider applies its (negative) correction with no
    /// extra delay (§3.3).
    pub fn ternary_subtract(&mut self, a: &[Net], b: &[Net], c: &[Net], cin: Net) -> Vec<Net> {
        self.ternary_core(a, b, c, cin, true)
    }

    fn ternary_core(
        &mut self,
        a: &[Net],
        b: &[Net],
        c: &[Net],
        cin: Net,
        invert_b: bool,
    ) -> Vec<Net> {
        let w = a.len().max(b.len()).max(c.len());
        let get = |v: &[Net], i: usize| v.get(i).copied().unwrap_or(NET0);
        // Carry-save compress: s_i = a⊕b⊕c, t_i = maj(a,b,c); then add
        // s + (t << 1) on the chain. One fractured LUT6_2 per bit:
        // O6 = s_i ⊕ t_{i-1} (all 6 inputs), O5 = t_{i-1} (low 3 inputs) —
        // the canonical 7-series ternary-adder mapping, N+1 LUTs total.
        let mut s_in = Vec::with_capacity(w + 1);
        let mut di = Vec::with_capacity(w + 1);
        for i in 0..=w {
            let cur = [get(a, i), get(b, i), get(c, i)];
            let prev = if i == 0 {
                [NET0, NET0, NET0]
            } else {
                [get(a, i - 1), get(b, i - 1), get(c, i - 1)]
            };
            // prev triple on the low inputs so O5 (maj of prev) is legal.
            // Input order per triple: (a, b, c); bit 1 of each triple is
            // the (possibly inverted) b operand.
            let ins = [prev[0], prev[1], prev[2], cur[0], cur[1], cur[2]];
            let inv = invert_b;
            let maj3 = move |m: u32| {
                let b = ((m >> 1) & 1) ^ u32::from(inv);
                (m & 1) + b + ((m >> 2) & 1) >= 2
            };
            let (d, s) = self.lut52(
                &ins,
                move |m| maj3(m),
                move |m| {
                    let pb = maj3(m);
                    let bb = ((m >> 4) & 1) ^ u32::from(inv);
                    let cb = ((m >> 3) & 1) + bb + ((m >> 5) & 1);
                    ((cb & 1) == 1) ^ pb
                },
            );
            s_in.push(s);
            di.push(d);
        }
        let (sum, co) = self.carry_chain(&s_in, &di, cin);
        let mut out = sum;
        out.push(co);
        out
    }

    /// Constant bus of `width` bits holding `value`.
    pub fn constant(&mut self, width: u32, value: u64) -> Vec<Net> {
        (0..width).map(|i| if (value >> i) & 1 == 1 { NET1 } else { NET0 }).collect()
    }
}

/// Specialize a truth table over constant inputs (NET0/NET1), returning
/// the surviving inputs and the reduced table.
fn fold_constants(inputs: &[Net], truth: u64) -> (Vec<Net>, u64) {
    let mut ins: Vec<Net> = inputs.to_vec();
    let mut t = truth;
    let mut i = 0;
    while i < ins.len() {
        let n = ins[i];
        if n == NET0 || n == NET1 {
            let bit = u32::from(n == NET1);
            // Collapse input i: keep entries where input i == bit.
            let k = ins.len();
            let mut nt = 0u64;
            for m in 0..(1u32 << (k - 1)) {
                let low = m & ((1 << i) - 1);
                let high = (m >> i) << (i + 1);
                let full = high | (bit << i) | low;
                if (t >> full) & 1 == 1 {
                    nt |= 1 << m;
                }
            }
            t = nt;
            ins.remove(i);
        } else {
            i += 1;
        }
    }
    // Drop don't-care inputs (function independent of them).
    let mut i = 0;
    while i < ins.len() {
        let k = ins.len();
        let mut independent = true;
        for m in 0..(1u32 << k) {
            if (t >> m) & 1 != (t >> (m ^ (1 << i))) & 1 {
                independent = false;
                break;
            }
        }
        if independent {
            let mut nt = 0u64;
            for m in 0..(1u32 << (k - 1)) {
                let low = m & ((1 << i) - 1);
                let high = (m >> i) << (i + 1);
                if (t >> (high | low)) & 1 == 1 {
                    nt |= 1 << m;
                }
            }
            t = nt;
            ins.remove(i);
        } else {
            i += 1;
        }
    }
    (ins, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::Simulator;

    fn eval1(nl: &Netlist, ins: &[(&str, u64)]) -> u64 {
        let sim = Simulator::new(nl);
        let out = sim.run_single(ins);
        out[0].1
    }

    #[test]
    fn lut_truth_table() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 2);
        let x = nl.xor2(a[0], a[1]);
        nl.output("x", &[x]);
        for v in 0..4u64 {
            let want = (v & 1) ^ ((v >> 1) & 1);
            assert_eq!(eval1(&nl, &[("a", v)]), want);
        }
    }

    #[test]
    fn adder_exhaustive_8bit() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let b = nl.input("b", 8);
        let (sum, co) = nl.adder(&a, &b, NET0);
        let mut out = sum;
        out.push(co);
        nl.output("s", &out);
        let sim = Simulator::new(&nl);
        for a in (0..256u64).step_by(7) {
            for b in 0..256u64 {
                let got = sim.run_single(&[("a", a), ("b", b)])[0].1;
                assert_eq!(got, a + b, "{a}+{b}");
            }
        }
    }

    #[test]
    fn subtractor_exhaustive() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let b = nl.input("b", 8);
        let (d, bor) = nl.subtractor(&a, &b, NET1);
        let mut out = d;
        out.push(bor);
        nl.output("d", &out);
        let sim = Simulator::new(&nl);
        for a in (0..256u64).step_by(11) {
            for b in 0..256u64 {
                let got = sim.run_single(&[("a", a), ("b", b)])[0].1;
                let want = (a.wrapping_sub(b) & 0xFF) | (u64::from(a >= b) << 8);
                assert_eq!(got, want, "{a}-{b}");
            }
        }
    }

    #[test]
    fn ternary_adder_matches_sum() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 8);
        let b = nl.input("b", 8);
        let c = nl.input("c", 8);
        let s = nl.ternary_adder(&a, &b, &c);
        nl.output("s", &s);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..2_000 {
            let (a, b, c) = (rng.below(256), rng.below(256), rng.below(256));
            let got = sim.run_single(&[("a", a), ("b", b), ("c", c)])[0].1;
            assert_eq!(got, a + b + c, "{a}+{b}+{c}");
        }
    }

    #[test]
    fn mux_and_or_tree() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 6);
        let sel = nl.input("sel", 1);
        let any = nl.or_tree(&a);
        let m = nl.mux2(sel[0], a[0], any);
        nl.output("m", &[m]);
        assert_eq!(eval1(&nl, &[("a", 0b100), ("sel", 1)]), 1);
        assert_eq!(eval1(&nl, &[("a", 0b100), ("sel", 0)]), 0);
        assert_eq!(eval1(&nl, &[("a", 0b101), ("sel", 0)]), 1);
    }

    #[test]
    fn wide_or_tree() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 32);
        let any = nl.or_tree(&a);
        nl.output("o", &[any]);
        assert_eq!(eval1(&nl, &[("a", 0)]), 0);
        assert_eq!(eval1(&nl, &[("a", 1 << 31)]), 1);
        assert_eq!(eval1(&nl, &[("a", 0x0001_0000)]), 1);
    }

    #[test]
    fn lut52_dual_outputs() {
        let mut nl = Netlist::new();
        let a = nl.input("a", 4);
        // O5 = zero flag (NOR), O6 = parity.
        let (z, p) = nl.lut52(&a, |m| m == 0, |m| (m.count_ones() & 1) == 1);
        nl.output("zp", &[z, p]);
        let sim = Simulator::new(&nl);
        for v in 0..16u64 {
            let got = sim.run_single(&[("a", v)])[0].1;
            let want = u64::from(v == 0) | (u64::from((v.count_ones() & 1) == 1) << 1);
            assert_eq!(got, want, "v={v}");
        }
    }
}
