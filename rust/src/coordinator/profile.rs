//! Error profiles and the error-budget router (DESIGN.md §9).
//!
//! SIMDive's accuracy knob `w` (§3.3) is a cost dial: every extra
//! coefficient LUT buys error reduction. Most clients, though, don't think
//! in LUT counts — they have an error *budget* ("anything under 1%
//! relative error is fine"). The router turns one into the other: a
//! precomputed profile maps every `{op, width, w}` point to its measured
//! mean relative error (MRED), and [`ErrorProfile::pick_w`] returns the
//! **cheapest** `w` whose profiled MRED fits the budget.
//!
//! Profiles are measured once per process against the real-valued
//! behavioral models (`simdive_{mul,div}_real_w`) vs the exact real
//! product/quotient — the paper's §4.1 error convention: 8-bit entries
//! are exhaustive over all non-zero operand pairs; 16/32-bit entries are
//! sampled with fixed [`util::Rng`](crate::util::Rng) seeds, so the table
//! (and therefore budget routing) is deterministic run-to-run.

use super::packer::ReqOp;
use crate::arith::simdive::{simdive_div_real_w, simdive_mul_real_w};
use crate::arith::{W_MAX, WIDTHS};
use crate::util::Rng;
use std::sync::OnceLock;

/// Samples per `{op, width, w}` point for the 16/32-bit profile entries.
const PROFILE_SAMPLES: u64 = 20_000;

/// Fixed seed base for the sampled profile entries.
const PROFILE_SEED: u64 = 0x0E44_0B0D_6E70;

/// Measured mean relative error per `{op, width, w}`, in parts per
/// million, plus the budget router over it.
pub struct ErrorProfile {
    /// `mred_ppm[op][width_index][w]`; op 0 = mul, 1 = div.
    mred_ppm: [[[u64; (W_MAX + 1) as usize]; 3]; 2],
}

fn op_index(op: ReqOp) -> usize {
    match op {
        ReqOp::Mul => 0,
        ReqOp::Div => 1,
    }
}

fn width_index(bits: u32) -> usize {
    match bits {
        8 => 0,
        16 => 1,
        32 => 2,
        other => panic!("unsupported precision {other}"),
    }
}

/// Mean relative error (fraction, not percent) of one `{op, bits, w}`
/// point over an operand-pair iterator.
fn mred_over(op: ReqOp, bits: u32, w: u32, pairs: impl Iterator<Item = (u64, u64)>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0u64);
    for (a, b) in pairs {
        let (exact, approx) = match op {
            ReqOp::Mul => ((a as f64) * (b as f64), simdive_mul_real_w(bits, a, b, w)),
            ReqOp::Div => (a as f64 / b as f64, simdive_div_real_w(bits, a, b, w)),
        };
        sum += (exact - approx).abs() / exact;
        n += 1;
    }
    sum / n as f64
}

/// The process-wide profile singleton, shared by [`ErrorProfile::get`]
/// and [`ErrorProfile::try_get`].
static CACHE: OnceLock<ErrorProfile> = OnceLock::new();

impl ErrorProfile {
    /// The process-wide profile, computed on first use (~2M behavioral
    /// evaluations, sub-second in release).
    pub fn get() -> &'static ErrorProfile {
        CACHE.get_or_init(ErrorProfile::compute)
    }

    /// The cached profile if some caller already forced it, else `None`.
    /// Observability snapshots use this so reading stats never pays (or
    /// blocks on) the multi-second debug-build profile computation.
    pub fn try_get() -> Option<&'static ErrorProfile> {
        CACHE.get()
    }

    fn compute() -> ErrorProfile {
        let mut mred_ppm = [[[0u64; (W_MAX + 1) as usize]; 3]; 2];
        for op in [ReqOp::Mul, ReqOp::Div] {
            for &bits in &WIDTHS {
                for w in 0..=W_MAX {
                    let mred = if bits == 8 {
                        // Exhaustive: every non-zero 8-bit operand pair.
                        mred_over(
                            op,
                            bits,
                            w,
                            (1..256u64).flat_map(|a| (1..256u64).map(move |b| (a, b))),
                        )
                    } else {
                        let mut rng = Rng::new(
                            PROFILE_SEED ^ ((op_index(op) as u64) << 32)
                                ^ ((bits as u64) << 8)
                                ^ w as u64,
                        );
                        mred_over(
                            op,
                            bits,
                            w,
                            (0..PROFILE_SAMPLES).map(|_| (rng.operand(bits), rng.operand(bits))),
                        )
                    };
                    mred_ppm[op_index(op)][width_index(bits)][w as usize] =
                        (mred * 1e6).round() as u64;
                }
            }
        }
        ErrorProfile { mred_ppm }
    }

    /// Profiled mean relative error of `{op, bits, w}` in parts per
    /// million (10_000 ppm = 1% MRED).
    pub fn mred_ppm(&self, op: ReqOp, bits: u32, w: u32) -> u64 {
        assert!(w <= W_MAX, "unsupported accuracy knob {w}");
        self.mred_ppm[op_index(op)][width_index(bits)][w as usize]
    }

    /// Route an error budget to the cheapest accuracy knob: the smallest
    /// `w` whose profiled MRED is within `budget_ppm`. An unsatisfiable
    /// budget (tighter than even the full 8-LUT correction achieves)
    /// degrades to best effort: `W_MAX`.
    pub fn pick_w(&self, op: ReqOp, bits: u32, budget_ppm: u32) -> u32 {
        let table = &self.mred_ppm[op_index(op)][width_index(bits)];
        for w in 0..=W_MAX {
            if table[w as usize] <= budget_ppm as u64 {
                return w;
            }
        }
        W_MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_is_populated_and_sane() {
        let p = ErrorProfile::get();
        for op in [ReqOp::Mul, ReqOp::Div] {
            for &bits in &WIDTHS {
                // w=0 is pure Mitchell (~4% MRED); w=W_MAX well under 2%.
                let worst = p.mred_ppm(op, bits, 0);
                let best = p.mred_ppm(op, bits, W_MAX);
                assert!(worst > 20_000, "{op:?}@{bits}: Mitchell MRED {worst} ppm");
                assert!(worst < 80_000, "{op:?}@{bits}: Mitchell MRED {worst} ppm");
                assert!(best < 20_000, "{op:?}@{bits}: full-w MRED {best} ppm");
                assert!(best < worst, "{op:?}@{bits}: w must reduce MRED");
            }
        }
    }

    #[test]
    fn pick_w_returns_cheapest_satisfying_knob() {
        let p = ErrorProfile::get();
        for op in [ReqOp::Mul, ReqOp::Div] {
            for &bits in &WIDTHS {
                // A budget looser than Mitchell's own error costs nothing.
                let loose = p.mred_ppm(op, bits, 0) + 1;
                assert_eq!(p.pick_w(op, bits, loose as u32), 0);
                // The exact MRED of some mid w must pick a knob no more
                // expensive than that w, and its profile must fit.
                for w in 0..=W_MAX {
                    let budget = p.mred_ppm(op, bits, w);
                    let picked = p.pick_w(op, bits, budget as u32);
                    assert!(picked <= w, "{op:?}@{bits}: picked {picked} for budget of w={w}");
                    assert!(
                        p.mred_ppm(op, bits, picked) <= budget,
                        "{op:?}@{bits}: picked w={picked} violates its own budget"
                    );
                }
            }
        }
    }

    #[test]
    fn unsatisfiable_budget_degrades_to_best_effort() {
        let p = ErrorProfile::get();
        // 1 ppm is far below anything an approximate log multiplier can
        // reach; the router must hand back the most accurate knob.
        assert_eq!(p.pick_w(ReqOp::Mul, 16, 1), W_MAX);
        assert_eq!(p.pick_w(ReqOp::Div, 8, 1), W_MAX);
    }

    #[cfg_attr(
        debug_assertions,
        ignore = "recomputes the full profile twice; run in --release (CI accuracy-oracle job)"
    )]
    #[test]
    fn profile_is_deterministic() {
        // Two independent computations (not the cached singleton) agree —
        // the sampled entries are seeded.
        let a = ErrorProfile::compute();
        let b = ErrorProfile::compute();
        for op in [ReqOp::Mul, ReqOp::Div] {
            for &bits in &WIDTHS {
                for w in 0..=W_MAX {
                    assert_eq!(a.mred_ppm(op, bits, w), b.mred_ppm(op, bits, w));
                }
            }
        }
    }
}
