//! Threaded coordinator v2: bounded request queue (backpressure), a
//! batcher that drains the queue into the mixed-`{bits, w}` word
//! [`Assembler`], one shared worker pool executing packed words through
//! the multi-accuracy batched kernel, and accounting (latency, energy
//! from the calibrated fabric model, lane utilization, power-gated idle
//! lanes). std::thread + mpsc — tokio is unavailable offline (DESIGN.md
//! §1).
//!
//! Hot-path structure (DESIGN.md §6, §9):
//!
//! * **One pool for every accuracy tier.** Requests carry their own `w`;
//!   the assembler keeps per-`{bits, w}` sub-queues drained round-robin,
//!   so mixed-accuracy traffic shares one worker pool instead of
//!   fragmenting across per-`w` coordinators. Words are emitted eagerly
//!   while full; partial residues are held to merge with later arrivals
//!   of the same tier, flushed the instant the queue idles (and at a
//!   round cap under saturation), so a lone request is never stranded.
//! * **O(1) response routing.** Response routes ride lane-aligned inside
//!   each assembled word ([`Assembled::payload`]), so every route lookup
//!   is a direct index — there are no linear `find` scans anywhere on
//!   the request path.
//! * **Per-batch response channels.** [`Coordinator::submit_batch`] sends
//!   a whole request batch with *one* response channel; workers tag each
//!   response with its request-index slot and [`BatchHandle::wait`]
//!   reassembles in submission order. The per-request channel of
//!   [`Coordinator::submit`] remains for single-shot callers.
//! * **Per-worker feeds.** Each worker owns its own channel, fed
//!   round-robin with contiguous chunks of packed words, so workers never
//!   contend on a shared `Mutex<Receiver>`; chunks execute through a
//!   [`batch::MultiKernel`](crate::arith::batch::MultiKernel) whose
//!   correction-table rescales (all nine accuracy knobs) are resolved
//!   once per worker thread.

use super::packer::{lane_value, Assembled, Assembler, Request};
use crate::arith::batch;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A completed request.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    pub id: u64,
    pub value: u64,
}

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Bounded queue depth (backpressure: submit blocks when full).
    pub queue_depth: usize,
    /// Max requests drained into one packing batch.
    pub batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 4, queue_depth: 1024, batch: 64 }
    }
}

/// Aggregate statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub requests: u64,
    pub words: u64,
    pub active_lanes: u64,
    pub total_lanes: u64,
    /// Estimated energy (pJ) from the calibrated per-word figure, with
    /// idle lanes power-gated to ~10% of their share.
    pub energy_pj: f64,
}

impl Stats {
    pub fn lane_utilization(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.active_lanes as f64 / self.total_lanes as f64
        }
    }

    /// Fold another snapshot into this one (aggregation across
    /// coordinators, e.g. in multi-process roll-ups).
    pub fn merge(&mut self, other: &Stats) {
        self.requests += other.requests;
        self.words += other.words;
        self.active_lanes += other.active_lanes;
        self.total_lanes += other.total_lanes;
        self.energy_pj += other.energy_pj;
    }
}

struct Shared {
    requests: AtomicU64,
    words: AtomicU64,
    active_lanes: AtomicU64,
    total_lanes: AtomicU64,
    energy_mpj: AtomicU64, // milli-pJ, to keep atomic integer math
}

/// Where a completed request's response goes.
#[derive(Clone)]
enum Route {
    /// Dedicated per-request channel ([`Coordinator::submit`]).
    Single(Sender<Response>),
    /// Shared per-batch channel + request-index slot
    /// ([`Coordinator::submit_batch`]).
    Slot(Sender<(u32, Response)>, u32),
}

impl Route {
    #[inline]
    fn send(&self, resp: Response) {
        match self {
            Route::Single(tx) => {
                let _ = tx.send(resp);
            }
            Route::Slot(tx, slot) => {
                let _ = tx.send((*slot, resp));
            }
        }
    }
}

/// One packed word plus its lane-aligned response routes (the assembler's
/// payload slot `l` routes the request in lane `l` — direct index, no
/// scan).
type Job = Assembled<Route>;

enum Msg {
    Req(Request, Route),
    /// A chunk of a batch submission: requests, the slot index of the
    /// first one, and the batch's shared response channel. Large batches
    /// are split into `cfg.batch`-sized chunks so the bounded queue's
    /// backpressure still applies to batch submitters.
    Batch(Vec<Request>, u32, Sender<(u32, Response)>),
    Flush,
    Stop,
}

/// Batcher control flow after folding in one queue message.
enum Flow {
    /// Keep draining into the current batch.
    Drain,
    /// Close the current batch now (flush partial residues too).
    CloseBatch,
    /// Shut the coordinator down.
    Stop,
}

/// Residues survive at most this many consecutive full-word emission
/// rounds under sustained traffic before being force-flushed — a rare
/// `{bits, w}` tier must not be starved by a saturated queue that never
/// goes empty. (When the queue *does* go empty, everything flushes
/// immediately — residues never wait on traffic that may not come.)
const MAX_HELD_ROUNDS: u32 = 4;

/// One batcher emission round: emit words from the assembler (full words
/// only while residues may still merge, everything when `flush` or the
/// round cap hits) and dispatch them round-robin to the workers in
/// contiguous chunks. Returns false when the workers are gone.
fn emit_and_dispatch(
    asm: &mut Assembler<Route>,
    words: &mut Vec<Job>,
    work_txs: &[SyncSender<Vec<Job>>],
    rr: &mut usize,
    held_rounds: &mut u32,
    flush: bool,
) -> bool {
    words.clear();
    if flush || *held_rounds >= MAX_HELD_ROUNDS {
        asm.emit_all(words);
    } else {
        asm.emit_full(words);
    }
    *held_rounds = if asm.is_empty() { 0 } else { *held_rounds + 1 };
    if words.is_empty() {
        return true;
    }
    let n_workers = work_txs.len();
    let chunk = words.len().div_ceil(n_workers).max(1);
    let mut iter = words.drain(..);
    loop {
        let chunk_jobs: Vec<Job> = iter.by_ref().take(chunk).collect();
        if chunk_jobs.is_empty() {
            return true;
        }
        if work_txs[*rr % n_workers].send(chunk_jobs).is_err() {
            return false;
        }
        *rr = rr.wrapping_add(1);
    }
}

/// The coordinator front end.
pub struct Coordinator {
    tx: SyncSender<Msg>,
    batcher: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    /// Chunk size for splitting batch submissions (`cfg.batch`).
    batch_chunk: usize,
}

/// In-flight batch submitted via [`Coordinator::submit_batch`]: one
/// response channel for the whole batch, responses tagged with their
/// request-index slot.
pub struct BatchHandle {
    rx: Receiver<(u32, Response)>,
    n: usize,
}

impl BatchHandle {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Block until every response arrives; returns them in submission
    /// order.
    pub fn wait(self) -> Vec<Response> {
        let mut out: Vec<Option<Response>> = vec![None; self.n];
        let mut got = 0usize;
        while got < self.n {
            let (slot, resp) = self.rx.recv().expect("coordinator stopped");
            if out[slot as usize].replace(resp).is_none() {
                got += 1;
            }
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

/// Per-word energy estimate (pJ) with power gating: idle lanes of a word
/// consume `IDLE_FRACTION` of their proportional share.
pub const IDLE_FRACTION: f64 = 0.1;

fn word_energy_pj(per_word_pj: f64, active: u32, lanes: u32) -> f64 {
    let share = per_word_pj / lanes as f64;
    share * active as f64 + share * (lanes - active) as f64 * IDLE_FRACTION
}

/// Milli-pJ increment added to the shared energy counter for a chunk's
/// energy. Rounds to nearest — truncation would floor every chunk's
/// fractional milli-pJ and drift `Stats::energy_pj` low over millions of
/// words.
#[inline]
fn energy_increment_mpj(energy_pj: f64) -> u64 {
    (energy_pj * 1000.0).round() as u64
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
        let shared = Arc::new(Shared {
            requests: AtomicU64::new(0),
            words: AtomicU64::new(0),
            active_lanes: AtomicU64::new(0),
            total_lanes: AtomicU64::new(0),
            energy_mpj: AtomicU64::new(0),
        });

        // Calibrated per-word energy of the 32-bit SIMD unit (computed
        // once; the gate-level characterization is cached globally).
        let per_word_pj = simd_word_energy_pj();

        // Worker pool: one channel per worker (no shared-receiver lock),
        // fed round-robin by the batcher.
        let n_workers = cfg.workers.max(1);
        let mut work_txs: Vec<SyncSender<Vec<Job>>> = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (work_tx, work_rx) = sync_channel::<Vec<Job>>(cfg.queue_depth.max(16));
            work_txs.push(work_tx);
            let shared = Arc::clone(&shared);
            workers.push(std::thread::spawn(move || {
                // Coefficient rescales for every {width, w} hoisted once
                // per worker thread, not once per chunk.
                let kernel = batch::MultiKernel::new();
                let mut ws = Vec::new();
                let mut ops = Vec::new();
                let mut words = Vec::new();
                let mut results = Vec::new();
                while let Ok(jobs) = work_rx.recv() {
                    // Execute the whole chunk through the batched kernel.
                    ws.clear();
                    ws.extend(jobs.iter().map(|j| j.pw.w));
                    ops.clear();
                    ops.extend(jobs.iter().map(|j| j.pw.op));
                    words.clear();
                    words.extend(jobs.iter().map(|j| j.pw.word));
                    results.clear();
                    results.resize(jobs.len(), 0);
                    kernel.execute_mixed_into(&ws, &ops, &words, &mut results);

                    let (mut active, mut total) = (0u64, 0u64);
                    let mut energy = 0.0f64;
                    for (job, &packed) in jobs.iter().zip(&results) {
                        let pw = &job.pw;
                        active += pw.active_lanes as u64;
                        total += pw.lane_count() as u64;
                        energy +=
                            word_energy_pj(per_word_pj, pw.active_lanes, pw.lane_count() as u32);
                        for (l, route) in job.payload.iter().enumerate().take(pw.lane_count()) {
                            if let Some(route) = route {
                                let id = pw.lane_req[l].expect("routed lane carries an id");
                                route.send(Response { id, value: lane_value(pw, packed, l) });
                            }
                        }
                    }
                    shared.words.fetch_add(jobs.len() as u64, Ordering::Relaxed);
                    shared.active_lanes.fetch_add(active, Ordering::Relaxed);
                    shared.total_lanes.fetch_add(total, Ordering::Relaxed);
                    shared
                        .energy_mpj
                        .fetch_add(energy_increment_mpj(energy), Ordering::Relaxed);
                }
            }));
        }

        // Batcher thread: drain bursts into the word assembler, emit
        // full words every `batch` requests, and flush everything the
        // instant the queue goes empty (or on Flush/Stop) — a partial
        // residue never waits on traffic that may not come.
        let shared_b = Arc::clone(&shared);
        let batch_size = cfg.batch.max(1);
        let batcher = std::thread::spawn(move || {
            let mut rr = 0usize; // round-robin worker cursor
            let mut asm: Assembler<Route> = Assembler::new();
            let mut words: Vec<Job> = Vec::new();
            // Consecutive full-word-only emissions with residues still
            // held; at MAX_HELD_ROUNDS the next emission flushes, so a
            // rare tier's residue is bounded by ~MAX_HELD_ROUNDS × batch
            // requests of sustained foreign traffic.
            let mut held_rounds = 0u32;
            let mut stop = false;
            // Fold one message into the assembler; returns the resulting
            // control flow.
            let on_msg = |asm: &mut Assembler<Route>, folded: &mut usize, msg: Msg| -> Flow {
                match msg {
                    Msg::Req(r, route) => {
                        asm.push(r, route);
                        *folded += 1;
                    }
                    Msg::Batch(batch_reqs, base, tx) => {
                        for (k, r) in batch_reqs.into_iter().enumerate() {
                            asm.push(r, Route::Slot(tx.clone(), base + k as u32));
                            *folded += 1;
                        }
                    }
                    Msg::Flush => return Flow::CloseBatch,
                    Msg::Stop => return Flow::Stop,
                }
                Flow::Drain
            };
            'bursts: while !stop {
                // Between bursts the assembler is empty (every burst ends
                // in a flush), so blocking indefinitely strands nothing.
                let mut folded = 0usize;
                match rx.recv() {
                    Ok(msg) => match on_msg(&mut asm, &mut folded, msg) {
                        Flow::Drain => {}
                        Flow::CloseBatch => {} // nothing held yet
                        Flow::Stop => stop = true,
                    },
                    Err(_) => break 'bursts,
                }
                // Drain the burst.
                while !stop {
                    if folded >= batch_size {
                        shared_b.requests.fetch_add(folded as u64, Ordering::Relaxed);
                        folded = 0;
                        if !emit_and_dispatch(
                            &mut asm,
                            &mut words,
                            &work_txs,
                            &mut rr,
                            &mut held_rounds,
                            false,
                        ) {
                            return;
                        }
                    }
                    match rx.try_recv() {
                        Ok(msg) => match on_msg(&mut asm, &mut folded, msg) {
                            Flow::Drain => {}
                            Flow::CloseBatch => {
                                // Explicit flush request mid-burst.
                                shared_b.requests.fetch_add(folded as u64, Ordering::Relaxed);
                                folded = 0;
                                if !emit_and_dispatch(
                                    &mut asm,
                                    &mut words,
                                    &work_txs,
                                    &mut rr,
                                    &mut held_rounds,
                                    true,
                                ) {
                                    return;
                                }
                            }
                            Flow::Stop => stop = true,
                        },
                        // Empty (burst over) or disconnected — either way
                        // flush below; a disconnect also ends the outer
                        // loop at its next recv.
                        Err(_) => break,
                    }
                }
                // Burst over (idle queue or Stop): flush everything held.
                if folded > 0 {
                    shared_b.requests.fetch_add(folded as u64, Ordering::Relaxed);
                }
                if !emit_and_dispatch(
                    &mut asm,
                    &mut words,
                    &work_txs,
                    &mut rr,
                    &mut held_rounds,
                    true,
                ) {
                    return;
                }
            }
            drop(work_txs);
            for w in workers {
                let _ = w.join();
            }
        });

        Coordinator { tx, batcher: Some(batcher), shared, batch_chunk: batch_size }
    }

    /// Submit a request; returns the response channel. Blocks when the
    /// queue is full (backpressure).
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx.send(Msg::Req(req, Route::Single(tx))).expect("coordinator stopped");
        rx
    }

    /// Submit a batch of requests sharing one response channel; responses
    /// are tagged with their request-index slot and reassembled in
    /// submission order by [`BatchHandle::wait`]. This is the throughput
    /// path: one channel allocation per batch instead of one per request.
    ///
    /// The batch is split into `cfg.batch`-sized queue messages, so the
    /// bounded queue's backpressure applies to batch submitters too (a
    /// batch occupies one queue slot per `cfg.batch` requests; submission
    /// blocks when the queue is full).
    pub fn submit_batch(&self, reqs: Vec<Request>) -> BatchHandle {
        let n = reqs.len();
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit_batch_streaming(reqs, 0, &tx);
        BatchHandle { rx, n }
    }

    /// Streaming form of [`Coordinator::submit_batch`]: the response for
    /// `reqs[i]` is sent on the caller-owned channel tagged with slot
    /// `base_slot + i`, *as its lane completes* — there is no reassembly
    /// barrier. The network serve layer uses this to write responses
    /// out-of-order while lanes are still executing (DESIGN.md §8); every
    /// response still carries the caller's original request id. Chunking
    /// (and therefore bounded-queue backpressure) matches `submit_batch`.
    pub fn submit_batch_streaming(
        &self,
        reqs: Vec<Request>,
        base_slot: u32,
        tx: &Sender<(u32, Response)>,
    ) {
        let mut slot = base_slot;
        let mut iter = reqs.into_iter();
        loop {
            let chunk: Vec<Request> = iter.by_ref().take(self.batch_chunk).collect();
            if chunk.is_empty() {
                break;
            }
            let len = chunk.len() as u32;
            self.tx.send(Msg::Batch(chunk, slot, tx.clone())).expect("coordinator stopped");
            slot += len;
        }
    }

    /// Force the batcher to close the current batch (flushing any held
    /// partial words).
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> Stats {
        Stats {
            requests: self.shared.requests.load(Ordering::Relaxed),
            words: self.shared.words.load(Ordering::Relaxed),
            active_lanes: self.shared.active_lanes.load(Ordering::Relaxed),
            total_lanes: self.shared.total_lanes.load(Ordering::Relaxed),
            energy_pj: self.shared.energy_mpj.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }

    /// Stop the coordinator and return final statistics. Messages queued
    /// before the stop are fully processed (their responses delivered)
    /// and every batcher/worker thread is joined before this returns.
    pub fn shutdown(mut self) -> Stats {
        let _ = self.tx.send(Msg::Stop);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        self.stats()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
    }
}

/// Calibrated energy per packed word (pJ), cached.
pub fn simd_word_energy_pj() -> f64 {
    use std::sync::OnceLock;
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let nl = crate::circuits::simdive::simd32(8);
        let cal = crate::fabric::calibrate::fitted();
        let t = crate::fabric::timing::analyze(&nl, cal);
        let p = crate::fabric::power::estimate_at(&nl, cal, 0x51D, 2048, t.critical_ns);
        p.total_mw * t.critical_ns
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::{simdive_div_w, simdive_mul_w};
    use crate::coordinator::packer::ReqOp;

    fn expect(req: &Request) -> u64 {
        match req.op {
            ReqOp::Mul => simdive_mul_w(req.bits, req.a, req.b, req.w),
            ReqOp::Div => simdive_div_w(req.bits, req.a, req.b, req.w),
        }
    }

    #[test]
    fn stats_account_all_requests() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut handles = Vec::new();
        for i in 0..100 {
            handles.push(coord.submit(Request {
                id: i,
                op: ReqOp::Mul,
                bits: 8,
                w: 8,
                a: 1 + i % 200,
                b: 3,
            }));
        }
        for h in handles {
            h.recv().unwrap();
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 100);
        assert!(s.energy_pj > 0.0);
        assert!(s.words <= 100);
    }

    #[test]
    fn batch_submission_routes_in_order() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut rng = crate::util::Rng::new(0xBEEF);
        let reqs: Vec<Request> = (0..500u64)
            .map(|i| {
                let bits = [8u32, 16, 32][rng.below(3) as usize];
                Request {
                    id: 1000 + i,
                    op: if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                    bits,
                    w: rng.below(crate::arith::W_MAX as u64 + 1) as u32,
                    a: rng.operand(bits),
                    b: rng.operand(bits),
                }
            })
            .collect();
        let handle = coord.submit_batch(reqs.clone());
        assert_eq!(handle.len(), 500);
        let responses = handle.wait();
        for (resp, req) in responses.iter().zip(&reqs) {
            assert_eq!(resp.id, req.id, "responses must come back in submission order");
            assert_eq!(resp.value, expect(req), "req {}", req.id);
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 500);
    }

    #[test]
    fn streaming_submission_delivers_every_response_with_original_ids() {
        // The serve layer's entry point: caller-owned channel, responses
        // arriving as lanes complete (any order), ids preserved.
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (tx, rx) = std::sync::mpsc::channel();
        let reqs: Vec<Request> = (0..300u64)
            .map(|i| Request { id: 5000 + i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i % 255, b: 3 })
            .collect();
        coord.submit_batch_streaming(reqs.clone(), 7, &tx);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..reqs.len() {
            let (slot, resp) = rx.recv().unwrap();
            assert!((7..7 + reqs.len() as u32).contains(&slot), "slot {slot}");
            seen.insert(resp.id, resp.value);
        }
        for req in &reqs {
            assert_eq!(seen[&req.id], simdive_mul_w(8, req.a, req.b, 8), "req {}", req.id);
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 300);
    }

    #[test]
    fn empty_batch_returns_immediately() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let handle = coord.submit_batch(Vec::new());
        assert!(handle.is_empty());
        assert!(handle.wait().is_empty());
        coord.shutdown();
    }

    #[test]
    fn duplicate_ids_each_get_a_response() {
        // Caller-chosen ids need not be unique: routing is positional.
        let coord = Coordinator::start(CoordinatorConfig::default());
        let reqs: Vec<Request> = (0..8)
            .map(|_| Request { id: 7, op: ReqOp::Mul, bits: 8, w: 8, a: 43, b: 10 })
            .collect();
        let responses = coord.submit_batch(reqs).wait();
        assert_eq!(responses.len(), 8);
        for r in responses {
            assert_eq!(r.id, 7);
            assert_eq!(r.value, simdive_mul_w(8, 43, 10, 8));
        }
        coord.shutdown();
    }

    #[test]
    fn mixed_single_and_batch_submission() {
        let coord =
            Coordinator::start(CoordinatorConfig { workers: 2, queue_depth: 64, batch: 16 });
        let single =
            coord.submit(Request { id: 0, op: ReqOp::Div, bits: 16, w: 8, a: 5000, b: 40 });
        let batch = coord.submit_batch(
            (0..32)
                .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i, b: 3 })
                .collect(),
        );
        assert_eq!(single.recv().unwrap().value, simdive_div_w(16, 5000, 40, 8));
        let responses = batch.wait();
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.value, simdive_mul_w(8, 1 + i as u64, 3, 8));
        }
        coord.shutdown();
    }

    #[test]
    fn mixed_w_traffic_shares_one_pool_and_stays_bit_exact() {
        // The v2 headline: one coordinator serves every accuracy tier at
        // once, and each request's answer matches its own w's tables.
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut rng = crate::util::Rng::new(0x2A11);
        let reqs: Vec<Request> = (0..1_000u64)
            .map(|i| {
                let bits = [8u32, 8, 16, 32][rng.below(4) as usize];
                Request {
                    id: i,
                    op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
                    bits,
                    w: rng.below(crate::arith::W_MAX as u64 + 1) as u32,
                    a: rng.operand(bits),
                    b: rng.operand(bits),
                }
            })
            .collect();
        let responses = coord.submit_batch(reqs.clone()).wait();
        for (resp, req) in responses.iter().zip(&reqs) {
            assert_eq!(resp.value, expect(req), "req {} (w={})", req.id, req.w);
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 1_000);
        // Mixed-w 8-bit-heavy traffic must still pack multiple lanes per
        // word on average (the shared-pool utilization claim).
        assert!(s.lane_utilization() > 0.5, "utilization {}", s.lane_utilization());
    }

    #[test]
    fn power_gating_reduces_energy_of_partial_words() {
        let full = word_energy_pj(100.0, 4, 4);
        let one = word_energy_pj(100.0, 1, 4);
        assert!((full - 100.0).abs() < 1e-9);
        assert!(one < 0.4 * full, "gated {one} vs full {full}");
    }

    #[test]
    fn word_energy_is_positive_and_sane() {
        let e = simd_word_energy_pj();
        assert!(e > 1.0 && e < 100_000.0, "per-word energy {e} pJ");
    }

    #[test]
    fn energy_accumulation_rounds_not_floors() {
        // The increment actually used by the worker loop must round to the
        // nearest milli-pJ; truncation (`as u64` on the raw product) would
        // floor 0.4999 pJ to 499 and 0.0006 pJ to 0.
        assert_eq!(energy_increment_mpj(0.4999), 500);
        assert_eq!(energy_increment_mpj(0.0006), 1);
        assert_eq!(energy_increment_mpj(0.0004), 0);
        assert!(energy_increment_mpj(0.4999) > (0.4999f64 * 1000.0) as u64);
    }
}
