//! Coordinator front end over the sharded execution engine.
//!
//! The batcher-plus-worker-pool of coordinator v2 is gone: request
//! assembly and execution both live in [`engine::Sharded`] (DESIGN.md
//! §10), a pool of independent shards each owning its own mixed-`{bits,
//! w}` word [`Assembler`](super::packer::Assembler) and its own bank of
//! rescaled correction tables. This module keeps the submission surface
//! the serve layer and the benches speak:
//!
//! * [`Coordinator::submit`] — one request, one response channel;
//! * [`Coordinator::submit_batch`] — one response channel per batch,
//!   request-index slots, reassembled in order by [`BatchHandle::wait`];
//! * [`Coordinator::submit_batch_streaming`] — caller-owned channel, no
//!   reassembly barrier (the network serve path, DESIGN.md §8).
//!
//! Submissions are split into `cfg.batch`-sized chunks dispatched
//! round-robin across the shards, so the bounded per-shard queues apply
//! backpressure to every submitter and a chunk's requests assemble
//! together on one shard (packing quality tracks the chunk size).
//! Results are bit-identical to the scalar models for every `{op, bits,
//! w}` and invariant under the shard count (`tests/engine_props.rs`).

use super::packer::Request;
use crate::engine::sharded::{Route, Sharded, ShardedConfig, StatsHandle};
use crate::faults::FaultInjector;
use crate::obs::{Registry, Span};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

// Re-exported so the serve layer and external callers keep one import
// path for the coordinator surface.
pub use crate::engine::sharded::{simd_word_energy_pj, Response, Stats, IDLE_FRACTION};

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Worker shards of the execution pool.
    pub workers: usize,
    /// Bounded per-shard queue depth (backpressure: submit blocks when a
    /// shard's queue is full).
    pub queue_depth: usize,
    /// Max requests per dispatch chunk (and per shard emission round).
    pub batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 4, queue_depth: 1024, batch: 64 }
    }
}

/// The coordinator front end.
pub struct Coordinator {
    pool: Sharded,
    stats: StatsHandle,
    /// Chunk size for splitting submissions (`cfg.batch`).
    batch_chunk: usize,
}

/// In-flight batch submitted via [`Coordinator::submit_batch`]: one
/// response channel for the whole batch, responses tagged with their
/// request-index slot.
pub struct BatchHandle {
    rx: Receiver<(u32, Response)>,
    n: usize,
}

impl BatchHandle {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Block until every response arrives; returns them in submission
    /// order.
    pub fn wait(self) -> Vec<Response> {
        let mut out: Vec<Option<Response>> = vec![None; self.n];
        let mut got = 0usize;
        while got < self.n {
            let (slot, resp) = self.rx.recv().expect("coordinator stopped");
            if out[slot as usize].replace(resp).is_none() {
                got += 1;
            }
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        Coordinator::start_with_faults(cfg, None)
    }

    /// Start with a chaos-harness fault injector threaded into the shard
    /// pool (`None` behaves exactly like [`Coordinator::start`]).
    pub fn start_with_faults(cfg: CoordinatorConfig, faults: Option<Arc<FaultInjector>>) -> Self {
        let pool = Sharded::start_with_faults(Coordinator::pool_config(cfg), faults);
        let stats = pool.stats_handle();
        Coordinator { pool, stats, batch_chunk: cfg.batch.max(1) }
    }

    /// Start with observability attached: the shard pool registers its
    /// engine counters, tier counters, per-shard gauges and stage
    /// histograms in `registry`, and every response carries a stamped
    /// lifecycle [`Span`]. The serve layer's constructor (DESIGN.md §12).
    pub fn start_observed(
        cfg: CoordinatorConfig,
        faults: Option<Arc<FaultInjector>>,
        registry: &Registry,
    ) -> Self {
        let pool = Sharded::start_observed(Coordinator::pool_config(cfg), faults, registry);
        let stats = pool.stats_handle();
        Coordinator { pool, stats, batch_chunk: cfg.batch.max(1) }
    }

    fn pool_config(cfg: CoordinatorConfig) -> ShardedConfig {
        ShardedConfig {
            shards: cfg.workers.max(1),
            queue_depth: cfg.queue_depth,
            batch: cfg.batch.max(1),
        }
    }

    /// Number of execution shards.
    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// Submit a request; returns the response channel. Blocks when the
    /// target shard's queue is full (backpressure).
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.pool.submit(vec![(req, Route::Single(tx))]);
        rx
    }

    /// Submit a batch of requests sharing one response channel; responses
    /// are tagged with their request-index slot and reassembled in
    /// submission order by [`BatchHandle::wait`]. This is the throughput
    /// path: one channel allocation per batch instead of one per request.
    pub fn submit_batch(&self, reqs: Vec<Request>) -> BatchHandle {
        let n = reqs.len();
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit_batch_streaming(reqs, 0, &tx);
        BatchHandle { rx, n }
    }

    /// Streaming form of [`Coordinator::submit_batch`]: the response for
    /// `reqs[i]` is sent on the caller-owned channel tagged with slot
    /// `base_slot + i`, *as its lane completes* — there is no reassembly
    /// barrier. The network serve layer uses this to write responses
    /// out-of-order while lanes are still executing (DESIGN.md §8); every
    /// response still carries the caller's original request id.
    ///
    /// The batch is split into `cfg.batch`-sized chunks round-robin
    /// across the shards, so the bounded per-shard queues' backpressure
    /// applies to batch submitters too.
    pub fn submit_batch_streaming(
        &self,
        reqs: Vec<Request>,
        base_slot: u32,
        tx: &Sender<(u32, Response)>,
    ) {
        self.submit_batch_streaming_spanned(
            reqs.into_iter().map(|r| (r, Span::disabled())).collect(),
            base_slot,
            tx,
        );
    }

    /// As [`Coordinator::submit_batch_streaming`], with caller-stamped
    /// lifecycle spans (the serve layer stamps `t_admit` and the sampling
    /// decision at admission). Spans ride the responses back out.
    pub fn submit_batch_streaming_spanned(
        &self,
        reqs: Vec<(Request, Span)>,
        base_slot: u32,
        tx: &Sender<(u32, Response)>,
    ) {
        let mut slot = base_slot;
        let mut iter = reqs.into_iter();
        loop {
            let chunk: Vec<(Request, Route, Span)> = iter
                .by_ref()
                .take(self.batch_chunk)
                .map(|(r, span)| {
                    let routed = (r, Route::Slot(tx.clone(), slot), span);
                    slot += 1;
                    routed
                })
                .collect();
            if chunk.is_empty() {
                break;
            }
            self.pool.submit_spanned(chunk);
        }
    }

    /// Ask every shard to flush its held partial words now.
    pub fn flush(&self) {
        self.pool.flush();
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> Stats {
        self.stats.snapshot()
    }

    /// Stop the coordinator and return final statistics. Chunks submitted
    /// before the stop are fully processed (their responses delivered)
    /// and every shard thread is joined before this returns.
    pub fn shutdown(self) -> Stats {
        let Coordinator { pool, .. } = self;
        pool.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::{simdive_div_w, simdive_mul_w};
    use crate::coordinator::packer::ReqOp;

    fn expect(req: &Request) -> u64 {
        match req.op {
            ReqOp::Mul => simdive_mul_w(req.bits, req.a, req.b, req.w),
            ReqOp::Div => simdive_div_w(req.bits, req.a, req.b, req.w),
        }
    }

    #[test]
    fn stats_account_all_requests() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut handles = Vec::new();
        for i in 0..100 {
            handles.push(coord.submit(Request {
                id: i,
                op: ReqOp::Mul,
                bits: 8,
                w: 8,
                a: 1 + i % 200,
                b: 3,
            }));
        }
        for h in handles {
            h.recv().unwrap();
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 100);
        assert!(s.energy_pj > 0.0);
        assert!(s.words <= 100);
    }

    #[test]
    fn batch_submission_routes_in_order() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut rng = crate::util::Rng::new(0xBEEF);
        let reqs: Vec<Request> = (0..500u64)
            .map(|i| {
                let bits = [8u32, 16, 32][rng.below(3) as usize];
                Request {
                    id: 1000 + i,
                    op: if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                    bits,
                    w: rng.below(crate::arith::W_MAX as u64 + 1) as u32,
                    a: rng.operand(bits),
                    b: rng.operand(bits),
                }
            })
            .collect();
        let handle = coord.submit_batch(reqs.clone());
        assert_eq!(handle.len(), 500);
        let responses = handle.wait();
        for (resp, req) in responses.iter().zip(&reqs) {
            assert_eq!(resp.id, req.id, "responses must come back in submission order");
            assert_eq!(resp.value, expect(req), "req {}", req.id);
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 500);
    }

    #[test]
    fn streaming_submission_delivers_every_response_with_original_ids() {
        // The serve layer's entry point: caller-owned channel, responses
        // arriving as lanes complete (any order), ids preserved.
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (tx, rx) = std::sync::mpsc::channel();
        let reqs: Vec<Request> = (0..300u64)
            .map(|i| Request { id: 5000 + i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i % 255, b: 3 })
            .collect();
        coord.submit_batch_streaming(reqs.clone(), 7, &tx);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..reqs.len() {
            let (slot, resp) = rx.recv().unwrap();
            assert!((7..7 + reqs.len() as u32).contains(&slot), "slot {slot}");
            seen.insert(resp.id, resp.value);
        }
        for req in &reqs {
            assert_eq!(seen[&req.id], simdive_mul_w(8, req.a, req.b, 8), "req {}", req.id);
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 300);
    }

    #[test]
    fn empty_batch_returns_immediately() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let handle = coord.submit_batch(Vec::new());
        assert!(handle.is_empty());
        assert!(handle.wait().is_empty());
        coord.shutdown();
    }

    #[test]
    fn duplicate_ids_each_get_a_response() {
        // Caller-chosen ids need not be unique: routing is positional.
        let coord = Coordinator::start(CoordinatorConfig::default());
        let reqs: Vec<Request> = (0..8)
            .map(|_| Request { id: 7, op: ReqOp::Mul, bits: 8, w: 8, a: 43, b: 10 })
            .collect();
        let responses = coord.submit_batch(reqs).wait();
        assert_eq!(responses.len(), 8);
        for r in responses {
            assert_eq!(r.id, 7);
            assert_eq!(r.value, simdive_mul_w(8, 43, 10, 8));
        }
        coord.shutdown();
    }

    #[test]
    fn mixed_single_and_batch_submission() {
        let coord =
            Coordinator::start(CoordinatorConfig { workers: 2, queue_depth: 64, batch: 16 });
        let single =
            coord.submit(Request { id: 0, op: ReqOp::Div, bits: 16, w: 8, a: 5000, b: 40 });
        let batch = coord.submit_batch(
            (0..32)
                .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i, b: 3 })
                .collect(),
        );
        assert_eq!(single.recv().unwrap().value, simdive_div_w(16, 5000, 40, 8));
        let responses = batch.wait();
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.value, simdive_mul_w(8, 1 + i as u64, 3, 8));
        }
        coord.shutdown();
    }

    #[test]
    fn mixed_w_traffic_shares_one_pool_and_stays_bit_exact() {
        // The headline invariant: one coordinator serves every accuracy
        // tier at once, and each request's answer matches its own w's
        // tables — now across independent shards.
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut rng = crate::util::Rng::new(0x2A11);
        let reqs: Vec<Request> = (0..1_000u64)
            .map(|i| {
                let bits = [8u32, 8, 16, 32][rng.below(4) as usize];
                Request {
                    id: i,
                    op: if rng.below(4) == 0 { ReqOp::Div } else { ReqOp::Mul },
                    bits,
                    w: rng.below(crate::arith::W_MAX as u64 + 1) as u32,
                    a: rng.operand(bits),
                    b: rng.operand(bits),
                }
            })
            .collect();
        let responses = coord.submit_batch(reqs.clone()).wait();
        for (resp, req) in responses.iter().zip(&reqs) {
            assert_eq!(resp.value, expect(req), "req {} (w={})", req.id, req.w);
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 1_000);
        // Mixed-w 8-bit-heavy traffic must still pack multiple lanes per
        // word on average (the shared-pool utilization claim), even with
        // the batch split across shards.
        assert!(s.lane_utilization() > 0.5, "utilization {}", s.lane_utilization());
    }
}
