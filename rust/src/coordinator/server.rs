//! Threaded coordinator: bounded request queue (backpressure), a batcher
//! that drains the queue into the lane packer, a worker pool executing
//! packed words on the SIMDive behavioral unit, and accounting (latency,
//! energy from the calibrated fabric model, lane utilization, power-gated
//! idle lanes). std::thread + mpsc — tokio is unavailable offline
//! (DESIGN.md §1).

use super::packer::{pack_requests, unpack_results, PackedWord, Request};
use crate::arith::simd;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A completed request.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    pub id: u64,
    pub value: u64,
}

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// SIMDive accuracy knob for the executing units.
    pub w: u32,
    /// Bounded queue depth (backpressure: submit blocks when full).
    pub queue_depth: usize,
    /// Max requests drained into one packing batch.
    pub batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 4, w: 8, queue_depth: 1024, batch: 64 }
    }
}

/// Aggregate statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub requests: u64,
    pub words: u64,
    pub active_lanes: u64,
    pub total_lanes: u64,
    /// Estimated energy (pJ) from the calibrated per-word figure, with
    /// idle lanes power-gated to ~10% of their share.
    pub energy_pj: f64,
}

impl Stats {
    pub fn lane_utilization(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.active_lanes as f64 / self.total_lanes as f64
        }
    }
}

struct Shared {
    requests: AtomicU64,
    words: AtomicU64,
    active_lanes: AtomicU64,
    total_lanes: AtomicU64,
    energy_mpj: AtomicU64, // milli-pJ, to keep atomic integer math
}

enum Msg {
    Req(Request, Sender<Response>),
    Flush,
    Stop,
}

/// The coordinator front end.
pub struct Coordinator {
    tx: SyncSender<Msg>,
    batcher: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
}

/// Per-word energy estimate (pJ) with power gating: idle lanes of a word
/// consume `IDLE_FRACTION` of their proportional share.
pub const IDLE_FRACTION: f64 = 0.1;

fn word_energy_pj(per_word_pj: f64, active: u32, lanes: u32) -> f64 {
    let share = per_word_pj / lanes as f64;
    share * active as f64 + share * (lanes - active) as f64 * IDLE_FRACTION
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
        let shared = Arc::new(Shared {
            requests: AtomicU64::new(0),
            words: AtomicU64::new(0),
            active_lanes: AtomicU64::new(0),
            total_lanes: AtomicU64::new(0),
            energy_mpj: AtomicU64::new(0),
        });

        // Calibrated per-word energy of the 32-bit SIMD unit (computed
        // once; the gate-level characterization is cached globally).
        let per_word_pj = simd_word_energy_pj();

        // Worker pool fed by the batcher.
        let (work_tx, work_rx) = sync_channel::<(PackedWord, Vec<(u64, Sender<Response>)>)>(
            cfg.queue_depth.max(16),
        );
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let work_rx = Arc::clone(&work_rx);
            let shared = Arc::clone(&shared);
            let w = cfg.w;
            workers.push(std::thread::spawn(move || loop {
                let item = {
                    let guard = work_rx.lock().unwrap();
                    guard.recv()
                };
                let Ok((pw, pending)) = item else { break };
                let packed = simd::execute(pw.op, pw.word, w);
                let results = unpack_results(&pw, packed);
                shared.words.fetch_add(1, Ordering::Relaxed);
                shared.active_lanes.fetch_add(pw.active_lanes as u64, Ordering::Relaxed);
                shared
                    .total_lanes
                    .fetch_add(pw.lane_count() as u64, Ordering::Relaxed);
                let e = word_energy_pj(per_word_pj, pw.active_lanes, pw.lane_count() as u32);
                shared
                    .energy_mpj
                    .fetch_add((e * 1000.0) as u64, Ordering::Relaxed);
                for (id, value) in results {
                    if let Some((_, tx)) = pending.iter().find(|(pid, _)| *pid == id) {
                        let _ = tx.send(Response { id, value });
                    }
                }
            }));
        }

        // Batcher thread: drain up to `batch` requests, pack, dispatch.
        let shared_b = Arc::clone(&shared);
        let batch_size = cfg.batch.max(1);
        let batcher = std::thread::spawn(move || {
            let mut stop = false;
            while !stop {
                let mut reqs: Vec<Request> = Vec::new();
                let mut senders: Vec<(u64, Sender<Response>)> = Vec::new();
                // Block for the first message, then drain greedily.
                match rx.recv() {
                    Ok(Msg::Req(r, s)) => {
                        senders.push((r.id, s));
                        reqs.push(r);
                    }
                    Ok(Msg::Flush) => {}
                    Ok(Msg::Stop) | Err(_) => break,
                }
                while reqs.len() < batch_size {
                    match rx.try_recv() {
                        Ok(Msg::Req(r, s)) => {
                            senders.push((r.id, s));
                            reqs.push(r);
                        }
                        Ok(Msg::Flush) => break,
                        Ok(Msg::Stop) => {
                            stop = true;
                            break;
                        }
                        Err(_) => break,
                    }
                }
                if reqs.is_empty() {
                    continue;
                }
                shared_b.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
                for pw in pack_requests(&reqs) {
                    let pending: Vec<(u64, Sender<Response>)> = pw
                        .lane_req
                        .iter()
                        .flatten()
                        .filter_map(|id| senders.iter().find(|(sid, _)| sid == id).cloned())
                        .collect();
                    if work_tx.send((pw, pending)).is_err() {
                        return;
                    }
                }
            }
            drop(work_tx);
            for w in workers {
                let _ = w.join();
            }
        });

        Coordinator { tx, batcher: Some(batcher), shared }
    }

    /// Submit a request; returns the response channel. Blocks when the
    /// queue is full (backpressure).
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.tx.send(Msg::Req(req, tx)).expect("coordinator stopped");
        rx
    }

    /// Force the batcher to close the current batch.
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> Stats {
        Stats {
            requests: self.shared.requests.load(Ordering::Relaxed),
            words: self.shared.words.load(Ordering::Relaxed),
            active_lanes: self.shared.active_lanes.load(Ordering::Relaxed),
            total_lanes: self.shared.total_lanes.load(Ordering::Relaxed),
            energy_pj: self.shared.energy_mpj.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }

    /// Stop the coordinator and return final statistics.
    pub fn shutdown(mut self) -> Stats {
        let _ = self.tx.send(Msg::Stop);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        self.stats()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
    }
}

/// Calibrated energy per packed word (pJ), cached.
pub fn simd_word_energy_pj() -> f64 {
    use std::sync::OnceLock;
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let nl = crate::circuits::simdive::simd32(8);
        let cal = crate::fabric::calibrate::fitted();
        let t = crate::fabric::timing::analyze(&nl, cal);
        let p = crate::fabric::power::estimate_at(&nl, cal, 0x51D, 2048, t.critical_ns);
        p.total_mw * t.critical_ns
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::packer::ReqOp;

    #[test]
    fn stats_account_all_requests() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let mut handles = Vec::new();
        for i in 0..100 {
            handles.push(coord.submit(Request {
                id: i,
                op: ReqOp::Mul,
                bits: 8,
                a: 1 + i % 200,
                b: 3,
            }));
        }
        for h in handles {
            h.recv().unwrap();
        }
        let s = coord.shutdown();
        assert_eq!(s.requests, 100);
        assert!(s.energy_pj > 0.0);
        assert!(s.words <= 100);
    }

    #[test]
    fn power_gating_reduces_energy_of_partial_words() {
        let full = word_energy_pj(100.0, 4, 4);
        let one = word_energy_pj(100.0, 1, 4);
        assert!((full - 100.0).abs() < 1e-9);
        assert!(one < 0.4 * full, "gated {one} vs full {full}");
    }

    #[test]
    fn word_energy_is_positive_and_sane() {
        let e = simd_word_energy_pj();
        assert!(e > 1.0 && e < 100_000.0, "per-word energy {e} pJ");
    }
}
