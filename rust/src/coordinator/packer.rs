//! Lane packer: greedy bin-packing of scalar requests into 32-bit SIMD
//! word-ops.
//!
//! Policy (highest lane utilization first):
//! 1. any 32-bit request → `One32`;
//! 2. two 16-bit requests → `Two16`;
//! 3. one 16-bit + up to two 8-bit → `One16Two8`;
//! 4. up to four 8-bit → `Four8`.
//! Partial words are padded with power-gated idle lanes (operands 0,
//! which the hardware's per-lane data-size gating switches off — §3.2).

use crate::arith::simd::{LaneCfg, LaneMode, SimdOp, SimdWord};

/// Request operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqOp {
    Mul,
    Div,
}

/// A scalar arithmetic request.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    pub op: ReqOp,
    pub bits: u32,
    pub a: u64,
    pub b: u64,
}

/// A packed word-op: the SIMD op, operand word, and per-lane request ids
/// (None = idle, power-gated lane).
#[derive(Clone, Debug)]
pub struct PackedWord {
    pub op: SimdOp,
    pub word: SimdWord,
    pub lane_req: [Option<u64>; 4],
    /// Active lanes (for the power-gating model).
    pub active_lanes: u32,
}

impl PackedWord {
    pub fn lane_count(&self) -> usize {
        self.op.cfg.lane_count()
    }
}

fn mode_of(op: ReqOp) -> LaneMode {
    match op {
        ReqOp::Mul => LaneMode::Mul,
        ReqOp::Div => LaneMode::Div,
    }
}

/// Pack a batch of requests into word-ops. Every request appears in
/// exactly one lane of exactly one word.
pub fn pack_requests(reqs: &[Request]) -> Vec<PackedWord> {
    let mut q8: Vec<&Request> = Vec::new();
    let mut q16: Vec<&Request> = Vec::new();
    let mut q32: Vec<&Request> = Vec::new();
    for r in reqs {
        match r.bits {
            8 => q8.push(r),
            16 => q16.push(r),
            32 => q32.push(r),
            other => panic!("unsupported precision {other}"),
        }
    }
    let mut out = Vec::new();

    // 1: 32-bit words.
    for r in q32 {
        out.push(PackedWord {
            op: SimdOp { cfg: LaneCfg::One32, modes: [mode_of(r.op); 4] },
            word: SimdWord::new(r.a as u32, r.b as u32),
            lane_req: [Some(r.id), None, None, None],
            active_lanes: 1,
        });
    }

    // 2: pair up 16-bit requests.
    let mut i16 = 0;
    while i16 + 1 < q16.len() {
        let (r0, r1) = (q16[i16], q16[i16 + 1]);
        let word = SimdWord::pack(LaneCfg::Two16, &[r0.a, r1.a], &[r0.b, r1.b]);
        let mut modes = [LaneMode::Mul; 4];
        modes[0] = mode_of(r0.op); // SimdOp.modes is lane-indexed
        modes[1] = mode_of(r1.op);
        out.push(PackedWord {
            op: SimdOp { cfg: LaneCfg::Two16, modes },
            word,
            lane_req: [Some(r0.id), Some(r1.id), None, None],
            active_lanes: 2,
        });
        i16 += 2;
    }

    // 3: leftover 16-bit + up to two 8-bit → One16Two8.
    if i16 < q16.len() {
        let r16 = q16[i16];
        let e0 = q8.pop();
        let e1 = q8.pop();
        let word = SimdWord::pack(
            LaneCfg::One16Two8,
            &[e0.map_or(0, |r| r.a), e1.map_or(0, |r| r.a), r16.a],
            &[e0.map_or(0, |r| r.b), e1.map_or(0, |r| r.b), r16.b],
        );
        let mut modes = [LaneMode::Mul; 4];
        if let Some(r) = e0 {
            modes[0] = mode_of(r.op);
        }
        if let Some(r) = e1 {
            modes[1] = mode_of(r.op);
        }
        modes[2] = mode_of(r16.op);
        out.push(PackedWord {
            op: SimdOp { cfg: LaneCfg::One16Two8, modes },
            word,
            lane_req: [e0.map(|r| r.id), e1.map(|r| r.id), Some(r16.id), None],
            active_lanes: 1 + e0.is_some() as u32 + e1.is_some() as u32,
        });
    }

    // 4: quads of 8-bit.
    for chunk in q8.chunks(4) {
        let mut a = [0u64; 4];
        let mut b = [0u64; 4];
        let mut modes = [LaneMode::Mul; 4];
        let mut ids = [None; 4];
        for (l, r) in chunk.iter().enumerate() {
            a[l] = r.a;
            b[l] = r.b;
            modes[l] = mode_of(r.op);
            ids[l] = Some(r.id);
        }
        out.push(PackedWord {
            op: SimdOp { cfg: LaneCfg::Four8, modes },
            word: SimdWord::pack(LaneCfg::Four8, &a, &b),
            lane_req: ids,
            active_lanes: chunk.len() as u32,
        });
    }
    out
}

/// Extract lane `lane`'s scalar result from a packed 64-bit result word.
/// Divide results occupy the low N bits of the 2N field.
#[inline]
pub fn lane_value(pw: &PackedWord, packed_result: u64, lane: usize) -> u64 {
    let raw = crate::arith::simd::result_lane(pw.op, packed_result, lane);
    let width = pw.op.cfg.lanes()[lane].1;
    match pw.op.modes[lane] {
        LaneMode::Div if width < 32 => raw & crate::arith::max_val(width),
        _ => raw,
    }
}

/// Unpack per-lane results: `(request id, value)` for active lanes.
pub fn unpack_results(pw: &PackedWord, packed_result: u64) -> Vec<(u64, u64)> {
    let mut out = Vec::with_capacity(pw.lane_count());
    for (l, id) in pw.lane_req.iter().enumerate().take(pw.lane_count()) {
        if let Some(id) = id {
            out.push((*id, lane_value(pw, packed_result, l)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simd;

    fn req(id: u64, op: ReqOp, bits: u32, a: u64, b: u64) -> Request {
        Request { id, op, bits, a, b }
    }

    #[test]
    fn every_request_packed_exactly_once() {
        let mut rng = crate::util::Rng::new(1);
        let reqs: Vec<Request> = (0..200)
            .map(|i| {
                let bits = [8u32, 16, 32][rng.below(3) as usize];
                req(
                    i,
                    if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                    bits,
                    rng.operand(bits),
                    rng.operand(bits),
                )
            })
            .collect();
        let words = pack_requests(&reqs);
        let mut seen = std::collections::HashSet::new();
        for w in &words {
            for id in w.lane_req.iter().flatten() {
                assert!(seen.insert(*id), "id {id} packed twice");
            }
        }
        assert_eq!(seen.len(), reqs.len());
    }

    #[test]
    fn packing_prefers_full_words() {
        let reqs: Vec<Request> =
            (0..8).map(|i| req(i, ReqOp::Mul, 8, 10 + i, 3)).collect();
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 2, "8 byte-ops must pack into 2 words");
        assert!(words.iter().all(|w| w.active_lanes == 4));
    }

    #[test]
    fn mixed_precision_uses_one16two8() {
        let reqs = vec![
            req(0, ReqOp::Mul, 16, 1000, 3),
            req(1, ReqOp::Div, 8, 200, 7),
            req(2, ReqOp::Mul, 8, 11, 13),
        ];
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 1);
        assert_eq!(words[0].op.cfg, simd::LaneCfg::One16Two8);
        assert_eq!(words[0].active_lanes, 3);
    }

    #[test]
    fn results_roundtrip_through_simd_unit() {
        let reqs = vec![
            req(0, ReqOp::Mul, 16, 300, 21),
            req(1, ReqOp::Div, 16, 5000, 40),
            req(2, ReqOp::Mul, 8, 43, 10),
            req(3, ReqOp::Div, 8, 200, 9),
            req(4, ReqOp::Mul, 32, 1 << 20, 3),
        ];
        let words = pack_requests(&reqs);
        let mut results = std::collections::HashMap::new();
        for w in &words {
            let packed = simd::execute(w.op, w.word, 8);
            for (id, v) in unpack_results(w, packed) {
                results.insert(id, v);
            }
        }
        use crate::arith::simdive::{simdive_div, simdive_mul};
        assert_eq!(results[&0], simdive_mul(16, 300, 21));
        assert_eq!(results[&1], simdive_div(16, 5000, 40));
        assert_eq!(results[&2], simdive_mul(8, 43, 10));
        assert_eq!(results[&3], simdive_div(8, 200, 9));
        assert_eq!(results[&4], simdive_mul(32, 1 << 20, 3));
    }

    #[test]
    fn idle_lanes_are_marked() {
        let reqs = vec![req(0, ReqOp::Mul, 8, 5, 6)];
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 1);
        assert_eq!(words[0].active_lanes, 1);
        assert_eq!(words[0].lane_req[1], None);
    }
}
