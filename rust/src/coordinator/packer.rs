//! Lane packer: greedy bin-packing of scalar requests into 32-bit SIMD
//! word-ops, mixed-width *and* mixed-accuracy (coordinator v2 — DESIGN.md
//! §9).
//!
//! Width policy within one accuracy tier (highest lane utilization first):
//! 1. any 32-bit request → `One32`;
//! 2. two 16-bit requests → `Two16`;
//! 3. one 16-bit + up to two 8-bit → `One16Two8`;
//! 4. up to four 8-bit → `Four8`.
//! Partial words are padded with power-gated idle lanes (operands 0,
//! which the hardware's per-lane data-size gating switches off — §3.2).
//!
//! Requests carrying different accuracy knobs `w` use different correction
//! tables (§3.3) and must never share a word, so the [`Assembler`] keeps
//! one sub-queue bank per `w` and drains the banks round-robin: full words
//! are emitted eagerly from whichever tier can form one, partial words
//! only on flush. Held-back partials merge with later arrivals of the
//! same `{bits, w}` tier, which is what lifts lane utilization under
//! mixed-accuracy traffic compared to one isolated pool per `w`.

use crate::arith::simd::{LaneCfg, LaneMode, SimdOp, SimdWord};
use crate::arith::W_MAX;
use std::collections::VecDeque;

/// Request operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqOp {
    Mul,
    Div,
}

/// A scalar arithmetic request.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    pub op: ReqOp,
    pub bits: u32,
    /// Accuracy knob (coefficient LUTs, `0..=W_MAX`) — per request, so one
    /// coordinator serves every accuracy tier (DESIGN.md §9).
    pub w: u32,
    pub a: u64,
    pub b: u64,
}

/// A packed word-op: the SIMD op, operand word, accuracy knob, and
/// per-lane request ids (None = idle, power-gated lane).
#[derive(Clone, Debug)]
pub struct PackedWord {
    pub op: SimdOp,
    pub word: SimdWord,
    /// Accuracy knob shared by every request in this word.
    pub w: u32,
    pub lane_req: [Option<u64>; 4],
    /// Active lanes (for the power-gating model).
    pub active_lanes: u32,
}

impl PackedWord {
    pub fn lane_count(&self) -> usize {
        self.op.cfg.lane_count()
    }
}

fn mode_of(op: ReqOp) -> LaneMode {
    match op {
        ReqOp::Mul => LaneMode::Mul,
        ReqOp::Div => LaneMode::Div,
    }
}

/// A packed word plus the lane-aligned payloads of its requests —
/// `payload[l]` belongs to the request in lane `l`. The coordinator
/// attaches response routes here, so routing a result is a direct index.
pub struct Assembled<T> {
    pub pw: PackedWord,
    pub payload: [Option<T>; 4],
}

/// One accuracy tier's width-split sub-queues.
struct SubQueue<T> {
    q8: VecDeque<(Request, T)>,
    q16: VecDeque<(Request, T)>,
    q32: VecDeque<(Request, T)>,
}

impl<T> SubQueue<T> {
    fn new() -> Self {
        SubQueue { q8: VecDeque::new(), q16: VecDeque::new(), q32: VecDeque::new() }
    }

    /// Form one *full* word (every lane active) if the queued widths allow
    /// it: a 32-bit request, a 16-bit pair, or an 8-bit quad.
    fn pop_full_word(&mut self, w: u32) -> Option<Assembled<T>> {
        if let Some((r, t)) = self.q32.pop_front() {
            return Some(Assembled {
                pw: PackedWord {
                    op: SimdOp { cfg: LaneCfg::One32, modes: [mode_of(r.op); 4] },
                    word: SimdWord::new(r.a as u32, r.b as u32),
                    w,
                    lane_req: [Some(r.id), None, None, None],
                    active_lanes: 1,
                },
                payload: [Some(t), None, None, None],
            });
        }
        if self.q16.len() >= 2 {
            let (r0, t0) = self.q16.pop_front().unwrap();
            let (r1, t1) = self.q16.pop_front().unwrap();
            let word = SimdWord::pack(LaneCfg::Two16, &[r0.a, r1.a], &[r0.b, r1.b]);
            let mut modes = [LaneMode::Mul; 4];
            modes[0] = mode_of(r0.op); // SimdOp.modes is lane-indexed
            modes[1] = mode_of(r1.op);
            return Some(Assembled {
                pw: PackedWord {
                    op: SimdOp { cfg: LaneCfg::Two16, modes },
                    word,
                    w,
                    lane_req: [Some(r0.id), Some(r1.id), None, None],
                    active_lanes: 2,
                },
                payload: [Some(t0), Some(t1), None, None],
            });
        }
        if self.q8.len() >= 4 {
            return Some(self.pop_four8(w));
        }
        None
    }

    /// Form a `Four8` word from up to four queued 8-bit requests (callers
    /// guarantee at least one).
    fn pop_four8(&mut self, w: u32) -> Assembled<T> {
        let mut a = [0u64; 4];
        let mut b = [0u64; 4];
        let mut modes = [LaneMode::Mul; 4];
        let mut ids = [None; 4];
        let mut payload = [None, None, None, None];
        let mut active = 0u32;
        for l in 0..4 {
            let Some((r, t)) = self.q8.pop_front() else { break };
            a[l] = r.a;
            b[l] = r.b;
            modes[l] = mode_of(r.op);
            ids[l] = Some(r.id);
            payload[l] = Some(t);
            active += 1;
        }
        Assembled {
            pw: PackedWord {
                op: SimdOp { cfg: LaneCfg::Four8, modes },
                word: SimdWord::pack(LaneCfg::Four8, &a, &b),
                w,
                lane_req: ids,
                active_lanes: active,
            },
            payload,
        }
    }

    /// Flush the leftovers (≤ one 16-bit, ≤ three 8-bit after full-word
    /// extraction), padding with power-gated idle lanes.
    fn pop_partials(&mut self, w: u32, out: &mut Vec<Assembled<T>>) {
        while let Some(word) = self.pop_full_word(w) {
            out.push(word);
        }
        if let Some((r16, t16)) = self.q16.pop_front() {
            // Leftover 16-bit + up to two 8-bit → One16Two8.
            let e0 = self.q8.pop_front();
            let e1 = self.q8.pop_front();
            let (r0, t0) = match e0 {
                Some((r, t)) => (Some(r), Some(t)),
                None => (None, None),
            };
            let (r1, t1) = match e1 {
                Some((r, t)) => (Some(r), Some(t)),
                None => (None, None),
            };
            let word = SimdWord::pack(
                LaneCfg::One16Two8,
                &[r0.map_or(0, |r| r.a), r1.map_or(0, |r| r.a), r16.a],
                &[r0.map_or(0, |r| r.b), r1.map_or(0, |r| r.b), r16.b],
            );
            let mut modes = [LaneMode::Mul; 4];
            if let Some(r) = r0 {
                modes[0] = mode_of(r.op);
            }
            if let Some(r) = r1 {
                modes[1] = mode_of(r.op);
            }
            modes[2] = mode_of(r16.op);
            out.push(Assembled {
                pw: PackedWord {
                    op: SimdOp { cfg: LaneCfg::One16Two8, modes },
                    word,
                    w,
                    lane_req: [r0.map(|r| r.id), r1.map(|r| r.id), Some(r16.id), None],
                    active_lanes: 1 + r0.is_some() as u32 + r1.is_some() as u32,
                },
                payload: [t0, t1, Some(t16), None],
            });
        }
        while !self.q8.is_empty() {
            let word = self.pop_four8(w);
            out.push(word);
        }
    }
}

/// The mixed-`{bits, w}` word assembler of coordinator v2: one sub-queue
/// bank per accuracy knob, drained round-robin. `T` is an opaque per-
/// request payload carried lane-aligned into the emitted words (the
/// coordinator uses it for response routes).
pub struct Assembler<T> {
    subs: Vec<SubQueue<T>>,
    held: usize,
    /// Round-robin cursor over accuracy tiers, rotated per emission cycle
    /// so no tier is systematically drained first.
    rr: usize,
}

impl<T> Assembler<T> {
    pub fn new() -> Self {
        Assembler {
            subs: (0..=W_MAX).map(|_| SubQueue::new()).collect(),
            held: 0,
            rr: 0,
        }
    }

    /// Requests currently queued (not yet emitted in a word).
    pub fn len(&self) -> usize {
        self.held
    }

    pub fn is_empty(&self) -> bool {
        self.held == 0
    }

    /// Queue one request with its payload.
    ///
    /// Panics on an unsupported width or accuracy knob — the coordinator
    /// front ends validate both before submission.
    pub fn push(&mut self, req: Request, payload: T) {
        assert!(req.w <= W_MAX, "unsupported accuracy knob {}", req.w);
        let sub = &mut self.subs[req.w as usize];
        match req.bits {
            8 => sub.q8.push_back((req, payload)),
            16 => sub.q16.push_back((req, payload)),
            32 => sub.q32.push_back((req, payload)),
            other => panic!("unsupported precision {other}"),
        }
        self.held += 1;
    }

    /// Emit every word that can be formed with all lanes active, round-
    /// robin across accuracy tiers. Partial residues stay queued to merge
    /// with later arrivals of the same `{bits, w}` tier.
    pub fn emit_full(&mut self, out: &mut Vec<Assembled<T>>) {
        loop {
            let mut progress = false;
            for k in 0..self.subs.len() {
                let w = (self.rr + k) % self.subs.len();
                if let Some(word) = self.subs[w].pop_full_word(w as u32) {
                    self.held -= word.pw.active_lanes as usize;
                    out.push(word);
                    progress = true;
                }
            }
            if !progress {
                break;
            }
            self.rr = (self.rr + 1) % self.subs.len();
        }
    }

    /// Emit everything: full words first, then the partial residues padded
    /// with power-gated idle lanes (flush / shutdown path).
    pub fn emit_all(&mut self, out: &mut Vec<Assembled<T>>) {
        self.emit_full(out);
        for w in 0..self.subs.len() {
            let tier = (self.rr + w) % self.subs.len();
            let before = out.len();
            self.subs[tier].pop_partials(tier as u32, out);
            for word in &out[before..] {
                self.held -= word.pw.active_lanes as usize;
            }
        }
        debug_assert_eq!(self.held, 0);
    }
}

impl<T> Default for Assembler<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Pack a batch of requests into word-ops. Every request appears in
/// exactly one lane of exactly one word, and only requests sharing an
/// accuracy knob `w` share a word. One-shot form of [`Assembler`].
pub fn pack_requests(reqs: &[Request]) -> Vec<PackedWord> {
    let mut asm: Assembler<()> = Assembler::new();
    for r in reqs {
        asm.push(*r, ());
    }
    let mut out = Vec::new();
    asm.emit_all(&mut out);
    out.into_iter().map(|a| a.pw).collect()
}

/// Extract lane `lane`'s scalar result from a packed 64-bit result word.
/// Divide results occupy the low N bits of the 2N field.
#[inline]
pub fn lane_value(pw: &PackedWord, packed_result: u64, lane: usize) -> u64 {
    let raw = crate::arith::simd::result_lane(pw.op, packed_result, lane);
    let width = pw.op.cfg.lanes()[lane].1;
    match pw.op.modes[lane] {
        LaneMode::Div if width < 32 => raw & crate::arith::max_val(width),
        _ => raw,
    }
}

/// Unpack per-lane results: `(request id, value)` for active lanes.
pub fn unpack_results(pw: &PackedWord, packed_result: u64) -> Vec<(u64, u64)> {
    let mut out = Vec::with_capacity(pw.lane_count());
    for (l, id) in pw.lane_req.iter().enumerate().take(pw.lane_count()) {
        if let Some(id) = id {
            out.push((*id, lane_value(pw, packed_result, l)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simd;

    fn req(id: u64, op: ReqOp, bits: u32, a: u64, b: u64) -> Request {
        Request { id, op, bits, w: 8, a, b }
    }

    #[test]
    fn every_request_packed_exactly_once() {
        let mut rng = crate::util::Rng::new(1);
        let reqs: Vec<Request> = (0..200)
            .map(|i| {
                let bits = [8u32, 16, 32][rng.below(3) as usize];
                let mut r = req(
                    i,
                    if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                    bits,
                    rng.operand(bits),
                    rng.operand(bits),
                );
                r.w = rng.below(W_MAX as u64 + 1) as u32;
                r
            })
            .collect();
        let words = pack_requests(&reqs);
        let mut seen = std::collections::HashSet::new();
        for w in &words {
            for id in w.lane_req.iter().flatten() {
                assert!(seen.insert(*id), "id {id} packed twice");
            }
        }
        assert_eq!(seen.len(), reqs.len());
    }

    #[test]
    fn packing_prefers_full_words() {
        let reqs: Vec<Request> =
            (0..8).map(|i| req(i, ReqOp::Mul, 8, 10 + i, 3)).collect();
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 2, "8 byte-ops must pack into 2 words");
        assert!(words.iter().all(|w| w.active_lanes == 4));
    }

    #[test]
    fn mixed_precision_uses_one16two8() {
        let reqs = vec![
            req(0, ReqOp::Mul, 16, 1000, 3),
            req(1, ReqOp::Div, 8, 200, 7),
            req(2, ReqOp::Mul, 8, 11, 13),
        ];
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 1);
        assert_eq!(words[0].op.cfg, simd::LaneCfg::One16Two8);
        assert_eq!(words[0].active_lanes, 3);
    }

    #[test]
    fn different_w_never_share_a_word() {
        // Four 8-bit requests that would pack into one word — except they
        // carry two different accuracy knobs, whose correction tables
        // differ (§3.3).
        let mut reqs: Vec<Request> =
            (0..4).map(|i| req(i, ReqOp::Mul, 8, 10 + i, 3)).collect();
        reqs[0].w = 2;
        reqs[1].w = 2;
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 2, "mixed-w quad must split into 2 words");
        for word in &words {
            for (l, id) in word.lane_req.iter().enumerate() {
                if let Some(id) = id {
                    assert_eq!(
                        reqs[*id as usize].w, word.w,
                        "request {id} in lane {l} has w {} but word is tagged {}",
                        reqs[*id as usize].w, word.w
                    );
                }
            }
        }
    }

    #[test]
    fn assembler_holds_partials_until_flush() {
        let mut asm: Assembler<u64> = Assembler::new();
        for i in 0..6u64 {
            asm.push(req(i, ReqOp::Mul, 8, 1 + i, 3), i);
        }
        let mut out = Vec::new();
        asm.emit_full(&mut out);
        // One full quad comes out; two 8-bit requests stay queued.
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].pw.active_lanes, 4);
        assert_eq!(asm.len(), 2);
        // Two more arrivals complete the second quad without a partial.
        asm.push(req(6, ReqOp::Mul, 8, 9, 3), 6);
        asm.push(req(7, ReqOp::Mul, 8, 11, 3), 7);
        asm.emit_full(&mut out);
        assert_eq!(out.len(), 2);
        assert!(asm.is_empty());
        assert!(out.iter().all(|a| a.pw.active_lanes == 4));
        // Payloads ride lane-aligned with their requests.
        for a in &out {
            for (l, p) in a.payload.iter().enumerate() {
                assert_eq!(a.pw.lane_req[l], *p, "payload follows its lane");
            }
        }
    }

    #[test]
    fn assembler_flush_emits_padded_partials() {
        let mut asm: Assembler<()> = Assembler::new();
        asm.push(req(0, ReqOp::Mul, 8, 5, 6), ());
        let mut out = Vec::new();
        asm.emit_full(&mut out);
        assert!(out.is_empty(), "a lone 8-bit request cannot fill a word");
        asm.emit_all(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].pw.active_lanes, 1);
        assert_eq!(out[0].pw.lane_req[1], None);
        assert!(asm.is_empty());
    }

    #[test]
    fn results_roundtrip_through_simd_unit() {
        let reqs = vec![
            req(0, ReqOp::Mul, 16, 300, 21),
            req(1, ReqOp::Div, 16, 5000, 40),
            req(2, ReqOp::Mul, 8, 43, 10),
            req(3, ReqOp::Div, 8, 200, 9),
            req(4, ReqOp::Mul, 32, 1 << 20, 3),
        ];
        let words = pack_requests(&reqs);
        let mut results = std::collections::HashMap::new();
        for w in &words {
            assert_eq!(w.w, 8);
            let packed = simd::execute(w.op, w.word, 8);
            for (id, v) in unpack_results(w, packed) {
                results.insert(id, v);
            }
        }
        use crate::arith::simdive::{simdive_div, simdive_mul};
        assert_eq!(results[&0], simdive_mul(16, 300, 21));
        assert_eq!(results[&1], simdive_div(16, 5000, 40));
        assert_eq!(results[&2], simdive_mul(8, 43, 10));
        assert_eq!(results[&3], simdive_div(8, 200, 9));
        assert_eq!(results[&4], simdive_mul(32, 1 << 20, 3));
    }

    #[test]
    fn idle_lanes_are_marked() {
        let reqs = vec![req(0, ReqOp::Mul, 8, 5, 6)];
        let words = pack_requests(&reqs);
        assert_eq!(words.len(), 1);
        assert_eq!(words[0].active_lanes, 1);
        assert_eq!(words[0].lane_req[1], None);
    }
}
