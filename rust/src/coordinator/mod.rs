//! L3 coordinator: the SIMD dispatch front end.
//!
//! SIMDive's architectural point is that one 32-bit unit serves mixed
//! precision *and* mixed functionality at once. Coordinator v2 (DESIGN.md
//! §9) extends the serving side of that claim to mixed *accuracy*:
//! scalar multiply/divide requests at 8/16/32-bit precision — each
//! carrying its own accuracy knob `w` — are bin-packed by the [`packer`]'s
//! word assembler into 32-bit SIMD word-ops from per-`{bits, w}`
//! sub-queues, with per-word energy/latency accounting from the
//! calibrated fabric model and power gating for idle lanes.
//!
//! Execution lives behind the engine seam (DESIGN.md §10): [`server`]'s
//! [`Coordinator`] is a submission front end over
//! [`engine::Sharded`](crate::engine::Sharded) — N independent shards,
//! each owning its own assembler and rescaled correction tables, fed
//! round-robin. Scaling the pool is a shard-count knob, not a rewrite.
//!
//! Clients that think in error budgets rather than LUT counts go through
//! [`profile`]: a precomputed `{op, width, w} → MRED` table routes a
//! maximum-relative-error budget to the cheapest satisfying `w`.

pub mod packer;
pub mod profile;
pub mod server;

pub use packer::{
    lane_value, pack_requests, unpack_results, Assembled, Assembler, PackedWord, ReqOp, Request,
};
pub use profile::ErrorProfile;
pub use server::{BatchHandle, Coordinator, CoordinatorConfig, Response, Stats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::{simdive_div_w, simdive_mul_w};

    #[test]
    fn end_to_end_through_threads() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            queue_depth: 64,
            batch: 16,
        });
        let mut rng = crate::util::Rng::new(5);
        let mut expected = Vec::new();
        let mut handles = Vec::new();
        for i in 0..500u64 {
            let bits = [8u32, 16, 32][rng.below(3) as usize];
            let w = rng.below(crate::arith::W_MAX as u64 + 1) as u32;
            let a = rng.operand(bits);
            let b = rng.operand(bits);
            let op = if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div };
            expected.push(match op {
                ReqOp::Mul => simdive_mul_w(bits, a, b, w),
                ReqOp::Div => simdive_div_w(bits, a, b, w),
            });
            handles.push(coord.submit(Request { id: i, op, bits, w, a, b }));
        }
        for (h, want) in handles.into_iter().zip(expected) {
            assert_eq!(h.recv().unwrap().value, want);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 500);
        assert!(stats.words >= 125, "words {}", stats.words);
        assert!(stats.lane_utilization() > 0.3);
    }
}
