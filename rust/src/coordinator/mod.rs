//! L3 coordinator: the SIMD dispatch engine.
//!
//! SIMDive's architectural point is that one 32-bit unit serves mixed
//! precision *and* mixed functionality at once. The coordinator realizes
//! the serving side of that claim: scalar multiply/divide requests at
//! 8/16/32-bit precision arrive on a queue, the [`packer`] bin-packs them
//! into 32-bit SIMD word-ops (choosing the one-hot lane configuration per
//! word), and a pool of worker threads executes the packed words on the
//! behavioral SIMDive unit, with per-word energy/latency accounting from
//! the calibrated fabric model and power gating for idle lanes.

pub mod packer;
pub mod server;

pub use packer::{lane_value, pack_requests, unpack_results, PackedWord, ReqOp, Request};
pub use server::{BatchHandle, Coordinator, CoordinatorConfig, Response, Stats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::{simdive_div, simdive_mul};

    #[test]
    fn end_to_end_through_threads() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            w: 8,
            queue_depth: 64,
            batch: 16,
        });
        let mut rng = crate::util::Rng::new(5);
        let mut expected = Vec::new();
        let mut handles = Vec::new();
        for i in 0..500u64 {
            let bits = [8u32, 16, 32][rng.below(3) as usize];
            let a = rng.operand(bits);
            let b = rng.operand(bits);
            let op = if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div };
            expected.push(match op {
                ReqOp::Mul => simdive_mul(bits, a, b),
                ReqOp::Div => simdive_div(bits, a, b),
            });
            handles.push(coord.submit(Request { id: i, op, bits, a, b }));
        }
        for (h, want) in handles.into_iter().zip(expected) {
            assert_eq!(h.recv().unwrap().value, want);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.requests, 500);
        assert!(stats.words >= 125, "words {}", stats.words);
        assert!(stats.lane_utilization() > 0.3);
    }
}
