//! The sharded execution backend: N independent worker shards, each owning
//! its own word [`Assembler`] and its own bank of rescaled correction
//! tables ([`batch::MultiKernel`]), fed round-robin with request chunks
//! (DESIGN.md §10).
//!
//! This replaces the coordinator-v2 layout of one central batcher thread
//! plus an execution-only worker pool: the serial assembly stage is gone,
//! every shard assembles *and* executes, so packing work scales with the
//! shard count instead of bottlenecking on one thread. RAPID
//! (arXiv 2206.13970) makes the same move in hardware — replicate the
//! unit rather than widen one instance.
//!
//! Invariants preserved from the single-pool coordinator:
//!
//! * **Bit-exactness, invariant under shard count.** Every request is
//!   executed independently through the multi-accuracy batched kernel, so
//!   results are identical to the scalar models for any shard count
//!   (property-tested in `tests/engine_props.rs`).
//! * **Lane-aligned response routing.** Routes ride in the assembled
//!   words' payload slots ([`Assembled::payload`]); every route lookup is
//!   a direct index, never a scan.
//! * **Residue handling.** Partial words merge with later same-`{bits,w}`
//!   arrivals, flush the instant a shard's queue idles, and are force-
//!   flushed after [`MAX_HELD_ROUNDS`] full-word rounds under saturation.
//! * **Drain-on-shutdown.** Dropping the pool disconnects the shard
//!   queues; each shard finishes every buffered message, flushes its
//!   residues, and delivers every response before its thread is joined.
//! * **Staged execution (DESIGN.md §13).** A round's `Four8` words run
//!   through the SWAR kernel's decode → approx → correct → assemble
//!   stages *fissioned across the whole round*: each stage is one dense
//!   loop over every staged word, so the shard overlaps stages across
//!   consecutive words instead of running each word start-to-finish.
//!   Per-stage latency rides the `pipe.{decode,approx,correct,assemble}`
//!   histogram instances; words that can't stage (non-`Four8` configs, or
//!   tables outside the SWAR budget) fall back to the lane-wise kernel in
//!   the same round. Either path is bit-identical to
//!   [`batch::MultiKernel::execute`].
//! * **Supervision (DESIGN.md §11).** A panic during a shard's emission
//!   round — injected by the chaos harness or genuine — is caught at the
//!   round boundary; the emitted-but-unrouted words are re-executed
//!   through a freshly built kernel, and only a *double* fault (recovery
//!   panics too) fails the affected requests with
//!   [`RESP_ERR_UNAVAILABLE`] instead of stranding their writers. The
//!   shard thread itself never dies, so shutdown always joins. All
//!   injected faults fire *before* response routing, so recovery can
//!   never deliver a response twice.

use crate::arith::batch;
use crate::arith::simd::{LaneCfg, LaneMode};
use crate::arith::swar::{self, Swar8};
use crate::coordinator::packer::{lane_value, Assembled, Assembler, ReqOp, Request};
use crate::faults::FaultInjector;
use crate::obs::{self, Counter, Gauge, Hist, Registry, Span, Tiers};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// `Response::err` value for a request that shard supervision gave up on
/// (the round panicked and recovery failed too). The serve layer maps any
/// non-zero `err` to `wire::ERR_UNAVAILABLE`; engine-level callers fall
/// back to the scalar models.
pub const RESP_ERR_UNAVAILABLE: u8 = 1;

/// A completed request.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    pub id: u64,
    pub value: u64,
    /// `0` = success; non-zero = the request could not be executed
    /// ([`RESP_ERR_UNAVAILABLE`]) and `value` is meaningless.
    pub err: u8,
    /// Lifecycle span, stamped through submit → fold → emit → done. All
    /// zeros (and never sampled) on unobserved pools.
    pub span: Span,
}

/// Where a completed request's response goes. Routes are attached
/// lane-aligned to the assembled words, so delivery is a direct index.
#[derive(Clone)]
pub enum Route {
    /// Dedicated per-request channel.
    Single(Sender<Response>),
    /// Shared channel + caller-chosen slot (batch and streaming callers).
    Slot(Sender<(u32, Response)>, u32),
}

impl Route {
    #[inline]
    fn send(&self, resp: Response) {
        match self {
            Route::Single(tx) => {
                let _ = tx.send(resp);
            }
            Route::Slot(tx, slot) => {
                let _ = tx.send((*slot, resp));
            }
        }
    }
}

/// Aggregate statistics of a shard pool.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub requests: u64,
    pub words: u64,
    pub active_lanes: u64,
    pub total_lanes: u64,
    /// Estimated energy (pJ) from the calibrated per-word figure, with
    /// idle lanes power-gated to ~10% of their share.
    pub energy_pj: f64,
}

impl Stats {
    pub fn lane_utilization(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.active_lanes as f64 / self.total_lanes as f64
        }
    }

    /// Fold another snapshot into this one (aggregation across pools,
    /// e.g. in multi-process roll-ups).
    pub fn merge(&mut self, other: &Stats) {
        self.requests += other.requests;
        self.words += other.words;
        self.active_lanes += other.active_lanes;
        self.total_lanes += other.total_lanes;
        self.energy_pj += other.energy_pj;
    }
}

/// One shard's observability handles. On an observed pool these are
/// registry-backed (`shard.{i}.*` names, `stage.*` per-shard instances
/// merged on snapshot); on an unobserved pool they are detached atomics
/// that nothing ever records into.
#[derive(Clone)]
struct ShardObs {
    queue_depth: Arc<Gauge>,
    residue_flushes: Arc<Counter>,
    /// Packed `Four8` words the staged SWAR pipeline executed.
    swar_words: Arc<Counter>,
    stage_queue: Arc<Hist>,
    stage_assemble: Arc<Hist>,
    stage_execute: Arc<Hist>,
    /// Per-stage latency of the staged SWAR pipeline inside the execute
    /// stage (one `record_ns_n` per round and stage, weighted by the
    /// round's staged word count).
    pipe_decode: Arc<Hist>,
    pipe_approx: Arc<Hist>,
    pipe_correct: Arc<Hist>,
    pipe_assemble: Arc<Hist>,
}

impl ShardObs {
    fn detached() -> ShardObs {
        ShardObs {
            queue_depth: Arc::new(Gauge::new()),
            residue_flushes: Arc::new(Counter::new()),
            swar_words: Arc::new(Counter::new()),
            stage_queue: Arc::new(Hist::new()),
            stage_assemble: Arc::new(Hist::new()),
            stage_execute: Arc::new(Hist::new()),
            pipe_decode: Arc::new(Hist::new()),
            pipe_approx: Arc::new(Hist::new()),
            pipe_correct: Arc::new(Hist::new()),
            pipe_assemble: Arc::new(Hist::new()),
        }
    }

    fn registered(shard: usize, reg: &Registry) -> ShardObs {
        ShardObs {
            queue_depth: reg.gauge(&format!("shard.{shard}.queue_depth")),
            residue_flushes: reg.counter(&format!("shard.{shard}.residue_flushes")),
            swar_words: reg.counter(&format!("shard.{shard}.swar_words")),
            stage_queue: reg.hist_instance("stage.queue"),
            stage_assemble: reg.hist_instance("stage.assemble"),
            stage_execute: reg.hist_instance("stage.execute"),
            pipe_decode: reg.hist_instance("pipe.decode"),
            pipe_approx: reg.hist_instance("pipe.approx"),
            pipe_correct: reg.hist_instance("pipe.correct"),
            pipe_assemble: reg.hist_instance("pipe.assemble"),
        }
    }
}

/// Pool-wide counters. The aggregate `Stats` API reads these whether or
/// not a registry is attached; the stage/tier/gauge recording on the hot
/// path only runs when `enabled` (i.e. [`Sharded::start_observed`]).
struct Shared {
    requests: Arc<Counter>,
    words: Arc<Counter>,
    active_lanes: Arc<Counter>,
    total_lanes: Arc<Counter>,
    energy_mpj: Arc<Counter>, // milli-pJ, to keep atomic integer math
    /// Observability on: spans are stamped, stage histograms, tier
    /// counters and queue-depth gauges are recorded.
    enabled: bool,
    tiers: Option<Tiers>,
    shards: Vec<ShardObs>,
}

impl Shared {
    fn detached(shards: usize) -> Shared {
        Shared {
            requests: Arc::new(Counter::new()),
            words: Arc::new(Counter::new()),
            active_lanes: Arc::new(Counter::new()),
            total_lanes: Arc::new(Counter::new()),
            energy_mpj: Arc::new(Counter::new()),
            enabled: false,
            tiers: None,
            shards: (0..shards).map(|_| ShardObs::detached()).collect(),
        }
    }

    fn registered(shards: usize, reg: &Registry) -> Shared {
        Shared {
            requests: reg.counter("engine.requests"),
            words: reg.counter("engine.words"),
            active_lanes: reg.counter("engine.active_lanes"),
            total_lanes: reg.counter("engine.total_lanes"),
            energy_mpj: reg.counter("engine.energy_mpj"),
            enabled: true,
            tiers: Some(Tiers::register(reg)),
            shards: (0..shards).map(|i| ShardObs::registered(i, reg)).collect(),
        }
    }
}

/// A cloneable read handle on a pool's counters that stays valid after the
/// pool itself is shut down (the front ends read final stats through it).
#[derive(Clone)]
pub struct StatsHandle(Arc<Shared>);

impl StatsHandle {
    pub fn snapshot(&self) -> Stats {
        Stats {
            requests: self.0.requests.get(),
            words: self.0.words.get(),
            active_lanes: self.0.active_lanes.get(),
            total_lanes: self.0.total_lanes.get(),
            energy_pj: self.0.energy_mpj.get() as f64 / 1000.0,
        }
    }
}

/// Shard-pool configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShardedConfig {
    /// Number of worker shards.
    pub shards: usize,
    /// Bounded per-shard queue depth (backpressure: submission blocks when
    /// a shard's queue is full).
    pub queue_depth: usize,
    /// Requests folded into a shard's assembler between full-word
    /// emission rounds.
    pub batch: usize,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        let shards = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ShardedConfig { shards, queue_depth: 1024, batch: 64 }
    }
}

enum ShardMsg {
    /// A chunk of routed requests with their lifecycle spans (one queue
    /// slot per chunk, so the bounded queue's backpressure applies per
    /// chunk).
    Batch(Vec<(Request, Route, Span)>),
    /// Flush held partial words now.
    Flush,
}

/// Residues survive at most this many consecutive full-word emission
/// rounds under sustained traffic before being force-flushed — a rare
/// `{bits, w}` tier must not be starved by a shard queue that never goes
/// empty. (When the queue *does* go empty, everything flushes
/// immediately — residues never wait on traffic that may not come.)
const MAX_HELD_ROUNDS: u32 = 4;

/// Per-word energy estimate (pJ) with power gating: idle lanes of a word
/// consume `IDLE_FRACTION` of their proportional share.
pub const IDLE_FRACTION: f64 = 0.1;

/// Tier-counter coordinate of a lane's mode.
#[inline]
fn lane_op(mode: LaneMode) -> ReqOp {
    match mode {
        LaneMode::Mul => ReqOp::Mul,
        LaneMode::Div => ReqOp::Div,
    }
}

fn word_energy_pj(per_word_pj: f64, active: u32, lanes: u32) -> f64 {
    let share = per_word_pj / lanes as f64;
    share * active as f64 + share * (lanes - active) as f64 * IDLE_FRACTION
}

/// Milli-pJ increment added to the shared energy counter for a round's
/// energy. Rounds to nearest — truncation would floor every round's
/// fractional milli-pJ and drift `Stats::energy_pj` low over millions of
/// words.
#[inline]
fn energy_increment_mpj(energy_pj: f64) -> u64 {
    (energy_pj * 1000.0).round() as u64
}

/// Calibrated energy per packed word (pJ), cached.
pub fn simd_word_energy_pj() -> f64 {
    use std::sync::OnceLock;
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let nl = crate::circuits::simdive::simd32(8);
        let cal = crate::fabric::calibrate::fitted();
        let t = crate::fabric::timing::analyze(&nl, cal);
        let p = crate::fabric::power::estimate_at(&nl, cal, 0x51D, 2048, t.critical_ns);
        p.total_mw * t.critical_ns
    })
}

/// One shard's working state: its own assembler, its own kernel (all nine
/// accuracy knobs' coefficient rescales hoisted once per shard thread),
/// and reusable execution scratch.
struct ShardCtx {
    kernel: batch::MultiKernel,
    asm: Assembler<(Route, Span)>,
    words: Vec<Assembled<(Route, Span)>>,
    /// Staged-pipeline scratch: `(word index, mul-lane mask)` of every
    /// word in this round taking the SWAR path, plus the per-stage state
    /// vectors the fissioned loops read and write (`staged[si]` ↔
    /// `dec/appr/corr[si]`).
    staged: Vec<(usize, u64)>,
    dec: Vec<swar::Decoded>,
    appr: Vec<swar::Approxed>,
    corr: Vec<swar::Corrected>,
    results: Vec<u64>,
    held_rounds: u32,
    shared: Arc<Shared>,
    per_word_pj: f64,
    /// Chaos-harness injector; `None` in production (zero overhead beyond
    /// the Option check per round).
    faults: Option<Arc<FaultInjector>>,
    /// Observability on ([`Shared::enabled`], hoisted out of the Arc).
    enabled: bool,
    /// This shard's gauge/counter/histogram handles.
    obs: ShardObs,
    tiers: Option<Tiers>,
}

impl ShardCtx {
    fn new(
        shared: Arc<Shared>,
        shard: usize,
        per_word_pj: f64,
        faults: Option<Arc<FaultInjector>>,
    ) -> Self {
        let enabled = shared.enabled;
        let obs = shared.shards[shard].clone();
        let tiers = shared.tiers.clone();
        ShardCtx {
            kernel: batch::MultiKernel::new(),
            asm: Assembler::new(),
            words: Vec::new(),
            staged: Vec::new(),
            dec: Vec::new(),
            appr: Vec::new(),
            corr: Vec::new(),
            results: Vec::new(),
            held_rounds: 0,
            shared,
            per_word_pj,
            faults,
            enabled,
            obs,
            tiers,
        }
    }

    /// Queue a chunk of routed requests; returns how many were folded.
    fn fold(&mut self, chunk: Vec<(Request, Route, Span)>) -> usize {
        let n = chunk.len();
        if self.enabled && n > 0 {
            // One clock read per chunk: every request in the chunk shares
            // t_submit (stamped once at submission) and t_fold, so the
            // queue stage records n samples of one duration in a single
            // bucket increment.
            let t_fold = obs::now_ns();
            self.obs.queue_depth.sub(n as i64);
            let t_submit = chunk[0].2.t_submit_ns;
            self.obs.stage_queue.record_ns_n(t_fold.saturating_sub(t_submit), n as u64);
            for (req, route, mut span) in chunk {
                span.t_fold_ns = t_fold;
                self.asm.push(req, (route, span));
            }
        } else {
            for (req, route, span) in chunk {
                self.asm.push(req, (route, span));
            }
        }
        n
    }

    /// One emission round: emit words (full words only while residues may
    /// still merge, everything when `flush` or the round cap hits),
    /// execute them through the batched kernel, and route every response
    /// lane-aligned.
    ///
    /// Supervision contract: every panic this round can raise — injected
    /// or genuine — fires *before* [`ShardCtx::route_words`] sends the
    /// first response, so [`ShardCtx::recover`] re-executes the emitted
    /// words without ever double-delivering.
    fn run(&mut self, flush: bool) {
        self.words.clear();
        let emit_all = flush || self.held_rounds >= MAX_HELD_ROUNDS;
        if emit_all {
            self.asm.emit_all(&mut self.words);
        } else {
            self.asm.emit_full(&mut self.words);
        }
        self.held_rounds = if self.asm.is_empty() { 0 } else { self.held_rounds + 1 };
        if self.words.is_empty() {
            return;
        }
        let t_emit = self.stamp_emitted(emit_all);

        if let Some(inj) = &self.faults {
            if inj.shard_slow() {
                std::thread::sleep(inj.slow_delay());
            }
            if inj.shard_panic() {
                panic!("injected shard fault");
            }
        }

        self.execute_round();

        if let Some(inj) = &self.faults {
            if inj.delay_completion() {
                std::thread::sleep(inj.completion_delay());
            }
        }

        self.route_words(t_emit);
    }

    /// Execute the round's emitted words into `results`.
    ///
    /// `Four8` words whose `w`-tier table admits the packed kernel run
    /// through the staged SWAR pipeline with each stage *fissioned across
    /// the whole round*: decode over every staged word, then approx over
    /// every staged word, and so on — four dense, branch-free loops whose
    /// iterations are independent, so the shard overlaps a stage across
    /// consecutive words (and LLVM can pipeline the loop bodies) instead
    /// of dragging each word through all four stages back-to-back.
    /// Per-stage wall time lands in the `pipe.*` histogram instances,
    /// weighted by the round's staged word count; the decode stamp also
    /// covers the eligibility partition.
    ///
    /// Words that cannot stage — non-`Four8` lane configs, or a table
    /// outside the SWAR guard-bit budget — execute lane-wise through
    /// [`batch::MultiKernel::execute`] in the same round. Both paths are
    /// bit-identical to the lane-wise kernel (`tests/engine_props.rs`
    /// pins Sharded ≡ Reference over mixed streams).
    fn execute_round(&mut self) {
        self.results.clear();
        self.results.resize(self.words.len(), 0);
        self.staged.clear();
        self.dec.clear();
        self.appr.clear();
        self.corr.clear();

        // Stage 1 — decode: partition the round, spread each eligible
        // word's operand bytes into SWAR fields, mask zero lanes, align
        // all four lanes into the log domain.
        let t0 = if self.enabled { obs::now_ns() } else { 0 };
        for (i, job) in self.words.iter().enumerate() {
            let pw = &job.pw;
            if pw.op.cfg == LaneCfg::Four8 && self.kernel.swar8(pw.w).is_some() {
                self.staged.push((i, swar::mul_lane_mask(&pw.op.modes)));
                self.dec.push(Swar8::decode4(
                    swar::spread_bytes(pw.word.a),
                    swar::spread_bytes(pw.word.b),
                ));
            }
        }
        let t1 = if self.enabled { obs::now_ns() } else { 0 };

        // Stage 2 — approx: Mitchell's log-domain sums + table indices.
        self.appr.extend(self.dec.iter().map(|&d| Swar8::approx4(d)));
        let t2 = if self.enabled { obs::now_ns() } else { 0 };

        // Stage 3 — correct: per-word `w` selects the table bank.
        for (si, &(wi, _)) in self.staged.iter().enumerate() {
            let k =
                self.kernel.swar8(self.words[wi].pw.w).expect("staged words have SWAR tables");
            self.corr.push(k.correct4(self.appr[si]));
        }
        let t3 = if self.enabled { obs::now_ns() } else { 0 };

        // Stage 4 — assemble: antilog, saturate, zero-mask, mode-select.
        for (si, &(wi, mask)) in self.staged.iter().enumerate() {
            self.results[wi] = Swar8::assemble4(self.corr[si], mask);
        }
        let t4 = if self.enabled { obs::now_ns() } else { 0 };

        // Fallback pass: everything the partition skipped, lane-wise.
        // `staged` is sorted by word index, so one forward cursor
        // identifies the staged words without a lookup structure.
        let mut staged_it = self.staged.iter().peekable();
        for (i, job) in self.words.iter().enumerate() {
            if staged_it.peek().is_some_and(|&&(wi, _)| wi == i) {
                staged_it.next();
                continue;
            }
            self.results[i] = self.kernel.execute(job.pw.w, job.pw.op, job.pw.word);
        }

        if self.enabled && !self.staged.is_empty() {
            let n = self.staged.len() as u64;
            self.obs.swar_words.add(n);
            self.obs.pipe_decode.record_ns_n(t1.saturating_sub(t0), n);
            self.obs.pipe_approx.record_ns_n(t2.saturating_sub(t1), n);
            self.obs.pipe_correct.record_ns_n(t3.saturating_sub(t2), n);
            self.obs.pipe_assemble.record_ns_n(t4.saturating_sub(t3), n);
        }
    }

    /// Stamp `t_emit` on every routed lane of the emitted words, record
    /// the assemble stage (fold → emit: how long each request waited in
    /// the assembler — this is the one per-lane recording, because
    /// residue lanes genuinely wait extra rounds), and count the partial
    /// words an emit-everything round releases as residue flushes.
    /// Returns the round's emit timestamp (0 when observability is off).
    fn stamp_emitted(&mut self, emit_all: bool) -> u64 {
        if !self.enabled {
            return 0;
        }
        let t_emit = obs::now_ns();
        let mut residues = 0u64;
        for job in &mut self.words {
            if emit_all && (job.pw.active_lanes as usize) < job.pw.lane_count() {
                residues += 1;
            }
            for slot in job.payload.iter_mut() {
                if let Some((_, span)) = slot {
                    span.t_emit_ns = t_emit;
                    self.obs.stage_assemble.record_ns(t_emit.saturating_sub(span.t_fold_ns));
                }
            }
        }
        if residues > 0 {
            self.obs.residue_flushes.add(residues);
        }
        t_emit
    }

    /// Deliver one executed round: route every lane's response (span
    /// stamped `t_done`, its `{op, bits, w}` tier counted), fold the
    /// round into the shared counters, and mark the words routed (the
    /// cleared buffer is what tells [`ShardCtx::recover`] there is
    /// nothing left to re-execute).
    fn route_words(&mut self, t_emit_ns: u64) {
        let (mut active, mut total) = (0u64, 0u64);
        let mut energy = 0.0f64;
        let t_done = if self.enabled { obs::now_ns() } else { 0 };
        let mut routed = 0u64;
        for (job, &packed) in self.words.iter().zip(self.results.iter()) {
            let pw = &job.pw;
            active += pw.active_lanes as u64;
            total += pw.lane_count() as u64;
            energy += word_energy_pj(self.per_word_pj, pw.active_lanes, pw.lane_count() as u32);
            for (l, slot) in job.payload.iter().enumerate().take(pw.lane_count()) {
                if let Some((route, span)) = slot {
                    let id = pw.lane_req[l].expect("routed lane carries an id");
                    let mut span = *span;
                    span.t_done_ns = t_done;
                    if let Some(tiers) = &self.tiers {
                        tiers.add(lane_op(pw.op.modes[l]), pw.op.cfg.lanes()[l].1, pw.w, 1);
                    }
                    route.send(Response { id, value: lane_value(pw, packed, l), err: 0, span });
                    routed += 1;
                }
            }
        }
        if self.enabled {
            // All lanes of a round share emit → done; one bucket add.
            self.obs.stage_execute.record_ns_n(t_done.saturating_sub(t_emit_ns), routed);
        }
        let words = self.words.len() as u64;
        self.count_round(words, active, total, energy);
        self.words.clear();
    }

    fn count_round(&self, words: u64, active: u64, total: u64, energy: f64) {
        self.shared.words.add(words);
        self.shared.active_lanes.add(active);
        self.shared.total_lanes.add(total);
        self.shared.energy_mpj.add(energy_increment_mpj(energy));
    }

    /// Recover from a panicked round: the emitted words still hold every
    /// route, so re-execute each word through a *freshly built* kernel —
    /// independent of whatever state the panicking one was left in — and
    /// deliver its lanes. A word whose re-execution panics too (a double
    /// fault: the kernel itself is broken for this input, or the chaos
    /// harness forces it via `recover_panic_ppm`) fails its requests with
    /// [`RESP_ERR_UNAVAILABLE`] rather than stranding their writers.
    /// Either way every routed lane gets exactly one response and the
    /// shard thread survives.
    fn recover(&mut self) {
        if self.words.is_empty() {
            return; // the panic predated emission: nothing in flight
        }
        let fresh = catch_unwind(batch::MultiKernel::new).ok();
        let (mut active, mut total) = (0u64, 0u64);
        let mut energy = 0.0f64;
        let t_done = if self.enabled { obs::now_ns() } else { 0 };
        for job in &self.words {
            let pw = &job.pw;
            let forced = self.faults.as_ref().is_some_and(|f| f.recover_panic());
            let packed: Option<u64> = if forced {
                None
            } else {
                fresh
                    .as_ref()
                    .and_then(|k| catch_unwind(AssertUnwindSafe(|| k.execute(pw.w, pw.op, pw.word))).ok())
            };
            active += pw.active_lanes as u64;
            total += pw.lane_count() as u64;
            energy += word_energy_pj(self.per_word_pj, pw.active_lanes, pw.lane_count() as u32);
            for (l, slot) in job.payload.iter().enumerate().take(pw.lane_count()) {
                if let Some((route, span)) = slot {
                    let id = pw.lane_req[l].expect("routed lane carries an id");
                    let mut span = *span;
                    span.t_done_ns = t_done;
                    // Recovered (or failed) lanes count in the same tier
                    // and stage accounting as clean rounds, so Σ tier ==
                    // requests holds whether or not supervision fired.
                    if let Some(tiers) = &self.tiers {
                        tiers.add(lane_op(pw.op.modes[l]), pw.op.cfg.lanes()[l].1, pw.w, 1);
                    }
                    if self.enabled && span.t_emit_ns > 0 {
                        self.obs.stage_execute.record_ns(t_done.saturating_sub(span.t_emit_ns));
                    }
                    match packed {
                        Some(p) => {
                            route.send(Response { id, value: lane_value(pw, p, l), err: 0, span })
                        }
                        None => {
                            route.send(Response { id, value: 0, err: RESP_ERR_UNAVAILABLE, span })
                        }
                    }
                }
            }
        }
        let words = self.words.len() as u64;
        self.count_round(words, active, total, energy);
        self.words.clear();
        if let Some(k) = fresh {
            self.kernel = k; // replace the possibly-poisoned kernel
        }
    }
}

/// Run one round under supervision: a panic (injected or genuine) is
/// caught at the round boundary and handed to recovery. The shard thread
/// itself never unwinds away — shutdown always joins.
fn run_supervised(ctx: &mut ShardCtx, flush: bool) {
    if catch_unwind(AssertUnwindSafe(|| ctx.run(flush))).is_err() {
        ctx.recover();
    }
}

/// One shard thread: drain bursts from the shard queue into the local
/// assembler, emit full words every `batch` requests, and flush everything
/// the instant the queue goes empty (or on Flush / disconnect) — a partial
/// residue never waits on traffic that may not come.
fn shard_loop(
    rx: Receiver<ShardMsg>,
    shared: Arc<Shared>,
    shard: usize,
    batch_size: usize,
    per_word_pj: f64,
    faults: Option<Arc<FaultInjector>>,
) {
    let mut ctx = ShardCtx::new(shared, shard, per_word_pj, faults);
    loop {
        // Between bursts the assembler is empty (every burst ends in a
        // flush), so blocking indefinitely strands nothing.
        let mut folded = 0usize;
        match rx.recv() {
            Ok(ShardMsg::Batch(chunk)) => folded += ctx.fold(chunk),
            Ok(ShardMsg::Flush) => {}
            Err(_) => break,
        }
        // Drain the burst.
        loop {
            if folded >= batch_size {
                folded = 0;
                run_supervised(&mut ctx, false);
            }
            match rx.try_recv() {
                Ok(ShardMsg::Batch(chunk)) => folded += ctx.fold(chunk),
                Ok(ShardMsg::Flush) => run_supervised(&mut ctx, true),
                // Empty (burst over) or disconnected — either way flush
                // below; a disconnect also ends the outer loop at its
                // next recv.
                Err(_) => break,
            }
        }
        // Burst over (idle queue or disconnect): flush everything held.
        run_supervised(&mut ctx, true);
    }
    // Defensive final flush — unreachable residues would otherwise strand
    // their routes (the loop above always flushes before looping back).
    run_supervised(&mut ctx, true);
}

/// The sharded backend: N shard threads behind bounded queues, dispatched
/// round-robin at chunk granularity.
pub struct Sharded {
    txs: Vec<SyncSender<ShardMsg>>,
    handles: Vec<JoinHandle<()>>,
    rr: AtomicUsize,
    shared: Arc<Shared>,
}

impl Sharded {
    /// Spawn the shard pool.
    pub fn start(cfg: ShardedConfig) -> Sharded {
        Sharded::start_with_faults(cfg, None)
    }

    /// Spawn the shard pool with a chaos-harness fault injector threaded
    /// into every shard (`None` behaves exactly like [`Sharded::start`]).
    pub fn start_with_faults(cfg: ShardedConfig, faults: Option<Arc<FaultInjector>>) -> Sharded {
        let shared = Shared::detached(cfg.shards.max(1));
        Sharded::start_inner(cfg, faults, shared)
    }

    /// Spawn the shard pool with observability attached: engine counters,
    /// per-`{op, bits, w}` tier counters, per-shard queue-depth gauges and
    /// residue-flush counters, and `stage.{queue,assemble,execute}`
    /// histogram instances all register in `registry`, and every response
    /// carries a stamped [`Span`]. The unobserved constructors pay none of
    /// this (one `bool` test per round).
    pub fn start_observed(
        cfg: ShardedConfig,
        faults: Option<Arc<FaultInjector>>,
        registry: &Registry,
    ) -> Sharded {
        let shared = Shared::registered(cfg.shards.max(1), registry);
        Sharded::start_inner(cfg, faults, shared)
    }

    fn start_inner(
        cfg: ShardedConfig,
        faults: Option<Arc<FaultInjector>>,
        shared: Shared,
    ) -> Sharded {
        let n = cfg.shards.max(1);
        let batch = cfg.batch.max(1);
        let per_word_pj = simd_word_energy_pj();
        let shared = Arc::new(shared);
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = sync_channel::<ShardMsg>(cfg.queue_depth.max(16));
            txs.push(tx);
            let shared = Arc::clone(&shared);
            let faults = faults.clone();
            handles.push(
                std::thread::spawn(move || shard_loop(rx, shared, i, batch, per_word_pj, faults)),
            );
        }
        Sharded { txs, handles, rr: AtomicUsize::new(0), shared }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Submit one chunk of routed requests to the next shard round-robin.
    /// Chunks stay contiguous (they assemble together on one shard — the
    /// packing quality of a submission tracks its chunk size). Blocks when
    /// that shard's bounded queue is full (backpressure).
    pub fn submit(&self, chunk: Vec<(Request, Route)>) {
        self.submit_spanned(
            chunk.into_iter().map(|(req, route)| (req, route, Span::disabled())).collect(),
        );
    }

    /// As [`Sharded::submit`], with caller-stamped lifecycle spans (the
    /// serve path stamps `t_admit` at admission). On an observed pool the
    /// chunk's spans get `t_submit` and the target shard stamped here —
    /// one clock read per chunk — and the shard's queue-depth gauge rises
    /// until the shard folds the chunk.
    pub fn submit_spanned(&self, mut chunk: Vec<(Request, Route, Span)>) {
        if chunk.is_empty() {
            return;
        }
        self.shared.requests.add(chunk.len() as u64);
        let shard = self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        if self.shared.enabled {
            let t_submit = obs::now_ns();
            for (_, _, span) in chunk.iter_mut() {
                span.t_submit_ns = t_submit;
                span.shard = shard as u8;
            }
            self.shared.shards[shard].queue_depth.add(chunk.len() as i64);
        }
        self.txs[shard].send(ShardMsg::Batch(chunk)).expect("engine shards stopped");
    }

    /// Ask every shard to flush its held partial words now.
    pub fn flush(&self) {
        for tx in &self.txs {
            let _ = tx.send(ShardMsg::Flush);
        }
    }

    /// A read handle on the pool counters that survives shutdown.
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle(Arc::clone(&self.shared))
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> Stats {
        self.stats_handle().snapshot()
    }

    /// Stop the pool and return final statistics. Chunks submitted before
    /// the shutdown are fully executed (their responses delivered) and
    /// every shard thread is joined before this returns.
    pub fn shutdown(mut self) -> Stats {
        self.join_shards();
        self.stats()
    }

    fn join_shards(&mut self) {
        self.txs.clear(); // disconnect: shards drain their queues and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Sharded {
    fn drop(&mut self) {
        self.join_shards();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::simdive_mul_w;
    use crate::coordinator::packer::ReqOp;
    use std::sync::mpsc::channel;

    #[test]
    fn power_gating_reduces_energy_of_partial_words() {
        let full = word_energy_pj(100.0, 4, 4);
        let one = word_energy_pj(100.0, 1, 4);
        assert!((full - 100.0).abs() < 1e-9);
        assert!(one < 0.4 * full, "gated {one} vs full {full}");
    }

    #[test]
    fn word_energy_is_positive_and_sane() {
        let e = simd_word_energy_pj();
        assert!(e > 1.0 && e < 100_000.0, "per-word energy {e} pJ");
    }

    #[test]
    fn energy_accumulation_rounds_not_floors() {
        // The increment actually used by the shard loop must round to the
        // nearest milli-pJ; truncation (`as u64` on the raw product) would
        // floor 0.4999 pJ to 499 and 0.0006 pJ to 0.
        assert_eq!(energy_increment_mpj(0.4999), 500);
        assert_eq!(energy_increment_mpj(0.0006), 1);
        assert_eq!(energy_increment_mpj(0.0004), 0);
        assert!(energy_increment_mpj(0.4999) > (0.4999f64 * 1000.0) as u64);
    }

    #[test]
    fn routed_submission_executes_and_counts() {
        let pool = Sharded::start(ShardedConfig { shards: 2, queue_depth: 64, batch: 8 });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = (0..100u64)
            .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i % 200, b: 3 })
            .collect();
        let chunk: Vec<(Request, Route)> = reqs
            .iter()
            .enumerate()
            .map(|(k, r)| (*r, Route::Slot(tx.clone(), k as u32)))
            .collect();
        pool.submit(chunk);
        let mut got = vec![None; reqs.len()];
        for _ in 0..reqs.len() {
            let (slot, resp) = rx.recv().unwrap();
            assert!(got[slot as usize].replace(resp).is_none(), "slot {slot} twice");
        }
        for (k, r) in reqs.iter().enumerate() {
            let resp = got[k].unwrap();
            assert_eq!(resp.id, r.id);
            assert_eq!(resp.value, simdive_mul_w(8, r.a, r.b, 8));
        }
        let s = pool.shutdown();
        assert_eq!(s.requests, 100);
        assert!(s.energy_pj > 0.0);
        assert!(s.words > 0 && s.words <= 100);
    }

    #[test]
    fn empty_submit_is_a_no_op() {
        let pool = Sharded::start(ShardedConfig { shards: 1, queue_depth: 16, batch: 4 });
        pool.submit(Vec::new());
        let s = pool.shutdown();
        assert_eq!(s.requests, 0);
        assert_eq!(s.words, 0);
    }

    #[test]
    fn single_route_delivers() {
        let pool = Sharded::start(ShardedConfig { shards: 1, queue_depth: 16, batch: 4 });
        let (tx, rx) = channel();
        let req = Request { id: 7, op: ReqOp::Mul, bits: 8, w: 8, a: 43, b: 10 };
        pool.submit(vec![(req, Route::Single(tx))]);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.value, simdive_mul_w(8, 43, 10, 8));
        assert!(!resp.span.sampled, "unobserved pools never sample");
        pool.shutdown();
    }

    #[test]
    fn observed_pool_records_stages_tiers_and_spans() {
        let reg = Registry::new();
        let pool = Sharded::start_observed(
            ShardedConfig { shards: 2, queue_depth: 64, batch: 8 },
            None,
            &reg,
        );
        let (tx, rx) = channel();
        let chunk: Vec<(Request, Route, Span)> = (0..40u64)
            .map(|i| {
                let req = Request { id: i, op: ReqOp::Mul, bits: 8, w: 4, a: 1 + i, b: 3 };
                (req, Route::Slot(tx.clone(), i as u32), Span::admitted(false, 0, 8, 4))
            })
            .collect();
        pool.submit_spanned(chunk);
        let mut spans = Vec::new();
        for _ in 0..40 {
            let (_, resp) = rx.recv().unwrap();
            assert_eq!(resp.err, 0);
            spans.push(resp.span);
        }
        pool.shutdown();
        for s in &spans {
            assert!(s.t_admit_ns > 0, "admission stamp survives the pipeline");
            assert!(s.t_submit_ns >= s.t_admit_ns);
            assert!(s.t_fold_ns >= s.t_submit_ns);
            assert!(s.t_emit_ns >= s.t_fold_ns);
            assert!(s.t_done_ns >= s.t_emit_ns);
        }
        let snap = reg.snapshot();
        assert_eq!(snap.counter("engine.requests"), Some(40));
        assert_eq!(snap.counter("tier.mul8.w4"), Some(40), "every lane counted in its tier");
        assert_eq!(snap.hist("stage.queue").unwrap().count(), 40);
        assert_eq!(snap.hist("stage.assemble").unwrap().count(), 40);
        assert_eq!(snap.hist("stage.execute").unwrap().count(), 40);
        assert_eq!(snap.gauge("shard.0.queue_depth"), Some(0), "drained after shutdown");
        assert_eq!(snap.gauge("shard.1.queue_depth"), Some(0));
        // 40 mul8 requests pack into 10 Four8 words, all of which take the
        // staged SWAR pipeline: the per-shard counter and every pipe stage
        // histogram must account for exactly those words.
        let swar_total = snap.counter("shard.0.swar_words").unwrap_or(0)
            + snap.counter("shard.1.swar_words").unwrap_or(0);
        assert_eq!(swar_total, 10, "every Four8 word staged through the SWAR pipeline");
        for stage in ["pipe.decode", "pipe.approx", "pipe.correct", "pipe.assemble"] {
            assert_eq!(snap.hist(stage).unwrap().count(), 10, "{stage}");
        }
    }

    #[test]
    fn residue_flush_is_counted_and_tiered() {
        let reg = Registry::new();
        let pool = Sharded::start_observed(
            ShardedConfig { shards: 1, queue_depth: 16, batch: 4 },
            None,
            &reg,
        );
        let (tx, rx) = channel();
        let req = Request { id: 1, op: ReqOp::Div, bits: 8, w: 0, a: 200, b: 7 };
        pool.submit_spanned(vec![(req, Route::Single(tx), Span::admitted(true, 1, 8, 0))]);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.err, 0);
        assert!(resp.span.sampled, "the sampling decision rides the span");
        pool.shutdown();
        let snap = reg.snapshot();
        assert_eq!(
            snap.counter("shard.0.residue_flushes"),
            Some(1),
            "a lone 8-bit request flushes as a partial word"
        );
        assert_eq!(snap.counter("tier.div8.w0"), Some(1));
    }
}
