//! The sharded execution backend: N independent worker shards, each owning
//! its own word [`Assembler`] and its own bank of rescaled correction
//! tables ([`batch::MultiKernel`]), fed round-robin with request chunks
//! (DESIGN.md §10).
//!
//! This replaces the coordinator-v2 layout of one central batcher thread
//! plus an execution-only worker pool: the serial assembly stage is gone,
//! every shard assembles *and* executes, so packing work scales with the
//! shard count instead of bottlenecking on one thread. RAPID
//! (arXiv 2206.13970) makes the same move in hardware — replicate the
//! unit rather than widen one instance.
//!
//! Invariants preserved from the single-pool coordinator:
//!
//! * **Bit-exactness, invariant under shard count.** Every request is
//!   executed independently through the multi-accuracy batched kernel, so
//!   results are identical to the scalar models for any shard count
//!   (property-tested in `tests/engine_props.rs`).
//! * **Lane-aligned response routing.** Routes ride in the assembled
//!   words' payload slots ([`Assembled::payload`]); every route lookup is
//!   a direct index, never a scan.
//! * **Residue handling.** Partial words merge with later same-`{bits,w}`
//!   arrivals, flush the instant a shard's queue idles, and are force-
//!   flushed after [`MAX_HELD_ROUNDS`] full-word rounds under saturation.
//! * **Drain-on-shutdown.** Dropping the pool disconnects the shard
//!   queues; each shard finishes every buffered message, flushes its
//!   residues, and delivers every response before its thread is joined.
//! * **Supervision (DESIGN.md §11).** A panic during a shard's emission
//!   round — injected by the chaos harness or genuine — is caught at the
//!   round boundary; the emitted-but-unrouted words are re-executed
//!   through a freshly built kernel, and only a *double* fault (recovery
//!   panics too) fails the affected requests with
//!   [`RESP_ERR_UNAVAILABLE`] instead of stranding their writers. The
//!   shard thread itself never dies, so shutdown always joins. All
//!   injected faults fire *before* response routing, so recovery can
//!   never deliver a response twice.

use crate::arith::batch;
use crate::coordinator::packer::{lane_value, Assembled, Assembler, Request};
use crate::faults::FaultInjector;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// `Response::err` value for a request that shard supervision gave up on
/// (the round panicked and recovery failed too). The serve layer maps any
/// non-zero `err` to `wire::ERR_UNAVAILABLE`; engine-level callers fall
/// back to the scalar models.
pub const RESP_ERR_UNAVAILABLE: u8 = 1;

/// A completed request.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    pub id: u64,
    pub value: u64,
    /// `0` = success; non-zero = the request could not be executed
    /// ([`RESP_ERR_UNAVAILABLE`]) and `value` is meaningless.
    pub err: u8,
}

/// Where a completed request's response goes. Routes are attached
/// lane-aligned to the assembled words, so delivery is a direct index.
#[derive(Clone)]
pub enum Route {
    /// Dedicated per-request channel.
    Single(Sender<Response>),
    /// Shared channel + caller-chosen slot (batch and streaming callers).
    Slot(Sender<(u32, Response)>, u32),
}

impl Route {
    #[inline]
    fn send(&self, resp: Response) {
        match self {
            Route::Single(tx) => {
                let _ = tx.send(resp);
            }
            Route::Slot(tx, slot) => {
                let _ = tx.send((*slot, resp));
            }
        }
    }
}

/// Aggregate statistics of a shard pool.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub requests: u64,
    pub words: u64,
    pub active_lanes: u64,
    pub total_lanes: u64,
    /// Estimated energy (pJ) from the calibrated per-word figure, with
    /// idle lanes power-gated to ~10% of their share.
    pub energy_pj: f64,
}

impl Stats {
    pub fn lane_utilization(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.active_lanes as f64 / self.total_lanes as f64
        }
    }

    /// Fold another snapshot into this one (aggregation across pools,
    /// e.g. in multi-process roll-ups).
    pub fn merge(&mut self, other: &Stats) {
        self.requests += other.requests;
        self.words += other.words;
        self.active_lanes += other.active_lanes;
        self.total_lanes += other.total_lanes;
        self.energy_pj += other.energy_pj;
    }
}

#[derive(Default)]
struct Shared {
    requests: AtomicU64,
    words: AtomicU64,
    active_lanes: AtomicU64,
    total_lanes: AtomicU64,
    energy_mpj: AtomicU64, // milli-pJ, to keep atomic integer math
}

/// A cloneable read handle on a pool's counters that stays valid after the
/// pool itself is shut down (the front ends read final stats through it).
#[derive(Clone)]
pub struct StatsHandle(Arc<Shared>);

impl StatsHandle {
    pub fn snapshot(&self) -> Stats {
        Stats {
            requests: self.0.requests.load(Ordering::Relaxed),
            words: self.0.words.load(Ordering::Relaxed),
            active_lanes: self.0.active_lanes.load(Ordering::Relaxed),
            total_lanes: self.0.total_lanes.load(Ordering::Relaxed),
            energy_pj: self.0.energy_mpj.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }
}

/// Shard-pool configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShardedConfig {
    /// Number of worker shards.
    pub shards: usize,
    /// Bounded per-shard queue depth (backpressure: submission blocks when
    /// a shard's queue is full).
    pub queue_depth: usize,
    /// Requests folded into a shard's assembler between full-word
    /// emission rounds.
    pub batch: usize,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        let shards = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ShardedConfig { shards, queue_depth: 1024, batch: 64 }
    }
}

enum ShardMsg {
    /// A chunk of routed requests (one queue slot per chunk, so the
    /// bounded queue's backpressure applies per chunk).
    Batch(Vec<(Request, Route)>),
    /// Flush held partial words now.
    Flush,
}

/// Residues survive at most this many consecutive full-word emission
/// rounds under sustained traffic before being force-flushed — a rare
/// `{bits, w}` tier must not be starved by a shard queue that never goes
/// empty. (When the queue *does* go empty, everything flushes
/// immediately — residues never wait on traffic that may not come.)
const MAX_HELD_ROUNDS: u32 = 4;

/// Per-word energy estimate (pJ) with power gating: idle lanes of a word
/// consume `IDLE_FRACTION` of their proportional share.
pub const IDLE_FRACTION: f64 = 0.1;

fn word_energy_pj(per_word_pj: f64, active: u32, lanes: u32) -> f64 {
    let share = per_word_pj / lanes as f64;
    share * active as f64 + share * (lanes - active) as f64 * IDLE_FRACTION
}

/// Milli-pJ increment added to the shared energy counter for a round's
/// energy. Rounds to nearest — truncation would floor every round's
/// fractional milli-pJ and drift `Stats::energy_pj` low over millions of
/// words.
#[inline]
fn energy_increment_mpj(energy_pj: f64) -> u64 {
    (energy_pj * 1000.0).round() as u64
}

/// Calibrated energy per packed word (pJ), cached.
pub fn simd_word_energy_pj() -> f64 {
    use std::sync::OnceLock;
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let nl = crate::circuits::simdive::simd32(8);
        let cal = crate::fabric::calibrate::fitted();
        let t = crate::fabric::timing::analyze(&nl, cal);
        let p = crate::fabric::power::estimate_at(&nl, cal, 0x51D, 2048, t.critical_ns);
        p.total_mw * t.critical_ns
    })
}

/// One shard's working state: its own assembler, its own kernel (all nine
/// accuracy knobs' coefficient rescales hoisted once per shard thread),
/// and reusable execution scratch.
struct ShardCtx {
    kernel: batch::MultiKernel,
    asm: Assembler<Route>,
    words: Vec<Assembled<Route>>,
    ws: Vec<u32>,
    ops: Vec<crate::arith::SimdOp>,
    operands: Vec<crate::arith::SimdWord>,
    results: Vec<u64>,
    held_rounds: u32,
    shared: Arc<Shared>,
    per_word_pj: f64,
    /// Chaos-harness injector; `None` in production (zero overhead beyond
    /// the Option check per round).
    faults: Option<Arc<FaultInjector>>,
}

impl ShardCtx {
    fn new(shared: Arc<Shared>, per_word_pj: f64, faults: Option<Arc<FaultInjector>>) -> Self {
        ShardCtx {
            kernel: batch::MultiKernel::new(),
            asm: Assembler::new(),
            words: Vec::new(),
            ws: Vec::new(),
            ops: Vec::new(),
            operands: Vec::new(),
            results: Vec::new(),
            held_rounds: 0,
            shared,
            per_word_pj,
            faults,
        }
    }

    /// Queue a chunk of routed requests; returns how many were folded.
    fn fold(&mut self, chunk: Vec<(Request, Route)>) -> usize {
        let n = chunk.len();
        for (req, route) in chunk {
            self.asm.push(req, route);
        }
        n
    }

    /// One emission round: emit words (full words only while residues may
    /// still merge, everything when `flush` or the round cap hits),
    /// execute them through the batched kernel, and route every response
    /// lane-aligned.
    ///
    /// Supervision contract: every panic this round can raise — injected
    /// or genuine — fires *before* [`ShardCtx::route_words`] sends the
    /// first response, so [`ShardCtx::recover`] re-executes the emitted
    /// words without ever double-delivering.
    fn run(&mut self, flush: bool) {
        self.words.clear();
        if flush || self.held_rounds >= MAX_HELD_ROUNDS {
            self.asm.emit_all(&mut self.words);
        } else {
            self.asm.emit_full(&mut self.words);
        }
        self.held_rounds = if self.asm.is_empty() { 0 } else { self.held_rounds + 1 };
        if self.words.is_empty() {
            return;
        }

        if let Some(inj) = &self.faults {
            if inj.shard_slow() {
                std::thread::sleep(inj.slow_delay());
            }
            if inj.shard_panic() {
                panic!("injected shard fault");
            }
        }

        self.ws.clear();
        self.ws.extend(self.words.iter().map(|j| j.pw.w));
        self.ops.clear();
        self.ops.extend(self.words.iter().map(|j| j.pw.op));
        self.operands.clear();
        self.operands.extend(self.words.iter().map(|j| j.pw.word));
        self.results.clear();
        self.results.resize(self.words.len(), 0);
        self.kernel.execute_mixed_into(&self.ws, &self.ops, &self.operands, &mut self.results);

        if let Some(inj) = &self.faults {
            if inj.delay_completion() {
                std::thread::sleep(inj.completion_delay());
            }
        }

        self.route_words();
    }

    /// Deliver one executed round: route every lane's response, fold the
    /// round into the shared counters, and mark the words routed (the
    /// cleared buffer is what tells [`ShardCtx::recover`] there is
    /// nothing left to re-execute).
    fn route_words(&mut self) {
        let (mut active, mut total) = (0u64, 0u64);
        let mut energy = 0.0f64;
        for (job, &packed) in self.words.iter().zip(self.results.iter()) {
            let pw = &job.pw;
            active += pw.active_lanes as u64;
            total += pw.lane_count() as u64;
            energy += word_energy_pj(self.per_word_pj, pw.active_lanes, pw.lane_count() as u32);
            for (l, route) in job.payload.iter().enumerate().take(pw.lane_count()) {
                if let Some(route) = route {
                    let id = pw.lane_req[l].expect("routed lane carries an id");
                    route.send(Response { id, value: lane_value(pw, packed, l), err: 0 });
                }
            }
        }
        let words = self.words.len() as u64;
        self.count_round(words, active, total, energy);
        self.words.clear();
    }

    fn count_round(&self, words: u64, active: u64, total: u64, energy: f64) {
        self.shared.words.fetch_add(words, Ordering::Relaxed);
        self.shared.active_lanes.fetch_add(active, Ordering::Relaxed);
        self.shared.total_lanes.fetch_add(total, Ordering::Relaxed);
        self.shared.energy_mpj.fetch_add(energy_increment_mpj(energy), Ordering::Relaxed);
    }

    /// Recover from a panicked round: the emitted words still hold every
    /// route, so re-execute each word through a *freshly built* kernel —
    /// independent of whatever state the panicking one was left in — and
    /// deliver its lanes. A word whose re-execution panics too (a double
    /// fault: the kernel itself is broken for this input, or the chaos
    /// harness forces it via `recover_panic_ppm`) fails its requests with
    /// [`RESP_ERR_UNAVAILABLE`] rather than stranding their writers.
    /// Either way every routed lane gets exactly one response and the
    /// shard thread survives.
    fn recover(&mut self) {
        if self.words.is_empty() {
            return; // the panic predated emission: nothing in flight
        }
        let fresh = catch_unwind(batch::MultiKernel::new).ok();
        let (mut active, mut total) = (0u64, 0u64);
        let mut energy = 0.0f64;
        for job in &self.words {
            let pw = &job.pw;
            let forced = self.faults.as_ref().is_some_and(|f| f.recover_panic());
            let packed: Option<u64> = if forced {
                None
            } else {
                fresh
                    .as_ref()
                    .and_then(|k| catch_unwind(AssertUnwindSafe(|| k.execute(pw.w, pw.op, pw.word))).ok())
            };
            active += pw.active_lanes as u64;
            total += pw.lane_count() as u64;
            energy += word_energy_pj(self.per_word_pj, pw.active_lanes, pw.lane_count() as u32);
            for (l, route) in job.payload.iter().enumerate().take(pw.lane_count()) {
                if let Some(route) = route {
                    let id = pw.lane_req[l].expect("routed lane carries an id");
                    match packed {
                        Some(p) => route.send(Response { id, value: lane_value(pw, p, l), err: 0 }),
                        None => {
                            route.send(Response { id, value: 0, err: RESP_ERR_UNAVAILABLE })
                        }
                    }
                }
            }
        }
        let words = self.words.len() as u64;
        self.count_round(words, active, total, energy);
        self.words.clear();
        if let Some(k) = fresh {
            self.kernel = k; // replace the possibly-poisoned kernel
        }
    }
}

/// Run one round under supervision: a panic (injected or genuine) is
/// caught at the round boundary and handed to recovery. The shard thread
/// itself never unwinds away — shutdown always joins.
fn run_supervised(ctx: &mut ShardCtx, flush: bool) {
    if catch_unwind(AssertUnwindSafe(|| ctx.run(flush))).is_err() {
        ctx.recover();
    }
}

/// One shard thread: drain bursts from the shard queue into the local
/// assembler, emit full words every `batch` requests, and flush everything
/// the instant the queue goes empty (or on Flush / disconnect) — a partial
/// residue never waits on traffic that may not come.
fn shard_loop(
    rx: Receiver<ShardMsg>,
    shared: Arc<Shared>,
    batch_size: usize,
    per_word_pj: f64,
    faults: Option<Arc<FaultInjector>>,
) {
    let mut ctx = ShardCtx::new(shared, per_word_pj, faults);
    loop {
        // Between bursts the assembler is empty (every burst ends in a
        // flush), so blocking indefinitely strands nothing.
        let mut folded = 0usize;
        match rx.recv() {
            Ok(ShardMsg::Batch(chunk)) => folded += ctx.fold(chunk),
            Ok(ShardMsg::Flush) => {}
            Err(_) => break,
        }
        // Drain the burst.
        loop {
            if folded >= batch_size {
                folded = 0;
                run_supervised(&mut ctx, false);
            }
            match rx.try_recv() {
                Ok(ShardMsg::Batch(chunk)) => folded += ctx.fold(chunk),
                Ok(ShardMsg::Flush) => run_supervised(&mut ctx, true),
                // Empty (burst over) or disconnected — either way flush
                // below; a disconnect also ends the outer loop at its
                // next recv.
                Err(_) => break,
            }
        }
        // Burst over (idle queue or disconnect): flush everything held.
        run_supervised(&mut ctx, true);
    }
    // Defensive final flush — unreachable residues would otherwise strand
    // their routes (the loop above always flushes before looping back).
    run_supervised(&mut ctx, true);
}

/// The sharded backend: N shard threads behind bounded queues, dispatched
/// round-robin at chunk granularity.
pub struct Sharded {
    txs: Vec<SyncSender<ShardMsg>>,
    handles: Vec<JoinHandle<()>>,
    rr: AtomicUsize,
    shared: Arc<Shared>,
}

impl Sharded {
    /// Spawn the shard pool.
    pub fn start(cfg: ShardedConfig) -> Sharded {
        Sharded::start_with_faults(cfg, None)
    }

    /// Spawn the shard pool with a chaos-harness fault injector threaded
    /// into every shard (`None` behaves exactly like [`Sharded::start`]).
    pub fn start_with_faults(cfg: ShardedConfig, faults: Option<Arc<FaultInjector>>) -> Sharded {
        let n = cfg.shards.max(1);
        let batch = cfg.batch.max(1);
        let per_word_pj = simd_word_energy_pj();
        let shared = Arc::new(Shared::default());
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = sync_channel::<ShardMsg>(cfg.queue_depth.max(16));
            txs.push(tx);
            let shared = Arc::clone(&shared);
            let faults = faults.clone();
            handles.push(
                std::thread::spawn(move || shard_loop(rx, shared, batch, per_word_pj, faults)),
            );
        }
        Sharded { txs, handles, rr: AtomicUsize::new(0), shared }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Submit one chunk of routed requests to the next shard round-robin.
    /// Chunks stay contiguous (they assemble together on one shard — the
    /// packing quality of a submission tracks its chunk size). Blocks when
    /// that shard's bounded queue is full (backpressure).
    pub fn submit(&self, chunk: Vec<(Request, Route)>) {
        if chunk.is_empty() {
            return;
        }
        self.shared.requests.fetch_add(chunk.len() as u64, Ordering::Relaxed);
        let shard = self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.txs[shard].send(ShardMsg::Batch(chunk)).expect("engine shards stopped");
    }

    /// Ask every shard to flush its held partial words now.
    pub fn flush(&self) {
        for tx in &self.txs {
            let _ = tx.send(ShardMsg::Flush);
        }
    }

    /// A read handle on the pool counters that survives shutdown.
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle(Arc::clone(&self.shared))
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> Stats {
        self.stats_handle().snapshot()
    }

    /// Stop the pool and return final statistics. Chunks submitted before
    /// the shutdown are fully executed (their responses delivered) and
    /// every shard thread is joined before this returns.
    pub fn shutdown(mut self) -> Stats {
        self.join_shards();
        self.stats()
    }

    fn join_shards(&mut self) {
        self.txs.clear(); // disconnect: shards drain their queues and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Sharded {
    fn drop(&mut self) {
        self.join_shards();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::simdive::simdive_mul_w;
    use crate::coordinator::packer::ReqOp;
    use std::sync::mpsc::channel;

    #[test]
    fn power_gating_reduces_energy_of_partial_words() {
        let full = word_energy_pj(100.0, 4, 4);
        let one = word_energy_pj(100.0, 1, 4);
        assert!((full - 100.0).abs() < 1e-9);
        assert!(one < 0.4 * full, "gated {one} vs full {full}");
    }

    #[test]
    fn word_energy_is_positive_and_sane() {
        let e = simd_word_energy_pj();
        assert!(e > 1.0 && e < 100_000.0, "per-word energy {e} pJ");
    }

    #[test]
    fn energy_accumulation_rounds_not_floors() {
        // The increment actually used by the shard loop must round to the
        // nearest milli-pJ; truncation (`as u64` on the raw product) would
        // floor 0.4999 pJ to 499 and 0.0006 pJ to 0.
        assert_eq!(energy_increment_mpj(0.4999), 500);
        assert_eq!(energy_increment_mpj(0.0006), 1);
        assert_eq!(energy_increment_mpj(0.0004), 0);
        assert!(energy_increment_mpj(0.4999) > (0.4999f64 * 1000.0) as u64);
    }

    #[test]
    fn routed_submission_executes_and_counts() {
        let pool = Sharded::start(ShardedConfig { shards: 2, queue_depth: 64, batch: 8 });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = (0..100u64)
            .map(|i| Request { id: i, op: ReqOp::Mul, bits: 8, w: 8, a: 1 + i % 200, b: 3 })
            .collect();
        let chunk: Vec<(Request, Route)> = reqs
            .iter()
            .enumerate()
            .map(|(k, r)| (*r, Route::Slot(tx.clone(), k as u32)))
            .collect();
        pool.submit(chunk);
        let mut got = vec![None; reqs.len()];
        for _ in 0..reqs.len() {
            let (slot, resp) = rx.recv().unwrap();
            assert!(got[slot as usize].replace(resp).is_none(), "slot {slot} twice");
        }
        for (k, r) in reqs.iter().enumerate() {
            let resp = got[k].unwrap();
            assert_eq!(resp.id, r.id);
            assert_eq!(resp.value, simdive_mul_w(8, r.a, r.b, 8));
        }
        let s = pool.shutdown();
        assert_eq!(s.requests, 100);
        assert!(s.energy_pj > 0.0);
        assert!(s.words > 0 && s.words <= 100);
    }

    #[test]
    fn empty_submit_is_a_no_op() {
        let pool = Sharded::start(ShardedConfig { shards: 1, queue_depth: 16, batch: 4 });
        pool.submit(Vec::new());
        let s = pool.shutdown();
        assert_eq!(s.requests, 0);
        assert_eq!(s.words, 0);
    }

    #[test]
    fn single_route_delivers() {
        let pool = Sharded::start(ShardedConfig { shards: 1, queue_depth: 16, batch: 4 });
        let (tx, rx) = channel();
        let req = Request { id: 7, op: ReqOp::Mul, bits: 8, w: 8, a: 43, b: 10 };
        pool.submit(vec![(req, Route::Single(tx))]);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.value, simdive_mul_w(8, 43, 10, 8));
        pool.shutdown();
    }
}
