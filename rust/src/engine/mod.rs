//! The unified execution engine: one [`Backend`] seam from the scalar
//! models to the serve path (DESIGN.md §10).
//!
//! Before this seam existed the repo had three parallel execution
//! surfaces that callers hand-picked: scalar `MulDesign`/`DivDesign`
//! dispatch (ANN, image, metrics, report), `arith::batch` slice kernels,
//! and word execution inside the coordinator. The seam collapses them:
//! every substrate holds an [`Engine`] handle and the backend decides how
//! the work runs —
//!
//! * [`Reference`] — one scalar-model dispatch per element: the bit-exact
//!   oracle every other backend is tested against;
//! * [`Batched`] — the `arith::batch` slice kernels (tables and width
//!   resolved once per call) and one-shot word assembly for mixed
//!   `{bits, w}` streams; the default for in-process substrates;
//! * [`Sharded`] — N independent worker shards, each with its own
//!   assembler and rescaled tables, fed round-robin: the coordinator's
//!   worker pool and the scaling path (see [`sharded`]).
//!
//! The seam contract: **every backend is bit-identical to [`Reference`]
//! for every `{op, bits, w}`**, and [`Sharded`] is invariant under shard
//! count (`tests/engine_props.rs`). Pick backends for speed, never for
//! semantics.
//!
//! Not to be confused with [`crate::runtime::Engine`], the PJRT executor
//! for the AOT-compiled Pallas artifacts.

pub mod sharded;

pub use sharded::{Response, Route, Sharded, ShardedConfig, Stats, StatsHandle};

use crate::arith::simdive::{simdive_div_w, simdive_mul_w};
use crate::arith::{batch, DivDesign, MulDesign};
use crate::coordinator::packer::{lane_value, Assembler, ReqOp, Request};
use std::sync::Arc;

/// The execution seam: batched multiply/divide slices (integer and the
/// real-valued error-analysis form) plus mixed-`{bits, w}` SIMDive word
/// streams.
///
/// Contract: for any backend, `mul_batch`/`div_batch` are bit-identical
/// to `design.mul`/`design.div` per element, and `execute_stream` is
/// bit-identical to `simdive_mul_w`/`simdive_div_w` per request.
pub trait Backend: Send + Sync {
    /// Backend name (for benches and logs).
    fn name(&self) -> &'static str;

    /// `out[i] = design.mul(bits, a[i], b[i])`, bit-exactly.
    fn mul_batch(&self, design: MulDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>);

    /// `out[i] = design.div(bits, a[i], b[i])`, bit-exactly.
    fn div_batch(&self, design: DivDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>);

    /// `out[i] = design.mul_real(bits, a[i], b[i])` — the behavioral
    /// error-analysis form (paper §4.1).
    fn mul_real_batch(
        &self,
        design: MulDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    );

    /// `out[i] = design.div_real(bits, a[i], b[i])`.
    fn div_real_batch(
        &self,
        design: DivDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    );

    /// Mixed-`{op, bits, w}` SIMDive stream: `out[i]` is the scalar result
    /// of `reqs[i]` (request ids are not interpreted).
    fn execute_stream(&self, reqs: &[Request], out: &mut Vec<u64>);
}

/// Scalar-model backend: one design dispatch per element. Slow and
/// table-resolving per call — exactly why it is the oracle, not the hot
/// path.
#[derive(Clone, Copy, Debug, Default)]
pub struct Reference;

impl Backend for Reference {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn mul_batch(&self, design: MulDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| design.mul(bits, x, y)));
    }

    fn div_batch(&self, design: DivDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| design.div(bits, x, y)));
    }

    fn mul_real_batch(
        &self,
        design: MulDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| design.mul_real(bits, x, y)));
    }

    fn div_real_batch(
        &self,
        design: DivDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(a.len(), b.len());
        out.clear();
        out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| design.div_real(bits, x, y)));
    }

    fn execute_stream(&self, reqs: &[Request], out: &mut Vec<u64>) {
        out.clear();
        out.extend(reqs.iter().map(|r| match r.op {
            ReqOp::Mul => simdive_mul_w(r.bits, r.a, r.b, r.w),
            ReqOp::Div => simdive_div_w(r.bits, r.a, r.b, r.w),
        }));
    }
}

/// Batched in-process backend: slice kernels with per-call hoisting for
/// mul/div batches, and one-shot word assembly through a resident
/// [`batch::MultiKernel`] (all nine accuracy knobs' rescales paid once at
/// construction) for mixed streams.
pub struct Batched {
    kernel: batch::MultiKernel,
}

impl Batched {
    pub fn new() -> Self {
        Batched { kernel: batch::MultiKernel::new() }
    }
}

impl Default for Batched {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for Batched {
    fn name(&self) -> &'static str {
        "batched"
    }

    fn mul_batch(&self, design: MulDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        design.mul_batch_into(bits, a, b, out);
    }

    fn div_batch(&self, design: DivDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        design.div_batch_into(bits, a, b, out);
    }

    fn mul_real_batch(
        &self,
        design: MulDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        design.mul_real_batch_into(bits, a, b, out);
    }

    fn div_real_batch(
        &self,
        design: DivDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        design.div_real_batch_into(bits, a, b, out);
    }

    fn execute_stream(&self, reqs: &[Request], out: &mut Vec<u64>) {
        out.clear();
        out.resize(reqs.len(), 0);
        if reqs.is_empty() {
            return;
        }
        // One-shot assembly: payloads are request indices, so scatter-back
        // is a direct index per lane.
        let mut asm: Assembler<u32> = Assembler::new();
        for (i, r) in reqs.iter().enumerate() {
            asm.push(*r, i as u32);
        }
        let mut words = Vec::new();
        asm.emit_all(&mut words);
        for job in &words {
            let packed = self.kernel.execute(job.pw.w, job.pw.op, job.pw.word);
            for (l, payload) in job.payload.iter().enumerate().take(job.pw.lane_count()) {
                if let Some(idx) = payload {
                    out[*idx as usize] = lane_value(&job.pw, packed, l);
                }
            }
        }
    }
}

impl Backend for Sharded {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn mul_batch(&self, design: MulDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        debug_assert_eq!(a.len(), b.len());
        match design {
            // Only SIMDive at a SIMD lane width has a word form; anything
            // else falls back to the batched slice path (same numbers, no
            // shard parallelism) so every backend accepts the same inputs.
            MulDesign::Simdive { w } if crate::arith::WIDTHS.contains(&bits) => {
                let mut reqs = Vec::with_capacity(a.len());
                for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                    reqs.push(Request { id: i as u64, op: ReqOp::Mul, bits, w, a: x, b: y });
                }
                self.execute_stream(&reqs, out);
            }
            _ => design.mul_batch_into(bits, a, b, out),
        }
    }

    fn div_batch(&self, design: DivDesign, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        debug_assert_eq!(a.len(), b.len());
        match design {
            DivDesign::Simdive { w } if crate::arith::WIDTHS.contains(&bits) => {
                let mut reqs = Vec::with_capacity(a.len());
                for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                    reqs.push(Request { id: i as u64, op: ReqOp::Div, bits, w, a: x, b: y });
                }
                self.execute_stream(&reqs, out);
            }
            _ => design.div_batch_into(bits, a, b, out),
        }
    }

    fn mul_real_batch(
        &self,
        design: MulDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        // The real-valued error-analysis form has no packed-word
        // equivalent; delegate to the batched kernels.
        design.mul_real_batch_into(bits, a, b, out);
    }

    fn div_real_batch(
        &self,
        design: DivDesign,
        bits: u32,
        a: &[u64],
        b: &[u64],
        out: &mut Vec<f64>,
    ) {
        design.div_real_batch_into(bits, a, b, out);
    }

    fn execute_stream(&self, reqs: &[Request], out: &mut Vec<u64>) {
        out.clear();
        out.resize(reqs.len(), 0);
        if reqs.is_empty() {
            return;
        }
        // Contiguous per-shard chunks (packing quality tracks chunk size),
        // responses routed slot-aligned back into `out`.
        let (tx, rx) = std::sync::mpsc::channel();
        let chunk = reqs.len().div_ceil(self.shards()).max(1);
        let mut slot = 0u32;
        for piece in reqs.chunks(chunk) {
            let routed: Vec<(Request, Route)> = piece
                .iter()
                .enumerate()
                .map(|(k, r)| (*r, Route::Slot(tx.clone(), slot + k as u32)))
                .collect();
            slot += piece.len() as u32;
            self.submit(routed);
        }
        drop(tx);
        for _ in 0..reqs.len() {
            let (s, resp) = rx.recv().expect("engine shards stopped");
            out[s as usize] = if resp.err == 0 {
                resp.value
            } else {
                // Shard supervision gave the request up (double fault).
                // In-process callers have the scalar models right here, so
                // the seam contract (bit-exact, always answers) holds even
                // under injected chaos.
                let r = reqs[s as usize];
                match r.op {
                    ReqOp::Mul => simdive_mul_w(r.bits, r.a, r.b, r.w),
                    ReqOp::Div => simdive_div_w(r.bits, r.a, r.b, r.w),
                }
            };
        }
    }
}

/// The caller-facing handle: a shared backend plus the `{mul, div}`
/// design pair it executes. Cloning shares the backend.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
    mul_design: MulDesign,
    div_design: DivDesign,
}

impl Engine {
    /// Wrap an existing backend.
    pub fn with_backend(backend: Arc<dyn Backend>, mul: MulDesign, div: DivDesign) -> Engine {
        Engine { backend, mul_design: mul, div_design: div }
    }

    /// Scalar-oracle engine ([`Reference`]).
    pub fn reference(mul: MulDesign, div: DivDesign) -> Engine {
        Engine::with_backend(Arc::new(Reference), mul, div)
    }

    /// Batched in-process engine ([`Batched`]) — the default choice for
    /// the application substrates.
    pub fn batched(mul: MulDesign, div: DivDesign) -> Engine {
        Engine::with_backend(Arc::new(Batched::new()), mul, div)
    }

    /// Sharded engine ([`Sharded`]): spawns the shard pool.
    pub fn sharded(mul: MulDesign, div: DivDesign, cfg: ShardedConfig) -> Engine {
        Engine::with_backend(Arc::new(Sharded::start(cfg)), mul, div)
    }

    /// Batched SIMDive engine at accuracy knob `w` for both operations.
    pub fn simdive(w: u32) -> Engine {
        Engine::batched(MulDesign::Simdive { w }, DivDesign::Simdive { w })
    }

    /// Batched exact-arithmetic engine.
    pub fn accurate() -> Engine {
        Engine::batched(MulDesign::Accurate, DivDesign::Accurate)
    }

    /// Batched engine for a multiplier design (divider: accurate) —
    /// convenience for multiply-only substrates like the quantized MLP.
    pub fn from_mul(mul: MulDesign) -> Engine {
        Engine::batched(mul, DivDesign::Accurate)
    }

    /// Same backend, different design pair.
    pub fn with_designs(&self, mul: MulDesign, div: DivDesign) -> Engine {
        Engine { backend: Arc::clone(&self.backend), mul_design: mul, div_design: div }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn mul_design(&self) -> MulDesign {
        self.mul_design
    }

    pub fn div_design(&self) -> DivDesign {
        self.div_design
    }

    /// Scalar multiply — bit-identical to the batched path (the seam
    /// contract), for one-off values and oracles.
    #[inline]
    pub fn mul(&self, bits: u32, a: u64, b: u64) -> u64 {
        self.mul_design.mul(bits, a, b)
    }

    /// Scalar divide — bit-identical to the batched path.
    #[inline]
    pub fn div(&self, bits: u32, a: u64, b: u64) -> u64 {
        self.div_design.div(bits, a, b)
    }

    /// Batched multiply into a reusable buffer.
    pub fn mul_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        self.backend.mul_batch(self.mul_design, bits, a, b, out);
    }

    /// Batched divide into a reusable buffer.
    pub fn div_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<u64>) {
        self.backend.div_batch(self.div_design, bits, a, b, out);
    }

    /// Batched real-valued multiply (error-analysis form).
    pub fn mul_real_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<f64>) {
        self.backend.mul_real_batch(self.mul_design, bits, a, b, out);
    }

    /// Batched real-valued divide (error-analysis form).
    pub fn div_real_into(&self, bits: u32, a: &[u64], b: &[u64], out: &mut Vec<f64>) {
        self.backend.div_real_batch(self.div_design, bits, a, b, out);
    }

    /// Execute a mixed-`{op, bits, w}` SIMDive request stream.
    pub fn execute_stream_into(&self, reqs: &[Request], out: &mut Vec<u64>) {
        self.backend.execute_stream(reqs, out);
    }

    /// Allocating form of [`Engine::execute_stream_into`].
    pub fn execute_stream(&self, reqs: &[Request]) -> Vec<u64> {
        let mut out = Vec::new();
        self.execute_stream_into(reqs, &mut out);
        out
    }
}

impl Default for Engine {
    /// The paper's full-accuracy configuration: batched SIMDive at
    /// `w = 8`.
    fn default() -> Self {
        Engine::simdive(crate::arith::W_MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn batched_matches_reference_for_every_design() {
        let mut rng = Rng::new(0xE16);
        let a: Vec<u64> = (0..256).map(|_| rng.below(1 << 16)).collect();
        let b: Vec<u64> = (0..256).map(|_| rng.below(1 << 16)).collect();
        let (mut got, mut want) = (Vec::new(), Vec::new());
        for d in MulDesign::table2_rows() {
            let eng = Engine::batched(d, DivDesign::Accurate);
            let oracle = Engine::reference(d, DivDesign::Accurate);
            eng.mul_into(16, &a, &b, &mut got);
            oracle.mul_into(16, &a, &b, &mut want);
            assert_eq!(got, want, "{}", d.name());
        }
        for d in DivDesign::table2_rows() {
            let eng = Engine::batched(MulDesign::Accurate, d);
            let oracle = Engine::reference(MulDesign::Accurate, d);
            eng.div_into(16, &a, &b, &mut got);
            oracle.div_into(16, &a, &b, &mut want);
            assert_eq!(got, want, "{}", d.name());
        }
    }

    #[test]
    fn batched_stream_matches_reference() {
        let mut rng = Rng::new(0xE17);
        let reqs: Vec<Request> = (0..800u64)
            .map(|i| {
                let bits = [8u32, 8, 16, 32][rng.below(4) as usize];
                Request {
                    id: i,
                    op: if rng.below(2) == 0 { ReqOp::Mul } else { ReqOp::Div },
                    bits,
                    w: rng.below(crate::arith::W_MAX as u64 + 1) as u32,
                    a: rng.operand(bits),
                    b: rng.operand(bits),
                }
            })
            .collect();
        // Designs are irrelevant to streams (each request carries its
        // own `{op, bits, w}`): only the backend matters.
        let oracle = Engine::reference(MulDesign::Accurate, DivDesign::Accurate);
        assert_eq!(Engine::default().execute_stream(&reqs), oracle.execute_stream(&reqs));
    }

    #[test]
    fn empty_inputs_are_fine() {
        let eng = Engine::default();
        let mut out = Vec::new();
        eng.mul_into(16, &[], &[], &mut out);
        assert!(out.is_empty());
        assert!(eng.execute_stream(&[]).is_empty());
    }

    #[test]
    fn scalar_convenience_matches_batch() {
        let eng = Engine::simdive(8);
        let mut out = Vec::new();
        eng.mul_into(8, &[43], &[10], &mut out);
        assert_eq!(out[0], eng.mul(8, 43, 10));
        eng.div_into(8, &[43], &[10], &mut out);
        assert_eq!(out[0], eng.div(8, 43, 10));
    }
}
