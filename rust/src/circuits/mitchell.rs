//! Gate-level Mitchell multiplier and divider [22] (paper §3.1), shared by
//! the MBM / INZeD / SIMDive netlists — those differ only in the correction
//! operand added alongside the fractions.
//!
//! Multiplier datapath: LOD → fraction align (×2) → exponent adder
//! `K = k1 + k2` → fraction add `T = f1 + f2 (+ c)` → antilog left-shift of
//! the unified mantissa `{T[F+1], ovf ? T[F] : 1, T[F−1:0]}` by `K` (+1 on
//! fraction carry).
//!
//! Divider datapath: same front end; `K = k1 − k2`; `T = f1 − f2 (+ c)` in
//! two's complement; mantissa `{1, T[F−1:0]}` (or `T[F:0]` on borrow) is
//! right-shifted by `F − e` with `e = K − borrow`.

use super::components::{align_fraction, barrel_left, barrel_right, lod};
use crate::fabric::netlist::{Net, Netlist, NET0, NET1};

/// Shared front end: LOD + fraction alignment for both operands.
/// Returns `(k1, f1, nz1, k2, f2, nz2)`.
pub fn frontend(
    nl: &mut Netlist,
    a: &[Net],
    b: &[Net],
) -> (Vec<Net>, Vec<Net>, Net, Vec<Net>, Vec<Net>, Net) {
    let (k1, nz1) = lod(nl, a);
    let (k2, nz2) = lod(nl, b);
    let f1 = align_fraction(nl, a, &k1);
    let f2 = align_fraction(nl, b, &k2);
    (k1, f1, nz1, k2, f2, nz2)
}

/// Multiplier back end: from `(k1, k2)` and the fraction sum `t`
/// (`F+2`-bit bus: f1 + f2 + optional correction), produce the `2N`-bit
/// product. `zero` forces the output to 0 (an all-zero operand).
pub fn mul_backend(
    nl: &mut Netlist,
    bits: u32,
    k1: &[Net],
    k2: &[Net],
    t: &[Net],
    zero: Net,
) -> Vec<Net> {
    let f = (bits - 1) as usize;
    assert_eq!(t.len(), f + 2);
    let ovf = nl.or2(t[f], t[f + 1]);
    // K = k1 + k2 + ovf  (exponent of the mantissa MSB position).
    let kw = k1.len();
    let (ksum, kco) = {
        let (s, co) = nl.adder(k1, k2, ovf);
        (s, co)
    };
    let mut kbus = ksum;
    kbus.push(kco); // kw+1 bits: K in 0 .. 2^(kw+1)-1
    debug_assert_eq!(kbus.len(), kw + 1);

    // Mantissa (F+2 bits): bits F-1..0 = t, bit F = ovf ? t[F] : 1,
    // bit F+1 = t[F+1].
    let mut mant: Vec<Net> = t[..f].to_vec();
    let bit_f = nl.mux2(ovf, NET1, t[f]);
    mant.push(bit_f);
    mant.push(t[f + 1]);

    // Product = mant << K >> F: left barrel shift into 2N+F+1 bits, then
    // drop the low F (static). Output bits are [F .. F+2N-1]; bit F+2N can
    // only be set on corrected near-max operands — saturate to all-ones
    // then (the behavioral model's 2^2N−1 cap).
    let shifted = barrel_left(nl, &mant, &kbus, f + 2 * bits as usize + 1);
    let sat = shifted[f + 2 * bits as usize];
    let mut out: Vec<Net> = shifted[f..f + 2 * bits as usize].to_vec();
    // Zero-operand gating + saturation in one LUT level per bit:
    // out = !zero & (bit | sat).
    for o in out.iter_mut() {
        *o = nl.lut(&[*o, sat, zero], |m| (m >> 2) & 1 == 0 && (m & 3) != 0);
    }
    out
}

/// Divider back end: from exponents and the two's-complement fraction
/// difference `r` (`F+2` bits, bit `F+1` = sign), produce the `N`-bit
/// quotient. `zero_a` → 0, `zero_b` → saturate to all-ones.
pub fn div_backend(
    nl: &mut Netlist,
    bits: u32,
    divisor_bits: u32,
    k1: &[Net],
    k2: &[Net],
    r: &[Net],
    zero_a: Net,
    zero_b: Net,
) -> Vec<Net> {
    let f = (bits - 1) as usize;
    assert_eq!(r.len(), f + 2);
    let sign = r[f + 1];
    // Mantissa F+1 bits: positive → {1, r[F-1:0]}; negative → r[F:0].
    let mut mant: Vec<Net> = r[..f].to_vec();
    let bit_f = nl.mux2(sign, NET1, r[f]);
    mant.push(bit_f);

    // Shift amount: s = F - e, e = (k1 - k2) - sign.
    // s = F - k1 + k2 + sign. Compute in (kw+2)-bit two's complement:
    // s = F + k2 + sign - k1 = (F + sign) + k2 + ~k1 + 1.
    let kw = k1.len().max(k2.len());
    let width = kw + 2;
    let not_k1: Vec<Net> = (0..width)
        .map(|i| {
            if i < k1.len() {
                nl.not(k1[i])
            } else {
                NET1 // sign-extend ~k1 (k1 is non-negative)
            }
        })
        .collect();
    let k2x: Vec<Net> = (0..width).map(|i| k2.get(i).copied().unwrap_or(NET0)).collect();
    // s = (F + 1 + sign) + k2 + ~k1 in one ternary-adder pass. F + 1 is a
    // power of two (bits = 8/16/32 → F+1 = 8/16/32) so its low bit is 0 and
    // the `sign` bit can ride in bit 0 of the constant operand; the "+1" of
    // the two's complement ~k1 is folded into the constant.
    let mut third = nl.constant(width as u32, (f + 1) as u64);
    third[0] = sign;
    let mut s_bus = nl.ternary_adder(&k2x, &not_k1, &third);
    // Negative s (e > F) cannot happen for quotients < 2^N when the
    // divisor is ≥ 1: s ∈ [0, F + max_k2 + 1]; drop the wrap-around carry.
    s_bus.truncate(width);

    // Quotient = mant >> s, clipped to N bits. Max shift value covers the
    // full bus width so oversized shifts naturally produce 0.
    let q = barrel_right(nl, &mant, &s_bus, bits as usize);

    // Gating in one LUT level: a == 0 → 0; b == 0 → all ones.
    // out = zero_b | (!zero_a & q).
    let _ = divisor_bits;
    q.iter()
        .map(|&qb| {
            nl.lut(&[qb, zero_a, zero_b], |m| {
                (m >> 2) & 1 == 1 || ((m >> 1) & 1 == 0 && m & 1 == 1)
            })
        })
        .collect()
}

/// Complete Mitchell multiplier netlist (`a`, `b` → `p`).
pub fn mul(bits: u32) -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", bits);
    let (k1, f1, nz1, k2, f2, nz2) = frontend(&mut nl, &a, &b);
    // T = f1 + f2 over F+2 bits.
    let (sum, co) = nl.adder(&f1, &f2, NET0);
    let mut t = sum;
    t.push(co);
    t.push(NET0);
    let zero = nl.lut(&[nz1, nz2], |m| m != 3);
    let p = mul_backend(&mut nl, bits, &k1, &k2, &t, zero);
    nl.output("p", &p);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "mitchell_mul");
    nl
}

/// Complete Mitchell divider netlist (`a` is `bits` wide, `b` is
/// `divisor_bits` wide → `q` is `bits` wide).
pub fn div(bits: u32, divisor_bits: u32) -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", divisor_bits);
    let (k1, nz1) = lod(&mut nl, &a);
    let (k2, nz2) = lod(&mut nl, &b);
    let f1 = align_fraction(&mut nl, &a, &k1);
    let f2full = align_fraction(&mut nl, &b, &k2);
    // Align divisor fraction to the dividend's F grid (divisor fraction has
    // divisor_bits-1 significant top bits; pad the low side with zeros).
    let f = (bits - 1) as usize;
    let fd = (divisor_bits - 1) as usize;
    let mut f2 = vec![NET0; f];
    for i in 0..fd {
        f2[f - fd + i] = f2full[i];
    }
    // r = f1 - f2 in two's complement over F+2 bits.
    let f1x: Vec<Net> = f1.iter().copied().chain([NET0, NET0]).collect();
    let f2x: Vec<Net> = f2.iter().copied().chain([NET0, NET0]).collect();
    let (r, _) = nl.subtractor(&f1x, &f2x, NET1);
    let zero_a = nl.not(nz1);
    let zero_b = nl.not(nz2);
    let q = div_backend(&mut nl, bits, divisor_bits, &k1, &k2, &r, zero_a, zero_b);
    nl.output("q", &q);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "mitchell_div");
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith;
    use crate::fabric::Simulator;

    #[test]
    fn mul_8bit_exhaustive_matches_behavioral() {
        let nl = mul(8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        for a in 0..256u64 {
            for b in (0..256u64).step_by(5) {
                avals.push(a);
                bvals.push(b);
            }
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::mitchell::mul(8, avals[i], bvals[i]);
            assert_eq!(outs[0].1[i], want, "{}x{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn mul_16bit_sampled_matches_behavioral() {
        let nl = mul(16);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(21);
        let avals: Vec<u64> = (0..20_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..20_000).map(|_| rng.below(65536)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::mitchell::mul(16, avals[i], bvals[i]);
            assert_eq!(outs[0].1[i], want, "{}x{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn div_16_8_sampled_matches_behavioral() {
        let nl = div(16, 8);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(22);
        let avals: Vec<u64> = (0..20_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..20_000).map(|_| rng.below(256)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::mitchell::div(16, avals[i], bvals[i]) & 0xFFFF;
            assert_eq!(outs[0].1[i], want, "{}/{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn div_8bit_exhaustive_matches_behavioral() {
        let nl = div(8, 8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        for a in (0..256u64).step_by(3) {
            for b in 0..256u64 {
                avals.push(a);
                bvals.push(b);
            }
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::mitchell::div(8, avals[i], bvals[i]);
            assert_eq!(outs[0].1[i], want, "{}/{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn area_and_delay_in_paper_regime() {
        // Paper Table 2 (16-bit): Mitchell mul 174 LUT / 4.7 ns;
        // Mitchell div 119 LUT / 5.3 ns. Structural mapping differs from
        // Vivado's optimizer, so allow a generous band — the *ordering*
        // (both far below the accurate IPs) is what must hold.
        let cal = crate::fabric::Calibration::default();
        let m = mul(16);
        let am = crate::fabric::area::report(&m);
        let tm = crate::fabric::timing::analyze(&m, &cal);
        assert!(am.luts >= 100 && am.luts <= 320, "mitchell mul area {}", am.luts);
        assert!(tm.critical_ns < 11.0, "mitchell mul delay {}", tm.critical_ns);

        let d = div(16, 8);
        let ad = crate::fabric::area::report(&d);
        let td = crate::fabric::timing::analyze(&d, &cal);
        assert!(ad.luts >= 70 && ad.luts <= 260, "mitchell div area {}", ad.luts);
        assert!(td.critical_ns < 10.5, "mitchell div delay {}", td.critical_ns);
    }
}
