//! Gate-level (LUT/carry-chain) implementations of every design in the
//! paper's evaluation, built on the [`crate::fabric`] netlist primitives
//! and verified bit-exactly against the behavioral models in
//! [`crate::arith`] (see `rust/tests/netlist_vs_behavioral.rs`).
//!
//! * [`components`] — the paper's §3.2 building blocks: 4-bit-segment LOD
//!   (two 6-LUTs per segment), fraction aligner, barrel shifters packed
//!   into 4:1 LUT muxes, error-LUT bank (§3.3), priority logic.
//! * [`mitchell`] — Mitchell multiplier/divider netlists [22].
//! * [`simdive`] — the proposed SISD multiplier, divider, hybrid unit and
//!   the 32-bit SIMD unit with one-hot precision/mode controls.
//! * [`baselines`] — accurate array multiplier (LogiCORE stand-in),
//!   restoring array divider, truncated multipliers, CA, MBM, INZeD, AAXD.

pub mod baselines;
pub mod components;
pub mod mitchell;
pub mod simdive;

use crate::fabric::Netlist;

/// A named buildable circuit with `a`/`b` inputs and one output bus.
pub struct BuiltCircuit {
    pub name: String,
    pub netlist: Netlist,
}

/// Catalog of the gate-level designs characterized in Tables 2–3.
/// `bits` is the operand width.
pub enum CircuitKind {
    AccurateMul,
    AccurateDiv { divisor_bits: u32 },
    MitchellMul,
    MitchellDiv { divisor_bits: u32 },
    MbmMul,
    InzedDiv { divisor_bits: u32 },
    SimdiveMul { w: u32 },
    SimdiveDiv { divisor_bits: u32, w: u32 },
    SimdiveHybrid { w: u32 },
    TruncMul { seven_a: bool, seven_b: bool },
    CaMul,
    AaxdDiv { divisor_bits: u32, m: u32, n: u32 },
    SimdiveSimd32 { w: u32 },
}

impl CircuitKind {
    /// Build the netlist at the given operand width.
    pub fn build(&self, bits: u32) -> BuiltCircuit {
        match *self {
            CircuitKind::AccurateMul => BuiltCircuit {
                name: format!("accurate_mul_{bits}"),
                netlist: baselines::array_mul(bits),
            },
            CircuitKind::AccurateDiv { divisor_bits } => BuiltCircuit {
                name: format!("accurate_div_{bits}_{divisor_bits}"),
                netlist: baselines::restoring_div(bits, divisor_bits),
            },
            CircuitKind::MitchellMul => BuiltCircuit {
                name: format!("mitchell_mul_{bits}"),
                netlist: mitchell::mul(bits),
            },
            CircuitKind::MitchellDiv { divisor_bits } => BuiltCircuit {
                name: format!("mitchell_div_{bits}_{divisor_bits}"),
                netlist: mitchell::div(bits, divisor_bits),
            },
            CircuitKind::MbmMul => BuiltCircuit {
                name: format!("mbm_mul_{bits}"),
                netlist: baselines::mbm_mul(bits),
            },
            CircuitKind::InzedDiv { divisor_bits } => BuiltCircuit {
                name: format!("inzed_div_{bits}_{divisor_bits}"),
                netlist: baselines::inzed_div(bits, divisor_bits),
            },
            CircuitKind::SimdiveMul { w } => BuiltCircuit {
                name: format!("simdive_mul_{bits}_w{w}"),
                netlist: simdive::mul(bits, w),
            },
            CircuitKind::SimdiveDiv { divisor_bits, w } => BuiltCircuit {
                name: format!("simdive_div_{bits}_{divisor_bits}_w{w}"),
                netlist: simdive::div(bits, divisor_bits, w),
            },
            CircuitKind::SimdiveHybrid { w } => BuiltCircuit {
                name: format!("simdive_hybrid_{bits}_w{w}"),
                netlist: simdive::hybrid(bits, w),
            },
            CircuitKind::TruncMul { seven_a, seven_b } => BuiltCircuit {
                name: format!("trunc_mul_{bits}_{}{}", u8::from(seven_a), u8::from(seven_b)),
                netlist: baselines::trunc_mul(bits, seven_a, seven_b),
            },
            CircuitKind::CaMul => BuiltCircuit {
                name: format!("ca_mul_{bits}"),
                netlist: baselines::ca_mul(bits),
            },
            CircuitKind::AaxdDiv { divisor_bits, m, n } => BuiltCircuit {
                name: format!("aaxd_div_{bits}_{divisor_bits}_{m}_{n}"),
                netlist: baselines::aaxd_div(bits, divisor_bits, m, n),
            },
            CircuitKind::SimdiveSimd32 { w } => BuiltCircuit {
                name: format!("simdive_simd32_w{w}"),
                netlist: simdive::simd32(w),
            },
        }
    }
}
