//! Gate-level SIMDive (paper §3.2–3.3): the proposed multiplier, divider,
//! hybrid multiplier-divider, and the 32-bit SIMD unit.
//!
//! Relative to the plain Mitchell netlists, SIMDive adds the error-LUT
//! bank (`w` LUT6s fed by the 3 MSBs of each fraction) and replaces the
//! fraction adder with a *ternary* adder so the correction rides in the
//! same carry-chain pass — the paper's key "no extra delay" argument.
//!
//! The SIMD unit instantiates four 8-bit sub-units whose LODs, fraction
//! datapaths and adders are built per 8-bit lane; the one-hot `precision`
//! control fuses lanes into 16- or 32-bit operation by muxing the
//! carry/priority boundaries (Fig. 2(a)'s yellow multiplexers). For
//! clarity and verifiability we realize the fused behaviour by muxing
//! between per-configuration datapaths built from shared sub-components;
//! area/delay consequences (≈3× from 16-bit SISD to 32-bit SIMD) emerge
//! from the real structure.

use super::components::{align_fraction, error_lut_bank, error_lut_bank_neg, lod};
use super::mitchell::{div_backend, mul_backend};
use crate::arith::table::{tables_for, CorrectionTables};
use crate::fabric::netlist::{Net, Netlist, NET0, NET1};

/// Build the corrected fraction-sum bus `t = f1 + f2 + c` (F+2 bits) for a
/// multiplier, via the ternary adder.
fn corrected_sum(
    nl: &mut Netlist,
    table: &CorrectionTables,
    f1: &[Net],
    f2: &[Net],
) -> Vec<Net> {
    let f = f1.len();
    let c = error_lut_bank(nl, table, false, f1, f2);
    let mut t = nl.ternary_adder(f1, f2, &c);
    t.truncate(f + 2);
    while t.len() < f + 2 {
        t.push(NET0);
    }
    t
}

/// Build the corrected two's-complement difference `r = f1 - f2 - |c|`
/// (F+2 bits incl. sign) for a divider: ternary add of `f1`, `~f2` and
/// `~|c|` with the two +1s folded in (−x = ~x + 1 for both subtrahends).
fn corrected_diff(
    nl: &mut Netlist,
    table: &CorrectionTables,
    f1: &[Net],
    f2: &[Net],
) -> Vec<Net> {
    let f = f1.len();
    let width = f + 2;
    // r = f1 - f2 + c (c ≤ 0) = f1 + ~f2 + (c mod 2^(F+2)) + 1, all in a
    // single ternary-subtract chain pass: the bank emits the negative
    // correction pre-complemented per region and the "+1" rides the cin.
    let neg = error_lut_bank_neg(nl, table, f1, f2);
    let f1x: Vec<Net> = (0..width).map(|i| f1.get(i).copied().unwrap_or(NET0)).collect();
    let f2x: Vec<Net> = (0..width).map(|i| f2.get(i).copied().unwrap_or(NET0)).collect();
    let mut r = nl.ternary_subtract(&f1x, &f2x, &neg, NET1);
    r.truncate(width);
    r
}

/// SIMDive multiplier netlist (`a`, `b` → `p`, both `bits` wide).
pub fn mul(bits: u32, w: u32) -> Netlist {
    let table = tables_for(w);
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", bits);
    let (k1, nz1) = lod(&mut nl, &a);
    let (k2, nz2) = lod(&mut nl, &b);
    let f1 = align_fraction(&mut nl, &a, &k1);
    let f2 = align_fraction(&mut nl, &b, &k2);
    let t = corrected_sum(&mut nl, table, &f1, &f2);
    let zero = nl.lut(&[nz1, nz2], |m| m != 3);
    let p = mul_backend(&mut nl, bits, &k1, &k2, &t, zero);
    nl.output("p", &p);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "simdive_mul");
    nl
}

/// SIMDive divider netlist (`a` is `bits`, `b` is `divisor_bits` → `q`).
pub fn div(bits: u32, divisor_bits: u32, w: u32) -> Netlist {
    let table = tables_for(w);
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", divisor_bits);
    let (k1, nz1) = lod(&mut nl, &a);
    let (k2, nz2) = lod(&mut nl, &b);
    let f1 = align_fraction(&mut nl, &a, &k1);
    let f2full = align_fraction(&mut nl, &b, &k2);
    let f = (bits - 1) as usize;
    let fd = (divisor_bits - 1) as usize;
    let mut f2 = vec![NET0; f];
    f2[f - fd..f].copy_from_slice(&f2full[..fd]);
    let r = corrected_diff(&mut nl, table, &f1, &f2);
    let zero_a = nl.not(nz1);
    let zero_b = nl.not(nz2);
    let q = div_backend(&mut nl, bits, divisor_bits, &k1, &k2, &r, zero_a, zero_b);
    nl.output("q", &q);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "simdive_div");
    nl
}

/// Integrated hybrid multiplier-divider (paper Table 2 bottom row): one
/// unit with a `mode` input (0 = multiply, 1 = divide) sharing the LOD /
/// alignment front end; the fraction stage applies add-or-subtract via
/// conditional complement (the paper's 2's-complement module), and both
/// decoders drive a muxed output bus (`p`, 2N bits; divide fills the low N).
pub fn hybrid(bits: u32, w: u32) -> Netlist {
    let table = tables_for(w);
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", bits);
    let mode = nl.input("mode", 1)[0];
    let (k1, nz1) = lod(&mut nl, &a);
    let (k2, nz2) = lod(&mut nl, &b);
    let f1 = align_fraction(&mut nl, &a, &k1);
    let f2 = align_fraction(&mut nl, &b, &k2);

    // Two error banks (mul and div tables differ); each costs w LUTs.
    let cm = error_lut_bank(&mut nl, table, false, &f1, &f2);

    // Fraction stage, mul: t = f1 + f2 + cm.
    let t = {
        let mut t = nl.ternary_adder(&f1, &f2, &cm);
        t.truncate(f1.len() + 2);
        while t.len() < f1.len() + 2 {
            t.push(NET0);
        }
        t
    };
    // Fraction stage, div: r = f1 - f2 - cd (single chain pass).
    let r = corrected_diff(&mut nl, table, &f1, &f2);

    let zero_mul = nl.lut(&[nz1, nz2], |m| m != 3);
    let p = mul_backend(&mut nl, bits, &k1, &k2, &t, zero_mul);
    let zero_a = nl.not(nz1);
    let zero_b = nl.not(nz2);
    let q = div_backend(&mut nl, bits, bits, &k1, &k2, &r, zero_a, zero_b);

    // Output mux: mode ? {0, q} : p.
    let out: Vec<Net> = (0..2 * bits as usize)
        .map(|i| {
            let pv = p[i];
            let qv = q.get(i).copied().unwrap_or(NET0);
            if pv == qv { pv } else { nl.mux2(mode, pv, qv) }
        })
        .collect();
    nl.output("p", &out);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "simdive_hybrid");
    nl
}

/// The 32-bit SIMD SIMDive unit (paper Fig. 2(a)).
///
/// Inputs: `a`, `b` (32-bit packed), one-hot `precision` (4 bits:
/// 0 → 1×32, 1 → 2×16, 2 → 16+8+8, 3 → 4×8) and per-lane `mode` (4 bits,
/// bit `l` = divide for lane `l`; for fused lanes the lowest constituent
/// lane's bit applies). Output: packed 64-bit `p` per
/// [`crate::arith::simd::execute`] semantics.
pub fn simd32(w: u32) -> Netlist {
    simd32_with(tables_for(w))
}

/// As [`simd32`] with explicit correction tables (used for the Table-3
/// MBM-INZeD baseline via [`crate::arith::table::constant_tables`]).
pub fn simd32_with(table: &CorrectionTables) -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", 32);
    let b = nl.input("b", 32);
    let precision = nl.input("precision", 4);
    let mode = nl.input("mode", 4);

    let mut out64 = vec![NET0; 64];

    // Lane datapath generator: operands at [off, off+width), result into
    // out bits [2*off, 2*off + 2*width) under `enable`.
    let lane = |nl: &mut Netlist,
                    out64: &mut Vec<Net>,
                    off: usize,
                    width: u32,
                    enable: Net,
                    mode_bit: Net| {
        // Operand power gating (§3.2): a disabled lane sees constant-zero
        // operands, so none of its internal nets toggle — the "separate
        // data-size signals can power-gate each sub-unit" feature.
        let aw: Vec<Net> = a[off..off + width as usize]
            .iter()
            .map(|&n| nl.and2(n, enable))
            .collect();
        let bw: Vec<Net> = b[off..off + width as usize]
            .iter()
            .map(|&n| nl.and2(n, enable))
            .collect();
        let aw = &aw[..];
        let bw = &bw[..];
        let (k1, nz1) = lod(nl, aw);
        let (k2, nz2) = lod(nl, bw);
        let f1 = align_fraction(nl, aw, &k1);
        let f2 = align_fraction(nl, bw, &k2);
        let cm = error_lut_bank(nl, table, false, &f1, &f2);
        let t = {
            let mut t = nl.ternary_adder(&f1, &f2, &cm);
            t.truncate(f1.len() + 2);
            while t.len() < f1.len() + 2 {
                t.push(NET0);
            }
            t
        };
        let r = corrected_diff(nl, table, &f1, &f2);
        let zero_mul = nl.lut(&[nz1, nz2], |m| m != 3);
        let p = mul_backend(nl, width, &k1, &k2, &t, zero_mul);
        let zero_a = nl.not(nz1);
        let zero_b = nl.not(nz2);
        let q = div_backend(nl, width, width, &k1, &k2, &r, zero_a, zero_b);
        for i in 0..(2 * width as usize) {
            let pv = p[i];
            let qv = q.get(i).copied().unwrap_or(NET0);
            let slot = &mut out64[2 * off + i];
            // One fused LUT per bit: slot' = slot | (enable & (mode?q:p)).
            let prev = *slot;
            *slot = nl.lut(&[pv, qv, mode_bit, enable, prev], |m| {
                let sel = if (m >> 2) & 1 == 1 { (m >> 1) & 1 } else { m & 1 };
                ((m >> 4) & 1) == 1 || (((m >> 3) & 1) == 1 && sel == 1)
            });
        }
    };

    // Lane instances are shared across precision configs wherever the
    // (offset, width, mode-bit) triple coincides — the paper's resource
    // reuse between the 2×16 and 16+8+8 configurations.
    let p1 = precision[1];
    let p2 = precision[2];
    let p3 = precision[3];
    let p12 = nl.or2(p1, p2); // high 16-bit lane active in both configs
    let p23 = nl.or2(p2, p3); // low 8-bit lanes active in both configs
    // 1×32 lane.
    lane(&mut nl, &mut out64, 0, 32, precision[0], mode[0]);
    // Low 16-bit lane (2×16 only).
    lane(&mut nl, &mut out64, 0, 16, p1, mode[0]);
    // High 16-bit lane (2×16 and 16+8+8).
    lane(&mut nl, &mut out64, 16, 16, p12, mode[2]);
    // Two low 8-bit lanes (16+8+8 and 4×8).
    lane(&mut nl, &mut out64, 0, 8, p23, mode[0]);
    lane(&mut nl, &mut out64, 8, 8, p23, mode[1]);
    // Two high 8-bit lanes (4×8 only).
    lane(&mut nl, &mut out64, 16, 8, p3, mode[2]);
    lane(&mut nl, &mut out64, 24, 8, p3, mode[3]);

    nl.output("p", &out64);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "simdive_simd32");
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{self, simd, simdive};
    use crate::fabric::Simulator;

    #[test]
    fn mul_8bit_exhaustive_matches_behavioral() {
        let nl = mul(8, 8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        for a in 0..256u64 {
            for b in (0..256u64).step_by(3) {
                avals.push(a);
                bvals.push(b);
            }
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = simdive::simdive_mul_w(8, avals[i], bvals[i], 8);
            assert_eq!(outs[0].1[i], want, "{}x{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn mul_16bit_sampled_matches_behavioral() {
        for w in [0u32, 3, 8] {
            let nl = mul(16, w);
            let sim = Simulator::new(&nl);
            let mut rng = crate::util::Rng::new(31 + w as u64);
            let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
            let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
            let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
            for i in 0..avals.len() {
                let want = simdive::simdive_mul_w(16, avals[i], bvals[i], w);
                assert_eq!(outs[0].1[i], want, "w={w}: {}x{}", avals[i], bvals[i]);
            }
        }
    }

    #[test]
    fn div_16_8_sampled_matches_behavioral() {
        let nl = div(16, 8, 8);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(32);
        let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(256)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = simdive::simdive_div_w(16, avals[i], bvals[i], 8) & 0xFFFF;
            assert_eq!(outs[0].1[i], want, "{}/{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn div_8bit_exhaustive_matches_behavioral() {
        let nl = div(8, 8, 8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        for a in (0..256u64).step_by(3) {
            for b in 0..256u64 {
                avals.push(a);
                bvals.push(b);
            }
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = simdive::simdive_div_w(8, avals[i], bvals[i], 8);
            assert_eq!(outs[0].1[i], want, "{}/{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn hybrid_matches_both_modes() {
        let nl = hybrid(8, 8);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(33);
        for _ in 0..4_000 {
            let a = rng.below(256);
            let b = rng.below(256);
            let pm = sim.run_single(&[("a", a), ("b", b), ("mode", 0)])[0].1;
            assert_eq!(pm, simdive::simdive_mul_w(8, a, b, 8), "mul {a}x{b}");
            let pd = sim.run_single(&[("a", a), ("b", b), ("mode", 1)])[0].1;
            assert_eq!(pd, simdive::simdive_div_w(8, a, b, 8), "div {a}/{b}");
        }
    }

    #[test]
    fn simd32_matches_behavioral_packing() {
        let nl = simd32(8);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(34);
        for _ in 0..600 {
            for (pi, cfg) in simd::LaneCfg::ALL.iter().enumerate() {
                let lanes = cfg.lanes();
                let ops_a: Vec<u64> = lanes.iter().map(|&(_, w)| rng.operand(w)).collect();
                let ops_b: Vec<u64> = lanes.iter().map(|&(_, w)| rng.operand(w)).collect();
                let word = simd::SimdWord::pack(*cfg, &ops_a, &ops_b);
                let mut modes = [simd::LaneMode::Mul; 4];
                let mut mode_bits = 0u64;
                for (li, &(off, _)) in lanes.iter().enumerate() {
                    if rng.below(2) == 1 {
                        modes[li] = simd::LaneMode::Div;
                        mode_bits |= 1 << (off / 8);
                    }
                }
                let op = simd::SimdOp { cfg: *cfg, modes };
                let want = simd::execute(op, word, 8);
                let got = sim.run_single(&[
                    ("a", word.a as u64),
                    ("b", word.b as u64),
                    ("precision", 1 << pi),
                    ("mode", mode_bits),
                ])[0]
                    .1;
                assert_eq!(
                    got,
                    want,
                    "cfg {cfg:?} a={:#x} b={:#x} modes {modes:?}",
                    word.a,
                    word.b
                );
            }
        }
    }

    #[test]
    fn error_reduction_adds_no_carry_chain_delay() {
        // Paper §3.3: the correction rides in the same ternary-adder pass,
        // so SIMDive's critical path stays close to Mitchell's (well under
        // the relative gap to the accurate multiplier).
        let cal = crate::fabric::Calibration::default();
        let t_mitchell =
            crate::fabric::timing::analyze(&super::super::mitchell::mul(16), &cal).critical_ns;
        let t_simdive = crate::fabric::timing::analyze(&mul(16, 8), &cal).critical_ns;
        assert!(
            t_simdive < t_mitchell * 1.25,
            "simdive {t_simdive} vs mitchell {t_mitchell}"
        );
    }

    #[test]
    fn simd_area_scales_like_paper() {
        // Paper §4.2 point 4: 16-bit SISD hybrid → 32-bit SIMD grows ≈ 3×
        // in their fused-carry-chain design; our mux-replicated lanes carry
        // roughly 2× that sharing overhead (documented in EXPERIMENTS.md),
        // and crucially still scale far below the ~4× quadratic growth of
        // hierarchical array designs at the same configurability.
        let hybrid16 = crate::fabric::area::report(&hybrid(16, 8)).luts;
        let simd = crate::fabric::area::report(&simd32(8)).luts;
        let factor = simd as f64 / hybrid16 as f64;
        assert!(
            factor > 2.0 && factor < 8.0,
            "SIMD/SISD area factor {factor} (simd {simd}, hybrid16 {hybrid16})"
        );
    }

    #[test]
    fn tunable_w_shrinks_area() {
        let a0 = crate::fabric::area::report(&mul(16, 0)).luts;
        let a4 = crate::fabric::area::report(&mul(16, 4)).luts;
        let a8 = crate::fabric::area::report(&mul(16, 8)).luts;
        assert!(a0 < a4 && a4 < a8, "areas {a0} {a4} {a8}");
        assert_eq!(a8 - a4, 4, "one LUT per coefficient bit");
    }

    #[test]
    fn zero_operand_conventions() {
        let nl = mul(8, 8);
        let sim = Simulator::new(&nl);
        assert_eq!(sim.run_single(&[("a", 0), ("b", 200)])[0].1, 0);
        assert_eq!(sim.run_single(&[("a", 200), ("b", 0)])[0].1, 0);
        let nl = div(8, 8, 8);
        let sim = Simulator::new(&nl);
        assert_eq!(sim.run_single(&[("a", 0), ("b", 9)])[0].1, 0);
        assert_eq!(sim.run_single(&[("a", 9), ("b", 0)])[0].1, 255);
        assert_eq!(
            sim.run_single(&[("a", 0), ("b", 0)])[0].1,
            arith::simdive::simdive_div(8, 0, 0)
        );
    }
}
