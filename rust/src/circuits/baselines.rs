//! Gate-level baseline designs of Tables 2–3: the accurate soft IPs
//! (array multiplier, restoring divider), the truncated multipliers, CA,
//! MBM and INZeD (Mitchell + constant correction), and AAXD.

use super::components::{align_fraction, barrel_left, barrel_right, lod};
use super::mitchell::{div_backend, mul_backend};
use crate::fabric::netlist::{Net, Netlist, NET0, NET1};

// ---------------------------------------------------------------- helpers

/// First-level partial-product pair adder: `(a & bj) + ((a & bk) << 1)`.
/// One fractured LUT per bit (O6 = the propagate XOR of the two product
/// bits, O5 = the DI generate), the canonical Vivado mapping that absorbs
/// PP generation into the adder LUTs. `kill_low` kills the carries
/// generated below bit 2 (the CA approximation); 0 for exact.
fn pp_pair(nl: &mut Netlist, a: &[Net], bj: Net, bk: Net, kill_low: usize) -> Vec<Net> {
    let n = a.len();
    // Bit i: X_i = a_i & bj (i < n), Y_i = a_{i-1} & bk (1 <= i <= n).
    let mut s = Vec::with_capacity(n + 1);
    let mut di = Vec::with_capacity(n + 1);
    for i in 0..=n {
        match (i < n, i > 0) {
            (true, true) => {
                let ins = [a[i], bj, a[i - 1], bk];
                let (d, x) = nl.lut52(
                    &ins,
                    |m| m & 3 == 3,
                    |m| (m & 3 == 3) ^ ((m >> 2) & 3 == 3),
                );
                s.push(x);
                di.push(d);
            }
            (true, false) => {
                let x = nl.and2(a[0], bj);
                s.push(x);
                di.push(x);
            }
            (false, true) => {
                let y = nl.and2(a[n - 1], bk);
                s.push(y);
                di.push(NET0);
            }
            (false, false) => unreachable!(),
        }
    }
    if kill_low == 0 {
        let (sum, co) = nl.carry_chain(&s, &di, NET0);
        let mut out = sum;
        out.push(co);
        out
    } else {
        // Low bits: plain sums, no carry chain (generated carries killed).
        let mut out: Vec<Net> = s[..kill_low].to_vec();
        let (sum, co) = nl.carry_chain(&s[kill_low..], &di[kill_low..], NET0);
        out.extend(sum);
        out.push(co);
        out
    }
}

/// Add two buses at bit offsets: result covers `[min_off, …)`.
fn add_aligned(
    nl: &mut Netlist,
    x: (&[Net], usize),
    y: (&[Net], usize),
) -> (Vec<Net>, usize) {
    let (xb, xo) = x;
    let (yb, yo) = y;
    let (lo, hi) = if xo <= yo { (x, y) } else { (y, x) };
    let shift = hi.1 - lo.1;
    let mut out: Vec<Net> = lo.0[..shift.min(lo.0.len())].to_vec();
    let lo_hi = if lo.0.len() > shift { &lo.0[shift..] } else { &[][..] };
    let (sum, co) = nl.adder(lo_hi, hi.0, NET0);
    out.extend(sum);
    out.push(co);
    let _ = (xb, yb);
    (out, lo.1)
}

/// Reduce a list of (bus, offset) partial sums with a balanced adder tree.
fn adder_tree(nl: &mut Netlist, mut items: Vec<(Vec<Net>, usize)>) -> (Vec<Net>, usize) {
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let (Some(a), b) = (it.next(), it.next()) {
            match b {
                Some(b) => {
                    let (bus, off) = add_aligned(nl, (&a.0, a.1), (&b.0, b.1));
                    next.push((bus, off));
                }
                None => next.push(a),
            }
        }
        items = next;
    }
    items.pop().unwrap()
}

/// Core array-multiplier structure over (optionally masked) operands.
/// `kill_low` > 0 selects the CA approximation at the first level.
fn array_mul_core(bits: u32, am: u64, bm: u64, kill_low: usize) -> Netlist {
    let mut nl = Netlist::new();
    let a_in = nl.input("a", bits);
    let b_in = nl.input("b", bits);
    // Masked operand views: dropped bits become constant 0 (their LUTs
    // disappear — truncation's area saving).
    let a: Vec<Net> = a_in
        .iter()
        .enumerate()
        .map(|(i, &n)| if (am >> i) & 1 == 1 { n } else { NET0 })
        .collect();
    let b: Vec<Net> = b_in
        .iter()
        .enumerate()
        .map(|(i, &n)| if (bm >> i) & 1 == 1 { n } else { NET0 })
        .collect();
    // Trim constant-zero tails of `a` (masked-away high bits).
    let a_eff: Vec<Net> = {
        let hi = (0..bits as usize).rev().find(|&i| a[i] != NET0).map_or(0, |i| i + 1);
        a[..hi].to_vec()
    };
    let mut partials: Vec<(Vec<Net>, usize)> = Vec::new();
    for j in 0..(bits as usize / 2) {
        let bj = b[2 * j];
        let bk = b[2 * j + 1];
        if bj == NET0 && bk == NET0 {
            continue;
        }
        let bus = pp_pair(&mut nl, &a_eff, bj, bk, kill_low);
        partials.push((bus, 2 * j));
    }
    let out = if partials.is_empty() {
        (vec![NET0; 2 * bits as usize], 0)
    } else {
        adder_tree(&mut nl, partials)
    };
    // Assemble the 2N-bit product bus.
    let mut p = vec![NET0; 2 * bits as usize];
    for (i, &n) in out.0.iter().enumerate() {
        let pos = out.1 + i;
        if pos < p.len() {
            p[pos] = n;
        }
    }
    nl.output("p", &p);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "array_mul");
    nl
}

/// Accurate array multiplier (Xilinx LogiCORE multiplier stand-in).
pub fn array_mul(bits: u32) -> Netlist {
    array_mul_core(bits, u64::MAX, u64::MAX, 0)
}

/// Truncated multiplier (Table 2/3 "Trunc" rows): per-8-bit-segment
/// 7-bit truncation per `crate::arith::trunc`.
pub fn trunc_mul(bits: u32, seven_a: bool, seven_b: bool) -> Netlist {
    let seg7 = {
        let mut m = 0u64;
        for s in 0..(bits / 8) {
            m |= 0xFEu64 << (8 * s);
        }
        m
    };
    let am = if seven_a { seg7 } else { crate::arith::max_val(bits) & !1 };
    let bm = if seven_b { seg7 } else { crate::arith::max_val(bits) & !1 };
    array_mul_core(bits, am, bm, 0)
}

/// CA approximate multiplier [30]: truncated-carry first level.
pub fn ca_mul(bits: u32) -> Netlist {
    array_mul_core(bits, u64::MAX, u64::MAX, 2)
}

/// Shared restoring-division array: `a` (dividend nets, LSB first) divided
/// by `b` (divisor nets), quotient has `a.len()` bits. One fractured LUT
/// per remainder bit per stage — the restore mux of stage *i* is fused
/// into stage *i+1*'s subtract-propagate LUT (O5 = muxed remainder bit for
/// the chain DI, O6 = that bit ⊕ !divisor), the canonical Vivado mapping.
pub(crate) fn restoring_core(nl: &mut Netlist, a: &[Net], b: &[Net]) -> Vec<Net> {
    let n = a.len();
    let dr = b.len(); // remainder needs dr+1 bits
    // State carried between stages: for each remainder bit, the *pair*
    // (r_keep, r_sub) plus the stage's no-borrow select — the mux is
    // evaluated lazily inside the next stage's LUT.
    let mut pend: Option<(Vec<Net>, Vec<Net>, Net)> = None; // (rp, sub, nb)
    let mut q = vec![NET0; n];
    for i in (0..n).rev() {
        // Build this stage's R' bits as lazy muxes: R'_0 = a_i,
        // R'_j = mux(nb, rp_{j-1}, sub_{j-1}).
        let w = dr + 1;
        let mut s_nets = Vec::with_capacity(w);
        let mut d_nets = Vec::with_capacity(w);
        for j in 0..w {
            let bj = if j < dr { b[j] } else { NET0 };
            match (&pend, j) {
                (_, 0) => {
                    // propagate = a_i ⊕ !b_0 ; DI = a_i.
                    let s = nl.lut(&[a[i], bj], |m| (m & 1) ^ (((m >> 1) & 1) ^ 1) == 1);
                    s_nets.push(s);
                    d_nets.push(a[i]);
                }
                (None, _) => {
                    // First stage: upper R' bits are 0 → propagate = !b_j.
                    let s = nl.lut(&[bj], |m| m & 1 == 0);
                    s_nets.push(s);
                    d_nets.push(NET0);
                }
                (Some((rp, sub, nb)), _) => {
                    let rj = rp[j - 1];
                    let sj = sub[j - 1];
                    // O5 = nb ? sub : rp ; O6 = O5 ⊕ !b_j.
                    let ins = [sj, rj, *nb, bj];
                    let (d, s) = nl.lut52(
                        &ins,
                        |m| if (m >> 2) & 1 == 1 { m & 1 == 1 } else { (m >> 1) & 1 == 1 },
                        |m| {
                            let muxed = if (m >> 2) & 1 == 1 { m & 1 } else { (m >> 1) & 1 };
                            muxed ^ ((m >> 3) & 1) ^ 1 == 1
                        },
                    );
                    s_nets.push(s);
                    d_nets.push(d);
                }
            }
        }
        let (sub, no_borrow) = nl.carry_chain(&s_nets, &d_nets, NET1);
        q[i] = no_borrow;
        // The DI nets are exactly this stage's R' bits (DI_j = R'_j), so
        // the next stage's fused muxes take (R', sub, nb) directly.
        pend = Some((d_nets, sub, no_borrow));
    }
    q
}

/// Restoring array divider (Xilinx LogiCORE divider stand-in):
/// `bits`-wide dividend, `divisor_bits`-wide divisor, `bits`-wide quotient.
pub fn restoring_div(bits: u32, divisor_bits: u32) -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", divisor_bits);
    let q = restoring_core(&mut nl, &a, &b);
    nl.output("q", &q);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "restoring_div");
    nl
}

/// MBM multiplier [28]: Mitchell + the constant 1/16 compensation, riding
/// in a ternary-adder pass like SIMDive's correction.
pub fn mbm_mul(bits: u32) -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", bits);
    let (k1, nz1) = lod(&mut nl, &a);
    let (k2, nz2) = lod(&mut nl, &b);
    let f1 = align_fraction(&mut nl, &a, &k1);
    let f2 = align_fraction(&mut nl, &b, &k2);
    let f = f1.len();
    // Constant 1/16 in F-bit units = 2^(F-4).
    let cbus = nl.constant(f as u32, 1u64 << (f - 4));
    let mut t = nl.ternary_adder(&f1, &f2, &cbus);
    t.truncate(f + 2);
    while t.len() < f + 2 {
        t.push(NET0);
    }
    let zero = nl.lut(&[nz1, nz2], |m| m != 3);
    let p = mul_backend(&mut nl, bits, &k1, &k2, &t, zero);
    nl.output("p", &p);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "mbm_mul");
    nl
}

/// INZeD divider [29]: Mitchell divider + constant negative compensation.
pub fn inzed_div(bits: u32, divisor_bits: u32) -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", divisor_bits);
    let (k1, nz1) = lod(&mut nl, &a);
    let (k2, nz2) = lod(&mut nl, &b);
    let f1 = align_fraction(&mut nl, &a, &k1);
    let f2full = align_fraction(&mut nl, &b, &k2);
    let f = (bits - 1) as usize;
    let fd = (divisor_bits - 1) as usize;
    let mut f2 = vec![NET0; f];
    f2[f - fd..f].copy_from_slice(&f2full[..fd]);

    // r = f1 - f2 + c  (c < 0): r = f1 + ~f2 + const where
    // const = (1 + c) mod 2^(F+2) — both two's-complement +1 and the
    // negative constant folded together.
    let width = f + 2;
    let c = crate::arith::saadat::inzed_coeff_f_units(bits);
    let konst = ((1i64 + c) as i128).rem_euclid(1i128 << width) as u64;
    let f1x: Vec<Net> = (0..width).map(|i| f1.get(i).copied().unwrap_or(NET0)).collect();
    let nf2: Vec<Net> = (0..width)
        .map(|i| f2.get(i).map(|&x| nl.not(x)).unwrap_or(NET1))
        .collect();
    let kbus = nl.constant(width as u32, konst);
    let mut r = nl.ternary_adder(&f1x, &nf2, &kbus);
    r.truncate(width);

    let zero_a = nl.not(nz1);
    let zero_b = nl.not(nz2);
    let q = div_backend(&mut nl, bits, divisor_bits, &k1, &k2, &r, zero_a, zero_b);
    nl.output("q", &q);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "inzed_div");
    nl
}

/// AAXD divider [13]: dynamic truncation around the leading ones — keep
/// `m` dividend / `n` divisor bits, divide exactly with a small restoring
/// array, shift the quotient back.
pub fn aaxd_div(bits: u32, divisor_bits: u32, m: u32, n: u32) -> Netlist {
    let kw = (31 - bits.leading_zeros()) as usize; // k bits for dividend
    let kwb = (31 - divisor_bits.leading_zeros()) as usize;
    let mut nl = Netlist::new();
    let a = nl.input("a", bits);
    let b = nl.input("b", divisor_bits);
    let (ka, nza) = lod(&mut nl, &a);
    let (kb, nzb) = lod(&mut nl, &b);

    // sa = max(0, ka + 1 - m): subtract in kw+1-bit two's complement, then
    // AND with !sign to clamp at 0.
    let clamp_shift = |nl: &mut Netlist, k: &[Net], keep: u32, w: usize| -> Vec<Net> {
        // s = k + (1 - keep) ; sign bit = borrow.
        let konst = ((1i64 - keep as i64) as i128).rem_euclid(1i128 << (w + 1)) as u64;
        let kb = nl.constant(w as u32 + 1, konst);
        let kx: Vec<Net> = (0..=w).map(|i| k.get(i).copied().unwrap_or(NET0)).collect();
        let (s, _) = nl.adder(&kx, &kb, NET0);
        let sign = s[w];
        let nsign = nl.not(sign);
        (0..w).map(|i| nl.and2(s[i], nsign)).collect()
    };
    let sa = clamp_shift(&mut nl, &ka, m, kw);
    let sb = clamp_shift(&mut nl, &kb, n, kwb);

    // at = a >> sa (m significant bits), bt = b >> sb (n significant bits).
    let at = barrel_right(&mut nl, &a, &sa, m as usize);
    let bt = barrel_right(&mut nl, &b, &sb, n as usize);

    // Small exact restoring divider at / bt (m-bit quotient).
    let qsmall = restoring_core(&mut nl, &at, &bt);

    // Quotient scale-back: q = qsmall << (sa - sb). Bias by (divisor_bits -
    // n) so the amount is non-negative: d = sa - sb + bias; q = (qsmall
    // << d) >> bias.
    let bias = (divisor_bits - n) as usize;
    let dw = kw + 2;
    let sax: Vec<Net> = (0..dw).map(|i| sa.get(i).copied().unwrap_or(NET0)).collect();
    let nsb: Vec<Net> = (0..dw)
        .map(|i| sb.get(i).map(|&x| nl.not(x)).unwrap_or(NET1))
        .collect();
    let konst = ((bias as i64 + 1) as u64) & ((1u64 << dw) - 1);
    let kbus = nl.constant(dw as u32, konst);
    let mut d = nl.ternary_adder(&sax, &nsb, &kbus);
    d.truncate(dw);
    let shifted = barrel_left(&mut nl, &qsmall, &d, bias + bits as usize);
    let q = &shifted[bias..bias + bits as usize];

    // Gating: a == 0 → 0, b == 0 → all ones.
    let zero_a = nl.not(nza);
    let zero_b = nl.not(nzb);
    let out: Vec<Net> = q
        .iter()
        .map(|&qb| {
            nl.lut(&[qb, zero_a, zero_b], |m| {
                (m >> 2) & 1 == 1 || ((m >> 1) & 1 == 0 && m & 1 == 1)
            })
        })
        .collect();
    nl.output("q", &out);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "aaxd_div");
    nl
}

/// Accurate variable-precision SIMD multiplier (Perri et al. [24, 25],
/// the Table-3 "Accurate Multiplier" baseline): a 32-bit partial-product
/// array whose cross-lane products are gated by the one-hot `precision`
/// control. Lane products occupy disjoint 2N-bit fields of the 64-bit
/// output, so the ordinary adder tree composes them without cross-lane
/// carries.
pub fn simd_accurate_mul() -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input("a", 32);
    let b = nl.input("b", 32);
    let precision = nl.input("precision", 4);
    // same-lane gate per (a-byte-block, b-byte-block): OR of precision
    // configs in which blocks i and j belong to one lane.
    let lane_of = |cfg: usize, blk: usize| -> usize {
        match cfg {
            0 => 0,
            1 => blk / 2,
            2 => {
                if blk >= 2 { 2 } else { blk }
            }
            _ => blk,
        }
    };
    let mut gate = [[NET0; 4]; 4];
    for bi in 0..4 {
        for bj in 0..4 {
            let cfgs: Vec<Net> = (0..4)
                .filter(|&c| lane_of(c, bi) == lane_of(c, bj))
                .map(|c| precision[c])
                .collect();
            gate[bi][bj] = nl.or_tree(&cfgs);
        }
    }
    let mut partials: Vec<(Vec<Net>, usize)> = Vec::new();
    for j in 0..16 {
        let (bjn, bkn) = (b[2 * j], b[2 * j + 1]);
        let jblk = (2 * j) / 8;
        // Gated pp-pair: one Lut52 per bit with the lane gate folded in.
        let mut s = Vec::with_capacity(33);
        let mut di = Vec::with_capacity(33);
        for i in 0..=32usize {
            let x_ins = if i < 32 { Some((a[i], gate[i / 8][jblk])) } else { None };
            let y_ins = if i > 0 { Some((a[i - 1], gate[(i - 1) / 8][jblk])) } else { None };
            match (x_ins, y_ins) {
                (Some((ai, gi)), Some((ap, gp))) => {
                    let ins = [ai, bjn, gi, ap, bkn, gp];
                    let (d, sx) = nl.lut52(
                        &ins,
                        |m| m & 7 == 7,
                        |m| (m & 7 == 7) ^ ((m >> 3) & 7 == 7),
                    );
                    s.push(sx);
                    di.push(d);
                }
                (Some((ai, gi)), None) => {
                    let x = nl.lut(&[ai, bjn, gi], |m| m == 7);
                    s.push(x);
                    di.push(x);
                }
                (None, Some((ap, gp))) => {
                    let y = nl.lut(&[ap, bkn, gp], |m| m == 7);
                    s.push(y);
                    di.push(NET0);
                }
                (None, None) => unreachable!(),
            }
        }
        let (sum, co) = nl.carry_chain(&s, &di, NET0);
        let mut bus = sum;
        bus.push(co);
        partials.push((bus, 2 * j));
    }
    // Output field placement: row-pair j of lane starting at byte L
    // contributes at output offset 2·(8L) + (2j − 8L) = 2j + 8L… which
    // depends on the lane config. In all configs a product bit of weight
    // 2^(i+2j) within lane [off..) lands at output bit 2·off + (i+2j−2·off)
    // = i + 2j + (off)… wait — lane result field starts at 2·off and the
    // in-lane product has weight i′+j′ with i′ = i−off, j′ = 2j−off:
    // output bit = 2·off + (i−off) + (2j−off) = i + 2j. Offsets therefore
    // coincide across configs and the plain tree is config-independent.
    let out = adder_tree(&mut nl, partials);
    let mut p = vec![NET0; 64];
    for (i, &n) in out.0.iter().enumerate() {
        let pos = out.1 + i;
        if pos < 64 {
            p[pos] = n;
        }
    }
    nl.output("p", &p);
    #[cfg(debug_assertions)]
    crate::fabric::analyze::debug_validate(&nl, "simd_accurate_mul");
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith;
    use crate::fabric::{area, timing, Calibration, Simulator};

    #[test]
    fn array_mul_8bit_exhaustive() {
        let nl = array_mul(8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        for a in (0..256u64).step_by(3) {
            for b in 0..256u64 {
                avals.push(a);
                bvals.push(b);
            }
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            assert_eq!(outs[0].1[i], avals[i] * bvals[i], "{}x{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn array_mul_16bit_sampled() {
        let nl = array_mul(16);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(41);
        let avals: Vec<u64> = (0..20_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..20_000).map(|_| rng.below(65536)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            assert_eq!(outs[0].1[i], avals[i] * bvals[i]);
        }
    }

    #[test]
    fn array_mul_16_area_matches_vivado_ip() {
        // Paper Table 2: accurate multiplier IP = 287 LUTs. Our structural
        // mapping must land in the same neighbourhood (±20%).
        let r = area::report(&array_mul(16));
        assert!(r.luts >= 230 && r.luts <= 345, "array mul LUTs {}", r.luts);
    }

    #[test]
    fn restoring_div_16_8_exhaustive_slice() {
        let nl = restoring_div(16, 8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..30_000 {
            avals.push(rng.below(65536));
            bvals.push(rng.range(1, 255));
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            assert_eq!(
                outs[0].1[i],
                avals[i] / bvals[i],
                "{}/{}",
                avals[i],
                bvals[i]
            );
        }
    }

    #[test]
    fn restoring_div_area_and_delay_match_ip() {
        // Paper Table 2: divider IP 168 LUTs, 21.4 ns — the long iterative
        // carry-chain cascade is the defining feature.
        let nl = restoring_div(16, 8);
        let r = area::report(&nl);
        assert!(r.luts >= 120 && r.luts <= 220, "restoring div LUTs {}", r.luts);
        let t = timing::analyze(&nl, &Calibration::default());
        let tm = timing::analyze(&array_mul(16), &Calibration::default());
        assert!(
            t.critical_ns > 2.5 * tm.critical_ns,
            "divider ({} ns) must be several times slower than multiplier ({} ns)",
            t.critical_ns,
            tm.critical_ns
        );
    }

    #[test]
    fn trunc_mul_matches_behavioral() {
        for (sa, sb) in [(true, true), (false, true)] {
            let nl = trunc_mul(16, sa, sb);
            let sim = Simulator::new(&nl);
            let mut rng = crate::util::Rng::new(43);
            let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
            let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
            let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
            for i in 0..avals.len() {
                let want = arith::trunc::trunc_mul(16, sa, sb, avals[i], bvals[i]);
                assert_eq!(outs[0].1[i], want, "({sa},{sb}) {}x{}", avals[i], bvals[i]);
            }
        }
    }

    #[test]
    fn trunc_area_below_accurate() {
        let acc = area::report(&array_mul(16)).luts;
        let t77 = area::report(&trunc_mul(16, true, true)).luts;
        let t157 = area::report(&trunc_mul(16, false, true)).luts;
        assert!(t77 < acc, "7x7 {t77} !< accurate {acc}");
        assert!(t157 < acc, "15x7 {t157} !< accurate {acc}");
    }

    #[test]
    fn ca_mul_matches_behavioral() {
        let nl = ca_mul(16);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(44);
        let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::ca::ca_mul(16, avals[i], bvals[i]);
            assert_eq!(outs[0].1[i], want, "{}x{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn ca_mul_8bit_exhaustive() {
        let nl = ca_mul(8);
        let sim = Simulator::new(&nl);
        let mut avals = Vec::new();
        let mut bvals = Vec::new();
        for a in (0..256u64).step_by(5) {
            for b in 0..256u64 {
                avals.push(a);
                bvals.push(b);
            }
        }
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            assert_eq!(outs[0].1[i], arith::ca::ca_mul(8, avals[i], bvals[i]));
        }
    }

    #[test]
    fn mbm_mul_matches_behavioral() {
        let nl = mbm_mul(16);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(45);
        let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::saadat::mbm_mul(16, avals[i], bvals[i]);
            assert_eq!(outs[0].1[i], want, "{}x{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn inzed_div_matches_behavioral() {
        let nl = inzed_div(16, 8);
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(46);
        let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
        let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(256)).collect();
        let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
        for i in 0..avals.len() {
            let want = arith::saadat::inzed_div(16, avals[i], bvals[i]) & 0xFFFF;
            assert_eq!(outs[0].1[i], want, "{}/{}", avals[i], bvals[i]);
        }
    }

    #[test]
    fn aaxd_div_matches_behavioral() {
        for (m, n) in [(8u32, 4u32), (12, 6)] {
            let nl = aaxd_div(16, 8, m, n);
            let sim = Simulator::new(&nl);
            let mut rng = crate::util::Rng::new(47 + m as u64);
            let avals: Vec<u64> = (0..10_000).map(|_| rng.below(65536)).collect();
            let bvals: Vec<u64> = (0..10_000).map(|_| rng.below(256)).collect();
            let outs = sim.run_batch(&[("a", &avals), ("b", &bvals)]);
            for i in 0..avals.len() {
                let want = arith::aaxd::aaxd_div(16, m, n, avals[i], bvals[i]) & 0xFFFF;
                assert_eq!(outs[0].1[i], want, "({m}/{n}) {}/{}", avals[i], bvals[i]);
            }
        }
    }

    #[test]
    fn simd_accurate_mul_matches_lane_products() {
        let nl = simd_accurate_mul();
        let sim = Simulator::new(&nl);
        let mut rng = crate::util::Rng::new(48);
        for _ in 0..400 {
            for (pi, cfg) in arith::simd::LaneCfg::ALL.iter().enumerate() {
                let lanes = cfg.lanes();
                let ops_a: Vec<u64> = lanes.iter().map(|&(_, w)| rng.operand(w)).collect();
                let ops_b: Vec<u64> = lanes.iter().map(|&(_, w)| rng.operand(w)).collect();
                let word = arith::simd::SimdWord::pack(*cfg, &ops_a, &ops_b);
                let got = sim.run_single(&[
                    ("a", word.a as u64),
                    ("b", word.b as u64),
                    ("precision", 1 << pi),
                ])[0]
                    .1;
                let mut want = 0u64;
                for (l, &(off, _w)) in lanes.iter().enumerate() {
                    want |= (ops_a[l] * ops_b[l]) << (2 * off);
                }
                assert_eq!(got, want, "{cfg:?} a={:#x} b={:#x}", word.a, word.b);
            }
        }
    }

    #[test]
    fn simd_accurate_mul_area_near_paper() {
        // Paper Table 3: accurate SIMD multiplier [25] = 1125 LUTs.
        let r = area::report(&simd_accurate_mul());
        assert!(r.luts >= 900 && r.luts <= 1500, "SIMD accurate mul LUTs {}", r.luts);
    }

    #[test]
    fn aaxd_faster_than_full_divider() {
        let cal = Calibration::default();
        let full = timing::analyze(&restoring_div(16, 8), &cal).critical_ns;
        let axd = timing::analyze(&aaxd_div(16, 8, 8, 4), &cal).critical_ns;
        assert!(axd < full, "AAXD {axd} !< accurate {full}");
    }
}
